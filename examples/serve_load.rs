//! Closed-loop load generator for the serving engine: N client threads each
//! issue blocking generate RPCs back-to-back against a spawned engine,
//! exercising continuous batching from *outside* the engine (requests
//! arrive asynchronously, sequences join/leave the batch between waves).
//!
//! With more than one client the reported batch occupancy should exceed 1 —
//! the scheduler is merging independent request streams into shared decode
//! waves — while per-request results stay identical to serial execution.
//! `--shared-prefix N` makes every prompt start with the same N tokens (a
//! system-prompt workload): with the prefix cache enabled the engine
//! should report prefix hits and reuse K/V across clients. Sharing is
//! block-granular, so hits need `shared-prefix >= kv-block` (the default
//! kv-block here is 8 to match the default shared prefix).
//!
//! `--kv-store <label>` additionally quantizes the KV arena itself
//! (block-granular codes + po2 scales through the quant registry, e.g.
//! `fp8_e3m4` or `int8_sr`); the default `f32` keeps today's exact path.
//!
//! Run: cargo run --release --example serve_load -- \
//!        [--clients 8] [--requests-per-client 4] [--store fp8_e3m4]
//!        [--max-batch 8] [--threads 2] [--prompt-len 12] [--max-new 16]
//!        [--kv-block 8] [--kv-blocks 0] [--prefill-chunk 8]
//!        [--kv-store f32] [--shared-prefix 8] [--no-prefix-cache]

use gaussws::config::schema::{Arch, ModelConfig};
use gaussws::data::{SynthCorpus, SynthSpec};
use gaussws::nn::transformer::Transformer;
use gaussws::serve::{Engine, EngineConfig, GenRequest, WeightStore};
use gaussws::util::stats::percentile;
use gaussws::util::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let clients = args.usize_or("clients", 8);
    let per_client = args.usize_or("requests-per-client", 4);
    let store_mode = gaussws::quant::resolve(args.get_or("store", "fp8_e3m4"))?;
    let max_batch = args.usize_or("max-batch", 8);
    let threads = args.usize_or("threads", 2);
    let prompt_len = args.usize_or("prompt-len", 12);
    let max_new = args.usize_or("max-new", 16);
    let seed = args.u64_or("seed", 2026);
    let kv_block = args.usize_or("kv-block", 8);
    let kv_blocks = args.usize_or("kv-blocks", 0);
    let prefill_chunk = args.usize_or("prefill-chunk", 8);
    let prefix_cache = !args.flag("no-prefix-cache");
    let shared_prefix = args.usize_or("shared-prefix", 8).min(prompt_len.saturating_sub(1));

    // demo weights: random init snapshotted through the quantized store
    // (swap in `gaussws serve --checkpoint` for trained weights)
    let cfg = ModelConfig::tiny(Arch::Gpt2);
    let model = Transformer::new(cfg.clone());
    let params = model.init_params(seed);
    let store = WeightStore::from_params(&params, &cfg, store_mode, seed)?;
    println!(
        "store {}: {} -> {} bytes ({:.2}x)",
        store.label(),
        store.master_bytes(),
        store.bytes(),
        store.master_bytes() as f64 / store.bytes() as f64
    );

    let kv_scheme = gaussws::quant::resolve(args.get_or("kv-store", "f32"))?;
    let ecfg = EngineConfig {
        max_batch,
        kv_block,
        kv_blocks,
        prefill_chunk,
        prefix_cache,
        threads,
        eos: None,
        capacity: usize::MAX,
        kv_scheme,
        kv_seed: seed,
    };
    ecfg.validate_for(&cfg)?;
    let engine = Engine::from_store(&store, ecfg);
    println!(
        "kv store: {} ({} B/position encoded)",
        engine.kv_store(),
        engine.kv_bytes_per_position()
    );
    let handle = engine.spawn();

    let corpus = SynthCorpus::generate(SynthSpec {
        vocab: cfg.vocab,
        len: 1 << 16,
        seed: seed ^ 0xFEED,
        ..Default::default()
    });
    let span = corpus.tokens.len() - prompt_len - 1;
    // the shared head every prompt starts with (system-prompt workload)
    let head: Vec<usize> =
        corpus.tokens[29..29 + shared_prefix].iter().map(|&t| t as usize).collect();

    println!(
        "{clients} closed-loop clients × {per_client} requests, max_new {max_new}, \
         shared prefix {shared_prefix}, prefix cache {}...",
        if prefix_cache { "on" } else { "off" }
    );
    let mut joins = Vec::new();
    for c in 0..clients {
        let client = handle.client();
        let head = head.clone();
        let prompts: Vec<Vec<usize>> = (0..per_client)
            .map(|k| {
                let start = ((c * per_client + k) * 1777 + 13) % span;
                let mut p = head.clone();
                p.extend(
                    corpus.tokens[start..start + prompt_len - shared_prefix]
                        .iter()
                        .map(|&t| t as usize),
                );
                p
            })
            .collect();
        joins.push(std::thread::spawn(move || -> anyhow::Result<Vec<f64>> {
            let mut latencies = Vec::new();
            for (k, prompt) in prompts.into_iter().enumerate() {
                let id = (c * 10_000 + k) as u64;
                let resp = client.generate(GenRequest::greedy(id, prompt, max_new))?;
                assert_eq!(resp.id, id);
                assert_eq!(resp.tokens.len(), max_new);
                latencies.push(resp.total_s * 1e3);
            }
            Ok(latencies)
        }));
    }
    let mut client_lat = Vec::new();
    for j in joins {
        client_lat.extend(j.join().expect("client thread panicked")?);
    }
    let stats = handle.shutdown();

    println!();
    println!("{}", stats.render(store.label()));
    println!(
        "client-side latency p50/p95: {:.1} / {:.1} ms over {} calls",
        percentile(&client_lat, 50.0),
        percentile(&client_lat, 95.0),
        client_lat.len()
    );
    if clients > 1 && stats.max_occupancy() <= 1 {
        println!("WARNING: batch occupancy never exceeded 1 — continuous batching inactive");
    } else {
        println!(
            "continuous batching active: mean occupancy {:.2}, max {}",
            stats.mean_occupancy(),
            stats.max_occupancy()
        );
    }
    if prefix_cache && shared_prefix > 0 && stats.prefix_hits() == 0 {
        println!("WARNING: shared-prefix workload produced no prefix hits");
    } else if prefix_cache {
        println!(
            "prefix cache: {} hits ({:.0}% of lookups), {} K/V positions reused",
            stats.prefix_hits(),
            stats.prefix_hit_rate() * 100.0,
            stats.prefix_tokens_reused()
        );
    }
    Ok(())
}
