//! Load-generator example, now a thin wrapper over the declarative
//! workload framework (`gaussws::load`): pick a named corpus scenario or
//! shape a custom spec from flags, then drive it through the in-process
//! engine or the loopback TCP front end.
//!
//! The old ad-hoc flag soup (hand-rolled prompts, per-client loops) lives
//! on as a [`WorkloadSpec`] — distributions, shared-prefix mixture,
//! arrival schedule and deadline mix are spec fields, and the request
//! stream is seeded + deterministic, so any run here can be reproduced
//! bit-for-bit by `gaussws load` or the conformance tests.
//!
//! Run: cargo run --release --example serve_load -- \
//!        [--scenario bursty-chat|long-doc-prefill|many-short|preemption-storm]
//!        [--driver in-process|direct|tcp]
//!      or shape a custom workload:
//!        [--clients 8] [--requests 32] [--prompt-len "uniform 4 16"]
//!        [--max-new "fixed 8"] [--arrival "bursts 4 10"]
//!        [--shared-prefix 8] [--shared-frac 0.5] [--deadline-ms 0(off)]
//!        [--max-batch 8] [--kv-block 8] [--kv-blocks 0] [--threads 2]
//!        [--seed 2026]

use gaussws::load::{run, run_scenario, tiny_model, Arrival, Dist, Driver, Scenario, WorkloadSpec};
use gaussws::serve::{EngineConfig, NetServerConfig};
use gaussws::util::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let seed = args.u64_or("seed", 2026);
    let driver = match args.get_or("driver", "in-process") {
        "direct" => Driver::Direct,
        "in-process" => Driver::InProcess,
        "tcp" => Driver::Tcp(NetServerConfig::default()),
        other => anyhow::bail!("unknown --driver '{other}' (direct|in-process|tcp)"),
    };

    let (spec, outcome) = if let Some(name) = args.get("scenario") {
        let sc = Scenario::by_name(name)?;
        println!("scenario {}: {}", sc.spec.name, sc.about);
        let outcome = run_scenario(&sc, driver.clone(), seed)?;
        (sc.spec, outcome)
    } else {
        let shared_prefix = args.usize_or("shared-prefix", 8);
        let deadline_ms = args.u64_or("deadline-ms", 0);
        let mut spec = WorkloadSpec::new("serve-load-example")
            .clients(args.usize_or("clients", 8))
            .requests(args.usize_or("requests", 32))
            .prompt_len(Dist::parse(args.get_or("prompt-len", "uniform 4 16"))?)
            .max_new(Dist::parse(args.get_or("max-new", "fixed 8"))?)
            .shared_prefix(shared_prefix, args.f64_or("shared-frac", 0.5))
            .arrival(Arrival::parse(args.get_or("arrival", "closed"))?)
            .seed(seed);
        if deadline_ms > 0 {
            spec = spec.deadlines(deadline_ms, args.f64_or("deadline-frac", 1.0));
        }
        spec.validate()?;
        let (cfg, params) = tiny_model(seed);
        let ecfg = EngineConfig {
            max_batch: args.usize_or("max-batch", 8),
            kv_block: args.usize_or("kv-block", 8),
            kv_blocks: args.usize_or("kv-blocks", 0),
            prefill_chunk: args.usize_or("prefill-chunk", 8),
            prefix_cache: !args.flag("no-prefix-cache"),
            threads: args.usize_or("threads", 2),
            ..EngineConfig::default()
        };
        ecfg.validate_for(&cfg)?;
        let outcome = run(&spec, cfg, params, ecfg, driver.clone())?;
        (spec, outcome)
    };

    println!();
    println!("{}", outcome.stats.render(&format!("{} ({})", spec.name, driver.label())));
    let stats = &outcome.stats;
    if spec.clients > 1 && stats.max_occupancy() <= 1 {
        println!("WARNING: batch occupancy never exceeded 1 — continuous batching inactive");
    } else if spec.clients > 1 {
        println!(
            "continuous batching active: mean occupancy {:.2}, max {}",
            stats.mean_occupancy(),
            stats.max_occupancy()
        );
    }
    if spec.shared_prefix_len > 0 && stats.prefix_hits() > 0 {
        println!(
            "prefix cache: {} hits ({:.0}% of lookups), {} K/V positions reused",
            stats.prefix_hits(),
            stats.prefix_hit_rate() * 100.0,
            stats.prefix_tokens_reused()
        );
    }
    if outcome.failed > 0 {
        println!("failed requests: {}", outcome.failed);
    }
    println!("BENCH {}", outcome.bench_arm(&spec, driver.label()));
    Ok(())
}
