//! Quickstart: the smallest useful tour of the stack.
//!
//! 1. sample ŵ from w with the GaussWS op — pure rust (no artifacts needed);
//! 2. run the Pallas-lowered sampling kernel through the PJRT runtime and
//!    check it agrees bit-for-bit;
//! 3. train a tiny GPT2 for a few steps through the full L1→L2→L3 stack.
//!
//! Run: `cargo run --release --example quickstart` (after `make artifacts`)

use gaussws::config::schema::TrainConfig;
use gaussws::coordinator::Trainer;
use gaussws::pqt::PqtLinear;
use gaussws::prng::Philox4x32;
use gaussws::runtime::{HostTensor, Runtime};

fn main() -> anyhow::Result<()> {
    // ---- 1. the core op in pure rust -----------------------------------
    let (rows, cols) = (64, 64);
    let mut rng = Philox4x32::new(0);
    let w: Vec<f32> = (0..rows * cols).map(|_| (rng.next_f32() - 0.5) * 0.1).collect();
    // the default [pqt] config: gaussws method, 32x32 blocks, b 6→4, and
    // the bf16 ŵ-operator cast resolved through the quant registry
    // (set `pqt.cast = "fp8_e4m3"` in a run TOML for an FP8-operator arm)
    let layer = PqtLinear::from_config(
        "demo.qkv",
        rows,
        cols,
        &gaussws::config::schema::PqtConfig::default(),
    );
    let mut w_hat = vec![0f32; w.len()];
    let state = layer.forward(&w, /*seed=*/ 42, &mut w_hat);
    let changed = w.iter().zip(&w_hat).filter(|(a, b)| a != b).count();
    println!(
        "GaussWS sample: {changed}/{} elements perturbed, noise storage {} B ({} B/param)",
        w.len(),
        state.noise_bytes(),
        state.noise_bytes() as f64 / w.len() as f64
    );

    // ---- 2. the same op through the AOT Pallas kernel ------------------
    let mut rt = Runtime::new("artifacts")?;
    println!("PJRT platform: {}", rt.platform());
    let spec = rt.manifest.get("op.gaussws_sample")?.clone();
    let (m, n) = (spec.inputs[0].shape[0], spec.inputs[0].shape[1]);
    let w2: Vec<f32> = (0..m * n).map(|_| rng.next_f32() - 0.5).collect();
    let bt = vec![4.0f32; (m / 32) * (n / 32)];
    let noise: Vec<f32> =
        (0..m * n).map(|_| ((rng.next_u32() % 5) as i32 - 2) as f32).collect();
    let out = rt.execute(
        "op.gaussws_sample",
        &[
            HostTensor::F32(w2.clone()),
            HostTensor::F32(bt.clone()),
            HostTensor::F32(noise.clone()),
        ],
    )?;
    let what_kernel = out[0].as_f32()?;
    // reproduce in rust and compare
    let amax = gaussws::mx::block_absmax_f32(&w2, m, n, 32);
    let mut agree = true;
    for r in 0..m {
        for c in 0..n {
            let i = r * n + c;
            let blk = (r / 32) * (n / 32) + c / 32;
            let expect = gaussws::numerics::Bf16::from_f32(
                w2[i] + noise[i] * amax[blk] * (1.0 - bt[blk]).exp2(),
            )
            .to_f32();
            agree &= what_kernel[i] == expect;
        }
    }
    println!("Pallas kernel vs rust op: {}", if agree { "bit-exact OK" } else { "MISMATCH" });
    assert!(agree);

    // ---- 3. a few training steps through the full stack ----------------
    let cfg = TrainConfig { steps: 10, warmup_steps: 2, workers: 1, ..Default::default() };
    let rt = Runtime::new("artifacts")?;
    let mut trainer = Trainer::new(rt, "tiny_gpt2.gaussws_all", cfg, "quickstart")?;
    println!(
        "training tiny GPT2 (gaussws[all]): {} params, {} PQT layers",
        trainer.params.values().map(|v| v.len()).sum::<usize>(),
        trainer.bi.len()
    );
    trainer.run(10, 2)?;
    println!(
        "done: loss {:.3} -> {:.3}  ({:.0} tokens/s)",
        trainer.log.losses()[0],
        trainer.log.losses().last().unwrap(),
        trainer.log.tokens_per_sec()
    );
    Ok(())
}
