//! End-to-end driver (EXPERIMENTS.md §E2E): pre-train a multi-million-
//! parameter GPT2-style model through the full three-layer stack — Pallas
//! noise kernel inside the jax-lowered train-step HLO, executed by the rust
//! coordinator with rust AdamW, seed tree, LR schedule and checkpointing —
//! and log the loss curve.
//!
//! Run: cargo run --release --example pretrain_gpt2 -- \
//!        [--method gaussws|diffq|bf16] [--steps 300] [--workers 1]
//!        [--size small|tiny] [--out runs]

use gaussws::config::schema::{Optimizer, TrainConfig};
use gaussws::coordinator::Trainer;
use gaussws::exp;
use gaussws::runtime::Runtime;
use gaussws::util::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let method = args.get_or("method", "gaussws");
    let size = args.get_or("size", "small");
    let steps = args.usize_or("steps", 300);
    let out = args.get_or("out", "runs");
    let tag = match method {
        "bf16" | "none" => format!("{size}_gpt2.bf16"),
        "diffq" => format!("{size}_gpt2.diffq_all"),
        _ => format!("{size}_gpt2.gaussws_all"),
    };

    let cfg = TrainConfig {
        steps,
        warmup_steps: args.usize_or("warmup", steps / 10),
        max_lr: args.f64_or("lr", 6e-4),
        min_lr: args.f64_or("min-lr", 6e-5),
        optimizer: Optimizer::parse(args.get_or("optimizer", "adamw"))?,
        workers: args.usize_or("workers", 1),
        seed: args.u64_or("seed", 1234),
        ..Default::default()
    };

    let rt = Runtime::new(args.get_or("artifacts-dir", "artifacts"))?;
    let run_name = format!("e2e_gpt2_{method}_{size}");
    let mut t = Trainer::new(rt, &tag, cfg, &run_name)?;
    let n_params: usize = t.params.values().map(|v| v.len()).sum();
    println!(
        "== e2e pre-train: {tag} ==\n   {n_params} params | {} PQT layers | {} tokens/step | {steps} steps",
        t.bi.len(),
        t.tokens_per_step()
    );
    let t0 = std::time::Instant::now();
    t.run(steps, args.usize_or("print-every", 20))?;
    let wall = t0.elapsed().as_secs_f64();

    t.log.write_to(out)?;
    t.save_checkpoint(&format!("{out}/{run_name}.ck"))?;
    println!("\n== results ==");
    println!("  loss: {:.4} -> {:.4} (wma16 {:.4})",
        t.log.losses()[0],
        t.log.losses().last().unwrap(),
        t.log.final_loss().unwrap());
    println!("  throughput: {:.0} tokens/s  (wall {wall:.0}s, {} tokens total)",
        t.log.tokens_per_sec(),
        t.tokens_per_step() * steps);
    println!("  divergences: {:?}", t.log.divergences);
    println!("  memory model ({method}): {:.1} MiB",
        t.memory_model_bytes(method) as f64 / (1 << 20) as f64);
    if !t.bi.is_empty() {
        println!("\n{}", exp::render_fig5(&exp::fig5_report(&t)));
    }
    println!("curve: {out}/{run_name}.csv   checkpoint: {out}/{run_name}.ck");
    Ok(())
}
