//! Llama2-style pre-training driver (the Fig. 4 workload shape): RMSNorm +
//! SwiGLU + rotary architecture, GaussWS/DiffQ/BF16 arms, AdamW or
//! Adam-mini, with the avg + windowed-max loss reporting the paper uses.
//!
//! Run: cargo run --release --example pretrain_llama2 -- \
//!        [--method gaussws|diffq|bf16] [--optimizer adamw|adam-mini]
//!        [--size tiny|small] [--steps 200]

use gaussws::config::schema::{Optimizer, TrainConfig};
use gaussws::coordinator::Trainer;
use gaussws::exp;
use gaussws::runtime::Runtime;
use gaussws::util::stats::windowed_max;
use gaussws::util::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let method = args.get_or("method", "gaussws");
    let size = args.get_or("size", "tiny");
    let steps = args.usize_or("steps", 200);
    let tag = match method {
        "bf16" | "none" => format!("{size}_llama2.bf16"),
        "diffq" => format!("{size}_llama2.diffq_all"),
        "b8t6" => format!("{size}_llama2.gaussws_b8t6"), // Fig F.1 arm
        _ => format!("{size}_llama2.gaussws_all"),
    };

    let cfg = TrainConfig {
        steps,
        warmup_steps: args.usize_or("warmup", steps / 10),
        max_lr: args.f64_or("lr", 1e-3),
        min_lr: args.f64_or("min-lr", 1e-4),
        optimizer: Optimizer::parse(args.get_or("optimizer", "adamw"))?,
        workers: args.usize_or("workers", 1),
        seed: args.u64_or("seed", 1234),
        ..Default::default()
    };

    let rt = Runtime::new(args.get_or("artifacts-dir", "artifacts"))?;
    let run_name = format!("e2e_llama2_{method}_{}", cfg.optimizer.name());
    let mut t = Trainer::new(rt, &tag, cfg, &run_name)?;
    println!(
        "== llama2 pre-train: {tag} ({}) — {} params ==",
        t.cfg.optimizer.name(),
        t.params.values().map(|v| v.len()).sum::<usize>()
    );
    t.run(steps, args.usize_or("print-every", 20))?;

    // Fig. 4 style reporting: smoothed average + windowed max
    let losses = t.log.losses();
    let wma16 = t.log.smoothed(1.0 / 16.0);
    let wma128 = t.log.smoothed(1.0 / 128.0);
    let mx = windowed_max(&losses, 64);
    println!("\n== Fig-4-style summary (avg | max windows) ==");
    for frac in [0.25, 0.5, 0.75, 1.0] {
        let i = ((losses.len() as f64 * frac) as usize).saturating_sub(1);
        println!(
            "  {:>4.0}% of run: wma16 {:.4}  wma128 {:.4}  max64 {:.4}",
            frac * 100.0,
            wma16[i],
            wma128[i],
            mx[i]
        );
    }
    let out = args.get_or("out", "runs");
    t.log.write_to(out)?;
    if !t.bi.is_empty() {
        println!("\n{}", exp::render_fig5(&exp::fig5_report(&t)));
    }
    println!("curve: {out}/{run_name}.csv");
    Ok(())
}
