//! Fake-quantized inference: validate the Table C.1 claim that GaussWS-
//! trained parameters survive low-precision FP storage.
//!
//! Trains a tiny GPT2 with GaussWS through the full stack, exports the
//! parameters into the pure-rust transformer, then evaluates perplexity
//! with the linear weights cast to each candidate datatype (MX square-
//! blockwise, matching the training-time grouping). The GaussWS-trained
//! model should degrade gracefully down to FP8/FP6, the BF16 baseline less
//! so at the same bitwidths.
//!
//! Run: cargo run --release --example fq_inference -- [--steps 60]

use gaussws::config::schema::{Arch, ModelConfig, PqtMethod, TrainConfig};
use gaussws::coordinator::Trainer;
use gaussws::nn::tensor::Mat;
use gaussws::nn::transformer::{Params, Transformer};
use gaussws::quant::Scheme;
use gaussws::runtime::Runtime;
use gaussws::util::Args;
use std::collections::BTreeMap;

fn train(tag: &str, steps: usize, args: &Args) -> anyhow::Result<Trainer> {
    let cfg = TrainConfig {
        steps,
        warmup_steps: steps / 10 + 1,
        max_lr: 1e-3,
        min_lr: 1e-4,
        seed: args.u64_or("seed", 7),
        ..Default::default()
    };
    let rt = Runtime::new(args.get_or("artifacts-dir", "artifacts"))?;
    let mut t = Trainer::new(rt, tag, cfg, tag)?;
    t.run(steps, 0)?;
    Ok(t)
}

fn to_rust_params(t: &Trainer) -> Params {
    let mut tensors = BTreeMap::new();
    for (name, shape, data) in t.export_params() {
        let (rows, cols) = match shape.len() {
            2 => (shape[0], shape[1]),
            1 => (1, shape[0]),
            _ => panic!("unexpected rank for {name}"),
        };
        tensors.insert(name, Mat::from_vec(rows, cols, data));
    }
    Params { tensors }
}

/// Mean eval loss of the rust transformer over held-out synthetic windows.
fn eval_loss(model: &Transformer, params: &Params, vocab: usize, seq: usize) -> f64 {
    use gaussws::data::{SynthCorpus, SynthSpec};
    let corpus = SynthCorpus::generate(SynthSpec {
        vocab,
        len: 1 << 16,
        seed: 1234 ^ 0xC0FFEE, // same corpus family as training
        ..Default::default()
    });
    let mut total = 0.0;
    let n_windows = 8;
    for k in 0..n_windows {
        let start = 1000 + k * 2048;
        let toks: Vec<usize> =
            corpus.tokens[start..start + seq + 1].iter().map(|&t| t as usize).collect();
        total += model.loss(params, &toks);
    }
    total / n_windows as f64
}

fn quantize_linears(params: &Params, cfg: &ModelConfig, scheme: &Scheme) -> Params {
    let mut out = params.clone();
    out.quantize_linears(cfg, scheme, 0);
    out
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let steps = args.usize_or("steps", 60);

    let cfg = ModelConfig {
        arch: Arch::Gpt2,
        n_layer: 2,
        d_model: 64,
        n_head: 2,
        d_ff: 128,
        vocab: 256,
        seq_len: 64,
    };
    let model = Transformer::new(cfg.clone());

    let arms: [(&str, &str, PqtMethod); 2] = [
        ("gaussws", "tiny_gpt2.gaussws_all", PqtMethod::GaussWs),
        ("bf16", "tiny_gpt2.bf16", PqtMethod::None),
    ];
    // Table C.1 datatypes, resolved through the quant registry
    let labels = ["bf16", "fp12_e4m7", "fp8_e3m4", "fp6_e3m2", "fp4_e2m1"];
    let schemes: Vec<Scheme> =
        labels.iter().map(|l| gaussws::quant::resolve(l).expect("builtin scheme")).collect();

    println!("== fake-quantized inference (Table C.1 validation) ==");
    println!("training {} steps per arm...\n", steps);
    println!(
        "{:<14} {:>10} {:>12} {:>12} {:>12} {:>12} {:>12}",
        "arm", "f32", "bf16", "fp12_e4m7", "fp8_e3m4", "fp6_e3m2", "fp4_e2m1"
    );
    for (label, tag, _method) in arms {
        let t = train(tag, steps, &args)?;
        let params = to_rust_params(&t);
        let base = eval_loss(&model, &params, cfg.vocab, 48);
        let mut row = format!("{label:<14} {base:>10.4}");
        for scheme in &schemes {
            let q = quantize_linears(&params, &cfg, scheme);
            let loss = eval_loss(&model, &q, cfg.vocab, 48);
            row.push_str(&format!(" {loss:>12.4}"));
        }
        println!("{row}");
    }
    println!(
        "\n(lower is better; GaussWS-trained weights should track f32 down to\n\
         fp8/fp6 — the stochastic precision annealing of Prop. 4 at work)"
    );
    Ok(())
}
