//! Noise laboratory: everything about the paper's R = round(N(0,1)/2).
//!
//! Prints the Eq. 10 target probabilities, the empirical histograms of the
//! exact / fast bitwise generators and the Box–Muller reference, the exact
//! rounded-normal probabilities for comparison, a quick per-method
//! throughput shoot-out, and a stochastic-precision-annealing demo
//! (Proposition 4) on a real weight block.
//!
//! Run: cargo run --release --example noise_lab

use gaussws::numerics::analysis::{self, ROUNDED_NORMAL};
use gaussws::numerics::formats;
use gaussws::pqt::gaussws::{forward, noise_histogram, NoiseGen};
use gaussws::prng::bitwise::target_probabilities;
use gaussws::prng::gauss::{exact_rounded_probs, fill_rounded_normal};
use gaussws::prng::{generate_exact, generate_fast, Philox4x32};
use gaussws::util::bench::Bencher;

fn main() {
    let n = 2_000_000usize;

    // ---- distributions ---------------------------------------------------
    let (p0, p1, p2) = target_probabilities();
    let (e0, e1, e2) = exact_rounded_probs();
    println!("== R distributions ==");
    println!("{:<26} {:>9} {:>9} {:>9}", "", "Pr(0)", "Pr(±1)ea", "Pr(±2)ea");
    println!("{:<26} {:>9.4} {:>9.4} {:>9.6}", "Eq.10 target (paper)", p0, p1, p2);
    println!("{:<26} {:>9.4} {:>9.4} {:>9.6}", "exact round(N(0,1)/2)", e0, e1, e2);

    let hist = |vals: &[i32]| -> [f64; 5] {
        let mut h = [0f64; 5];
        for &v in vals {
            h[(v + 2) as usize] += 1.0;
        }
        h.iter_mut().for_each(|x| *x /= vals.len() as f64);
        *h.as_slice().try_into().as_ref().unwrap()
    };
    let exact = generate_exact(1, n);
    let fast = generate_fast(2, n);
    let he = hist(&(0..n).map(|i| exact.get(i)).collect::<Vec<_>>());
    let hf = hist(&(0..n).map(|i| fast.get(i)).collect::<Vec<_>>());
    let mut bm = vec![0f32; n];
    fill_rounded_normal(3, &mut bm);
    let hb = hist(&bm.iter().map(|&x| (x as i32).clamp(-2, 2)).collect::<Vec<_>>());
    println!("{:<26} {:>9.4} {:>9.4} {:>9.6}", "bitwise exact (measured)", he[2], he[3], he[4]);
    println!("{:<26} {:>9.4} {:>9.4} {:>9.6}", "bitwise fast  (measured)", hf[2], hf[3], hf[4]);
    println!("{:<26} {:>9.4} {:>9.4} {:>9.6}", "box-muller    (measured)", hb[2], hb[3], hb[4]);

    // ---- throughput ------------------------------------------------------
    println!("\n== generator throughput ({} elements) ==", n);
    let b = Bencher::quick();
    for (name, f) in [
        ("bitwise fast (ours)", Box::new(|| generate_fast(9, n).words.len()) as Box<dyn FnMut() -> usize>),
        ("bitwise exact", Box::new(|| generate_exact(9, n).words.len())),
        ("box-muller f32", Box::new(|| {
            let mut buf = vec![0f32; n];
            fill_rounded_normal(9, &mut buf);
            buf.len()
        })),
    ] {
        let mut f = f;
        let r = b.run(name, &mut f);
        println!("  {:<22} {:>8.1} Melem/s", r.name, r.elems_per_sec(n) / 1e6);
    }

    // ---- Lemma 1 / Prop 4 on a live block ---------------------------------
    println!("\n== Section 3.3 on a live 32x32 block (BF16 operator) ==");
    println!(
        "Lemma 1 bound (rounded normal, m=7): b_t < {}",
        analysis::lemma1_bt_bound(7, &ROUNDED_NORMAL)
    );
    let mut rng = Philox4x32::new(5);
    let mut w: Vec<f32> = (0..32 * 32)
        .map(|_| gaussws::prng::gauss::box_muller_pair(&mut rng).0 as f32 * 0.02)
        .collect();
    // plant tiny parameters well below the Lemma-2 threshold
    let tiny_idx: Vec<usize> = (0..64).map(|k| k * 16 + 3).collect();
    for &i in &tiny_idx {
        w[i] = 2f32.powi(-24);
    }
    let mut what = vec![0f32; w.len()];
    let st = forward(&w, 32, 32, 32, &[4.0], 11, NoiseGen::Exact, &mut what);
    let masked = tiny_idx.iter().filter(|&&i| what[i] != w[i] || what[i] == 0.0).count();
    let nonzero_r = tiny_idx
        .iter()
        .filter(|&&i| gaussws::pqt::gaussws::noise_at(&st, i) != 0)
        .count();
    println!(
        "Prop 4: {}/{} planted 2^-24 params perturbed/masked; {} had R != 0 \
         (masking tracks Pr(R!=0) ~ {:.3})",
        masked,
        tiny_idx.len(),
        nonzero_r,
        1.0 - ROUNDED_NORMAL.p_zero
    );
    println!("noise histogram of the block: {:?}", noise_histogram(&st));

    // ---- Table C.1 anchor ------------------------------------------------
    println!("\n== datatype sufficiency (Prop 3) ==");
    for bt in [3, 4, 5, 9] {
        println!(
            "  b_t = {bt}: w needs e{}, ŵ needs e{}m{}",
            analysis::prop3_exp_bits_w(bt, &ROUNDED_NORMAL),
            analysis::prop3_exp_bits_what(bt, &ROUNDED_NORMAL),
            analysis::mantissa_bits_what(bt)
        );
    }
    let _ = formats::FP6_E3M2; // anchor: see `gaussws tables c1`
    println!("\nfull table: `gaussws tables c1`");
}
