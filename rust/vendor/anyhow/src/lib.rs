//! Offline shim for the subset of the `anyhow` 1.x API used by this
//! workspace: [`Error`], [`Result`], the [`Context`] extension trait and the
//! `anyhow!` / `bail!` / `ensure!` macros. The container image has no crates
//! registry, so the real crate cannot be fetched; this shim is std-only and
//! keeps every call site source-compatible.
//!
//! Differences from the real crate: no backtraces, no downcasting — the
//! error is a context chain of rendered strings. `Display` shows the
//! outermost message; the `{:#}` alternate form shows the whole chain
//! separated by `: `, matching anyhow's formatting contract.

use std::fmt;

/// An error: an outermost message plus the chain of underlying causes.
pub struct Error {
    /// `chain[0]` is the outermost context, last entry the root cause.
    chain: Vec<String>,
}

impl Error {
    /// Construct from a single rendered message.
    pub fn from_msg(msg: String) -> Error {
        Error { chain: vec![msg] }
    }

    /// Construct from anything displayable (mirrors `anyhow::Error::msg`).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error::from_msg(m.to_string())
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The root cause (innermost message).
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// NB: `Error` deliberately does NOT implement `std::error::Error`; that is
// what makes the blanket `From` below coherent next to `From<T> for T`
// (the same trick the real anyhow uses).
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>`: `std::result::Result` defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to `Result`
/// and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::from_msg(context.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::from_msg(f().to_string()))
    }
}

/// Build an [`Error`] from format arguments (or a displayable value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::from_msg(::std::format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::from_msg(::std::format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !$cond {
            $crate::bail!("condition failed: `{}`", ::std::stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn question_mark_on_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(e.to_string(), "file missing");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("opening config").unwrap_err();
        assert_eq!(format!("{e}"), "opening config");
        assert_eq!(format!("{e:#}"), "opening config: file missing");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing key '{}'", "x")).unwrap_err();
        assert_eq!(e.to_string(), "missing key 'x'");
    }

    #[test]
    fn context_stacks_on_anyhow_results() {
        fn inner() -> Result<()> {
            bail!("root cause {}", 7);
        }
        let e = inner().context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: root cause 7");
        assert_eq!(e.root_cause(), "root cause 7");
        assert_eq!(e.chain().count(), 2);
    }

    #[test]
    fn macros_build_errors() {
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
        let x = 3;
        let e = anyhow!("x = {x}, y = {}", 4);
        assert_eq!(e.to_string(), "x = 3, y = 4");

        fn ens(v: bool) -> Result<u32> {
            ensure!(v, "v was false");
            Ok(1)
        }
        assert!(ens(true).is_ok());
        assert_eq!(ens(false).unwrap_err().to_string(), "v was false");
    }

    #[test]
    fn debug_renders_cause_chain() {
        fn inner() -> Result<()> {
            Err(io_err()).context("layer1").context("layer0")
        }
        let msg = format!("{:?}", inner().unwrap_err());
        assert!(msg.starts_with("layer0"));
        assert!(msg.contains("Caused by:"));
        assert!(msg.contains("file missing"));
    }
}
