//! §3.5 design-decision ablations:
//!
//! 1. store-ŵ (2 B/param) vs regenerate-in-backward (0 B, second noise
//!    pass) — the paper chose storing; we time both so the trade-off is
//!    explicit on this testbed.
//! 2. b_i weight-decay annealing speed, with and without the Eq. 12 λ loss
//!    — the mechanism that pulls b_t from b_init to b_target.
//! 3. noise generator variants (exact vs fast) inside the full layer op.

use gaussws::config::schema::PqtMethod;
use gaussws::pqt::gaussws::{backward_bt, forward, NoiseGen};
use gaussws::pqt::{PqtGrads, PqtLinear};
use gaussws::prng::Philox4x32;
use gaussws::util::bench::Bencher;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let b = if quick { Bencher::quick() } else { Bencher::default() };
    let (m, n) = (2048, 2048);
    let total = m * n;
    let mut rng = Philox4x32::new(1);
    let w: Vec<f32> = (0..total).map(|_| rng.next_f32() - 0.5).collect();
    let g: Vec<f32> = (0..total).map(|_| rng.next_f32() - 0.5).collect();
    let bt = vec![4.0f32; (m / 32) * (n / 32)];
    let mut what = vec![0f32; total];

    println!("== ablation 1: store-ŵ vs regenerate (backward path, {m}x{n}) ==");
    let st = forward(&w, m, n, 32, &bt, 7, NoiseGen::Fast, &mut what);
    let r_stored = b.run("stored noise backward", || backward_bt(&st, &g).len());
    let r_regen = b.run("regenerate + backward", || {
        let st2 = forward(&w, m, n, 32, &bt, 7, NoiseGen::Fast, &mut what);
        backward_bt(&st2, &g).len()
    });
    println!(
        "  stored: {:>7.1} ms   regenerate: {:>7.1} ms   ({:.2}x)   storage saved: {} KiB",
        r_stored.median_s * 1e3,
        r_regen.median_s * 1e3,
        r_regen.median_s / r_stored.median_s,
        st.noise_bytes() / 1024
    );

    println!("\n== ablation 2: b_t annealing (b_init 6 -> b_target 4, 2000 steps @ lr 1e-2) ==");
    // Eq. 12's per-block gradient carries a 1/m factor (m = blocks/layer),
    // so visible-λ values scale with the block count; the paper's 1e-4 is
    // calibrated for 600k-step runs.
    for lambda in [0.0, 1.0, 10.0] {
        let mut layer = PqtLinear::new("a", 512, 512, 32, PqtMethod::GaussWs, 6.0, 4.0);
        let zero = PqtGrads { grad_bi: vec![0.0; layer.n_blocks()] };
        for _ in 0..2000 {
            layer.update_bi(&zero, 1e-2, 0.1, lambda);
        }
        println!(
            "  lambda = {:<4}: b_t after 2000 steps = {:.3} (wd-only drifts, λ accelerates)",
            lambda,
            layer.bw.bt()[0]
        );
    }

    println!("\n== ablation 3: noise generator variant inside the layer op ==");
    for (name, gen) in [("exact (16 w/32e)", NoiseGen::Exact), ("fast (4 w/32e)", NoiseGen::Fast)] {
        let r = b.run(name, || {
            forward(&w, m, n, 32, &bt, 9, gen, &mut what);
            what[0]
        });
        println!("  {:<18} {:>8.3} Gelem/s", name, r.gelems_per_sec(total));
    }
}
