//! Serving-path throughput/latency benchmark: drives the continuous-
//! batching engine at batch sizes 1/4/16 on the tiny GPT2 config and emits
//! one `BENCH {json}` record per arm plus an aggregate written to
//! `BENCH_serve.json` at the workspace root (or `--out <path>`), replacing
//! the committed placeholder. This is the perf trajectory for the serving
//! hot path — rerun after engine changes and compare `tokens_per_sec` /
//! `p95_total_ms` per arm.
//!
//! Run: cargo bench --bench bench_serve [-- --quick --out BENCH_serve.json]

use gaussws::config::schema::{Arch, ModelConfig};
use gaussws::data::{SynthCorpus, SynthSpec};
use gaussws::nn::transformer::Transformer;
use gaussws::serve::{Engine, EngineConfig, GenRequest, WeightStore};
use gaussws::util::json::{arr, num, obj, s, Json};
use gaussws::util::Args;

fn run_arm(
    store: &WeightStore,
    corpus: &SynthCorpus,
    batch: usize,
    threads: usize,
    requests: usize,
    prompt_len: usize,
    max_new: usize,
) -> Json {
    let mut engine = Engine::from_store(
        store,
        EngineConfig { max_batch: batch, kv_slots: batch, threads, eos: None, capacity: usize::MAX },
    );
    let span = corpus.tokens.len() - prompt_len - 1;
    for id in 0..requests {
        let start = (id * 2311 + 97) % span;
        let prompt: Vec<usize> =
            corpus.tokens[start..start + prompt_len].iter().map(|&t| t as usize).collect();
        engine.enqueue(GenRequest::greedy(id as u64, prompt, max_new)).expect("valid request");
    }
    let done = engine.run_to_completion();
    assert_eq!(done.len(), requests, "batch={batch}: all requests must complete");
    assert!(
        batch == 1 || engine.stats.max_occupancy() > 1,
        "batch={batch}: continuous batching inactive"
    );
    let record = engine.stats.bench_json(
        &format!("{}/b{batch}", store.label()),
        vec![
            ("store", s(store.label())),
            ("batch", num(batch as f64)),
            ("threads", num(threads as f64)),
            ("prompt_len", num(prompt_len as f64)),
            ("max_new", num(max_new as f64)),
        ],
    );
    println!("BENCH {record}");
    record
}

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let quick = args.flag("quick");
    let seed = args.u64_or("seed", 7);
    let threads = args.usize_or(
        "threads",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
    );
    let prompt_len = args.usize_or("prompt-len", 12);
    let max_new = args.usize_or("max-new", if quick { 8 } else { 24 });
    let per_slot = if quick { 2 } else { 4 };

    let cfg = ModelConfig::tiny(Arch::Gpt2);
    let model = Transformer::new(cfg.clone());
    let params = model.init_params(seed);
    let store = WeightStore::from_params(
        &params,
        &cfg,
        gaussws::quant::resolve(args.get_or("store", "fp8_e3m4")).expect("store mode"),
        seed,
    )
    .expect("snapshot");
    let corpus = SynthCorpus::generate(SynthSpec {
        vocab: cfg.vocab,
        len: 1 << 16,
        seed: seed ^ 0xFEED,
        ..Default::default()
    });

    println!(
        "bench_serve: tiny_gpt2, store {}, threads {threads}, {} req/slot, max_new {max_new}",
        store.label(),
        per_slot
    );
    let mut records = Vec::new();
    for batch in [1usize, 4, 16] {
        let requests = batch * per_slot;
        records.push(run_arm(&store, &corpus, batch, threads, requests, prompt_len, max_new));
    }

    let aggregate = obj(vec![
        ("bench", s("serve")),
        ("model", s("tiny_gpt2")),
        ("store", s(store.label())),
        ("status", s("measured")),
        ("threads", num(threads as f64)),
        ("arms", arr(records)),
    ]);
    // default to the committed placeholder at the workspace root (cargo
    // bench's cwd is the package dir, one level below it)
    let default_out = format!("{}/../BENCH_serve.json", env!("CARGO_MANIFEST_DIR"));
    let out = args.get_or("out", &default_out);
    if let Some(dir) = std::path::Path::new(out).parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir).expect("create bench output dir");
        }
    }
    std::fs::write(out, format!("{aggregate}\n")).expect("write bench record");
    println!("aggregate -> {out}");
}
