//! Serving-path throughput/latency benchmark: drives the paged continuous-
//! batching engine on the tiny GPT2 config and emits one `BENCH {json}`
//! record per arm plus an aggregate written to `BENCH_serve.json` at the
//! workspace root (or `--out <path>`), replacing the committed placeholder.
//!
//! Arms:
//!   * batch scaling 1/4/16 (paged, block 16, prefix cache on);
//!   * paged (block 16) vs contiguous-equivalent (one seq_len-sized block
//!     per sequence — the PR-1 reservation strategy) at batch 8;
//!   * shared-prefix workload with the prefix cache on vs off at batch 8 —
//!     the "on" arm must show prefix_hit_rate > 0 AND lower mean block
//!     occupancy (asserted);
//!   * KV-store scaling on the shared-prefix workload at batch 8: `f32`
//!     vs `fp8_e3m4` vs `int8_sr` vs sub-byte-packed `fp4_e2m1_sr` KV
//!     arenas, reporting tokens/sec, encoded bytes/position
//!     (`kv_bytes_per_position`, true packed bits — 160 B for fp4 on the
//!     tiny config), and the perplexity-proxy per-prompt logit drift vs
//!     the f32 reference, recorded into the stats drift histogram so the
//!     BENCH record carries max AND p50 (asserted zero for f32, bounded
//!     per-scheme for the quantized arms);
//!   * fused packed-code decode vs the f32 mirror (`fused-on`/`fused-off`)
//!     on the fp8 KV arena at batch 8: identical workload with
//!     `kv_mirror` off/on — the greedy token streams are asserted
//!     bit-identical (via per-arm token digests recorded in the BENCH
//!     schema), demonstrating the fused kernels are a pure storage win;
//!   * speculative decoding on vs off at batch 8: the "on" arm drafts 4
//!     tokens/round through a `fp4_e2m1_sr` round-trip of the serving
//!     weights and verifies them in one wave — token digests are asserted
//!     identical (exact-match acceptance is lossless) and the record
//!     carries tokens/sec plus the observed acceptance rate;
//!   * wave batching on vs off at batch 8 (`wave-on`/`wave-off`): the
//!     "on" arm stacks every steady-state decode chunk into one
//!     weight-stationary `decode_wave` — each weight matrix streamed once
//!     per wave instead of once per sequence — and its record carries the
//!     `serve.wave_batch_size` histogram (waves/mean/max); token digests
//!     are asserted bit-identical to the per-sequence "off" arm;
//!   * telemetry on vs off at batch 8 (best-of-N tokens/sec each): the
//!     "on" arm records full per-request trace timelines on top of the
//!     always-on registry; asserted within 2% of the "off" arm;
//!   * the declarative workload corpus (`load::Scenario::all()`): every
//!     named scenario — bursty-chat, long-doc-prefill, many-short,
//!     preemption-storm — replayed through the deterministic direct
//!     driver, each recorded as a `load.<name>` arm carrying the
//!     telemetry-backed p50/p95/p99 latency percentiles.
//!
//! Run: cargo bench --bench bench_serve [-- --quick --out BENCH_serve.json]

use gaussws::config::schema::{Arch, ModelConfig};
use gaussws::data::{SynthCorpus, SynthSpec};
use gaussws::load::{run_scenario, Driver, Scenario};
use gaussws::nn::transformer::Transformer;
use gaussws::serve::{Engine, EngineConfig, GenRequest, WeightStore};
use gaussws::testing::fuzz::{drift_bound, kv_logit_drift};
use gaussws::util::json::{arr, num, obj, s, Json};
use gaussws::util::Args;

struct Arm {
    label: String,
    batch: usize,
    kv_block: usize,
    prefix_cache: bool,
    shared_prefix: usize,
    requests: usize,
    kv_store: String,
    /// keep the f32 decode mirror beside the packed KV codes
    /// (`EngineConfig::kv_mirror`; the fused-decode comparison arm)
    mirror: bool,
    /// record per-request trace timelines (the telemetry-overhead arm)
    trace: bool,
    /// self-speculative decoding: `(draft store label, spec_k)` — the
    /// serving weights round-tripped through the draft scheme propose
    /// `spec_k` tokens per round, verified in one wave (the spec-on arm)
    spec: Option<(&'static str, usize)>,
    /// batch steady-state decode chunks into one weight-stationary
    /// `decode_wave` (`EngineConfig::wave_batch`; on everywhere except the
    /// wave-off comparison arm)
    wave_batch: bool,
}

#[allow(clippy::too_many_arguments)]
fn run_arm(
    store: &WeightStore,
    corpus: &SynthCorpus,
    arm: &Arm,
    threads: usize,
    prompt_len: usize,
    max_new: usize,
    kv_seed: u64,
    kv_drifts: &[f64],
    extra: Vec<(&'static str, Json)>,
) -> (Json, f64, f64) {
    let mut engine = Engine::from_store(
        store,
        EngineConfig {
            max_batch: arm.batch,
            kv_block: arm.kv_block,
            kv_blocks: 0, // auto-size: admission is batch-bound, not block-bound
            prefill_chunk: 8,
            prefix_cache: arm.prefix_cache,
            threads,
            kv_scheme: gaussws::quant::resolve(&arm.kv_store).expect("kv store label"),
            // same SR streams as the drift probe, so the recorded
            // kv_logit_drift_max describes this arm's actual quantization
            kv_seed,
            kv_mirror: arm.mirror,
            trace: arm.trace,
            spec_draft_store: arm
                .spec
                .map(|(label, _)| gaussws::quant::resolve(label).expect("draft store label")),
            spec_k: arm.spec.map_or(4, |(_, k)| k),
            wave_batch: arm.wave_batch,
            ..EngineConfig::default()
        },
    );
    let span = corpus.tokens.len() - prompt_len - 1;
    let head: Vec<usize> =
        corpus.tokens[41..41 + arm.shared_prefix].iter().map(|&t| t as usize).collect();
    if arm.shared_prefix > 0 {
        // warmup request so the fan-out can hit the published chain
        let mut p = head.clone();
        p.extend(corpus.tokens[7..7 + prompt_len - arm.shared_prefix].iter().map(|&t| t as usize));
        engine.enqueue(GenRequest::greedy(u64::MAX, p, max_new)).expect("warmup request");
        let warm = engine.run_to_completion();
        assert_eq!(warm.len(), 1, "{}: warmup must complete", arm.label);
    }
    for id in 0..arm.requests {
        let start = (id * 2311 + 97) % span;
        let mut prompt = head.clone();
        prompt.extend(
            corpus.tokens[start..start + prompt_len - arm.shared_prefix]
                .iter()
                .map(|&t| t as usize),
        );
        engine.enqueue(GenRequest::greedy(id as u64, prompt, max_new)).expect("valid request");
    }
    let mut done = engine.run_to_completion();
    assert_eq!(done.len(), arm.requests, "{}: all requests must complete", arm.label);
    // FNV-1a over (id, tokens) in id order: a stable digest of the greedy
    // outputs, so arms meant to be output-identical (fused-on vs
    // fused-off) can be compared from their BENCH records alone
    done.sort_by_key(|r| r.id);
    let mut digest: u64 = 0xcbf2_9ce4_8422_2325;
    let mut fold = |v: u64| {
        digest ^= v;
        digest = digest.wrapping_mul(0x0000_0100_0000_01B3);
    };
    for r in &done {
        fold(r.id);
        for &t in &r.tokens {
            fold(t as u64 + 1);
        }
    }
    assert!(
        arm.batch == 1 || engine.stats.max_occupancy() > 1,
        "{}: continuous batching inactive",
        arm.label
    );
    for &d in kv_drifts {
        engine.stats.record_kv_drift(d);
    }
    let mut extras = vec![
        ("store", s(store.label())),
        ("batch", num(arm.batch as f64)),
        ("threads", num(threads as f64)),
        ("prompt_len", num(prompt_len as f64)),
        ("max_new", num(max_new as f64)),
        ("kv_block", num(arm.kv_block as f64)),
        ("prefix_cache", Json::Bool(arm.prefix_cache)),
        ("shared_prefix", num(arm.shared_prefix as f64)),
        ("kv_mirror", Json::Bool(arm.mirror)),
        ("wave_batch", Json::Bool(arm.wave_batch)),
        ("tokens_digest", s(&format!("{digest:016x}"))),
    ];
    extras.extend(extra);
    let record = engine.stats.bench_json(&arm.label, extras);
    println!("BENCH {record}");
    (record, engine.stats.prefix_hit_rate(), engine.stats.mean_blocks_live())
}

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let quick = args.flag("quick");
    let seed = args.u64_or("seed", 7);
    let threads = args.usize_or(
        "threads",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
    );
    let prompt_len = args.usize_or("prompt-len", 12);
    let max_new = args.usize_or("max-new", if quick { 8 } else { 24 });
    let per_slot = if quick { 2 } else { 4 };

    let cfg = ModelConfig::tiny(Arch::Gpt2);
    let model = Transformer::new(cfg.clone());
    let params = model.init_params(seed);
    let store = WeightStore::from_params(
        &params,
        &cfg,
        gaussws::quant::resolve(args.get_or("store", "fp8_e3m4")).expect("store mode"),
        seed,
    )
    .expect("snapshot");
    let corpus = SynthCorpus::generate(SynthSpec {
        vocab: cfg.vocab,
        len: 1 << 16,
        seed: seed ^ 0xFEED,
        ..Default::default()
    });

    println!(
        "bench_serve: tiny_gpt2, store {}, threads {threads}, {} req/slot, max_new {max_new}",
        store.label(),
        per_slot
    );
    let mut records = Vec::new();

    // ---- batch scaling (paged, block 16) ----
    for batch in [1usize, 4, 16] {
        let arm = Arm {
            label: format!("{}/b{batch}", store.label()),
            batch,
            kv_block: 16,
            prefix_cache: true,
            shared_prefix: 0,
            requests: batch * per_slot,
            kv_store: "f32".into(),
            mirror: false,
            trace: false,
            spec: None,
            wave_batch: true,
        };
        records.push(run_arm(&store, &corpus, &arm, threads, prompt_len, max_new, seed, &[], vec![]).0);
    }

    // ---- paged vs contiguous-equivalent reservation at equal batch ----
    for (tag, kv_block) in [("paged16", 16usize), ("contig", cfg.seq_len)] {
        let arm = Arm {
            label: format!("{}/{tag}/b8", store.label()),
            batch: 8,
            kv_block,
            prefix_cache: false,
            shared_prefix: 0,
            requests: 8 * per_slot,
            kv_store: "f32".into(),
            mirror: false,
            trace: false,
            spec: None,
            wave_batch: true,
        };
        records.push(run_arm(&store, &corpus, &arm, threads, prompt_len, max_new, seed, &[], vec![]).0);
    }

    // ---- shared-prefix workload: prefix cache on vs off at equal batch ----
    let shared_prefix = prompt_len.saturating_sub(3);
    let mk_prefix_arm = |on: bool| Arm {
        label: format!(
            "{}/prefix-{}/b8",
            store.label(),
            if on { "on" } else { "off" }
        ),
        batch: 8,
        kv_block: 4,
        prefix_cache: on,
        shared_prefix,
        requests: 8 * per_slot,
        kv_store: "f32".into(),
        mirror: false,
        trace: false,
        spec: None,
        wave_batch: true,
    };
    let (rec_on, hit_rate_on, occ_on) =
        run_arm(&store, &corpus, &mk_prefix_arm(true), threads, prompt_len, max_new, seed, &[], vec![]);
    let (rec_off, hit_rate_off, occ_off) =
        run_arm(&store, &corpus, &mk_prefix_arm(false), threads, prompt_len, max_new, seed, &[], vec![]);
    assert!(hit_rate_on > 0.0, "shared-prefix arm must hit the prefix cache");
    assert_eq!(hit_rate_off, 0.0);
    assert!(
        occ_on < occ_off,
        "prefix sharing must lower mean block occupancy: {occ_on} vs {occ_off}"
    );
    records.push(rec_on);
    records.push(rec_off);

    // ---- KV-store scaling on the shared-prefix workload at equal batch ----
    // tokens/sec per scheme + perplexity-proxy logit drift vs the f32 KV
    // reference over a fixed probe prompt set (decoded with the *served*
    // dequantized weights, so the drift isolates the KV arena's rounding)
    let model_for_drift = Transformer::new(cfg.clone());
    let served_params = store.to_params();
    let drift_prompts: Vec<Vec<usize>> = (0..4)
        .map(|k| {
            let start = 900 + k * 3100;
            corpus.tokens[start..start + 24].iter().map(|&t| t as usize).collect()
        })
        .collect();
    // the "fp4-packed" tag names the sub-byte stratum: 4-bit codes packed
    // two per byte, 160 B/position on the tiny config vs 1024 B for f32
    for (kv_store, tag) in [
        ("f32", "f32"),
        ("fp8_e3m4", "fp8_e3m4"),
        ("int8_sr", "int8_sr"),
        ("fp4_e2m1_sr", "fp4-packed"),
    ] {
        let drifts: Vec<f64> = drift_prompts
            .iter()
            .map(|p| kv_logit_drift(&model_for_drift, &served_params, p, kv_store, 4, seed) as f64)
            .collect();
        let drift = drifts.iter().cloned().fold(0f64, f64::max);
        let bound = drift_bound(kv_store) as f64;
        if kv_store == "f32" {
            assert_eq!(drift, 0.0, "f32 KV passthrough must be drift-free");
        } else {
            assert!(
                drift.is_finite() && drift < bound,
                "{kv_store}: KV logit drift {drift} exceeds bound {bound}"
            );
        }
        let arm = Arm {
            label: format!("{}/kv-{tag}/b8", store.label()),
            batch: 8,
            kv_block: 4,
            prefix_cache: true,
            shared_prefix,
            requests: 8 * per_slot,
            kv_store: kv_store.into(),
            mirror: false,
            trace: false,
            spec: None,
            wave_batch: true,
        };
        // the per-prompt drifts land in the stats histogram, so the BENCH
        // record carries kv_logit_drift_max AND kv_logit_drift_p50
        let rec =
            run_arm(&store, &corpus, &arm, threads, prompt_len, max_new, seed, &drifts, vec![]).0;
        if kv_store == "fp4_e2m1_sr" {
            assert_eq!(
                rec.get("kv_bytes_per_position").as_usize(),
                Some(160),
                "fp4 KV must cost 160 B/position on the tiny config (true packed bits)"
            );
        }
        records.push(rec);
    }

    // ---- fused packed-code decode vs the f32 mirror, equal workload ----
    // fused-on is the default (codes + scales only); fused-off re-enables
    // the resident f32 mirror and reads rows through it. Same codes, two
    // read paths: the token streams must be bit-identical, which the
    // recorded digests prove from the BENCH file alone
    let mk_fused_arm = |mirror: bool| Arm {
        label: format!("{}/fused-{}/b8", store.label(), if mirror { "off" } else { "on" }),
        batch: 8,
        kv_block: 4,
        prefix_cache: true,
        shared_prefix,
        requests: 8 * per_slot,
        kv_store: "fp8_e3m4".into(),
        mirror,
        trace: false,
        spec: None,
        wave_batch: true,
    };
    let (rec_fused, ..) =
        run_arm(&store, &corpus, &mk_fused_arm(false), threads, prompt_len, max_new, seed, &[], vec![]);
    let (rec_mirror, ..) =
        run_arm(&store, &corpus, &mk_fused_arm(true), threads, prompt_len, max_new, seed, &[], vec![]);
    assert_eq!(
        rec_fused.get("tokens_digest").as_str(),
        rec_mirror.get("tokens_digest").as_str(),
        "fused packed-code decode must be bit-identical to the f32 mirror"
    );
    assert_eq!(
        rec_fused.get("kv_bytes_per_position").as_usize(),
        rec_mirror.get("kv_bytes_per_position").as_usize(),
        "the mirror is resident state, not encoded state"
    );
    records.push(rec_fused);
    records.push(rec_mirror);

    // ---- speculative decoding on vs off, equal workload ----
    // spec-on forks each greedy decode into a fp4-draft + one-wave-verify
    // round; exact-match acceptance keeps the token streams bit-identical
    // (asserted via digests), so the arm isolates the wave-count win and
    // reports the observed acceptance rate
    let mk_spec_arm = |spec: Option<(&'static str, usize)>| Arm {
        label: format!("{}/spec-{}/b8", store.label(), if spec.is_some() { "on" } else { "off" }),
        batch: 8,
        kv_block: 16,
        prefix_cache: true,
        shared_prefix: 0,
        requests: 8 * per_slot,
        kv_store: "fp8_e3m4".into(),
        mirror: false,
        trace: false,
        spec,
        wave_batch: true,
    };
    let (rec_spec_off, ..) =
        run_arm(&store, &corpus, &mk_spec_arm(None), threads, prompt_len, max_new, seed, &[], vec![]);
    let (rec_spec_on, ..) = run_arm(
        &store,
        &corpus,
        &mk_spec_arm(Some(("fp4_e2m1_sr", 4))),
        threads,
        prompt_len,
        max_new,
        seed,
        &[],
        vec![],
    );
    assert_eq!(
        rec_spec_on.get("tokens_digest").as_str(),
        rec_spec_off.get("tokens_digest").as_str(),
        "speculative decoding must be bit-identical to plain greedy decode"
    );
    let rounds = rec_spec_on.get("spec_rounds").as_f64().unwrap_or(0.0);
    let rate = rec_spec_on.get("spec_acceptance_rate").as_f64().unwrap_or(-1.0);
    assert!(rounds > 0.0, "spec-on arm ran no speculative rounds");
    assert!((0.0..=1.0).contains(&rate), "acceptance rate {rate} out of range");
    println!(
        "spec decode: off {:.1} tok/s, on {:.1} tok/s, acceptance {:.0}%",
        rec_spec_off.get("tokens_per_sec").as_f64().unwrap_or(0.0),
        rec_spec_on.get("tokens_per_sec").as_f64().unwrap_or(0.0),
        rate * 100.0
    );
    records.push(rec_spec_off);
    records.push(rec_spec_on);

    // ---- wave batching on vs off, equal workload ----
    // wave-on is the default (steady-state decode chunks stacked into one
    // weight-stationary decode_wave, each weight matrix streamed once per
    // wave); wave-off decodes every sequence separately. Same schedule,
    // two execution shapes: the token streams must be bit-identical,
    // proven by the recorded digests, and the wave-on record carries the
    // serve.wave_batch_size histogram
    let mk_wave_arm = |on: bool| Arm {
        label: format!("{}/wave-{}/b8", store.label(), if on { "on" } else { "off" }),
        batch: 8,
        kv_block: 16,
        prefix_cache: true,
        shared_prefix: 0,
        requests: 8 * per_slot,
        kv_store: "fp8_e3m4".into(),
        mirror: false,
        trace: false,
        spec: None,
        wave_batch: on,
    };
    let (rec_wave_on, ..) =
        run_arm(&store, &corpus, &mk_wave_arm(true), threads, prompt_len, max_new, seed, &[], vec![]);
    let (rec_wave_off, ..) =
        run_arm(&store, &corpus, &mk_wave_arm(false), threads, prompt_len, max_new, seed, &[], vec![]);
    assert_eq!(
        rec_wave_on.get("tokens_digest").as_str(),
        rec_wave_off.get("tokens_digest").as_str(),
        "wave-batched decode must be bit-identical to per-sequence decode"
    );
    let waves = rec_wave_on.get("wave_batch_waves").as_f64().unwrap_or(0.0);
    let widest = rec_wave_on.get("wave_batch_max").as_f64().unwrap_or(0.0);
    assert!(waves > 0.0, "wave-on arm batched no decode waves");
    assert!(widest > 1.0, "wave-on arm at batch 8 never stacked >1 sequence");
    println!(
        "wave batching: off {:.1} tok/s, on {:.1} tok/s, {waves:.0} batched waves (max width {widest:.0})",
        rec_wave_off.get("tokens_per_sec").as_f64().unwrap_or(0.0),
        rec_wave_on.get("tokens_per_sec").as_f64().unwrap_or(0.0),
    );
    records.push(rec_wave_on);
    records.push(rec_wave_off);

    // ---- telemetry overhead: trace timelines on vs off, equal workload ----
    // the registry is always on (ServeStats is a view over it), so this
    // isolates the incremental cost of full per-request trace recording;
    // best-of-N throughput must stay within 2% of the untraced arm
    let mk_tel_arm = |on: bool| Arm {
        label: format!("{}/telemetry-{}/b8", store.label(), if on { "on" } else { "off" }),
        batch: 8,
        kv_block: 16,
        prefix_cache: true,
        shared_prefix: 0,
        requests: 8 * per_slot,
        kv_store: "f32".into(),
        mirror: false,
        trace: on,
        spec: None,
        wave_batch: true,
    };
    let reps = if quick { 2 } else { 3 };
    let mut best = [0f64; 2];
    let mut best_rec: [Option<Json>; 2] = [None, None];
    for (i, on) in [false, true].into_iter().enumerate() {
        for _ in 0..reps {
            let (rec, _, _) = run_arm(
                &store,
                &corpus,
                &mk_tel_arm(on),
                threads,
                prompt_len,
                max_new,
                seed,
                &[],
                vec![],
            );
            let tps = rec.get("tokens_per_sec").as_f64().unwrap_or(0.0);
            if tps > best[i] {
                best[i] = tps;
                best_rec[i] = Some(rec);
            }
        }
    }
    println!(
        "telemetry overhead: off {:.1} tok/s, on {:.1} tok/s ({:+.2}%)",
        best[0],
        best[1],
        (best[1] / best[0] - 1.0) * 100.0
    );
    assert!(
        best[1] >= best[0] * 0.98,
        "telemetry-on throughput {:.1} tok/s is more than 2% below telemetry-off {:.1} tok/s",
        best[1],
        best[0]
    );
    records.push(best_rec[0].take().expect("telemetry-off arm ran"));
    records.push(best_rec[1].take().expect("telemetry-on arm ran"));

    // ---- workload corpus: every named scenario through the direct driver ----
    // the direct driver (enqueue-all + run_to_completion) gives the
    // scheduler maximum concurrency with deterministic ordering, so these
    // arms are reproducible and comparable run-to-run; the spec seed fixes
    // the request mix, the model seed fixes the weights
    for sc in Scenario::all() {
        let outcome = run_scenario(&sc, Driver::Direct, seed)
            .unwrap_or_else(|e| panic!("scenario {}: {e:#}", sc.spec.name));
        assert_eq!(
            outcome.responses.len() + outcome.failed,
            sc.spec.requests,
            "{}: requests lost",
            sc.spec.name
        );
        assert_eq!(
            outcome.stats.blocks_live_now(),
            0.0,
            "{}: blocks leaked after drain",
            sc.spec.name
        );
        let record = outcome.bench_arm(&sc.spec, Driver::Direct.label());
        println!("BENCH {record}");
        records.push(record);
    }

    let aggregate = obj(vec![
        ("bench", s("serve")),
        ("model", s("tiny_gpt2")),
        ("store", s(store.label())),
        ("status", s("measured")),
        ("threads", num(threads as f64)),
        ("arms", arr(records)),
    ]);
    // default to the committed placeholder at the workspace root (cargo
    // bench's cwd is the package dir, one level below it)
    let default_out = format!("{}/../BENCH_serve.json", env!("CARGO_MANIFEST_DIR"));
    let out = args.get_or("out", &default_out);
    if let Some(dir) = std::path::Path::new(out).parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir).expect("create bench output dir");
        }
    }
    std::fs::write(out, format!("{aggregate}\n")).expect("write bench record");
    println!("aggregate -> {out}");
}
