//! Figure 6 — noise-generation throughput (10⁹ elements/second) across the
//! paper's matrix sizes, three generators:
//!
//!   "torch"  -> naive per-element path: PRNG → f64 uniforms → Box–Muller →
//!               divide → round, one call per element (the eager-framework
//!               baseline in the paper);
//!   "bm"     -> batched Box–Muller (the paper's fused-Triton comparison);
//!   "ours"   -> Eq. 10 bitwise generator (exact + fast variants).
//!
//! The absolute numbers are CPU-bound; the *ratios* reproduce the figure's
//! shape: ours > bm > torch, with the gap widening on larger matrices.

use gaussws::prng::gauss::{box_muller_pair, fill_rounded_normal};
use gaussws::prng::{generate_exact, generate_fast, Philox4x32};
use gaussws::util::bench::{report, Bencher};

/// Naive per-element generator: fresh transcendental math per element with
/// no batching — the "torch" eager baseline.
fn naive_per_element(seed: u64, out: &mut [f32]) {
    let mut g = Philox4x32::new(seed);
    for o in out.iter_mut() {
        let (a, _) = box_muller_pair(&mut g); // discards the pair partner
        *o = (a / 2.0).round() as f32;
    }
}

fn main() {
    // Paper Fig. 6 sizes: weight dims of Llama-3.2-1B .. Llama-3.1-405B
    let sizes: [(usize, usize); 6] =
        [(2048, 512), (2048, 2048), (2048, 8192), (4096, 4096), (16384, 1024), (16384, 16384)];
    let quick = std::env::args().any(|a| a == "--quick");
    let b = if quick { Bencher::quick() } else { Bencher { min_time_s: 0.4, warmup: 1, max_iters: 30 } };

    println!("Fig 6 — noise generation throughput (Gelem/s), higher is better\n");
    println!(
        "{:<14} {:>12} {:>12} {:>14} {:>13}  {:>8}",
        "size (MxN)", "torch-like", "box-muller", "bitwise-exact", "bitwise-fast", "speedup"
    );
    for (m, n) in sizes {
        let total = m * n;
        // cap the naive arm's size: it is orders of magnitude slower and
        // its throughput is size-independent
        let naive_n = total.min(1 << 20);
        let mut buf = vec![0f32; naive_n];
        let r_naive = b.run("torch", || {
            naive_per_element(7, &mut buf);
            buf[0]
        });
        let mut buf2 = vec![0f32; total];
        let r_bm = b.run("bm", || {
            fill_rounded_normal(7, &mut buf2);
            buf2[0]
        });
        let r_exact = b.run("exact", || generate_exact(7, total).words.len());
        let r_fast = b.run("fast", || generate_fast(7, total).words.len());
        let g_naive = r_naive.gelems_per_sec(naive_n);
        let g_bm = r_bm.gelems_per_sec(total);
        let g_exact = r_exact.gelems_per_sec(total);
        let g_fast = r_fast.gelems_per_sec(total);
        println!(
            "{:<14} {:>12.3} {:>12.3} {:>14.3} {:>13.3}  {:>7.1}x",
            format!("{m}x{n}"),
            g_naive,
            g_bm,
            g_exact,
            g_fast,
            g_fast / g_bm
        );
    }
    println!(
        "\npaper shape check: ours ('bitwise-fast') beats box-muller everywhere,\n\
         and both beat the per-element 'torch' baseline by >3x."
    );
    // detailed rows for the largest size
    let (m, n) = sizes[3];
    let total = m * n;
    println!("\ndetail at {m}x{n}:");
    report(&b.run("bitwise-fast", || generate_fast(3, total).words.len()), Some(total));
    report(&b.run("bitwise-exact", || generate_exact(3, total).words.len()), Some(total));
    let mut buf = vec![0f32; total];
    report(
        &b.run("box-muller", || {
            fill_rounded_normal(3, &mut buf);
            buf[0]
        }),
        Some(total),
    );
}
