//! Table 1 — training tokens/second and memory overhead of GaussWS and
//! DiffQ over the BF16 baseline, across a model ladder × {AdamW, Adam-mini},
//! measured end-to-end through the full stack (HLO train step + rust
//! optimizer). Requires `make artifacts`.
//!
//! The ladder is the CPU-testbed stand-in for the paper's
//! {134M, 1B, 3B, 70B†} (see DESIGN.md substitutions); the quantity under
//! test — the *relative overhead* of each PQT arm — is scale-transferable.

use gaussws::config::schema::{Optimizer, TrainConfig};
use gaussws::coordinator::Trainer;
use gaussws::runtime::Runtime;
use gaussws::util::stats::geo_mean;

fn tps(model: &str, method: &str, opt: Optimizer, steps: usize) -> anyhow::Result<(f64, f64)> {
    let rt = Runtime::new("artifacts")?;
    let cfg = TrainConfig {
        steps,
        warmup_steps: 1,
        max_lr: 1e-4,
        min_lr: 1e-5,
        optimizer: opt,
        workers: 1,
        seed: 42,
        ..Default::default()
    };
    let tag = format!("{model}.{method}");
    let mut t = Trainer::new(rt, &tag, cfg, "bench")?;
    t.run(steps, 0)?;
    let mem = t.memory_model_bytes(method.split('_').next().unwrap()) as f64 / (1 << 20) as f64;
    Ok((t.log.tokens_per_sec(), mem))
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let steps = if quick { 4 } else { 8 };
    let ladder = ["tiny_gpt2", "small_gpt2", "small_llama2"];
    let methods = [("bf16", "baseline"), ("gaussws_all", "+GaussWS[all]"), ("diffq_all", "+DiffQ[all]")];

    for opt in [Optimizer::AdamW, Optimizer::AdamMini] {
        println!("\nTable 1 — tokens/s (memory MiB) on the CPU testbed, optimizer = {}", opt.name());
        print!("{:<16}", "");
        for m in ladder {
            print!(" {m:>24}");
        }
        println!();
        let mut base_tps = Vec::new();
        let mut overheads: Vec<Vec<f64>> = vec![vec![], vec![]];
        for (mi, (method, label)) in methods.iter().enumerate() {
            print!("{label:<16}");
            for (li, model) in ladder.iter().enumerate() {
                match tps(model, method, opt, steps) {
                    Ok((t, mem)) => {
                        if mi == 0 {
                            base_tps.push(t);
                            print!(" {:>13.0} ({:>6.1})", t, mem);
                        } else {
                            let ov = (base_tps[li] - t) / base_tps[li] * 100.0;
                            overheads[mi - 1].push(1.0 + ov.max(0.0) / 100.0);
                            print!(" {:>6.0} {:>5.2}% ({:>6.1})", t, ov, mem);
                        }
                    }
                    Err(e) => {
                        print!(" {:>24}", "n/a");
                        eprintln!("({model}.{method}: {e})");
                    }
                }
            }
            println!();
        }
        for (k, name) in ["GaussWS", "DiffQ"].iter().enumerate() {
            if !overheads[k].is_empty() {
                println!(
                    "  geomean {name} overhead: {:.2}%",
                    (geo_mean(&overheads[k]) - 1.0) * 100.0
                );
            }
        }
    }
    println!(
        "\npaper shape check: GaussWS overhead < DiffQ overhead at every rung\n\
         (paper: 3.14% vs 22.34% geomean on A100); GaussWS memory < DiffQ memory\n\
         (0.5 B/param packed noise vs 2 B/param uniform)."
    );
    Ok(())
}
