//! Figure D.1 / §2.1 ablation — forward/backward consistency of the block
//! geometries: vector-wise (standard MX) vs square 32×32 (GaussWS). Also
//! times both quantizers (the square geometry costs nothing extra).

use gaussws::mx::{measure_square, measure_vectorwise};
use gaussws::numerics::Rounding;
use gaussws::prng::gauss::box_muller_pair;
use gaussws::prng::Philox4x32;
use gaussws::quant::{fake_quantize, Axis, Codec, Geometry, QuantScheme};
use gaussws::util::bench::Bencher;

fn randn(seed: u64, n: usize) -> Vec<f64> {
    let mut g = Philox4x32::new(seed);
    (0..n).map(|_| box_muller_pair(&mut g).0).collect()
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let b = if quick { Bencher::quick() } else { Bencher::default() };
    let codecs = [
        ("INT4", Codec::Int { bits: 4 }),
        ("INT8", Codec::Int { bits: 8 }),
        ("FP8_e4m3", Codec::Fp(gaussws::numerics::formats::FP8_E4M3)),
        ("FP6_e3m2", Codec::Fp(gaussws::numerics::formats::FP6_E3M2)),
    ];
    let (rows, cols) = (512, 512);
    let w = randn(1, rows * cols);

    println!("Fig D.1 ablation — transpose consistency of quantization geometries");
    println!(
        "{:<10} {:>17} {:>14} {:>17} {:>14}",
        "elem", "vec mismatch %", "vec rms err", "square mismatch %", "square rms err"
    );
    for (name, codec) in &codecs {
        let rv = measure_vectorwise(&w, rows, cols, 32, codec);
        let rs = measure_square(&w, rows, cols, 32, codec);
        println!(
            "{:<10} {:>16.2}% {:>14.5} {:>16.2}% {:>14.5}",
            name,
            rv.mismatch_fraction * 100.0,
            rv.rms_error_fwd,
            rs.mismatch_fraction * 100.0,
            rs.rms_error_fwd
        );
        assert_eq!(rs.mismatch_fraction, 0.0, "square blocks must commute");
    }

    println!("\nquantizer cost (Melem/s):");
    let int4 = Codec::Int { bits: 4 };
    let rv = b.run("vectorwise", || {
        fake_quantize(
            &w,
            rows,
            cols,
            Geometry::Vector { block: 32, axis: Axis::Row },
            &int4,
            Rounding::NearestEven,
            0,
        )
        .data[0]
    });
    let rs = b.run("square", || {
        fake_quantize(
            &w,
            rows,
            cols,
            Geometry::Square { block: 32 },
            &int4,
            Rounding::NearestEven,
            0,
        )
        .data[0]
    });
    // the registry-resolved scheme path must cost the same as the explicit
    // geometry/codec call
    let scheme = gaussws::quant::resolve("int4").expect("builtin scheme");
    let rq = b.run("scheme int4", || scheme.quantize(&w, rows, cols, 0).data[0]);
    println!(
        "  vectorwise {:>8.1}   square {:>8.1}   scheme {:>8.1}   (vec/sq ratio {:.2}x)",
        rv.elems_per_sec(rows * cols) / 1e6,
        rs.elems_per_sec(rows * cols) / 1e6,
        rq.elems_per_sec(rows * cols) / 1e6,
        rv.median_s / rs.median_s
    );
    println!(
        "\npaper shape check: vector-wise quantization shows fwd/bwd mismatch for\n\
         the integer element types the paper's Fig D.1 uses (FP elements with\n\
         wide exponent ranges can mask it); square-blockwise is exactly\n\
         consistent everywhere at similar RMS error and comparable cost."
    );
}
