//! Figure 6 companion — forward-pass throughput of the full Eq. 3 layer op
//! (noise generation + blockwise max + scaled add + bf16 cast) on the L3
//! hot path, in 10⁹ elements/second, vs the DiffQ-uniform arm and the
//! plain bf16-cast baseline. This is the op the paper wraps in a single
//! PyTorch module (§3.5); here it is `pqt::PqtLinear::forward`.

use gaussws::config::schema::PqtMethod;
use gaussws::pqt::PqtLinear;
use gaussws::prng::Philox4x32;
use gaussws::util::bench::Bencher;

fn main() {
    let sizes: [(usize, usize); 5] =
        [(2048, 512), (2048, 2048), (2048, 8192), (4096, 4096), (8192, 8192)];
    let quick = std::env::args().any(|a| a == "--quick");
    let b = if quick { Bencher::quick() } else { Bencher { min_time_s: 0.4, warmup: 1, max_iters: 30 } };

    println!("Eq. 3 layer-op forward throughput (Gelem/s)\n");
    println!(
        "{:<14} {:>10} {:>10} {:>10}  {:>14}",
        "size (MxN)", "bf16-cast", "gaussws", "diffq", "gaussws/diffq"
    );
    for (m, n) in sizes {
        let total = m * n;
        let mut rng = Philox4x32::new(1);
        let w: Vec<f32> = (0..total).map(|_| rng.next_f32() - 0.5).collect();
        let mut what = vec![0f32; total];

        let mk = |method: PqtMethod| PqtLinear::new("bench", m, n, 32, method, 6.0, 4.0);
        let base_l = mk(PqtMethod::None);
        let gauss_l = mk(PqtMethod::GaussWs);
        let diffq_l = mk(PqtMethod::DiffQ);

        let r_base = b.run("bf16", || {
            base_l.forward(&w, 7, &mut what);
            what[0]
        });
        let r_gauss = b.run("gaussws", || {
            gauss_l.forward(&w, 7, &mut what);
            what[0]
        });
        let r_diffq = b.run("diffq", || {
            diffq_l.forward(&w, 7, &mut what);
            what[0]
        });
        println!(
            "{:<14} {:>10.3} {:>10.3} {:>10.3}  {:>13.2}x",
            format!("{m}x{n}"),
            r_base.gelems_per_sec(total),
            r_gauss.gelems_per_sec(total),
            r_diffq.gelems_per_sec(total),
            r_gauss.median_s.recip() / r_diffq.median_s.recip()
        );
    }
    println!(
        "\npaper shape check: gaussws sampling sustains a higher rate than the\n\
         uniform-noise DiffQ arm (cheaper noise, packed storage), both below\n\
         the pure cast baseline."
    );
}
