//! Dense-kernel microbench: the two primitives the serving hot path leans
//! on, measured in isolation so regressions show up without the engine's
//! scheduling noise on top.
//!
//! Arms:
//!   * `gemm.decode` — `matmul_bt` at the weight-stationary decode-wave
//!     geometry: a `(n_seqs × d)` activation panel against each of the
//!     per-layer weights (`d × d` attention projections, `d_ff × d` MLP,
//!     `vocab × d` logits). The batched wave is compared against decoding
//!     the same rows one sequence at a time (n_seqs separate `1 × d`
//!     calls) — same flops, but the batched form streams each weight
//!     matrix once instead of n_seqs times, which is the whole point of
//!     the decode wave. Outputs are asserted bit-identical row-for-row.
//!   * `gemm.panel` — the fused-qkv panel read (`matmul_bt_panel` over the
//!     three d-row slices of a `3d × d` weight) vs materializing the full
//!     `(t × 3d)` product; asserted bit-identical against the full
//!     product's column slices.
//!   * `gemm.prefill` — `matmul_bt` at prefill geometry (`t × d` against
//!     `d_ff × d`), the tiled kernel's cache-blocking showcase.
//!   * `packed.group` — sub-byte group decode throughput: summing 4-bit
//!     codes through `PackedCodes::iter_group`'s word-at-a-time reader
//!     (one u64 load yields up to 16 codes) vs the scalar per-code
//!     `get()`; asserted to agree exactly.
//!
//! Run: cargo bench --bench bench_kernels [-- --quick]

use std::time::Instant;

use gaussws::nn::tensor::{matmul_bt, matmul_bt_panel, Mat};
use gaussws::quant::PackedCodes;
use gaussws::testing::prop::Gen;
use gaussws::util::Args;

/// Best-of-`reps` wall time for `f`, in seconds.
fn best_of<F: FnMut()>(reps: usize, mut f: F) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

fn gflops(m: usize, k: usize, n: usize, secs: f64) -> f64 {
    (2.0 * m as f64 * k as f64 * n as f64) / secs / 1e9
}

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let quick = args.flag("quick");
    let reps = if quick { 3 } else { 8 };
    let mut g = Gen::new(args.u64_or("seed", 11));

    // serving-scale geometry: bigger than the tiny test config so the
    // weight matrices genuinely fall out of cache between per-seq calls
    let d = if quick { 128 } else { 256 };
    let d_ff = 4 * d;
    let vocab = if quick { 1024 } else { 4096 };
    let n_seqs = 16;

    println!("bench_kernels: d {d}, d_ff {d_ff}, vocab {vocab}, n_seqs {n_seqs}, best of {reps}");

    // ---- gemm.decode: one batched wave vs n_seqs per-sequence calls ----
    let acts = Mat::from_vec(n_seqs, d, g.normal_vec_f32(n_seqs * d));
    for (tag, rows) in [("attn", d), ("mlp", d_ff), ("logits", vocab)] {
        let w = Mat::from_vec(rows, d, g.normal_vec_f32(rows * d));
        let mut wave = Mat::zeros(n_seqs, rows);
        let batched = best_of(reps, || {
            matmul_bt(&acts, &w, &mut wave);
            std::hint::black_box(&wave);
        });
        let mut solo_out = Mat::zeros(1, rows);
        let solo = best_of(reps, || {
            for s in 0..n_seqs {
                let row = Mat::from_vec(1, d, acts.row(s).to_vec());
                matmul_bt(&row, &w, &mut solo_out);
                std::hint::black_box(&solo_out);
            }
        });
        // the batched wave must be a pure execution-shape change
        for s in 0..n_seqs {
            let row = Mat::from_vec(1, d, acts.row(s).to_vec());
            matmul_bt(&row, &w, &mut solo_out);
            assert_eq!(wave.row(s), solo_out.row(0), "gemm.decode/{tag}: row {s} diverged");
        }
        println!(
            "BENCH {{\"bench\":\"kernels\",\"arm\":\"gemm.decode/{tag}\",\
             \"gflops_batched\":{:.2},\"gflops_per_seq\":{:.2},\"speedup\":{:.2}}}",
            gflops(n_seqs, d, rows, batched),
            gflops(n_seqs, d, rows, solo),
            solo / batched
        );
    }

    // ---- gemm.panel: fused-qkv panel reads vs the full product ----
    let t = n_seqs;
    let wqkv = Mat::from_vec(3 * d, d, g.normal_vec_f32(3 * d * d));
    let h = Mat::from_vec(t, d, g.normal_vec_f32(t * d));
    let mut q = Mat::zeros(t, d);
    let mut k = Mat::zeros(t, d);
    let mut v = Mat::zeros(t, d);
    let panels = best_of(reps, || {
        matmul_bt_panel(&h, &wqkv, 0, d, &mut q);
        matmul_bt_panel(&h, &wqkv, d, d, &mut k);
        matmul_bt_panel(&h, &wqkv, 2 * d, d, &mut v);
        std::hint::black_box((&q, &k, &v));
    });
    let mut full = Mat::zeros(t, 3 * d);
    let fused = best_of(reps, || {
        matmul_bt(&h, &wqkv, &mut full);
        std::hint::black_box(&full);
    });
    for i in 0..t {
        for j in 0..d {
            assert_eq!(q.at(i, j), full.at(i, j), "q panel diverged at ({i},{j})");
            assert_eq!(k.at(i, j), full.at(i, d + j), "k panel diverged at ({i},{j})");
            assert_eq!(v.at(i, j), full.at(i, 2 * d + j), "v panel diverged at ({i},{j})");
        }
    }
    println!(
        "BENCH {{\"bench\":\"kernels\",\"arm\":\"gemm.panel\",\
         \"gflops_panels\":{:.2},\"gflops_full\":{:.2}}}",
        gflops(t, d, 3 * d, panels),
        gflops(t, d, 3 * d, fused)
    );

    // ---- gemm.prefill: the tiled kernel at prefill geometry ----
    let t_pre = if quick { 64 } else { 128 };
    let a = Mat::from_vec(t_pre, d, g.normal_vec_f32(t_pre * d));
    let w = Mat::from_vec(d_ff, d, g.normal_vec_f32(d_ff * d));
    let mut out = Mat::zeros(t_pre, d_ff);
    let pre = best_of(reps, || {
        matmul_bt(&a, &w, &mut out);
        std::hint::black_box(&out);
    });
    println!(
        "BENCH {{\"bench\":\"kernels\",\"arm\":\"gemm.prefill\",\"gflops\":{:.2}}}",
        gflops(t_pre, d, d_ff, pre)
    );

    // ---- packed.group: word-at-a-time group decode vs scalar get() ----
    let n_codes = if quick { 1 << 16 } else { 1 << 20 };
    let mut codes = PackedCodes::with_len(4, n_codes);
    for i in 0..n_codes {
        codes.set(i, (g.u64() & 0xF) as u16);
    }
    let group = 64;
    let mut sum_word = 0u64;
    let word = best_of(reps, || {
        sum_word = 0;
        let mut start = 0;
        while start < n_codes {
            for c in codes.iter_group(start, group) {
                sum_word += c as u64;
            }
            start += group;
        }
        std::hint::black_box(sum_word);
    });
    let mut sum_scalar = 0u64;
    let scalar = best_of(reps, || {
        sum_scalar = 0;
        for i in 0..n_codes {
            sum_scalar += codes.get(i) as u64;
        }
        std::hint::black_box(sum_scalar);
    });
    assert_eq!(sum_word, sum_scalar, "word-at-a-time group decode changed the codes");
    println!(
        "BENCH {{\"bench\":\"kernels\",\"arm\":\"packed.group\",\
         \"mcodes_per_sec_word\":{:.1},\"mcodes_per_sec_scalar\":{:.1},\"speedup\":{:.2}}}",
        n_codes as f64 / word / 1e6,
        n_codes as f64 / scalar / 1e6,
        scalar / word
    );
}
