//! Compact `bf16` storage type (u16 payload) with f32 conversion.
//!
//! The paper stores the sampled parameter `ŵ` explicitly in BF16
//! (2 bytes/param, Section 3.5 "GPU memory"). The L3 hot path mirrors that:
//! sampling produces a `Vec<Bf16>` buffer, and matmuls decode lazily.

/// A bfloat16 value: the top 16 bits of an IEEE f32.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
#[repr(transparent)]
pub struct Bf16(pub u16);

impl Bf16 {
    pub const ZERO: Bf16 = Bf16(0);
    pub const ONE: Bf16 = Bf16(0x3f80);

    /// Convert from f32 with round-to-nearest-even (hardware semantics).
    #[inline(always)]
    pub fn from_f32(x: f32) -> Self {
        let bits = x.to_bits();
        if x.is_nan() {
            // quiet NaN, preserve sign
            return Bf16(((bits >> 16) as u16) | 0x0040);
        }
        let lsb = (bits >> 16) & 1;
        let rounded = bits.wrapping_add(0x7fff + lsb);
        Bf16((rounded >> 16) as u16)
    }

    /// Convert from f32 by truncation (round-toward-zero); cheaper, used by
    /// the fast path when the extra half-ulp bias is acceptable.
    #[inline(always)]
    pub fn from_f32_truncate(x: f32) -> Self {
        Bf16((x.to_bits() >> 16) as u16)
    }

    /// Widen to f32 (exact).
    #[inline(always)]
    pub fn to_f32(self) -> f32 {
        f32::from_bits((self.0 as u32) << 16)
    }

    #[inline(always)]
    pub fn is_nan(self) -> bool {
        (self.0 & 0x7f80) == 0x7f80 && (self.0 & 0x007f) != 0
    }

    #[inline(always)]
    pub fn abs(self) -> Self {
        Bf16(self.0 & 0x7fff)
    }
}

impl From<f32> for Bf16 {
    fn from(x: f32) -> Self {
        Bf16::from_f32(x)
    }
}

impl From<Bf16> for f32 {
    fn from(x: Bf16) -> Self {
        x.to_f32()
    }
}

/// Round an f32 slice to bf16 precision in place (value stays f32 but with
/// bf16 granularity). This is the "BF16 operator" emulation used by the
/// training substrate: inputs/outputs of an op are representable in bf16.
pub fn round_slice_bf16(xs: &mut [f32]) {
    for x in xs.iter_mut() {
        *x = Bf16::from_f32(*x).to_f32();
    }
}

/// Encode an f32 slice into a packed bf16 buffer.
pub fn encode_slice(xs: &[f32]) -> Vec<Bf16> {
    xs.iter().map(|&x| Bf16::from_f32(x)).collect()
}

/// Decode a bf16 buffer into f32s.
pub fn decode_slice(xs: &[Bf16]) -> Vec<f32> {
    xs.iter().map(|x| x.to_f32()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_exact_values() {
        for v in [0.0f32, 1.0, -1.0, 0.5, 2.0, -3.5, 128.0] {
            assert_eq!(Bf16::from_f32(v).to_f32(), v);
        }
    }

    #[test]
    fn rne_rounding() {
        // 1 + 2^-8 is exactly between 1.0 and 1 + 2^-7; RNE picks even (1.0).
        let x = 1.0 + 2f32.powi(-8);
        assert_eq!(Bf16::from_f32(x).to_f32(), 1.0);
        // 1 + 3*2^-8 is between 1+2^-7 and 1+2^-6; tie -> even -> 1+2^-6.
        let x = 1.0 + 3.0 * 2f32.powi(-8);
        assert_eq!(Bf16::from_f32(x).to_f32(), 1.0 + 2f32.powi(-6));
    }

    #[test]
    fn nan_preserved() {
        assert!(Bf16::from_f32(f32::NAN).is_nan());
        assert!(Bf16::from_f32(f32::NAN).to_f32().is_nan());
    }

    #[test]
    fn matches_fpformat_emulation() {
        use crate::numerics::fpformat::formats::BF16;
        let mut state = 0x9e3779b9u32;
        for _ in 0..2000 {
            state ^= state << 13;
            state ^= state >> 17;
            state ^= state << 5;
            let x = f32::from_bits(0x3000_0000 | (state & 0x0fff_ffff)); // finite positives
            let a = Bf16::from_f32(x).to_f32() as f64;
            let b = BF16.cast(x as f64);
            assert_eq!(a, b, "x={x}");
        }
    }

    #[test]
    fn truncate_never_increases_magnitude() {
        let mut state = 7u32;
        for _ in 0..1000 {
            state = state.wrapping_mul(1664525).wrapping_add(1013904223);
            let x = (state as f32 / u32::MAX as f32 - 0.5) * 100.0;
            let t = Bf16::from_f32_truncate(x).to_f32();
            assert!(t.abs() <= x.abs());
        }
    }
}
