//! Section 3.3 analysis: Lemmas 1–2, Propositions 3–4 and Table C.1.
//!
//! These are the paper's closed-form conditions under which the computation
//! `fp_{e,m}(ŵ) = fp_{e,m}(w + PQN)` loses no information:
//!
//! * **Lemma 1** — the PQN itself does not underflow iff `b_t < m + 2 + τ`,
//!   where `2^τ = min_{R≠0} |R|`.
//! * **Lemma 2** — small parameters `±ε = ±2^ξ` survive the addition iff
//!   `ξ > ⌊τ + 2 − b_t + log2 max|w|⌋ − m`.
//! * **Proposition 3** — FP exponent cutoff: `⌈log2(−τ + b_t + 1)⌉` exponent
//!   bits suffice for `w`; `⌈log2(−τ + b_t + 3)⌉` for `ŵ`.
//! * **Proposition 4** — stochastic precision annealing with `Pr(R = 0) = p`.
//!
//! The module provides both the closed forms and *empirical* checkers that
//! verify them against the software-FP emulation in [`crate::numerics::fpformat`].

use crate::numerics::fpformat::FpFormat;

/// Properties of a noise basis `R` relevant to the analysis.
#[derive(Debug, Clone, Copy)]
pub struct NoiseBasis {
    /// τ such that `2^τ = min_{R_ij != 0} |R|`.
    pub tau: i32,
    /// `Pr(R = 0)` — mass at zero (0 for uniform, ≈0.717 for rounded normal).
    pub p_zero: f64,
    /// Largest |R| the basis can produce (2 for rounded normal, 0.5 uniform).
    pub max_abs: f64,
    /// Human-readable name for reports.
    pub name: &'static str,
}

/// The paper's proposed basis `R = ⌊N(0,1)/2⌉` (Eq. 10 approximation):
/// support {−2, −1, 0, +1, +2}, τ = 0, Pr(0) ≈ 0.717.
pub const ROUNDED_NORMAL: NoiseBasis = NoiseBasis {
    tau: 0,
    // Exact Pr(0) of the Eq. 10 bitwise approximation:
    // 1 − 2·[(3/4)^2·2^-2·(1−3/4·2^-9·2)] − 2·(3/4·2^-9)
    p_zero: 1.0 - 2.0 * ((0.75 * 0.75 / 4.0) * (1.0 - 2.0 * 0.75 / 512.0)) - 2.0 * (0.75 / 512.0),
    max_abs: 2.0,
    name: "rounded_normal",
};

/// DiffQ-style uniform `U(-0.5, 0.5)` in a `k`-bit representation: the
/// smallest non-zero magnitude is `2^-k` (one lsb of the uniform sample), so
/// τ = −k. In BF16 the effective τ is −8 (7 mantissa bits + sign ~ min
/// positive of the sample near 0 is bounded by the format), the paper quotes
/// τ = −2 for a 4-bit representation ⇒ b_t < 5 with m = 7.
pub const fn uniform_basis(sample_bits: i32) -> NoiseBasis {
    NoiseBasis {
        tau: 1 - sample_bits, // min nonzero |U| = 2^(1-k) for k-bit signed sample in (-0.5, 0.5]
        p_zero: 0.0,
        max_abs: 0.5,
        name: "uniform",
    }
}

/// Lemma 1: largest `b_t` (exclusive bound) such that non-zero PQN survives
/// `fp_{e,m}` casting: returns the bound `B` with the guarantee `b_t < B`.
pub fn lemma1_bt_bound(man_bits: u32, basis: &NoiseBasis) -> i32 {
    man_bits as i32 + 2 + basis.tau
}

/// Lemma 2: lower bound (exclusive) on ξ = log2|ε| such that ±ε in `w`
/// survives. `log2_max_w` is `log2 max|w|` of the block.
pub fn lemma2_xi_bound(man_bits: u32, bt: f64, basis: &NoiseBasis, log2_max_w: f64) -> i32 {
    ((basis.tau as f64 + 2.0 - bt + log2_max_w).floor() as i32) - man_bits as i32
}

/// Proposition 3: exponent bits sufficient for `w`.
pub fn prop3_exp_bits_w(bt: i32, basis: &NoiseBasis) -> u32 {
    let ranges = -basis.tau + bt + 1;
    (ranges as f64).log2().ceil() as u32
}

/// Proposition 3: exponent bits sufficient for `ŵ`.
pub fn prop3_exp_bits_what(bt: i32, basis: &NoiseBasis) -> u32 {
    let ranges = -basis.tau + bt + 3;
    (ranges as f64).log2().ceil() as u32
}

/// Mantissa bits for `ŵ` from Section 3.3: `(b_t − 2)` for the proposed R.
pub fn mantissa_bits_what(bt: i32) -> u32 {
    (bt - 2).max(0) as u32
}

/// One row of Table C.1.
#[derive(Debug, Clone, PartialEq)]
pub struct TableC1Row {
    pub bt: i32,
    pub exp_w: u32,
    pub exp_what: u32,
    pub man_what: u32,
    pub datatypes: Vec<&'static str>,
}

/// Generate Table C.1 for the proposed rounded-normal basis (τ = 0).
pub fn table_c1() -> Vec<TableC1Row> {
    (3..=13)
        .map(|bt| {
            let exp_w = prop3_exp_bits_w(bt, &ROUNDED_NORMAL);
            let exp_what = prop3_exp_bits_what(bt, &ROUNDED_NORMAL);
            let man_what = mantissa_bits_what(bt);
            let total = 1 + exp_what + man_what;
            let datatypes: Vec<&'static str> = if total <= 6 && exp_what <= 3 && man_what <= 2 {
                vec!["FP6_e3m2"]
            } else if exp_what <= 4 && man_what <= 3 && total <= 8 {
                vec!["FP8_e4m3", "FP8_e3m4"]
            } else if exp_what <= 4 && man_what <= 7 {
                vec!["BF16", "FP16"]
            } else if exp_what <= 5 && man_what <= 10 {
                vec!["FP16"]
            } else {
                vec!["FP32"]
            };
            TableC1Row { bt, exp_w, exp_what, man_what, datatypes }
        })
        .collect()
}

/// Empirical Lemma-1 check: sweep every non-zero noise value of magnitude
/// `>= 2^tau` applied to parameters across the block's dynamic range and
/// test that the cast never swallows the PQN entirely.
///
/// Returns the fraction of trials where the PQN survived; Lemma 1 predicts
/// 1.0 when `bt < lemma1_bt_bound` and < 1.0 otherwise (for adversarial w).
pub fn empirical_pqn_survival(fmt: &FpFormat, bt: f64, basis: &NoiseBasis, trials: u32) -> f64 {
    let mut survived = 0u32;
    let mut total = 0u32;
    let max_w = 1.0f64; // wlog: scale-invariant
    let mut state = 0xdead_beefu64;
    for _ in 0..trials {
        // adversarial-ish w: spread log-uniformly across [2^-6, 1]
        state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        let u = (state >> 11) as f64 / (1u64 << 53) as f64;
        let w = (u * -6.0).exp2() * max_w;
        let w = fmt.cast(w);
        if w == 0.0 {
            continue;
        }
        // smallest non-zero noise magnitude: R = 2^tau
        let pqn = (basis.tau as f64).exp2() * max_w * (1.0 - bt).exp2();
        let what = fmt.cast(w + pqn);
        total += 1;
        if what != fmt.cast(w) {
            survived += 1;
        }
    }
    if total == 0 {
        return 0.0;
    }
    survived as f64 / total as f64
}

/// Empirical Proposition-4 check: fraction of near-zero parameters masked to
/// zero when R != 0. Should be ≈ `1 − p` masked, `p` preserved.
pub fn empirical_annealing_mask_rate(
    fmt: &FpFormat,
    bt: f64,
    basis: &NoiseBasis,
    r_samples: &[f64],
) -> f64 {
    // ε well below the Lemma-2 threshold (the lemma bound is the largest
    // stepsize across the reachable binades; 4 binades lower guarantees ε is
    // under half an ulp for every non-zero R, so the cast masks it).
    let xi = lemma2_xi_bound(fmt.man_bits, bt, basis, 0.0) - 4;
    let eps = (xi as f64).exp2();
    let mut masked = 0usize;
    for &r in r_samples {
        let pqn = r * (1.0 - bt).exp2();
        let what = fmt.cast(eps + pqn);
        // "masked" = ε's contribution lost: ŵ equals the PQN alone after cast
        let pqn_only = fmt.cast(pqn);
        if what == pqn_only {
            masked += 1;
        }
    }
    masked as f64 / r_samples.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numerics::fpformat::formats;

    #[test]
    fn paper_headline_bounds() {
        // "with BF16 operator, rounded normal supports b_t < 9"
        assert_eq!(lemma1_bt_bound(7, &ROUNDED_NORMAL), 9);
        // "the range is narrower with b_t < 5 for uniform in 4-bit representation"
        // 4-bit uniform: tau = -4... the paper's quoted bound b_t < 5 with m=7
        // corresponds to tau = -4: 7 + 2 - 4 = 5.
        let u4 = NoiseBasis { tau: -4, ..uniform_basis(4) };
        assert_eq!(lemma1_bt_bound(7, &u4), 5);
    }

    #[test]
    fn rounded_normal_p_zero_matches_eq10() {
        // Eq. 10: Pr(0) ≈ 0.717
        assert!((ROUNDED_NORMAL.p_zero - 0.717).abs() < 2e-3, "{}", ROUNDED_NORMAL.p_zero);
    }

    #[test]
    fn table_c1_matches_paper() {
        let t = table_c1();
        // paper rows: (bt, exp_w, exp_what, man_what)
        let expect = [
            (3, 2, 3, 1),
            (4, 3, 3, 2),
            (5, 3, 3, 3),
            (6, 3, 4, 4),
            (7, 3, 4, 5),
            (8, 4, 4, 6),
            (9, 4, 4, 7),
            (10, 4, 4, 8),
            (11, 4, 4, 9),
            (12, 4, 4, 10),
            (13, 4, 4, 11),
        ];
        assert_eq!(t.len(), expect.len());
        for (row, (bt, ew, ewh, mwh)) in t.iter().zip(expect) {
            assert_eq!(row.bt, bt);
            assert_eq!(row.exp_w, ew, "bt={bt} exp_w");
            assert_eq!(row.exp_what, ewh, "bt={bt} exp_what");
            assert_eq!(row.man_what, mwh, "bt={bt} man_what");
        }
        // spot-check datatype column
        assert_eq!(t[0].datatypes, vec!["FP6_e3m2"]); // bt=3
        assert_eq!(t[2].datatypes, vec!["FP8_e4m3", "FP8_e3m4"]); // bt=5
        assert!(t[6].datatypes.contains(&"BF16")); // bt=9
        assert_eq!(t[10].datatypes, vec!["FP32"]); // bt=13
    }

    #[test]
    fn lemma1_empirical_boundary_bf16() {
        let fmt = formats::BF16;
        // Below the bound: PQN always survives.
        let ok = empirical_pqn_survival(&fmt, 8.0, &ROUNDED_NORMAL, 4000);
        assert!(ok > 0.999, "b_t=8 survival={ok}");
        // Above the bound: PQN sometimes (in fact often) underflows.
        let bad = empirical_pqn_survival(&fmt, 11.0, &ROUNDED_NORMAL, 4000);
        assert!(bad < 0.9, "b_t=11 survival={bad}");
    }

    #[test]
    fn lemma2_threshold_is_tight_bf16() {
        let fmt = formats::BF16;
        let bt = 4.0;
        let xi = lemma2_xi_bound(fmt.man_bits, bt, &ROUNDED_NORMAL, 0.0);
        // ε just above the bound survives addition with the smallest noise
        let eps_ok = ((xi + 1) as f64).exp2();
        let pqn = (ROUNDED_NORMAL.tau as f64 + 1.0 - bt).exp2();
        assert_ne!(fmt.cast(eps_ok + pqn), fmt.cast(pqn), "ε above bound must survive");
        // ε two binades below the bound is swallowed
        let eps_bad = ((xi - 2) as f64).exp2();
        assert_eq!(fmt.cast(eps_bad + pqn), fmt.cast(pqn), "ε below bound must be masked");
    }

    #[test]
    fn prop3_lower_bound_formats() {
        // Section 3.3: FP with ceil(log2(b_t+1)) exponent bits for w and
        // ceil(log2(b_t+3)) exponent / (b_t-2) mantissa for ŵ (τ = 0).
        assert_eq!(prop3_exp_bits_w(4, &ROUNDED_NORMAL), 3);
        assert_eq!(prop3_exp_bits_what(4, &ROUNDED_NORMAL), 3);
        assert_eq!(mantissa_bits_what(4), 2); // => FP6_e3m2
    }

    #[test]
    fn annealing_masks_at_one_minus_p() {
        // With the rounded-normal distribution, ε below the Lemma-2 bound is
        // masked whenever R != 0, i.e. with probability ≈ 1 − p ≈ 0.283.
        let fmt = formats::BF16;
        // R samples with exact Eq. 10 probabilities, deterministic mix:
        let mut samples = Vec::new();
        let n = 10000;
        let p1 = (0.75f64 * 0.75 / 4.0) * (1.0 - 2.0 * 0.75 / 512.0);
        let p2 = 0.75 / 512.0;
        let n2 = (p2 * n as f64).round() as usize;
        let n1 = (p1 * n as f64).round() as usize;
        for _ in 0..n2 {
            samples.push(2.0);
            samples.push(-2.0);
        }
        for _ in 0..n1 {
            samples.push(1.0);
            samples.push(-1.0);
        }
        while samples.len() < n {
            samples.push(0.0);
        }
        let masked = empirical_annealing_mask_rate(&fmt, 4.0, &ROUNDED_NORMAL, &samples);
        let expect = 1.0 - ROUNDED_NORMAL.p_zero;
        assert!((masked - expect).abs() < 0.02, "masked={masked} expect={expect}");
    }
}
