//! Numeric-format substrate: software floating-point emulation, bf16
//! storage, and the paper's Section-3.3 underflow analysis.

pub mod analysis;
pub mod bf16;
pub mod fpformat;

pub use bf16::Bf16;
pub use fpformat::{formats, FpFormat, Overflow, Rounding};
