//! Software emulation of arbitrary floating-point formats `fp_{e,m}`.
//!
//! This is the numeric-format substrate underlying the paper's analysis
//! (Section 3.3, Lemmas 1–2, Propositions 3–4, Table C.1): a value cast to a
//! low-precision floating-point format with `e` exponent bits and `m`
//! mantissa bits, with IEEE-754 semantics (subnormals, round-to-nearest-even
//! by default, saturating or inf overflow policy).
//!
//! All arithmetic is done by decoding to `f32`/`f64` and re-encoding; the
//! emulation is exact for every format with `e <= 8` and `m <= 23`.

/// Rounding mode used when casting into a low-precision format.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rounding {
    /// Round to nearest, ties to even (IEEE default).
    NearestEven,
    /// Round toward zero (truncate).
    TowardZero,
    /// Stochastic rounding; probability of rounding up equals the fractional
    /// distance. The `u32` argument threaded through `cast_stochastic` is the
    /// random draw.
    Stochastic,
}

/// Behaviour when a finite value exceeds the largest representable magnitude.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Overflow {
    /// Clamp to ±max_normal (common for FP8/FP6 formats without inf).
    Saturate,
    /// Round to ±infinity (IEEE behaviour).
    Infinity,
}

/// A floating-point format with `e` exponent bits and `m` mantissa bits
/// (plus one sign bit). Bias is the IEEE-style `2^(e-1) - 1`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FpFormat {
    /// Number of exponent bits (1..=8).
    pub exp_bits: u32,
    /// Number of mantissa bits (0..=23).
    pub man_bits: u32,
    /// Whether the format reserves the top exponent code for inf/nan.
    /// FP8_e4m3 (OCP) famously does not reserve inf; we model the common
    /// "IEEE-like" variant by default and expose this knob for OCP variants.
    pub has_inf_nan: bool,
    /// Overflow policy for finite inputs.
    pub overflow: Overflow,
}

impl FpFormat {
    /// Construct an IEEE-like format (`has_inf_nan = true`, inf on overflow).
    pub const fn ieee(exp_bits: u32, man_bits: u32) -> Self {
        FpFormat { exp_bits, man_bits, has_inf_nan: true, overflow: Overflow::Infinity }
    }

    /// Construct a saturating format without inf/nan codes (OCP-FP8 style).
    pub const fn saturating(exp_bits: u32, man_bits: u32) -> Self {
        FpFormat { exp_bits, man_bits, has_inf_nan: false, overflow: Overflow::Saturate }
    }

    /// IEEE exponent bias `2^(e-1) - 1`.
    pub const fn bias(&self) -> i32 {
        (1i32 << (self.exp_bits - 1)) - 1
    }

    /// Exponent of the smallest normal value: `1 - bias`.
    pub const fn min_normal_exp(&self) -> i32 {
        1 - self.bias()
    }

    /// Exponent of the largest finite exponent range.
    pub const fn max_exp(&self) -> i32 {
        let top = (1i32 << self.exp_bits) - 1;
        let max_code = if self.has_inf_nan { top - 1 } else { top };
        max_code - self.bias()
    }

    /// Smallest positive normal value.
    pub fn min_normal(&self) -> f64 {
        (self.min_normal_exp() as f64).exp2()
    }

    /// Smallest positive subnormal value: `2^(min_normal_exp - m)`.
    pub fn min_subnormal(&self) -> f64 {
        ((self.min_normal_exp() - self.man_bits as i32) as f64).exp2()
    }

    /// Largest finite value.
    pub fn max_finite(&self) -> f64 {
        let frac = 2.0 - (-(self.man_bits as f64)).exp2();
        frac * (self.max_exp() as f64).exp2()
    }

    /// Unit-in-the-last-place of `x` in this format (stepsize of its
    /// exponent range), used throughout the underflow analysis:
    /// `2^(floor(log2|x|) - m)` clamped to the subnormal step.
    pub fn ulp(&self, x: f64) -> f64 {
        if x == 0.0 || !x.is_finite() {
            return self.min_subnormal();
        }
        let e = x.abs().log2().floor() as i32;
        let e = e.max(self.min_normal_exp());
        ((e - self.man_bits as i32) as f64).exp2()
    }

    /// Total number of bits (sign + exp + mantissa).
    pub const fn total_bits(&self) -> u32 {
        1 + self.exp_bits + self.man_bits
    }

    /// Cast `x` into this format with round-to-nearest-even. Exact for
    /// `e <= 11`, `m <= 52`.
    pub fn cast(&self, x: f64) -> f64 {
        self.cast_mode(x, Rounding::NearestEven, 0)
    }

    /// Cast with an explicit rounding mode. `rand` is consumed only by
    /// [`Rounding::Stochastic`]; pass 0 otherwise.
    ///
    /// NaN policy (enforced by `tests/quant_suite.rs`): formats *with*
    /// inf/nan codes propagate NaN; formats *without* them (the saturating
    /// OCP-style FP8/FP6/FP4 variants) have no NaN encoding at all, so a
    /// NaN input saturates to ±max_finite — casting can then never produce
    /// a value the packed codec cannot represent.
    pub fn cast_mode(&self, x: f64, mode: Rounding, rand: u32) -> f64 {
        if x.is_nan() {
            return if self.has_inf_nan {
                f64::NAN
            } else {
                self.max_finite().copysign(x)
            };
        }
        if x.is_infinite() {
            return if self.has_inf_nan {
                x
            } else {
                self.max_finite().copysign(x)
            };
        }
        if x == 0.0 {
            return x; // preserve signed zero
        }
        let sign = if x < 0.0 { -1.0 } else { 1.0 };
        let a = x.abs();

        // Determine the quantization step for the exponent range of `a`,
        // treating values below min_normal as subnormal.
        let e = a.log2().floor() as i32;
        let eff_e = e.max(self.min_normal_exp());
        let step = ((eff_e - self.man_bits as i32) as f64).exp2();

        let q = a / step; // in units of the step; may be fractional
        let r = match mode {
            Rounding::NearestEven => round_ties_even(q),
            Rounding::TowardZero => q.floor(),
            Rounding::Stochastic => {
                let frac = q - q.floor();
                // rand/2^32 uniform in [0,1)
                let u = (rand as f64) / 4294967296.0;
                if frac > u {
                    q.floor() + 1.0
                } else {
                    q.floor()
                }
            }
        };
        let v = r * step;

        // Overflow handling. Note rounding can bump into the next binade,
        // which is fine — we only clamp past max_finite.
        if v > self.max_finite() {
            return match self.overflow {
                Overflow::Saturate => self.max_finite() * sign,
                Overflow::Infinity => f64::INFINITY * sign,
            };
        }
        v * sign
    }

    /// Cast an `f32`, convenience wrapper.
    pub fn cast_f32(&self, x: f32) -> f32 {
        self.cast(x as f64) as f32
    }

    /// True iff `x` is exactly representable (cast is the identity).
    pub fn is_representable(&self, x: f64) -> bool {
        let c = self.cast(x);
        (c == x) || (c.is_nan() && x.is_nan())
    }

    /// True iff casting `x` underflows to zero (x != 0 but cast(x) == 0).
    pub fn underflows(&self, x: f64) -> bool {
        x != 0.0 && x.is_finite() && self.cast(x) == 0.0
    }

    /// Enumerate every non-negative finite representable value in ascending
    /// order. Cheap for tiny formats (FP4/FP6/FP8); used by exhaustive tests.
    pub fn enumerate_non_negative(&self) -> Vec<f64> {
        let mut out = vec![0.0];
        // subnormals
        for frac in 1..(1u64 << self.man_bits) {
            out.push(frac as f64 * self.min_subnormal());
        }
        // normals
        for e in self.min_normal_exp()..=self.max_exp() {
            for frac in 0..(1u64 << self.man_bits) {
                let mant = 1.0 + frac as f64 / (1u64 << self.man_bits) as f64;
                out.push(mant * (e as f64).exp2());
            }
        }
        out
    }
}

/// `round(x)` with ties to even, like IEEE RNE at integer granularity.
pub fn round_ties_even(x: f64) -> f64 {
    let fl = x.floor();
    let frac = x - fl;
    if frac > 0.5 {
        fl + 1.0
    } else if frac < 0.5 {
        fl
    } else {
        // tie: pick the even integer
        if (fl as i64) % 2 == 0 {
            fl
        } else {
            fl + 1.0
        }
    }
}

/// Named formats used throughout the paper and Table C.1.
pub mod formats {
    use super::FpFormat;

    /// bfloat16: e8m7 (same exponent range as f32).
    pub const BF16: FpFormat = FpFormat::ieee(8, 7);
    /// IEEE half precision: e5m10.
    pub const FP16: FpFormat = FpFormat::ieee(5, 10);
    /// OCP FP8 E4M3 (saturating, no inf).
    pub const FP8_E4M3: FpFormat = FpFormat::saturating(4, 3);
    /// FP8 E5M2 (IEEE-like).
    pub const FP8_E5M2: FpFormat = FpFormat::ieee(5, 2);
    /// FP8 E3M4 — discussed in Table C.1 as the b_t <= 5 parameter type.
    pub const FP8_E3M4: FpFormat = FpFormat::saturating(3, 4);
    /// FP6 E3M2 — Table C.1 lower bound for b_t <= 4.
    pub const FP6_E3M2: FpFormat = FpFormat::saturating(3, 2);
    /// FP6 E2M3.
    pub const FP6_E2M3: FpFormat = FpFormat::saturating(2, 3);
    /// FP4 E2M1 (MXFP4 element type).
    pub const FP4_E2M1: FpFormat = FpFormat::saturating(2, 1);
    /// FP12 E4M7 — Table C.1 for b_t <= 9.
    pub const FP12_E4M7: FpFormat = FpFormat::saturating(4, 7);
    /// f32 emulation bound (identity for f32 inputs).
    pub const FP32: FpFormat = FpFormat::ieee(8, 23);

    // Name-based lookup lives in `crate::quant::Registry` — the one place
    // format labels are parsed (`quant::resolve("fp8_e3m4")`, etc.).
}

#[cfg(test)]
mod tests {
    use super::formats::*;
    use super::*;

    #[test]
    fn bf16_cast_matches_bit_truncation_rne() {
        // Compare against direct f32-bit RNE truncation to 7 mantissa bits.
        let vals = [1.0f32, 1.5, 3.1415926, -0.3333, 1e-30, 6.5e4, -1.234e-5];
        for &v in &vals {
            let expect = {
                let bits = v.to_bits();
                let lsb = (bits >> 16) & 1;
                let rounded = bits.wrapping_add(0x7fff + lsb);
                f32::from_bits(rounded & 0xffff_0000)
            };
            let got = BF16.cast_f32(v);
            assert_eq!(got, expect, "v={v}");
        }
    }

    #[test]
    fn fp16_constants() {
        assert_eq!(FP16.max_finite(), 65504.0);
        assert_eq!(FP16.min_normal(), 6.103515625e-5);
        assert_eq!(FP16.min_subnormal(), 5.960464477539063e-8);
    }

    #[test]
    fn fp8_e4m3_range() {
        // IEEE-like e4m3 with saturation: max = (2 - 2^-3) * 2^8 = 480.
        assert_eq!(FP8_E4M3.max_finite(), 480.0);
        assert_eq!(FP8_E4M3.cast(1e6), 480.0);
        assert_eq!(FP8_E4M3.cast(-1e6), -480.0);
    }

    #[test]
    fn fp6_e3m2_enumeration_is_sorted_and_distinct() {
        let vals = FP6_E3M2.enumerate_non_negative();
        // zero + 3 subnormals + 7 exponent ranges × 4 mantissas = 32 codes
        assert_eq!(vals.len(), 1 + 3 + 7 * 4);
        for w in vals.windows(2) {
            assert!(w[0] < w[1], "{} !< {}", w[0], w[1]);
        }
        for v in vals {
            assert!(FP6_E3M2.is_representable(v));
        }
    }

    #[test]
    fn rne_ties_to_even() {
        assert_eq!(round_ties_even(0.5), 0.0);
        assert_eq!(round_ties_even(1.5), 2.0);
        assert_eq!(round_ties_even(2.5), 2.0);
        assert_eq!(round_ties_even(-0.5), -0.0);
        assert_eq!(round_ties_even(3.5), 4.0);
    }

    #[test]
    fn cast_is_idempotent() {
        for fmt in [FP16, FP8_E4M3, FP8_E3M4, FP6_E3M2, FP4_E2M1, FP12_E4M7] {
            for i in 0..1000 {
                let x = (i as f64 - 500.0) * 0.137 + 0.001;
                let once = fmt.cast(x);
                assert_eq!(fmt.cast(once), once, "fmt={fmt:?} x={x}");
            }
        }
    }

    #[test]
    fn subnormal_underflow_threshold() {
        // Values below half the min subnormal round to zero under RNE.
        for fmt in [FP16, FP8_E4M3, FP6_E3M2] {
            let tiny = fmt.min_subnormal() * 0.49;
            assert!(fmt.underflows(tiny));
            let keep = fmt.min_subnormal() * 0.51;
            assert!(!fmt.underflows(keep));
        }
    }

    #[test]
    fn toward_zero_truncates() {
        let got = FP8_E4M3.cast_mode(1.99, Rounding::TowardZero, 0);
        assert!(got <= 1.99);
        // 1.99 in e4m3: step at [1,2) is 2^-3; floor(1.99/0.125)*0.125 = 1.875
        assert_eq!(got, 1.875);
    }

    #[test]
    fn stochastic_rounding_is_unbiased() {
        // Mean of many stochastic casts approximates the input.
        let fmt = FP8_E4M3;
        let x = 1.3; // between 1.25 and 1.375
        let mut acc = 0.0;
        let mut state = 0x1234_5678u32;
        let n = 20000;
        for _ in 0..n {
            // xorshift32 as the random source
            state ^= state << 13;
            state ^= state >> 17;
            state ^= state << 5;
            acc += fmt.cast_mode(x, Rounding::Stochastic, state);
        }
        let mean = acc / n as f64;
        assert!((mean - x).abs() < 5e-3, "mean={mean}");
    }

    #[test]
    fn ulp_matches_step() {
        assert_eq!(FP16.ulp(1.0), (2.0f64).powi(-10));
        assert_eq!(FP16.ulp(2.0), (2.0f64).powi(-9));
        assert_eq!(FP8_E4M3.ulp(1.5), 0.125);
    }

}
