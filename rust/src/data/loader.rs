//! Batching + sharding: deterministic, seekable batch streams over a token
//! corpus, shardable across the simulated data-parallel workers.

use super::synth::SynthCorpus;
use crate::prng::Philox4x32;

/// A (inputs, targets) batch of next-token training windows.
#[derive(Debug, Clone, PartialEq)]
pub struct Batch {
    pub batch: usize,
    pub seq_len: usize,
    /// batch × seq_len token ids
    pub x: Vec<u32>,
    /// batch × seq_len next-token targets
    pub y: Vec<u32>,
}

/// Deterministic random-window loader over a corpus; counter-addressed so
/// any (step, worker) batch can be regenerated without streaming state.
#[derive(Debug, Clone)]
pub struct Loader {
    corpus: SynthCorpus,
    pub batch: usize,
    pub seq_len: usize,
    pub seed: u64,
    /// This loader's shard id and total shard count (data parallelism).
    pub shard: usize,
    pub n_shards: usize,
}

impl Loader {
    pub fn new(corpus: SynthCorpus, batch: usize, seq_len: usize, seed: u64) -> Loader {
        assert!(corpus.tokens.len() > seq_len + 1, "corpus shorter than one window");
        Loader { corpus, batch, seq_len, seed, shard: 0, n_shards: 1 }
    }

    /// Restrict to shard `i` of `n` (each shard sees disjoint batches).
    pub fn sharded(mut self, shard: usize, n_shards: usize) -> Loader {
        assert!(shard < n_shards);
        self.shard = shard;
        self.n_shards = n_shards;
        self
    }

    pub fn vocab(&self) -> usize {
        self.corpus.vocab
    }

    /// The batch for a given global step. Disjoint across shards at the
    /// same step (counter space is striped by shard).
    pub fn batch_at(&self, step: u64) -> Batch {
        let counter = (step as u128) * self.n_shards as u128 + self.shard as u128;
        let mut g = Philox4x32::with_counter(self.seed, counter << 32);
        let span = self.corpus.tokens.len() - self.seq_len - 1;
        let mut x = Vec::with_capacity(self.batch * self.seq_len);
        let mut y = Vec::with_capacity(self.batch * self.seq_len);
        for _ in 0..self.batch {
            let start = (g.next_u64() % span as u64) as usize;
            x.extend_from_slice(&self.corpus.tokens[start..start + self.seq_len]);
            y.extend_from_slice(&self.corpus.tokens[start + 1..start + self.seq_len + 1]);
        }
        Batch { batch: self.batch, seq_len: self.seq_len, x, y }
    }

    /// Tokens consumed per step per shard.
    pub fn tokens_per_batch(&self) -> usize {
        self.batch * self.seq_len
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;

    fn loader() -> Loader {
        let corpus = SynthCorpus::generate(SynthSpec { len: 100_000, ..Default::default() });
        Loader::new(corpus, 4, 32, 7)
    }

    #[test]
    fn shapes_and_target_shift() {
        let b = loader().batch_at(0);
        assert_eq!(b.x.len(), 4 * 32);
        assert_eq!(b.y.len(), 4 * 32);
        // y is x shifted by one within each row
        for row in 0..4 {
            for i in 0..31 {
                assert_eq!(b.y[row * 32 + i], b.x[row * 32 + i + 1]);
            }
        }
    }

    #[test]
    fn deterministic_and_step_addressable() {
        let l = loader();
        assert_eq!(l.batch_at(5), l.batch_at(5));
        assert_ne!(l.batch_at(5).x, l.batch_at(6).x);
    }

    #[test]
    fn shards_are_disjoint_at_same_step() {
        let corpus = SynthCorpus::generate(SynthSpec { len: 100_000, ..Default::default() });
        let a = Loader::new(corpus.clone(), 4, 32, 7).sharded(0, 2);
        let b = Loader::new(corpus, 4, 32, 7).sharded(1, 2);
        assert_ne!(a.batch_at(3).x, b.batch_at(3).x);
    }

    #[test]
    fn tokens_in_vocab() {
        let l = loader();
        let b = l.batch_at(11);
        assert!(b.x.iter().chain(b.y.iter()).all(|&t| (t as usize) < l.vocab()));
    }
}
