//! Byte-level tokenizer with an optional tiny BPE merge table — the
//! tokenization substrate for feeding real text through the pipeline
//! (quickstart demo / fq_inference on text prompts).

use std::collections::HashMap;

/// Byte-level BPE tokenizer: ids 0..=255 are raw bytes, ids ≥256 are merges.
#[derive(Debug, Clone)]
pub struct Tokenizer {
    /// merge list in priority order: (left, right) -> new id 256+i
    merges: Vec<(u32, u32)>,
    merge_rank: HashMap<(u32, u32), usize>,
}

impl Tokenizer {
    /// A pure byte tokenizer (no merges).
    pub fn bytes() -> Tokenizer {
        Tokenizer { merges: vec![], merge_rank: HashMap::new() }
    }

    /// Train `n_merges` BPE merges over a corpus.
    pub fn train(corpus: &[u8], n_merges: usize) -> Tokenizer {
        let mut ids: Vec<u32> = corpus.iter().map(|&b| b as u32).collect();
        let mut merges = Vec::new();
        for step in 0..n_merges {
            let mut counts: HashMap<(u32, u32), usize> = HashMap::new();
            for w in ids.windows(2) {
                *counts.entry((w[0], w[1])).or_insert(0) += 1;
            }
            // deterministic tie-break: highest count, then smallest pair
            let best = counts.iter().max_by_key(|(pair, &c)| (c, std::cmp::Reverse(**pair)));
            let Some((&pair, &count)) = best else { break };
            if count < 2 {
                break;
            }
            let new_id = 256 + step as u32;
            merges.push(pair);
            ids = merge_pass(&ids, pair, new_id);
        }
        let merge_rank = merges.iter().enumerate().map(|(i, &p)| (p, i)).collect();
        Tokenizer { merges, merge_rank }
    }

    /// Vocabulary size (256 + merges).
    pub fn vocab(&self) -> usize {
        256 + self.merges.len()
    }

    /// Encode bytes to token ids.
    pub fn encode(&self, text: &[u8]) -> Vec<u32> {
        let mut ids: Vec<u32> = text.iter().map(|&b| b as u32).collect();
        // repeatedly apply the lowest-rank applicable merge
        loop {
            let mut best: Option<(usize, (u32, u32))> = None;
            for w in ids.windows(2) {
                if let Some(&rank) = self.merge_rank.get(&(w[0], w[1])) {
                    if best.map_or(true, |(r, _)| rank < r) {
                        best = Some((rank, (w[0], w[1])));
                    }
                }
            }
            match best {
                Some((rank, pair)) => {
                    ids = merge_pass(&ids, pair, 256 + rank as u32);
                }
                None => break,
            }
        }
        ids
    }

    /// Decode token ids back to bytes.
    pub fn decode(&self, ids: &[u32]) -> Vec<u8> {
        let mut out = Vec::new();
        for &id in ids {
            self.push_bytes(id, &mut out);
        }
        out
    }

    fn push_bytes(&self, id: u32, out: &mut Vec<u8>) {
        if id < 256 {
            out.push(id as u8);
        } else {
            let (l, r) = self.merges[(id - 256) as usize];
            self.push_bytes(l, out);
            self.push_bytes(r, out);
        }
    }
}

fn merge_pass(ids: &[u32], pair: (u32, u32), new_id: u32) -> Vec<u32> {
    let mut out = Vec::with_capacity(ids.len());
    let mut i = 0;
    while i < ids.len() {
        if i + 1 < ids.len() && (ids[i], ids[i + 1]) == pair {
            out.push(new_id);
            i += 2;
        } else {
            out.push(ids[i]);
            i += 1;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn byte_tokenizer_roundtrip() {
        let t = Tokenizer::bytes();
        let text = b"hello, GaussWS \xf0\x9f\x8e\xb2";
        assert_eq!(t.decode(&t.encode(text)), text);
        assert_eq!(t.vocab(), 256);
    }

    #[test]
    fn bpe_roundtrip_and_compression() {
        let corpus = b"the cat sat on the mat. the cat sat on the hat. the cat ran.".repeat(20);
        let t = Tokenizer::train(&corpus, 32);
        assert!(t.vocab() > 256);
        let ids = t.encode(&corpus);
        assert!(ids.len() < corpus.len(), "{} !< {}", ids.len(), corpus.len());
        assert_eq!(t.decode(&ids), corpus);
    }

    #[test]
    fn training_is_deterministic() {
        let corpus = b"abababab cdcdcdcd".repeat(10);
        let a = Tokenizer::train(&corpus, 8);
        let b = Tokenizer::train(&corpus, 8);
        assert_eq!(a.merges, b.merges);
    }

    #[test]
    fn unseen_text_still_roundtrips() {
        let t = Tokenizer::train(b"aaaa bbbb aaaa bbbb", 4);
        let novel = b"zzzz qqqq aaaa";
        assert_eq!(t.decode(&t.encode(novel)), novel);
    }
}
