//! Data pipeline substrate: synthetic corpus (OpenWebText/C4 stand-in),
//! byte-BPE tokenizer, and the deterministic sharded batch loader.

pub mod loader;
pub mod synth;
pub mod tokenizer;

pub use loader::{Batch, Loader};
pub use synth::{SynthCorpus, SynthSpec};
pub use tokenizer::Tokenizer;
