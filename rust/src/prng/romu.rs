//! Romu family of fast nonlinear PRNGs (Overton, 2020) — the "legacy
//! hardware friendly" generator cited by the paper (§3.4): multiply-free
//! variants exist and state is tiny. We provide RomuTrio (the recommended
//! general-purpose member) and RomuDuoJr (fastest).

/// RomuTrio: 192-bit state, period > 2^75 w.h.p.
#[derive(Debug, Clone, Copy)]
pub struct RomuTrio {
    x: u64,
    y: u64,
    z: u64,
}

impl RomuTrio {
    /// Seed via SplitMix64 expansion to avoid weak all-zero-ish states.
    pub fn new(seed: u64) -> Self {
        let mut s = seed;
        let mut next = || {
            s = s.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = s;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        let mut g = RomuTrio { x: next(), y: next(), z: next() };
        if g.x == 0 && g.y == 0 && g.z == 0 {
            g.x = 1;
        }
        // warm up
        for _ in 0..4 {
            g.next_u64();
        }
        g
    }

    #[inline(always)]
    pub fn next_u64(&mut self) -> u64 {
        let xp = self.x;
        let yp = self.y;
        let zp = self.z;
        self.x = zp.wrapping_mul(15241094284759029579);
        self.y = yp.wrapping_sub(xp).rotate_left(12);
        self.z = zp.wrapping_sub(yp).rotate_left(44);
        xp
    }

    #[inline(always)]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform f64 in [0,1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }
}

/// RomuDuoJr: 128-bit state, fastest member; fine for noise generation where
/// statistical demands are modest and throughput is the point.
#[derive(Debug, Clone, Copy)]
pub struct RomuDuoJr {
    x: u64,
    y: u64,
}

impl RomuDuoJr {
    pub fn new(seed: u64) -> Self {
        let mut s = seed;
        let mut next = || {
            s = s.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = s;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        let mut g = RomuDuoJr { x: next(), y: next() };
        if g.x == 0 && g.y == 0 {
            g.x = 1;
        }
        for _ in 0..4 {
            g.next_u64();
        }
        g
    }

    #[inline(always)]
    pub fn next_u64(&mut self) -> u64 {
        let xp = self.x;
        self.x = self.y.wrapping_mul(15241094284759029579);
        self.y = self.y.wrapping_sub(xp).rotate_left(27);
        xp
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trio_deterministic() {
        let mut a = RomuTrio::new(99);
        let mut b = RomuTrio::new(99);
        for _ in 0..32 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn trio_uniform_mean() {
        let mut g = RomuTrio::new(5);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| g.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn duojr_no_short_cycle() {
        let mut g = RomuDuoJr::new(1);
        let first = g.next_u64();
        for _ in 0..10_000 {
            assert_ne!(g.next_u64(), first); // coarse anti-cycle check
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = RomuTrio::new(1);
        let mut b = RomuTrio::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
