//! Philox4x32-10 counter-based PRNG (Salmon et al., SC'11).
//!
//! Counter-based generation is what makes the paper's seed discipline
//! (Section 3.6) work: the forward and backward passes regenerate *the same*
//! noise by replaying the same (key, counter) pairs, with no stored stream
//! state. This is also the PRNG family used by CUDA/cuRAND and
//! `jax.random` (threefry/philox).

/// Philox4x32-10: 64-bit key, 128-bit counter, 128 random bits per block.
#[derive(Debug, Clone, Copy)]
pub struct Philox4x32 {
    key: [u32; 2],
    counter: [u32; 4],
}

const PHILOX_M0: u32 = 0xD251_1F53;
const PHILOX_M1: u32 = 0xCD9E_8D57;
const PHILOX_W0: u32 = 0x9E37_79B9;
const PHILOX_W1: u32 = 0xBB67_AE85;

#[inline(always)]
fn mulhilo(a: u32, b: u32) -> (u32, u32) {
    let p = (a as u64) * (b as u64);
    ((p >> 32) as u32, p as u32)
}

impl Philox4x32 {
    /// Create from a 64-bit key (seed); counter starts at zero.
    pub fn new(seed: u64) -> Self {
        Philox4x32 { key: [seed as u32, (seed >> 32) as u32], counter: [0; 4] }
    }

    /// Create positioned at an arbitrary 128-bit counter. Used to jump the
    /// stream to a (step, offset) coordinate without generating.
    pub fn with_counter(seed: u64, counter: u128) -> Self {
        Philox4x32 {
            key: [seed as u32, (seed >> 32) as u32],
            counter: [
                counter as u32,
                (counter >> 32) as u32,
                (counter >> 64) as u32,
                (counter >> 96) as u32,
            ],
        }
    }

    /// One Philox round.
    #[inline(always)]
    fn round(ctr: [u32; 4], key: [u32; 2]) -> [u32; 4] {
        let (hi0, lo0) = mulhilo(PHILOX_M0, ctr[0]);
        let (hi1, lo1) = mulhilo(PHILOX_M1, ctr[2]);
        [hi1 ^ ctr[1] ^ key[0], lo1, hi0 ^ ctr[3] ^ key[1], lo0]
    }

    /// Generate the 128-bit block at the current counter and advance.
    #[inline]
    pub fn next_block(&mut self) -> [u32; 4] {
        let mut ctr = self.counter;
        let mut key = self.key;
        for _ in 0..10 {
            ctr = Self::round(ctr, key);
            key[0] = key[0].wrapping_add(PHILOX_W0);
            key[1] = key[1].wrapping_add(PHILOX_W1);
        }
        // advance 128-bit counter
        let (c0, carry0) = self.counter[0].overflowing_add(1);
        self.counter[0] = c0;
        if carry0 {
            let (c1, carry1) = self.counter[1].overflowing_add(1);
            self.counter[1] = c1;
            if carry1 {
                let (c2, carry2) = self.counter[2].overflowing_add(1);
                self.counter[2] = c2;
                if carry2 {
                    self.counter[3] = self.counter[3].wrapping_add(1);
                }
            }
        }
        ctr
    }

    /// Next 32 random bits.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        self.next_block()[0]
    }

    /// Next 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let b = self.next_block();
        (b[0] as u64) | ((b[1] as u64) << 32)
    }

    /// Fill `out` with random u32 words (4 per block).
    pub fn fill_u32(&mut self, out: &mut [u32]) {
        let mut chunks = out.chunks_exact_mut(4);
        for chunk in &mut chunks {
            let b = self.next_block();
            chunk.copy_from_slice(&b);
        }
        let rem = chunks.into_remainder();
        if !rem.is_empty() {
            let b = self.next_block();
            for (dst, src) in rem.iter_mut().zip(b.iter()) {
                *dst = *src;
            }
        }
    }

    /// Uniform f64 in [0, 1) with 53-bit precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in [0, 1) with 24-bit precision.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 / (1u32 << 24) as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_counter_addressable() {
        let mut a = Philox4x32::new(42);
        let seq: Vec<u32> = (0..16).map(|_| a.next_u32()).collect();
        let mut b = Philox4x32::new(42);
        let seq2: Vec<u32> = (0..16).map(|_| b.next_u32()).collect();
        assert_eq!(seq, seq2);
        // jumping to counter=8 reproduces the 9th block
        let mut c = Philox4x32::with_counter(42, 8);
        assert_eq!(c.next_u32(), seq[8]);
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Philox4x32::new(1);
        let mut b = Philox4x32::new(2);
        let xa: Vec<u32> = (0..8).map(|_| a.next_u32()).collect();
        let xb: Vec<u32> = (0..8).map(|_| b.next_u32()).collect();
        assert_ne!(xa, xb);
    }

    #[test]
    fn uniformity_coarse() {
        // Mean of 100k uniforms should be ~0.5; variance ~1/12.
        let mut g = Philox4x32::new(2026);
        let n = 100_000;
        let mut sum = 0.0;
        let mut sumsq = 0.0;
        for _ in 0..n {
            let u = g.next_f64();
            sum += u;
            sumsq += u * u;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!((mean - 0.5).abs() < 0.005, "mean={mean}");
        assert!((var - 1.0 / 12.0).abs() < 0.005, "var={var}");
    }

    #[test]
    fn bit_balance() {
        // each of the 32 bit positions should be ~50% ones
        let mut g = Philox4x32::new(7);
        let n = 20_000;
        let mut counts = [0u32; 32];
        for _ in 0..n {
            let x = g.next_u32();
            for (i, c) in counts.iter_mut().enumerate() {
                *c += (x >> i) & 1;
            }
        }
        for (i, &c) in counts.iter().enumerate() {
            let frac = c as f64 / n as f64;
            assert!((frac - 0.5).abs() < 0.02, "bit {i}: {frac}");
        }
    }

    #[test]
    fn fill_handles_non_multiple_of_four() {
        let mut g = Philox4x32::new(3);
        let mut buf = vec![0u32; 10];
        g.fill_u32(&mut buf);
        let mut g2 = Philox4x32::new(3);
        let expect: Vec<u32> = {
            let mut v = Vec::new();
            for _ in 0..3 {
                v.extend_from_slice(&g2.next_block());
            }
            v.truncate(10);
            v
        };
        assert_eq!(buf, expect);
    }
}
