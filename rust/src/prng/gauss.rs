//! Gaussian sampling baselines: Box–Muller `N(0,1)` and the exact rounded
//! normal `⌊N(0,1)/2⌉`.
//!
//! These are the comparison points for Figure 6: the "conventional" way to
//! obtain the paper's noise is PRNG → uniform → Box–Muller → divide → round,
//! all in floating point. The bitwise generator in [`super::bitwise`]
//! replaces every one of those FP ops with AND/OR.

use super::philox::Philox4x32;
use std::f64::consts::PI;

/// Draw two independent `N(0,1)` samples via the Box–Muller transform.
#[inline]
pub fn box_muller_pair(g: &mut Philox4x32) -> (f64, f64) {
    // u1 in (0,1] to avoid ln(0)
    let u1 = 1.0 - g.next_f64();
    let u2 = g.next_f64();
    let r = (-2.0 * u1.ln()).sqrt();
    let theta = 2.0 * PI * u2;
    (r * theta.cos(), r * theta.sin())
}

/// Fill `out` with `N(0,1)` samples (Box–Muller).
pub fn fill_normal(seed: u64, out: &mut [f64]) {
    let mut g = Philox4x32::new(seed);
    let mut chunks = out.chunks_exact_mut(2);
    for pair in &mut chunks {
        let (a, b) = box_muller_pair(&mut g);
        pair[0] = a;
        pair[1] = b;
    }
    if let [last] = chunks.into_remainder() {
        *last = box_muller_pair(&mut g).0;
    }
}

/// Exact rounded normal `⌊N(0,1)/2⌉` — round-half-away-from-zero of `N/2`,
/// i.e. support {…,−2,−1,0,1,2,…} with `Pr(0) = P(|N| < 1) ≈ 0.6827`.
#[inline]
pub fn rounded_normal(g: &mut Philox4x32) -> i32 {
    let (a, _) = box_muller_pair(g);
    (a / 2.0).round() as i32
}

/// Fill a buffer with exact rounded normals (f32-valued, for the DiffQ-style
/// reference path and distribution comparisons).
pub fn fill_rounded_normal(seed: u64, out: &mut [f32]) {
    let mut g = Philox4x32::new(seed);
    let mut i = 0;
    while i + 1 < out.len() {
        let (a, b) = box_muller_pair(&mut g);
        out[i] = (a / 2.0).round() as f32;
        out[i + 1] = (b / 2.0).round() as f32;
        i += 2;
    }
    if i < out.len() {
        out[i] = rounded_normal(&mut g) as f32;
    }
}

/// Fill a buffer with uniform `U(-0.5, 0.5)` samples — the DiffQ noise basis.
pub fn fill_uniform_pm_half(seed: u64, out: &mut [f32]) {
    let mut g = Philox4x32::new(seed);
    for o in out.iter_mut() {
        *o = g.next_f32() - 0.5;
    }
}

/// Theoretical probabilities of the *exact* rounded normal over {0,±1,±2}:
/// `(p0, p1_each, p2_each)` from the normal CDF.
pub fn exact_rounded_probs() -> (f64, f64, f64) {
    // Φ via erf approximation (Abramowitz–Stegun 7.1.26, |err| < 1.5e-7)
    fn phi(x: f64) -> f64 {
        0.5 * (1.0 + erf(x / std::f64::consts::SQRT_2))
    }
    fn erf(x: f64) -> f64 {
        let sign = if x < 0.0 { -1.0 } else { 1.0 };
        let x = x.abs();
        let t = 1.0 / (1.0 + 0.3275911 * x);
        let y = 1.0
            - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736) * t
                + 0.254829592)
                * t
                * (-x * x).exp();
        sign * y
    }
    let p0 = phi(1.0) - phi(-1.0);
    let p1 = phi(3.0) - phi(1.0);
    let p2 = 1.0 - phi(3.0); // everything beyond ±3 rounds to ≥2; tail mass
    (p0, p1, p2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normal_moments() {
        let mut buf = vec![0f64; 200_000];
        fill_normal(5, &mut buf);
        let n = buf.len() as f64;
        let mean = buf.iter().sum::<f64>() / n;
        let var = buf.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn rounded_normal_distribution() {
        let mut buf = vec![0f32; 500_000];
        fill_rounded_normal(9, &mut buf);
        let n = buf.len() as f64;
        let count = |v: f32| buf.iter().filter(|&&x| x == v).count() as f64 / n;
        let (p0, p1, _p2) = exact_rounded_probs();
        assert!((count(0.0) - p0).abs() < 5e-3, "p0={} expect={}", count(0.0), p0);
        assert!((count(1.0) - p1).abs() < 3e-3);
        assert!((count(-1.0) - p1).abs() < 3e-3);
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut buf = vec![0f32; 100_000];
        fill_uniform_pm_half(13, &mut buf);
        assert!(buf.iter().all(|&x| (-0.5..0.5).contains(&x)));
        let mean: f32 = buf.iter().sum::<f32>() / buf.len() as f32;
        assert!(mean.abs() < 5e-3, "mean={mean}");
    }

    #[test]
    fn eq10_approximation_is_close_to_exact() {
        // The paper's bitwise approximation vs the true rounded normal:
        // Pr(0): 0.717 vs 0.6827 — within 0.035; Pr(±1): 0.140 vs 0.157.
        let (a0, a1, _a2) = super::super::bitwise::target_probabilities();
        let (e0, e1, _e2) = exact_rounded_probs();
        assert!((a0 - e0).abs() < 0.04);
        assert!((a1 - e1).abs() < 0.02);
    }
}
