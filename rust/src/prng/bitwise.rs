//! Efficient generation of the approximated rounded normal `R ≈ ⌊N(0,1)/2⌉`
//! using only bitwise operations (paper Section 3.4, Eq. 9–10).
//!
//! Target distribution over the support {−2, −1, 0, +1, +2}:
//!
//! ```text
//! Pr(±2) = 3/4 · 2^-9           ≈ 1/682.7   (each)
//! Pr(±1) = (3/4)^2 · 2^-2 · (1 − Pr(|R|=2)) ≈ 1/7.1 (each)
//! Pr(0)  = remainder            ≈ 0.717
//! ```
//!
//! Construction from independent random bits, 32 lanes at a time (one bit
//! per lane across a `u32` word):
//!
//! * `mag2 = (a ∨ b) ∧ c₁ ∧ … ∧ c₈` — probability `3/4 · 2^-8` (the
//!   *magnitude* event; the sign bit halves it to the `3/4 · 2^-9` above).
//! * `mag1 = (d ∨ e) ∧ (f ∨ g) ∧ h` — probability `(3/4)² · 2^-1`, applied
//!   only where `mag2` is clear.
//! * `sign` — one raw random bit.
//!
//! Output is packed **sign–mantissa, 4 bits per element, 8 elements per
//! `u32`** exactly as in the paper: `code = sign << 3 | magnitude`, with
//! magnitude ∈ {0, 1, 2}. Dequantization multiplies by the per-block scale.
//!
//! Two generator variants:
//! * [`generate_exact`] — 16 fresh random words per 32 elements; every bit
//!   independent (the reference).
//! * [`generate_fast`] — 4 fresh random words per 32 elements; the rare
//!   `mag2` AND-chain reuses rotated copies of the same words. Marginal
//!   per-lane probabilities are unchanged; only intra-word correlations are
//!   introduced, which the tests bound. This mirrors the paper's trade-off
//!   of tuning PRNG work per output element.

use super::philox::Philox4x32;

/// Number of 4-bit codes packed per u32 word.
pub const CODES_PER_WORD: usize = 8;

/// Packed 4-bit sign–mantissa codes for a noise tensor, 8 per u32 —
/// 0.5 bytes per element, the paper's temporary-R footprint (§4.2).
#[derive(Debug, Clone)]
pub struct PackedNoise {
    /// Packed words; element `i` lives in word `i / 8`, nibble `i % 8`.
    pub words: Vec<u32>,
    /// Number of valid elements (may be less than `words.len() * 8`).
    pub len: usize,
}

impl PackedNoise {
    /// Decode element `i` to its integer value in {−2, −1, 0, +1, +2}.
    #[inline(always)]
    pub fn get(&self, i: usize) -> i32 {
        debug_assert!(i < self.len);
        let nib = (self.words[i / 8] >> ((i % 8) * 4)) & 0xF;
        decode_nibble(nib)
    }

    /// Decode everything to f32 (mostly for tests and small demos).
    pub fn to_f32(&self) -> Vec<f32> {
        (0..self.len).map(|i| self.get(i) as f32).collect()
    }

    /// Bytes of storage used (the 0.5 B/element figure from the paper).
    pub fn storage_bytes(&self) -> usize {
        self.words.len() * 4
    }
}

/// Decode one 4-bit sign–mantissa nibble.
#[inline(always)]
pub fn decode_nibble(nib: u32) -> i32 {
    let mag = (nib & 0x3) as i32;
    if nib & 0x8 != 0 {
        -mag
    } else {
        mag
    }
}

/// Encode sign (true = negative) and magnitude into a nibble.
#[inline(always)]
pub fn encode_nibble(neg: bool, mag: u32) -> u32 {
    debug_assert!(mag <= 2);
    ((neg as u32) << 3) | mag
}

/// Spread the 8 bits of a byte to the low bits of 8 nibbles:
/// bit k of the byte lands at bit 4k of the u32.
const fn spread8(b: u8) -> u32 {
    let mut out = 0u32;
    let mut k = 0;
    while k < 8 {
        out |= (((b >> k) & 1) as u32) << (4 * k);
        k += 1;
    }
    out
}

/// Precomputed byte -> nibble-spread table (perf pass: replaces the
/// per-lane shift loop; see EXPERIMENTS.md §Perf).
static SPREAD: [u32; 256] = {
    let mut t = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        t[i] = spread8(i as u8);
        i += 1;
    }
    t
};

/// Pack 32 per-lane bitplanes (sign, mag-bit0, mag-bit1) into 4 output
/// words of 8 nibbles each: one table lookup per plane byte instead of
/// eight per-lane shifts.
#[inline(always)]
fn pack_lanes(sign: u32, m0: u32, m1: u32, out: &mut [u32; 4]) {
    let sb = sign.to_le_bytes();
    let b0 = m0.to_le_bytes();
    let b1 = m1.to_le_bytes();
    let mut w = 0;
    while w < 4 {
        out[w] = SPREAD[b0[w] as usize]
            | (SPREAD[b1[w] as usize] << 1)
            | (SPREAD[sb[w] as usize] << 3);
        w += 1;
    }
}

/// Compute the three bitplanes (sign, mag0, mag1) for 32 lanes from fully
/// independent words. `r` must hold 16 words.
#[inline(always)]
fn planes_exact(r: &[u32; 16]) -> (u32, u32, u32) {
    let sign = r[0];
    // mag2 event: (a|b) & 8-deep AND chain => p = 3/4 * 2^-8
    let mag2 = (r[1] | r[2]) & r[3] & r[4] & r[5] & r[6] & r[7] & r[8] & r[9] & r[10];
    // mag1 event: (d|e)&(f|g)&h => p = 9/32, masked off where mag2 fires
    let mag1 = (r[11] | r[12]) & (r[13] | r[14]) & r[15] & !mag2;
    // magnitude bits: mag2 -> binary 10, mag1 -> binary 01
    let m0 = mag1;
    let m1 = mag2;
    (sign, m0, m1)
}

/// Bitplanes from only 4 fresh words; the AND chain reuses rotations.
/// Marginal probabilities per lane are identical to `planes_exact`
/// because a rotation of a uniform word is uniform and the chain ANDs
/// 8 *distinct-rotation* copies (lane-wise still 8 distinct bits of the
/// underlying words at distinct positions).
#[inline(always)]
fn planes_fast(r: &[u32; 4]) -> (u32, u32, u32) {
    let sign = r[0];
    let a = r[1];
    let b = r[2];
    let c = r[3];
    // 8-deep AND from rotations of two words: each lane sees 8 bits drawn
    // from distinct positions of (b, c) — independent per lane, correlated
    // across lanes only through rotation overlap.
    let chain = b
        & b.rotate_left(7)
        & b.rotate_left(13)
        & b.rotate_left(22)
        & c
        & c.rotate_left(5)
        & c.rotate_left(17)
        & c.rotate_left(26);
    let mag2 = (a | a.rotate_left(11)) & chain;
    let mag1 = (a.rotate_left(3) | b.rotate_left(29)) & (c.rotate_left(9) | a.rotate_left(19)) & b.rotate_left(16) & !mag2;
    (sign, mag1, mag2)
}

/// Generate `n` packed codes from `seed` using fully independent bits
/// (16 PRNG words / 32 elements). Reference implementation.
pub fn generate_exact(seed: u64, n: usize) -> PackedNoise {
    let mut g = Philox4x32::new(seed);
    let n_groups = n.div_ceil(32);
    let mut words = Vec::with_capacity(n_groups * 4);
    let mut r = [0u32; 16];
    let mut out = [0u32; 4];
    for _ in 0..n_groups {
        g.fill_u32(&mut r);
        let (s, m0, m1) = planes_exact(&r);
        pack_lanes(s, m0, m1, &mut out);
        words.extend_from_slice(&out);
    }
    PackedNoise { words, len: n }
}

/// Generate `n` packed codes from `seed` with the fast 4-words/32-elements
/// construction (the performance hot path; see module docs for the
/// correlation caveat).
pub fn generate_fast(seed: u64, n: usize) -> PackedNoise {
    let mut g = Philox4x32::new(seed);
    let n_groups = n.div_ceil(32);
    let mut words = Vec::with_capacity(n_groups * 4);
    let mut r = [0u32; 4];
    let mut out = [0u32; 4];
    for _ in 0..n_groups {
        g.fill_u32(&mut r);
        let (s, m0, m1) = planes_fast(&r);
        pack_lanes(s, m0, m1, &mut out);
        words.extend_from_slice(&out);
    }
    PackedNoise { words, len: n }
}

/// Dequantize packed codes directly into an f32 buffer scaled by `scale`
/// (a single block's `max|w| · 2^(1-b_t)`), i.e. the PQN of Eq. 3 for one
/// block. `out.len()` must equal `noise.len`.
pub fn dequantize_into(noise: &PackedNoise, scale: f32, out: &mut [f32]) {
    assert_eq!(out.len(), noise.len);
    // Lookup table over the 16 nibble codes — branch-free inner loop.
    let mut lut = [0f32; 16];
    for (nib, slot) in lut.iter_mut().enumerate() {
        *slot = decode_nibble(nib as u32) as f32 * scale;
    }
    let full_words = noise.len / 8;
    for w in 0..full_words {
        let word = noise.words[w];
        let base = w * 8;
        for j in 0..8 {
            out[base + j] = lut[((word >> (j * 4)) & 0xF) as usize];
        }
    }
    for i in full_words * 8..noise.len {
        out[i] = lut[((noise.words[i / 8] >> ((i % 8) * 4)) & 0xF) as usize];
    }
}

/// Exact target probabilities of the Eq. 10 construction.
/// Returns (p_zero, p_one_each, p_two_each).
pub fn target_probabilities() -> (f64, f64, f64) {
    let p2_each = 0.75 * 2f64.powi(-9);
    let p_mag2 = 2.0 * p2_each;
    let p1_each = 0.75 * 0.75 * 0.25 * (1.0 - p_mag2);
    let p0 = 1.0 - 2.0 * p1_each - p_mag2;
    (p0, p1_each, p2_each)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn histogram(noise: &PackedNoise) -> [usize; 5] {
        // index: value + 2
        let mut h = [0usize; 5];
        for i in 0..noise.len {
            h[(noise.get(i) + 2) as usize] += 1;
        }
        h
    }

    #[test]
    fn nibble_roundtrip() {
        for neg in [false, true] {
            for mag in 0..=2u32 {
                let nib = encode_nibble(neg, mag);
                let v = decode_nibble(nib);
                let expect = if neg { -(mag as i32) } else { mag as i32 };
                assert_eq!(v, expect);
            }
        }
    }

    #[test]
    fn exact_generator_matches_eq10_probabilities() {
        let n = 2_000_000;
        let h = histogram(&generate_exact(42, n));
        let (p0, p1, p2) = target_probabilities();
        let f = |c: usize| c as f64 / n as f64;
        assert!((f(h[2]) - p0).abs() < 3e-3, "p0={} vs {}", f(h[2]), p0);
        assert!((f(h[1]) - p1).abs() < 2e-3, "p(-1)={}", f(h[1]));
        assert!((f(h[3]) - p1).abs() < 2e-3, "p(+1)={}", f(h[3]));
        assert!((f(h[0]) - p2).abs() < 4e-4, "p(-2)={}", f(h[0]));
        assert!((f(h[4]) - p2).abs() < 4e-4, "p(+2)={}", f(h[4]));
    }

    #[test]
    fn fast_generator_matches_eq10_probabilities() {
        let n = 2_000_000;
        let h = histogram(&generate_fast(43, n));
        let (p0, p1, p2) = target_probabilities();
        let f = |c: usize| c as f64 / n as f64;
        assert!((f(h[2]) - p0).abs() < 3e-3, "p0={} vs {}", f(h[2]), p0);
        assert!((f(h[1]) - p1).abs() < 2e-3);
        assert!((f(h[3]) - p1).abs() < 2e-3);
        assert!((f(h[0]) - p2).abs() < 4e-4, "p(-2)={}", f(h[0]));
        assert!((f(h[4]) - p2).abs() < 4e-4, "p(+2)={}", f(h[4]));
    }

    #[test]
    fn deterministic_per_seed() {
        let a = generate_fast(7, 1000);
        let b = generate_fast(7, 1000);
        assert_eq!(a.words, b.words);
        let c = generate_fast(8, 1000);
        assert_ne!(a.words, c.words);
    }

    #[test]
    fn mean_near_zero_and_variance_near_rounded_normal() {
        // Var of the Eq.10 dist: 2*(p1*1 + p2*4)
        let n = 1_000_000;
        let noise = generate_exact(11, n);
        let mut sum = 0i64;
        let mut sumsq = 0i64;
        for i in 0..n {
            let v = noise.get(i) as i64;
            sum += v;
            sumsq += v * v;
        }
        let mean = sum as f64 / n as f64;
        let var = sumsq as f64 / n as f64 - mean * mean;
        let (_, p1, p2) = target_probabilities();
        let expect_var = 2.0 * (p1 + 4.0 * p2);
        assert!(mean.abs() < 2e-3, "mean={mean}");
        assert!((var - expect_var).abs() < 5e-3, "var={var} expect={expect_var}");
    }

    #[test]
    fn storage_is_half_byte_per_element() {
        let noise = generate_fast(1, 4096);
        assert_eq!(noise.storage_bytes(), 4096 / 2);
    }

    #[test]
    fn dequantize_scales_correctly() {
        let noise = generate_exact(3, 1000);
        let mut out = vec![0f32; 1000];
        dequantize_into(&noise, 0.25, &mut out);
        for i in 0..1000 {
            assert_eq!(out[i], noise.get(i) as f32 * 0.25);
        }
    }

    #[test]
    fn sign_symmetry() {
        let n = 500_000;
        let noise = generate_fast(21, n);
        let (mut neg, mut pos) = (0usize, 0usize);
        for i in 0..n {
            match noise.get(i) {
                v if v > 0 => pos += 1,
                v if v < 0 => neg += 1,
                _ => {}
            }
        }
        let ratio = pos as f64 / neg as f64;
        assert!((ratio - 1.0).abs() < 0.02, "pos/neg={ratio}");
    }
}
