//! PRNG substrate: counter-based Philox, fast Romu, Box–Muller Gaussian
//! baselines, the paper's Eq. 10 bitwise rounded-normal generator, and the
//! Section-3.6 seed tree.

pub mod bitwise;
pub mod gauss;
pub mod philox;
pub mod romu;
pub mod seedtree;

pub use bitwise::{generate_exact, generate_fast, PackedNoise};
pub use philox::Philox4x32;
pub use romu::{RomuDuoJr, RomuTrio};
pub use seedtree::SeedTree;
