//! Multi-layer seed management (paper Section 3.6, "Managing seed").
//!
//! Requirements the paper states:
//! 1. R in the forward pass must be bit-identical to R regenerated in the
//!    backward pass of the same step.
//! 2. R across layers must be independently random (no shared stream).
//!
//! Structure: a *seed generator* (master PRNG) is initialized with the user
//! seed and deals one sub-seed per layer; each layer owns a PRNG whose state
//! advances **once per gradient update**; the layer PRNG's output for the
//! current step is the seed handed to the bulk generator (the GPU PRNG in
//! the paper; [`crate::prng::bitwise`] here).

use super::philox::Philox4x32;
use std::collections::HashMap;

/// Seed tree: master seed → per-layer streams → per-step bulk seeds.
#[derive(Debug, Clone)]
pub struct SeedTree {
    master_seed: u64,
    /// Per-layer dealt seeds, assigned in registration order.
    layer_seeds: HashMap<String, u64>,
    /// Registration order (stable reporting).
    order: Vec<String>,
    /// Current training step (advanced once per gradient update).
    step: u64,
}

impl SeedTree {
    /// Create a seed tree from the user-specified master seed.
    pub fn new(master_seed: u64) -> Self {
        SeedTree { master_seed, layer_seeds: HashMap::new(), order: Vec::new(), step: 0 }
    }

    /// Register a layer by name and deal it an independent sub-seed.
    /// Idempotent: re-registering returns the existing seed.
    pub fn register_layer(&mut self, name: &str) -> u64 {
        if let Some(&s) = self.layer_seeds.get(name) {
            return s;
        }
        // Deal from the master PRNG at a counter derived from the
        // registration index, so dealing is order-stable and collision-free.
        let idx = self.order.len() as u128;
        let mut g = Philox4x32::with_counter(self.master_seed, idx);
        let seed = g.next_u64();
        self.layer_seeds.insert(name.to_string(), seed);
        self.order.push(name.to_string());
        seed
    }

    /// The bulk-generator seed for `layer` at the **current** step. Calling
    /// this any number of times within a step returns the same value — this
    /// is what guarantees forward/backward R consistency.
    pub fn step_seed(&self, layer: &str) -> u64 {
        let ls = *self
            .layer_seeds
            .get(layer)
            .unwrap_or_else(|| panic!("layer '{layer}' not registered in seed tree"));
        // layer PRNG advanced `step` times == counter-addressed at `step`
        let mut g = Philox4x32::with_counter(ls, self.step as u128);
        g.next_u64()
    }

    /// Advance every layer stream by one gradient update.
    pub fn advance_step(&mut self) {
        self.step += 1;
    }

    /// Current step index.
    pub fn step(&self) -> u64 {
        self.step
    }

    /// Restore to a given step (checkpoint resume).
    pub fn set_step(&mut self, step: u64) {
        self.step = step;
    }

    /// Registered layer names in registration order.
    pub fn layers(&self) -> &[String] {
        &self.order
    }

    /// Master seed (for checkpointing).
    pub fn master_seed(&self) -> u64 {
        self.master_seed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forward_backward_consistency() {
        let mut t = SeedTree::new(1234);
        t.register_layer("blk0.qkv");
        let fwd = t.step_seed("blk0.qkv");
        let bwd = t.step_seed("blk0.qkv"); // later in the same step
        assert_eq!(fwd, bwd);
        t.advance_step();
        assert_ne!(t.step_seed("blk0.qkv"), fwd);
    }

    #[test]
    fn layers_are_independent() {
        let mut t = SeedTree::new(1234);
        t.register_layer("a");
        t.register_layer("b");
        assert_ne!(t.step_seed("a"), t.step_seed("b"));
        // and their step sequences don't collide over many steps
        let mut seen = std::collections::HashSet::new();
        for _ in 0..1000 {
            assert!(seen.insert(t.step_seed("a")));
            assert!(seen.insert(t.step_seed("b")));
            t.advance_step();
        }
    }

    #[test]
    fn registration_is_idempotent_and_order_stable() {
        let mut t1 = SeedTree::new(7);
        let s1 = t1.register_layer("x");
        assert_eq!(t1.register_layer("x"), s1);
        // Same registration order => same seeds in a fresh tree
        let mut t2 = SeedTree::new(7);
        assert_eq!(t2.register_layer("x"), s1);
    }

    #[test]
    fn checkpoint_resume_reproduces_seeds() {
        let mut t = SeedTree::new(99);
        t.register_layer("l");
        for _ in 0..17 {
            t.advance_step();
        }
        let s17 = t.step_seed("l");
        let mut fresh = SeedTree::new(99);
        fresh.register_layer("l");
        fresh.set_step(17);
        assert_eq!(fresh.step_seed("l"), s17);
    }

    #[test]
    fn different_master_seed_changes_everything() {
        let mut a = SeedTree::new(1);
        let mut b = SeedTree::new(2);
        a.register_layer("l");
        b.register_layer("l");
        assert_ne!(a.step_seed("l"), b.step_seed("l"));
    }
}
