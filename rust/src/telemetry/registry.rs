//! Named metric registry — counters, gauges and histograms with JSON and
//! Prometheus-text exposition.
//!
//! The registry itself is a `Mutex<BTreeMap>` locked only on the cold
//! paths (handle registration, snapshot). Hot-path recording goes through
//! cloneable handles that touch nothing but atomics:
//!
//! * [`Counter`] — monotone `u64`, sharded across [`COUNTER_SHARDS`]
//!   cache-line-padded atomics so concurrent serve workers never contend
//!   on one line; each thread picks a shard once by hashing its
//!   `ThreadId`.
//! * [`Gauge`] — a single `f64` stored as atomic bits (`set`/`add`/`get`).
//! * [`Histogram`] — the log-bucketed streaming histogram from
//!   [`crate::telemetry::hist`].
//!
//! Exposition is deterministic: names iterate in `BTreeMap` order and the
//! JSON emitter sorts object keys, so two snapshots of the same state are
//! byte-identical.

use crate::telemetry::hist::Histogram;
use crate::util::json::{num, obj, Json};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Counter shard count; each shard sits on its own cache line.
pub const COUNTER_SHARDS: usize = 16;

#[repr(align(64))]
#[derive(Debug)]
struct Shard(AtomicU64);

/// Monotone counter handle. `add` touches one thread-affine shard; `get`
/// sums all shards (exact once writers quiesce).
#[derive(Clone, Debug)]
pub struct Counter {
    shards: Arc<[Shard; COUNTER_SHARDS]>,
}

fn shard_of_thread() -> usize {
    use std::cell::Cell;
    thread_local! {
        static SHARD: Cell<usize> = const { Cell::new(usize::MAX) };
    }
    SHARD.with(|s| {
        let mut i = s.get();
        if i == usize::MAX {
            use std::hash::{Hash, Hasher};
            let mut h = std::collections::hash_map::DefaultHasher::new();
            std::thread::current().id().hash(&mut h);
            i = (h.finish() as usize) % COUNTER_SHARDS;
            s.set(i);
        }
        i
    })
}

impl Counter {
    pub fn new() -> Counter {
        Counter { shards: Arc::new(std::array::from_fn(|_| Shard(AtomicU64::new(0)))) }
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.shards[shard_of_thread()].0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.shards.iter().map(|s| s.0.load(Ordering::Relaxed)).sum()
    }
}

impl Default for Counter {
    fn default() -> Self {
        Counter::new()
    }
}

/// `f64` gauge handle (value stored as atomic bits).
#[derive(Clone, Debug, Default)]
pub struct Gauge {
    bits: Arc<AtomicU64>, // 0u64 == 0.0f64.to_bits()
}

impl Gauge {
    pub fn new() -> Gauge {
        Gauge::default()
    }

    pub fn set(&self, v: f64) {
        self.bits.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn add(&self, d: f64) {
        let mut cur = self.bits.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + d).to_bits();
            match self.bits.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed)
            {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }
}

#[derive(Clone, Debug)]
enum Metric {
    Counter(Counter),
    Gauge(Gauge),
    Histogram(Histogram),
}

/// Shared, cloneable registry of named metrics. Lock is taken only for
/// registration and snapshots — recording goes through the handles.
#[derive(Clone, Debug, Default)]
pub struct Registry {
    metrics: Arc<Mutex<BTreeMap<String, Metric>>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Get-or-create the counter `name`. Panics if `name` is already
    /// registered as a different metric kind.
    pub fn counter(&self, name: &str) -> Counter {
        let mut m = self.metrics.lock().unwrap();
        match m.entry(name.to_string()).or_insert_with(|| Metric::Counter(Counter::new())) {
            Metric::Counter(c) => c.clone(),
            _ => panic!("metric '{name}' already registered with a different kind"),
        }
    }

    /// Get-or-create the gauge `name`.
    pub fn gauge(&self, name: &str) -> Gauge {
        let mut m = self.metrics.lock().unwrap();
        match m.entry(name.to_string()).or_insert_with(|| Metric::Gauge(Gauge::new())) {
            Metric::Gauge(g) => g.clone(),
            _ => panic!("metric '{name}' already registered with a different kind"),
        }
    }

    /// Get-or-create the histogram `name`.
    pub fn histogram(&self, name: &str) -> Histogram {
        let mut m = self.metrics.lock().unwrap();
        match m.entry(name.to_string()).or_insert_with(|| Metric::Histogram(Histogram::new())) {
            Metric::Histogram(h) => h.clone(),
            _ => panic!("metric '{name}' already registered with a different kind"),
        }
    }

    /// Registered metric names, sorted.
    pub fn names(&self) -> Vec<String> {
        self.metrics.lock().unwrap().keys().cloned().collect()
    }

    /// Deterministic JSON snapshot: counters/gauges as numbers, histograms
    /// as `{count, sum, mean, min, max, p50, p95, p99}` objects.
    pub fn snapshot_json(&self) -> Json {
        let m = self.metrics.lock().unwrap();
        let mut out = BTreeMap::new();
        for (name, metric) in m.iter() {
            let v = match metric {
                Metric::Counter(c) => num(c.get() as f64),
                Metric::Gauge(g) => num(g.get()),
                Metric::Histogram(h) => obj(vec![
                    ("count", num(h.count() as f64)),
                    ("sum", num(h.sum())),
                    ("mean", num(h.mean())),
                    ("min", num(h.min())),
                    ("max", num(h.max())),
                    ("p50", num(h.quantile(0.5))),
                    ("p95", num(h.quantile(0.95))),
                    ("p99", num(h.quantile(0.99))),
                ]),
            };
            out.insert(name.clone(), v);
        }
        Json::Obj(out)
    }

    /// Prometheus text exposition. Counters and gauges expose one sample;
    /// histograms expose summary-style quantiles plus `_sum`/`_count`.
    /// Metric names are sanitized (`.` → `_`) and prefixed `gaussws_`.
    pub fn prometheus_text(&self) -> String {
        fn sanitize(name: &str) -> String {
            let mut s: String = name
                .chars()
                .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
                .collect();
            s.insert_str(0, "gaussws_");
            s
        }
        let m = self.metrics.lock().unwrap();
        let mut out = String::new();
        for (name, metric) in m.iter() {
            let n = sanitize(name);
            match metric {
                Metric::Counter(c) => {
                    out.push_str(&format!("# TYPE {n} counter\n{n} {}\n", c.get()));
                }
                Metric::Gauge(g) => {
                    out.push_str(&format!("# TYPE {n} gauge\n{n} {}\n", g.get()));
                }
                Metric::Histogram(h) => {
                    out.push_str(&format!("# TYPE {n} summary\n"));
                    for (label, q) in [("0.5", 0.5), ("0.95", 0.95), ("0.99", 0.99)] {
                        out.push_str(&format!(
                            "{n}{{quantile=\"{label}\"}} {}\n",
                            h.quantile(q)
                        ));
                    }
                    out.push_str(&format!("{n}_sum {}\n", h.sum()));
                    out.push_str(&format!("{n}_count {}\n", h.count()));
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn handles_are_shared_views() {
        let reg = Registry::new();
        let a = reg.counter("hits");
        let b = reg.counter("hits");
        a.add(3);
        b.inc();
        assert_eq!(reg.counter("hits").get(), 4);
        let g = reg.gauge("level");
        g.set(2.5);
        g.add(-0.5);
        assert_eq!(reg.gauge("level").get(), 2.0);
        let h = reg.histogram("lat");
        h.record(0.25);
        assert_eq!(reg.histogram("lat").count(), 1);
        assert_eq!(reg.names(), vec!["hits".to_string(), "lat".into(), "level".into()]);
    }

    #[test]
    #[should_panic(expected = "different kind")]
    fn kind_mismatch_panics() {
        let reg = Registry::new();
        reg.counter("x");
        reg.gauge("x");
    }

    #[test]
    fn sharded_counter_is_exact_under_contention() {
        let reg = Registry::new();
        let c = reg.counter("n");
        std::thread::scope(|sc| {
            for _ in 0..8 {
                let c = c.clone();
                sc.spawn(move || {
                    for _ in 0..10_000 {
                        c.inc();
                    }
                });
            }
        });
        assert_eq!(c.get(), 80_000);
    }

    #[test]
    fn snapshot_json_is_deterministic_and_parseable() {
        let reg = Registry::new();
        reg.counter("b.count").add(7);
        reg.gauge("a.level").set(1.5);
        let h = reg.histogram("c.lat");
        h.record(0.01);
        h.record(0.03);
        let one = reg.snapshot_json().to_string();
        let two = reg.snapshot_json().to_string();
        assert_eq!(one, two, "same state must snapshot byte-identically");
        let parsed = Json::parse(&one).unwrap();
        assert_eq!(parsed.get("b.count").as_f64(), Some(7.0));
        assert_eq!(parsed.get("a.level").as_f64(), Some(1.5));
        assert_eq!(parsed.get("c.lat").get("count").as_f64(), Some(2.0));
        assert_eq!(parsed.get("c.lat").get("sum").as_f64(), Some(0.04));
    }

    #[test]
    fn prometheus_text_shape() {
        let reg = Registry::new();
        reg.counter("serve.requests_completed").add(6);
        reg.gauge("serve.kv_blocks_live").set(0.0);
        reg.histogram("serve.latency_total_s").record(0.02);
        let text = reg.prometheus_text();
        assert!(text.contains("# TYPE gaussws_serve_requests_completed counter"));
        assert!(text.contains("gaussws_serve_requests_completed 6"));
        assert!(text.contains("# TYPE gaussws_serve_kv_blocks_live gauge"));
        assert!(text.contains("# TYPE gaussws_serve_latency_total_s summary"));
        assert!(text.contains("gaussws_serve_latency_total_s{quantile=\"0.95\"}"));
        assert!(text.contains("gaussws_serve_latency_total_s_count 1"));
        assert_eq!(reg.prometheus_text(), text, "exposition must be deterministic");
    }
}
