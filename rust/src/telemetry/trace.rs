//! Per-request trace timelines in Chrome trace-event form.
//!
//! The serve engine records one [`TraceEvent`] per lifecycle edge of a
//! request — enqueue, admission (prefix hit/miss, blocks reserved), each
//! prefill chunk, each decode wave, preemption (blocks released),
//! re-admission and retirement — plus counter events for live KV blocks.
//! Events use the request id as `tid`, so every request renders as its
//! own track.
//!
//! Export is JSONL: one trace-event object per line, each parseable by
//! [`crate::util::json`]. `ui.perfetto.dev` opens the file directly;
//! `chrome://tracing` wants a JSON array — wrap the lines in `[...]` with
//! commas (see README "Observability").
//!
//! [`check_well_nested`] is the structural invariant used by the serving
//! fuzz harness: per `tid`, `B`/`E` events must form a proper bracket
//! sequence (a request span wrapping one or more residency episodes).

use crate::util::json::{num, s, Json};
use std::time::Instant;

/// Chrome trace-event phase.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// `B` — span begin.
    Begin,
    /// `E` — span end.
    End,
    /// `X` — complete span with explicit duration.
    Complete,
    /// `i` — instant event.
    Instant,
    /// `C` — counter sample.
    Counter,
}

impl Phase {
    /// The single-character `ph` code used by the trace-event format.
    pub fn code(self) -> &'static str {
        match self {
            Phase::Begin => "B",
            Phase::End => "E",
            Phase::Complete => "X",
            Phase::Instant => "i",
            Phase::Counter => "C",
        }
    }
}

/// One timeline event. Timestamps are microseconds since the owning
/// buffer's origin.
#[derive(Debug, Clone)]
pub struct TraceEvent {
    pub name: &'static str,
    pub ph: Phase,
    pub ts_us: u64,
    /// Duration — meaningful for [`Phase::Complete`] events only.
    pub dur_us: u64,
    /// Track id: the request id for per-request spans, 0 for globals.
    pub tid: u64,
    pub args: Vec<(&'static str, Json)>,
}

/// An in-memory, append-only event timeline with a fixed time origin.
/// Recording is single-writer by construction (the engine's coordinator
/// thread); worker threads never touch it.
#[derive(Debug, Clone)]
pub struct TraceBuffer {
    origin: Instant,
    events: Vec<TraceEvent>,
}

impl Default for TraceBuffer {
    fn default() -> Self {
        TraceBuffer::new()
    }
}

impl TraceBuffer {
    pub fn new() -> TraceBuffer {
        TraceBuffer { origin: Instant::now(), events: Vec::new() }
    }

    /// Microseconds elapsed since the buffer was created.
    pub fn now_us(&self) -> u64 {
        self.origin.elapsed().as_micros() as u64
    }

    pub fn events(&self) -> &[TraceEvent] {
        &self.events
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Open a span on track `tid`.
    pub fn begin(&mut self, name: &'static str, tid: u64, args: Vec<(&'static str, Json)>) {
        let ts_us = self.now_us();
        self.events.push(TraceEvent { name, ph: Phase::Begin, ts_us, dur_us: 0, tid, args });
    }

    /// Close the innermost open span named `name` on track `tid`.
    pub fn end(&mut self, name: &'static str, tid: u64, args: Vec<(&'static str, Json)>) {
        let ts_us = self.now_us();
        self.events.push(TraceEvent { name, ph: Phase::End, ts_us, dur_us: 0, tid, args });
    }

    /// Zero-duration marker on track `tid`.
    pub fn instant(&mut self, name: &'static str, tid: u64, args: Vec<(&'static str, Json)>) {
        let ts_us = self.now_us();
        self.events.push(TraceEvent { name, ph: Phase::Instant, ts_us, dur_us: 0, tid, args });
    }

    /// Complete span with an explicit start and duration (used for wave
    /// work recorded after the fact).
    pub fn complete(
        &mut self,
        name: &'static str,
        tid: u64,
        ts_us: u64,
        dur_us: u64,
        args: Vec<(&'static str, Json)>,
    ) {
        self.events.push(TraceEvent { name, ph: Phase::Complete, ts_us, dur_us, tid, args });
    }

    /// Counter sample on the global track (renders as a stacked area).
    pub fn counter(&mut self, name: &'static str, value: f64) {
        let ts_us = self.now_us();
        self.events.push(TraceEvent {
            name,
            ph: Phase::Counter,
            ts_us,
            dur_us: 0,
            tid: 0,
            args: vec![("value", num(value))],
        });
    }

    /// One event as a trace-event JSON object.
    pub fn event_json(e: &TraceEvent) -> Json {
        let mut pairs = vec![
            ("name", s(e.name)),
            ("ph", s(e.ph.code())),
            ("ts", num(e.ts_us as f64)),
            ("pid", num(1.0)),
            ("tid", num(e.tid as f64)),
        ];
        if e.ph == Phase::Complete {
            pairs.push(("dur", num(e.dur_us as f64)));
        }
        let args: std::collections::BTreeMap<String, Json> =
            e.args.iter().map(|(k, v)| (k.to_string(), v.clone())).collect();
        pairs.push(("args", Json::Obj(args)));
        crate::util::json::obj(pairs)
    }

    /// The whole timeline as JSONL — one trace-event object per line.
    pub fn to_json_lines(&self) -> String {
        let mut out = String::new();
        for e in &self.events {
            out.push_str(&Self::event_json(e).to_string());
            out.push('\n');
        }
        out
    }

    /// Write the timeline as a `.jsonl` file.
    pub fn write_jsonl(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_json_lines())
    }
}

/// Structural invariant over a timeline: per track (`tid`), `B`/`E`
/// events must bracket properly — every `E` closes the matching innermost
/// `B`, and no span stays open at the end. `X`/`i`/`C` events are
/// nesting-neutral.
pub fn check_well_nested(events: &[TraceEvent]) -> Result<(), String> {
    use std::collections::BTreeMap;
    let mut stacks: BTreeMap<u64, Vec<&'static str>> = BTreeMap::new();
    for e in events {
        match e.ph {
            Phase::Begin => stacks.entry(e.tid).or_default().push(e.name),
            Phase::End => match stacks.entry(e.tid).or_default().pop() {
                Some(open) if open == e.name => {}
                Some(open) => {
                    return Err(format!(
                        "tid {}: E '{}' closes open span '{}'",
                        e.tid, e.name, open
                    ))
                }
                None => return Err(format!("tid {}: E '{}' without a matching B", e.tid, e.name)),
            },
            _ => {}
        }
    }
    for (tid, stack) in &stacks {
        if let Some(open) = stack.last() {
            return Err(format!("tid {tid}: span '{open}' never closed"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jsonl_lines_parse_with_trace_event_schema() {
        let mut t = TraceBuffer::new();
        t.begin("request", 7, vec![("prompt_len", num(12.0))]);
        t.begin("resident", 7, vec![("prefix", s("miss"))]);
        t.complete("prefill", 7, 0, 120, vec![("positions", num(8.0))]);
        t.instant("preempt", 7, vec![]);
        t.counter("kv_blocks_live", 3.0);
        t.end("resident", 7, vec![]);
        t.end("request", 7, vec![("gen_tokens", num(4.0))]);
        let lines: Vec<&str> = t.to_json_lines().lines().collect();
        assert_eq!(lines.len(), 7);
        for line in &lines {
            let v = Json::parse(line).expect("every line must be standalone JSON");
            assert!(v.get("name").as_str().is_some());
            assert!(matches!(v.get("ph").as_str(), Some("B" | "E" | "X" | "i" | "C")));
            assert!(v.get("ts").as_f64().is_some());
            assert!(v.get("tid").as_f64().is_some());
            assert!(v.get("args").as_obj().is_some());
        }
        // the complete event carries its duration
        let x = Json::parse(lines[2]).unwrap();
        assert_eq!(x.get("ph").as_str(), Some("X"));
        assert_eq!(x.get("dur").as_f64(), Some(120.0));
        // counter events carry their value in args
        let c = Json::parse(lines[4]).unwrap();
        assert_eq!(c.get("args").get("value").as_f64(), Some(3.0));
    }

    #[test]
    fn well_nested_accepts_request_with_two_residencies() {
        let mut t = TraceBuffer::new();
        t.begin("request", 1, vec![]);
        t.begin("resident", 1, vec![]);
        t.end("resident", 1, vec![]); // preempted
        t.begin("resident", 1, vec![]); // re-admitted
        t.end("resident", 1, vec![]);
        t.end("request", 1, vec![]);
        t.begin("request", 2, vec![]);
        t.end("request", 2, vec![]);
        assert!(check_well_nested(t.events()).is_ok());
    }

    #[test]
    fn well_nested_rejects_bad_brackets() {
        let mut open = TraceBuffer::new();
        open.begin("request", 1, vec![]);
        assert!(check_well_nested(open.events()).unwrap_err().contains("never closed"));

        let mut cross = TraceBuffer::new();
        cross.begin("request", 1, vec![]);
        cross.begin("resident", 1, vec![]);
        cross.end("request", 1, vec![]);
        assert!(check_well_nested(cross.events()).unwrap_err().contains("closes open span"));

        let mut orphan = TraceBuffer::new();
        orphan.end("resident", 3, vec![]);
        assert!(check_well_nested(orphan.events()).unwrap_err().contains("without a matching B"));
    }

    #[test]
    fn timestamps_are_monotone() {
        let mut t = TraceBuffer::new();
        t.begin("a", 1, vec![]);
        t.instant("b", 1, vec![]);
        t.end("a", 1, vec![]);
        let ts: Vec<u64> = t.events().iter().map(|e| e.ts_us).collect();
        assert!(ts.windows(2).all(|w| w[0] <= w[1]));
    }
}
