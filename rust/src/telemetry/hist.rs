//! Log-bucketed streaming histogram — the latency/drift primitive behind
//! the [`crate::telemetry::Registry`].
//!
//! Buckets are geometric: [`BUCKETS_PER_OCTAVE`] sub-buckets per factor of
//! two, anchored at [`MIN_TRACKED`]. With 4 sub-buckets per octave every
//! bucket spans a ratio of 2^(1/4) ≈ 1.19, so any quantile estimate is
//! within ~19% (one bucket width) of the exact sample — tight enough for
//! p50/p95/p99 latency and drift reporting while recording stays O(1),
//! allocation-free and lock-free (relaxed atomics only).
//!
//! [`Histogram::quantile`] implements the *nearest-rank* estimator: the
//! returned value lands in the same bucket as the exact nearest-rank
//! sample, and is clamped to the observed `[min, max]` range (so a
//! single-sample histogram reports that sample exactly at every
//! quantile). Values at or below [`MIN_TRACKED`] — including zero and
//! negatives — share the catch-all bucket 0, which is therefore the one
//! bucket with no width bound.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Total bucket count. 256 buckets at 4/octave span 64 octaves:
/// [`MIN_TRACKED`] (1e-9) up to ~1.8e10 — nanoseconds to hours when the
/// recorded unit is seconds.
pub const N_BUCKETS: usize = 256;

/// Sub-buckets per factor of two (bucket ratio = 2^(1/4) ≈ 1.19).
pub const BUCKETS_PER_OCTAVE: f64 = 4.0;

/// Lower edge of the geometric grid. Values `<= MIN_TRACKED` (including
/// zero and negatives) clamp into bucket 0.
pub const MIN_TRACKED: f64 = 1e-9;

/// Bucket index for a value (clamped into `[0, N_BUCKETS)`).
pub fn bucket_index(v: f64) -> usize {
    if !(v > MIN_TRACKED) {
        return 0; // catch-all: zero, negatives, NaN already filtered
    }
    let i = ((v / MIN_TRACKED).log2() * BUCKETS_PER_OCTAVE) as usize;
    i.min(N_BUCKETS - 1)
}

/// `[lo, hi)` value bounds of bucket `i`.
pub fn bucket_bounds(i: usize) -> (f64, f64) {
    let lo = MIN_TRACKED * (i as f64 / BUCKETS_PER_OCTAVE).exp2();
    let hi = MIN_TRACKED * ((i + 1) as f64 / BUCKETS_PER_OCTAVE).exp2();
    (lo, hi)
}

/// Width of the bucket `v` falls in — the quantile error bound at `v`.
pub fn bucket_width(v: f64) -> f64 {
    let (lo, hi) = bucket_bounds(bucket_index(v));
    hi - lo
}

struct Core {
    counts: Vec<AtomicU64>, // N_BUCKETS entries
    count: AtomicU64,
    sum_bits: AtomicU64,
    min_bits: AtomicU64,
    max_bits: AtomicU64,
}

/// A cloneable handle to a shared streaming histogram. Clones record into
/// the same buckets; reads are exact once writers quiesce.
#[derive(Clone)]
pub struct Histogram {
    core: Arc<Core>,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

impl std::fmt::Debug for Histogram {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Histogram")
            .field("count", &self.count())
            .field("min", &self.min())
            .field("max", &self.max())
            .finish()
    }
}

fn atomic_f64_update(bits: &AtomicU64, f: impl Fn(f64) -> f64) {
    let mut cur = bits.load(Ordering::Relaxed);
    loop {
        let next = f(f64::from_bits(cur)).to_bits();
        match bits.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(seen) => cur = seen,
        }
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            core: Arc::new(Core {
                counts: (0..N_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
                count: AtomicU64::new(0),
                sum_bits: AtomicU64::new(0f64.to_bits()),
                min_bits: AtomicU64::new(f64::INFINITY.to_bits()),
                max_bits: AtomicU64::new(f64::NEG_INFINITY.to_bits()),
            }),
        }
    }

    /// Record one sample. NaN is dropped.
    pub fn record(&self, v: f64) {
        if v.is_nan() {
            return;
        }
        let c = &self.core;
        c.counts[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        c.count.fetch_add(1, Ordering::Relaxed);
        atomic_f64_update(&c.sum_bits, |s| s + v);
        atomic_f64_update(&c.min_bits, |m| m.min(v));
        atomic_f64_update(&c.max_bits, |m| m.max(v));
    }

    pub fn count(&self) -> u64 {
        self.core.count.load(Ordering::Relaxed)
    }

    pub fn is_empty(&self) -> bool {
        self.count() == 0
    }

    /// Exact sum of recorded samples.
    pub fn sum(&self) -> f64 {
        f64::from_bits(self.core.sum_bits.load(Ordering::Relaxed))
    }

    /// Exact mean (0 when empty).
    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum() / n as f64
        }
    }

    /// Exact minimum recorded sample (0 when empty).
    pub fn min(&self) -> f64 {
        if self.is_empty() {
            0.0
        } else {
            f64::from_bits(self.core.min_bits.load(Ordering::Relaxed))
        }
    }

    /// Exact maximum recorded sample (0 when empty).
    pub fn max(&self) -> f64 {
        if self.is_empty() {
            0.0
        } else {
            f64::from_bits(self.core.max_bits.load(Ordering::Relaxed))
        }
    }

    /// Nearest-rank quantile estimate for `q` in `[0, 1]` — the geometric
    /// midpoint of the bucket holding the rank-`ceil(q·n)` sample, clamped
    /// to the observed `[min, max]`. Within one bucket width of the exact
    /// nearest-rank percentile (see module docs). 0 when empty.
    pub fn quantile(&self, q: f64) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        let q = q.clamp(0.0, 1.0);
        let rank = ((q * n as f64).ceil() as u64).clamp(1, n);
        let mut seen = 0u64;
        for (i, c) in self.core.counts.iter().enumerate() {
            seen += c.load(Ordering::Relaxed);
            if seen >= rank {
                let (lo, hi) = bucket_bounds(i);
                return (lo * hi).sqrt().clamp(self.min(), self.max());
            }
        }
        self.max()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Exact nearest-rank percentile: the rank-`ceil(q·n)` order statistic.
    fn exact_nearest_rank(xs: &[f64], q: f64) -> f64 {
        let mut v = xs.to_vec();
        v.sort_by(|a, b| a.total_cmp(b));
        let rank = ((q * v.len() as f64).ceil() as usize).clamp(1, v.len());
        v[rank - 1]
    }

    #[test]
    fn bucket_grid_is_monotone_and_covering() {
        assert_eq!(bucket_index(0.0), 0);
        assert_eq!(bucket_index(-3.0), 0);
        assert_eq!(bucket_index(MIN_TRACKED), 0);
        assert_eq!(bucket_index(f64::MAX), N_BUCKETS - 1);
        let mut last = 0;
        for k in 0..60 {
            let v = 1e-8 * 1.5f64.powi(k);
            let i = bucket_index(v);
            assert!(i >= last, "bucket index must be monotone in v");
            last = i;
            let (lo, hi) = bucket_bounds(i);
            assert!(lo <= v && v < hi * (1.0 + 1e-12), "{v} outside [{lo},{hi})");
        }
    }

    #[test]
    fn empty_histogram_is_all_zeros() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.max(), 0.0);
        assert_eq!(h.quantile(0.5), 0.0);
    }

    #[test]
    fn single_sample_is_exact_at_every_quantile() {
        let h = Histogram::new();
        h.record(0.0123);
        for q in [0.0, 0.25, 0.5, 0.95, 1.0] {
            assert_eq!(h.quantile(q), 0.0123, "q={q}");
        }
        assert_eq!(h.min(), 0.0123);
        assert_eq!(h.max(), 0.0123);
        assert!((h.mean() - 0.0123).abs() < 1e-15);
    }

    #[test]
    fn quantiles_are_monotone_and_range_clamped() {
        let h = Histogram::new();
        for k in 1..=100 {
            h.record(k as f64 * 0.001);
        }
        let mut last = 0.0;
        for q in [0.0, 0.1, 0.5, 0.9, 0.95, 0.99, 1.0] {
            let v = h.quantile(q);
            assert!(v >= last, "quantiles must be monotone");
            assert!(v >= h.min() && v <= h.max());
            last = v;
        }
    }

    #[test]
    fn quantile_matches_exact_within_one_bucket() {
        // deterministic pseudo-random samples spanning several octaves
        let xs: Vec<f64> =
            (0..500).map(|k| 1e-4 * (1.0 + ((k * 2654435761u64 as usize) % 9973) as f64)).collect();
        let h = Histogram::new();
        for &x in &xs {
            h.record(x);
        }
        for q in [0.5, 0.95, 0.99] {
            let exact = exact_nearest_rank(&xs, q);
            let est = h.quantile(q);
            assert!(
                (est - exact).abs() <= bucket_width(exact),
                "q={q}: est {est} vs exact {exact} (width {})",
                bucket_width(exact)
            );
        }
    }

    #[test]
    fn mean_sum_min_max_are_exact() {
        let h = Histogram::new();
        for v in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            h.record(v);
        }
        assert_eq!(h.count(), 8);
        assert_eq!(h.sum(), 40.0);
        assert_eq!(h.mean(), 5.0);
        assert_eq!(h.min(), 2.0);
        assert_eq!(h.max(), 9.0);
    }

    #[test]
    fn nan_is_dropped_zero_is_kept() {
        let h = Histogram::new();
        h.record(f64::NAN);
        assert_eq!(h.count(), 0);
        h.record(0.0);
        assert_eq!(h.count(), 1);
        assert_eq!(h.quantile(0.5), 0.0, "all-zero histogram clamps to 0");
    }

    #[test]
    fn concurrent_recording_totals_are_exact() {
        let h = Histogram::new();
        std::thread::scope(|sc| {
            for t in 0..4 {
                let h = h.clone();
                sc.spawn(move || {
                    for k in 0..10_000 {
                        h.record(1.0 + ((t * 10_000 + k) % 7) as f64);
                    }
                });
            }
        });
        assert_eq!(h.count(), 40_000);
        // every sample is a small integer: the f64 CAS-add sum is exact
        let expect: f64 = (0..40_000).map(|i| 1.0 + (i % 7) as f64).sum();
        assert_eq!(h.sum(), expect);
    }
}
