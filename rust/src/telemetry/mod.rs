//! Observability substrate shared by L3 training and L4 serving.
//!
//! Three pieces, all std-only and lock-light:
//!
//! * [`registry`] — a [`Registry`] of named metrics behind cloneable
//!   handles: sharded atomic [`Counter`]s (per-thread shard selection, so
//!   serve workers never contend on a cache line), bit-cast `f64`
//!   [`Gauge`]s, and log-bucketed streaming [`Histogram`]s. Snapshots
//!   expose deterministically as JSON ([`Registry::snapshot_json`]) or
//!   Prometheus text ([`Registry::prometheus_text`]).
//! * [`hist`] — the histogram core: geometric buckets (4 per octave,
//!   ~19% width), exact count/sum/min/max, nearest-rank quantiles that
//!   land within one bucket width of the exact percentile.
//! * [`trace`] — per-request [`TraceBuffer`] timelines in Chrome
//!   trace-event form (enqueue → admit → prefill/decode waves →
//!   preempt/re-admit → retire, with block reserve/release deltas),
//!   exported as JSONL via `gaussws serve --trace-out <path>`.
//!
//! `serve::ServeStats` and `coordinator::metrics::RunLog` are *views over*
//! a registry — their counters and latency percentiles read straight from
//! these primitives, so `--metrics-every` snapshots, bench JSON, and
//! exposition all agree by construction. The paper budgets 1.40% for PQT
//! overhead; `bench_serve`'s telemetry-on/off arm holds this layer to the
//! same standard (< 2% tokens/sec).

pub mod hist;
pub mod registry;
pub mod trace;

pub use hist::Histogram;
pub use registry::{Counter, Gauge, Registry, COUNTER_SHARDS};
pub use trace::{check_well_nested, Phase, TraceBuffer, TraceEvent};
