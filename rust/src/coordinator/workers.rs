//! Simulated data-parallel workers + gradient all-reduce.
//!
//! The paper trains on 8 GPUs with DDP: every rank holds a full parameter
//! replica, consumes a disjoint data shard, and gradients are all-reduced
//! before the optimizer step. Weight-sampling noise R must be *identical*
//! across ranks (same seed), otherwise each replica trains a different ŵ —
//! the coordinator enforces that by broadcasting the step seed.
//!
//! On the 1-core CPU testbed the rank executions are sequential, but the
//! reduction topology is real: a binary-tree all-reduce whose communication
//! volume matches what a ring/tree implementation would move, which the
//! overhead model in `bench_overhead` accounts for.

/// Accumulate `src` into `dst` element-wise.
fn add_into(dst: &mut [f32], src: &[f32]) {
    assert_eq!(dst.len(), src.len());
    for (d, s) in dst.iter_mut().zip(src.iter()) {
        *d += s;
    }
}

/// Tree all-reduce (sum) over per-worker gradient sets, in place into
/// worker 0's buffers; returns the number of pairwise block transfers
/// performed (the communication-volume proxy).
///
/// `grads[w][t]` is tensor `t` of worker `w`. All workers must have
/// identical tensor shapes.
pub fn tree_all_reduce_sum(grads: &mut [Vec<Vec<f32>>]) -> usize {
    let n = grads.len();
    assert!(n > 0);
    let mut transfers = 0;
    let mut stride = 1;
    while stride < n {
        let mut i = 0;
        while i + stride < n {
            // split_at_mut to take two disjoint workers
            let (lo, hi) = grads.split_at_mut(i + stride);
            let dst = &mut lo[i];
            let src = &hi[0];
            for (d, s) in dst.iter_mut().zip(src.iter()) {
                add_into(d, s);
                transfers += 1;
            }
            i += stride * 2;
        }
        stride *= 2;
    }
    transfers
}

/// Average worker-0 buffers by the worker count after a sum-reduce.
pub fn scale_grads(grads: &mut [Vec<f32>], factor: f32) {
    for g in grads.iter_mut() {
        for v in g.iter_mut() {
            *v *= factor;
        }
    }
}

/// Global L2 norm over a gradient set.
pub fn global_norm(grads: &[Vec<f32>]) -> f64 {
    grads
        .iter()
        .flat_map(|g| g.iter())
        .map(|&v| (v as f64) * (v as f64))
        .sum::<f64>()
        .sqrt()
}

/// Clip a gradient set to `max_norm` (no-op if already within). Returns the
/// pre-clip norm.
pub fn clip_global_norm(grads: &mut [Vec<f32>], max_norm: f64) -> f64 {
    let norm = global_norm(grads);
    if norm > max_norm && norm > 0.0 {
        let scale = (max_norm / norm) as f32;
        scale_grads(grads, scale);
    }
    norm
}

#[cfg(test)]
mod tests {
    use super::*;

    fn worker_grads(n_workers: usize, val: f32) -> Vec<Vec<Vec<f32>>> {
        (0..n_workers).map(|w| vec![vec![val * (w + 1) as f32; 4], vec![val; 2]]).collect()
    }

    #[test]
    fn all_reduce_sums_across_workers() {
        for n in [1usize, 2, 3, 4, 5, 8] {
            let mut g = worker_grads(n, 1.0);
            tree_all_reduce_sum(&mut g);
            // tensor 0: sum over w of (w+1) = n(n+1)/2
            let expect = (n * (n + 1) / 2) as f32;
            assert_eq!(g[0][0], vec![expect; 4], "n={n}");
            assert_eq!(g[0][1], vec![n as f32; 2], "n={n}");
        }
    }

    #[test]
    fn averaging_after_reduce() {
        let mut g = worker_grads(4, 2.0);
        tree_all_reduce_sum(&mut g);
        scale_grads(&mut g[0].clone(), 0.25); // smoke: no panic
        let mut w0 = g.swap_remove(0);
        scale_grads(&mut w0, 1.0 / 4.0);
        assert_eq!(w0[1], vec![2.0; 2]);
    }

    #[test]
    fn clip_reduces_norm() {
        let mut g = vec![vec![3.0f32, 4.0]]; // norm 5
        let pre = clip_global_norm(&mut g, 1.0);
        assert!((pre - 5.0).abs() < 1e-9);
        assert!((global_norm(&g) - 1.0).abs() < 1e-5);
        // under the bound: untouched
        let mut g2 = vec![vec![0.3f32, 0.4]];
        clip_global_norm(&mut g2, 1.0);
        assert_eq!(g2[0], vec![0.3, 0.4]);
    }

    #[test]
    fn transfer_count_is_log_tree() {
        // n workers, t tensors: (n-1) pair merges × t tensor transfers
        let mut g = worker_grads(8, 1.0);
        let transfers = tree_all_reduce_sum(&mut g);
        assert_eq!(transfers, 7 * 2);
    }
}
