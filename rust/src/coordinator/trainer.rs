//! The training coordinator: owns parameters, optimizer state, the seed
//! tree, the sharded data loaders and the metrics log; drives the AOT
//! train-step artifact through the PJRT runtime.
//!
//! Division of labour (deliberate, see DESIGN.md):
//! * the **HLO artifact** computes `(loss, ∂L/∂params, ∂L/∂b_i)` for one
//!   micro-batch — model math, Pallas noise kernel and Eq. 4 inside;
//! * **rust** owns everything stateful: AdamW/Adam-mini, LR schedule,
//!   decoupled weight decay (including the b_i decay that anneals b_t
//!   toward b_target), gradient clipping, the data-parallel all-reduce,
//!   seed management, divergence detection and checkpointing.

use super::checkpoint::Checkpoint;
use super::metrics::{RunLog, StepRow};
use super::workers::{clip_global_norm, scale_grads, tree_all_reduce_sum};
use crate::config::schema::{Optimizer, TrainConfig};
use crate::data::{Loader, SynthCorpus, SynthSpec};
use crate::nn::optim::{AdamMini, AdamW, LrSchedule, Opt};
use crate::prng::{Philox4x32, SeedTree};
use crate::runtime::{HostTensor, Runtime};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::time::Instant;

/// Trainer over one train artifact.
pub struct Trainer {
    pub runtime: Runtime,
    pub artifact: String,
    pub cfg: TrainConfig,
    pub params: BTreeMap<String, Vec<f32>>,
    pub bi: BTreeMap<String, Vec<f32>>,
    param_shapes: BTreeMap<String, Vec<usize>>,
    bi_shapes: BTreeMap<String, Vec<usize>>,
    opt_params: Opt,
    opt_bi: Opt,
    schedule: LrSchedule,
    seeds: SeedTree,
    loaders: Vec<Loader>,
    pub log: RunLog,
    pub step: usize,
    /// Artifact meta: b_init/b_target for bt reconstruction (Fig. 5).
    pub b_init: f64,
    pub b_target: f64,
    /// Weight decay applied to b_i (paper: guides b_t to b_target).
    pub bi_weight_decay: f64,
}

impl Trainer {
    /// Build a trainer for `artifact` (name without the `.train` suffix or
    /// with it — normalized here), e.g. "tiny_gpt2.gaussws_all".
    pub fn new(
        runtime: Runtime,
        artifact: &str,
        cfg: TrainConfig,
        run_name: &str,
    ) -> Result<Trainer> {
        let artifact = if artifact.ends_with(".train") {
            artifact.to_string()
        } else {
            format!("{artifact}.train")
        };
        let spec = runtime.manifest.get(&artifact)?.clone();
        if spec.kind != "train" {
            bail!("artifact '{artifact}' is kind '{}', not train", spec.kind);
        }
        let vocab = spec.meta_usize("vocab").context("meta.vocab")?;
        let batch = spec.meta_usize("batch").context("meta.batch")?;
        let seq_len = spec.meta_usize("seq_len").context("meta.seq_len")?;
        let b_init = spec.meta.get("b_init").as_f64().unwrap_or(6.0);
        let b_target = spec.meta.get("b_target").as_f64().unwrap_or(4.0);

        // ---- parameter init (rust-side; python only defines shapes) ----
        let mut params = BTreeMap::new();
        let mut param_shapes = BTreeMap::new();
        let n_layer = spec.meta_usize("n_layer").unwrap_or(2);
        let resid_std = 0.02 / (2.0 * n_layer as f32).sqrt();
        let mut rng = Philox4x32::new(cfg.seed ^ 0x9E37_79B9);
        for name in spec.param_names() {
            let shape = spec.param_shape(&name).context("param shape")?;
            let numel: usize = shape.iter().product();
            let data = if name.ends_with(".g") || name == "lnf.g" {
                vec![1.0; numel]
            } else if name.ends_with(".b") {
                vec![0.0; numel]
            } else {
                let std = if name.ends_with(".out") || name.ends_with(".down") {
                    resid_std
                } else if name == "pos_embed" {
                    0.01
                } else {
                    0.02
                };
                let mut v = vec![0f32; numel];
                let mut i = 0;
                while i < numel {
                    let (a, b) = crate::prng::gauss::box_muller_pair(&mut rng);
                    v[i] = a as f32 * std;
                    if i + 1 < numel {
                        v[i + 1] = b as f32 * std;
                    }
                    i += 2;
                }
                v
            };
            params.insert(name.clone(), data);
            param_shapes.insert(name, shape);
        }
        let mut bi = BTreeMap::new();
        let mut bi_shapes = BTreeMap::new();
        for name in spec.bi_names() {
            let shape = spec.bi_shape(&name).context("bi shape")?;
            let numel: usize = shape.iter().product();
            bi.insert(name.clone(), vec![1.0; numel]); // b_i init = 1 (§3.6)
            bi_shapes.insert(name, shape);
        }

        // ---- optimizers ----
        let p_sizes: Vec<usize> = params.values().map(|v| v.len()).collect();
        let b_sizes: Vec<usize> = bi.values().map(|v| v.len()).collect();
        let mk = |sizes: &[usize], wd: f64| -> Opt {
            match cfg.optimizer {
                Optimizer::AdamW => {
                    Opt::AdamW(AdamW::new(sizes, cfg.max_lr, cfg.beta1, cfg.beta2, cfg.eps, wd))
                }
                Optimizer::AdamMini => Opt::AdamMini(AdamMini::new(
                    sizes, 64, cfg.max_lr, cfg.beta1, cfg.beta2, cfg.eps, wd,
                )),
            }
        };
        let opt_params = mk(&p_sizes, cfg.weight_decay);
        let opt_bi = mk(&b_sizes, 0.0); // b_i decay applied manually (decoupled)

        // ---- data ----
        let corpus = SynthCorpus::generate(SynthSpec {
            vocab,
            len: 1 << 21,
            seed: cfg.seed ^ 0xC0FFEE,
            ..Default::default()
        });
        let loaders: Vec<Loader> = (0..cfg.workers)
            .map(|w| {
                Loader::new(corpus.clone(), batch, seq_len, cfg.seed ^ 0xDA7A)
                    .sharded(w, cfg.workers)
            })
            .collect();

        // ---- seeds ----
        let mut seeds = SeedTree::new(cfg.seed);
        seeds.register_layer("noise");

        let schedule =
            LrSchedule::linear(cfg.max_lr, cfg.min_lr, cfg.warmup_steps, cfg.steps);
        Ok(Trainer {
            runtime,
            artifact,
            params,
            bi,
            param_shapes,
            bi_shapes,
            opt_params,
            opt_bi,
            schedule,
            seeds,
            loaders,
            log: RunLog::new(run_name),
            step: 0,
            b_init,
            b_target,
            bi_weight_decay: 0.1,
            cfg,
        })
    }

    /// Tokens per optimizer step across all workers.
    pub fn tokens_per_step(&self) -> usize {
        self.loaders.iter().map(|l| l.tokens_per_batch()).sum::<usize>() * self.cfg.grad_accum
    }

    fn input_tensors(&self, x: Vec<i32>, y: Vec<i32>, seed: i32) -> Vec<HostTensor> {
        let mut inputs = Vec::with_capacity(self.params.len() + self.bi.len() + 3);
        for v in self.params.values() {
            inputs.push(HostTensor::F32(v.clone()));
        }
        for v in self.bi.values() {
            inputs.push(HostTensor::F32(v.clone()));
        }
        inputs.push(HostTensor::S32(x));
        inputs.push(HostTensor::S32(y));
        inputs.push(HostTensor::S32(vec![seed]));
        inputs
    }

    /// Execute one full optimizer step (all workers, grad-accum, reduce,
    /// clip, update, seed advance). Returns the mean loss.
    pub fn train_step(&mut self) -> Result<f64> {
        let t0 = Instant::now();
        let lr = self.schedule.at(self.step);
        // one noise seed per step, SHARED across workers (DDP requires the
        // same ŵ on every replica; §3.6)
        let seed = (self.seeds.step_seed("noise") & 0x7FFF_FFFF) as i32;

        let n_out = self.params.len() + self.bi.len(); // grads per worker
        let mut worker_grads: Vec<Vec<Vec<f32>>> = Vec::with_capacity(self.loaders.len());
        let mut loss_sum = 0f64;
        let mut n_micro = 0usize;
        for w in 0..self.loaders.len() {
            let mut accum: Option<Vec<Vec<f32>>> = None;
            for micro in 0..self.cfg.grad_accum {
                let b = self.loaders[w]
                    .batch_at((self.step * self.cfg.grad_accum + micro) as u64);
                let x: Vec<i32> = b.x.iter().map(|&t| t as i32).collect();
                let y: Vec<i32> = b.y.iter().map(|&t| t as i32).collect();
                let inputs = self.input_tensors(x, y, seed);
                let outputs = self.runtime.execute(&self.artifact, &inputs)?;
                if outputs.len() != n_out + 1 {
                    bail!("expected {} outputs, got {}", n_out + 1, outputs.len());
                }
                loss_sum += outputs[0].scalar_f32()? as f64;
                n_micro += 1;
                let grads: Vec<Vec<f32>> = outputs[1..]
                    .iter()
                    .map(|t| t.as_f32().map(|s| s.to_vec()))
                    .collect::<Result<_>>()?;
                match &mut accum {
                    None => accum = Some(grads),
                    Some(a) => {
                        for (dst, src) in a.iter_mut().zip(grads.iter()) {
                            for (d, s) in dst.iter_mut().zip(src.iter()) {
                                *d += s;
                            }
                        }
                    }
                }
            }
            worker_grads.push(accum.unwrap());
        }

        // all-reduce + average over (workers × micro-batches)
        tree_all_reduce_sum(&mut worker_grads);
        let mut grads = worker_grads.swap_remove(0);
        scale_grads(&mut grads, 1.0 / (self.loaders.len() * self.cfg.grad_accum) as f32);
        if self.cfg.grad_clip > 0.0 {
            clip_global_norm(&mut grads, self.cfg.grad_clip);
        }

        // optimizer updates: params then bi (grads are ordered the same way)
        self.opt_params.set_lr(lr);
        self.opt_params.step_begin();
        let names: Vec<String> = self.params.keys().cloned().collect();
        for (idx, name) in names.iter().enumerate() {
            let decay = self.param_shapes[name].len() >= 2; // matrices only
            let w = self.params.get_mut(name).unwrap();
            self.opt_params.update(idx, w, &grads[idx], decay);
        }
        self.opt_bi.set_lr(lr);
        self.opt_bi.step_begin();
        let bi_names: Vec<String> = self.bi.keys().cloned().collect();
        let off = self.params.len();
        for (k, name) in bi_names.iter().enumerate() {
            let b = self.bi.get_mut(name).unwrap();
            self.opt_bi.update(k, b, &grads[off + k], false);
            // decoupled b_i weight decay — the b_t annealing mechanism
            let decay = 1.0 - lr * self.bi_weight_decay;
            for v in b.iter_mut() {
                *v = (*v as f64 * decay) as f32;
            }
        }

        self.seeds.advance_step();
        let loss = loss_sum / n_micro as f64;
        // per-layer PQT numerics gauges (effective bitwidth + noise
        // amplitude), aggregated into the step row as run-wide means
        let mut bt_sum = 0.0;
        let mut amp_sum = 0.0;
        let mut n_layers = 0usize;
        for name in self.bi_layer_names() {
            if let Some(bt) = self.bt_of(&name) {
                let (bt_mean, noise_amp) = self.log.record_layer_numerics(&name, &bt);
                bt_sum += bt_mean;
                amp_sum += noise_amp;
                n_layers += 1;
            }
        }
        let n = n_layers.max(1) as f64;
        self.log.push(StepRow {
            step: self.step,
            loss,
            lr,
            tokens: self.tokens_per_step(),
            dt: t0.elapsed().as_secs_f64(),
            bt_mean: if n_layers > 0 { bt_sum / n } else { 0.0 },
            noise_amp: if n_layers > 0 { amp_sum / n } else { 0.0 },
        });
        self.log.check_divergence(3.0);
        self.step += 1;
        Ok(loss)
    }

    /// Run `n` steps, optionally printing progress every `print_every`.
    pub fn run(&mut self, n: usize, print_every: usize) -> Result<()> {
        for _ in 0..n {
            let loss = self.train_step()?;
            if print_every > 0 && self.step % print_every == 0 {
                println!(
                    "[{}] step {:>5} loss {:.4} (wma {:.4}) lr {:.2e} {:.0} tok/s",
                    self.log.name,
                    self.step,
                    loss,
                    self.log.final_loss().unwrap_or(loss),
                    self.schedule.at(self.step.saturating_sub(1)),
                    self.log.tokens_per_sec(),
                );
            }
        }
        Ok(())
    }

    /// Evaluate mean loss on `n_batches` held-out batches via an eval
    /// artifact (same model tag, `.eval` suffix).
    pub fn evaluate(&mut self, eval_artifact: &str, n_batches: usize) -> Result<f64> {
        let name = if eval_artifact.ends_with(".eval") {
            eval_artifact.to_string()
        } else {
            format!("{eval_artifact}.eval")
        };
        let mut total = 0f64;
        let seed = (self.seeds.step_seed("noise") & 0x7FFF_FFFF) as i32;
        for k in 0..n_batches {
            // held-out stream: offset far beyond any training step
            let b = self.loaders[0].batch_at(1_000_000 + k as u64);
            let x: Vec<i32> = b.x.iter().map(|&t| t as i32).collect();
            let y: Vec<i32> = b.y.iter().map(|&t| t as i32).collect();
            let inputs = self.input_tensors(x, y, seed);
            let outputs = self.runtime.execute(&name, &inputs)?;
            total += outputs[0].scalar_f32()? as f64;
        }
        Ok(total / n_batches as f64)
    }

    /// Effective bitwidths b_t of one PQT layer (Eq. 11 over current b_i).
    pub fn bt_of(&self, bi_name: &str) -> Option<Vec<f32>> {
        self.bi.get(bi_name).map(|b| {
            b.iter()
                .map(|&x| (self.b_target + x as f64 * (self.b_init - self.b_target)) as f32)
                .collect()
        })
    }

    /// Names of PQT layers (sorted).
    pub fn bi_layer_names(&self) -> Vec<String> {
        self.bi.keys().cloned().collect()
    }

    /// GPU-memory model of the paper's Table 1 (bytes): master weights
    /// (4 B f32) + ŵ (2 B bf16, PQT arms only) + optimizer state + packed
    /// noise (0.5 B GaussWS / 2 B DiffQ while a layer's backward is alive).
    pub fn memory_model_bytes(&self, method: &str) -> usize {
        let n_params: usize = self.params.values().map(|v| v.len()).sum();
        let pqt_params: usize = self
            .bi_shapes
            .iter()
            .map(|(name, _)| {
                let wname = name.clone();
                self.params.get(&wname).map(|w| w.len()).unwrap_or(0)
            })
            .sum();
        let base = n_params * 4 + self.opt_params.state_bytes() + self.opt_bi.state_bytes();
        match method {
            "gaussws" => base + pqt_params * 2 + pqt_params / 2,
            "diffq" => base + pqt_params * 2 + pqt_params * 2,
            _ => base,
        }
    }

    /// Save a full checkpoint (params + b_i + step/seed).
    pub fn save_checkpoint(&self, path: &str) -> Result<()> {
        let mut ck = Checkpoint {
            step: self.step as u64,
            master_seed: self.seeds.master_seed(),
            tensors: Default::default(),
        };
        for (k, v) in &self.params {
            ck.insert(&format!("param.{k}"), v.clone());
        }
        for (k, v) in &self.bi {
            ck.insert(&format!("bi.{k}"), v.clone());
        }
        ck.save(path)?;
        Ok(())
    }

    /// Restore params/b_i/step from a checkpoint.
    pub fn load_checkpoint(&mut self, path: &str) -> Result<()> {
        let ck = Checkpoint::load(path)?;
        for (k, v) in self.params.iter_mut() {
            *v = ck.get(&format!("param.{k}"))?.clone();
        }
        for (k, v) in self.bi.iter_mut() {
            *v = ck.get(&format!("bi.{k}"))?.clone();
        }
        self.step = ck.step as usize;
        self.seeds.set_step(ck.step);
        Ok(())
    }

    /// Export parameter tensors with shapes (for the rust inference path).
    pub fn export_params(&self) -> Vec<(String, Vec<usize>, Vec<f32>)> {
        self.params
            .iter()
            .map(|(k, v)| (k.clone(), self.param_shapes[k].clone(), v.clone()))
            .collect()
    }
}
