//! Run metrics: per-step loss/LR/throughput logging, WMA smoothing
//! (Fig. 4 uses α = 1/16 and 1/128), windowed-max loss, divergence
//! detection, and CSV/JSON export for the experiment harness.
//!
//! [`RunLog`] is a view over a [`telemetry::Registry`](crate::telemetry):
//! every pushed row also lands in `train.*` counters/gauges/histograms
//! (steps, tokens, loss, lr, step wall-time), and
//! [`RunLog::record_layer_numerics`] publishes per-layer PQT gauges —
//! the effective train-time bitwidth `train.bt_mean.<layer>` and the
//! Eq. 3 noise amplitude factor `train.noise_amp.<layer>` (mean of
//! `2^(1 − b_t)`, the multiplier on amax in the perturbation std) — so
//! training numerics share the serve layer's exposition path.

use crate::telemetry::Registry;
use crate::util::json::{arr, num, obj, s, Json};
use crate::util::stats::{wma_series, windowed_max};
use std::io::Write;
use std::path::Path;

/// One training-step record.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct StepRow {
    pub step: usize,
    pub loss: f64,
    pub lr: f64,
    /// tokens processed this step (across all workers)
    pub tokens: usize,
    /// wall seconds for the step
    pub dt: f64,
    /// mean effective train-time bitwidth b_t across PQT layers (Eq. 11);
    /// 0 when the run has no PQT linears
    pub bt_mean: f64,
    /// mean Eq. 3 noise amplitude factor 2^(1 − b_t) across PQT layers
    pub noise_amp: f64,
}

/// A full run log.
#[derive(Debug, Clone, Default)]
pub struct RunLog {
    pub name: String,
    pub rows: Vec<StepRow>,
    /// steps at which divergence was detected
    pub divergences: Vec<usize>,
    reg: Registry,
}

impl RunLog {
    pub fn new(name: &str) -> RunLog {
        RunLog { name: name.to_string(), ..Default::default() }
    }

    /// The backing telemetry registry (`train.*` metrics); shared across
    /// clones, exposable next to the serve metrics.
    pub fn registry(&self) -> &Registry {
        &self.reg
    }

    pub fn push(&mut self, row: StepRow) {
        self.reg.counter("train.steps").inc();
        self.reg.counter("train.tokens").add(row.tokens as u64);
        self.reg.gauge("train.loss").set(row.loss);
        self.reg.gauge("train.lr").set(row.lr);
        self.reg.histogram("train.step_dt_s").record(row.dt);
        if row.bt_mean != 0.0 {
            self.reg.gauge("train.bt_mean").set(row.bt_mean);
            self.reg.gauge("train.noise_amp").set(row.noise_amp);
        }
        self.rows.push(row);
    }

    /// Publish per-layer PQT numerics gauges (`train.bt_mean.<layer>`,
    /// `train.noise_amp.<layer>`) and return `(bt_mean, noise_amp)` for
    /// aggregation into the step row. `bt` is the per-group effective
    /// bitwidth vector of one layer's weight (Eq. 11).
    pub fn record_layer_numerics(&self, layer: &str, bt: &[f32]) -> (f64, f64) {
        if bt.is_empty() {
            return (0.0, 0.0);
        }
        let n = bt.len() as f64;
        let bt_mean = bt.iter().map(|&b| b as f64).sum::<f64>() / n;
        let noise_amp = bt.iter().map(|&b| (1.0 - b as f64).exp2()).sum::<f64>() / n;
        self.reg.gauge(&format!("train.bt_mean.{layer}")).set(bt_mean);
        self.reg.gauge(&format!("train.noise_amp.{layer}")).set(noise_amp);
        (bt_mean, noise_amp)
    }

    pub fn losses(&self) -> Vec<f64> {
        self.rows.iter().map(|r| r.loss).collect()
    }

    /// Smoothed loss curve (weighted moving average).
    pub fn smoothed(&self, alpha: f64) -> Vec<f64> {
        wma_series(&self.losses(), alpha)
    }

    /// Windowed max loss (Fig. 4 "maximum loss" columns).
    pub fn max_loss(&self, window: usize) -> Vec<f64> {
        windowed_max(&self.losses(), window)
    }

    /// Final smoothed loss (α=1/16), the scalar used in summary tables.
    pub fn final_loss(&self) -> Option<f64> {
        self.smoothed(1.0 / 16.0).last().copied()
    }

    /// Mean tokens/second over the run (ignores the first step: compile).
    pub fn tokens_per_sec(&self) -> f64 {
        let rows = if self.rows.len() > 1 { &self.rows[1..] } else { &self.rows[..] };
        let tok: usize = rows.iter().map(|r| r.tokens).sum();
        let dt: f64 = rows.iter().map(|r| r.dt).sum();
        if dt == 0.0 {
            0.0
        } else {
            tok as f64 / dt
        }
    }

    /// Detect divergence: loss non-finite, or exceeding `factor`× the
    /// running minimum of the smoothed curve. Records and returns true.
    pub fn check_divergence(&mut self, factor: f64) -> bool {
        let sm = self.smoothed(1.0 / 16.0);
        let Some(&last) = sm.last() else { return false };
        let step = self.rows.last().unwrap().step;
        let min = sm.iter().cloned().fold(f64::INFINITY, f64::min);
        if !last.is_finite() || (sm.len() > 10 && last > factor * min) {
            if self.divergences.last() != Some(&step) {
                self.divergences.push(step);
            }
            return true;
        }
        false
    }

    /// CSV with smoothed columns.
    pub fn to_csv(&self) -> String {
        let sm16 = self.smoothed(1.0 / 16.0);
        let sm128 = self.smoothed(1.0 / 128.0);
        let mx = self.max_loss(64);
        let mut out = String::from("step,loss,wma16,wma128,max64,lr,tokens,dt,bt_mean,noise_amp\n");
        for (i, r) in self.rows.iter().enumerate() {
            out.push_str(&format!(
                "{},{:.6},{:.6},{:.6},{:.6},{:.6e},{},{:.4},{:.4},{:.6}\n",
                r.step, r.loss, sm16[i], sm128[i], mx[i], r.lr, r.tokens, r.dt, r.bt_mean,
                r.noise_amp
            ));
        }
        out
    }

    /// Summary JSON (used by the experiment index in EXPERIMENTS.md).
    pub fn summary_json(&self) -> Json {
        obj(vec![
            ("name", s(&self.name)),
            ("steps", num(self.rows.len() as f64)),
            ("final_loss", num(self.final_loss().unwrap_or(f64::NAN))),
            ("tokens_per_sec", num(self.tokens_per_sec())),
            (
                "divergences",
                arr(self.divergences.iter().map(|&d| num(d as f64)).collect()),
            ),
        ])
    }

    /// Write `<dir>/<name>.csv` and `<dir>/<name>.summary.json`.
    pub fn write_to(&self, dir: impl AsRef<Path>) -> std::io::Result<()> {
        let dir = dir.as_ref();
        std::fs::create_dir_all(dir)?;
        let mut f = std::fs::File::create(dir.join(format!("{}.csv", self.name)))?;
        f.write_all(self.to_csv().as_bytes())?;
        let mut f = std::fs::File::create(dir.join(format!("{}.summary.json", self.name)))?;
        f.write_all(self.summary_json().to_string().as_bytes())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn log_with(losses: &[f64]) -> RunLog {
        let mut l = RunLog::new("t");
        for (i, &x) in losses.iter().enumerate() {
            l.push(StepRow { step: i, loss: x, lr: 1e-3, tokens: 100, dt: 0.1, ..Default::default() });
        }
        l
    }

    #[test]
    fn csv_has_header_and_rows() {
        let l = log_with(&[3.0, 2.5, 2.0]);
        let csv = l.to_csv();
        assert!(csv.starts_with("step,loss"));
        assert_eq!(csv.lines().count(), 4);
    }

    #[test]
    fn tokens_per_sec_skips_compile_step() {
        let mut l = RunLog::new("t");
        let row = |step, dt| StepRow { step, loss: 1.0, lr: 0.0, tokens: 100, dt, ..Default::default() };
        l.push(row(0, 10.0)); // compile
        l.push(row(1, 0.1));
        l.push(row(2, 0.1));
        assert!((l.tokens_per_sec() - 1000.0).abs() < 1.0);
    }

    #[test]
    fn divergence_on_nan() {
        let mut l = log_with(&[3.0, 2.0, f64::NAN]);
        assert!(l.check_divergence(3.0));
        assert_eq!(l.divergences.len(), 1);
    }

    #[test]
    fn divergence_on_explosion() {
        let mut losses = vec![2.0; 50];
        losses.extend(vec![50.0; 30]);
        let mut l = log_with(&losses);
        assert!(l.check_divergence(3.0));
    }

    #[test]
    fn no_false_divergence_on_noise() {
        let losses: Vec<f64> = (0..100).map(|i| 3.0 - i as f64 * 0.01).collect();
        let mut l = log_with(&losses);
        assert!(!l.check_divergence(3.0));
        assert!(l.divergences.is_empty());
    }

    #[test]
    fn registry_sees_training_metrics() {
        let mut l = RunLog::new("t");
        let (bt_mean, noise_amp) = l.record_layer_numerics("blk0.attn.qkv", &[3.0, 4.0]);
        assert!((bt_mean - 3.5).abs() < 1e-12);
        // mean of 2^(1-3) and 2^(1-4) = (0.25 + 0.125) / 2
        assert!((noise_amp - 0.1875).abs() < 1e-12);
        l.push(StepRow {
            step: 0,
            loss: 2.5,
            lr: 1e-3,
            tokens: 128,
            dt: 0.2,
            bt_mean,
            noise_amp,
        });
        let reg = l.registry();
        assert_eq!(reg.counter("train.steps").get(), 1);
        assert_eq!(reg.counter("train.tokens").get(), 128);
        assert_eq!(reg.gauge("train.loss").get(), 2.5);
        assert_eq!(reg.gauge("train.bt_mean.blk0.attn.qkv").get(), 3.5);
        assert_eq!(reg.histogram("train.step_dt_s").count(), 1);
        // layer gauges show up in the shared exposition
        let text = reg.prometheus_text();
        assert!(text.contains("gaussws_train_bt_mean_blk0_attn_qkv"));
        assert!(text.contains("gaussws_train_noise_amp_blk0_attn_qkv"));
    }

    #[test]
    fn empty_layer_numerics_is_zero_and_unpublished() {
        let l = RunLog::new("t");
        assert_eq!(l.record_layer_numerics("blk0", &[]), (0.0, 0.0));
        assert!(!l.registry().names().iter().any(|n| n.starts_with("train.bt_mean")));
    }

    #[test]
    fn summary_json_fields() {
        let l = log_with(&[3.0, 2.0, 1.0]);
        let j = l.summary_json();
        assert_eq!(j.get("steps").as_usize(), Some(3));
        assert!(j.get("final_loss").as_f64().unwrap() < 3.0);
    }
}
