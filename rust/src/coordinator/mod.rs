//! L3 coordinator: trainer loop, simulated data-parallel workers with tree
//! all-reduce, metrics, and checkpointing.

pub mod checkpoint;
pub mod metrics;
pub mod trainer;
pub mod workers;

pub use checkpoint::Checkpoint;
pub use metrics::{RunLog, StepRow};
pub use trainer::Trainer;
