//! Binary checkpointing of named f32 tensors (params, b_i, optimizer
//! moments) plus scalar metadata. Format:
//!
//! ```text
//! magic "GWCK1\n"
//! u64 step | u64 master_seed | u32 n_tensors
//! per tensor: u32 name_len | name bytes | u64 numel | numel × f32 LE
//! ```

use crate::config::schema::ModelConfig;
use crate::nn::tensor::Mat;
use crate::nn::transformer::{Params, Transformer};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 6] = b"GWCK1\n";

/// A checkpoint in memory.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Checkpoint {
    pub step: u64,
    pub master_seed: u64,
    pub tensors: BTreeMap<String, Vec<f32>>,
}

impl Checkpoint {
    pub fn insert(&mut self, name: &str, data: Vec<f32>) {
        self.tensors.insert(name.to_string(), data);
    }

    pub fn get(&self, name: &str) -> Result<&Vec<f32>> {
        self.tensors.get(name).with_context(|| format!("checkpoint missing tensor '{name}'"))
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(MAGIC)?;
        f.write_all(&self.step.to_le_bytes())?;
        f.write_all(&self.master_seed.to_le_bytes())?;
        f.write_all(&(self.tensors.len() as u32).to_le_bytes())?;
        for (name, data) in &self.tensors {
            f.write_all(&(name.len() as u32).to_le_bytes())?;
            f.write_all(name.as_bytes())?;
            f.write_all(&(data.len() as u64).to_le_bytes())?;
            for v in data {
                f.write_all(&v.to_le_bytes())?;
            }
        }
        Ok(())
    }

    /// Reassemble the `param.*` tensors into transformer [`Params`], with
    /// shapes derived from `cfg` (the checkpoint format stores flat buffers
    /// only). This is the manifest-free train→serve bridge: a checkpoint
    /// plus a model config is everything the serving engine needs.
    pub fn to_params(&self, cfg: &ModelConfig) -> Result<Params> {
        let shapes = Transformer::shapes(cfg);
        let mut tensors = BTreeMap::new();
        for (name, (rows, cols)) in shapes {
            let data = self.get(&format!("param.{name}"))?.clone();
            if data.len() != rows * cols {
                bail!(
                    "checkpoint tensor 'param.{name}' has {} elements, config wants {}×{}",
                    data.len(),
                    rows,
                    cols
                );
            }
            tensors.insert(name, Mat::from_vec(rows, cols, data));
        }
        Ok(Params { tensors })
    }

    /// [`Checkpoint::to_params`] with the linear weights fake-quantized
    /// through a [`crate::quant::Scheme`] — the checkpoint-side snapshot
    /// path of the train → low-precision-deploy hop (Table C.1 evals,
    /// `gaussws quantize`). Non-linear tensors pass through at master
    /// precision. Stochastic schemes use a deterministic per-tensor seed.
    pub fn to_quantized_params(
        &self,
        cfg: &ModelConfig,
        scheme: &crate::quant::Scheme,
    ) -> Result<Params> {
        let mut params = self.to_params(cfg)?;
        params.quantize_linears(cfg, scheme, self.master_seed);
        Ok(params)
    }

    /// Capture transformer [`Params`] as `param.*` tensors (inverse of
    /// [`Checkpoint::to_params`], minus optimizer state).
    pub fn from_params(params: &Params, step: u64, master_seed: u64) -> Checkpoint {
        let mut ck = Checkpoint { step, master_seed, tensors: Default::default() };
        for (name, m) in &params.tensors {
            ck.insert(&format!("param.{name}"), m.data.clone());
        }
        ck
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Checkpoint> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(&path)
                .with_context(|| format!("opening checkpoint {}", path.as_ref().display()))?,
        );
        let mut magic = [0u8; 6];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("bad checkpoint magic");
        }
        let mut u64b = [0u8; 8];
        f.read_exact(&mut u64b)?;
        let step = u64::from_le_bytes(u64b);
        f.read_exact(&mut u64b)?;
        let master_seed = u64::from_le_bytes(u64b);
        let mut u32b = [0u8; 4];
        f.read_exact(&mut u32b)?;
        let n = u32::from_le_bytes(u32b);
        let mut tensors = BTreeMap::new();
        for _ in 0..n {
            f.read_exact(&mut u32b)?;
            let name_len = u32::from_le_bytes(u32b) as usize;
            let mut name = vec![0u8; name_len];
            f.read_exact(&mut name)?;
            let name = String::from_utf8(name).context("tensor name utf8")?;
            f.read_exact(&mut u64b)?;
            let numel = u64::from_le_bytes(u64b) as usize;
            let mut bytes = vec![0u8; numel * 4];
            f.read_exact(&mut bytes)?;
            let data = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            tensors.insert(name, data);
        }
        Ok(Checkpoint { step, master_seed, tensors })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut ck = Checkpoint { step: 42, master_seed: 7, tensors: Default::default() };
        ck.insert("embed", vec![1.0, -2.5, 3.25]);
        ck.insert("blk0.qkv", vec![0.0; 128]);
        ck.insert("opt.m.embed", vec![0.5; 3]);
        let path = std::env::temp_dir().join("gaussws_ck_test.bin");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(ck, back);
    }

    #[test]
    fn missing_tensor_errors() {
        let ck = Checkpoint::default();
        assert!(ck.get("nope").is_err());
    }

    #[test]
    fn params_roundtrip_via_checkpoint() {
        use crate::config::schema::Arch;
        let cfg = ModelConfig::tiny(Arch::Gpt2);
        let model = Transformer::new(cfg.clone());
        let params = model.init_params(11);
        let ck = Checkpoint::from_params(&params, 3, 11);
        let back = ck.to_params(&cfg).unwrap();
        assert_eq!(params.tensors, back.tensors);
        // wrong config shape is rejected, not silently misread
        let mut bigger = cfg.clone();
        bigger.d_model = 128;
        bigger.n_head = 4;
        assert!(ck.to_params(&bigger).is_err());
    }

    #[test]
    fn quantized_params_follow_the_scheme() {
        use crate::config::schema::Arch;
        use crate::quant::QuantScheme;
        let cfg = ModelConfig::tiny(Arch::Gpt2);
        let model = Transformer::new(cfg.clone());
        let params = model.init_params(13);
        let ck = Checkpoint::from_params(&params, 1, 13);
        let scheme = crate::quant::resolve("fp6_e3m2").unwrap();
        let q = ck.to_quantized_params(&cfg, &scheme).unwrap();
        for name in Params::linear_names(&cfg) {
            let m = params.get(&name);
            let w64: Vec<f64> = m.data.iter().map(|&x| x as f64).collect();
            let want = scheme.quantize(&w64, m.rows, m.cols, 0);
            for (a, b) in q.get(&name).data.iter().zip(want.data.iter()) {
                assert_eq!(*a, *b as f32);
            }
        }
        // f32 scheme is a no-op
        let raw = ck.to_quantized_params(&cfg, &crate::quant::resolve("f32").unwrap()).unwrap();
        assert_eq!(raw.tensors, params.tensors);
        // embeddings untouched under quantizing schemes
        assert_eq!(q.get("embed").data, params.get("embed").data);
    }

    #[test]
    fn corrupt_magic_rejected() {
        let path = std::env::temp_dir().join("gaussws_ck_bad.bin");
        std::fs::write(&path, b"NOTCK!rest").unwrap();
        assert!(Checkpoint::load(&path).is_err());
    }
}
