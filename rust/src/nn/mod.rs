//! Neural-network substrate: f32 tensor ops, a GPT2/Llama2 transformer with
//! both a train-shaped full forward (evaluation path) and an incremental
//! KV-cache decode (serving path, storage-generic over [`kv::KvStorage`]
//! with contiguous and paged block-table implementations), and the
//! rust-side optimizers that apply HLO-computed gradients.

pub mod kv;
pub mod optim;
pub mod tensor;
pub mod transformer;

pub use kv::{KvBlock, KvQuant, KvStorage, PagedKv};
pub use optim::{AdamMini, AdamW, LrSchedule, Opt};
pub use tensor::Mat;
pub use transformer::{DecodeCache, Params, Transformer};
