//! Neural-network substrate: f32 tensor ops (tiled GEMM over `Bᵀ` weight
//! layout, with row-panel access for fused weights), a GPT2/Llama2
//! transformer with a train-shaped full forward (evaluation path), an
//! incremental KV-cache decode (serving path, storage-generic over
//! [`kv::KvStorage`] with contiguous and paged block-table
//! implementations), and a weight-stationary batched decode
//! ([`transformer::Transformer::decode_wave`]: many sequences' current
//! tokens through each weight matrix in one GEMM, bit-identical to
//! per-sequence decode), plus the rust-side optimizers that apply
//! HLO-computed gradients.

pub mod kv;
pub mod optim;
pub mod tensor;
pub mod transformer;

pub use kv::{KvBlock, KvQuant, KvStorage, PagedKv};
pub use optim::{AdamMini, AdamW, LrSchedule, Opt};
pub use tensor::Mat;
pub use transformer::{DecodeCache, Params, Transformer};
