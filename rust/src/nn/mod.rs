//! Neural-network substrate: f32 tensor ops, a forward-only GPT2/Llama2
//! transformer (evaluation path), and the rust-side optimizers that apply
//! HLO-computed gradients.

pub mod optim;
pub mod tensor;
pub mod transformer;

pub use optim::{AdamMini, AdamW, LrSchedule, Opt};
pub use tensor::Mat;
pub use transformer::{Params, Transformer};
