//! GPT2/Llama2-style transformer for the rust inference paths: the
//! train-shaped full forward (perplexity of fake-quantized checkpoints,
//! Table C.1 / FP6–FP12 claims, L3 overhead benchmarks) plus an
//! incremental decode over a per-sequence KV cache — the serving hot
//! path. Decode is storage-agnostic: [`Transformer::prefill_chunk`]
//! advances a sequence by N positions per wave,
//! [`Transformer::decode_step`] is its single-token special case, and
//! [`Transformer::decode_wave`] is the weight-stationary batched form —
//! one token from each of N *different* sequences stacked into a single
//! (N × d_model) activation so every dense weight matrix is read once per
//! wave instead of once per sequence. All are generic over [`KvStorage`]
//! (contiguous [`DecodeCache`] or the paged [`crate::nn::kv::PagedKv`]).
//! Attention reads go through the storage's fused hooks
//! ([`KvStorage::dot_k`] / [`KvStorage::axpy_v`]), so a quantized paged
//! cache dequantizes its packed codes inside the dot products — no f32
//! mirror — while raw storages keep the classic loops, bit-identically.
//! Training runs through the L2 HLO artifacts.
//!
//! Weight layout matches `python/compile/model.py` exactly (see the
//! manifest ordering in `runtime::artifact`), so HLO-trained parameters
//! load directly.

use super::kv::KvStorage;
use super::tensor::{
    gelu, layer_norm, matmul_bt, matmul_bt_panel, rms_norm, rope, rope_row, silu, softmax_rows,
    Mat,
};
use crate::config::schema::{Arch, ModelConfig};
use crate::prng::Philox4x32;
use std::collections::BTreeMap;

/// All parameters of the model, keyed by qualified name. Linear weights are
/// stored **transposed** (out_features × in_features, like torch) so the
/// forward pass can use the unit-stride `matmul_bt` kernel directly.
#[derive(Debug, Clone)]
pub struct Params {
    pub tensors: BTreeMap<String, Mat>,
}

impl Params {
    pub fn get(&self, k: &str) -> &Mat {
        self.tensors.get(k).unwrap_or_else(|| panic!("missing tensor '{k}'"))
    }

    pub fn get_mut(&mut self, k: &str) -> &mut Mat {
        self.tensors.get_mut(k).unwrap_or_else(|| panic!("missing tensor '{k}'"))
    }

    pub fn param_count(&self) -> usize {
        self.tensors.values().map(|m| m.data.len()).sum()
    }

    /// Names of the per-block linear weights, in (block, Fig. 5) order.
    pub fn linear_names(cfg: &ModelConfig) -> Vec<String> {
        let mut out = Vec::new();
        for l in 0..cfg.n_layer {
            for name in cfg.arch.linear_names() {
                out.push(format!("blk{l}.{name}"));
            }
        }
        out
    }

    /// Fake-quantize every linear weight in place through a
    /// [`crate::quant::Scheme`] — the single quantize-the-linears path
    /// shared by `Checkpoint::to_quantized_params` and `gaussws quantize`.
    /// Stochastic schemes draw per-tensor seeds via
    /// [`crate::quant::tensor_seed`] salted with `master_seed`, so the same
    /// checkpoint quantizes identically everywhere.
    pub fn quantize_linears(
        &mut self,
        cfg: &ModelConfig,
        scheme: &crate::quant::Scheme,
        master_seed: u64,
    ) {
        use crate::quant::QuantScheme;
        if !scheme.codec.is_packed() {
            return;
        }
        for name in Params::linear_names(cfg) {
            let m = self.get_mut(&name);
            let w64: Vec<f64> = m.data.iter().map(|&x| x as f64).collect();
            let seed = crate::quant::tensor_seed(&name, master_seed);
            let q = scheme.quantize(&w64, m.rows, m.cols, seed);
            for (dst, &src) in m.data.iter_mut().zip(q.data.iter()) {
                *dst = src as f32;
            }
        }
    }
}

/// Contiguous per-sequence K/V cache for incremental decoding: one
/// (capacity × d_model) K and V matrix per layer, filled row-by-row as
/// tokens are decoded. This is what turns the O(t²) train-shaped forward
/// into an O(t) per-token decode. The serving engine uses the paged
/// [`crate::nn::kv::PagedKv`] instead (same [`KvStorage`] interface,
/// block-granular memory); this contiguous layout remains for standalone
/// decode and as the equivalence reference.
#[derive(Debug, Clone)]
pub struct DecodeCache {
    /// Cached keys per layer, rows `0..len` valid. For Llama the rotary
    /// embedding is already applied (K is cached post-RoPE).
    pub k: Vec<Mat>,
    /// Cached values per layer, rows `0..len` valid.
    pub v: Vec<Mat>,
    /// Number of cached positions (== the next decode position).
    pub len: usize,
    /// Maximum positions this cache can hold.
    pub capacity: usize,
}

impl DecodeCache {
    pub fn new(cfg: &ModelConfig, capacity: usize) -> DecodeCache {
        let capacity = capacity.min(cfg.seq_len);
        DecodeCache {
            k: (0..cfg.n_layer).map(|_| Mat::zeros(capacity, cfg.d_model)).collect(),
            v: (0..cfg.n_layer).map(|_| Mat::zeros(capacity, cfg.d_model)).collect(),
            len: 0,
            capacity,
        }
    }

    /// Forget all cached positions (slot reuse between sequences).
    pub fn reset(&mut self) {
        self.len = 0;
    }

    pub fn is_full(&self) -> bool {
        self.len >= self.capacity
    }

    /// Bytes of K/V storage held by this cache.
    pub fn bytes(&self) -> usize {
        self.k
            .iter()
            .chain(self.v.iter())
            .map(|m| m.data.len() * std::mem::size_of::<f32>())
            .sum()
    }
}

impl KvStorage for DecodeCache {
    fn len(&self) -> usize {
        self.len
    }

    fn capacity(&self) -> usize {
        self.capacity
    }

    fn write(&mut self, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        let d = self.k[layer].cols;
        self.k[layer].data[pos * d..(pos + 1) * d].copy_from_slice(k);
        self.v[layer].data[pos * d..(pos + 1) * d].copy_from_slice(v);
    }

    fn k_row(&self, layer: usize, pos: usize) -> &[f32] {
        self.k[layer].row(pos)
    }

    fn v_row(&self, layer: usize, pos: usize) -> &[f32] {
        self.v[layer].row(pos)
    }

    fn commit(&mut self, n: usize) {
        self.len += n;
    }
}

/// The model: config + helpers. Parameters live in [`Params`] so callers
/// can mutate/quantize them freely between forwards.
#[derive(Debug, Clone)]
pub struct Transformer {
    pub cfg: ModelConfig,
}

impl Transformer {
    pub fn new(cfg: ModelConfig) -> Self {
        cfg.validate().expect("invalid model config");
        Transformer { cfg }
    }

    /// The (rows, cols) of every parameter tensor for a config — the same
    /// layout [`Transformer::init_params`] produces. This is the shape
    /// source for loading shape-less checkpoints (`coordinator::Checkpoint`
    /// stores flat buffers) without consulting an artifact manifest.
    pub fn shapes(cfg: &ModelConfig) -> BTreeMap<String, (usize, usize)> {
        let d = cfg.d_model;
        let mut out = BTreeMap::new();
        out.insert("embed".to_string(), (cfg.vocab, d));
        if cfg.arch == Arch::Gpt2 {
            out.insert("pos_embed".to_string(), (cfg.seq_len, d));
        }
        for l in 0..cfg.n_layer {
            let p = |s: &str| format!("blk{l}.{s}");
            match cfg.arch {
                Arch::Gpt2 => {
                    out.insert(p("qkv"), (3 * d, d));
                    out.insert(p("out"), (d, d));
                    out.insert(p("up"), (cfg.d_ff, d));
                    out.insert(p("down"), (d, cfg.d_ff));
                    out.insert(p("ln1.g"), (1, d));
                    out.insert(p("ln1.b"), (1, d));
                    out.insert(p("ln2.g"), (1, d));
                    out.insert(p("ln2.b"), (1, d));
                }
                Arch::Llama2 => {
                    out.insert(p("q"), (d, d));
                    out.insert(p("k"), (d, d));
                    out.insert(p("v"), (d, d));
                    out.insert(p("out"), (d, d));
                    out.insert(p("gate"), (cfg.d_ff, d));
                    out.insert(p("up"), (cfg.d_ff, d));
                    out.insert(p("down"), (d, cfg.d_ff));
                    out.insert(p("ln1.g"), (1, d));
                    out.insert(p("ln2.g"), (1, d));
                }
            }
        }
        out.insert("lnf.g".to_string(), (1, d));
        if cfg.arch == Arch::Gpt2 {
            out.insert("lnf.b".to_string(), (1, d));
        }
        out
    }

    /// GPT2-style init (N(0, 0.02), scaled residual projections).
    pub fn init_params(&self, seed: u64) -> Params {
        let cfg = &self.cfg;
        let mut g = Philox4x32::new(seed);
        let mut tensors = BTreeMap::new();
        let mut randn = |rows: usize, cols: usize, std: f32| -> Mat {
            let mut m = Mat::zeros(rows, cols);
            let mut i = 0;
            while i < m.data.len() {
                let (a, b) = crate::prng::gauss::box_muller_pair(&mut g);
                m.data[i] = a as f32 * std;
                if i + 1 < m.data.len() {
                    m.data[i + 1] = b as f32 * std;
                }
                i += 2;
            }
            m
        };
        let d = cfg.d_model;
        let resid_std = 0.02 / (2.0 * cfg.n_layer as f32).sqrt();
        tensors.insert("embed".into(), randn(cfg.vocab, d, 0.02));
        if cfg.arch == Arch::Gpt2 {
            tensors.insert("pos_embed".into(), randn(cfg.seq_len, d, 0.01));
        }
        for l in 0..cfg.n_layer {
            let p = |s: &str| format!("blk{l}.{s}");
            match cfg.arch {
                Arch::Gpt2 => {
                    tensors.insert(p("qkv"), randn(3 * d, d, 0.02));
                    tensors.insert(p("out"), randn(d, d, resid_std));
                    tensors.insert(p("up"), randn(cfg.d_ff, d, 0.02));
                    tensors.insert(p("down"), randn(d, cfg.d_ff, resid_std));
                    tensors.insert(p("ln1.g"), Mat::from_vec(1, d, vec![1.0; d]));
                    tensors.insert(p("ln1.b"), Mat::zeros(1, d));
                    tensors.insert(p("ln2.g"), Mat::from_vec(1, d, vec![1.0; d]));
                    tensors.insert(p("ln2.b"), Mat::zeros(1, d));
                }
                Arch::Llama2 => {
                    tensors.insert(p("q"), randn(d, d, 0.02));
                    tensors.insert(p("k"), randn(d, d, 0.02));
                    tensors.insert(p("v"), randn(d, d, 0.02));
                    tensors.insert(p("out"), randn(d, d, resid_std));
                    tensors.insert(p("gate"), randn(cfg.d_ff, d, 0.02));
                    tensors.insert(p("up"), randn(cfg.d_ff, d, 0.02));
                    tensors.insert(p("down"), randn(d, cfg.d_ff, resid_std));
                    tensors.insert(p("ln1.g"), Mat::from_vec(1, d, vec![1.0; d]));
                    tensors.insert(p("ln2.g"), Mat::from_vec(1, d, vec![1.0; d]));
                }
            }
        }
        tensors.insert(
            "lnf.g".into(),
            Mat::from_vec(1, d, vec![1.0; d]),
        );
        if cfg.arch == Arch::Gpt2 {
            tensors.insert("lnf.b".into(), Mat::zeros(1, d));
        }
        // output head tied to embed (we read "embed" for the head)
        Params { tensors }
    }

    /// Forward one sequence of token ids; returns logits (seq × vocab).
    pub fn forward(&self, params: &Params, tokens: &[usize]) -> Mat {
        let cfg = &self.cfg;
        let t = tokens.len();
        assert!(t <= cfg.seq_len, "sequence longer than seq_len");
        let d = cfg.d_model;
        let embed = params.get("embed");
        let mut x = Mat::zeros(t, d);
        for (i, &tok) in tokens.iter().enumerate() {
            assert!(tok < cfg.vocab, "token {tok} out of vocab");
            x.data[i * d..(i + 1) * d].copy_from_slice(embed.row(tok));
        }
        if cfg.arch == Arch::Gpt2 {
            let pos = params.get("pos_embed");
            for i in 0..t {
                for j in 0..d {
                    x.data[i * d + j] += pos.at(i, j);
                }
            }
        }

        for l in 0..cfg.n_layer {
            let p = |s: &str| format!("blk{l}.{s}");
            // ---- attention sublayer ----
            let mut h = x.clone();
            match cfg.arch {
                Arch::Gpt2 => layer_norm(
                    &mut h,
                    &params.get(&p("ln1.g")).data,
                    &params.get(&p("ln1.b")).data,
                    1e-5,
                ),
                Arch::Llama2 => rms_norm(&mut h, &params.get(&p("ln1.g")).data, 1e-5),
            }
            let (q, k, v) = match cfg.arch {
                Arch::Gpt2 => {
                    // read the fused (3d × d) qkv weight as three d-row
                    // panels, writing q/k/v directly — no (t × 3d)
                    // intermediate, no row-copy split
                    let w = params.get(&p("qkv"));
                    let mut q = Mat::zeros(t, d);
                    let mut k = Mat::zeros(t, d);
                    let mut v = Mat::zeros(t, d);
                    matmul_bt_panel(&h, w, 0, d, &mut q);
                    matmul_bt_panel(&h, w, d, d, &mut k);
                    matmul_bt_panel(&h, w, 2 * d, d, &mut v);
                    (q, k, v)
                }
                Arch::Llama2 => {
                    let mut q = Mat::zeros(t, d);
                    let mut k = Mat::zeros(t, d);
                    let mut v = Mat::zeros(t, d);
                    matmul_bt(&h, params.get(&p("q")), &mut q);
                    matmul_bt(&h, params.get(&p("k")), &mut k);
                    matmul_bt(&h, params.get(&p("v")), &mut v);
                    (q, k, v)
                }
            };
            let att = self.attention(q, k, v, t);
            let mut att_out = Mat::zeros(t, d);
            matmul_bt(&att, params.get(&p("out")), &mut att_out);
            for i in 0..x.data.len() {
                x.data[i] += att_out.data[i];
            }
            // ---- MLP sublayer ----
            let mut h = x.clone();
            match cfg.arch {
                Arch::Gpt2 => layer_norm(
                    &mut h,
                    &params.get(&p("ln2.g")).data,
                    &params.get(&p("ln2.b")).data,
                    1e-5,
                ),
                Arch::Llama2 => rms_norm(&mut h, &params.get(&p("ln2.g")).data, 1e-5),
            }
            let mut mlp = Mat::zeros(t, cfg.d_ff);
            match cfg.arch {
                Arch::Gpt2 => {
                    matmul_bt(&h, params.get(&p("up")), &mut mlp);
                    for v in mlp.data.iter_mut() {
                        *v = gelu(*v);
                    }
                }
                Arch::Llama2 => {
                    let mut gate = Mat::zeros(t, cfg.d_ff);
                    matmul_bt(&h, params.get(&p("gate")), &mut gate);
                    matmul_bt(&h, params.get(&p("up")), &mut mlp);
                    for (m, g) in mlp.data.iter_mut().zip(gate.data.iter()) {
                        *m *= silu(*g);
                    }
                }
            }
            let mut down = Mat::zeros(t, d);
            matmul_bt(&mlp, params.get(&p("down")), &mut down);
            for i in 0..x.data.len() {
                x.data[i] += down.data[i];
            }
        }

        match cfg.arch {
            Arch::Gpt2 => {
                layer_norm(&mut x, &params.get("lnf.g").data, &params.get("lnf.b").data, 1e-5)
            }
            Arch::Llama2 => rms_norm(&mut x, &params.get("lnf.g").data, 1e-5),
        }
        // tied head: logits = x · embedᵀ
        let mut logits = Mat::zeros(t, cfg.vocab);
        matmul_bt(&x, params.get("embed"), &mut logits);
        logits
    }

    /// Multi-head causal attention over already-projected q/k/v (t × d).
    fn attention(&self, mut q: Mat, mut k: Mat, v: Mat, t: usize) -> Mat {
        let cfg = &self.cfg;
        let d = cfg.d_model;
        let hd = d / cfg.n_head;
        if cfg.arch == Arch::Llama2 {
            // rotary on q and k per head
            for h in 0..cfg.n_head {
                let mut qh = Mat::zeros(t, hd);
                let mut kh = Mat::zeros(t, hd);
                for i in 0..t {
                    qh.data[i * hd..(i + 1) * hd]
                        .copy_from_slice(&q.row(i)[h * hd..(h + 1) * hd]);
                    kh.data[i * hd..(i + 1) * hd]
                        .copy_from_slice(&k.row(i)[h * hd..(h + 1) * hd]);
                }
                rope(&mut qh, 10000.0);
                rope(&mut kh, 10000.0);
                for i in 0..t {
                    q.data[i * d + h * hd..i * d + (h + 1) * hd]
                        .copy_from_slice(qh.row(i));
                    k.data[i * d + h * hd..i * d + (h + 1) * hd]
                        .copy_from_slice(kh.row(i));
                }
            }
        }
        let scale = 1.0 / (hd as f32).sqrt();
        let mut out = Mat::zeros(t, d);
        for h in 0..cfg.n_head {
            // scores = q_h · k_hᵀ
            let mut scores = Mat::zeros(t, t);
            for i in 0..t {
                for j in 0..t {
                    let mut acc = 0f32;
                    for e in 0..hd {
                        acc += q.at(i, h * hd + e) * k.at(j, h * hd + e);
                    }
                    *scores.at_mut(i, j) = acc * scale;
                }
            }
            softmax_rows(&mut scores, Some(0));
            for i in 0..t {
                for e in 0..hd {
                    let mut acc = 0f32;
                    for j in 0..=i {
                        acc += scores.at(i, j) * v.at(j, h * hd + e);
                    }
                    *out.at_mut(i, h * hd + e) = acc;
                }
            }
        }
        out
    }

    /// Incremental decode: run ONE token at position `cache.len()`,
    /// appending its K/V and attending over all cached positions. Returns
    /// the logits row (vocab). The single-token special case of
    /// [`Transformer::prefill_chunk`] — and the 1-sequence case of
    /// [`Transformer::decode_wave`], which batches this across sequences
    /// without changing any output bit.
    pub fn decode_step<C: KvStorage>(
        &self,
        params: &Params,
        token: usize,
        cache: &mut C,
    ) -> Vec<f32> {
        self.prefill_chunk(params, &[token], cache)
    }

    /// Weight-stationary batched decode: advance N *different* sequences by
    /// one token each in a single wave. `tokens[s]` is decoded at position
    /// `caches[s].len()` of its own cache, and the returned `(N × vocab)`
    /// [`Mat`] holds sequence `s`'s logits in row `s`.
    ///
    /// The point is weight traffic: the per-sequence path streams every
    /// dense weight matrix once *per sequence* per wave (each linear a
    /// 1 × d matvec), so an N-sequence decode wave reads N × |W| weight
    /// bytes. Here the N current-token hidden rows are stacked into one
    /// `(N × d_model)` activation and each layer's linears — qkv (or
    /// q/k/v), attention-out, the MLP pair, and the `vocab × d` logits
    /// head, the largest of all — run as ONE [`matmul_bt`] per wave: |W|
    /// bytes total, amortized across the batch. Attention stays
    /// per-sequence over each sequence's own [`KvStorage`] (sharded
    /// round-robin across `threads` scoped threads when `threads > 1`).
    ///
    /// **Bit-identity:** every dense kernel here computes output rows
    /// independently with the same full-depth ascending-k dot order as the
    /// 1-row call, `layer_norm`/`rms_norm`/`softmax_rows` are row-wise,
    /// `rope_row` is applied at each sequence's own absolute position, and
    /// the per-row attention goes through the same `attend_row` kernel as
    /// [`Transformer::prefill_chunk`]. Stacking N sequences' rows into one
    /// Mat and slicing the results back out is therefore exactly
    /// value-preserving: row `s` is bit-identical to what
    /// [`Transformer::decode_step`] would have returned for sequence `s`
    /// alone, for any batch composition, thread count, and KV storage.
    /// Each cache is committed by one position before returning.
    pub fn decode_wave<C: KvStorage + Sync>(
        &self,
        params: &Params,
        tokens: &[usize],
        caches: &mut [&mut C],
        threads: usize,
    ) -> Mat {
        let cfg = &self.cfg;
        let d = cfg.d_model;
        let n = tokens.len();
        assert!(n > 0, "decode wave must be non-empty");
        assert_eq!(n, caches.len(), "one cache per decoding sequence");
        let pos: Vec<usize> = caches.iter().map(|c| c.len()).collect();
        for (s, c) in caches.iter().enumerate() {
            assert!(
                pos[s] < c.capacity(),
                "KV cache full: seq {s} at {}/{}",
                pos[s],
                c.capacity()
            );
            assert!(pos[s] < cfg.seq_len, "seq {s}: decode past seq_len {}", cfg.seq_len);
        }

        let embed = params.get("embed");
        let mut x = Mat::zeros(n, d);
        for (s, &tok) in tokens.iter().enumerate() {
            assert!(tok < cfg.vocab, "token {tok} out of vocab");
            x.data[s * d..(s + 1) * d].copy_from_slice(embed.row(tok));
        }
        if cfg.arch == Arch::Gpt2 {
            let pe = params.get("pos_embed");
            for s in 0..n {
                for j in 0..d {
                    x.data[s * d + j] += pe.at(pos[s], j);
                }
            }
        }

        let hd = d / cfg.n_head;
        let scale = 1.0 / (hd as f32).sqrt();
        for l in 0..cfg.n_layer {
            let p = |s: &str| format!("blk{l}.{s}");
            // ---- attention sublayer ----
            let mut h = x.clone();
            match cfg.arch {
                Arch::Gpt2 => layer_norm(
                    &mut h,
                    &params.get(&p("ln1.g")).data,
                    &params.get(&p("ln1.b")).data,
                    1e-5,
                ),
                Arch::Llama2 => rms_norm(&mut h, &params.get(&p("ln1.g")).data, 1e-5),
            }
            let (mut q, mut k, v) = match cfg.arch {
                Arch::Gpt2 => {
                    let w = params.get(&p("qkv"));
                    let mut q = Mat::zeros(n, d);
                    let mut k = Mat::zeros(n, d);
                    let mut v = Mat::zeros(n, d);
                    matmul_bt_panel(&h, w, 0, d, &mut q);
                    matmul_bt_panel(&h, w, d, d, &mut k);
                    matmul_bt_panel(&h, w, 2 * d, d, &mut v);
                    (q, k, v)
                }
                Arch::Llama2 => {
                    let mut q = Mat::zeros(n, d);
                    let mut k = Mat::zeros(n, d);
                    let mut v = Mat::zeros(n, d);
                    matmul_bt(&h, params.get(&p("q")), &mut q);
                    matmul_bt(&h, params.get(&p("k")), &mut k);
                    matmul_bt(&h, params.get(&p("v")), &mut v);
                    (q, k, v)
                }
            };
            if cfg.arch == Arch::Llama2 {
                // rotary at each sequence's own absolute position
                for s in 0..n {
                    for head in 0..cfg.n_head {
                        let o = s * d + head * hd;
                        rope_row(&mut q.data[o..o + hd], pos[s], 10000.0);
                        rope_row(&mut k.data[o..o + hd], pos[s], 10000.0);
                    }
                }
            }
            for (s, c) in caches.iter_mut().enumerate() {
                c.write(l, pos[s], k.row(s), v.row(s));
            }

            // attention is the only per-sequence stage: each row attends
            // over its own cache through the shared `attend_row` kernel.
            // Rows are independent (disjoint output slices, &C reads), so
            // they shard round-robin across scoped threads.
            let mut att = Mat::zeros(n, d);
            let nt = threads.clamp(1, n);
            if nt == 1 {
                for (s, out) in att.data.chunks_mut(d).enumerate() {
                    attend_row(&*caches[s], l, pos[s], q.row(s), out, cfg.n_head, hd, scale);
                }
            } else {
                let shared: Vec<&C> = caches.iter().map(|c| &**c).collect();
                let (q, pos) = (&q, &pos);
                let mut parts: Vec<Vec<(usize, &mut [f32])>> =
                    (0..nt).map(|_| Vec::new()).collect();
                for (s, out) in att.data.chunks_mut(d).enumerate() {
                    parts[s % nt].push((s, out));
                }
                std::thread::scope(|sc| {
                    for part in parts {
                        let shared = &shared;
                        sc.spawn(move || {
                            for (s, out) in part {
                                attend_row(
                                    shared[s],
                                    l,
                                    pos[s],
                                    q.row(s),
                                    out,
                                    self.cfg.n_head,
                                    hd,
                                    scale,
                                );
                            }
                        });
                    }
                });
            }
            let mut att_out = Mat::zeros(n, d);
            matmul_bt(&att, params.get(&p("out")), &mut att_out);
            for i in 0..x.data.len() {
                x.data[i] += att_out.data[i];
            }
            // ---- MLP sublayer ----
            let mut h = x.clone();
            match cfg.arch {
                Arch::Gpt2 => layer_norm(
                    &mut h,
                    &params.get(&p("ln2.g")).data,
                    &params.get(&p("ln2.b")).data,
                    1e-5,
                ),
                Arch::Llama2 => rms_norm(&mut h, &params.get(&p("ln2.g")).data, 1e-5),
            }
            let mut mlp = Mat::zeros(n, cfg.d_ff);
            match cfg.arch {
                Arch::Gpt2 => {
                    matmul_bt(&h, params.get(&p("up")), &mut mlp);
                    for v in mlp.data.iter_mut() {
                        *v = gelu(*v);
                    }
                }
                Arch::Llama2 => {
                    let mut gate = Mat::zeros(n, cfg.d_ff);
                    matmul_bt(&h, params.get(&p("gate")), &mut gate);
                    matmul_bt(&h, params.get(&p("up")), &mut mlp);
                    for (m, g) in mlp.data.iter_mut().zip(gate.data.iter()) {
                        *m *= silu(*g);
                    }
                }
            }
            let mut down = Mat::zeros(n, d);
            matmul_bt(&mlp, params.get(&p("down")), &mut down);
            for i in 0..x.data.len() {
                x.data[i] += down.data[i];
            }
        }

        match cfg.arch {
            Arch::Gpt2 => {
                layer_norm(&mut x, &params.get("lnf.g").data, &params.get("lnf.b").data, 1e-5)
            }
            Arch::Llama2 => rms_norm(&mut x, &params.get("lnf.g").data, 1e-5),
        }
        // tied head: ONE (n × vocab) projection for the whole wave — the
        // single biggest weight matrix, read once instead of n times
        let mut logits = Mat::zeros(n, cfg.vocab);
        matmul_bt(&x, embed, &mut logits);
        for c in caches.iter_mut() {
            c.commit(1);
        }
        logits
    }

    /// Chunked prefill: advance a sequence by `tokens.len()` positions in
    /// one wave, appending each position's K/V to `cache` and attending
    /// causally over everything cached so far. Returns the logits row of
    /// the *last* position (the only one a scheduler samples from).
    ///
    /// Every per-position computation mirrors [`Transformer::forward`] /
    /// the one-token decode exactly (same row-wise op order), so a prompt
    /// prefilled in chunks of any size yields bit-identical cache contents
    /// and logits to feeding it token-by-token — chunking is purely a
    /// wave-amortization choice (fewer waves, batch-of-rows matmuls).
    pub fn prefill_chunk<C: KvStorage>(
        &self,
        params: &Params,
        tokens: &[usize],
        cache: &mut C,
    ) -> Vec<f32> {
        let x = self.prefill_hidden(params, tokens, cache);
        let cfg = &self.cfg;
        let t = tokens.len();
        // final norm over the chunk (row-wise), logits for the last row only
        let mut last = Mat::from_vec(1, cfg.d_model, x.row(t - 1).to_vec());
        match cfg.arch {
            Arch::Gpt2 => {
                layer_norm(&mut last, &params.get("lnf.g").data, &params.get("lnf.b").data, 1e-5)
            }
            Arch::Llama2 => rms_norm(&mut last, &params.get("lnf.g").data, 1e-5),
        }
        let mut logits = Mat::zeros(1, cfg.vocab);
        matmul_bt(&last, params.get("embed"), &mut logits);
        cache.commit(t);
        logits.data
    }

    /// [`Transformer::prefill_chunk`], but returning the logits row of
    /// **every** chunk position (a `tokens.len() × vocab` [`Mat`]): row `i`
    /// is the next-token distribution after consuming `tokens[i]` at
    /// position `cache.len() + i`. This is the speculative-decode verify
    /// wave — one chunk of `[last_token, draft_0, …, draft_{K-1}]` scores
    /// all K drafts at once. The final norm and the logits projection are
    /// row-wise ops (`layer_norm`/`rms_norm` normalize each row
    /// independently; `matmul_bt` computes output rows independently), so
    /// every row here is bit-identical to the logits `prefill_chunk` would
    /// return for the same position fed as the chunk's last token.
    pub fn prefill_chunk_logits<C: KvStorage>(
        &self,
        params: &Params,
        tokens: &[usize],
        cache: &mut C,
    ) -> Mat {
        let mut x = self.prefill_hidden(params, tokens, cache);
        let cfg = &self.cfg;
        let t = tokens.len();
        match cfg.arch {
            Arch::Gpt2 => {
                layer_norm(&mut x, &params.get("lnf.g").data, &params.get("lnf.b").data, 1e-5)
            }
            Arch::Llama2 => rms_norm(&mut x, &params.get("lnf.g").data, 1e-5),
        }
        let mut logits = Mat::zeros(t, cfg.vocab);
        matmul_bt(&x, params.get("embed"), &mut logits);
        cache.commit(t);
        logits
    }

    /// The shared body of [`Transformer::prefill_chunk`] /
    /// [`Transformer::prefill_chunk_logits`]: run the chunk through every
    /// block, staging each position's K/V into `cache`, and return the
    /// pre-final-norm hidden states (`tokens.len() × d_model`). Does **not**
    /// commit — the callers commit after projecting logits.
    fn prefill_hidden<C: KvStorage>(
        &self,
        params: &Params,
        tokens: &[usize],
        cache: &mut C,
    ) -> Mat {
        let cfg = &self.cfg;
        let d = cfg.d_model;
        let t = tokens.len();
        assert!(t > 0, "prefill chunk must be non-empty");
        let p0 = cache.len();
        assert!(
            p0 + t <= cache.capacity(),
            "KV cache full: {p0}+{t} positions > capacity {}",
            cache.capacity()
        );
        assert!(p0 + t <= cfg.seq_len, "decode past seq_len {}", cfg.seq_len);

        let embed = params.get("embed");
        let mut x = Mat::zeros(t, d);
        for (i, &tok) in tokens.iter().enumerate() {
            assert!(tok < cfg.vocab, "token {tok} out of vocab");
            x.data[i * d..(i + 1) * d].copy_from_slice(embed.row(tok));
        }
        if cfg.arch == Arch::Gpt2 {
            let pe = params.get("pos_embed");
            for i in 0..t {
                for j in 0..d {
                    x.data[i * d + j] += pe.at(p0 + i, j);
                }
            }
        }

        let hd = d / cfg.n_head;
        let scale = 1.0 / (hd as f32).sqrt();
        for l in 0..cfg.n_layer {
            let p = |s: &str| format!("blk{l}.{s}");
            // ---- attention sublayer ----
            let mut h = x.clone();
            match cfg.arch {
                Arch::Gpt2 => layer_norm(
                    &mut h,
                    &params.get(&p("ln1.g")).data,
                    &params.get(&p("ln1.b")).data,
                    1e-5,
                ),
                Arch::Llama2 => rms_norm(&mut h, &params.get(&p("ln1.g")).data, 1e-5),
            }
            let (q, k, v) = match cfg.arch {
                Arch::Gpt2 => {
                    // read the fused (3d × d) qkv weight as three d-row
                    // panels, writing q/k/v directly — no (t × 3d)
                    // intermediate, no row-copy split (bit-identical: each
                    // output cell is the same dot against the same row)
                    let w = params.get(&p("qkv"));
                    let mut q = Mat::zeros(t, d);
                    let mut k = Mat::zeros(t, d);
                    let mut v = Mat::zeros(t, d);
                    matmul_bt_panel(&h, w, 0, d, &mut q);
                    matmul_bt_panel(&h, w, d, d, &mut k);
                    matmul_bt_panel(&h, w, 2 * d, d, &mut v);
                    (q, k, v)
                }
                Arch::Llama2 => {
                    let mut q = Mat::zeros(t, d);
                    let mut k = Mat::zeros(t, d);
                    let mut v = Mat::zeros(t, d);
                    matmul_bt(&h, params.get(&p("q")), &mut q);
                    matmul_bt(&h, params.get(&p("k")), &mut k);
                    matmul_bt(&h, params.get(&p("v")), &mut v);
                    // rotary at each row's absolute position, per head; K
                    // is cached post-RoPE, matching `forward`
                    for i in 0..t {
                        for head in 0..cfg.n_head {
                            let o = i * d + head * hd;
                            rope_row(&mut q.data[o..o + hd], p0 + i, 10000.0);
                            rope_row(&mut k.data[o..o + hd], p0 + i, 10000.0);
                        }
                    }
                    (q, k, v)
                }
            };
            // append the chunk's K/V rows (K post-RoPE, matching forward)
            for i in 0..t {
                cache.write(l, p0 + i, k.row(i), v.row(i));
            }

            // causal attention: row i attends over cached positions
            // 0..=p0+i through the storage's fused hooks — quantized paged
            // caches dequantize packed codes in place, contiguous/mirrored
            // caches run the classic f32 loops; both accumulate in the
            // same element order, so the logits are storage-invariant.
            // The per-row kernel is shared with `decode_wave`, making the
            // batched wave's bit-identity structural rather than mirrored.
            let mut att = Mat::zeros(t, d);
            for i in 0..t {
                let out = &mut att.data[i * d..(i + 1) * d];
                attend_row(&*cache, l, p0 + i, q.row(i), out, cfg.n_head, hd, scale);
            }
            let mut att_out = Mat::zeros(t, d);
            matmul_bt(&att, params.get(&p("out")), &mut att_out);
            for i in 0..x.data.len() {
                x.data[i] += att_out.data[i];
            }
            // ---- MLP sublayer ----
            let mut h = x.clone();
            match cfg.arch {
                Arch::Gpt2 => layer_norm(
                    &mut h,
                    &params.get(&p("ln2.g")).data,
                    &params.get(&p("ln2.b")).data,
                    1e-5,
                ),
                Arch::Llama2 => rms_norm(&mut h, &params.get(&p("ln2.g")).data, 1e-5),
            }
            let mut mlp = Mat::zeros(t, cfg.d_ff);
            match cfg.arch {
                Arch::Gpt2 => {
                    matmul_bt(&h, params.get(&p("up")), &mut mlp);
                    for v in mlp.data.iter_mut() {
                        *v = gelu(*v);
                    }
                }
                Arch::Llama2 => {
                    let mut gate = Mat::zeros(t, cfg.d_ff);
                    matmul_bt(&h, params.get(&p("gate")), &mut gate);
                    matmul_bt(&h, params.get(&p("up")), &mut mlp);
                    for (m, g) in mlp.data.iter_mut().zip(gate.data.iter()) {
                        *m *= silu(*g);
                    }
                }
            }
            let mut down = Mat::zeros(t, d);
            matmul_bt(&mlp, params.get(&p("down")), &mut down);
            for i in 0..x.data.len() {
                x.data[i] += down.data[i];
            }
        }
        x
    }

    /// Mean cross-entropy of next-token prediction over a token sequence.
    pub fn loss(&self, params: &Params, tokens: &[usize]) -> f64 {
        assert!(tokens.len() >= 2);
        let logits = self.forward(params, &tokens[..tokens.len() - 1]);
        let mut total = 0f64;
        let n = logits.rows;
        for i in 0..n {
            let row = logits.row(i);
            let target = tokens[i + 1];
            let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let lse: f32 = row.iter().map(|&x| (x - mx).exp()).sum::<f32>().ln() + mx;
            total += (lse - row[target]) as f64;
        }
        total / n as f64
    }
}

/// One row of causal attention at absolute position `pos`: score `q_row`
/// against cached positions `0..=pos` per head, softmax, and accumulate the
/// attended values into `out` (a d_model slice) through the storage's fused
/// hooks. j-outer so each attended position's row resolves (or decodes)
/// once; per-element adds stay in ascending-j order, bit-identical to the
/// e-outer form. This is THE per-row attention kernel — both
/// [`Transformer::prefill_chunk`] (via `prefill_hidden`) and
/// [`Transformer::decode_wave`] call it, so per-sequence and batched decode
/// share the attention arithmetic by construction.
#[allow(clippy::too_many_arguments)]
fn attend_row<C: KvStorage>(
    cache: &C,
    layer: usize,
    pos: usize,
    q_row: &[f32],
    out: &mut [f32],
    n_head: usize,
    hd: usize,
    scale: f32,
) {
    for head in 0..n_head {
        let qh = &q_row[head * hd..(head + 1) * hd];
        let mut scores = Mat::zeros(1, pos + 1);
        for j in 0..=pos {
            *scores.at_mut(0, j) = cache.dot_k(layer, j, head * hd, qh) * scale;
        }
        softmax_rows(&mut scores, None);
        let ar = &mut out[head * hd..(head + 1) * hd];
        for j in 0..=pos {
            cache.axpy_v(layer, j, head * hd, scores.at(0, j), ar);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny(arch: Arch) -> (Transformer, Params) {
        let cfg = ModelConfig { n_layer: 2, d_model: 32, n_head: 2, d_ff: 64, vocab: 50, seq_len: 16, arch };
        let t = Transformer::new(cfg);
        let p = t.init_params(1);
        (t, p)
    }

    #[test]
    fn forward_shapes_gpt2() {
        let (t, p) = tiny(Arch::Gpt2);
        let logits = t.forward(&p, &[1, 2, 3, 4]);
        assert_eq!((logits.rows, logits.cols), (4, 50));
        assert!(logits.data.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn forward_shapes_llama2() {
        let (t, p) = tiny(Arch::Llama2);
        let logits = t.forward(&p, &[5, 6, 7]);
        assert_eq!((logits.rows, logits.cols), (3, 50));
        assert!(logits.data.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn causality_past_tokens_only() {
        // changing a future token must not change earlier logits
        let (t, p) = tiny(Arch::Gpt2);
        let a = t.forward(&p, &[1, 2, 3, 4]);
        let b = t.forward(&p, &[1, 2, 3, 9]);
        for c in 0..50 {
            assert_eq!(a.at(0, c), b.at(0, c));
            assert_eq!(a.at(2, c), b.at(2, c));
        }
        assert_ne!(a.row(3), b.row(3));
    }

    #[test]
    fn loss_near_log_vocab_at_init() {
        for arch in [Arch::Gpt2, Arch::Llama2] {
            let (t, p) = tiny(arch);
            let toks: Vec<usize> = (0..16).map(|i| (i * 7 + 3) % 50).collect();
            let loss = t.loss(&p, &toks);
            let expect = (50f64).ln();
            assert!((loss - expect).abs() < 1.0, "{arch:?}: loss={loss} vs ln(V)={expect}");
        }
    }

    #[test]
    fn params_count_in_expected_range() {
        let (t, p) = tiny(Arch::Gpt2);
        let approx = t.cfg.param_count();
        let exact = p.param_count();
        // approx excludes norms/pos-embed; within 30%
        assert!((exact as f64) < approx as f64 * 1.5);
        assert!((exact as f64) > approx as f64 * 0.9);
    }

    #[test]
    fn linear_name_enumeration() {
        let cfg = ModelConfig::tiny(Arch::Llama2);
        let names = Params::linear_names(&cfg);
        assert_eq!(names.len(), 2 * 7);
        assert_eq!(names[0], "blk0.q");
        assert_eq!(names[13], "blk1.up");
    }

    #[test]
    fn shapes_match_init_params_exactly() {
        for arch in [Arch::Gpt2, Arch::Llama2] {
            let (t, p) = tiny(arch);
            let shapes = Transformer::shapes(&t.cfg);
            assert_eq!(shapes.len(), p.tensors.len(), "{arch:?}: tensor count");
            for (name, m) in &p.tensors {
                let &(r, c) = shapes.get(name).unwrap_or_else(|| panic!("missing {name}"));
                assert_eq!((r, c), (m.rows, m.cols), "{arch:?}: {name}");
            }
        }
    }

    #[test]
    fn decode_step_matches_full_forward() {
        // every decode position must reproduce the train-shaped forward's
        // logits row for the same prefix — the KV-cache correctness claim
        for arch in [Arch::Gpt2, Arch::Llama2] {
            let (t, p) = tiny(arch);
            let tokens = [3usize, 17, 42, 5, 11, 29];
            let full = t.forward(&p, &tokens);
            let mut cache = DecodeCache::new(&t.cfg, tokens.len());
            for (i, &tok) in tokens.iter().enumerate() {
                let logits = t.decode_step(&p, tok, &mut cache);
                assert_eq!(cache.len, i + 1);
                assert_eq!(logits.len(), t.cfg.vocab);
                for (c, &got) in logits.iter().enumerate() {
                    let want = full.at(i, c);
                    assert!(
                        (got - want).abs() <= 1e-4 * (1.0 + want.abs()),
                        "{arch:?} pos {i} col {c}: {got} vs {want}"
                    );
                }
            }
        }
    }

    #[test]
    fn prefill_chunk_matches_token_by_token_bit_for_bit() {
        // chunked prefill must be a pure wave-amortization: same cache
        // contents, same final logits as feeding one token at a time
        for arch in [Arch::Gpt2, Arch::Llama2] {
            let (t, p) = tiny(arch);
            let tokens = [3usize, 17, 42, 5, 11, 29, 7];
            let mut ref_cache = DecodeCache::new(&t.cfg, 16);
            let mut ref_logits = Vec::new();
            for &tok in &tokens {
                ref_logits = t.decode_step(&p, tok, &mut ref_cache);
            }
            for chunk in [2usize, 3, 7] {
                let mut cache = DecodeCache::new(&t.cfg, 16);
                let mut logits = Vec::new();
                for part in tokens.chunks(chunk) {
                    logits = t.prefill_chunk(&p, part, &mut cache);
                }
                assert_eq!(logits, ref_logits, "{arch:?} chunk {chunk}: logits diverge");
                assert_eq!(cache.len, ref_cache.len);
                for l in 0..t.cfg.n_layer {
                    assert_eq!(cache.k[l].data, ref_cache.k[l].data, "{arch:?} chunk {chunk} K{l}");
                    assert_eq!(cache.v[l].data, ref_cache.v[l].data, "{arch:?} chunk {chunk} V{l}");
                }
            }
        }
    }

    #[test]
    fn prefill_chunk_logits_rows_match_stepwise_decode_bit_for_bit() {
        // the speculative-verify contract: row i of the all-rows variant
        // must be the exact logits the engine would have sampled had
        // tokens[..=i] been fed through ordinary sequential decode
        for arch in [Arch::Gpt2, Arch::Llama2] {
            let (t, p) = tiny(arch);
            let tokens = [3usize, 17, 42, 5, 11, 29, 7];
            let mut ref_cache = DecodeCache::new(&t.cfg, 16);
            let mut ref_rows: Vec<Vec<f32>> = Vec::new();
            for &tok in &tokens {
                ref_rows.push(t.decode_step(&p, tok, &mut ref_cache));
            }
            // whole sequence in one all-rows wave
            let mut cache = DecodeCache::new(&t.cfg, 16);
            let all = t.prefill_chunk_logits(&p, &tokens, &mut cache);
            assert_eq!((all.rows, all.cols), (tokens.len(), t.cfg.vocab));
            for (i, want) in ref_rows.iter().enumerate() {
                assert_eq!(all.row(i), &want[..], "{arch:?}: row {i} diverges");
            }
            assert_eq!(cache.len, ref_cache.len);
            // split waves: a committed prefix then an all-rows tail, the
            // shape the verify wave actually runs in
            let mut cache = DecodeCache::new(&t.cfg, 16);
            t.prefill_chunk(&p, &tokens[..3], &mut cache);
            let tail = t.prefill_chunk_logits(&p, &tokens[3..], &mut cache);
            for (i, want) in ref_rows.iter().enumerate().skip(3) {
                assert_eq!(tail.row(i - 3), &want[..], "{arch:?}: tail row {i} diverges");
            }
            for l in 0..t.cfg.n_layer {
                assert_eq!(cache.k[l].data, ref_cache.k[l].data, "{arch:?} K{l}");
                assert_eq!(cache.v[l].data, ref_cache.v[l].data, "{arch:?} V{l}");
            }
        }
    }

    #[test]
    fn paged_decode_bit_identical_to_contiguous() {
        use crate::nn::kv::PagedKv;
        for arch in [Arch::Gpt2, Arch::Llama2] {
            let (t, p) = tiny(arch);
            let tokens = [9usize, 1, 30, 44, 2];
            let mut contiguous = DecodeCache::new(&t.cfg, tokens.len());
            let mut paged = PagedKv::new(&t.cfg, 2, tokens.len());
            for &tok in &tokens {
                let a = t.decode_step(&p, tok, &mut contiguous);
                let b = t.decode_step(&p, tok, &mut paged);
                assert_eq!(a, b, "{arch:?}: paged logits diverge from contiguous");
            }
            assert_eq!(paged.n_blocks(), 3, "5 positions at block 2");
        }
    }

    #[test]
    fn decode_wave_single_sequence_equals_decode_step() {
        // n=1 wave over a contiguous cache == decode_step, bit for bit
        for arch in [Arch::Gpt2, Arch::Llama2] {
            let (t, p) = tiny(arch);
            let mut solo = DecodeCache::new(&t.cfg, 16);
            let mut wave = DecodeCache::new(&t.cfg, 16);
            for &tok in &[9usize, 1, 30, 44, 2] {
                let want = t.decode_step(&p, tok, &mut solo);
                let mut refs = [&mut wave];
                let got = t.decode_wave(&p, &[tok], &mut refs, 1);
                assert_eq!((got.rows, got.cols), (1, t.cfg.vocab));
                assert_eq!(got.row(0), &want[..], "{arch:?}: n=1 wave diverges");
            }
            assert_eq!(wave.len, solo.len);
        }
    }

    #[test]
    fn decode_wave_is_bit_identical_to_per_sequence_decode() {
        // the weight-stationary batched wave must be a pure traffic
        // optimization: for any batch size, prefix stagger, thread count
        // and KV quantization scheme, row s of decode_wave equals the
        // decode_step logits of sequence s run alone — and leaves the
        // caches in identical states (checked by continuing for rounds)
        use crate::nn::kv::{KvQuant, PagedKv};
        use crate::testing::prop::check;
        for arch in [Arch::Gpt2, Arch::Llama2] {
            let (t, p) = tiny(arch);
            let labels = ["f32", "fp8_e3m4", "int8_sr", "fp4_e2m1_sr"];
            check("decode_wave == per-seq decode_step", 6, |g| {
                let n = g.usize_in(1, 5);
                let label = *g.choose(&labels);
                let threads = g.usize_in(1, 3);
                let seed = g.u64();
                let mk = || {
                    let q = KvQuant::new(
                        crate::quant::resolve(label).unwrap(),
                        t.cfg.d_model,
                        seed,
                    )
                    .unwrap();
                    PagedKv::new_quantized(&t.cfg, 4, t.cfg.seq_len, q)
                };
                let mut wave: Vec<PagedKv> = (0..n).map(|_| mk()).collect();
                let mut solo: Vec<PagedKv> = (0..n).map(|_| mk()).collect();
                // stagger every sequence to its own position via a random
                // prefix fed identically into both cache sets
                for s in 0..n {
                    let plen = g.usize_in(1, 6);
                    let prefix: Vec<usize> =
                        (0..plen).map(|_| g.usize_in(0, t.cfg.vocab - 1)).collect();
                    t.prefill_chunk(&p, &prefix, &mut wave[s]);
                    t.prefill_chunk(&p, &prefix, &mut solo[s]);
                }
                for round in 0..g.usize_in(1, 3) {
                    let tokens: Vec<usize> =
                        (0..n).map(|_| g.usize_in(0, t.cfg.vocab - 1)).collect();
                    let mut refs: Vec<&mut PagedKv> = wave.iter_mut().collect();
                    let logits = t.decode_wave(&p, &tokens, &mut refs, threads);
                    for s in 0..n {
                        let want = t.decode_step(&p, tokens[s], &mut solo[s]);
                        if logits.row(s) != &want[..] {
                            return Err(format!(
                                "{arch:?} {label} n={n} threads={threads} \
                                 round {round} seq {s}: wave logits diverge"
                            ));
                        }
                    }
                }
                Ok(())
            });
        }
    }

    #[test]
    fn decode_cache_reset_reuses_slot() {
        let (t, p) = tiny(Arch::Gpt2);
        let mut cache = DecodeCache::new(&t.cfg, 8);
        let a: Vec<f32> = t.decode_step(&p, 7, &mut cache);
        t.decode_step(&p, 9, &mut cache);
        cache.reset();
        assert_eq!(cache.len, 0);
        let b = t.decode_step(&p, 7, &mut cache);
        assert_eq!(a, b, "slot reuse must be state-free after reset");
        assert!(cache.bytes() > 0);
    }

    #[test]
    fn quantized_params_still_produce_finite_loss() {
        use crate::quant::QuantScheme;
        let scheme = crate::quant::resolve("fp8_e3m4").unwrap();
        let (t, mut p) = tiny(Arch::Gpt2);
        let names = Params::linear_names(&t.cfg);
        for n in names {
            let m = p.get_mut(&n);
            let w64: Vec<f64> = m.data.iter().map(|&x| x as f64).collect();
            let q = scheme.quantize(&w64, m.rows, m.cols, 0);
            for (dst, &src) in m.data.iter_mut().zip(q.data.iter()) {
                *dst = src as f32;
            }
        }
        let toks: Vec<usize> = (0..16).map(|i| (i * 11 + 1) % 50).collect();
        let loss = t.loss(&p, &toks);
        assert!(loss.is_finite());
    }
}
