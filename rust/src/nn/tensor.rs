//! Minimal row-major f32 tensor ops for the rust inference path
//! (fake-quantized evaluation, Table C.1 validation) and L3 benchmarks.
//!
//! Training math runs in the AOT-compiled HLO (L2); this module only needs
//! forward-pass ops, so it stays small and predictable. The matmul is
//! cache-blocked with a transposed-B inner kernel — enough to evaluate
//! multi-million-parameter models in seconds on the 1-core testbed.

/// 2-D row-major matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Mat {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data }
    }

    #[inline(always)]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline(always)]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        &mut self.data[r * self.cols + c]
    }

    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.data[c * self.rows + r] = self.at(r, c);
            }
        }
        out
    }
}

/// `C = A · B` where `A` is (m,k) and `b_t` is **B transposed** (n,k).
/// Transposing B makes both inner loops unit-stride.
pub fn matmul_bt(a: &Mat, b_t: &Mat, out: &mut Mat) {
    let (m, k, n) = (a.rows, a.cols, b_t.rows);
    assert_eq!(b_t.cols, k);
    assert_eq!((out.rows, out.cols), (m, n));
    for i in 0..m {
        let ar = a.row(i);
        let or = &mut out.data[i * n..(i + 1) * n];
        for j in 0..n {
            let br = b_t.row(j);
            let mut acc = 0f32;
            // the compiler vectorizes this reliably
            for (x, y) in ar.iter().zip(br.iter()) {
                acc += x * y;
            }
            or[j] = acc;
        }
    }
}

/// `C = A · B` with B in natural (k,n) layout (transposes internally).
pub fn matmul(a: &Mat, b: &Mat, out: &mut Mat) {
    let bt = b.transpose();
    matmul_bt(a, &bt, out);
}

/// In-place row-wise softmax with max-subtraction, optionally causal
/// (row `i` attends to columns `0..=i+offset`).
pub fn softmax_rows(x: &mut Mat, causal_offset: Option<usize>) {
    for r in 0..x.rows {
        let limit = match causal_offset {
            Some(off) => (r + off + 1).min(x.cols),
            None => x.cols,
        };
        let row = &mut x.data[r * x.cols..(r + 1) * x.cols];
        let mx = row[..limit].iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0f32;
        for v in row[..limit].iter_mut() {
            *v = (*v - mx).exp();
            sum += *v;
        }
        let inv = 1.0 / sum;
        for v in row[..limit].iter_mut() {
            *v *= inv;
        }
        for v in row[limit..].iter_mut() {
            *v = 0.0;
        }
    }
}

/// GELU (tanh approximation, as in GPT2).
#[inline]
pub fn gelu(x: f32) -> f32 {
    0.5 * x * (1.0 + ((0.7978845608 * (x + 0.044715 * x * x * x)) as f64).tanh() as f32)
}

/// SiLU (swish), used by Llama's SwiGLU MLP.
#[inline]
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// LayerNorm over the last dim with learned gain/bias.
pub fn layer_norm(x: &mut Mat, gain: &[f32], bias: &[f32], eps: f32) {
    assert_eq!(gain.len(), x.cols);
    assert_eq!(bias.len(), x.cols);
    for r in 0..x.rows {
        let row = &mut x.data[r * x.cols..(r + 1) * x.cols];
        let mean = row.iter().sum::<f32>() / row.len() as f32;
        let var = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / row.len() as f32;
        let inv = 1.0 / (var + eps).sqrt();
        for (i, v) in row.iter_mut().enumerate() {
            *v = (*v - mean) * inv * gain[i] + bias[i];
        }
    }
}

/// RMSNorm over the last dim with learned gain (Llama-style).
pub fn rms_norm(x: &mut Mat, gain: &[f32], eps: f32) {
    assert_eq!(gain.len(), x.cols);
    for r in 0..x.rows {
        let row = &mut x.data[r * x.cols..(r + 1) * x.cols];
        let ms = row.iter().map(|v| v * v).sum::<f32>() / row.len() as f32;
        let inv = 1.0 / (ms + eps).sqrt();
        for (i, v) in row.iter_mut().enumerate() {
            *v = *v * inv * gain[i];
        }
    }
}

/// Rotary position embedding applied in-place to a (seq, d) matrix where
/// consecutive pairs (2i, 2i+1) rotate with angle `pos / theta^(2i/d)`.
pub fn rope(x: &mut Mat, theta: f32) {
    let d = x.cols;
    for pos in 0..x.rows {
        rope_row(&mut x.data[pos * d..(pos + 1) * d], pos, theta);
    }
}

/// Rotary embedding for one head-dim row at absolute position `pos` — the
/// incremental-decode form of [`rope`] (identical math for a single row).
pub fn rope_row(row: &mut [f32], pos: usize, theta: f32) {
    let d = row.len();
    let mut i = 0;
    while i + 1 < d {
        let freq = 1.0 / theta.powf(i as f32 / d as f32);
        let ang = pos as f32 * freq;
        let (sin, cos) = ang.sin_cos();
        let (a, b) = (row[i], row[i + 1]);
        row[i] = a * cos - b * sin;
        row[i + 1] = a * sin + b * cos;
        i += 2;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small_known() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let mut c = Mat::zeros(2, 2);
        matmul(&a, &b, &mut c);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_matches_naive() {
        use crate::testing::prop::{check, Gen};
        check("matmul vs naive", 10, |g: &mut Gen| {
            let (m, k, n) = (g.usize_in(1, 17), g.usize_in(1, 23), g.usize_in(1, 13));
            let a = Mat::from_vec(m, k, g.normal_vec_f32(m * k));
            let b = Mat::from_vec(k, n, g.normal_vec_f32(k * n));
            let mut c = Mat::zeros(m, n);
            matmul(&a, &b, &mut c);
            for i in 0..m {
                for j in 0..n {
                    let mut acc = 0f64;
                    for t in 0..k {
                        acc += a.at(i, t) as f64 * b.at(t, j) as f64;
                    }
                    if (acc as f32 - c.at(i, j)).abs() > 1e-3 {
                        return Err(format!("({i},{j}): {} vs {}", acc, c.at(i, j)));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut x = Mat::from_vec(2, 3, vec![1.0, 2.0, 3.0, -1.0, 0.0, 1.0]);
        softmax_rows(&mut x, None);
        for r in 0..2 {
            let s: f32 = x.row(r).iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn causal_softmax_masks_future() {
        let mut x = Mat::from_vec(3, 3, vec![1.0; 9]);
        softmax_rows(&mut x, Some(0));
        assert_eq!(x.row(0), &[1.0, 0.0, 0.0]);
        assert!((x.at(1, 0) - 0.5).abs() < 1e-6);
        assert_eq!(x.at(1, 2), 0.0);
    }

    #[test]
    fn layer_norm_zero_mean_unit_var() {
        let mut x = Mat::from_vec(1, 4, vec![1.0, 2.0, 3.0, 4.0]);
        let gain = vec![1.0; 4];
        let bias = vec![0.0; 4];
        layer_norm(&mut x, &gain, &bias, 1e-5);
        let mean: f32 = x.row(0).iter().sum::<f32>() / 4.0;
        let var: f32 = x.row(0).iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 4.0;
        assert!(mean.abs() < 1e-6);
        assert!((var - 1.0).abs() < 1e-3);
    }

    #[test]
    fn rms_norm_unit_rms() {
        let mut x = Mat::from_vec(1, 4, vec![2.0, -2.0, 2.0, -2.0]);
        rms_norm(&mut x, &[1.0; 4], 1e-6);
        let ms: f32 = x.row(0).iter().map(|v| v * v).sum::<f32>() / 4.0;
        assert!((ms - 1.0).abs() < 1e-4);
    }

    #[test]
    fn rope_preserves_pair_norms() {
        let mut x = Mat::from_vec(3, 4, (0..12).map(|i| i as f32).collect());
        let orig = x.clone();
        rope(&mut x, 10000.0);
        for r in 0..3 {
            for p in 0..2 {
                let n0 = orig.at(r, 2 * p).hypot(orig.at(r, 2 * p + 1));
                let n1 = x.at(r, 2 * p).hypot(x.at(r, 2 * p + 1));
                assert!((n0 - n1).abs() < 1e-4);
            }
        }
        // position 0 is unrotated
        assert_eq!(x.row(0), orig.row(0));
    }

    #[test]
    fn rope_row_matches_full_rope() {
        let mut x = Mat::from_vec(5, 6, (0..30).map(|i| (i as f32).sin()).collect());
        let rows: Vec<Vec<f32>> = (0..5).map(|r| x.row(r).to_vec()).collect();
        rope(&mut x, 10000.0);
        for (pos, mut row) in rows.into_iter().enumerate() {
            rope_row(&mut row, pos, 10000.0);
            assert_eq!(&row[..], x.row(pos), "pos {pos}");
        }
    }

    #[test]
    fn activations_reference_values() {
        assert!((gelu(0.0)).abs() < 1e-7);
        assert!((gelu(1.0) - 0.8412).abs() < 1e-3);
        assert!((silu(0.0)).abs() < 1e-7);
        assert!((silu(1.0) - 0.7311).abs() < 1e-3);
    }
}
