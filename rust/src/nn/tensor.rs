//! Minimal row-major f32 tensor ops for the rust inference path
//! (fake-quantized evaluation, Table C.1 validation) and L3 benchmarks.
//!
//! Training math runs in the AOT-compiled HLO (L2); this module only needs
//! forward-pass ops, so it stays small and predictable. The matmul is
//! cache-blocked with a transposed-B inner kernel — enough to evaluate
//! multi-million-parameter models in seconds on the 1-core testbed.

/// 2-D row-major matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Mat {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data }
    }

    #[inline(always)]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline(always)]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        &mut self.data[r * self.cols + c]
    }

    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.data[c * self.rows + r] = self.at(r, c);
            }
        }
        out
    }
}

/// Output-row panel height for the blocked [`matmul_bt`] kernel: enough A
/// rows to amortize each streamed B panel, few enough that the panel of
/// partial C rows stays resident.
const GEMM_TILE_M: usize = 8;
/// Output-column panel width (B rows per panel): `GEMM_TILE_N` rows of B
/// at typical depths fit in L1/L2, so a panel loaded for A row 0 is still
/// hot for rows 1..GEMM_TILE_M.
const GEMM_TILE_N: usize = 64;

/// `C = A · B` where `A` is (m,k) and `b_t` is **B transposed** (n,k).
/// Transposing B makes both inner loops unit-stride.
///
/// The (i, j) output space is walked in `GEMM_TILE_M` × `GEMM_TILE_N`
/// panels so each panel of B rows is reused across a panel of A rows
/// instead of being re-streamed from memory per row. Tiling only reorders
/// *which* output cell is computed next — every `C[i][j]` is still one
/// full-depth ascending-k accumulation written exactly once — so the
/// result is bit-identical to the untiled kernel (the invariant the
/// weight-stationary decode wave relies on).
pub fn matmul_bt(a: &Mat, b_t: &Mat, out: &mut Mat) {
    matmul_bt_panel(a, b_t, 0, b_t.rows, out)
}

///// [`matmul_bt`] against a row panel of `b_t`: `C = A · B[b_row0 ..
/// b_row0+n]ᵀ`, with `out` sized (a.rows × n). This is how a fused-weight
/// matrix is consumed in slices — e.g. the GPT-2 qkv weight (3d × d) is
/// read as three d-row panels producing q, k and v directly, with no
/// (t × 3d) intermediate and no row-copy split. Each output cell is the
/// same full-depth ascending-k dot against the same weight row as the
/// full-matrix call, so panel results are bit-identical to slicing the
/// full product.
pub fn matmul_bt_panel(a: &Mat, b_t: &Mat, b_row0: usize, n: usize, out: &mut Mat) {
    let (m, k) = (a.rows, a.cols);
    assert_eq!(b_t.cols, k);
    assert!(
        b_row0 + n <= b_t.rows,
        "panel rows [{b_row0}, {}) out of range {}",
        b_row0 + n,
        b_t.rows
    );
    assert_eq!((out.rows, out.cols), (m, n));
    let mut j0 = 0;
    while j0 < n {
        let j1 = (j0 + GEMM_TILE_N).min(n);
        let mut i0 = 0;
        while i0 < m {
            let i1 = (i0 + GEMM_TILE_M).min(m);
            for i in i0..i1 {
                let ar = a.row(i);
                let or = &mut out.data[i * n..(i + 1) * n];
                for j in j0..j1 {
                    let br = b_t.row(b_row0 + j);
                    let mut acc = 0f32;
                    // the compiler vectorizes this reliably
                    for (x, y) in ar.iter().zip(br.iter()) {
                        acc += x * y;
                    }
                    or[j] = acc;
                }
            }
            i0 = i1;
        }
        j0 = j1;
    }
}

/// In-place row-wise softmax with max-subtraction, optionally causal
/// (row `i` attends to columns `0..=i+offset`).
pub fn softmax_rows(x: &mut Mat, causal_offset: Option<usize>) {
    for r in 0..x.rows {
        let limit = match causal_offset {
            Some(off) => (r + off + 1).min(x.cols),
            None => x.cols,
        };
        let row = &mut x.data[r * x.cols..(r + 1) * x.cols];
        let mx = row[..limit].iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0f32;
        for v in row[..limit].iter_mut() {
            *v = (*v - mx).exp();
            sum += *v;
        }
        let inv = 1.0 / sum;
        for v in row[..limit].iter_mut() {
            *v *= inv;
        }
        for v in row[limit..].iter_mut() {
            *v = 0.0;
        }
    }
}

/// GELU (tanh approximation, as in GPT2).
#[inline]
pub fn gelu(x: f32) -> f32 {
    0.5 * x * (1.0 + ((0.7978845608 * (x + 0.044715 * x * x * x)) as f64).tanh() as f32)
}

/// SiLU (swish), used by Llama's SwiGLU MLP.
#[inline]
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// LayerNorm over the last dim with learned gain/bias.
pub fn layer_norm(x: &mut Mat, gain: &[f32], bias: &[f32], eps: f32) {
    assert_eq!(gain.len(), x.cols);
    assert_eq!(bias.len(), x.cols);
    for r in 0..x.rows {
        let row = &mut x.data[r * x.cols..(r + 1) * x.cols];
        let mean = row.iter().sum::<f32>() / row.len() as f32;
        let var = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / row.len() as f32;
        let inv = 1.0 / (var + eps).sqrt();
        for (i, v) in row.iter_mut().enumerate() {
            *v = (*v - mean) * inv * gain[i] + bias[i];
        }
    }
}

/// RMSNorm over the last dim with learned gain (Llama-style).
pub fn rms_norm(x: &mut Mat, gain: &[f32], eps: f32) {
    assert_eq!(gain.len(), x.cols);
    for r in 0..x.rows {
        let row = &mut x.data[r * x.cols..(r + 1) * x.cols];
        let ms = row.iter().map(|v| v * v).sum::<f32>() / row.len() as f32;
        let inv = 1.0 / (ms + eps).sqrt();
        for (i, v) in row.iter_mut().enumerate() {
            *v = *v * inv * gain[i];
        }
    }
}

/// Rotary position embedding applied in-place to a (seq, d) matrix where
/// consecutive pairs (2i, 2i+1) rotate with angle `pos / theta^(2i/d)`.
pub fn rope(x: &mut Mat, theta: f32) {
    let d = x.cols;
    for pos in 0..x.rows {
        rope_row(&mut x.data[pos * d..(pos + 1) * d], pos, theta);
    }
}

/// Rotary embedding for one head-dim row at absolute position `pos` — the
/// incremental-decode form of [`rope`] (identical math for a single row).
pub fn rope_row(row: &mut [f32], pos: usize, theta: f32) {
    let d = row.len();
    let mut i = 0;
    while i + 1 < d {
        let freq = 1.0 / theta.powf(i as f32 / d as f32);
        let ang = pos as f32 * freq;
        let (sin, cos) = ang.sin_cos();
        let (a, b) = (row[i], row[i + 1]);
        row[i] = a * cos - b * sin;
        row[i + 1] = a * sin + b * cos;
        i += 2;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small_known() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let bt = b.transpose();
        let mut c = Mat::zeros(2, 2);
        matmul_bt(&a, &bt, &mut c);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_matches_naive() {
        use crate::testing::prop::{check, Gen};
        check("matmul vs naive", 10, |g: &mut Gen| {
            let (m, k, n) = (g.usize_in(1, 17), g.usize_in(1, 23), g.usize_in(1, 13));
            let a = Mat::from_vec(m, k, g.normal_vec_f32(m * k));
            let b = Mat::from_vec(k, n, g.normal_vec_f32(k * n));
            let bt = b.transpose();
            let mut c = Mat::zeros(m, n);
            matmul_bt(&a, &bt, &mut c);
            for i in 0..m {
                for j in 0..n {
                    let mut acc = 0f64;
                    for t in 0..k {
                        acc += a.at(i, t) as f64 * b.at(t, j) as f64;
                    }
                    if (acc as f32 - c.at(i, j)).abs() > 1e-3 {
                        return Err(format!("({i},{j}): {} vs {}", acc, c.at(i, j)));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn tiled_matmul_bt_is_bit_identical_to_untiled() {
        // the blocked kernel must not just be close — every output cell is
        // one full-depth ascending-k f32 accumulation, so it must equal the
        // unblocked row-major walk bit-for-bit across shapes that land on
        // every tile-boundary phase (including shapes smaller than a tile
        // and shapes spanning several panels in both dimensions)
        use crate::testing::prop::{check, Gen};
        check("tiled matmul_bt == untiled, bit-for-bit", 20, |g: &mut Gen| {
            let m = g.usize_in(1, 3 * GEMM_TILE_M + 1);
            let k = g.usize_in(1, 48);
            let n = g.usize_in(1, 2 * GEMM_TILE_N + 3);
            let a = Mat::from_vec(m, k, g.normal_vec_f32(m * k));
            let bt = Mat::from_vec(n, k, g.normal_vec_f32(n * k));
            let mut c = Mat::zeros(m, n);
            matmul_bt(&a, &bt, &mut c);
            for i in 0..m {
                for j in 0..n {
                    let mut acc = 0f32;
                    for (x, y) in a.row(i).iter().zip(bt.row(j).iter()) {
                        acc += x * y;
                    }
                    if acc.to_bits() != c.at(i, j).to_bits() {
                        return Err(format!(
                            "({m},{k},{n}) cell ({i},{j}): {acc} vs {} (bits differ)",
                            c.at(i, j)
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn matmul_bt_panel_equals_column_slice_of_full_product() {
        // consuming a fused weight as row panels (the GPT-2 qkv split)
        // must reproduce the corresponding column slice of the full
        // product bit-for-bit, including panels that start mid-matrix
        use crate::testing::prop::{check, Gen};
        check("matmul_bt_panel == full-product slice", 20, |g: &mut Gen| {
            let m = g.usize_in(1, 2 * GEMM_TILE_M + 1);
            let k = g.usize_in(1, 32);
            let rows = g.usize_in(2, GEMM_TILE_N + 9);
            let a = Mat::from_vec(m, k, g.normal_vec_f32(m * k));
            let bt = Mat::from_vec(rows, k, g.normal_vec_f32(rows * k));
            let mut full = Mat::zeros(m, rows);
            matmul_bt(&a, &bt, &mut full);
            let b_row0 = g.usize_in(0, rows - 1);
            let n = g.usize_in(1, rows - b_row0);
            let mut panel = Mat::zeros(m, n);
            matmul_bt_panel(&a, &bt, b_row0, n, &mut panel);
            for i in 0..m {
                for j in 0..n {
                    if panel.at(i, j).to_bits() != full.at(i, b_row0 + j).to_bits() {
                        return Err(format!(
                            "({m},{k},{rows}) panel [{b_row0},{}) cell ({i},{j}): \
                             {} vs {} (bits differ)",
                            b_row0 + n,
                            panel.at(i, j),
                            full.at(i, b_row0 + j)
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut x = Mat::from_vec(2, 3, vec![1.0, 2.0, 3.0, -1.0, 0.0, 1.0]);
        softmax_rows(&mut x, None);
        for r in 0..2 {
            let s: f32 = x.row(r).iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn causal_softmax_masks_future() {
        let mut x = Mat::from_vec(3, 3, vec![1.0; 9]);
        softmax_rows(&mut x, Some(0));
        assert_eq!(x.row(0), &[1.0, 0.0, 0.0]);
        assert!((x.at(1, 0) - 0.5).abs() < 1e-6);
        assert_eq!(x.at(1, 2), 0.0);
    }

    #[test]
    fn layer_norm_zero_mean_unit_var() {
        let mut x = Mat::from_vec(1, 4, vec![1.0, 2.0, 3.0, 4.0]);
        let gain = vec![1.0; 4];
        let bias = vec![0.0; 4];
        layer_norm(&mut x, &gain, &bias, 1e-5);
        let mean: f32 = x.row(0).iter().sum::<f32>() / 4.0;
        let var: f32 = x.row(0).iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 4.0;
        assert!(mean.abs() < 1e-6);
        assert!((var - 1.0).abs() < 1e-3);
    }

    #[test]
    fn rms_norm_unit_rms() {
        let mut x = Mat::from_vec(1, 4, vec![2.0, -2.0, 2.0, -2.0]);
        rms_norm(&mut x, &[1.0; 4], 1e-6);
        let ms: f32 = x.row(0).iter().map(|v| v * v).sum::<f32>() / 4.0;
        assert!((ms - 1.0).abs() < 1e-4);
    }

    #[test]
    fn rope_preserves_pair_norms() {
        let mut x = Mat::from_vec(3, 4, (0..12).map(|i| i as f32).collect());
        let orig = x.clone();
        rope(&mut x, 10000.0);
        for r in 0..3 {
            for p in 0..2 {
                let n0 = orig.at(r, 2 * p).hypot(orig.at(r, 2 * p + 1));
                let n1 = x.at(r, 2 * p).hypot(x.at(r, 2 * p + 1));
                assert!((n0 - n1).abs() < 1e-4);
            }
        }
        // position 0 is unrotated
        assert_eq!(x.row(0), orig.row(0));
    }

    #[test]
    fn rope_row_matches_full_rope() {
        let mut x = Mat::from_vec(5, 6, (0..30).map(|i| (i as f32).sin()).collect());
        let rows: Vec<Vec<f32>> = (0..5).map(|r| x.row(r).to_vec()).collect();
        rope(&mut x, 10000.0);
        for (pos, mut row) in rows.into_iter().enumerate() {
            rope_row(&mut row, pos, 10000.0);
            assert_eq!(&row[..], x.row(pos), "pos {pos}");
        }
    }

    #[test]
    fn activations_reference_values() {
        assert!((gelu(0.0)).abs() < 1e-7);
        assert!((gelu(1.0) - 0.8412).abs() < 1e-3);
        assert!((silu(0.0)).abs() < 1e-7);
        assert!((silu(1.0) - 0.7311).abs() < 1e-3);
    }
}
