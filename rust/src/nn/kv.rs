//! KV-cache storage substrate for incremental decode: the [`KvStorage`]
//! trait abstracts *where* per-position K/V rows live so the transformer's
//! decode math ([`crate::nn::transformer::Transformer::decode_step`] /
//! `prefill_chunk`) is storage-agnostic.
//!
//! Two implementations:
//!
//! * [`crate::nn::transformer::DecodeCache`] — one contiguous
//!   (capacity × d_model) K and V matrix per layer. Simple, exactly the
//!   PR-1 layout; used by training-side eval and standalone decode.
//! * [`PagedKv`] — the serving layout: positions are grouped into
//!   fixed-size [`KvBlock`]s (e.g. 16 positions each, all layers) chained
//!   through a per-sequence block table. Blocks are `Arc`-shared, so
//!   identical prompt prefixes across requests can reference the *same*
//!   physical block (cross-request prefix caching) and a sequence only
//!   ever writes blocks it holds exclusively — the serve-side
//!   [`crate::serve::kvcache::BlockAllocator`] copy-on-writes a shared
//!   tail before any append.
//!
//! The paged layout exists for memory, not math: a contiguous cache
//! reserves `capacity` positions per sequence up front regardless of how
//! many a request actually uses, while paged allocation grows a sequence
//! block-by-block, so arena admission can be bounded by *blocks actually
//! in use*. Decode results are bit-identical between the two (see
//! `tests/paged_suite.rs`).

use crate::config::schema::ModelConfig;
use std::sync::Arc;

/// One fixed-size position block: the K and V rows of `block_size`
/// consecutive sequence positions for *every* layer, laid out layer-major
/// (`(layer * block_size + slot) * d_model`). This is the unit of KV-cache
/// allocation, sharing, and copy-on-write in the serve layer.
#[derive(Debug, Clone, PartialEq)]
pub struct KvBlock {
    /// Arena identity (block-table entry). Standalone [`PagedKv`]s number
    /// their private blocks 0..; the serve arena assigns global ids.
    pub id: u32,
    k: Vec<f32>,
    v: Vec<f32>,
    block_size: usize,
    d_model: usize,
}

impl KvBlock {
    pub fn new(id: u32, n_layer: usize, block_size: usize, d_model: usize) -> KvBlock {
        assert!(block_size > 0 && d_model > 0 && n_layer > 0);
        let n = n_layer * block_size * d_model;
        KvBlock { id, k: vec![0.0; n], v: vec![0.0; n], block_size, d_model }
    }

    /// Positions this block can hold.
    pub fn block_size(&self) -> usize {
        self.block_size
    }

    /// Bytes of K/V storage in this block.
    pub fn bytes(&self) -> usize {
        (self.k.len() + self.v.len()) * std::mem::size_of::<f32>()
    }

    #[inline]
    fn off(&self, layer: usize, slot: usize) -> usize {
        debug_assert!(slot < self.block_size);
        (layer * self.block_size + slot) * self.d_model
    }

    /// K row of `layer` at in-block position `slot`.
    pub fn k_row(&self, layer: usize, slot: usize) -> &[f32] {
        let o = self.off(layer, slot);
        &self.k[o..o + self.d_model]
    }

    /// V row of `layer` at in-block position `slot`.
    pub fn v_row(&self, layer: usize, slot: usize) -> &[f32] {
        let o = self.off(layer, slot);
        &self.v[o..o + self.d_model]
    }

    /// Write the K and V rows of `layer` at in-block position `slot`.
    pub fn write(&mut self, layer: usize, slot: usize, k: &[f32], v: &[f32]) {
        let o = self.off(layer, slot);
        self.k[o..o + self.d_model].copy_from_slice(k);
        self.v[o..o + self.d_model].copy_from_slice(v);
    }

    /// Copy another block's K/V contents into this one (copy-on-write),
    /// keeping this block's own `id`.
    pub fn copy_contents_from(&mut self, other: &KvBlock) {
        assert_eq!(self.k.len(), other.k.len(), "block geometry mismatch");
        self.k.copy_from_slice(&other.k);
        self.v.copy_from_slice(&other.v);
    }
}

/// Storage interface for incremental decode: absolute sequence positions
/// in, K/V rows out. The transformer stages the rows of each new position
/// layer-by-layer with [`KvStorage::write`], reads any position `< len() +
/// staged` during attention, and [`KvStorage::commit`]s once every layer
/// of the wave's positions has been written.
pub trait KvStorage {
    /// Committed positions (== the next position to be decoded).
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Maximum positions this cache can hold.
    fn capacity(&self) -> usize;

    fn is_full(&self) -> bool {
        self.len() >= self.capacity()
    }

    /// Stage the K/V rows of `layer` for absolute position `pos`
    /// (`len() <= pos < capacity()`).
    fn write(&mut self, layer: usize, pos: usize, k: &[f32], v: &[f32]);

    /// K row of `layer` at absolute position `pos` (committed or staged).
    fn k_row(&self, layer: usize, pos: usize) -> &[f32];

    /// V row of `layer` at absolute position `pos` (committed or staged).
    fn v_row(&self, layer: usize, pos: usize) -> &[f32];

    /// Commit `n` staged positions: `len()` advances by `n`.
    fn commit(&mut self, n: usize);
}

/// Paged per-sequence KV cache: a chain of [`KvBlock`]s (the block table)
/// mapping logical position `p` to block `p / block_size`, slot
/// `p % block_size`. Blocks are `Arc`-shared; writes require the target
/// block to be held exclusively (the serve scheduler copy-on-writes a
/// shared tail via its allocator before every append wave).
#[derive(Debug)]
pub struct PagedKv {
    n_layer: usize,
    d_model: usize,
    block_size: usize,
    capacity: usize,
    len: usize,
    /// The block table: logical block `i` backs positions
    /// `[i*block_size, (i+1)*block_size)`.
    blocks: Vec<Arc<KvBlock>>,
    /// Standalone mode allocates private blocks on demand; the serve path
    /// disables this so every block goes through the arena budget.
    auto_grow: bool,
}

impl PagedKv {
    /// Standalone paged cache (private blocks, allocated on demand) — the
    /// drop-in paged counterpart of
    /// [`crate::nn::transformer::DecodeCache::new`].
    pub fn new(cfg: &ModelConfig, block_size: usize, capacity: usize) -> PagedKv {
        PagedKv::with_auto_grow(cfg, block_size, capacity, true)
    }

    /// A paged cache whose blocks must be provided externally
    /// ([`PagedKv::push_block`] / [`PagedKv::adopt_prefix`]) — used by the
    /// serve arena so allocation stays under its budget.
    pub fn external(cfg: &ModelConfig, block_size: usize, capacity: usize) -> PagedKv {
        PagedKv::with_auto_grow(cfg, block_size, capacity, false)
    }

    fn with_auto_grow(
        cfg: &ModelConfig,
        block_size: usize,
        capacity: usize,
        auto_grow: bool,
    ) -> PagedKv {
        assert!(block_size > 0, "kv block size must be positive");
        PagedKv {
            n_layer: cfg.n_layer,
            d_model: cfg.d_model,
            block_size,
            capacity: capacity.min(cfg.seq_len),
            len: 0,
            blocks: Vec::new(),
            auto_grow,
        }
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    /// Blocks currently in the chain.
    pub fn n_blocks(&self) -> usize {
        self.blocks.len()
    }

    /// The block table: physical block ids in logical order.
    pub fn block_table(&self) -> Vec<u32> {
        self.blocks.iter().map(|b| b.id).collect()
    }

    /// Positions the existing chain can still absorb without a new block.
    pub fn staged_room(&self) -> usize {
        let chain = (self.blocks.len() * self.block_size).saturating_sub(self.len);
        chain.min(self.capacity.saturating_sub(self.len))
    }

    /// Blocks that must be appended to hold `n_tokens` more positions.
    pub fn blocks_needed(&self, n_tokens: usize) -> usize {
        let have = self.blocks.len() * self.block_size;
        (self.len + n_tokens).saturating_sub(have).div_ceil(self.block_size)
    }

    /// The next append lands inside an existing block (as opposed to a
    /// block not yet in the chain).
    pub fn next_write_in_chain(&self) -> bool {
        self.len / self.block_size < self.blocks.len()
    }

    /// The block the next append writes into, if already in the chain.
    pub fn tail_block(&self) -> Option<&Arc<KvBlock>> {
        if self.next_write_in_chain() {
            Some(&self.blocks[self.len / self.block_size])
        } else {
            None
        }
    }

    /// Append an externally-allocated (exclusively held) block.
    pub fn push_block(&mut self, b: Arc<KvBlock>) {
        assert_eq!(b.block_size, self.block_size, "block size mismatch");
        assert_eq!(b.d_model, self.d_model, "d_model mismatch");
        self.blocks.push(b);
    }

    /// Swap the block the next append writes into for `fresh`
    /// (copy-on-write), returning the displaced block so the caller can
    /// drop its reference.
    pub fn replace_tail(&mut self, fresh: Arc<KvBlock>) -> Arc<KvBlock> {
        let idx = self.len / self.block_size;
        assert!(idx < self.blocks.len(), "replace_tail with no writable block in the chain");
        std::mem::replace(&mut self.blocks[idx], fresh)
    }

    /// Adopt a shared prefix chain covering `positions` committed
    /// positions (cross-request prefix reuse). The cache must be empty.
    pub fn adopt_prefix(&mut self, blocks: &[Arc<KvBlock>], positions: usize) {
        assert_eq!(self.len, 0, "adopt_prefix on a non-empty cache");
        assert!(self.blocks.is_empty(), "adopt_prefix on a non-empty chain");
        let covering = positions.div_ceil(self.block_size);
        assert!(covering <= blocks.len(), "prefix chain too short for {positions} positions");
        assert!(positions <= self.capacity, "prefix longer than cache capacity");
        self.blocks.extend(blocks[..covering].iter().cloned());
        self.len = positions;
    }

    /// Drain the chain for release back to the arena; the cache resets to
    /// empty and can be re-armed (preemption → later re-prefill).
    pub fn take_blocks(&mut self) -> Vec<Arc<KvBlock>> {
        self.len = 0;
        std::mem::take(&mut self.blocks)
    }

    /// The chain prefix covering the first `positions` positions (e.g. the
    /// prompt's blocks, for prefix-index insertion).
    pub fn blocks_covering(&self, positions: usize) -> &[Arc<KvBlock>] {
        let covering = positions.div_ceil(self.block_size);
        assert!(covering <= self.blocks.len(), "{positions} positions not materialized");
        &self.blocks[..covering]
    }

    /// Bytes of K/V storage referenced by this chain (shared blocks count
    /// fully; the arena tracks unique bytes).
    pub fn bytes(&self) -> usize {
        self.blocks.iter().map(|b| b.bytes()).sum()
    }
}

impl KvStorage for PagedKv {
    fn len(&self) -> usize {
        self.len
    }

    fn capacity(&self) -> usize {
        self.capacity
    }

    fn write(&mut self, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        debug_assert!(pos >= self.len, "rewriting a committed position");
        assert!(pos < self.capacity, "position {pos} beyond capacity {}", self.capacity);
        let lb = pos / self.block_size;
        while lb >= self.blocks.len() {
            assert!(
                self.auto_grow,
                "no block reserved for position {pos} (scheduler must reserve before the wave)"
            );
            let id = self.blocks.len() as u32;
            self.blocks.push(Arc::new(KvBlock::new(
                id,
                self.n_layer,
                self.block_size,
                self.d_model,
            )));
        }
        let block = Arc::get_mut(&mut self.blocks[lb])
            .expect("append into a shared block (copy-on-write was skipped)");
        block.write(layer, pos % self.block_size, k, v);
    }

    fn k_row(&self, layer: usize, pos: usize) -> &[f32] {
        self.blocks[pos / self.block_size].k_row(layer, pos % self.block_size)
    }

    fn v_row(&self, layer: usize, pos: usize) -> &[f32] {
        self.blocks[pos / self.block_size].v_row(layer, pos % self.block_size)
    }

    fn commit(&mut self, n: usize) {
        self.len += n;
        debug_assert!(self.len <= self.blocks.len() * self.block_size);
        debug_assert!(self.len <= self.capacity);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::schema::Arch;

    fn cfg() -> ModelConfig {
        ModelConfig::tiny(Arch::Gpt2)
    }

    #[test]
    fn block_rows_roundtrip() {
        let mut b = KvBlock::new(7, 2, 4, 8);
        let k: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let v: Vec<f32> = (0..8).map(|i| -(i as f32)).collect();
        b.write(1, 3, &k, &v);
        assert_eq!(b.k_row(1, 3), &k[..]);
        assert_eq!(b.v_row(1, 3), &v[..]);
        assert_eq!(b.k_row(0, 3), &[0.0; 8]);
        assert!(b.bytes() > 0);
        assert_eq!(b.id, 7);
    }

    #[test]
    fn paged_write_read_commit_across_blocks() {
        let c = cfg();
        let mut kv = PagedKv::new(&c, 4, 10);
        let row = vec![1.5f32; c.d_model];
        for pos in 0..6 {
            for l in 0..c.n_layer {
                kv.write(l, pos, &row, &row);
            }
            kv.commit(1);
        }
        assert_eq!(kv.len(), 6);
        assert_eq!(kv.n_blocks(), 2, "6 positions at block 4 => 2 blocks");
        assert_eq!(kv.block_table(), vec![0, 1]);
        assert_eq!(kv.k_row(1, 5), &row[..]);
        assert_eq!(kv.staged_room(), 2);
        assert_eq!(kv.blocks_needed(2), 0);
        assert_eq!(kv.blocks_needed(3), 1);
        assert!(kv.next_write_in_chain());
    }

    #[test]
    fn external_paged_requires_reserved_blocks() {
        let c = cfg();
        let mut kv = PagedKv::external(&c, 4, 16);
        assert!(!kv.next_write_in_chain());
        let b = Arc::new(KvBlock::new(3, c.n_layer, 4, c.d_model));
        kv.push_block(b);
        let row = vec![0.5f32; c.d_model];
        for l in 0..c.n_layer {
            kv.write(l, 0, &row, &row);
        }
        kv.commit(1);
        assert_eq!(kv.block_table(), vec![3]);
    }

    #[test]
    #[should_panic(expected = "no block reserved")]
    fn external_paged_panics_without_reservation() {
        let c = cfg();
        let mut kv = PagedKv::external(&c, 4, 16);
        let row = vec![0.0f32; c.d_model];
        kv.write(0, 0, &row, &row);
    }

    #[test]
    fn adopt_prefix_and_take_blocks() {
        let c = cfg();
        let shared: Vec<Arc<KvBlock>> =
            (0..3).map(|i| Arc::new(KvBlock::new(i, c.n_layer, 4, c.d_model))).collect();
        let mut kv = PagedKv::external(&c, 4, 32);
        // 6 positions need only the first 2 of the 3 cached blocks
        kv.adopt_prefix(&shared, 6);
        assert_eq!(kv.len(), 6);
        assert_eq!(kv.block_table(), vec![0, 1]);
        assert!(Arc::strong_count(&shared[0]) == 2);
        assert!(Arc::strong_count(&shared[2]) == 1);
        let drained = kv.take_blocks();
        assert_eq!(drained.len(), 2);
        assert_eq!(kv.len(), 0);
        assert_eq!(kv.n_blocks(), 0);
    }

    #[test]
    #[should_panic(expected = "shared block")]
    fn shared_tail_write_panics_without_cow() {
        let c = cfg();
        let block = Arc::new(KvBlock::new(0, c.n_layer, 4, c.d_model));
        let _other_holder = block.clone();
        let mut kv = PagedKv::external(&c, 4, 16);
        kv.adopt_prefix(&[block], 2);
        let row = vec![0.0f32; c.d_model];
        kv.write(0, 2, &row, &row); // position 2 lives in the shared block
    }

    #[test]
    fn replace_tail_swaps_for_exclusive_copy() {
        let c = cfg();
        let block = Arc::new(KvBlock::new(0, c.n_layer, 4, c.d_model));
        let holder = block.clone();
        let mut kv = PagedKv::external(&c, 4, 16);
        kv.adopt_prefix(&[block], 2);
        let mut fresh = KvBlock::new(9, c.n_layer, 4, c.d_model);
        fresh.copy_contents_from(&holder);
        let old = kv.replace_tail(Arc::new(fresh));
        assert_eq!(old.id, 0);
        assert_eq!(kv.block_table(), vec![9]);
        let row = vec![2.0f32; c.d_model];
        for l in 0..c.n_layer {
            kv.write(l, 2, &row, &row); // now exclusive: append works
        }
        kv.commit(1);
        assert_eq!(kv.len(), 3);
    }
}
