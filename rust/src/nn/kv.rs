//! KV-cache storage substrate for incremental decode: the [`KvStorage`]
//! trait abstracts *where* per-position K/V rows live so the transformer's
//! decode math ([`crate::nn::transformer::Transformer::decode_step`] /
//! `prefill_chunk`) is storage-agnostic.
//!
//! Two implementations:
//!
//! * [`crate::nn::transformer::DecodeCache`] — one contiguous
//!   (capacity × d_model) K and V matrix per layer. Simple, exactly the
//!   PR-1 layout; used by training-side eval and standalone decode.
//! * [`PagedKv`] — the serving layout: positions are grouped into
//!   fixed-size [`KvBlock`]s (e.g. 16 positions each, all layers) chained
//!   through a per-sequence block table. Blocks are `Arc`-shared, so
//!   identical prompt prefixes across requests can reference the *same*
//!   physical block (cross-request prefix caching) and a sequence only
//!   ever writes blocks it holds exclusively — the serve-side
//!   [`crate::serve::kvcache::BlockAllocator`] copy-on-writes a shared
//!   tail before any append.
//!
//! The paged layout exists for memory, not math: a contiguous cache
//! reserves `capacity` positions per sequence up front regardless of how
//! many a request actually uses, while paged allocation grows a sequence
//! block-by-block, so arena admission can be bounded by *blocks actually
//! in use*. Decode results are bit-identical between the two (see
//! `tests/paged_suite.rs`).
//!
//! # Quantized KV blocks ([`KvQuant`])
//!
//! The paged arena can additionally store K/V rows *through a
//! [`crate::quant::Scheme`]*: each row is split into `block`-element groups
//! sharing one power-of-two scale, and elements are encoded as
//! [`crate::quant::PackedCodes`] — a dense sub-byte bitvector at the
//! codec's true width (fp4 = 4 bits/element, not a padded byte), plus one
//! f32 scale per group. The codes + scales are the **only** resident
//! storage by default: attention reads go through the fused dequant
//! kernels ([`KvStorage::dot_k`] / [`KvStorage::axpy_v`]), which walk the
//! packed codes group-by-group through the codec's
//! [`crate::quant::DequantLut`] — one table index and one scale widen per
//! element, no f32 row ever materialized. [`KvQuant::with_mirror`] re-
//! enables the PR-4 resident f32 *decode mirror* (zero-copy
//! [`KvStorage::k_row`]/[`KvStorage::v_row`] reads) as a debug/test mode;
//! the fused path is asserted bit-identical to the mirror for every
//! registered packed codec (`tests/property_suite.rs`, and invariant 8 of
//! the fuzz harness). [`KvQuant::bytes_per_position`] reports the true
//! packed footprint (bit-granular, e.g. 160 B/position for fp4 on the
//! tiny config vs 1024 B f32); [`KvBlock::bytes`] counts exactly what the
//! block holds resident (packed bytes + scales, plus the mirror only when
//! enabled).
//!
//! Rows are encoded at **stage time** ([`KvStorage::write`]), not at
//! commit: a position staged earlier in the same prefill chunk must read
//! identically to one committed in a previous wave, otherwise splitting a
//! prompt into different chunk sizes would change the logits. With
//! stage-time encoding, chunked prefill stays bit-identical for any split
//! under every scheme (fuzzed in `tests/fuzz_serve.rs`); `commit` remains a
//! pure cursor advance. Stochastic rounding draws are keyed per
//! (seed, layer, absolute position), so a preempted sequence re-prefilling
//! from scratch — or a second request recomputing a shared prefix —
//! reproduces the exact same codes, which is what keeps greedy outputs
//! independent of preemption and prefix-cache hits.
//! The `"f32"` passthrough scheme stores raw rows with no codes: that path
//! is byte-identical to the pre-quantization behaviour.

use crate::config::schema::ModelConfig;
use crate::numerics::fpformat::Rounding;
use crate::prng::Philox4x32;
use crate::quant::{po2_scale, DequantLut, PackedCodes, QuantScheme, Scheme};
use anyhow::{bail, Result};
use std::sync::Arc;

/// Row-granular KV quantization policy: how the K/V rows inside a
/// [`KvBlock`] are encoded. Wraps a [`crate::quant::Scheme`]; the scheme's
/// block size becomes the per-row scale-group size (each `d_model`-element
/// row holds `d_model / group` groups, one po2 scale each).
///
/// Construction rejects schemes the row layout cannot host: a packed codec
/// with elementwise geometry (no block, so no shared scale), or a block
/// size that does not divide `d_model` (ragged tail groups are not
/// supported — see [`crate::serve::EngineConfig::validate_for`]).
///
/// Quantized policies default to **fused** reads (packed codes only, no
/// resident f32 rows); [`KvQuant::with_mirror`] opts back into the f32
/// decode mirror for debugging and bit-identity tests.
#[derive(Debug, Clone)]
pub struct KvQuant {
    scheme: Scheme,
    /// Elements per shared po2 scale; 0 for the f32 passthrough.
    group: usize,
    d_model: usize,
    /// Base seed for stochastic-rounding draws (mixed per layer/position).
    seed: u64,
    /// Keep a resident f32 decode mirror next to the codes (debug/test
    /// mode). Always true for passthrough, where the raw rows *are* the
    /// storage.
    mirror: bool,
    /// The codec's 2^bits decode table, driving the fused kernels.
    /// `Arc`-shared: a 16-bit codec's table is 64 Ki entries and every
    /// sequence cache clones the policy.
    lut: Option<Arc<DequantLut>>,
}

impl KvQuant {
    /// The f32 passthrough policy (raw rows, no codes) — today's
    /// bit-identical path.
    pub fn passthrough(d_model: usize) -> KvQuant {
        let scheme = crate::quant::resolve("f32").expect("f32 scheme is registered");
        KvQuant { scheme, group: 0, d_model, seed: 0, mirror: true, lut: None }
    }

    /// Build a KV quantizer for `scheme` over `d_model`-wide rows. `seed`
    /// feeds stochastic rounding (deterministic per layer/position).
    /// Quantized policies start in fused mode (no f32 mirror).
    pub fn new(scheme: Scheme, d_model: usize, seed: u64) -> Result<KvQuant> {
        if !scheme.codec.is_packed() {
            return Ok(KvQuant { scheme, group: 0, d_model, seed, mirror: true, lut: None });
        }
        let Some(group) = scheme.block() else {
            bail!(
                "kv-store scheme '{}' is an elementwise cast (no block scale); \
                 KV quantization is block-granular — pick a blockwise label such as 'fp8_e3m4'",
                scheme.label()
            );
        };
        if d_model % group != 0 {
            bail!(
                "kv-store scheme '{}' block {group} does not divide d_model {d_model}; \
                 KV rows need row-divisible block geometry",
                scheme.label()
            );
        }
        let lut = DequantLut::for_codec(&scheme.codec).map(Arc::new);
        Ok(KvQuant { scheme, group, d_model, seed, mirror: false, lut })
    }

    /// Keep the resident f32 decode mirror next to the packed codes, so
    /// [`KvStorage::k_row`]/[`KvStorage::v_row`] stay readable on quantized
    /// caches. Debug/test mode: the fused default is asserted bit-identical
    /// to it, so serving never needs the extra `2 × n_layer × d_model × 4`
    /// bytes per position.
    pub fn with_mirror(mut self) -> KvQuant {
        self.mirror = true;
        self
    }

    /// Whether blocks under this policy hold resident f32 rows (always
    /// true for passthrough; opt-in via [`KvQuant::with_mirror`] for
    /// quantized schemes).
    pub fn keeps_mirror(&self) -> bool {
        self.mirror
    }

    /// The codec's 2^bits decode table (`None` for passthrough).
    pub fn lut(&self) -> Option<&DequantLut> {
        self.lut.as_deref()
    }

    /// Canonical scheme label, e.g. `"fp8_e3m4"` (`"f32"` for passthrough).
    pub fn label(&self) -> &str {
        self.scheme.label()
    }

    /// False for the f32 passthrough (raw rows, no codes).
    pub fn is_quantizing(&self) -> bool {
        self.scheme.codec.is_packed()
    }

    /// Scale groups per K (or V) row; 0 for passthrough.
    pub fn groups_per_row(&self) -> usize {
        if self.group == 0 {
            0
        } else {
            self.d_model / self.group
        }
    }

    /// Encoded bytes one sequence position costs (K + V rows of every
    /// layer): densely packed element codes at the codec's true bit width
    /// plus one f32 scale per group, or plain f32 rows for the
    /// passthrough. This is the deployment-layout number `ServeStats`
    /// reports as `kv_bytes_per_position` — and, since PR 8, also what the
    /// fused arena actually keeps resident (tiny config: f32 1024 B,
    /// fp8/int8 288 B, fp6 224 B, fp4/int4 160 B).
    pub fn bytes_per_position(&self, n_layer: usize) -> usize {
        let per_row = if self.is_quantizing() {
            let bits = self.scheme.codec.bits_per_elem() as usize;
            (self.d_model * bits).div_ceil(8) + self.groups_per_row() * 4
        } else {
            self.d_model * 4
        };
        2 * n_layer * per_row
    }

    /// Deterministic SR stream key for one row: splitmix64-style mix of
    /// (seed, layer, position, K-or-V), so a row re-encoded after
    /// preemption or on a prefix-cache miss reproduces its codes exactly.
    fn row_seed(&self, layer: usize, pos: usize, which: u64) -> u64 {
        let mut h = self.seed ^ 0x9E37_79B9_7F4A_7C15;
        for x in [layer as u64 + 1, pos as u64 + 1, which + 1] {
            h ^= x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
            h = (h ^ (h >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        }
        h
    }

    /// Encode one staged row: per group, compute the po2 scale, pack each
    /// element's code into `codes` at `code_off + i`, and (when a mirror
    /// slice is supplied) write the dequantized f32 value
    /// (`decode(code) × scale`) alongside.
    #[allow(clippy::too_many_arguments)]
    fn encode_row(
        &self,
        src: &[f32],
        mut mirror: Option<&mut [f32]>,
        codes: &mut PackedCodes,
        code_off: usize,
        scales: &mut [f32],
        layer: usize,
        pos: usize,
        which: u64,
    ) {
        debug_assert_eq!(src.len(), self.d_model);
        let codec = &self.scheme.codec;
        let rounding = self.scheme.rounding;
        let stochastic = rounding == Rounding::Stochastic;
        let mut rng = Philox4x32::new(self.row_seed(layer, pos, which));
        for (gi, chunk) in src.chunks(self.group).enumerate() {
            let amax = chunk.iter().fold(0f64, |m, &x| m.max((x as f64).abs()));
            // round-trip the po2 scale through f32: the stored f32 scale
            // must widen back to *exactly* the value used here, or the
            // fused path (which re-reads scales[gi]) could diverge from
            // the mirror by an ulp at the f32 exponent extremes
            let s = (po2_scale(amax, codec) as f32) as f64;
            scales[gi] = s as f32;
            for (e, &x) in chunk.iter().enumerate() {
                let rand = if stochastic { rng.next_u32() } else { 0 };
                let q = codec.quantize(x as f64 / s, rounding, rand);
                codes.set(code_off + gi * self.group + e, codec.encode(q));
                if let Some(m) = mirror.as_deref_mut() {
                    m[gi * self.group + e] = (q * s) as f32;
                }
            }
        }
    }
}

/// Packed payload of a quantized block: element codes stored densely at
/// the codec's bit width ([`PackedCodes`]) and one f32 po2 scale per row
/// group, for K and V separately.
#[derive(Debug, Clone, PartialEq)]
struct KvEnc {
    k_codes: PackedCodes,
    v_codes: PackedCodes,
    k_scales: Vec<f32>,
    v_scales: Vec<f32>,
    groups_per_row: usize,
}

/// One fixed-size position block: the K and V rows of `block_size`
/// consecutive sequence positions for *every* layer, laid out layer-major
/// (`(layer * block_size + slot) * d_model`). This is the unit of KV-cache
/// allocation, sharing, and copy-on-write in the serve layer.
///
/// For quantized blocks the packed codes + scales in `enc` are canonical
/// and — in the fused default — the only storage; `k`/`v` hold the
/// dequantized f32 mirror only when the policy was built
/// [`KvQuant::with_mirror`].
#[derive(Debug, Clone, PartialEq)]
pub struct KvBlock {
    /// Arena identity (block-table entry). Standalone [`PagedKv`]s number
    /// their private blocks 0..; the serve arena assigns global ids.
    pub id: u32,
    k: Vec<f32>,
    v: Vec<f32>,
    block_size: usize,
    d_model: usize,
    enc: Option<KvEnc>,
}

impl KvBlock {
    /// A raw-f32 block (the passthrough layout).
    pub fn new(id: u32, n_layer: usize, block_size: usize, d_model: usize) -> KvBlock {
        assert!(block_size > 0 && d_model > 0 && n_layer > 0);
        let n = n_layer * block_size * d_model;
        KvBlock { id, k: vec![0.0; n], v: vec![0.0; n], block_size, d_model, enc: None }
    }

    /// A block shaped for `quant`: allocates the packed code/scale payload
    /// when the policy quantizes (plus the f32 mirror only if the policy
    /// keeps one), otherwise identical to [`KvBlock::new`].
    pub fn for_quant(
        id: u32,
        n_layer: usize,
        block_size: usize,
        d_model: usize,
        quant: &KvQuant,
    ) -> KvBlock {
        assert!(block_size > 0 && d_model > 0 && n_layer > 0);
        let n = n_layer * block_size * d_model;
        let mirror_n = if quant.keeps_mirror() { n } else { 0 };
        let mut b = KvBlock {
            id,
            k: vec![0.0; mirror_n],
            v: vec![0.0; mirror_n],
            block_size,
            d_model,
            enc: None,
        };
        if quant.is_quantizing() {
            let g = quant.groups_per_row();
            b.enc = Some(KvEnc {
                k_codes: PackedCodes::for_codec(&quant.scheme.codec, n),
                v_codes: PackedCodes::for_codec(&quant.scheme.codec, n),
                k_scales: vec![1.0; n_layer * block_size * g],
                v_scales: vec![1.0; n_layer * block_size * g],
                groups_per_row: g,
            });
        }
        b
    }

    /// Positions this block can hold.
    pub fn block_size(&self) -> usize {
        self.block_size
    }

    /// This block stores packed codes (a quantized KV scheme).
    pub fn is_encoded(&self) -> bool {
        self.enc.is_some()
    }

    /// This block holds resident f32 rows ([`KvStorage::k_row`] works).
    pub fn has_mirror(&self) -> bool {
        !self.k.is_empty()
    }

    /// Resident bytes of K/V storage in this block: the true packed code
    /// bytes + per-group scales for quantized blocks (plus the f32 mirror
    /// only when the policy keeps one), or the raw f32 rows otherwise. In
    /// the fused default this matches `block_size ×`
    /// [`KvQuant::bytes_per_position`] — no 2 B/code padding, no hidden
    /// mirror.
    pub fn bytes(&self) -> usize {
        let mirror = (self.k.len() + self.v.len()) * std::mem::size_of::<f32>();
        match &self.enc {
            None => mirror,
            Some(e) => {
                mirror
                    + e.k_codes.byte_len()
                    + e.v_codes.byte_len()
                    + (e.k_scales.len() + e.v_scales.len()) * std::mem::size_of::<f32>()
            }
        }
    }

    #[inline]
    fn off(&self, layer: usize, slot: usize) -> usize {
        debug_assert!(slot < self.block_size);
        (layer * self.block_size + slot) * self.d_model
    }

    /// K row of `layer` at in-block position `slot`. Panics on fused
    /// quantized blocks (no resident rows) — reads there go through
    /// [`KvBlock::dot_k_encoded`] / [`KvBlock::axpy_v_encoded`], or build
    /// the policy with [`KvQuant::with_mirror`].
    pub fn k_row(&self, layer: usize, slot: usize) -> &[f32] {
        assert!(
            self.has_mirror(),
            "f32 row reads need the decode mirror (KvQuant::with_mirror); \
             fused quantized blocks are read through dot_k/axpy_v"
        );
        let o = self.off(layer, slot);
        &self.k[o..o + self.d_model]
    }

    /// V row of `layer` at in-block position `slot` (same mirror
    /// requirement as [`KvBlock::k_row`]).
    pub fn v_row(&self, layer: usize, slot: usize) -> &[f32] {
        assert!(
            self.has_mirror(),
            "f32 row reads need the decode mirror (KvQuant::with_mirror); \
             fused quantized blocks are read through dot_k/axpy_v"
        );
        let o = self.off(layer, slot);
        &self.v[o..o + self.d_model]
    }

    /// Packed K codes of `layer` at `slot`, unpacked to one `u16` per
    /// element (`None` for raw blocks). Allocates — a diagnostics/test
    /// read; the hot path iterates the packed buffer directly.
    pub fn k_codes(&self, layer: usize, slot: usize) -> Option<Vec<u16>> {
        let o = self.off(layer, slot);
        self.enc.as_ref().map(|e| e.k_codes.iter_group(o, self.d_model).collect())
    }

    /// Per-group K scales of `layer` at `slot` (None for raw blocks).
    pub fn k_scales(&self, layer: usize, slot: usize) -> Option<&[f32]> {
        self.enc.as_ref().map(|e| {
            let so = (layer * self.block_size + slot) * e.groups_per_row;
            &e.k_scales[so..so + e.groups_per_row]
        })
    }

    /// Fused dequant-dot kernel: dot `q` against elements
    /// `[head_off, head_off + q.len())` of the packed K row of `layer` at
    /// `slot`, never materializing an f32 row. Walks the row one scale
    /// group at a time — fetch the group's po2 scale once, then for each
    /// code: one [`DequantLut`] table index, one widen-by-scale, one f32
    /// multiply-accumulate *in ascending element order*, which makes the
    /// result bit-identical to dotting against the decode mirror. Code
    /// extraction rides [`PackedCodes::iter_group`]'s word-at-a-time
    /// reader — one u64 load yields up to 16 sub-byte codes — so the
    /// per-code cost is shifts and a mask, not byte reassembly.
    pub fn dot_k_encoded(
        &self,
        layer: usize,
        slot: usize,
        head_off: usize,
        q: &[f32],
        lut: &DequantLut,
    ) -> f32 {
        let enc = self.enc.as_ref().expect("dot_k_encoded on a raw block");
        let row = self.off(layer, slot);
        let srow = (layer * self.block_size + slot) * enc.groups_per_row;
        let group = self.d_model / enc.groups_per_row;
        let end = head_off + q.len();
        debug_assert!(end <= self.d_model);
        let mut acc = 0.0f32;
        let mut e = head_off;
        while e < end {
            let gi = e / group;
            let ge = ((gi + 1) * group).min(end);
            let s = enc.k_scales[srow + gi] as f64;
            for (code, &qv) in
                enc.k_codes.iter_group(row + e, ge - e).zip(&q[e - head_off..ge - head_off])
            {
                acc += qv * ((lut.decode(code) * s) as f32);
            }
            e = ge;
        }
        acc
    }

    /// Fused dequant-axpy kernel: `out[e] += w × V[head_off + e]` decoded
    /// straight from the packed V row of `layer` at `slot` (same group-wise
    /// walk and bit-identity guarantee as [`KvBlock::dot_k_encoded`]).
    pub fn axpy_v_encoded(
        &self,
        layer: usize,
        slot: usize,
        head_off: usize,
        w: f32,
        out: &mut [f32],
        lut: &DequantLut,
    ) {
        let enc = self.enc.as_ref().expect("axpy_v_encoded on a raw block");
        let row = self.off(layer, slot);
        let srow = (layer * self.block_size + slot) * enc.groups_per_row;
        let group = self.d_model / enc.groups_per_row;
        let end = head_off + out.len();
        debug_assert!(end <= self.d_model);
        let mut e = head_off;
        while e < end {
            let gi = e / group;
            let ge = ((gi + 1) * group).min(end);
            let s = enc.v_scales[srow + gi] as f64;
            for (code, o) in enc
                .v_codes
                .iter_group(row + e, ge - e)
                .zip(out[e - head_off..ge - head_off].iter_mut())
            {
                *o += w * ((lut.decode(code) * s) as f32);
            }
            e = ge;
        }
    }

    /// Write the K and V rows of `layer` at in-block position `slot`
    /// verbatim (raw path; quantized writes go through
    /// [`KvBlock::write_encoded`]).
    pub fn write(&mut self, layer: usize, slot: usize, k: &[f32], v: &[f32]) {
        let o = self.off(layer, slot);
        self.k[o..o + self.d_model].copy_from_slice(k);
        self.v[o..o + self.d_model].copy_from_slice(v);
    }

    /// Write the K/V rows of `layer` at `slot`, encoding them through
    /// `quant` (codes + scales become canonical; the mirror — when the
    /// block keeps one — holds the dequantized values). `pos` is the
    /// absolute sequence position — stochastic rounding is keyed on it so
    /// re-encoding after preemption reproduces the same codes.
    pub fn write_encoded(
        &mut self,
        layer: usize,
        slot: usize,
        k: &[f32],
        v: &[f32],
        quant: &KvQuant,
        pos: usize,
    ) {
        let Some(enc) = &mut self.enc else {
            self.write(layer, slot, k, v);
            return;
        };
        let o = (layer * self.block_size + slot) * self.d_model;
        let d = self.d_model;
        let g = enc.groups_per_row;
        let so = (layer * self.block_size + slot) * g;
        let k_mirror = if self.k.is_empty() { None } else { Some(&mut self.k[o..o + d]) };
        quant.encode_row(k, k_mirror, &mut enc.k_codes, o, &mut enc.k_scales[so..so + g], layer, pos, 0);
        let v_mirror = if self.v.is_empty() { None } else { Some(&mut self.v[o..o + d]) };
        quant.encode_row(v, v_mirror, &mut enc.v_codes, o, &mut enc.v_scales[so..so + g], layer, pos, 1);
    }

    /// Copy another block's K/V contents into this one (copy-on-write),
    /// keeping this block's own `id`. Codes and scales copy along with the
    /// mirror (if any), so the fresh block stays canonical.
    pub fn copy_contents_from(&mut self, other: &KvBlock) {
        assert_eq!(self.k.len(), other.k.len(), "block geometry mismatch");
        assert_eq!(self.enc.is_some(), other.enc.is_some(), "block encoding mismatch");
        self.k.copy_from_slice(&other.k);
        self.v.copy_from_slice(&other.v);
        if let (Some(dst), Some(src)) = (&mut self.enc, &other.enc) {
            dst.k_codes.clone_from(&src.k_codes);
            dst.v_codes.clone_from(&src.v_codes);
            dst.k_scales.copy_from_slice(&src.k_scales);
            dst.v_scales.copy_from_slice(&src.v_scales);
        }
    }
}

/// Storage interface for incremental decode: absolute sequence positions
/// in, K/V rows out. The transformer stages the rows of each new position
/// layer-by-layer with [`KvStorage::write`], reads any position `< len() +
/// staged` during attention, and [`KvStorage::commit`]s once every layer
/// of the wave's positions has been written.
///
/// Attention consumes rows through the fused hooks [`KvStorage::dot_k`]
/// and [`KvStorage::axpy_v`] rather than raw row slices: the defaults
/// reproduce the classic f32 loops exactly (same values, same
/// accumulation order), and quantized paged storage overrides them to
/// decode packed codes in place — so swapping storage never changes a
/// single logit bit.
pub trait KvStorage {
    /// Committed positions (== the next position to be decoded).
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Maximum positions this cache can hold.
    fn capacity(&self) -> usize;

    fn is_full(&self) -> bool {
        self.len() >= self.capacity()
    }

    /// Stage the K/V rows of `layer` for absolute position `pos`
    /// (`len() <= pos < capacity()`). Quantizing storages encode the rows
    /// here, so staged reads already see the codec's values.
    fn write(&mut self, layer: usize, pos: usize, k: &[f32], v: &[f32]);

    /// K row of `layer` at absolute position `pos` (committed or staged).
    fn k_row(&self, layer: usize, pos: usize) -> &[f32];

    /// V row of `layer` at absolute position `pos` (committed or staged).
    fn v_row(&self, layer: usize, pos: usize) -> &[f32];

    /// Fused attention-score hook: `Σ_e q[e] × K[head_off + e]` over the K
    /// row of `layer` at `pos`, accumulated in f32 in ascending element
    /// order. The default reads the f32 row; quantized paged storage
    /// decodes packed codes directly (bit-identical by construction).
    fn dot_k(&self, layer: usize, pos: usize, head_off: usize, q: &[f32]) -> f32 {
        let kr = self.k_row(layer, pos);
        let mut acc = 0.0f32;
        for (e, &qv) in q.iter().enumerate() {
            acc += qv * kr[head_off + e];
        }
        acc
    }

    /// Fused attention-value hook: `out[e] += w × V[head_off + e]` over
    /// the V row of `layer` at `pos`, in ascending element order. Same
    /// override contract as [`KvStorage::dot_k`].
    fn axpy_v(&self, layer: usize, pos: usize, head_off: usize, w: f32, out: &mut [f32]) {
        let vr = self.v_row(layer, pos);
        for (e, o) in out.iter_mut().enumerate() {
            *o += w * vr[head_off + e];
        }
    }

    /// Commit `n` staged positions: `len()` advances by `n`.
    fn commit(&mut self, n: usize);
}

/// Paged per-sequence KV cache: a chain of [`KvBlock`]s (the block table)
/// mapping logical position `p` to block `p / block_size`, slot
/// `p % block_size`. Blocks are `Arc`-shared; writes require the target
/// block to be held exclusively (the serve scheduler copy-on-writes a
/// shared tail via its allocator before every append wave).
#[derive(Debug)]
pub struct PagedKv {
    n_layer: usize,
    d_model: usize,
    block_size: usize,
    capacity: usize,
    len: usize,
    /// The block table: logical block `i` backs positions
    /// `[i*block_size, (i+1)*block_size)`.
    blocks: Vec<Arc<KvBlock>>,
    /// Standalone mode allocates private blocks on demand; the serve path
    /// disables this so every block goes through the arena budget.
    auto_grow: bool,
    /// How K/V rows are stored (f32 passthrough or a quantized scheme).
    quant: KvQuant,
}

impl PagedKv {
    /// Standalone paged cache (private f32 blocks, allocated on demand) —
    /// the drop-in paged counterpart of
    /// [`crate::nn::transformer::DecodeCache::new`].
    pub fn new(cfg: &ModelConfig, block_size: usize, capacity: usize) -> PagedKv {
        PagedKv::with_quant(cfg, block_size, capacity, true, KvQuant::passthrough(cfg.d_model))
    }

    /// A paged cache whose blocks must be provided externally
    /// ([`PagedKv::push_block`] / [`PagedKv::adopt_prefix`]) — used by the
    /// serve arena so allocation stays under its budget.
    pub fn external(cfg: &ModelConfig, block_size: usize, capacity: usize) -> PagedKv {
        PagedKv::with_quant(cfg, block_size, capacity, false, KvQuant::passthrough(cfg.d_model))
    }

    /// Standalone paged cache storing rows through `quant` (auto-grown
    /// private blocks) — used by drift probes and the fuzz harness.
    pub fn new_quantized(
        cfg: &ModelConfig,
        block_size: usize,
        capacity: usize,
        quant: KvQuant,
    ) -> PagedKv {
        PagedKv::with_quant(cfg, block_size, capacity, true, quant)
    }

    /// Externally-fed paged cache storing rows through `quant` — what
    /// [`crate::serve::kvcache::BlockAllocator::new_seq`] hands out.
    pub fn external_quantized(
        cfg: &ModelConfig,
        block_size: usize,
        capacity: usize,
        quant: KvQuant,
    ) -> PagedKv {
        PagedKv::with_quant(cfg, block_size, capacity, false, quant)
    }

    fn with_quant(
        cfg: &ModelConfig,
        block_size: usize,
        capacity: usize,
        auto_grow: bool,
        quant: KvQuant,
    ) -> PagedKv {
        assert!(block_size > 0, "kv block size must be positive");
        PagedKv {
            n_layer: cfg.n_layer,
            d_model: cfg.d_model,
            block_size,
            capacity: capacity.min(cfg.seq_len),
            len: 0,
            blocks: Vec::new(),
            auto_grow,
            quant,
        }
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    /// The row-storage policy this cache writes through.
    pub fn kv_quant(&self) -> &KvQuant {
        &self.quant
    }

    /// Blocks currently in the chain.
    pub fn n_blocks(&self) -> usize {
        self.blocks.len()
    }

    /// The block table: physical block ids in logical order.
    pub fn block_table(&self) -> Vec<u32> {
        self.blocks.iter().map(|b| b.id).collect()
    }

    /// Positions the existing chain can still absorb without a new block.
    pub fn staged_room(&self) -> usize {
        let chain = (self.blocks.len() * self.block_size).saturating_sub(self.len);
        chain.min(self.capacity.saturating_sub(self.len))
    }

    /// Blocks that must be appended to hold `n_tokens` more positions.
    pub fn blocks_needed(&self, n_tokens: usize) -> usize {
        let have = self.blocks.len() * self.block_size;
        (self.len + n_tokens).saturating_sub(have).div_ceil(self.block_size)
    }

    /// The next append lands inside an existing block (as opposed to a
    /// block not yet in the chain).
    pub fn next_write_in_chain(&self) -> bool {
        self.len / self.block_size < self.blocks.len()
    }

    /// The block the next append writes into, if already in the chain.
    pub fn tail_block(&self) -> Option<&Arc<KvBlock>> {
        if self.next_write_in_chain() {
            Some(&self.blocks[self.len / self.block_size])
        } else {
            None
        }
    }

    /// Append an externally-allocated (exclusively held) block.
    pub fn push_block(&mut self, b: Arc<KvBlock>) {
        assert_eq!(b.block_size, self.block_size, "block size mismatch");
        assert_eq!(b.d_model, self.d_model, "d_model mismatch");
        assert_eq!(
            b.is_encoded(),
            self.quant.is_quantizing(),
            "block storage layout does not match the cache's kv scheme"
        );
        self.blocks.push(b);
    }

    /// Swap the block the next append writes into for `fresh`
    /// (copy-on-write), returning the displaced block so the caller can
    /// drop its reference.
    pub fn replace_tail(&mut self, fresh: Arc<KvBlock>) -> Arc<KvBlock> {
        let idx = self.len / self.block_size;
        assert!(idx < self.blocks.len(), "replace_tail with no writable block in the chain");
        std::mem::replace(&mut self.blocks[idx], fresh)
    }

    /// Adopt a shared prefix chain covering `positions` committed
    /// positions (cross-request prefix reuse). The cache must be empty.
    pub fn adopt_prefix(&mut self, blocks: &[Arc<KvBlock>], positions: usize) {
        assert_eq!(self.len, 0, "adopt_prefix on a non-empty cache");
        assert!(self.blocks.is_empty(), "adopt_prefix on a non-empty chain");
        let covering = positions.div_ceil(self.block_size);
        assert!(covering <= blocks.len(), "prefix chain too short for {positions} positions");
        assert!(positions <= self.capacity, "prefix longer than cache capacity");
        for b in &blocks[..covering] {
            assert_eq!(
                b.is_encoded(),
                self.quant.is_quantizing(),
                "adopted block storage layout does not match the cache's kv scheme"
            );
        }
        self.blocks.extend(blocks[..covering].iter().cloned());
        self.len = positions;
    }

    /// Drain the chain for release back to the arena; the cache resets to
    /// empty and can be re-armed (preemption → later re-prefill).
    pub fn take_blocks(&mut self) -> Vec<Arc<KvBlock>> {
        self.len = 0;
        std::mem::take(&mut self.blocks)
    }

    /// Truncate the committed length to `new_len` (rejected speculative
    /// tail rollback), dropping the whole blocks the shorter chain no
    /// longer covers and returning them so the caller can release them to
    /// its arena. Slots `new_len..` inside the kept tail block become
    /// stale: the next append simply rewrites them, and because stage-time
    /// SR encoding is keyed per (seed, layer, absolute position) the
    /// rewritten codes are deterministic — a position re-encoded after a
    /// rollback is bit-identical to one that was never speculated on.
    pub fn truncate(&mut self, new_len: usize) -> Vec<Arc<KvBlock>> {
        assert!(new_len <= self.len, "truncate({new_len}) beyond committed len {}", self.len);
        let keep = new_len.div_ceil(self.block_size);
        let released = if keep < self.blocks.len() {
            self.blocks.split_off(keep)
        } else {
            Vec::new()
        };
        self.len = new_len;
        released
    }

    /// The chain prefix covering the first `positions` positions (e.g. the
    /// prompt's blocks, for prefix-index insertion).
    pub fn blocks_covering(&self, positions: usize) -> &[Arc<KvBlock>] {
        let covering = positions.div_ceil(self.block_size);
        assert!(covering <= self.blocks.len(), "{positions} positions not materialized");
        &self.blocks[..covering]
    }

    /// Resident bytes of K/V storage referenced by this chain (shared
    /// blocks count fully; the arena tracks unique bytes).
    pub fn bytes(&self) -> usize {
        self.blocks.iter().map(|b| b.bytes()).sum()
    }
}

impl KvStorage for PagedKv {
    fn len(&self) -> usize {
        self.len
    }

    fn capacity(&self) -> usize {
        self.capacity
    }

    fn write(&mut self, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        debug_assert!(pos >= self.len, "rewriting a committed position");
        assert!(pos < self.capacity, "position {pos} beyond capacity {}", self.capacity);
        let lb = pos / self.block_size;
        while lb >= self.blocks.len() {
            assert!(
                self.auto_grow,
                "no block reserved for position {pos} (scheduler must reserve before the wave)"
            );
            let id = self.blocks.len() as u32;
            self.blocks.push(Arc::new(KvBlock::for_quant(
                id,
                self.n_layer,
                self.block_size,
                self.d_model,
                &self.quant,
            )));
        }
        let quant = &self.quant;
        let block = Arc::get_mut(&mut self.blocks[lb])
            .expect("append into a shared block (copy-on-write was skipped)");
        block.write_encoded(layer, pos % self.block_size, k, v, quant, pos);
    }

    fn k_row(&self, layer: usize, pos: usize) -> &[f32] {
        self.blocks[pos / self.block_size].k_row(layer, pos % self.block_size)
    }

    fn v_row(&self, layer: usize, pos: usize) -> &[f32] {
        self.blocks[pos / self.block_size].v_row(layer, pos % self.block_size)
    }

    fn dot_k(&self, layer: usize, pos: usize, head_off: usize, q: &[f32]) -> f32 {
        let b = &self.blocks[pos / self.block_size];
        if !self.quant.keeps_mirror() {
            if let Some(lut) = self.quant.lut() {
                return b.dot_k_encoded(layer, pos % self.block_size, head_off, q, lut);
            }
        }
        // mirror / passthrough: identical math + order to the trait default
        let kr = b.k_row(layer, pos % self.block_size);
        let mut acc = 0.0f32;
        for (e, &qv) in q.iter().enumerate() {
            acc += qv * kr[head_off + e];
        }
        acc
    }

    fn axpy_v(&self, layer: usize, pos: usize, head_off: usize, w: f32, out: &mut [f32]) {
        let b = &self.blocks[pos / self.block_size];
        if !self.quant.keeps_mirror() {
            if let Some(lut) = self.quant.lut() {
                return b.axpy_v_encoded(layer, pos % self.block_size, head_off, w, out, lut);
            }
        }
        let vr = b.v_row(layer, pos % self.block_size);
        for (e, o) in out.iter_mut().enumerate() {
            *o += w * vr[head_off + e];
        }
    }

    fn commit(&mut self, n: usize) {
        self.len += n;
        debug_assert!(self.len <= self.blocks.len() * self.block_size);
        debug_assert!(self.len <= self.capacity);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::schema::Arch;

    fn cfg() -> ModelConfig {
        ModelConfig::tiny(Arch::Gpt2)
    }

    fn quant(label: &str) -> KvQuant {
        KvQuant::new(crate::quant::resolve(label).unwrap(), cfg().d_model, 7).unwrap()
    }

    #[test]
    fn block_rows_roundtrip() {
        let mut b = KvBlock::new(7, 2, 4, 8);
        let k: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let v: Vec<f32> = (0..8).map(|i| -(i as f32)).collect();
        b.write(1, 3, &k, &v);
        assert_eq!(b.k_row(1, 3), &k[..]);
        assert_eq!(b.v_row(1, 3), &v[..]);
        assert_eq!(b.k_row(0, 3), &[0.0; 8]);
        assert!(b.bytes() > 0);
        assert_eq!(b.id, 7);
        assert!(!b.is_encoded());
    }

    #[test]
    fn paged_write_read_commit_across_blocks() {
        let c = cfg();
        let mut kv = PagedKv::new(&c, 4, 10);
        let row = vec![1.5f32; c.d_model];
        for pos in 0..6 {
            for l in 0..c.n_layer {
                kv.write(l, pos, &row, &row);
            }
            kv.commit(1);
        }
        assert_eq!(kv.len(), 6);
        assert_eq!(kv.n_blocks(), 2, "6 positions at block 4 => 2 blocks");
        assert_eq!(kv.block_table(), vec![0, 1]);
        assert_eq!(kv.k_row(1, 5), &row[..]);
        assert_eq!(kv.staged_room(), 2);
        assert_eq!(kv.blocks_needed(2), 0);
        assert_eq!(kv.blocks_needed(3), 1);
        assert!(kv.next_write_in_chain());
    }

    #[test]
    fn external_paged_requires_reserved_blocks() {
        let c = cfg();
        let mut kv = PagedKv::external(&c, 4, 16);
        assert!(!kv.next_write_in_chain());
        let b = Arc::new(KvBlock::new(3, c.n_layer, 4, c.d_model));
        kv.push_block(b);
        let row = vec![0.5f32; c.d_model];
        for l in 0..c.n_layer {
            kv.write(l, 0, &row, &row);
        }
        kv.commit(1);
        assert_eq!(kv.block_table(), vec![3]);
    }

    #[test]
    #[should_panic(expected = "no block reserved")]
    fn external_paged_panics_without_reservation() {
        let c = cfg();
        let mut kv = PagedKv::external(&c, 4, 16);
        let row = vec![0.0f32; c.d_model];
        kv.write(0, 0, &row, &row);
    }

    #[test]
    fn adopt_prefix_and_take_blocks() {
        let c = cfg();
        let shared: Vec<Arc<KvBlock>> =
            (0..3).map(|i| Arc::new(KvBlock::new(i, c.n_layer, 4, c.d_model))).collect();
        let mut kv = PagedKv::external(&c, 4, 32);
        // 6 positions need only the first 2 of the 3 cached blocks
        kv.adopt_prefix(&shared, 6);
        assert_eq!(kv.len(), 6);
        assert_eq!(kv.block_table(), vec![0, 1]);
        assert!(Arc::strong_count(&shared[0]) == 2);
        assert!(Arc::strong_count(&shared[2]) == 1);
        let drained = kv.take_blocks();
        assert_eq!(drained.len(), 2);
        assert_eq!(kv.len(), 0);
        assert_eq!(kv.n_blocks(), 0);
    }

    #[test]
    #[should_panic(expected = "shared block")]
    fn shared_tail_write_panics_without_cow() {
        let c = cfg();
        let block = Arc::new(KvBlock::new(0, c.n_layer, 4, c.d_model));
        let _other_holder = block.clone();
        let mut kv = PagedKv::external(&c, 4, 16);
        kv.adopt_prefix(&[block], 2);
        let row = vec![0.0f32; c.d_model];
        kv.write(0, 2, &row, &row); // position 2 lives in the shared block
    }

    #[test]
    fn replace_tail_swaps_for_exclusive_copy() {
        let c = cfg();
        let block = Arc::new(KvBlock::new(0, c.n_layer, 4, c.d_model));
        let holder = block.clone();
        let mut kv = PagedKv::external(&c, 4, 16);
        kv.adopt_prefix(&[block], 2);
        let mut fresh = KvBlock::new(9, c.n_layer, 4, c.d_model);
        fresh.copy_contents_from(&holder);
        let old = kv.replace_tail(Arc::new(fresh));
        assert_eq!(old.id, 0);
        assert_eq!(kv.block_table(), vec![9]);
        let row = vec![2.0f32; c.d_model];
        for l in 0..c.n_layer {
            kv.write(l, 2, &row, &row); // now exclusive: append works
        }
        kv.commit(1);
        assert_eq!(kv.len(), 3);
    }

    // ------------------------------------------------- quantized KV blocks

    #[test]
    fn kv_quant_rejects_unhostable_geometries() {
        let c = cfg();
        let elem = crate::quant::resolve("fp8_e3m4").unwrap().elementwise();
        let err = KvQuant::new(elem, c.d_model, 0).unwrap_err().to_string();
        assert!(err.contains("elementwise"), "{err}");
        let ragged = crate::quant::resolve("fp8_e3m4").unwrap().with_block(48);
        let err = KvQuant::new(ragged, c.d_model, 0).unwrap_err().to_string();
        assert!(err.contains("does not divide"), "{err}");
        // passthrough and row-divisible blockwise schemes are fine
        assert!(KvQuant::new(crate::quant::resolve("f32").unwrap(), c.d_model, 0).is_ok());
        assert!(KvQuant::new(crate::quant::resolve("int8_sr").unwrap(), c.d_model, 0).is_ok());
    }

    #[test]
    fn quantized_write_keeps_mirror_equal_to_decoded_codes() {
        let c = cfg();
        let q = quant("fp8_e3m4").with_mirror();
        let codec = crate::quant::resolve("fp8_e3m4").unwrap().codec;
        let mut kv = PagedKv::new_quantized(&c, 4, 16, q);
        let k: Vec<f32> = (0..c.d_model).map(|i| (i as f32 - 30.0) * 0.11).collect();
        let v: Vec<f32> = (0..c.d_model).map(|i| (i as f32) * 0.07 - 1.0).collect();
        for l in 0..c.n_layer {
            kv.write(l, 0, &k, &v);
        }
        kv.commit(1);
        let block = &kv.blocks[0];
        assert!(block.is_encoded());
        let codes = block.k_codes(1, 0).unwrap();
        let scales = block.k_scales(1, 0).unwrap();
        let group = c.d_model / scales.len();
        for (i, &m) in block.k_row(1, 0).iter().enumerate() {
            let s = scales[i / group] as f64;
            let want = (codec.decode(codes[i]) * s) as f32;
            assert_eq!(m, want, "mirror[{i}] diverges from decode(code)*scale");
        }
        // the mirror is quantized, i.e. generally not the raw input
        assert!(block.k_row(0, 0).iter().zip(&k).any(|(a, b)| a != b));
    }

    #[test]
    fn fused_reads_are_bit_identical_to_the_mirror() {
        // the PR-8 acceptance invariant at block granularity: a fused
        // (codes-only) cache and a mirrored cache fed the same rows must
        // agree bit-for-bit through dot_k and axpy_v — including scale
        // groups straddled by the probe span (head_off 16 over group 32)
        let c = cfg();
        for label in ["fp8_e3m4", "fp6_e2m3", "fp4_e2m1_sr", "int4_sr", "bf16"] {
            let mut fused = PagedKv::new_quantized(&c, 4, 16, quant(label));
            let mut mirrored = PagedKv::new_quantized(&c, 4, 16, quant(label).with_mirror());
            for pos in 0..5 {
                let k: Vec<f32> = (0..c.d_model)
                    .map(|i| ((i * 31 + pos * 7) % 23) as f32 * 0.063 - 0.7)
                    .collect();
                let v: Vec<f32> = (0..c.d_model)
                    .map(|i| ((i * 17 + pos * 11) % 29) as f32 * 0.041 - 0.5)
                    .collect();
                for l in 0..c.n_layer {
                    fused.write(l, pos, &k, &v);
                    mirrored.write(l, pos, &k, &v);
                }
                fused.commit(1);
                mirrored.commit(1);
            }
            let probe: Vec<f32> = (0..32).map(|i| (i as f32) * 0.2 - 3.0).collect();
            for pos in 0..5 {
                for l in 0..c.n_layer {
                    for head_off in [0usize, 16, 32] {
                        let a = fused.dot_k(l, pos, head_off, &probe);
                        let b = mirrored.dot_k(l, pos, head_off, &probe);
                        assert_eq!(
                            a.to_bits(),
                            b.to_bits(),
                            "{label}: dot_k l{l} p{pos} off{head_off}: {a} vs {b}"
                        );
                        let mut oa = vec![0.1f32; 32];
                        let mut ob = vec![0.1f32; 32];
                        fused.axpy_v(l, pos, head_off, 0.37, &mut oa);
                        mirrored.axpy_v(l, pos, head_off, 0.37, &mut ob);
                        assert_eq!(oa, ob, "{label}: axpy_v l{l} p{pos} off{head_off}");
                    }
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "decode mirror")]
    fn raw_row_reads_without_mirror_panic_clearly() {
        let c = cfg();
        let mut kv = PagedKv::new_quantized(&c, 4, 16, quant("fp8_e3m4"));
        let row = vec![0.3f32; c.d_model];
        for l in 0..c.n_layer {
            kv.write(l, 0, &row, &row);
        }
        kv.commit(1);
        let _ = kv.k_row(0, 0);
    }

    #[test]
    fn passthrough_quant_stores_raw_rows_without_codes() {
        let c = cfg();
        let mut kv = PagedKv::new_quantized(&c, 4, 16, KvQuant::passthrough(c.d_model));
        let k: Vec<f32> = (0..c.d_model).map(|i| (i as f32) * 0.013 - 0.4).collect();
        for l in 0..c.n_layer {
            kv.write(l, 0, &k, &k);
        }
        kv.commit(1);
        assert!(!kv.blocks[0].is_encoded());
        assert_eq!(kv.k_row(0, 0), &k[..], "f32 passthrough must be bit-identical");
    }

    #[test]
    fn stochastic_kv_rows_reproduce_per_position() {
        // the SR stream is keyed on (seed, layer, pos): two caches fed the
        // same rows encode identically — the re-prefill/prefix-reuse
        // determinism guarantee — while another seed differs
        let c = cfg();
        let k: Vec<f32> = (0..c.d_model).map(|i| ((i * 13) % 17) as f32 * 0.031 - 0.2).collect();
        let run = |seed: u64| {
            let q = KvQuant::new(crate::quant::resolve("int8_sr").unwrap(), c.d_model, seed)
                .unwrap()
                .with_mirror();
            let mut kv = PagedKv::new_quantized(&c, 4, 16, q);
            for pos in 0..3 {
                for l in 0..c.n_layer {
                    kv.write(l, pos, &k, &k);
                }
                kv.commit(1);
            }
            (kv.k_row(1, 2).to_vec(), kv.v_row(0, 1).to_vec())
        };
        assert_eq!(run(5), run(5), "same seed must reproduce");
        assert_ne!(run(5), run(6), "different seeds should differ");
    }

    #[test]
    fn quantized_bytes_per_position_beats_f32() {
        let c = cfg();
        let f32b = KvQuant::passthrough(c.d_model).bytes_per_position(c.n_layer);
        assert_eq!(f32b, 2 * c.n_layer * c.d_model * 4);
        for label in ["fp8_e3m4", "int8_sr", "fp4_e2m1"] {
            let q = quant(label);
            let b = q.bytes_per_position(c.n_layer);
            assert!(b < f32b, "{label}: {b} >= {f32b}");
            assert!(q.is_quantizing());
        }
        // bf16 codes are 2 bytes: still half the f32 arena
        assert_eq!(quant("bf16").bytes_per_position(c.n_layer), 2 * c.n_layer * (c.d_model * 2 + 2 * 4));
    }

    #[test]
    fn packed_bytes_per_position_are_bit_true() {
        // the satellite-(a) accounting fix: sub-byte codecs no longer
        // charge a padded byte (or u16 slot) per code. Tiny config:
        // n_layer 2, d_model 64, scale group 32.
        let c = cfg();
        assert_eq!(quant("fp8_e3m4").bytes_per_position(c.n_layer), 288);
        assert_eq!(quant("int8_sr").bytes_per_position(c.n_layer), 288);
        assert_eq!(quant("fp6_e3m2").bytes_per_position(c.n_layer), 224);
        assert_eq!(quant("fp4_e2m1").bytes_per_position(c.n_layer), 160);
        assert_eq!(quant("int4_sr").bytes_per_position(c.n_layer), 160);
        // block resident bytes match the accounting exactly in fused mode…
        let q4 = quant("fp4_e2m1");
        let b = KvBlock::for_quant(0, c.n_layer, 4, c.d_model, &q4);
        assert_eq!(b.bytes(), 4 * q4.bytes_per_position(c.n_layer));
        // …and the opt-in mirror costs exactly the f32 rows on top
        let m = KvBlock::for_quant(0, c.n_layer, 4, c.d_model, &q4.with_mirror());
        assert_eq!(m.bytes(), b.bytes() + 2 * c.n_layer * 4 * c.d_model * 4);
    }

    #[test]
    fn truncate_drops_uncovered_blocks_and_rewrites_deterministically() {
        let c = cfg();
        let mk = || {
            let q = KvQuant::new(crate::quant::resolve("int8_sr").unwrap(), c.d_model, 11)
                .unwrap()
                .with_mirror();
            PagedKv::new_quantized(&c, 4, 16, q)
        };
        let row_at = |pos: usize| -> Vec<f32> {
            (0..c.d_model).map(|i| ((i * 7 + pos * 13) % 19) as f32 * 0.05 - 0.4).collect()
        };
        // reference: positions 0..6 written straight through
        let mut reference = mk();
        for pos in 0..6 {
            let r = row_at(pos);
            for l in 0..c.n_layer {
                reference.write(l, pos, &r, &r);
            }
            reference.commit(1);
        }
        // speculated: 0..9 written, then rolled back to 6 and nothing more
        let mut speculated = mk();
        for pos in 0..9 {
            let r = row_at(100 + pos); // draft rows differ from the reference
            for l in 0..c.n_layer {
                speculated.write(l, pos, &r, &r);
            }
            speculated.commit(1);
        }
        let released = speculated.truncate(6);
        assert_eq!(released.len(), 1, "9 positions / block 4 = 3 blocks; 6 keeps 2");
        assert_eq!(speculated.len(), 6);
        assert_eq!(speculated.n_blocks(), 2);
        // a cache that re-stages the reference rows from scratch must match
        // the reference bit-for-bit: SR draws are keyed on the absolute
        // position, not on write history, so rollback + rewrite is
        // indistinguishable from never having speculated
        let mut replay = mk();
        for pos in 0..6 {
            let r = row_at(pos);
            for l in 0..c.n_layer {
                replay.write(l, pos, &r, &r);
            }
            replay.commit(1);
        }
        for pos in 0..6 {
            for l in 0..c.n_layer {
                assert_eq!(reference.k_row(l, pos), replay.k_row(l, pos));
            }
        }
        // truncate to a block boundary releases exactly the tail
        let released = replay.truncate(4);
        assert_eq!(released.len(), 1);
        assert_eq!(replay.len(), 4);
        // truncate to zero drains everything
        let released = speculated.truncate(0);
        assert_eq!(released.len(), 2);
        assert_eq!(speculated.n_blocks(), 0);
    }

    #[test]
    fn copy_contents_from_carries_codes() {
        let c = cfg();
        let q = quant("int8").with_mirror();
        let mut kv = PagedKv::new_quantized(&c, 4, 16, q.clone());
        let k: Vec<f32> = (0..c.d_model).map(|i| (i as f32) * 0.09 - 2.0).collect();
        for l in 0..c.n_layer {
            kv.write(l, 0, &k, &k);
        }
        kv.commit(1);
        let src = kv.blocks[0].clone();
        let mut fresh = KvBlock::for_quant(9, c.n_layer, 4, c.d_model, &q);
        fresh.copy_contents_from(&src);
        assert_eq!(fresh.k_row(0, 0), src.k_row(0, 0));
        assert_eq!(fresh.k_codes(0, 0), src.k_codes(0, 0));
        assert_eq!(fresh.k_scales(0, 0), src.k_scales(0, 0));
    }
}
