//! Learning-rate schedules. The paper uses linear warmup followed by a
//! linear decay from `max_lr` to `min_lr` (Appendix E).

/// Schedule shape after warmup.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Decay {
    Linear,
    Cosine,
    /// Hold at max_lr after warmup (for overhead benches where the LR is
    /// irrelevant).
    Constant,
}

/// A warmup + decay LR schedule over a fixed horizon.
#[derive(Debug, Clone, Copy)]
pub struct LrSchedule {
    pub max_lr: f64,
    pub min_lr: f64,
    pub warmup_steps: usize,
    pub total_steps: usize,
    pub decay: Decay,
}

impl LrSchedule {
    pub fn linear(max_lr: f64, min_lr: f64, warmup: usize, total: usize) -> Self {
        LrSchedule { max_lr, min_lr, warmup_steps: warmup, total_steps: total, decay: Decay::Linear }
    }

    /// LR at step `t` (0-based).
    pub fn at(&self, t: usize) -> f64 {
        if self.warmup_steps > 0 && t < self.warmup_steps {
            // linear warmup from 0 (exclusive) to max_lr
            return self.max_lr * (t + 1) as f64 / self.warmup_steps as f64;
        }
        let span = self.total_steps.saturating_sub(self.warmup_steps).max(1);
        let p = ((t - self.warmup_steps) as f64 / span as f64).clamp(0.0, 1.0);
        match self.decay {
            Decay::Linear => self.max_lr + (self.min_lr - self.max_lr) * p,
            Decay::Cosine => {
                self.min_lr
                    + 0.5 * (self.max_lr - self.min_lr) * (1.0 + (std::f64::consts::PI * p).cos())
            }
            Decay::Constant => self.max_lr,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_rises_to_max() {
        let s = LrSchedule::linear(1e-3, 1e-4, 10, 100);
        assert!(s.at(0) > 0.0);
        assert!(s.at(0) < s.at(5));
        assert!((s.at(9) - 1e-3).abs() < 1e-12);
    }

    #[test]
    fn linear_decays_to_min() {
        let s = LrSchedule::linear(1e-3, 1e-4, 10, 100);
        assert!((s.at(100) - 1e-4).abs() < 1e-12);
        assert!(s.at(50) < s.at(20));
        // beyond the horizon clamps at min
        assert!((s.at(500) - 1e-4).abs() < 1e-12);
    }

    #[test]
    fn cosine_midpoint() {
        let s = LrSchedule {
            max_lr: 1.0,
            min_lr: 0.0,
            warmup_steps: 0,
            total_steps: 100,
            decay: Decay::Cosine,
        };
        assert!((s.at(50) - 0.5).abs() < 0.02);
    }

    #[test]
    fn constant_holds() {
        let s = LrSchedule {
            max_lr: 0.5,
            min_lr: 0.1,
            warmup_steps: 2,
            total_steps: 10,
            decay: Decay::Constant,
        };
        assert_eq!(s.at(5), 0.5);
    }
}
