//! Optimizers (AdamW, Adam-mini) and LR schedules — the rust side of the
//! training step: the HLO artifact computes (loss, grads); these apply them.

pub mod adamini;
pub mod adamw;
pub mod schedule;

pub use adamini::AdamMini;
pub use adamw::AdamW;
pub use schedule::{Decay, LrSchedule};

/// A unified handle over the two optimizers so the trainer is generic.
#[derive(Debug, Clone)]
pub enum Opt {
    AdamW(AdamW),
    AdamMini(AdamMini),
}

impl Opt {
    pub fn step_begin(&mut self) {
        match self {
            Opt::AdamW(o) => o.step_begin(),
            Opt::AdamMini(o) => o.step_begin(),
        }
    }

    pub fn update(&mut self, idx: usize, w: &mut [f32], g: &[f32], decay: bool) {
        match self {
            Opt::AdamW(o) => o.update(idx, w, g, decay),
            Opt::AdamMini(o) => o.update(idx, w, g, decay),
        }
    }

    pub fn set_lr(&mut self, lr: f64) {
        match self {
            Opt::AdamW(o) => o.lr = lr,
            Opt::AdamMini(o) => o.lr = lr,
        }
    }

    pub fn state_bytes(&self) -> usize {
        match self {
            Opt::AdamW(o) => o.state_bytes(),
            Opt::AdamMini(o) => o.state_bytes(),
        }
    }
}
