//! AdamW (Loshchilov & Hutter, 2019) with decoupled weight decay — the
//! baseline optimizer of the paper's experiments (Appendix E).

/// AdamW state and hyperparameters for a set of parameter tensors.
#[derive(Debug, Clone)]
pub struct AdamW {
    pub lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    pub weight_decay: f64,
    /// first/second moment per parameter tensor
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    t: u64,
}

impl AdamW {
    /// `sizes[i]` is the element count of tensor `i`.
    pub fn new(sizes: &[usize], lr: f64, beta1: f64, beta2: f64, eps: f64, wd: f64) -> Self {
        AdamW {
            lr,
            beta1,
            beta2,
            eps,
            weight_decay: wd,
            m: sizes.iter().map(|&n| vec![0.0; n]).collect(),
            v: sizes.iter().map(|&n| vec![0.0; n]).collect(),
            t: 0,
        }
    }

    /// Number of managed tensors.
    pub fn n_tensors(&self) -> usize {
        self.m.len()
    }

    /// Optimizer-state memory in bytes (2 f32 moments per parameter).
    pub fn state_bytes(&self) -> usize {
        self.m.iter().map(|x| x.len()).sum::<usize>() * 8
    }

    /// Advance the shared timestep. Call once per step, before `update`.
    pub fn step_begin(&mut self) {
        self.t += 1;
    }

    /// Current timestep.
    pub fn t(&self) -> u64 {
        self.t
    }

    /// Update tensor `idx` in place given its gradient. `decay` toggles
    /// weight decay for this tensor (off for biases/norms, per convention).
    pub fn update(&mut self, idx: usize, w: &mut [f32], g: &[f32], decay: bool) {
        assert_eq!(w.len(), g.len());
        assert_eq!(w.len(), self.m[idx].len());
        let b1 = self.beta1;
        let b2 = self.beta2;
        let bc1 = 1.0 - b1.powi(self.t as i32);
        let bc2 = 1.0 - b2.powi(self.t as i32);
        let lr = self.lr;
        let wd = if decay { self.weight_decay } else { 0.0 };
        let m = &mut self.m[idx];
        let v = &mut self.v[idx];
        for i in 0..w.len() {
            let gi = g[i] as f64;
            let mi = b1 * m[i] as f64 + (1.0 - b1) * gi;
            let vi = b2 * v[i] as f64 + (1.0 - b2) * gi * gi;
            m[i] = mi as f32;
            v[i] = vi as f32;
            let mhat = mi / bc1;
            let vhat = vi / bc2;
            let upd = lr * (mhat / (vhat.sqrt() + self.eps) + wd * w[i] as f64);
            w[i] = (w[i] as f64 - upd) as f32;
        }
    }

    /// Serialize moments (for checkpoints): flat (m, v) per tensor.
    pub fn export_state(&self) -> (Vec<Vec<f32>>, Vec<Vec<f32>>) {
        (self.m.clone(), self.v.clone())
    }

    /// Restore moments and timestep.
    pub fn import_state(&mut self, m: Vec<Vec<f32>>, v: Vec<Vec<f32>>, t: u64) {
        assert_eq!(m.len(), self.m.len());
        self.m = m;
        self.v = v;
        self.t = t;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minimizes_quadratic() {
        // f(w) = 0.5*(w-3)^2, grad = w-3
        let mut opt = AdamW::new(&[1], 0.1, 0.9, 0.999, 1e-8, 0.0);
        let mut w = vec![0.0f32];
        for _ in 0..500 {
            opt.step_begin();
            let g = vec![w[0] - 3.0];
            opt.update(0, &mut w, &g, false);
        }
        assert!((w[0] - 3.0).abs() < 1e-2, "w={}", w[0]);
    }

    #[test]
    fn weight_decay_is_decoupled() {
        // zero gradient + decay shrinks weights multiplicatively
        let mut opt = AdamW::new(&[1], 0.1, 0.9, 0.999, 1e-8, 0.5);
        let mut w = vec![1.0f32];
        opt.step_begin();
        opt.update(0, &mut w, &[0.0], true);
        assert!((w[0] - (1.0 - 0.1 * 0.5)).abs() < 1e-6);
        // and decay=false leaves it alone under zero grad
        let mut w2 = vec![1.0f32];
        let mut opt2 = AdamW::new(&[1], 0.1, 0.9, 0.999, 1e-8, 0.5);
        opt2.step_begin();
        opt2.update(0, &mut w2, &[0.0], false);
        assert_eq!(w2[0], 1.0);
    }

    #[test]
    fn first_step_is_lr_sized() {
        // bias correction makes the first Adam step ≈ lr * sign(g)
        let mut opt = AdamW::new(&[1], 0.01, 0.9, 0.999, 1e-12, 0.0);
        let mut w = vec![0.0f32];
        opt.step_begin();
        opt.update(0, &mut w, &[5.0], false);
        assert!((w[0] + 0.01).abs() < 1e-4, "w={}", w[0]);
    }

    #[test]
    fn state_roundtrip() {
        let mut opt = AdamW::new(&[3], 0.1, 0.9, 0.999, 1e-8, 0.0);
        let mut w = vec![1.0f32, 2.0, 3.0];
        opt.step_begin();
        opt.update(0, &mut w, &[0.1, 0.2, 0.3], false);
        let (m, v) = opt.export_state();
        let mut opt2 = AdamW::new(&[3], 0.1, 0.9, 0.999, 1e-8, 0.0);
        opt2.import_state(m, v, opt.t());
        let mut w2 = w.clone();
        opt.step_begin();
        opt2.step_begin();
        opt.update(0, &mut w, &[0.1, 0.2, 0.3], false);
        opt2.update(0, &mut w2, &[0.1, 0.2, 0.3], false);
        assert_eq!(w, w2);
    }

    #[test]
    fn state_bytes_accounting() {
        let opt = AdamW::new(&[100, 50], 0.1, 0.9, 0.999, 1e-8, 0.0);
        assert_eq!(opt.state_bytes(), 150 * 8);
    }
}
