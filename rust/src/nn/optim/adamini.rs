//! Adam-mini (Zhang et al., 2024): Adam with one shared second-moment
//! scalar per parameter *block* instead of per element, cutting optimizer
//! state from 8 B/param to ≈4 B/param. The paper uses it as the
//! parameter-efficient-optimizer arm (Fig. 3b, Fig. 4, Table 1).
//!
//! We implement the blockwise variant: each tensor is partitioned into
//! fixed-size blocks (one block per head/neuron in the original; a fixed
//! width here), each block sharing `v = mean(g²)` while keeping per-element
//! first moments.

/// Adam-mini optimizer state.
#[derive(Debug, Clone)]
pub struct AdamMini {
    pub lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    pub weight_decay: f64,
    /// Block width for the shared second moment.
    pub block: usize,
    m: Vec<Vec<f32>>,
    /// One v per block per tensor.
    v: Vec<Vec<f32>>,
    t: u64,
}

impl AdamMini {
    pub fn new(
        sizes: &[usize],
        block: usize,
        lr: f64,
        beta1: f64,
        beta2: f64,
        eps: f64,
        wd: f64,
    ) -> Self {
        assert!(block > 0);
        AdamMini {
            lr,
            beta1,
            beta2,
            eps,
            weight_decay: wd,
            block,
            m: sizes.iter().map(|&n| vec![0.0; n]).collect(),
            v: sizes.iter().map(|&n| vec![0.0; n.div_ceil(block)]).collect(),
            t: 0,
        }
    }

    pub fn step_begin(&mut self) {
        self.t += 1;
    }

    pub fn t(&self) -> u64 {
        self.t
    }

    /// Optimizer-state bytes: per-element m + per-block v.
    pub fn state_bytes(&self) -> usize {
        let m: usize = self.m.iter().map(|x| x.len()).sum();
        let v: usize = self.v.iter().map(|x| x.len()).sum();
        (m + v) * 4
    }

    pub fn update(&mut self, idx: usize, w: &mut [f32], g: &[f32], decay: bool) {
        assert_eq!(w.len(), g.len());
        let b1 = self.beta1;
        let b2 = self.beta2;
        let bc1 = 1.0 - b1.powi(self.t as i32);
        let bc2 = 1.0 - b2.powi(self.t as i32);
        let lr = self.lr;
        let wd = if decay { self.weight_decay } else { 0.0 };
        let block = self.block;
        let m = &mut self.m[idx];
        let v = &mut self.v[idx];
        for (b, vb) in v.iter_mut().enumerate() {
            let lo = b * block;
            let hi = ((b + 1) * block).min(w.len());
            if lo >= hi {
                break;
            }
            // shared v <- beta2*v + (1-beta2)*mean(g^2 over block)
            let msq: f64 = g[lo..hi].iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>()
                / (hi - lo) as f64;
            let vnew = b2 * *vb as f64 + (1.0 - b2) * msq;
            *vb = vnew as f32;
            let denom = (vnew / bc2).sqrt() + self.eps;
            for i in lo..hi {
                let mi = b1 * m[i] as f64 + (1.0 - b1) * g[i] as f64;
                m[i] = mi as f32;
                let upd = lr * ((mi / bc1) / denom + wd * w[i] as f64);
                w[i] = (w[i] as f64 - upd) as f32;
            }
        }
    }

    pub fn export_state(&self) -> (Vec<Vec<f32>>, Vec<Vec<f32>>) {
        (self.m.clone(), self.v.clone())
    }

    pub fn import_state(&mut self, m: Vec<Vec<f32>>, v: Vec<Vec<f32>>, t: u64) {
        self.m = m;
        self.v = v;
        self.t = t;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minimizes_quadratic() {
        let mut opt = AdamMini::new(&[4], 2, 0.05, 0.9, 0.999, 1e-8, 0.0);
        let mut w = vec![0.0f32; 4];
        let target = [1.0f32, -2.0, 3.0, 0.5];
        for _ in 0..2000 {
            opt.step_begin();
            let g: Vec<f32> = w.iter().zip(target.iter()).map(|(&a, &t)| a - t).collect();
            opt.update(0, &mut w, &g, false);
        }
        for (a, t) in w.iter().zip(target.iter()) {
            assert!((a - t).abs() < 0.05, "{a} vs {t}");
        }
    }

    #[test]
    fn state_is_smaller_than_adamw() {
        let sizes = [1024usize, 4096];
        let mini = AdamMini::new(&sizes, 64, 0.1, 0.9, 0.999, 1e-8, 0.0);
        let full = super::super::adamw::AdamW::new(&sizes, 0.1, 0.9, 0.999, 1e-8, 0.0);
        assert!(mini.state_bytes() < full.state_bytes() * 6 / 10);
        // ~4 B/param + v overhead
        let n: usize = sizes.iter().sum();
        assert!(mini.state_bytes() >= n * 4);
    }

    #[test]
    fn blockwise_v_is_shared() {
        // two elements in one block with very different g² still get the
        // same denominator -> update ratio equals m ratio
        let mut opt = AdamMini::new(&[2], 2, 0.1, 0.0, 0.999, 1e-12, 0.0);
        let mut w = vec![0.0f32, 0.0];
        opt.step_begin();
        opt.update(0, &mut w, &[1.0, 0.01], false);
        // beta1=0 -> m = g; shared denom -> w ratio == g ratio
        let ratio = w[0] / w[1];
        assert!((ratio - 100.0).abs() < 1.0, "ratio={ratio}");
    }

    #[test]
    fn ragged_last_block() {
        let mut opt = AdamMini::new(&[5], 2, 0.1, 0.9, 0.999, 1e-8, 0.0);
        let mut w = vec![1.0f32; 5];
        opt.step_begin();
        opt.update(0, &mut w, &[0.1; 5], false);
        assert!(w.iter().all(|x| x.is_finite() && *x < 1.0));
    }
}
