//! Shared utilities: JSON, CLI args, statistics, benchmarking harness.

pub mod args;
pub mod bench;
pub mod json;
pub mod stats;

pub use args::Args;
pub use json::Json;
