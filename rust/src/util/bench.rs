//! Mini benchmark harness (substrate — criterion is not in the offline
//! vendor). Warmup + timed iterations, median/MAD reporting, and throughput
//! helpers matching the units the paper reports (10⁹ elements/s in Fig. 6,
//! tokens/s in Table 1).

use std::time::Instant;

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    /// Median wall time per iteration, seconds.
    pub median_s: f64,
    /// Median absolute deviation, seconds.
    pub mad_s: f64,
    /// Number of timed iterations.
    pub iters: usize,
}

impl BenchResult {
    /// Elements per second given `n` elements processed per iteration.
    pub fn elems_per_sec(&self, n: usize) -> f64 {
        n as f64 / self.median_s
    }

    /// Giga-elements per second (Fig. 6 unit).
    pub fn gelems_per_sec(&self, n: usize) -> f64 {
        self.elems_per_sec(n) / 1e9
    }
}

/// Benchmark runner configuration.
#[derive(Debug, Clone, Copy)]
pub struct Bencher {
    /// Minimum total measured time before stopping (seconds).
    pub min_time_s: f64,
    /// Warmup iterations.
    pub warmup: usize,
    /// Max timed iterations (cap for very fast functions).
    pub max_iters: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { min_time_s: 0.5, warmup: 2, max_iters: 200 }
    }
}

impl Bencher {
    /// Fast settings for CI-ish runs.
    pub fn quick() -> Self {
        Bencher { min_time_s: 0.15, warmup: 1, max_iters: 50 }
    }

    /// Run `f` repeatedly, returning per-iteration statistics. The closure's
    /// return value is consumed with `std::hint::black_box` to prevent DCE.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> BenchResult {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut times = Vec::new();
        let start = Instant::now();
        while start.elapsed().as_secs_f64() < self.min_time_s && times.len() < self.max_iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            times.push(t0.elapsed().as_secs_f64());
        }
        if times.is_empty() {
            // function slower than min_time; one mandatory sample
            let t0 = Instant::now();
            std::hint::black_box(f());
            times.push(t0.elapsed().as_secs_f64());
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = times[times.len() / 2];
        let mut devs: Vec<f64> = times.iter().map(|t| (t - median).abs()).collect();
        devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mad = devs[devs.len() / 2];
        BenchResult { name: name.to_string(), median_s: median, mad_s: mad, iters: times.len() }
    }
}

/// Pretty-print a row: name, median time, optional throughput.
pub fn report(res: &BenchResult, elems: Option<usize>) {
    match elems {
        Some(n) => println!(
            "{:<42} {:>10.3} ms ± {:>7.3}  {:>9.3} Gelem/s  ({} iters)",
            res.name,
            res.median_s * 1e3,
            res.mad_s * 1e3,
            res.gelems_per_sec(n),
            res.iters
        ),
        None => println!(
            "{:<42} {:>10.3} ms ± {:>7.3}  ({} iters)",
            res.name,
            res.median_s * 1e3,
            res.mad_s * 1e3,
            res.iters
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let b = Bencher { min_time_s: 0.02, warmup: 1, max_iters: 20 };
        let r = b.run("spin", || {
            let mut acc = 0u64;
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(r.median_s > 0.0);
        assert!(r.iters >= 1);
    }

    #[test]
    fn throughput_units() {
        let r = BenchResult { name: "x".into(), median_s: 0.001, mad_s: 0.0, iters: 1 };
        assert_eq!(r.elems_per_sec(1_000_000), 1e9);
        assert_eq!(r.gelems_per_sec(1_000_000), 1.0);
    }
}
