//! Minimal JSON parser/emitter (substrate — serde is not available in the
//! offline vendor). Supports the full JSON grammar except `\u` surrogate
//! pairs are passed through unpaired. Used for the artifact manifest and
//! run logs.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|n| n as u64)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["key"]` convenience; returns Null for missing keys/non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.i, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(_) => {
                    // copy a full UTF-8 scalar
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf8"))?;
                    let ch = s.chars().next().unwrap();
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\r' => write!(f, "\\r")?,
                        '\t' => write!(f, "\\t")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

/// Builder helpers for emitting JSON.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr(items: Vec<Json>) -> Json {
    Json::Arr(items)
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(text: &str) -> Json {
    Json::Str(text.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let src = r#"{"a": 1, "b": [true, null, "x\ny"], "c": {"d": -2.5e3}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").as_f64(), Some(1.0));
        assert_eq!(v.get("b").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").get("d").as_f64(), Some(-2500.0));
        // display then reparse is identity
        let again = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, again);
    }

    #[test]
    fn strings_with_escapes() {
        let v = Json::parse(r#""tab\there é""#).unwrap();
        assert_eq!(v.as_str(), Some("tab\there é"));
    }

    #[test]
    fn errors_have_offsets() {
        let e = Json::parse("{\"a\": }").unwrap_err();
        assert!(e.offset > 0);
        assert!(Json::parse("[1, 2").is_err());
        assert!(Json::parse("01x").is_err());
        assert!(Json::parse("{} junk").is_err());
    }

    #[test]
    fn get_on_missing_is_null() {
        let v = Json::parse("{}").unwrap();
        assert_eq!(*v.get("nope"), Json::Null);
        assert_eq!(*v.get("nope").get("deeper"), Json::Null);
    }

    #[test]
    fn builder_helpers() {
        let v = obj(vec![("name", s("gaussws")), ("n", num(3.0)), ("xs", arr(vec![num(1.0)]))]);
        assert_eq!(v.to_string(), r#"{"n":3,"name":"gaussws","xs":[1]}"#);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
    }
}
