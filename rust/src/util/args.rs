//! Tiny CLI argument parser (substrate — clap is not in the offline vendor).
//!
//! Model: `program <subcommand> [--flag] [--key value] [positional...]`.
//! Long options only; `--key=value` and `--key value` both accepted.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First non-option token (subcommand), if any.
    pub command: Option<String>,
    /// Remaining non-option tokens after the subcommand.
    pub positional: Vec<String>,
    /// `--key value` / `--key=value` pairs; bare `--flag` maps to "true".
    pub options: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of tokens (not including argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Args {
        let mut out = Args::default();
        let toks: Vec<String> = tokens.into_iter().collect();
        let mut i = 0;
        while i < toks.len() {
            let t = &toks[i];
            if let Some(name) = t.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if i + 1 < toks.len() && !toks[i + 1].starts_with("--") {
                    out.options.insert(name.to_string(), toks[i + 1].clone());
                    i += 1;
                } else {
                    out.options.insert(name.to_string(), "true".to_string());
                }
            } else if out.command.is_none() {
                out.command = Some(t.clone());
            } else {
                out.positional.push(t.clone());
            }
            i += 1;
        }
        out
    }

    /// Parse from the real process arguments.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_options() {
        // NB: a bare `--flag` greedily consumes a following non-option
        // token, so boolean flags go last or use `--flag=true`.
        let a = parse("train --config cfg.toml --steps 100 run1 --verbose");
        assert_eq!(a.command.as_deref(), Some("train"));
        assert_eq!(a.get("config"), Some("cfg.toml"));
        assert_eq!(a.usize_or("steps", 0), 100);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["run1"]);
    }

    #[test]
    fn equals_form() {
        let a = parse("bench --size=1024x512 --iters=3");
        assert_eq!(a.get("size"), Some("1024x512"));
        assert_eq!(a.usize_or("iters", 0), 3);
    }

    #[test]
    fn defaults() {
        let a = parse("x");
        assert_eq!(a.get_or("missing", "d"), "d");
        assert_eq!(a.f64_or("lr", 1e-3), 1e-3);
        assert!(!a.flag("nope"));
    }

    #[test]
    fn trailing_flag() {
        let a = parse("run --dry-run");
        assert!(a.flag("dry-run"));
    }
}
