//! Small statistics helpers shared by metrics, benches and tests.

/// Arithmetic mean. Returns 0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// p-th percentile by linear interpolation on a sorted copy.
///
/// Total on its domain: an empty slice gives 0, a single sample is
/// returned at every `p`, `p` is clamped into `[0, 100]` (so `p = 0` is
/// the minimum and `p = 100` the maximum, never an out-of-range index),
/// and NaN samples sort last (`total_cmp`) instead of panicking.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let p = p.clamp(0.0, 100.0);
    let idx = (p / 100.0) * (v.len() - 1) as f64;
    let lo = idx.floor() as usize;
    let hi = idx.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (idx - lo as f64)
    }
}

/// Exact nearest-rank percentile: the rank-`⌈(p/100)·n⌉` order statistic
/// (clamped to rank 1). This is the estimator the telemetry histogram's
/// `quantile` approximates — the two agree within one bucket width, which
/// the telemetry suite checks by property test. Returns 0 when empty.
pub fn percentile_nearest_rank(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let p = p.clamp(0.0, 100.0);
    let rank = (((p / 100.0) * v.len() as f64).ceil() as usize).clamp(1, v.len());
    v[rank - 1]
}

/// Median.
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Geometric mean of positive values; 0 if any non-positive or empty.
/// Used for the Table-1 "geometric mean of the overhead" summary.
pub fn geo_mean(xs: &[f64]) -> f64 {
    if xs.is_empty() || xs.iter().any(|&x| x <= 0.0) {
        return 0.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Exponentially weighted moving average with smoothing `alpha` — the
/// paper's Fig. 4 uses α = 1/16 and 1/128 for loss-curve smoothing.
pub struct Wma {
    alpha: f64,
    state: Option<f64>,
}

impl Wma {
    pub fn new(alpha: f64) -> Self {
        Wma { alpha, state: None }
    }

    /// Feed one sample; returns the updated average.
    pub fn update(&mut self, x: f64) -> f64 {
        let next = match self.state {
            None => x,
            Some(s) => s + self.alpha * (x - s),
        };
        self.state = Some(next);
        next
    }

    pub fn value(&self) -> Option<f64> {
        self.state
    }
}

/// Apply a WMA over a whole series.
pub fn wma_series(xs: &[f64], alpha: f64) -> Vec<f64> {
    let mut w = Wma::new(alpha);
    xs.iter().map(|&x| w.update(x)).collect()
}

/// Windowed maximum (the paper's "maximum loss" columns in Fig. 4).
pub fn windowed_max(xs: &[f64], window: usize) -> Vec<f64> {
    assert!(window > 0);
    xs.iter()
        .enumerate()
        .map(|(i, _)| {
            let lo = i.saturating_sub(window - 1);
            xs[lo..=i].iter().cloned().fold(f64::NEG_INFINITY, f64::max)
        })
        .collect()
}

/// Fixed-bin histogram over [lo, hi); values outside are clamped into the
/// edge bins.
pub fn histogram(xs: &[f64], lo: f64, hi: f64, bins: usize) -> Vec<usize> {
    assert!(bins > 0 && hi > lo);
    let mut h = vec![0usize; bins];
    let w = (hi - lo) / bins as f64;
    for &x in xs {
        let idx = (((x - lo) / w) as isize).clamp(0, bins as isize - 1) as usize;
        h[idx] += 1;
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert_eq!(mean(&xs), 5.0);
        assert!((std_dev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(median(&xs), 3.0);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 25.0), 2.0);
    }

    #[test]
    fn percentile_edge_cases() {
        // empty slice: defined, zero
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(percentile_nearest_rank(&[], 95.0), 0.0);
        // single sample: returned at every p
        for p in [0.0, 25.0, 50.0, 99.0, 100.0] {
            assert_eq!(percentile(&[42.0], p), 42.0, "p={p}");
            assert_eq!(percentile_nearest_rank(&[42.0], p), 42.0, "p={p}");
        }
        // out-of-range p clamps instead of indexing out of bounds
        let xs = [1.0, 2.0, 3.0];
        assert_eq!(percentile(&xs, -10.0), 1.0);
        assert_eq!(percentile(&xs, 250.0), 3.0);
        assert_eq!(percentile_nearest_rank(&xs, -10.0), 1.0);
        assert_eq!(percentile_nearest_rank(&xs, 250.0), 3.0);
        // NaN samples sort last instead of panicking
        let with_nan = [2.0, f64::NAN, 1.0];
        assert_eq!(percentile(&with_nan, 0.0), 1.0);
        assert!(percentile(&with_nan, 100.0).is_nan());
    }

    #[test]
    fn nearest_rank_matches_order_statistics() {
        let xs = [5.0, 1.0, 4.0, 2.0, 3.0];
        assert_eq!(percentile_nearest_rank(&xs, 0.0), 1.0); // rank clamps to 1
        assert_eq!(percentile_nearest_rank(&xs, 20.0), 1.0); // ceil(0.2*5) = 1
        assert_eq!(percentile_nearest_rank(&xs, 50.0), 3.0); // ceil(0.5*5) = 3
        assert_eq!(percentile_nearest_rank(&xs, 61.0), 4.0); // ceil(0.61*5) = 4
        assert_eq!(percentile_nearest_rank(&xs, 100.0), 5.0);
    }

    #[test]
    fn geo_mean_basic() {
        assert!((geo_mean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert_eq!(geo_mean(&[1.0, -1.0]), 0.0);
    }

    #[test]
    fn wma_converges_to_constant() {
        let mut w = Wma::new(1.0 / 16.0);
        let mut last = 0.0;
        for _ in 0..1000 {
            last = w.update(3.5);
        }
        assert!((last - 3.5).abs() < 1e-9);
    }

    #[test]
    fn wma_first_sample_is_identity() {
        let mut w = Wma::new(0.125);
        assert_eq!(w.update(7.0), 7.0);
    }

    #[test]
    fn windowed_max_tracks_spikes() {
        let xs = [1.0, 5.0, 2.0, 2.0, 2.0, 2.0];
        let m = windowed_max(&xs, 3);
        assert_eq!(m, vec![1.0, 5.0, 5.0, 5.0, 2.0, 2.0]);
    }

    #[test]
    fn histogram_counts() {
        let xs = [0.1, 0.2, 0.9, -5.0, 5.0];
        let h = histogram(&xs, 0.0, 1.0, 2);
        assert_eq!(h, vec![3, 2]);
    }
}
