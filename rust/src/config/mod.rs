//! Configuration system: minimal TOML parser + typed run-config schema.

pub mod schema;
pub mod toml;

pub use schema::{Arch, ModelConfig, Optimizer, PqtConfig, PqtMethod, RunConfig, TrainConfig};
pub use toml::{parse as parse_toml, TomlDoc, TomlValue};
