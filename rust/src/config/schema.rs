//! Typed configuration schema + validation, loaded from the TOML subset in
//! [`super::toml`]. Mirrors the paper's Appendix E hyperparameter table.

use super::toml::{parse, TomlDoc};
use anyhow::{bail, Context, Result};

/// Transformer architecture family.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Arch {
    /// GPT2-style: LayerNorm, GELU MLP, fused qkv; 4 linears per block
    /// (qkv, out, up, down).
    Gpt2,
    /// Llama2-style: RMSNorm, SwiGLU, rotary embeddings; 7 linears per
    /// block (q, k, v, out, gate, down, up).
    Llama2,
}

impl Arch {
    pub fn parse(s: &str) -> Result<Arch> {
        match s.to_ascii_lowercase().as_str() {
            "gpt2" => Ok(Arch::Gpt2),
            "llama2" | "llama" => Ok(Arch::Llama2),
            other => bail!("unknown arch '{other}' (expected gpt2|llama2)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Arch::Gpt2 => "gpt2",
            Arch::Llama2 => "llama2",
        }
    }

    /// Linear-layer names per transformer block, in paper order (Fig. 5).
    pub fn linear_names(&self) -> &'static [&'static str] {
        match self {
            Arch::Gpt2 => &["qkv", "out", "up", "down"],
            Arch::Llama2 => &["q", "k", "v", "out", "gate", "down", "up"],
        }
    }
}

/// Model shape configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub arch: Arch,
    pub n_layer: usize,
    pub d_model: usize,
    pub n_head: usize,
    pub d_ff: usize,
    pub vocab: usize,
    pub seq_len: usize,
}

impl ModelConfig {
    /// A tiny config for experiments on the 1-core CPU testbed.
    pub fn tiny(arch: Arch) -> ModelConfig {
        ModelConfig { arch, n_layer: 2, d_model: 64, n_head: 2, d_ff: 128, vocab: 256, seq_len: 64 }
    }

    /// Approximate parameter count (embeddings + blocks + head tied).
    pub fn param_count(&self) -> usize {
        let d = self.d_model;
        let per_block = match self.arch {
            // qkv (d,3d) + out (d,d) + up (d,ff) + down (ff,d)
            Arch::Gpt2 => d * 3 * d + d * d + d * self.d_ff + self.d_ff * d,
            // q,k,v,out (d,d each) + gate,up (d,ff) + down (ff,d)
            Arch::Llama2 => 4 * d * d + 2 * d * self.d_ff + self.d_ff * d,
        };
        self.vocab * d + self.n_layer * per_block
    }

    pub fn validate(&self) -> Result<()> {
        if self.d_model % self.n_head != 0 {
            bail!("d_model {} not divisible by n_head {}", self.d_model, self.n_head);
        }
        if self.n_layer == 0 || self.vocab < 2 || self.seq_len == 0 {
            bail!("degenerate model config: {self:?}");
        }
        Ok(())
    }
}

/// PQT method selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PqtMethod {
    /// Plain BF16 baseline — no noise.
    None,
    /// The paper's Gaussian weight sampling (rounded-normal R).
    GaussWs,
    /// DiffQ-style uniform U(-0.5, 0.5) R (the paper's extension of DiffQ).
    DiffQ,
}

impl PqtMethod {
    pub fn parse(s: &str) -> Result<PqtMethod> {
        match s.to_ascii_lowercase().as_str() {
            "none" | "bf16" | "baseline" => Ok(PqtMethod::None),
            "gaussws" | "gauss" => Ok(PqtMethod::GaussWs),
            "diffq" => Ok(PqtMethod::DiffQ),
            other => bail!("unknown pqt method '{other}'"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            PqtMethod::None => "bf16",
            PqtMethod::GaussWs => "gaussws",
            PqtMethod::DiffQ => "diffq",
        }
    }
}

/// PQT configuration (paper §3.6 + §4 settings).
#[derive(Debug, Clone, PartialEq)]
pub struct PqtConfig {
    pub method: PqtMethod,
    /// Which linear layers get PQT, e.g. ["all"], ["qkv"], ["out","down"].
    pub parts: Vec<String>,
    /// Square block size b_l (paper: 32).
    pub block: usize,
    /// Initial bitwidth b_init (paper default 6).
    pub b_init: f64,
    /// Target bitwidth b_target (paper default 4).
    pub b_target: f64,
    /// Weight decay applied to the internal b_i parameter.
    pub bi_weight_decay: f64,
    /// Optional λ for the Eq. 12 bitwidth loss (0 disables).
    pub lambda: f64,
    /// ŵ cast scheme (the paper's "BF16 operator" by default), resolved
    /// from `pqt.cast = "<label>"` through [`crate::quant::Registry`].
    pub cast: crate::quant::Scheme,
}

impl Default for PqtConfig {
    fn default() -> Self {
        PqtConfig {
            method: PqtMethod::GaussWs,
            parts: vec!["all".into()],
            block: 32,
            b_init: 6.0,
            b_target: 4.0,
            bi_weight_decay: 0.1,
            lambda: 0.0,
            cast: crate::quant::resolve("bf16").expect("builtin scheme"),
        }
    }
}

/// Optimizer selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Optimizer {
    AdamW,
    AdamMini,
}

impl Optimizer {
    pub fn parse(s: &str) -> Result<Optimizer> {
        match s.to_ascii_lowercase().as_str() {
            "adamw" => Ok(Optimizer::AdamW),
            "adam-mini" | "adamini" | "adam_mini" => Ok(Optimizer::AdamMini),
            other => bail!("unknown optimizer '{other}'"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Optimizer::AdamW => "adamw",
            Optimizer::AdamMini => "adam-mini",
        }
    }
}

/// Training-loop configuration (Appendix E shape).
#[derive(Debug, Clone, PartialEq)]
pub struct TrainConfig {
    pub steps: usize,
    pub warmup_steps: usize,
    pub max_lr: f64,
    pub min_lr: f64,
    pub batch: usize,
    pub grad_accum: usize,
    pub weight_decay: f64,
    pub optimizer: Optimizer,
    pub seed: u64,
    /// Simulated data-parallel worker count (the paper used 8 GPUs).
    pub workers: usize,
    /// Adam betas / eps.
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    /// Gradient clipping (global L2 norm); 0 disables.
    pub grad_clip: f64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            steps: 200,
            warmup_steps: 20,
            max_lr: 6e-4,
            min_lr: 6e-5,
            batch: 8,
            grad_accum: 1,
            weight_decay: 0.1,
            optimizer: Optimizer::AdamW,
            seed: 1234,
            workers: 1,
            beta1: 0.9,
            beta2: 0.95,
            eps: 1e-8,
            grad_clip: 1.0,
        }
    }
}

/// A full run configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct RunConfig {
    pub name: String,
    pub model: ModelConfig,
    pub pqt: PqtConfig,
    pub train: TrainConfig,
    /// Where AOT artifacts live.
    pub artifacts_dir: String,
    /// Where run outputs (CSV/JSON logs, checkpoints) go.
    pub out_dir: String,
}

impl RunConfig {
    /// Parse from TOML text.
    pub fn from_toml_str(text: &str) -> Result<RunConfig> {
        let doc = parse(text).context("parsing run config")?;
        Self::from_doc(&doc)
    }

    /// Load from a file path.
    pub fn load(path: &str) -> Result<RunConfig> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading config {path}"))?;
        Self::from_toml_str(&text)
    }

    pub fn from_doc(doc: &TomlDoc) -> Result<RunConfig> {
        let arch = Arch::parse(&doc.str_or("model.arch", "gpt2"))?;
        let tiny = ModelConfig::tiny(arch);
        let model = ModelConfig {
            arch,
            n_layer: doc.i64_or("model.n_layer", tiny.n_layer as i64) as usize,
            d_model: doc.i64_or("model.d_model", tiny.d_model as i64) as usize,
            n_head: doc.i64_or("model.n_head", tiny.n_head as i64) as usize,
            d_ff: doc.i64_or("model.d_ff", tiny.d_ff as i64) as usize,
            vocab: doc.i64_or("model.vocab", tiny.vocab as i64) as usize,
            seq_len: doc.i64_or("model.seq_len", tiny.seq_len as i64) as usize,
        };
        model.validate()?;
        let pd = PqtConfig::default();
        let parts = match doc.get("pqt.parts") {
            Some(v) => v
                .as_arr()
                .context("pqt.parts must be an array")?
                .iter()
                .map(|x| x.as_str().map(String::from).context("pqt.parts items must be strings"))
                .collect::<Result<Vec<_>>>()?,
            None => pd.parts.clone(),
        };
        let pqt = PqtConfig {
            method: PqtMethod::parse(&doc.str_or("pqt.method", "gaussws"))?,
            parts,
            block: doc.i64_or("pqt.block", pd.block as i64) as usize,
            b_init: doc.f64_or("pqt.b_init", pd.b_init),
            b_target: doc.f64_or("pqt.b_target", pd.b_target),
            bi_weight_decay: doc.f64_or("pqt.bi_weight_decay", pd.bi_weight_decay),
            lambda: doc.f64_or("pqt.lambda", pd.lambda),
            cast: crate::quant::resolve(&doc.str_or("pqt.cast", "bf16"))
                .context("pqt.cast")?,
        };
        let td = TrainConfig::default();
        let train = TrainConfig {
            steps: doc.i64_or("train.steps", td.steps as i64) as usize,
            warmup_steps: doc.i64_or("train.warmup_steps", td.warmup_steps as i64) as usize,
            max_lr: doc.f64_or("train.max_lr", td.max_lr),
            min_lr: doc.f64_or("train.min_lr", td.min_lr),
            batch: doc.i64_or("train.batch", td.batch as i64) as usize,
            grad_accum: doc.i64_or("train.grad_accum", td.grad_accum as i64) as usize,
            weight_decay: doc.f64_or("train.weight_decay", td.weight_decay),
            optimizer: Optimizer::parse(&doc.str_or("train.optimizer", "adamw"))?,
            seed: doc.i64_or("train.seed", td.seed as i64) as u64,
            workers: doc.i64_or("train.workers", td.workers as i64) as usize,
            beta1: doc.f64_or("train.beta1", td.beta1),
            beta2: doc.f64_or("train.beta2", td.beta2),
            eps: doc.f64_or("train.eps", td.eps),
            grad_clip: doc.f64_or("train.grad_clip", td.grad_clip),
        };
        if train.steps == 0 || train.batch == 0 || train.workers == 0 {
            bail!("degenerate train config: {train:?}");
        }
        if train.min_lr > train.max_lr {
            bail!("min_lr {} > max_lr {}", train.min_lr, train.max_lr);
        }
        Ok(RunConfig {
            name: doc.str_or("name", "run"),
            model,
            pqt,
            train,
            artifacts_dir: doc.str_or("artifacts_dir", "artifacts"),
            out_dir: doc.str_or("out_dir", "runs"),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_fill_in() {
        let c = RunConfig::from_toml_str("name = \"t\"").unwrap();
        assert_eq!(c.model.arch, Arch::Gpt2);
        assert_eq!(c.pqt.method, PqtMethod::GaussWs);
        assert_eq!(c.pqt.b_init, 6.0);
        assert_eq!(c.pqt.b_target, 4.0);
        assert_eq!(c.train.optimizer, Optimizer::AdamW);
        use crate::quant::QuantScheme;
        assert_eq!(c.pqt.cast.label(), "bf16");
    }

    #[test]
    fn pqt_cast_parses_through_registry() {
        use crate::quant::QuantScheme;
        let c = RunConfig::from_toml_str("[pqt]\ncast = \"fp8_e4m3\"").unwrap();
        assert_eq!(c.pqt.cast.label(), "fp8_e4m3");
        let err = RunConfig::from_toml_str("[pqt]\ncast = \"fp9_bogus\"").unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("unknown quant scheme"), "{msg}");
        assert!(msg.contains("fp8_e3m4"), "error should list available labels: {msg}");
    }

    #[test]
    fn full_config_parses() {
        let c = RunConfig::from_toml_str(
            r#"
name = "fig4-small"
[model]
arch = "llama2"
n_layer = 4
d_model = 128
n_head = 4
d_ff = 256
vocab = 512
seq_len = 128
[pqt]
method = "diffq"
parts = ["out", "down"]
b_init = 8
b_target = 6
[train]
steps = 500
optimizer = "adam-mini"
max_lr = 1e-3
min_lr = 1e-4
workers = 4
"#,
        )
        .unwrap();
        assert_eq!(c.model.arch, Arch::Llama2);
        assert_eq!(c.pqt.method, PqtMethod::DiffQ);
        assert_eq!(c.pqt.parts, vec!["out", "down"]);
        assert_eq!(c.pqt.b_init, 8.0);
        assert_eq!(c.train.workers, 4);
        assert_eq!(c.train.optimizer, Optimizer::AdamMini);
    }

    #[test]
    fn validation_rejects_bad_heads() {
        let r = RunConfig::from_toml_str("[model]\nd_model = 65\nn_head = 2");
        assert!(r.is_err());
    }

    #[test]
    fn validation_rejects_inverted_lr() {
        let r = RunConfig::from_toml_str("[train]\nmax_lr = 1e-5\nmin_lr = 1e-3");
        assert!(r.is_err());
    }

    #[test]
    fn param_count_sane() {
        let m = ModelConfig::tiny(Arch::Gpt2);
        // vocab*d + 2 blocks * (3dd + dd + 2*d*ff)
        let d = 64;
        let expect = 256 * d + 2 * (3 * d * d + d * d + 2 * d * 128);
        assert_eq!(m.param_count(), expect);
    }

    #[test]
    fn linear_names_match_fig5_order() {
        assert_eq!(Arch::Gpt2.linear_names(), &["qkv", "out", "up", "down"]);
        assert_eq!(Arch::Llama2.linear_names(), &["q", "k", "v", "out", "gate", "down", "up"]);
    }
}
