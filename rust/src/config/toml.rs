//! Minimal TOML parser (substrate). Supports the subset used by the
//! launcher configs: `[table]` / `[table.sub]` headers, `key = value` with
//! strings, integers, floats, booleans, and flat arrays. No multi-line
//! strings, no inline tables, no array-of-tables.

use std::collections::BTreeMap;

/// A TOML value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[TomlValue]> {
        match self {
            TomlValue::Arr(a) => Some(a),
            _ => None,
        }
    }
}

/// Parsed document: dotted-path keys (`"table.sub.key"`) to values.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TomlDoc {
    pub entries: BTreeMap<String, TomlValue>,
}

impl TomlDoc {
    pub fn get(&self, path: &str) -> Option<&TomlValue> {
        self.entries.get(path)
    }

    pub fn str_or(&self, path: &str, default: &str) -> String {
        self.get(path).and_then(|v| v.as_str()).unwrap_or(default).to_string()
    }

    pub fn i64_or(&self, path: &str, default: i64) -> i64 {
        self.get(path).and_then(|v| v.as_i64()).unwrap_or(default)
    }

    pub fn f64_or(&self, path: &str, default: f64) -> f64 {
        self.get(path).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn bool_or(&self, path: &str, default: bool) -> bool {
        self.get(path).and_then(|v| v.as_bool()).unwrap_or(default)
    }

    /// All keys under a table prefix (e.g. `"model"` → `model.*`).
    pub fn keys_under(&self, prefix: &str) -> Vec<&str> {
        let pfx = format!("{prefix}.");
        self.entries.keys().filter(|k| k.starts_with(&pfx)).map(|k| k.as_str()).collect()
    }
}

/// Parse error with 1-based line number.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for TomlError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "toml error on line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

/// Parse a TOML document (see module docs for the supported subset).
pub fn parse(text: &str) -> Result<TomlDoc, TomlError> {
    let mut doc = TomlDoc::default();
    let mut prefix = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = lineno + 1;
        let t = strip_comment(raw).trim();
        if t.is_empty() {
            continue;
        }
        if let Some(rest) = t.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or(TomlError { line, msg: "unterminated table header".into() })?
                .trim();
            if name.is_empty() {
                return Err(TomlError { line, msg: "empty table name".into() });
            }
            prefix = name.to_string();
            continue;
        }
        let (key, val) = t
            .split_once('=')
            .ok_or(TomlError { line, msg: format!("expected key = value, got '{t}'") })?;
        let key = key.trim();
        if key.is_empty() {
            return Err(TomlError { line, msg: "empty key".into() });
        }
        let value = parse_value(val.trim(), line)?;
        let path =
            if prefix.is_empty() { key.to_string() } else { format!("{prefix}.{key}") };
        doc.entries.insert(path, value);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // a '#' outside quotes starts a comment
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str, line: usize) -> Result<TomlValue, TomlError> {
    if s.is_empty() {
        return Err(TomlError { line, msg: "empty value".into() });
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or(TomlError { line, msg: "unterminated string".into() })?;
        return Ok(TomlValue::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner =
            rest.strip_suffix(']').ok_or(TomlError { line, msg: "unterminated array".into() })?;
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(TomlValue::Arr(vec![]));
        }
        let items = split_top_level(inner)
            .into_iter()
            .map(|item| parse_value(item.trim(), line))
            .collect::<Result<Vec<_>, _>>()?;
        return Ok(TomlValue::Arr(items));
    }
    match s {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.replace('_', "").parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = s.replace('_', "").parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(TomlError { line, msg: format!("cannot parse value '{s}'") })
}

/// Split an array body on commas that are not inside quotes or brackets.
fn split_top_level(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(&s[start..]);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_document() {
        let doc = parse(
            r#"
# run config
name = "fig1b"
seed = 1234

[model]
arch = "gpt2"
n_layer = 4
rotary = false

[train]
lr = 6e-4
steps = 1_000
parts = ["qkv", "out"]
"#,
        )
        .unwrap();
        assert_eq!(doc.str_or("name", ""), "fig1b");
        assert_eq!(doc.i64_or("seed", 0), 1234);
        assert_eq!(doc.str_or("model.arch", ""), "gpt2");
        assert_eq!(doc.i64_or("model.n_layer", 0), 4);
        assert!(!doc.bool_or("model.rotary", true));
        assert_eq!(doc.f64_or("train.lr", 0.0), 6e-4);
        assert_eq!(doc.i64_or("train.steps", 0), 1000);
        let parts = doc.get("train.parts").unwrap().as_arr().unwrap();
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[0].as_str(), Some("qkv"));
    }

    #[test]
    fn comments_and_strings_with_hash() {
        let doc = parse("k = \"a#b\" # trailing").unwrap();
        assert_eq!(doc.str_or("k", ""), "a#b");
    }

    #[test]
    fn int_promotes_to_f64() {
        let doc = parse("x = 3").unwrap();
        assert_eq!(doc.f64_or("x", 0.0), 3.0);
    }

    #[test]
    fn errors_carry_lines() {
        let e = parse("a = 1\nbroken").unwrap_err();
        assert_eq!(e.line, 2);
        assert!(parse("[open\n").is_err());
        assert!(parse("x = \"unterminated").is_err());
    }

    #[test]
    fn nested_table_names() {
        let doc = parse("[a.b]\nc = 1").unwrap();
        assert_eq!(doc.i64_or("a.b.c", 0), 1);
        assert_eq!(doc.keys_under("a.b"), vec!["a.b.c"]);
    }

    #[test]
    fn arrays_of_numbers() {
        let doc = parse("xs = [1, 2.5, 3]").unwrap();
        let xs = doc.get("xs").unwrap().as_arr().unwrap();
        assert_eq!(xs[1].as_f64(), Some(2.5));
    }
}
