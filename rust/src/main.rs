//! `gaussws` — the L3/L4 launcher.
//!
//! Subcommands:
//!   train   --artifact <tag> [--steps N --workers K --lr X --optimizer O]
//!   train   --config <file.toml>
//!   exp     fig1b|fig3a|fig3b|fig4|fig5|figf1  [--steps N --out runs]
//!   tables  c1|b1
//!   demo    figd1
//!   quantize --checkpoint ck --artifact tag [--formats bf16,fp8_e3m4,...]
//!           (Table C.1 on a checkpoint; labels resolve via quant::Registry)
//!   serve   [--checkpoint ck | --snapshot s.gwqs] --store fp8_e3m4
//!           (quantized-snapshot serving engine + self-driven load;
//!            --spec-draft enables self-speculative decoding via a
//!            lower-bit draft store (greedy outputs unchanged),
//!            --trace-out exports per-request Chrome trace timelines,
//!            --metrics-every prints telemetry registry snapshots;
//!            --listen ADDR serves over TCP — length-prefixed
//!            newline-JSON frames — until stdin closes, then drains)
//!   load    <scenario> | --spec workload.toml  [--driver direct|in-process|tcp]
//!           (declarative workload corpus: bursty-chat, long-doc-prefill,
//!            many-short, preemption-storm; `load --list` prints it)
//!   info    (list artifacts in the manifest + registered quant schemes)

use anyhow::{bail, Context, Result};
use gaussws::config::schema::{Arch, ModelConfig, Optimizer, RunConfig, TrainConfig};
use gaussws::coordinator::Trainer;
use gaussws::exp;
use gaussws::runtime::Runtime;
use gaussws::util::Args;

fn main() {
    let args = Args::from_env();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> Result<()> {
    match args.command.as_deref() {
        Some("train") => cmd_train(args),
        Some("exp") => cmd_exp(args),
        Some("tables") => cmd_tables(args),
        Some("demo") => cmd_demo(args),
        Some("info") => cmd_info(args),
        Some("quantize") => cmd_quantize(args),
        Some("serve") => cmd_serve(args),
        Some("load") => cmd_load(args),
        Some(other) => {
            bail!("unknown subcommand '{other}' (try: train|exp|tables|demo|quantize|serve|load|info)")
        }
        None => {
            print_usage();
            Ok(())
        }
    }
}

fn print_usage() {
    println!(
        "gaussws — Gaussian Weight Sampling PQT framework\n\
         \n\
         usage:\n\
         \x20 gaussws train --artifact tiny_gpt2.gaussws_all [--steps 200] [--workers 1]\n\
         \x20                [--lr 6e-4] [--optimizer adamw|adam-mini] [--seed 1234]\n\
         \x20                [--checkpoint out.ck] [--artifacts-dir artifacts]\n\
         \x20 gaussws train --config configs/run.toml\n\
         \x20 gaussws exp fig1b|fig3a|fig3b|fig4|fig5|figf1|stability [--steps 120] [--out runs]\n\
         \x20 gaussws tables c1|b1\n\
         \x20 gaussws demo figd1\n\
         \x20 gaussws quantize --checkpoint runs/x.ck --artifact tiny_gpt2.gaussws_all\n\
         \x20                  [--formats bf16,fp8_e3m4,int8_sr,...]   (see `gaussws info`)\n\
         \x20 gaussws serve [--checkpoint runs/x.ck | --snapshot w.gwqs] [--store fp8_e3m4]\n\
         \x20               [--arch gpt2 --n-layer 2 --d-model 64 --n-head 2 --d-ff 128\n\
         \x20                --vocab 256 --seq-len 64] [--save-snapshot w.gwqs]\n\
         \x20               [--requests 32 --max-batch 8 --threads N]\n\
         \x20               [--kv-block 16 --kv-blocks 0(auto) --prefill-chunk 8]\n\
         \x20               [--kv-store f32|fp8_e3m4|int8_sr|... (KV arena quantization)]\n\
         \x20               [--kv-mirror (debug: keep an f32 decode mirror beside the codes)]\n\
         \x20               [--spec-draft fp4_e2m1_sr --spec-k 4 (self-speculative decoding:\n\
         \x20                draft via a lower-bit weight store, verify in one wave;\n\
         \x20                greedy outputs stay bit-identical)]\n\
         \x20               [--no-wave-batch (debug: per-sequence decode instead of the\n\
         \x20                weight-stationary batched wave; outputs are bit-identical)]\n\
         \x20               [--no-prefix-cache] [--shared-prefix 0]\n\
         \x20               [--prompt-len 16 --max-new 24 --temperature 0 --top-k 0]\n\
         \x20               [--eval=true] [--bench-out runs/BENCH_serve.json]\n\
         \x20               [--trace-out trace.jsonl (per-request Chrome trace timeline)]\n\
         \x20               [--metrics-every N (print a registry snapshot every N waves)]\n\
         \x20               [--listen 127.0.0.1:7433 (serve over TCP until stdin closes;\n\
         \x20                --max-pending 64 --retry-after-ms 50 --default-deadline-ms D)]\n\
         \x20 gaussws load bursty-chat|long-doc-prefill|many-short|preemption-storm\n\
         \x20              [--driver in-process|direct|tcp] [--seed 1234]\n\
         \x20              [--bench-out runs/BENCH_serve.json]\n\
         \x20 gaussws load --spec workload.toml   (a [workload] table; see README)\n\
         \x20 gaussws load --list\n\
         \x20 gaussws info"
    );
}

fn artifacts_dir(args: &Args) -> String {
    args.get_or("artifacts-dir", "artifacts").to_string()
}

fn cmd_train(args: &Args) -> Result<()> {
    let (artifact, cfg, name) = if let Some(path) = args.get("config") {
        let rc = RunConfig::load(path)?;
        // the artifact tag is derived from the config's model+pqt or given
        // explicitly via --artifact
        let artifact = args
            .get("artifact")
            .map(String::from)
            .unwrap_or_else(|| format!("tiny_{}.{}_all", rc.model.arch.name(), rc.pqt.method.name()));
        (artifact, rc.train, rc.name)
    } else {
        let artifact = args
            .get("artifact")
            .context("--artifact or --config required (see `gaussws info` for tags)")?
            .to_string();
        let steps = args.usize_or("steps", 200);
        let max_lr = args.f64_or("lr", 6e-4);
        let cfg = TrainConfig {
            steps,
            warmup_steps: args.usize_or("warmup", (steps / 10).max(1)),
            max_lr,
            min_lr: args.f64_or("min-lr", max_lr / 10.0),
            batch: 0, // batch comes from the artifact; field unused here
            optimizer: Optimizer::parse(args.get_or("optimizer", "adamw"))?,
            workers: args.usize_or("workers", 1),
            seed: args.u64_or("seed", 1234),
            grad_accum: args.usize_or("grad-accum", 1),
            ..Default::default()
        };
        (artifact.clone(), cfg, artifact)
    };

    let steps = cfg.steps;
    let runtime = Runtime::new(&artifacts_dir(args))?;
    println!("platform: {}", runtime.platform());
    let mut t = Trainer::new(runtime, &artifact, cfg, &name)?;
    println!(
        "training '{artifact}' — {} params, {} PQT layers, {} tok/step",
        t.params.values().map(|v| v.len()).sum::<usize>(),
        t.bi.len(),
        t.tokens_per_step()
    );
    t.run(steps, args.usize_or("print-every", 10))?;
    let out = args.get_or("out", "runs");
    t.log.write_to(out)?;
    println!("wrote {out}/{}.csv  ({:.0} tok/s)", t.log.name, t.log.tokens_per_sec());
    if let Some(ck) = args.get("checkpoint") {
        t.save_checkpoint(ck)?;
        println!("checkpoint -> {ck}");
    }
    if !t.bi.is_empty() {
        println!("{}", exp::render_fig5(&exp::fig5_report(&t)));
    }
    Ok(())
}

fn cmd_exp(args: &Args) -> Result<()> {
    let which = args.positional.first().map(String::as_str).or(args
        .command
        .as_deref()
        .filter(|_| false))
        .context("exp needs a figure id: fig1b|fig3a|fig3b|fig4|fig5|figf1|stability")?;
    let steps = args.usize_or("steps", 120);
    let out = args.get_or("out", "runs");
    let dir = artifacts_dir(args);
    let workers = args.usize_or("workers", 1);
    let seed = args.u64_or("seed", 1234);
    let lr = args.f64_or("lr", 6e-4);

    match which {
        "fig1b" => {
            exp::run_figure("fig1b", &exp::fig1b_arms(lr, lr / 10.0), &dir, out, steps, workers, seed)?;
        }
        "fig3a" => {
            exp::run_figure("fig3a", &exp::fig3a_arms(lr), &dir, out, steps, workers, seed)?;
        }
        "fig3b" => {
            exp::run_figure("fig3b", &exp::fig3b_arms(lr), &dir, out, steps, workers, seed)?;
        }
        "fig4" => {
            let arms = exp::fig4_arms(args.f64_or("lr", 1e-3));
            exp::run_figure("fig4", &arms, &dir, out, steps, workers, seed)?;
        }
        "figf1" => {
            let arms = exp::figf1_arms(args.f64_or("lr", 1e-3));
            exp::run_figure("figf1", &arms, &dir, out, steps, workers, seed)?;
        }
        "stability" => {
            let lrs = [3e-3, 1e-2, 3e-2];
            let arms = exp::stability_arms(&lrs);
            let ts = exp::run_figure("stability", &arms, &dir, out, steps, workers, seed)?;
            println!("\narm -> diverged?");
            for (arm, t) in arms.iter().zip(&ts) {
                println!(
                    "  {:<28} {}",
                    arm.label,
                    if t.log.divergences.is_empty() { "stable".to_string() } else { format!("DIVERGED @ step {}", t.log.divergences[0]) }
                );
            }
        }
        "fig5" => {
            // train the two PQT archs briefly, then report b_t statistics
            for tag in ["tiny_gpt2.gaussws_all", "tiny_llama2.gaussws_all"] {
                let arm = exp::Arm::new(tag, tag, lr);
                let t = exp::run_arm(&dir, &arm, steps, workers, seed)?;
                println!("\n== {tag} after {steps} steps ==");
                println!("{}", exp::render_fig5(&exp::fig5_report(&t)));
            }
        }
        other => bail!("unknown experiment '{other}'"),
    }
    Ok(())
}

fn cmd_tables(args: &Args) -> Result<()> {
    match args.positional.first().map(String::as_str) {
        Some("c1") => print!("{}", exp::render_table_c1()),
        Some("b1") => print!("{}", exp::render_table_b1()),
        _ => bail!("tables needs c1|b1"),
    }
    Ok(())
}

fn cmd_demo(args: &Args) -> Result<()> {
    match args.positional.first().map(String::as_str) {
        Some("figd1") => print!("{}", exp::render_figd1(args.u64_or("seed", 2026))),
        _ => bail!("demo needs figd1"),
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let m = gaussws::runtime::Manifest::load(artifacts_dir(args))?;
    println!("{} artifacts in {}/manifest.json:", m.artifacts.len(), m.dir.display());
    for (name, a) in &m.artifacts {
        println!(
            "  {:<36} {:<5} {:>2} in / {:>2} out{}",
            name,
            a.kind,
            a.inputs.len(),
            a.outputs.len(),
            a.meta_str("method").map(|s| format!("  [{s}]")).unwrap_or_default()
        );
    }
    println!();
    println!("registered quant schemes (train ŵ cast / snapshot / serve --store):");
    print!("{}", gaussws::quant::Registry::global().render_table());
    Ok(())
}

/// `gaussws quantize`: load a training checkpoint into the pure-rust
/// transformer and report eval loss with the linear weights fake-quantized
/// (square 32x32 MX blocks) to each Table-C.1 datatype — the deployment-
/// side validation of the paper's low-precision-FP claim.
fn cmd_quantize(args: &Args) -> Result<()> {
    use gaussws::config::schema::{Arch, ModelConfig};
    use gaussws::coordinator::Checkpoint;
    use gaussws::data::{SynthCorpus, SynthSpec};
    use gaussws::nn::tensor::Mat;
    use gaussws::nn::transformer::{Params, Transformer};
    use gaussws::quant::QuantScheme;

    let ck_path = args.get("checkpoint").context("--checkpoint required")?;
    // resolve every requested scheme up front: an unknown label fails with
    // the full list of registered labels before any heavy lifting
    let mut schemes = Vec::new();
    for label in args
        .get_or("formats", "bf16,fp12_e4m7,fp8_e3m4,fp8_e4m3,fp6_e3m2,fp4_e2m1")
        .split(',')
        .map(str::trim)
        .filter(|l| !l.is_empty())
    {
        schemes.push(gaussws::quant::resolve(label)?);
    }
    let tag = args.get("artifact").context("--artifact required (for shapes/meta)")?;
    let m = gaussws::runtime::Manifest::load(artifacts_dir(args))?;
    let spec = m.get(&format!("{}.train", tag.trim_end_matches(".train")))?;
    let arch = Arch::parse(spec.meta_str("arch").context("meta.arch")?)?;
    let cfg = ModelConfig {
        arch,
        n_layer: spec.meta_usize("n_layer").context("n_layer")?,
        d_model: spec.meta_usize("d_model").context("d_model")?,
        n_head: spec.meta_usize("n_head").context("n_head")?,
        d_ff: spec.meta_usize("d_ff").context("d_ff")?,
        vocab: spec.meta_usize("vocab").context("vocab")?,
        seq_len: spec.meta_usize("seq_len").context("seq_len")?,
    };
    let ck = Checkpoint::load(ck_path)?;
    let mut tensors = std::collections::BTreeMap::new();
    for name in spec.param_names() {
        let shape = spec.param_shape(&name).context("shape")?;
        let data = ck.get(&format!("param.{name}"))?.clone();
        let (rows, cols) = match shape.len() {
            2 => (shape[0], shape[1]),
            _ => (1, shape[0]),
        };
        tensors.insert(name, Mat::from_vec(rows, cols, data));
    }
    let params = Params { tensors };
    let model = Transformer::new(cfg.clone());

    // held-out windows from the same corpus family as training
    let corpus = SynthCorpus::generate(SynthSpec {
        vocab: cfg.vocab,
        len: 1 << 16,
        seed: args.u64_or("seed", 1234) ^ 0xC0FFEE,
        ..Default::default()
    });
    let eval = |p: &Params| -> f64 {
        let mut total = 0.0;
        let n = 8;
        let seq = cfg.seq_len.min(64);
        for k in 0..n {
            let start = 500 + k * 1500;
            let toks: Vec<usize> =
                corpus.tokens[start..start + seq + 1].iter().map(|&t| t as usize).collect();
            total += model.loss(p, &toks);
        }
        total / n as f64
    };

    println!("checkpoint {ck_path} (step {}), {} params", ck.step, params.param_count());
    println!("{:<14} {:>10}", "datatype", "eval loss");
    println!("{:<14} {:>10.4}", "f32 (master)", eval(&params));
    for scheme in &schemes {
        // same per-tensor seeding as Checkpoint::to_quantized_params, so SR
        // labels quantize identically on every path
        let mut q = params.clone();
        q.quantize_linears(&cfg, scheme, ck.master_seed);
        println!("{:<14} {:>10.4}", scheme.label(), eval(&q));
    }
    Ok(())
}

/// Model shape from `--config <toml>` ([model] table) or individual flags,
/// defaulting to the tiny GPT2 testbed config.
fn serve_model_cfg(args: &Args) -> Result<ModelConfig> {
    if let Some(path) = args.get("config") {
        return Ok(RunConfig::load(path)?.model);
    }
    let arch = Arch::parse(args.get_or("arch", "gpt2"))?;
    let tiny = ModelConfig::tiny(arch);
    let cfg = ModelConfig {
        arch,
        n_layer: args.usize_or("n-layer", tiny.n_layer),
        d_model: args.usize_or("d-model", tiny.d_model),
        n_head: args.usize_or("n-head", tiny.n_head),
        d_ff: args.usize_or("d-ff", tiny.d_ff),
        vocab: args.usize_or("vocab", tiny.vocab),
        seq_len: args.usize_or("seq-len", tiny.seq_len),
    };
    cfg.validate()?;
    Ok(cfg)
}

/// `gaussws serve`: load a checkpoint (or a saved `.gwqs` snapshot) into the
/// low-precision MX weight store, spin up the continuous-batching engine,
/// and drive it with a synthetic request stream — the train → quantized
/// snapshot → serve lifecycle in one command. `--save-snapshot` exports the
/// store for later `--snapshot` serving; `--eval` reports the served
/// weights' held-out loss/perplexity (the Table C.1 deployment check).
fn cmd_serve(args: &Args) -> Result<()> {
    use gaussws::coordinator::Checkpoint;
    use gaussws::data::{SynthCorpus, SynthSpec};
    use gaussws::nn::transformer::Transformer;
    use gaussws::serve::{Engine, EngineConfig, GenRequest, WeightStore};
    use gaussws::util::json::{num, s};

    let block = args.usize_or("block", 32);
    if block == 0 {
        bail!("--block must be positive");
    }
    let scheme = gaussws::quant::resolve(args.get_or("store", "fp8_e3m4"))?.with_block(block);
    let seed = args.u64_or("seed", 1234);

    // ---- weights: snapshot > checkpoint > demo init ----
    let (store, source) = if let Some(path) = args.get("snapshot") {
        (WeightStore::load(path)?, format!("snapshot {path}"))
    } else {
        let cfg = serve_model_cfg(args)?;
        if let Some(ck_path) = args.get("checkpoint") {
            let ck = Checkpoint::load(ck_path)?;
            let step = ck.step;
            (
                WeightStore::from_checkpoint(&ck, &cfg, scheme)
                    .context("snapshotting checkpoint into the weight store")?,
                format!("checkpoint {ck_path} (step {step})"),
            )
        } else {
            println!(
                "note: no --checkpoint/--snapshot; serving randomly initialized weights (demo)"
            );
            let model = Transformer::new(cfg.clone());
            let params = model.init_params(seed);
            (
                WeightStore::from_params(&params, &cfg, scheme, seed)
                    .context("snapshotting random weights into the weight store")?,
                "random init (demo)".into(),
            )
        }
    };
    if let Some(out) = args.get("save-snapshot") {
        store.save(out)?;
        println!("quantized snapshot -> {out}");
    }
    let mcfg = store.cfg.clone();
    println!(
        "serving {} ({} arch, {} layers, d={}) from {source}",
        store.label(),
        mcfg.arch.name(),
        mcfg.n_layer,
        mcfg.d_model
    );
    println!(
        "weight store: {} -> {} bytes ({:.2}x vs master f32), {b}x{b}-block MX",
        store.master_bytes(),
        store.bytes(),
        store.master_bytes() as f64 / store.bytes() as f64,
        b = store.block()
    );

    // ---- engine ----
    let max_batch = args.usize_or("max-batch", 8);
    let threads = args.usize_or(
        "threads",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
    );
    let kv_block = args.usize_or("kv-block", 16);
    let kv_blocks = args.usize_or("kv-blocks", 0);
    let prefill_chunk = args.usize_or("prefill-chunk", 8);
    let prefix_cache = !args.flag("no-prefix-cache");
    // --kv-store: how the KV arena stores K/V rows — "f32" passthrough
    // (bit-identical to pre-quantization serving) or any blockwise
    // registry scheme (packed codes + per-group po2 scales)
    let kv_store_label = args.get_or("kv-store", "f32");
    let kv_scheme = gaussws::quant::resolve(kv_store_label)?;
    let ecfg = EngineConfig {
        max_batch,
        kv_block,
        kv_blocks,
        prefill_chunk,
        prefix_cache,
        threads,
        eos: args.get("eos").and_then(|v| v.parse().ok()),
        capacity: usize::MAX,
        kv_scheme,
        kv_seed: seed,
        // --kv-mirror: re-enable the resident f32 decode mirror (debug
        // mode; the fused packed-code read path is bit-identical to it)
        kv_mirror: args.flag("kv-mirror"),
        trace: args.get("trace-out").is_some(),
        // --spec-draft: self-speculative decoding — the served weights
        // round-tripped through a second (lower-bit) store draft
        // --spec-k tokens per round, verified in one wave; exact-match
        // acceptance keeps greedy outputs bit-identical to plain decode
        spec_draft_store: match args.get("spec-draft") {
            Some(label) => Some(gaussws::quant::resolve(label)?),
            None => None,
        },
        spec_k: args.usize_or("spec-k", 4),
        // --no-wave-batch: fall back to per-sequence decode (debug mode;
        // the weight-stationary batched wave is bit-identical to it)
        wave_batch: !args.flag("no-wave-batch"),
    };
    // degenerate paging configs (including an unhostable --kv-store
    // geometry for this model) fail here with a clean error, not a panic
    ecfg.validate_for(&mcfg)?;
    if let Some(label) = args.get("spec-draft") {
        println!(
            "speculative decoding: {label} draft, {} tokens/round, exact-match verify",
            ecfg.spec_k
        );
    }
    let mut engine = Engine::from_store(&store, ecfg);
    println!(
        "kv store: {} — {} B/position encoded vs {} B f32 ({:.2}x)",
        engine.kv_store(),
        engine.kv_bytes_per_position(),
        2 * mcfg.n_layer * mcfg.d_model * 4,
        (2 * mcfg.n_layer * mcfg.d_model * 4) as f64 / engine.kv_bytes_per_position() as f64
    );

    // ---- optional deployment-quality eval (Table C.1 check) ----
    if args.flag("eval") {
        let corpus = SynthCorpus::generate(SynthSpec {
            vocab: mcfg.vocab,
            len: 1 << 16,
            seed: seed ^ 0xC0FFEE,
            ..Default::default()
        });
        let seq = mcfg.seq_len.min(64);
        let mut total = 0.0;
        let n = 8;
        for k in 0..n {
            let start = 500 + k * 1500;
            let toks: Vec<usize> =
                corpus.tokens[start..start + seq + 1].iter().map(|&t| t as usize).collect();
            total += engine.model.loss(&engine.params, &toks);
        }
        let loss = total / n as f64;
        println!("served-weights eval: loss {loss:.4}  ppl {:.2}", loss.exp());
    }

    // ---- TCP front end: serve over the socket instead of self-driving ----
    if let Some(addr) = args.get("listen") {
        use gaussws::serve::NetServerConfig;
        let net_cfg = NetServerConfig {
            max_pending: args.usize_or("max-pending", 64),
            retry_after_ms: args.u64_or("retry-after-ms", 50),
            default_deadline_ms: args.get("default-deadline-ms").and_then(|v| v.parse().ok()),
        };
        let server = gaussws::serve::NetServer::bind(addr, engine, net_cfg)?;
        println!(
            "listening on {} — frames are '<len> <json>\\n'; close stdin (ctrl-d) to drain",
            server.local_addr()
        );
        // block until the operator closes stdin (or sends one line)
        let mut line = String::new();
        let _ = std::io::stdin().read_line(&mut line);
        println!("draining...");
        let stats = server.shutdown();
        println!("{}", stats.render(&format!("{} tcp", store.label())));
        if let Some(path) = args.get("trace-out") {
            if let Some(t) = stats.trace() {
                t.write_jsonl(path)?;
                println!("trace: {} events -> {path} (open with ui.perfetto.dev)", t.len());
            }
        }
        return Ok(());
    }

    // ---- self-driven synthetic load ----
    let n_req = args.usize_or("requests", 32);
    let prompt_len = args.usize_or("prompt-len", 16).clamp(1, mcfg.seq_len.saturating_sub(1));
    let max_new = args.usize_or("max-new", 24).max(1);
    let temperature = args.f64_or("temperature", 0.0) as f32;
    let top_k = args.usize_or("top-k", 0);
    // --shared-prefix N: every prompt starts with the same N tokens (a
    // system-prompt-style workload; exercises the prefix cache)
    let shared_prefix = args.usize_or("shared-prefix", 0).min(prompt_len.saturating_sub(1));
    if prefix_cache && shared_prefix > 0 && shared_prefix < kv_block {
        println!(
            "note: --shared-prefix {shared_prefix} is smaller than --kv-block {kv_block}; \
             prefix sharing is block-granular, so expect no hits (try --kv-block {shared_prefix})"
        );
    }
    let corpus = SynthCorpus::generate(SynthSpec {
        vocab: mcfg.vocab,
        len: 1 << 16,
        seed: seed ^ 0xFEED,
        ..Default::default()
    });
    let span = corpus.tokens.len() - prompt_len - 1;
    let head: Vec<usize> =
        corpus.tokens[17..17 + shared_prefix].iter().map(|&t| t as usize).collect();
    for id in 0..n_req {
        let start = (id * 2048 + 31) % span;
        let mut prompt = head.clone();
        prompt.extend(
            corpus.tokens[start..start + prompt_len - shared_prefix]
                .iter()
                .map(|&t| t as usize),
        );
        engine.enqueue(GenRequest {
            id: id as u64,
            prompt,
            max_new_tokens: max_new,
            temperature,
            top_k,
            seed: seed ^ id as u64,
            deadline_ms: None,
        })?;
    }
    // --metrics-every N: step the engine wave-by-wave and print a
    // registry snapshot every N waves (machine-greppable `METRICS` lines)
    let metrics_every = args.usize_or("metrics-every", 0);
    let done = if metrics_every == 0 {
        engine.run_to_completion()
    } else {
        let mut done = Vec::new();
        let mut wave = 0usize;
        while !engine.is_idle() {
            done.extend(engine.step());
            wave += 1;
            if wave % metrics_every == 0 {
                println!("METRICS wave {wave} {}", engine.stats.registry().snapshot_json());
            }
        }
        done
    };
    println!();
    println!("{}", engine.stats.render(store.label()));
    if let Some(path) = args.get("trace-out") {
        if let Some(t) = engine.stats.trace() {
            t.write_jsonl(path)?;
            println!("trace: {} events -> {path} (open with ui.perfetto.dev)", t.len());
        }
    }
    let (live, blocks, high_water, kv_bytes) = engine.kv_usage();
    println!(
        "kv arena: {blocks} blocks x {} positions, live {live}, high water {high_water}, \
         {kv_bytes} bytes budget, {} copy-on-write copies",
        kv_block,
        engine.cow_copies()
    );
    let pc = engine.prefix_cache_stats();
    println!(
        "prefix index: {} entries ({} insertions, {} evictions)",
        pc.entries, pc.insertions, pc.evictions
    );
    if done.len() != n_req {
        bail!("served {} of {n_req} requests", done.len());
    }

    let record = engine.stats.bench_json(
        &format!("{}/b{max_batch}", store.label()),
        vec![
            ("store", s(store.label())),
            ("arch", s(mcfg.arch.name())),
            ("max_batch", num(max_batch as f64)),
            ("threads", num(threads as f64)),
            ("prompt_len", num(prompt_len as f64)),
            ("max_new", num(max_new as f64)),
            ("kv_block", num(kv_block as f64)),
            ("prefill_chunk", num(prefill_chunk as f64)),
            ("prefix_cache", gaussws::util::json::Json::Bool(prefix_cache)),
            ("shared_prefix", num(shared_prefix as f64)),
        ],
    );
    println!("BENCH {record}");
    if let Some(path) = args.get("bench-out") {
        if let Some(dir) = std::path::Path::new(path).parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, format!("{record}\n"))?;
        println!("bench record -> {path}");
    }
    Ok(())
}

/// `gaussws load`: run a named workload scenario (or a custom `[workload]`
/// TOML spec) against the tiny reference model through the declarative
/// load framework — direct, in-process threaded, or loopback TCP.
fn cmd_load(args: &Args) -> Result<()> {
    use gaussws::load::{run, run_scenario, tiny_model, Driver, Scenario, WorkloadSpec};
    use gaussws::serve::{EngineConfig, NetServerConfig};

    let scenario_arg = args.positional.first().map(String::as_str);
    if args.flag("list") || (scenario_arg.is_none() && args.get("spec").is_none()) {
        println!("workload corpus (gaussws load <name>):");
        for sc in Scenario::all() {
            println!(
                "  {:<18} {:>3} reqs x {} clients — {}",
                sc.spec.name, sc.spec.requests, sc.spec.clients, sc.about
            );
        }
        println!("or: gaussws load --spec workload.toml  (a [workload] table; see README)");
        return Ok(());
    }

    let driver = match args.get_or("driver", "in-process") {
        "direct" => Driver::Direct,
        "in-process" => Driver::InProcess,
        "tcp" => Driver::Tcp(NetServerConfig {
            max_pending: args.usize_or("max-pending", 64),
            retry_after_ms: args.u64_or("retry-after-ms", 50),
            default_deadline_ms: args.get("default-deadline-ms").and_then(|v| v.parse().ok()),
        }),
        other => bail!("unknown --driver '{other}' (direct|in-process|tcp)"),
    };
    let model_seed = args.u64_or("seed", 1234);

    let (spec, outcome) = if let Some(path) = args.get("spec") {
        let text = std::fs::read_to_string(path).with_context(|| format!("read {path}"))?;
        let doc = gaussws::config::toml::parse(&text).with_context(|| format!("parse {path}"))?;
        let spec = WorkloadSpec::from_toml(&doc)?;
        let (mcfg, params) = tiny_model(model_seed);
        let ecfg = EngineConfig {
            max_batch: args.usize_or("max-batch", 8),
            kv_block: args.usize_or("kv-block", 8),
            kv_blocks: args.usize_or("kv-blocks", 0),
            prefill_chunk: args.usize_or("prefill-chunk", 8),
            threads: args.usize_or("threads", 2),
            ..EngineConfig::default()
        };
        let outcome = run(&spec, mcfg, params, ecfg, driver.clone())?;
        (spec, outcome)
    } else {
        let sc = Scenario::by_name(scenario_arg.expect("checked above"))?;
        println!("scenario {}: {}", sc.spec.name, sc.about);
        let outcome = run_scenario(&sc, driver.clone(), model_seed)?;
        (sc.spec, outcome)
    };

    println!("{}", outcome.stats.render(&format!("load.{} ({})", spec.name, driver.label())));
    if outcome.failed > 0 {
        println!("failed requests: {}", outcome.failed);
    }
    let record = outcome.bench_arm(&spec, driver.label());
    println!("BENCH {record}");
    if let Some(path) = args.get("bench-out") {
        if let Some(dir) = std::path::Path::new(path).parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, format!("{record}\n"))?;
        println!("bench record -> {path}");
    }
    let expected = spec.requests;
    let got = outcome.responses.len() + outcome.failed;
    if got != expected {
        bail!("lost responses: {got} accounted of {expected}");
    }
    Ok(())
}
