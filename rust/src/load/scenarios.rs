//! The named scenario corpus: curated (workload, engine-sizing) pairs that
//! each stress one serving behaviour, sized for the tiny reference model so
//! tests and benches run them end-to-end in milliseconds. Every scenario
//! records its run as a distinct `BENCH_serve.json` arm.

use crate::load::spec::{Arrival, Dist, WorkloadSpec};
use crate::serve::engine::EngineConfig;
use anyhow::{bail, Result};

/// A workload spec plus the engine sizing it is meant to stress.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub spec: WorkloadSpec,
    pub max_batch: usize,
    pub kv_block: usize,
    /// Arena budget in blocks (0 = roomy: no admission throttling).
    pub kv_blocks: usize,
    pub prefill_chunk: usize,
    pub prefix_cache: bool,
    /// One line on what the scenario exercises (shown by `load --list`).
    pub about: &'static str,
}

impl Scenario {
    /// The engine sizing for this scenario (2 worker threads: enough to
    /// exercise the parallel wave path without oversubscribing CI).
    pub fn engine_config(&self) -> EngineConfig {
        EngineConfig {
            max_batch: self.max_batch,
            kv_block: self.kv_block,
            kv_blocks: self.kv_blocks,
            prefill_chunk: self.prefill_chunk,
            prefix_cache: self.prefix_cache,
            threads: 2,
            ..EngineConfig::default()
        }
    }

    /// The built-in corpus. Sizing invariant: every scenario's worst-case
    /// `prompt + max_new - 1` fits the tiny model's 64-position capacity.
    pub fn all() -> Vec<Scenario> {
        vec![
            Scenario {
                spec: WorkloadSpec::new("bursty-chat")
                    .clients(4)
                    .requests(24)
                    .prompt_len(Dist::Uniform { lo: 4, hi: 20 })
                    .max_new(Dist::Uniform { lo: 4, hi: 10 })
                    .shared_prefix(12, 0.5)
                    .arrival(Arrival::Bursts { burst: 3, gap_ms: 10 })
                    .deadlines(2000, 0.25)
                    .seed(0xC4A7),
                max_batch: 8,
                kv_block: 8,
                kv_blocks: 48,
                prefill_chunk: 8,
                prefix_cache: true,
                about: "bursty multi-turn chat: shared system prefix, deadline mix, arrival bursts",
            },
            Scenario {
                spec: WorkloadSpec::new("long-doc-prefill")
                    .clients(2)
                    .requests(10)
                    .prompt_len(Dist::Uniform { lo: 40, hi: 56 })
                    .max_new(Dist::Fixed(6))
                    .seed(0xD0C5),
                max_batch: 4,
                kv_block: 16,
                kv_blocks: 0,
                prefill_chunk: 16,
                prefix_cache: false,
                about: "prefill-dominated: near-capacity prompts, few output tokens, big chunks",
            },
            Scenario {
                spec: WorkloadSpec::new("many-short")
                    .clients(8)
                    .requests(48)
                    .prompt_len(Dist::Uniform { lo: 2, hi: 6 })
                    .max_new(Dist::Fixed(4))
                    .seed(0x5407),
                max_batch: 8,
                kv_block: 8,
                kv_blocks: 0,
                prefill_chunk: 4,
                prefix_cache: false,
                about: "throughput floor: a swarm of tiny independent requests, batching-bound",
            },
            Scenario {
                spec: WorkloadSpec::new("preemption-storm")
                    .clients(4)
                    .requests(16)
                    .prompt_len(Dist::Uniform { lo: 10, hi: 14 })
                    .max_new(Dist::Fixed(6))
                    .seed(0x5702),
                max_batch: 4,
                kv_block: 8,
                kv_blocks: 6, // each sequence needs 3 of 6 blocks: arena churns
                prefill_chunk: 4,
                prefix_cache: false,
                about: "arena pressure: block budget forces preempt/re-admit churn",
            },
        ]
    }

    /// Corpus scenario names, in corpus order.
    pub fn names() -> Vec<String> {
        Scenario::all().into_iter().map(|s| s.spec.name).collect()
    }

    /// Look up a corpus scenario by name.
    pub fn by_name(name: &str) -> Result<Scenario> {
        match Scenario::all().into_iter().find(|s| s.spec.name == name) {
            Some(s) => Ok(s),
            None => bail!(
                "unknown scenario {name:?} (have: {})",
                Scenario::names().join(", ")
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_is_well_formed() {
        let all = Scenario::all();
        assert!(all.len() >= 4, "the corpus must keep at least 4 scenarios");
        for sc in &all {
            sc.spec.validate().unwrap_or_else(|e| panic!("{}: {e:#}", sc.spec.name));
            sc.engine_config().validate().unwrap_or_else(|e| panic!("{}: {e:#}", sc.spec.name));
            // worst case must fit the tiny model's 64-position capacity
            let worst = sc.spec.prompt_len.upper_bound() + sc.spec.max_new.upper_bound() - 1;
            assert!(worst <= 64, "{}: worst case {worst} positions > 64", sc.spec.name);
            // and, alone, must fit the scenario's arena
            if sc.kv_blocks > 0 {
                let blocks = worst.div_ceil(sc.kv_block);
                assert!(
                    blocks <= sc.kv_blocks,
                    "{}: worst request needs {blocks} blocks, arena has {}",
                    sc.spec.name,
                    sc.kv_blocks
                );
            }
            assert!(!sc.about.is_empty());
        }
        // names are unique and lookup round-trips
        let names = Scenario::names();
        for n in &names {
            assert_eq!(&Scenario::by_name(n).unwrap().spec.name, n);
        }
        assert_eq!(
            names.iter().collect::<std::collections::BTreeSet<_>>().len(),
            names.len(),
            "scenario names must be unique"
        );
        assert!(Scenario::by_name("no-such").is_err());
    }
}
