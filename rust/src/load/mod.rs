//! Declarative workload / load-generation framework for the serving layer.
//!
//! Three pieces:
//!
//! * [`spec`] — [`WorkloadSpec`]: a named, declarative workload
//!   (prompt-length and max-new **distributions** incl. mixtures,
//!   shared-prefix mixture, arrival schedule incl. bursts, client count,
//!   deadline mix, seed) with a deterministic generator: the same spec
//!   always expands to the same request sequence. Specs are buildable in
//!   code or loadable from a `[workload]` TOML table
//!   ([`WorkloadSpec::from_toml`]); distributions use a compact text form
//!   (`"uniform 4 20"`, `"mix 0.8 uniform 4 12 | 0.2 fixed 40"`).
//! * [`scenarios`] — the named corpus ([`Scenario`]): `bursty-chat`,
//!   `long-doc-prefill`, `many-short`, `preemption-storm`; each pairs a
//!   spec with the engine sizing it stresses, and records its run as a
//!   distinct `BENCH_serve.json` arm.
//! * [`runner`] — [`runner::run`] drives an expanded workload through one
//!   of three transports ([`Driver`]): synchronous direct enqueue, a
//!   spawned in-process engine with closed-loop client threads, or
//!   loopback TCP through [`crate::serve::net`]. Greedy serving is
//!   schedule-independent, so all three must produce bit-identical token
//!   streams — the conformance tests assert it.
//!
//! CLI: `load <scenario>` runs a corpus entry (or `--spec workload.toml`),
//! `load --list` prints the corpus.

pub mod runner;
pub mod scenarios;
pub mod spec;

pub use runner::{run, run_scenario, tiny_model, Driver, RunOutcome};
pub use scenarios::Scenario;
pub use spec::{Arrival, Dist, LoadRequest, WorkloadSpec};
