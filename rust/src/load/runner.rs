//! The workload runner: expands a [`WorkloadSpec`] and drives it against a
//! serving engine through one of three transports, returning the run's
//! [`ServeStats`] and every completed response.
//!
//! Because the serving stack is deterministic for greedy requests
//! regardless of batching/scheduling (the engine's batching-transparency
//! invariant), all three drivers must produce bit-identical token streams
//! for the same spec — the loopback conformance tests assert exactly that.

use crate::config::schema::{Arch, ModelConfig};
use crate::load::scenarios::Scenario;
use crate::load::spec::{LoadRequest, WorkloadSpec};
use crate::nn::transformer::{Params, Transformer};
use crate::serve::engine::{Engine, EngineConfig};
use crate::serve::net::{NetClient, NetServer, NetServerConfig};
use crate::serve::protocol::GenResponse;
use crate::serve::stats::ServeStats;
use crate::util::json::{num, s, Json};
use anyhow::{Context, Result};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// How the generated requests reach the engine.
#[derive(Debug, Clone)]
pub enum Driver {
    /// Synchronous: enqueue the whole workload into an [`Engine`] and
    /// `run_to_completion`. Ignores clients/arrival timing — maximum
    /// concurrency pressure, fully deterministic scheduling. The reference
    /// the other drivers are compared against.
    Direct,
    /// Threaded in-process: a spawned engine plus one closed-loop client
    /// thread per spec client, honoring per-request delays.
    InProcess,
    /// Loopback TCP: a [`NetServer`] on `127.0.0.1:0` plus one
    /// [`NetClient`] connection per spec client; shed requests are retried
    /// per their `retry_after_ms` hint.
    Tcp(NetServerConfig),
}

impl Driver {
    pub fn label(&self) -> &'static str {
        match self {
            Driver::Direct => "direct",
            Driver::InProcess => "in-process",
            Driver::Tcp(_) => "tcp",
        }
    }
}

/// What a workload run produced.
pub struct RunOutcome {
    pub stats: ServeStats,
    /// Completed responses, sorted by request id.
    pub responses: Vec<GenResponse>,
    /// Requests that errored at the client (rejected, or shed past the
    /// retry budget). Deadline-expired requests are *completions*, not
    /// failures — they come back as responses with `finish = "deadline"`.
    pub failed: usize,
}

impl RunOutcome {
    /// The run's `BENCH_serve.json` arm: the stats record labelled
    /// `load.<workload>` plus the workload/driver coordinates.
    pub fn bench_arm(&self, spec: &WorkloadSpec, driver_label: &str) -> Json {
        self.stats.bench_json(
            &format!("load.{}", spec.name),
            vec![
                ("workload", s(&spec.name)),
                ("driver", s(driver_label)),
                ("clients", num(spec.clients as f64)),
                ("spec_requests", num(spec.requests as f64)),
                ("failed", num(self.failed as f64)),
            ],
        )
    }
}

/// The tiny reference model every scenario is sized for (seeded params, so
/// two runs with the same seed serve identical weights).
pub fn tiny_model(seed: u64) -> (ModelConfig, Params) {
    let cfg = ModelConfig::tiny(Arch::Gpt2);
    let model = Transformer::new(cfg.clone());
    let params = model.init_params(seed);
    (cfg, params)
}

/// Expand `spec` and drive it through `driver`.
pub fn run(
    spec: &WorkloadSpec,
    model_cfg: ModelConfig,
    params: Params,
    ecfg: EngineConfig,
    driver: Driver,
) -> Result<RunOutcome> {
    spec.validate()?;
    let reqs = spec.generate();
    let engine = Engine::new(model_cfg, params, ecfg);
    match driver {
        Driver::Direct => run_direct(engine, reqs),
        Driver::InProcess => run_in_process(engine, spec.clients, reqs),
        Driver::Tcp(net_cfg) => run_tcp(engine, net_cfg, spec.clients, reqs),
    }
}

/// [`run`] for a corpus [`Scenario`]: builds the tiny model with
/// `model_seed` and the scenario's engine sizing.
pub fn run_scenario(sc: &Scenario, driver: Driver, model_seed: u64) -> Result<RunOutcome> {
    let (cfg, params) = tiny_model(model_seed);
    run(&sc.spec, cfg, params, sc.engine_config(), driver)
}

fn run_direct(mut engine: Engine, reqs: Vec<LoadRequest>) -> Result<RunOutcome> {
    let mut failed = 0;
    for lr in reqs {
        if engine.enqueue(lr.req).is_err() {
            failed += 1;
        }
    }
    let mut responses = engine.run_to_completion();
    responses.sort_by_key(|r| r.id);
    engine.clear_prefix_cache();
    Ok(RunOutcome { stats: engine.stats, responses, failed })
}

/// Split the expanded workload into per-client send lists (id order within
/// each client, as generated).
fn per_client(clients: usize, reqs: Vec<LoadRequest>) -> Vec<Vec<LoadRequest>> {
    let mut lists: Vec<Vec<LoadRequest>> = (0..clients.max(1)).map(|_| Vec::new()).collect();
    for lr in reqs {
        let c = lr.client % lists.len();
        lists[c].push(lr);
    }
    lists
}

fn run_in_process(engine: Engine, clients: usize, reqs: Vec<LoadRequest>) -> Result<RunOutcome> {
    let handle = engine.spawn();
    let collected: Mutex<Vec<GenResponse>> = Mutex::new(Vec::new());
    let failed = AtomicUsize::new(0);
    std::thread::scope(|sc| {
        for list in per_client(clients, reqs) {
            let client = handle.client();
            let collected = &collected;
            let failed = &failed;
            sc.spawn(move || {
                for lr in list {
                    if lr.delay_ms > 0 {
                        std::thread::sleep(Duration::from_millis(lr.delay_ms));
                    }
                    match client.generate(lr.req) {
                        Ok(resp) => collected.lock().expect("responses lock").push(resp),
                        Err(_) => {
                            failed.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
            });
        }
    });
    let stats = handle.shutdown();
    let mut responses = collected.into_inner().expect("responses lock");
    responses.sort_by_key(|r| r.id);
    Ok(RunOutcome { stats, responses, failed: failed.load(Ordering::Relaxed) })
}

fn run_tcp(
    engine: Engine,
    net_cfg: NetServerConfig,
    clients: usize,
    reqs: Vec<LoadRequest>,
) -> Result<RunOutcome> {
    let server = NetServer::bind("127.0.0.1:0", engine, net_cfg)?;
    let addr = server.local_addr();
    let collected: Mutex<Vec<GenResponse>> = Mutex::new(Vec::new());
    let failed = AtomicUsize::new(0);
    let connect_err: Mutex<Option<anyhow::Error>> = Mutex::new(None);
    std::thread::scope(|sc| {
        for list in per_client(clients, reqs) {
            let collected = &collected;
            let failed = &failed;
            let connect_err = &connect_err;
            sc.spawn(move || {
                let mut conn = match NetClient::connect(addr).context("load client connect") {
                    Ok(c) => c,
                    Err(e) => {
                        *connect_err.lock().expect("connect-err lock") = Some(e);
                        failed.fetch_add(list.len(), Ordering::Relaxed);
                        return;
                    }
                };
                for lr in list {
                    if lr.delay_ms > 0 {
                        std::thread::sleep(Duration::from_millis(lr.delay_ms));
                    }
                    // generous retry budget: load runs must not drop work
                    // just because the arena was momentarily full
                    match conn.generate_retrying(&lr.req, 200) {
                        Ok(resp) => collected.lock().expect("responses lock").push(resp),
                        Err(_) => {
                            failed.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
            });
        }
    });
    let stats = server.shutdown();
    if let Some(e) = connect_err.into_inner().expect("connect-err lock") {
        return Err(e);
    }
    let mut responses = collected.into_inner().expect("responses lock");
    responses.sort_by_key(|r| r.id);
    Ok(RunOutcome { stats, responses, failed: failed.load(Ordering::Relaxed) })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::load::spec::Dist;

    fn small_spec() -> WorkloadSpec {
        WorkloadSpec::new("runner-smoke")
            .clients(2)
            .requests(6)
            .prompt_len(Dist::Uniform { lo: 2, hi: 6 })
            .max_new(Dist::Fixed(3))
            .seed(31)
    }

    #[test]
    fn direct_driver_completes_and_is_deterministic() {
        let spec = small_spec();
        let go = || {
            let (cfg, params) = tiny_model(9);
            let ecfg = EngineConfig {
                max_batch: 4,
                kv_block: 8,
                prefill_chunk: 4,
                threads: 1,
                ..EngineConfig::default()
            };
            run(&spec, cfg, params, ecfg, Driver::Direct).unwrap()
        };
        let a = go();
        assert_eq!(a.responses.len(), 6);
        assert_eq!(a.failed, 0);
        assert_eq!(a.stats.completed(), 6);
        let b = go();
        for (x, y) in a.responses.iter().zip(b.responses.iter()) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.tokens, y.tokens, "direct driver must be reproducible");
        }
        let arm = a.bench_arm(&spec, Driver::Direct.label());
        assert_eq!(arm.get("workload").as_str(), Some("runner-smoke"));
        assert_eq!(arm.get("driver").as_str(), Some("direct"));
        assert_eq!(arm.get("requests").as_usize(), Some(6));
        assert_eq!(arm.get("spec_requests").as_usize(), Some(6));
    }

    #[test]
    fn in_process_driver_matches_direct_tokens() {
        let spec = small_spec();
        let (cfg, params) = tiny_model(9);
        let ecfg = EngineConfig {
            max_batch: 4,
            kv_block: 8,
            prefill_chunk: 4,
            threads: 2,
            ..EngineConfig::default()
        };
        let direct = run(&spec, cfg.clone(), params.clone(), ecfg.clone(), Driver::Direct).unwrap();
        let inproc = run(&spec, cfg, params, ecfg, Driver::InProcess).unwrap();
        assert_eq!(inproc.responses.len(), 6);
        assert_eq!(inproc.failed, 0);
        for (x, y) in direct.responses.iter().zip(inproc.responses.iter()) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.tokens, y.tokens, "req {}: transport changed the tokens", x.id);
        }
    }
}
