//! Declarative workload specifications with a seeded deterministic
//! generator: the same [`WorkloadSpec`] always expands to the same request
//! sequence, so a workload driven in-process and over TCP can be compared
//! token-for-token.

use crate::config::toml::TomlDoc;
use crate::serve::protocol::GenRequest;
use crate::testing::prop::Gen;
use anyhow::{bail, Context, Result};

/// A distribution over token counts.
#[derive(Debug, Clone, PartialEq)]
pub enum Dist {
    Fixed(usize),
    /// Uniform over `[lo, hi]` inclusive.
    Uniform { lo: usize, hi: usize },
    /// Weighted mixture of sub-distributions.
    Mix(Vec<(f64, Dist)>),
}

impl Dist {
    pub fn sample(&self, g: &mut Gen) -> usize {
        match self {
            Dist::Fixed(n) => *n,
            Dist::Uniform { lo, hi } => g.usize_in(*lo, *hi),
            Dist::Mix(parts) => {
                let total: f64 = parts.iter().map(|(w, _)| w).sum();
                let mut u = g.f64_in(0.0, total);
                for (w, d) in parts {
                    u -= w;
                    if u <= 0.0 {
                        return d.sample(g);
                    }
                }
                let (_, last) = parts.last().expect("mix is non-empty by construction");
                last.sample(g)
            }
        }
    }

    /// Largest value this distribution can produce (for capacity checks).
    pub fn upper_bound(&self) -> usize {
        match self {
            Dist::Fixed(n) => *n,
            Dist::Uniform { hi, .. } => *hi,
            Dist::Mix(parts) => parts.iter().map(|(_, d)| d.upper_bound()).max().unwrap_or(0),
        }
    }

    /// Parse the TOML/CLI text form: `"fixed N"`, `"uniform LO HI"`, or a
    /// flat mixture `"mix W fixed N | W uniform LO HI"` (weights need not
    /// sum to 1; they are normalized at sampling).
    pub fn parse(text: &str) -> Result<Dist> {
        let text = text.trim();
        if let Some(rest) = text.strip_prefix("mix ") {
            let mut parts = Vec::new();
            for piece in rest.split('|') {
                let piece = piece.trim();
                let (w_text, d_text) =
                    piece.split_once(' ').with_context(|| format!("mix arm {piece:?}: expected 'WEIGHT DIST'"))?;
                let w: f64 = w_text
                    .trim()
                    .parse()
                    .ok()
                    .with_context(|| format!("mix arm {piece:?}: bad weight {w_text:?}"))?;
                if w <= 0.0 || !w.is_finite() {
                    bail!("mix arm {piece:?}: weight must be positive and finite");
                }
                parts.push((w, Dist::parse(d_text)?));
            }
            if parts.is_empty() {
                bail!("mix: at least one arm required");
            }
            return Ok(Dist::Mix(parts));
        }
        let toks: Vec<&str> = text.split_whitespace().collect();
        match toks.as_slice() {
            ["fixed", n] => {
                Ok(Dist::Fixed(n.parse().ok().with_context(|| format!("fixed: bad count {n:?}"))?))
            }
            ["uniform", lo, hi] => {
                let lo: usize = lo.parse().ok().with_context(|| format!("uniform: bad lo {lo:?}"))?;
                let hi: usize = hi.parse().ok().with_context(|| format!("uniform: bad hi {hi:?}"))?;
                if hi < lo {
                    bail!("uniform: hi {hi} < lo {lo}");
                }
                Ok(Dist::Uniform { lo, hi })
            }
            _ => bail!(
                "distribution must be 'fixed N', 'uniform LO HI' or 'mix W DIST | ...' (got {text:?})"
            ),
        }
    }
}

/// When clients send their requests.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Arrival {
    /// Closed loop: each client sends its next request the moment the
    /// previous reply lands.
    Closed,
    /// Paced: each client waits `gap_ms` before every request.
    Paced { gap_ms: u64 },
    /// Bursty: per client, `burst` requests go back-to-back, then a
    /// `gap_ms` pause before the next burst.
    Bursts { burst: usize, gap_ms: u64 },
}

impl Arrival {
    /// Parse `"closed"`, `"paced GAP_MS"` or `"bursts N GAP_MS"`.
    pub fn parse(text: &str) -> Result<Arrival> {
        let toks: Vec<&str> = text.split_whitespace().collect();
        match toks.as_slice() {
            ["closed"] => Ok(Arrival::Closed),
            ["paced", gap] => Ok(Arrival::Paced {
                gap_ms: gap.parse().ok().with_context(|| format!("paced: bad gap {gap:?}"))?,
            }),
            ["bursts", n, gap] => {
                let burst: usize =
                    n.parse().ok().with_context(|| format!("bursts: bad size {n:?}"))?;
                if burst == 0 {
                    bail!("bursts: size must be positive");
                }
                Ok(Arrival::Bursts {
                    burst,
                    gap_ms: gap.parse().ok().with_context(|| format!("bursts: bad gap {gap:?}"))?,
                })
            }
            _ => bail!("arrival must be 'closed', 'paced GAP_MS' or 'bursts N GAP_MS' (got {text:?})"),
        }
    }
}

/// One generated request plus its schedule slot.
#[derive(Debug, Clone)]
pub struct LoadRequest {
    pub req: GenRequest,
    /// Which client issues it (requests round-robin across clients).
    pub client: usize,
    /// Milliseconds the client waits before sending it (0 in closed loop).
    pub delay_ms: u64,
}

/// A named, declarative workload: distributions, mixtures and schedule,
/// expanded deterministically by [`WorkloadSpec::generate`].
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadSpec {
    pub name: String,
    /// Concurrent closed-loop clients the runner spawns.
    pub clients: usize,
    /// Total requests across all clients.
    pub requests: usize,
    /// Prompt tokens are drawn uniformly from `[0, vocab)`.
    pub vocab: usize,
    pub prompt_len: Dist,
    pub max_new: Dist,
    /// Length of the workload's shared prompt prefix (0 = none).
    pub shared_prefix_len: usize,
    /// Fraction of requests whose prompt starts with the shared prefix.
    pub shared_prefix_frac: f64,
    pub arrival: Arrival,
    /// Deadline applied to a `deadline_frac` fraction of requests.
    pub deadline_ms: Option<u64>,
    pub deadline_frac: f64,
    /// Generator seed: same spec + same seed = same request sequence.
    pub seed: u64,
}

impl WorkloadSpec {
    /// A minimal closed-loop spec; shape it with the builder methods.
    pub fn new(name: &str) -> WorkloadSpec {
        WorkloadSpec {
            name: name.to_string(),
            clients: 2,
            requests: 8,
            vocab: 50,
            prompt_len: Dist::Uniform { lo: 2, hi: 8 },
            max_new: Dist::Fixed(4),
            shared_prefix_len: 0,
            shared_prefix_frac: 0.0,
            arrival: Arrival::Closed,
            deadline_ms: None,
            deadline_frac: 0.0,
            seed: 0x10AD,
        }
    }

    pub fn clients(mut self, n: usize) -> Self {
        self.clients = n;
        self
    }

    pub fn requests(mut self, n: usize) -> Self {
        self.requests = n;
        self
    }

    pub fn vocab(mut self, v: usize) -> Self {
        self.vocab = v;
        self
    }

    pub fn prompt_len(mut self, d: Dist) -> Self {
        self.prompt_len = d;
        self
    }

    pub fn max_new(mut self, d: Dist) -> Self {
        self.max_new = d;
        self
    }

    pub fn shared_prefix(mut self, len: usize, frac: f64) -> Self {
        self.shared_prefix_len = len;
        self.shared_prefix_frac = frac;
        self
    }

    pub fn arrival(mut self, a: Arrival) -> Self {
        self.arrival = a;
        self
    }

    pub fn deadlines(mut self, ms: u64, frac: f64) -> Self {
        self.deadline_ms = Some(ms);
        self.deadline_frac = frac;
        self
    }

    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }

    pub fn validate(&self) -> Result<()> {
        if self.clients == 0 {
            bail!("workload {}: clients must be positive", self.name);
        }
        if self.requests == 0 {
            bail!("workload {}: requests must be positive", self.name);
        }
        if self.vocab == 0 {
            bail!("workload {}: vocab must be positive", self.name);
        }
        for (label, frac) in
            [("shared_prefix_frac", self.shared_prefix_frac), ("deadline_frac", self.deadline_frac)]
        {
            if !(0.0..=1.0).contains(&frac) {
                bail!("workload {}: {label} must be in [0, 1], got {frac}", self.name);
            }
        }
        if self.prompt_len.upper_bound() == 0 {
            bail!("workload {}: prompt_len can produce 0 (prompts must be non-empty)", self.name);
        }
        Ok(())
    }

    /// Expand the spec into its request sequence. Deterministic: the draw
    /// order is fixed per request, so the expansion never depends on how
    /// the runner later schedules the clients.
    pub fn generate(&self) -> Vec<LoadRequest> {
        let mut g = Gen::new(self.seed ^ 0x10AD_5EED);
        let shared: Vec<usize> =
            (0..self.shared_prefix_len).map(|_| g.usize_in(0, self.vocab - 1)).collect();
        let clients = self.clients.max(1);
        let mut out = Vec::with_capacity(self.requests);
        for i in 0..self.requests {
            let plen = self.prompt_len.sample(&mut g).max(1);
            let use_shared = self.shared_prefix_len > 0
                && g.f64_in(0.0, 1.0) < self.shared_prefix_frac;
            let mut prompt: Vec<usize> = Vec::with_capacity(plen);
            if use_shared {
                prompt.extend(shared.iter().take(plen).copied());
            }
            while prompt.len() < plen {
                prompt.push(g.usize_in(0, self.vocab - 1));
            }
            let max_new = self.max_new.sample(&mut g).max(1);
            let deadline = match self.deadline_ms {
                Some(ms) if g.f64_in(0.0, 1.0) < self.deadline_frac => Some(ms),
                _ => None,
            };
            let seq_in_client = i / clients;
            let delay_ms = match self.arrival {
                Arrival::Closed => 0,
                Arrival::Paced { gap_ms } => gap_ms,
                Arrival::Bursts { burst, gap_ms } => {
                    // a client pauses before each burst (including a
                    // staggerless first one at seq 0: bursts align)
                    if seq_in_client > 0 && seq_in_client % burst.max(1) == 0 {
                        gap_ms
                    } else {
                        0
                    }
                }
            };
            let mut req = GenRequest::greedy(i as u64, prompt, max_new);
            req.seed = self.seed.wrapping_add(i as u64);
            req.deadline_ms = deadline;
            out.push(LoadRequest { req, client: i % clients, delay_ms });
        }
        out
    }

    /// Load a spec from a TOML document's `[workload]` table. Every key is
    /// optional over [`WorkloadSpec::new`] defaults; distributions and the
    /// arrival schedule use their text forms (see [`Dist::parse`] and
    /// [`Arrival::parse`]).
    pub fn from_toml(doc: &TomlDoc) -> Result<WorkloadSpec> {
        let mut spec = WorkloadSpec::new(&doc.str_or("workload.name", "custom"));
        spec.clients = doc.i64_or("workload.clients", spec.clients as i64) as usize;
        spec.requests = doc.i64_or("workload.requests", spec.requests as i64) as usize;
        spec.vocab = doc.i64_or("workload.vocab", spec.vocab as i64) as usize;
        spec.seed = doc.i64_or("workload.seed", spec.seed as i64) as u64;
        spec.shared_prefix_len =
            doc.i64_or("workload.shared_prefix_len", spec.shared_prefix_len as i64) as usize;
        spec.shared_prefix_frac = doc.f64_or("workload.shared_prefix_frac", spec.shared_prefix_frac);
        spec.deadline_frac = doc.f64_or("workload.deadline_frac", spec.deadline_frac);
        if let Some(v) = doc.get("workload.deadline_ms") {
            spec.deadline_ms =
                Some(v.as_i64().context("workload.deadline_ms must be an integer")? as u64);
        }
        if let Some(v) = doc.get("workload.prompt_len") {
            spec.prompt_len =
                Dist::parse(v.as_str().context("workload.prompt_len must be a string")?)?;
        }
        if let Some(v) = doc.get("workload.max_new") {
            spec.max_new = Dist::parse(v.as_str().context("workload.max_new must be a string")?)?;
        }
        if let Some(v) = doc.get("workload.arrival") {
            spec.arrival =
                Arrival::parse(v.as_str().context("workload.arrival must be a string")?)?;
        }
        spec.validate()?;
        Ok(spec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dist_text_forms_parse() {
        assert_eq!(Dist::parse("fixed 7").unwrap(), Dist::Fixed(7));
        assert_eq!(Dist::parse("uniform 2 9").unwrap(), Dist::Uniform { lo: 2, hi: 9 });
        let mix = Dist::parse("mix 0.75 uniform 4 16 | 0.25 fixed 200").unwrap();
        assert_eq!(
            mix,
            Dist::Mix(vec![(0.75, Dist::Uniform { lo: 4, hi: 16 }), (0.25, Dist::Fixed(200))])
        );
        assert_eq!(mix.upper_bound(), 200);
        assert!(Dist::parse("uniform 9 2").is_err());
        assert!(Dist::parse("gaussian 3").is_err());
        assert!(Dist::parse("mix x fixed 1").is_err());
    }

    #[test]
    fn arrival_text_forms_parse() {
        assert_eq!(Arrival::parse("closed").unwrap(), Arrival::Closed);
        assert_eq!(Arrival::parse("paced 15").unwrap(), Arrival::Paced { gap_ms: 15 });
        assert_eq!(
            Arrival::parse("bursts 8 40").unwrap(),
            Arrival::Bursts { burst: 8, gap_ms: 40 }
        );
        assert!(Arrival::parse("bursts 0 40").is_err());
        assert!(Arrival::parse("sometimes").is_err());
    }

    #[test]
    fn dist_samples_stay_in_range() {
        let mut g = Gen::new(42);
        let d = Dist::Uniform { lo: 3, hi: 11 };
        for _ in 0..200 {
            let v = d.sample(&mut g);
            assert!((3..=11).contains(&v));
        }
        let mix = Dist::Mix(vec![(0.5, Dist::Fixed(1)), (0.5, Dist::Fixed(9))]);
        let mut saw = [false, false];
        for _ in 0..200 {
            match mix.sample(&mut g) {
                1 => saw[0] = true,
                9 => saw[1] = true,
                other => panic!("mix produced {other}"),
            }
        }
        assert!(saw[0] && saw[1], "both mix arms must be reachable");
    }

    #[test]
    fn generation_is_deterministic() {
        let spec = WorkloadSpec::new("det")
            .clients(3)
            .requests(20)
            .prompt_len(Dist::Uniform { lo: 2, hi: 10 })
            .shared_prefix(6, 0.5)
            .deadlines(100, 0.3)
            .seed(77);
        let a = spec.generate();
        let b = spec.generate();
        assert_eq!(a.len(), 20);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.req, y.req);
            assert_eq!(x.client, y.client);
            assert_eq!(x.delay_ms, y.delay_ms);
        }
        // a different seed reshuffles the draws
        let c = spec.clone().seed(78).generate();
        assert!(
            a.iter().zip(c.iter()).any(|(x, y)| x.req.prompt != y.req.prompt),
            "seed change must alter the workload"
        );
    }

    #[test]
    fn shared_prefix_mixture_shows_up() {
        let spec = WorkloadSpec::new("mix")
            .clients(1)
            .requests(40)
            .prompt_len(Dist::Fixed(10))
            .shared_prefix(8, 0.5)
            .seed(5);
        let reqs = spec.generate();
        let shared: Vec<usize> = reqs
            .iter()
            .find(|r| reqs.iter().filter(|o| o.req.prompt[..8] == r.req.prompt[..8]).count() > 5)
            .expect("some prompts share a prefix")
            .req
            .prompt[..8]
            .to_vec();
        let with = reqs.iter().filter(|r| r.req.prompt[..8] == shared[..]).count();
        assert!(with >= 10 && with <= 30, "~half the prompts share the prefix, got {with}/40");
    }

    #[test]
    fn bursts_schedule_pauses_between_bursts() {
        let spec = WorkloadSpec::new("bursty")
            .clients(1)
            .requests(9)
            .arrival(Arrival::Bursts { burst: 3, gap_ms: 25 });
        let delays: Vec<u64> = spec.generate().iter().map(|r| r.delay_ms).collect();
        assert_eq!(delays, vec![0, 0, 0, 25, 0, 0, 25, 0, 0]);
    }

    #[test]
    fn deadline_mix_applies_to_a_fraction() {
        let spec =
            WorkloadSpec::new("dl").clients(1).requests(60).deadlines(150, 0.5).seed(11);
        let n = spec.generate().iter().filter(|r| r.req.deadline_ms == Some(150)).count();
        assert!(n > 15 && n < 45, "about half carry deadlines, got {n}/60");
    }

    #[test]
    fn toml_roundtrip() {
        let text = "\
[workload]
name = \"custom-burst\"
clients = 4
requests = 32
vocab = 64
prompt_len = \"mix 0.8 uniform 4 12 | 0.2 fixed 40\"
max_new = \"uniform 2 6\"
arrival = \"bursts 8 20\"
shared_prefix_len = 10
shared_prefix_frac = 0.4
deadline_ms = 300
deadline_frac = 0.25
seed = 9
";
        let doc = crate::config::toml::parse(text).unwrap();
        let spec = WorkloadSpec::from_toml(&doc).unwrap();
        assert_eq!(spec.name, "custom-burst");
        assert_eq!(spec.clients, 4);
        assert_eq!(spec.requests, 32);
        assert_eq!(spec.prompt_len.upper_bound(), 40);
        assert_eq!(spec.arrival, Arrival::Bursts { burst: 8, gap_ms: 20 });
        assert_eq!(spec.deadline_ms, Some(300));
        assert_eq!(spec.seed, 9);
        assert_eq!(spec.generate().len(), 32);
    }

    #[test]
    fn invalid_specs_rejected() {
        assert!(WorkloadSpec::new("z").requests(0).validate().is_err());
        assert!(WorkloadSpec::new("z").clients(0).validate().is_err());
        let mut s = WorkloadSpec::new("z");
        s.shared_prefix_frac = 1.5;
        assert!(s.validate().is_err());
    }
}
