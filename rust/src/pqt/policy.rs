//! Layer-selection policy: the paper's "method[part]" notation (§4).
//!
//! `[all]` applies PQT to every linear in every transformer block;
//! `[qkv]`, `[out]`, `[up]`, `[down]` restrict to one linear; `[od]` is
//! shorthand for `[out,down]` (the last layers of the two residual
//! branches). Names are architecture-specific (Fig. 5 order).

use crate::config::schema::Arch;
use anyhow::{bail, Result};

/// A resolved policy: the set of per-block linear names that get PQT.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Policy {
    names: Vec<String>,
    all: bool,
}

impl Policy {
    /// Resolve part tokens (e.g. `["od"]`, `["qkv","up"]`, `["all"]`)
    /// against an architecture's linear names.
    pub fn resolve(parts: &[String], arch: Arch) -> Result<Policy> {
        let valid = arch.linear_names();
        let mut names: Vec<String> = Vec::new();
        let mut all = false;
        for raw in parts {
            let p = raw.trim().to_ascii_lowercase();
            match p.as_str() {
                "all" => all = true,
                "od" => {
                    // shorthand for out,down (paper notation)
                    names.push("out".into());
                    names.push("down".into());
                }
                other => {
                    if !valid.contains(&other) {
                        bail!(
                            "unknown part '{other}' for arch {} (valid: {:?} plus 'all'/'od')",
                            arch.name(),
                            valid
                        );
                    }
                    names.push(other.to_string());
                }
            }
        }
        names.sort();
        names.dedup();
        Ok(Policy { names, all })
    }

    /// Does the linear `name` (e.g. "qkv") in any block get PQT?
    pub fn applies(&self, name: &str) -> bool {
        self.all || self.names.iter().any(|n| n == name)
    }

    /// Paper-style label, e.g. "gaussws[od]" / "gaussws[all]".
    pub fn label(&self, method: &str) -> String {
        if self.all {
            format!("{method}[all]")
        } else {
            format!("{method}[{}]", self.names.join(","))
        }
    }

    /// A policy that applies to nothing (BF16 baseline).
    pub fn none() -> Policy {
        Policy { names: vec![], all: false }
    }

    /// A policy that applies to everything.
    pub fn all() -> Policy {
        Policy { names: vec![], all: true }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_matches_everything() {
        let p = Policy::resolve(&["all".into()], Arch::Gpt2).unwrap();
        for n in Arch::Gpt2.linear_names() {
            assert!(p.applies(n));
        }
        assert_eq!(p.label("gaussws"), "gaussws[all]");
    }

    #[test]
    fn od_shorthand() {
        let p = Policy::resolve(&["od".into()], Arch::Gpt2).unwrap();
        assert!(p.applies("out"));
        assert!(p.applies("down"));
        assert!(!p.applies("qkv"));
        assert!(!p.applies("up"));
        assert_eq!(p.label("gaussws"), "gaussws[down,out]");
    }

    #[test]
    fn single_part() {
        let p = Policy::resolve(&["qkv".into()], Arch::Gpt2).unwrap();
        assert!(p.applies("qkv"));
        assert!(!p.applies("out"));
    }

    #[test]
    fn unknown_part_rejected() {
        assert!(Policy::resolve(&["qkv".into()], Arch::Llama2).is_err()); // llama has q,k,v
        assert!(Policy::resolve(&["gate".into()], Arch::Gpt2).is_err());
        assert!(Policy::resolve(&["gate".into()], Arch::Llama2).is_ok());
    }

    #[test]
    fn none_policy() {
        let p = Policy::none();
        assert!(!p.applies("qkv"));
    }
}
