//! The core GaussWS op (paper Eq. 3 forward / Eq. 4 backward) on host
//! buffers — the L3 reference implementation and rust hot path.
//!
//! Forward, per weight matrix `w (rows × cols)` with square blocks `b_l`:
//!
//! ```text
//! ŵ = bf16( w + R ⊙ broadcast_bl( max_bl(|w|) · 2^(1 − b_t) ) )
//! ```
//!
//! where `R` is the packed rounded-normal noise (one 4-bit code/element)
//! and `b_t` is the per-block bitwidth. The final bf16 cast models the
//! "BF16 operator" the paper assumes (§3.3): downstream matmuls consume ŵ
//! at bf16 precision, which is exactly where the underflow analysis bites.
//!
//! Backward (Eq. 4), given `g = ∂L/∂ŵ`:
//!
//! ```text
//! ∂L/∂w   = g                                 (identity; ∂max/∂w ≈ 0)
//! ∂L/∂b_t = −ln2 · max_bl(|w|) · 2^(1−b_t) · Σ_bl(g ⊙ R)
//! ```

use crate::mx::block::block_absmax_f32;
use crate::numerics::Bf16;
use crate::prng::bitwise::{decode_nibble, PackedNoise};
use crate::prng::{generate_exact, generate_fast};

/// Which noise generator backs the op.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NoiseGen {
    /// Fully independent bits (16 words / 32 elems) — reference.
    Exact,
    /// Rotation-reuse fast path (4 words / 32 elems).
    Fast,
}

/// Saved state from a forward sample, needed by the backward pass.
///
/// The packed noise costs 0.5 B/element (paper §4.2); `amax`/`scale` are one
/// f32 per 32×32 block. Regenerating `R` from the seed instead would drop
/// the 0.5 B at the cost of a second generator run — mirrored from the
/// paper's design decision to store it.
#[derive(Debug, Clone)]
pub struct SampleState {
    pub rows: usize,
    pub cols: usize,
    pub block: usize,
    /// Square-blockwise max|w|, grid row-major.
    pub amax: Vec<f32>,
    /// Per-block scale `amax · 2^(1−b_t)`.
    pub scale: Vec<f32>,
    /// Packed noise codes (sign–mantissa nibbles).
    pub noise: PackedNoise,
}

impl SampleState {
    /// Grid width (blocks per row of blocks).
    #[inline]
    pub fn grid_cols(&self) -> usize {
        self.cols.div_ceil(self.block)
    }

    /// Grid height.
    #[inline]
    pub fn grid_rows(&self) -> usize {
        self.rows.div_ceil(self.block)
    }

    /// Temporary memory footprint in bytes (the Table-1 accounting).
    pub fn noise_bytes(&self) -> usize {
        self.noise.storage_bytes()
    }
}

/// Eq. 3 forward: sample `ŵ` from `w` with per-block bitwidth `bt`
/// (grid row-major, `⌈rows/b⌉ × ⌈cols/b⌉`), writing bf16-rounded values
/// into `w_hat`. Returns the state needed for the backward pass.
///
/// `seed` must come from the layer's [`crate::prng::SeedTree`] stream so the
/// backward pass can regenerate the same noise.
pub fn forward(
    w: &[f32],
    rows: usize,
    cols: usize,
    block: usize,
    bt: &[f32],
    seed: u64,
    gen: NoiseGen,
    w_hat: &mut [f32],
) -> SampleState {
    assert_eq!(w.len(), rows * cols);
    assert_eq!(w_hat.len(), w.len());
    let grid_c = cols.div_ceil(block);
    let grid_r = rows.div_ceil(block);
    assert_eq!(bt.len(), grid_r * grid_c);

    let amax = block_absmax_f32(w, rows, cols, block);
    let scale: Vec<f32> =
        amax.iter().zip(bt.iter()).map(|(&a, &b)| a * (1.0 - b).exp2()).collect();
    let noise = match gen {
        NoiseGen::Exact => generate_exact(seed, w.len()),
        NoiseGen::Fast => generate_fast(seed, w.len()),
    };

    // Row-major traversal; per row the block index changes every `block`
    // columns. Perf pass (EXPERIMENTS.md §Perf): noise nibbles are decoded
    // a packed word (8 elements) at a time through a 16-entry value LUT
    // instead of per-element shifts, and the inner 8-wide loop is
    // branch-free so it vectorizes.
    const NIB_VAL: [f32; 16] = [
        0.0, 1.0, 2.0, 0.0, 0.0, 0.0, 0.0, 0.0, -0.0, -1.0, -2.0, 0.0, 0.0, 0.0, 0.0, 0.0,
    ];
    for r in 0..rows {
        let br = r / block;
        let row_off = r * cols;
        let mut c = 0;
        while c < cols {
            let bc = c / block;
            let end = ((bc + 1) * block).min(cols);
            let s = scale[br * grid_c + bc];
            let mut cc = c;
            // fast path: segments aligned to packed words of 8 nibbles
            while cc + 8 <= end && (row_off + cc) % 8 == 0 {
                let i = row_off + cc;
                let word = noise.words[i / 8];
                for j in 0..8 {
                    let v = NIB_VAL[((word >> (j * 4)) & 0xF) as usize];
                    w_hat[i + j] = Bf16::from_f32(w[i + j] + v * s).to_f32();
                }
                cc += 8;
            }
            for c2 in cc..end {
                let i = row_off + c2;
                let rv = noise.get(i) as f32;
                w_hat[i] = Bf16::from_f32(w[i] + rv * s).to_f32();
            }
            c = end;
        }
    }
    SampleState { rows, cols, block, amax, scale, noise }
}

/// Eq. 4 backward: given `g = ∂L/∂ŵ` and the forward state, accumulate
/// `∂L/∂b_t` per block. (`∂L/∂w` is the identity map, so callers reuse `g`.)
pub fn backward_bt(state: &SampleState, g: &[f32]) -> Vec<f32> {
    assert_eq!(g.len(), state.rows * state.cols);
    let grid_c = state.grid_cols();
    let mut dot = vec![0f64; state.scale.len()]; // Σ_bl (g ⊙ R), f64 accum
    for r in 0..state.rows {
        let br = r / state.block;
        let row_off = r * state.cols;
        for c in 0..state.cols {
            let rv = state.noise.get(row_off + c);
            if rv != 0 {
                dot[br * grid_c + c / state.block] += g[row_off + c] as f64 * rv as f64;
            }
        }
    }
    let ln2 = std::f64::consts::LN_2;
    state
        .scale
        .iter()
        .zip(dot.iter())
        .map(|(&s, &d)| (-ln2 * s as f64 * d) as f32)
        .collect()
}

/// Convenience: the PQN alone (`ŵ − w` before the bf16 cast) for analysis
/// and tests.
pub fn pqn(state: &SampleState) -> Vec<f32> {
    let grid_c = state.grid_cols();
    let mut out = vec![0f32; state.rows * state.cols];
    for r in 0..state.rows {
        let br = r / state.block;
        for c in 0..state.cols {
            let i = r * state.cols + c;
            let s = state.scale[br * grid_c + c / state.block];
            out[i] = state.noise.get(i) as f32 * s;
        }
    }
    out
}

/// Count noise values by code over a state (diagnostics).
pub fn noise_histogram(state: &SampleState) -> [usize; 5] {
    let mut h = [0usize; 5];
    for i in 0..state.noise.len {
        h[(state.noise.get(i) + 2) as usize] += 1;
    }
    h
}

/// Re-derive the integer noise value at element `i` (exposed for tests).
#[inline]
pub fn noise_at(state: &SampleState, i: usize) -> i32 {
    decode_nibble((state.noise.words[i / 8] >> ((i % 8) * 4)) & 0xF)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::{check, Gen};

    fn sample_setup(g: &mut Gen, rows: usize, cols: usize, block: usize) -> (Vec<f32>, Vec<f32>) {
        let w = g.normal_vec_f32(rows * cols);
        let grid = rows.div_ceil(block) * cols.div_ceil(block);
        let bt: Vec<f32> = (0..grid).map(|_| g.f64_in(3.0, 8.0) as f32).collect();
        (w, bt)
    }

    #[test]
    fn forward_matches_manual_formula() {
        check("gaussws fwd formula", 20, |g| {
            let (rows, cols, block) = (40, 36, 16);
            let (w, bt) = sample_setup(g, rows, cols, block);
            let seed = g.u64();
            let mut what = vec![0f32; w.len()];
            let st = forward(&w, rows, cols, block, &bt, seed, NoiseGen::Exact, &mut what);
            let grid_c = cols.div_ceil(block);
            for r in 0..rows {
                for c in 0..cols {
                    let i = r * cols + c;
                    let blk = (r / block) * grid_c + c / block;
                    let expect = crate::numerics::Bf16::from_f32(
                        w[i] + st.noise.get(i) as f32 * st.amax[blk] * (1.0 - bt[blk]).exp2(),
                    )
                    .to_f32();
                    if what[i] != expect {
                        return Err(format!("({r},{c}): {} vs {}", what[i], expect));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn zero_noise_elements_only_feel_bf16_cast() {
        check("R=0 passthrough", 10, |g| {
            let (rows, cols, block) = (32, 32, 32);
            let (w, bt) = sample_setup(g, rows, cols, block);
            let mut what = vec![0f32; w.len()];
            let st = forward(&w, rows, cols, block, &bt, g.u64(), NoiseGen::Fast, &mut what);
            for i in 0..w.len() {
                if st.noise.get(i) == 0 {
                    let expect = crate::numerics::Bf16::from_f32(w[i]).to_f32();
                    if what[i] != expect {
                        return Err(format!("elem {i}"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn same_seed_same_sample() {
        let mut g = Gen::new(1);
        let (w, bt) = sample_setup(&mut g, 64, 64, 32);
        let mut a = vec![0f32; w.len()];
        let mut b = vec![0f32; w.len()];
        forward(&w, 64, 64, 32, &bt, 777, NoiseGen::Fast, &mut a);
        forward(&w, 64, 64, 32, &bt, 777, NoiseGen::Fast, &mut b);
        assert_eq!(a, b);
        forward(&w, 64, 64, 32, &bt, 778, NoiseGen::Fast, &mut b);
        assert_ne!(a, b);
    }

    #[test]
    fn backward_bt_matches_finite_difference() {
        // dL/db_t via Eq. 4 vs central finite differences on a quadratic
        // loss L = Σ ŵ² / 2 (so ∂L/∂ŵ = ŵ), computed WITHOUT the bf16 cast
        // (use pqn directly) to avoid rounding noise in the FD.
        let mut g = Gen::new(2);
        let (rows, cols, block) = (32, 32, 32);
        let w = g.normal_vec_f32(rows * cols);
        let bt0 = 5.0f32;
        let seed = 42;

        let loss = |bt_val: f32| -> f64 {
            let bt = vec![bt_val];
            let mut what = vec![0f32; w.len()];
            let st = forward(&w, rows, cols, block, &bt, seed, NoiseGen::Exact, &mut what);
            // loss on the un-cast sample to keep FD smooth
            let p = pqn(&st);
            w.iter().zip(p.iter()).map(|(&wi, &pi)| ((wi + pi) as f64).powi(2) / 2.0).sum()
        };

        // analytic grad at bt0 (on the same un-cast ŵ)
        let bt = vec![bt0];
        let mut what = vec![0f32; w.len()];
        let st = forward(&w, rows, cols, block, &bt, seed, NoiseGen::Exact, &mut what);
        let p = pqn(&st);
        let gvec: Vec<f32> = w.iter().zip(p.iter()).map(|(&wi, &pi)| wi + pi).collect();
        let analytic = backward_bt(&st, &gvec)[0] as f64;

        let h = 1e-3f32;
        let fd = (loss(bt0 + h) - loss(bt0 - h)) / (2.0 * h as f64);
        let rel = (analytic - fd).abs() / fd.abs().max(1e-9);
        assert!(rel < 5e-3, "analytic={analytic} fd={fd} rel={rel}");
    }

    #[test]
    fn larger_bt_means_smaller_noise() {
        let mut g = Gen::new(3);
        let w = g.normal_vec_f32(64 * 64);
        for (lo, hi) in [(3.0f32, 6.0f32), (4.0, 8.0)] {
            let mut what = vec![0f32; w.len()];
            let s_lo = forward(&w, 64, 64, 32, &vec![lo; 4], 9, NoiseGen::Exact, &mut what);
            let s_hi = forward(&w, 64, 64, 32, &vec![hi; 4], 9, NoiseGen::Exact, &mut what);
            let mag = |st: &SampleState| {
                pqn(st).iter().map(|x| x.abs() as f64).sum::<f64>() / (64.0 * 64.0)
            };
            assert!(
                mag(&s_lo) > mag(&s_hi) * (2f64.powf((hi - lo) as f64) * 0.9),
                "noise should shrink ~2^Δb"
            );
        }
    }

    #[test]
    fn grad_w_is_identity_semantics() {
        // Eq. 4: ∂L/∂w = ∂L/∂ŵ — the module layer just forwards g; assert
        // the noise term is zero-mean so the passthrough is unbiased.
        let mut g = Gen::new(4);
        let w = g.normal_vec_f32(128 * 128);
        let bt = vec![4.0f32; 16];
        let mut what = vec![0f32; w.len()];
        let st = forward(&w, 128, 128, 32, &bt, 5, NoiseGen::Exact, &mut what);
        let p = pqn(&st);
        let mean: f64 = p.iter().map(|&x| x as f64).sum::<f64>() / p.len() as f64;
        let s = st.scale.iter().cloned().fold(0f32, f32::max) as f64;
        assert!(mean.abs() < 0.05 * s, "PQN mean {mean} too biased vs scale {s}");
    }

    #[test]
    fn noise_storage_accounting() {
        let mut g = Gen::new(5);
        let w = g.normal_vec_f32(64 * 64);
        let mut what = vec![0f32; w.len()];
        let st = forward(&w, 64, 64, 32, &vec![4.0; 4], 1, NoiseGen::Fast, &mut what);
        assert_eq!(st.noise_bytes(), 64 * 64 / 2); // 0.5 B per element
    }
}
