//! GaussWS pseudo-quantization training core: the Eq. 3/4 sampling ops
//! (rounded-normal and DiffQ-uniform arms), bitwidth parametrization, the
//! `PqtLinear` module, and the layer-selection policy.

pub mod bitwidth;
pub mod diffq;
pub mod gaussws;
pub mod module;
pub mod policy;

pub use bitwidth::{bt_stats, BitwidthParam, BtStats};
pub use gaussws::NoiseGen;
pub use module::{FwdState, PqtGrads, PqtLinear};
pub use policy::Policy;
