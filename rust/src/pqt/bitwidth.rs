//! Bitwidth parametrization (paper §3.6 "Bitwidth") and the Fig. 5
//! statistics.
//!
//! Each 32×32 block of a PQT linear owns an internal parameter `b_i`,
//! initialized to 1, linearly mapped to the effective bitwidth
//!
//! ```text
//! b_t = b_target + b_i · (b_init − b_target)           (Eq. 11)
//! ```
//!
//! so training starts at `b_init` and weight decay on `b_i` pulls `b_t`
//! toward `b_target`. Optionally the Eq. 12 loss term
//! `λ · Σ_layers mean_blocks |b_t − b_target|` adds explicit pressure.

/// Per-layer bitwidth parameter: one `b_i` per square block.
#[derive(Debug, Clone)]
pub struct BitwidthParam {
    /// Internal parameters, grid row-major; init 1.0.
    pub b_i: Vec<f32>,
    pub b_init: f32,
    pub b_target: f32,
}

impl BitwidthParam {
    pub fn new(n_blocks: usize, b_init: f64, b_target: f64) -> Self {
        BitwidthParam {
            b_i: vec![1.0; n_blocks],
            b_init: b_init as f32,
            b_target: b_target as f32,
        }
    }

    /// Eq. 11 map for one block.
    #[inline]
    pub fn bt_of(&self, bi: f32) -> f32 {
        self.b_target + bi * (self.b_init - self.b_target)
    }

    /// Effective bitwidths for all blocks.
    pub fn bt(&self) -> Vec<f32> {
        self.b_i.iter().map(|&bi| self.bt_of(bi)).collect()
    }

    /// Chain rule from ∂L/∂b_t to ∂L/∂b_i.
    pub fn grad_bi(&self, grad_bt: &[f32]) -> Vec<f32> {
        debug_assert_eq!(grad_bt.len(), self.b_i.len());
        let k = self.b_init - self.b_target;
        grad_bt.iter().map(|&g| g * k).collect()
    }

    /// Eq. 12 loss contribution of this layer: `mean_blocks |b_t − b_target|`.
    pub fn lambda_loss(&self) -> f64 {
        if self.b_i.is_empty() {
            return 0.0;
        }
        let sum: f64 = self
            .b_i
            .iter()
            .map(|&bi| (self.bt_of(bi) - self.b_target).abs() as f64)
            .sum();
        sum / self.b_i.len() as f64
    }

    /// ∂(Eq. 12 layer term)/∂b_i: `sign(b_t − b_target)·(b_init − b_target)/m`.
    pub fn lambda_grad_bi(&self) -> Vec<f32> {
        let m = self.b_i.len() as f32;
        let k = self.b_init - self.b_target;
        self.b_i
            .iter()
            .map(|&bi| {
                let d = self.bt_of(bi) - self.b_target;
                d.signum() * k / m
            })
            .collect()
    }
}

/// Fig. 5 tier boundaries: parameters with `b_t ≤ 5`, `≤ 9`, `≤ 12` map to
/// FP8_e3m4 / FP12_e4m7 / FP16 respectively (paper §5).
pub const TIER_BOUNDS: [f32; 3] = [5.0, 9.0, 12.0];

/// Summary statistics of resulting bitwidths for one layer (Fig. 5 rows).
#[derive(Debug, Clone, PartialEq)]
pub struct BtStats {
    pub mean: f64,
    pub std: f64,
    pub min: f32,
    pub max: f32,
    /// Fractions of blocks with b_t ≤ 5 / ≤ 9 / ≤ 12 (cumulative tiers).
    pub tier_frac: [f64; 3],
}

/// Compute Fig. 5 statistics from a layer's effective bitwidths.
pub fn bt_stats(bt: &[f32]) -> BtStats {
    assert!(!bt.is_empty());
    let n = bt.len() as f64;
    let mean = bt.iter().map(|&x| x as f64).sum::<f64>() / n;
    let var = bt.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / n;
    let min = bt.iter().cloned().fold(f32::INFINITY, f32::min);
    let max = bt.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut tier_frac = [0f64; 3];
    for (t, &bound) in TIER_BOUNDS.iter().enumerate() {
        tier_frac[t] = bt.iter().filter(|&&x| x <= bound).count() as f64 / n;
    }
    BtStats { mean, std: var.sqrt(), min, max, tier_frac }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq11_map() {
        let p = BitwidthParam::new(4, 6.0, 4.0);
        // b_i = 1 -> b_t = b_init
        assert_eq!(p.bt(), vec![6.0; 4]);
        // b_i = 0 -> b_t = b_target
        assert_eq!(p.bt_of(0.0), 4.0);
        // halfway
        assert_eq!(p.bt_of(0.5), 5.0);
    }

    #[test]
    fn chain_rule_scale() {
        let p = BitwidthParam::new(2, 8.0, 6.0);
        assert_eq!(p.grad_bi(&[1.0, -2.0]), vec![2.0, -4.0]);
    }

    #[test]
    fn lambda_loss_and_grad() {
        let mut p = BitwidthParam::new(2, 6.0, 4.0);
        p.b_i = vec![1.0, 0.5];
        // |b_t - target| = |6-4| and |5-4| -> mean 1.5
        assert!((p.lambda_loss() - 1.5).abs() < 1e-9);
        let g = p.lambda_grad_bi();
        // sign(+)·2/2 = 1 for both
        assert_eq!(g, vec![1.0, 1.0]);
    }

    #[test]
    fn lambda_grad_matches_fd() {
        let mut p = BitwidthParam::new(3, 6.0, 4.0);
        p.b_i = vec![0.9, 0.2, 0.6];
        let g = p.lambda_grad_bi();
        let h = 1e-4;
        for k in 0..3 {
            let mut ph = p.clone();
            ph.b_i[k] += h;
            let mut pl = p.clone();
            pl.b_i[k] -= h;
            let fd = (ph.lambda_loss() - pl.lambda_loss()) / (2.0 * h as f64);
            assert!((g[k] as f64 - fd).abs() < 1e-3, "k={k}: {} vs {fd}", g[k]);
        }
    }

    #[test]
    fn stats_and_tiers() {
        let bt = [4.0f32, 5.0, 6.0, 9.0, 10.0, 12.0];
        let s = bt_stats(&bt);
        assert!((s.mean - 7.666666).abs() < 1e-4);
        assert_eq!(s.min, 4.0);
        assert_eq!(s.max, 12.0);
        assert_eq!(s.tier_frac[0], 2.0 / 6.0); // <=5
        assert_eq!(s.tier_frac[1], 4.0 / 6.0); // <=9
        assert_eq!(s.tier_frac[2], 1.0); // <=12
    }

    #[test]
    fn weight_decay_drives_bt_to_target() {
        // simulate decoupled weight decay: b_i <- b_i (1 - lr*wd)
        let mut p = BitwidthParam::new(1, 6.0, 4.0);
        for _ in 0..2000 {
            p.b_i[0] *= 1.0 - 0.01 * 0.1;
        }
        assert!(p.bt()[0] < 4.3, "b_t={}", p.bt()[0]);
        assert!(p.bt()[0] >= 4.0);
    }
}
