//! `PqtLinear` — the modularized `f(w, b_t) = ŵ` unit the paper describes
//! in §3.5 ("a single PyTorch module" there; a single rust struct here).
//!
//! Owns the master weight `w` (f32), the per-block bitwidth parameter
//! `b_i`, and its layer seed stream; produces the sampled `ŵ` each step and
//! maps upstream gradients back onto `(w, b_i)`.

use super::bitwidth::{bt_stats, BitwidthParam, BtStats};
use super::gaussws::{self, NoiseGen, SampleState};
use super::{diffq, diffq::DiffqState};
use crate::config::schema::{PqtConfig, PqtMethod};
use crate::numerics::fpformat::{formats, Rounding};
use crate::prng::Philox4x32;
use crate::quant::{Codec, Scheme};

/// True iff `cast` is the paper's default ŵ operator (elementwise BF16
/// round-to-nearest-even) — the fast path baked into the sampling kernels.
fn is_bf16_rne(cast: &Scheme) -> bool {
    cast.codec == Codec::Fp(formats::BF16) && cast.rounding == Rounding::NearestEven
}

/// Derived PRNG stream for stochastic ŵ casts, decorrelated from the noise
/// generator's use of the same layer seed.
fn cast_rng(seed: u64) -> Philox4x32 {
    Philox4x32::new(seed ^ 0x00CA_5700_00CA_5700)
}

/// Re-cast `w + pqn` through a non-default scheme (no double rounding: the
/// pre-cast sample is reconstructed from the saved noise state).
fn recast(cast: &Scheme, w: &[f32], pqn: &[f32], seed: u64, w_hat: &mut [f32]) {
    let stochastic = cast.rounding == Rounding::Stochastic;
    let mut rng = cast_rng(seed);
    for ((o, &x), &p) in w_hat.iter_mut().zip(w.iter()).zip(pqn.iter()) {
        let rand = if stochastic { rng.next_u32() } else { 0 };
        *o = cast.cast_f32(x + p, rand);
    }
}

/// Per-step forward output state (consumed by `backward`).
#[derive(Debug)]
pub enum FwdState {
    /// BF16 baseline: no noise, nothing to store.
    Baseline,
    Gauss(SampleState),
    Diffq(DiffqState),
}

impl FwdState {
    /// Temporary noise bytes held for the backward pass (Table 1 memory
    /// accounting; ŵ itself adds 2 B/param on top in all PQT arms).
    pub fn noise_bytes(&self) -> usize {
        match self {
            FwdState::Baseline => 0,
            FwdState::Gauss(s) => s.noise_bytes(),
            FwdState::Diffq(s) => s.noise_bytes(),
        }
    }
}

/// Gradients produced by the backward pass.
#[derive(Debug, Clone)]
pub struct PqtGrads {
    /// ∂L/∂b_i per block (empty for the baseline).
    pub grad_bi: Vec<f32>,
}

/// A linear layer's PQT state.
#[derive(Debug, Clone)]
pub struct PqtLinear {
    /// Qualified name, e.g. "blk3.qkv" (stable key into the seed tree).
    pub name: String,
    pub rows: usize,
    pub cols: usize,
    /// Square block size b_l (32 in the paper).
    pub block: usize,
    pub method: PqtMethod,
    /// Per-block bitwidths (present for PQT arms; len 0 for baseline).
    pub bw: BitwidthParam,
    /// Noise generator variant for the GaussWS arm.
    pub gen: NoiseGen,
    /// The ŵ cast scheme (elementwise): the paper's "BF16 operator" by
    /// default, swappable through the quant registry (e.g. `fp8_e4m3` for
    /// an FP8-operator arm, `fp4_e2m1_sr` for FP4 FQT experiments).
    pub cast: Scheme,
}

impl PqtLinear {
    pub fn new(
        name: &str,
        rows: usize,
        cols: usize,
        block: usize,
        method: PqtMethod,
        b_init: f64,
        b_target: f64,
    ) -> Self {
        let grid = rows.div_ceil(block) * cols.div_ceil(block);
        let n_blocks = if method == PqtMethod::None { 0 } else { grid };
        PqtLinear {
            name: name.to_string(),
            rows,
            cols,
            block,
            method,
            bw: BitwidthParam::new(n_blocks, b_init, b_target),
            gen: NoiseGen::Fast,
            cast: crate::quant::resolve("bf16").expect("builtin scheme").elementwise(),
        }
    }

    /// Build a layer straight from a parsed `[pqt]` config table — the
    /// production path that consumes `pqt.cast` (the ŵ-operator scheme
    /// resolved through the quant registry) alongside method/block/bitwidth
    /// settings.
    pub fn from_config(name: &str, rows: usize, cols: usize, pqt: &PqtConfig) -> Self {
        PqtLinear::new(name, rows, cols, pqt.block, pqt.method, pqt.b_init, pqt.b_target)
            .with_cast(pqt.cast.clone())
    }

    /// Replace the ŵ cast scheme (forced elementwise — the ŵ operator casts
    /// values, block scaling belongs to the noise path).
    pub fn with_cast(mut self, cast: Scheme) -> Self {
        self.cast = cast.elementwise();
        self
    }

    /// Number of square blocks in the grid.
    pub fn n_blocks(&self) -> usize {
        self.rows.div_ceil(self.block) * self.cols.div_ceil(self.block)
    }

    /// Sample `ŵ` from `w` for this step. `seed` comes from the layer's
    /// seed-tree stream. For the baseline this is the bf16 cast of `w`
    /// (the BF16 operator consumes bf16 weights either way).
    pub fn forward(&self, w: &[f32], seed: u64, w_hat: &mut [f32]) -> FwdState {
        assert_eq!(w.len(), self.rows * self.cols);
        let default_cast = is_bf16_rne(&self.cast);
        match self.method {
            PqtMethod::None => {
                if default_cast {
                    for (o, &x) in w_hat.iter_mut().zip(w.iter()) {
                        *o = crate::numerics::Bf16::from_f32(x).to_f32();
                    }
                } else {
                    let stochastic = self.cast.rounding == Rounding::Stochastic;
                    let mut rng = cast_rng(seed);
                    for (o, &x) in w_hat.iter_mut().zip(w.iter()) {
                        let rand = if stochastic { rng.next_u32() } else { 0 };
                        *o = self.cast.cast_f32(x, rand);
                    }
                }
                FwdState::Baseline
            }
            PqtMethod::GaussWs => {
                let bt = self.bw.bt();
                let st = gaussws::forward(
                    w, self.rows, self.cols, self.block, &bt, seed, self.gen, w_hat,
                );
                if !default_cast {
                    // Non-default operators pay one extra pass (rebuild the
                    // PQN, overwrite the kernel's bf16 ŵ) — deliberate: the
                    // default bf16 hot path stays kernel-shaped and untouched.
                    recast(&self.cast, w, &gaussws::pqn(&st), seed, w_hat);
                }
                FwdState::Gauss(st)
            }
            PqtMethod::DiffQ => {
                let bt = self.bw.bt();
                let st =
                    diffq::forward(w, self.rows, self.cols, self.block, &bt, seed, w_hat);
                if !default_cast {
                    recast(&self.cast, w, &diffq::pqn(&st), seed, w_hat);
                }
                FwdState::Diffq(st)
            }
        }
    }

    /// Backward: given `g = ∂L/∂ŵ`, return PQT-parameter grads.
    /// (∂L/∂w = g, Eq. 4 — the caller routes `g` straight to the weight
    /// optimizer; we only produce ∂L/∂b_i here.)
    pub fn backward(&self, state: &FwdState, g: &[f32]) -> PqtGrads {
        match state {
            FwdState::Baseline => PqtGrads { grad_bi: vec![] },
            FwdState::Gauss(s) => {
                let grad_bt = gaussws::backward_bt(s, g);
                PqtGrads { grad_bi: self.bw.grad_bi(&grad_bt) }
            }
            FwdState::Diffq(s) => {
                let grad_bt = diffq::backward_bt(s, g);
                PqtGrads { grad_bi: self.bw.grad_bi(&grad_bt) }
            }
        }
    }

    /// Apply one optimizer step to `b_i`: SGD on the (λ-scaled) gradient
    /// plus decoupled weight decay toward 0 — the paper's mechanism for
    /// guiding b_t to b_target.
    pub fn update_bi(&mut self, grads: &PqtGrads, lr: f64, weight_decay: f64, lambda: f64) {
        if self.bw.b_i.is_empty() {
            return;
        }
        let lam_g = if lambda != 0.0 { self.bw.lambda_grad_bi() } else { vec![] };
        for (k, bi) in self.bw.b_i.iter_mut().enumerate() {
            let mut g = grads.grad_bi.get(k).copied().unwrap_or(0.0) as f64;
            if lambda != 0.0 {
                g += lambda * lam_g[k] as f64;
            }
            *bi = (*bi as f64 * (1.0 - lr * weight_decay) - lr * g) as f32;
        }
    }

    /// Fig. 5 statistics of this layer's current effective bitwidths.
    pub fn stats(&self) -> Option<BtStats> {
        if self.bw.b_i.is_empty() {
            None
        } else {
            Some(bt_stats(&self.bw.bt()))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::Gen;

    fn layer(method: PqtMethod) -> PqtLinear {
        PqtLinear::new("blk0.qkv", 64, 64, 32, method, 6.0, 4.0)
    }

    #[test]
    fn baseline_is_bf16_cast() {
        let mut g = Gen::new(1);
        let w = g.normal_vec_f32(64 * 64);
        let l = layer(PqtMethod::None);
        let mut what = vec![0f32; w.len()];
        let st = l.forward(&w, 123, &mut what);
        for (i, (&a, &b)) in what.iter().zip(w.iter()).enumerate() {
            assert_eq!(a, crate::numerics::Bf16::from_f32(b).to_f32(), "{i}");
        }
        assert_eq!(st.noise_bytes(), 0);
        assert!(l.backward(&st, &w).grad_bi.is_empty());
    }

    #[test]
    fn gaussws_forward_backward_roundtrip() {
        let mut g = Gen::new(2);
        let w = g.normal_vec_f32(64 * 64);
        let l = layer(PqtMethod::GaussWs);
        let mut what = vec![0f32; w.len()];
        let st = l.forward(&w, 99, &mut what);
        assert_ne!(what, w);
        let grads = l.backward(&st, &what);
        assert_eq!(grads.grad_bi.len(), l.n_blocks());
    }

    #[test]
    fn bi_update_decays_toward_target() {
        let mut g = Gen::new(3);
        let w = g.normal_vec_f32(64 * 64);
        let mut l = layer(PqtMethod::GaussWs);
        let mut what = vec![0f32; w.len()];
        let zero_g = PqtGrads { grad_bi: vec![0.0; l.n_blocks()] };
        let bt0 = l.bw.bt()[0];
        let _ = l.forward(&w, 1, &mut what);
        for _ in 0..100 {
            l.update_bi(&zero_g, 1e-2, 0.5, 0.0);
        }
        let bt1 = l.bw.bt()[0];
        assert!(bt1 < bt0, "{bt1} !< {bt0}");
        assert!(bt1 >= l.bw.b_target);
    }

    #[test]
    fn lambda_pressure_reduces_bt_faster() {
        let mut a = layer(PqtMethod::GaussWs);
        let mut b = layer(PqtMethod::GaussWs);
        let zero = PqtGrads { grad_bi: vec![0.0; a.n_blocks()] };
        for _ in 0..50 {
            a.update_bi(&zero, 1e-2, 0.1, 0.0);
            b.update_bi(&zero, 1e-2, 0.1, 1.0); // strong λ
        }
        assert!(b.bw.bt()[0] < a.bw.bt()[0]);
    }

    #[test]
    fn stats_reflect_current_bt() {
        let l = layer(PqtMethod::GaussWs);
        let s = l.stats().unwrap();
        assert_eq!(s.mean, 6.0); // b_i = 1 -> b_t = b_init
        assert!(layer(PqtMethod::None).stats().is_none());
    }

    #[test]
    fn non_default_cast_schemes_apply_elementwise() {
        use crate::numerics::fpformat::formats::FP8_E4M3;
        let mut g = Gen::new(5);
        let w = g.normal_vec_f32(64 * 64);
        let fp8 = crate::quant::resolve("fp8_e4m3").unwrap();
        // baseline arm: ŵ is the plain fp8 cast of w
        let l = layer(PqtMethod::None).with_cast(fp8.clone());
        let mut what = vec![0f32; w.len()];
        l.forward(&w, 11, &mut what);
        for (i, (&a, &b)) in what.iter().zip(w.iter()).enumerate() {
            assert_eq!(a as f64, FP8_E4M3.cast(b as f64), "{i}");
        }
        // gaussws arm: ŵ = fp8(w + pqn), not bf16 double-rounded
        let l = layer(PqtMethod::GaussWs).with_cast(fp8);
        let st = l.forward(&w, 12, &mut what);
        if let FwdState::Gauss(s) = &st {
            let p = super::gaussws::pqn(s);
            for i in 0..w.len() {
                let expect = FP8_E4M3.cast((w[i] + p[i]) as f64) as f32;
                assert_eq!(what[i], expect, "{i}");
            }
        } else {
            panic!("expected gauss state");
        }
    }

    #[test]
    fn from_config_wires_cast_and_bitwidths() {
        use crate::config::schema::PqtConfig;
        use crate::quant::QuantScheme;
        let pqt = PqtConfig {
            cast: crate::quant::resolve("fp8_e4m3").unwrap(),
            b_init: 5.0,
            ..PqtConfig::default()
        };
        let l = PqtLinear::from_config("blk0.out", 64, 64, &pqt);
        assert_eq!(l.cast.label(), "fp8_e4m3");
        assert_eq!(l.block, pqt.block);
        assert_eq!(l.bw.bt()[0], 5.0);
        assert!(!is_bf16_rne(&l.cast));
    }

    #[test]
    fn stochastic_cast_reproduces_per_seed() {
        let mut g = Gen::new(6);
        let w = g.normal_vec_f32(32 * 32);
        let l = layer(PqtMethod::None).with_cast(crate::quant::resolve("fp4_e2m1_sr").unwrap());
        let mut a = vec![0f32; w.len()];
        let mut b = vec![0f32; w.len()];
        l.forward(&w, 42, &mut a);
        l.forward(&w, 42, &mut b);
        assert_eq!(a, b);
        l.forward(&w, 43, &mut b);
        assert_ne!(a, b);
    }

    #[test]
    fn diffq_and_gaussws_share_interface() {
        let mut g = Gen::new(4);
        let w = g.normal_vec_f32(64 * 64);
        for m in [PqtMethod::DiffQ, PqtMethod::GaussWs] {
            let l = layer(m);
            let mut what = vec![0f32; w.len()];
            let st = l.forward(&w, 7, &mut what);
            let grads = l.backward(&st, &w);
            assert_eq!(grads.grad_bi.len(), 4);
            assert!(st.noise_bytes() > 0);
        }
    }
}
