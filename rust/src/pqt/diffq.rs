//! DiffQ-style baseline PQT op: identical to [`super::gaussws`] except the
//! noise basis is uniform `U(-0.5, 0.5)` in f32 ("BF16 U(-0.5,0.5) in place
//! of ⌊N(0,1)/2⌉" — paper §4 notation "DiffQ").
//!
//! This is the comparison arm of Figures 1b/3/4 and Table 1. Uniform noise
//! costs 2 B/element of temporary storage (vs 0.5 B packed for GaussWS) and
//! requires FP generation (PRNG ints → divide), which is what makes it
//! slower (§4.2).

use crate::mx::block::block_absmax_f32;
use crate::numerics::Bf16;
use crate::prng::Philox4x32;

/// Saved forward state for the backward pass. `noise` is kept dense in f32
/// (2 B/element as bf16 would be, 4 here for simplicity — accounted as 2 in
/// the memory model since the paper stores BF16 noise).
#[derive(Debug, Clone)]
pub struct DiffqState {
    pub rows: usize,
    pub cols: usize,
    pub block: usize,
    pub amax: Vec<f32>,
    pub scale: Vec<f32>,
    pub noise: Vec<f32>,
}

impl DiffqState {
    #[inline]
    pub fn grid_cols(&self) -> usize {
        self.cols.div_ceil(self.block)
    }

    /// Temporary noise bytes in the paper's accounting (BF16 storage).
    pub fn noise_bytes(&self) -> usize {
        self.noise.len() * 2
    }
}

/// Forward: `ŵ = bf16(w + U(-0.5,0.5) ⊙ broadcast(amax · 2^(1−b_t)))`.
pub fn forward(
    w: &[f32],
    rows: usize,
    cols: usize,
    block: usize,
    bt: &[f32],
    seed: u64,
    w_hat: &mut [f32],
) -> DiffqState {
    assert_eq!(w.len(), rows * cols);
    assert_eq!(w_hat.len(), w.len());
    let grid_c = cols.div_ceil(block);
    let grid_r = rows.div_ceil(block);
    assert_eq!(bt.len(), grid_r * grid_c);

    let amax = block_absmax_f32(w, rows, cols, block);
    let scale: Vec<f32> =
        amax.iter().zip(bt.iter()).map(|(&a, &b)| a * (1.0 - b).exp2()).collect();

    // Uniform noise in bf16 precision (the paper's DiffQ extension runs the
    // same BF16 operator).
    let mut g = Philox4x32::new(seed);
    let mut noise = vec![0f32; w.len()];
    for n in noise.iter_mut() {
        *n = Bf16::from_f32(g.next_f32() - 0.5).to_f32();
    }

    for r in 0..rows {
        let br = r / block;
        let row_off = r * cols;
        let mut c = 0;
        while c < cols {
            let bc = c / block;
            let end = ((bc + 1) * block).min(cols);
            let s = scale[br * grid_c + bc];
            for cc in c..end {
                let i = row_off + cc;
                w_hat[i] = Bf16::from_f32(w[i] + noise[i] * s).to_f32();
            }
            c = end;
        }
    }
    DiffqState { rows, cols, block, amax, scale, noise }
}

/// The PQN alone (`ŵ − w` before the bf16 cast): `noise ⊙ broadcast(scale)`.
/// Mirror of [`super::gaussws::pqn`], used to re-cast ŵ under a non-BF16
/// [`crate::quant::Scheme`] without double rounding.
pub fn pqn(state: &DiffqState) -> Vec<f32> {
    let grid_c = state.grid_cols();
    let mut out = vec![0f32; state.rows * state.cols];
    for r in 0..state.rows {
        let br = r / state.block;
        for c in 0..state.cols {
            let i = r * state.cols + c;
            out[i] = state.noise[i] * state.scale[br * grid_c + c / state.block];
        }
    }
    out
}

/// Backward: ∂L/∂b_t per block (same Eq. 4 form, R = uniform noise).
pub fn backward_bt(state: &DiffqState, g: &[f32]) -> Vec<f32> {
    assert_eq!(g.len(), state.rows * state.cols);
    let grid_c = state.grid_cols();
    let mut dot = vec![0f64; state.scale.len()];
    for r in 0..state.rows {
        let br = r / state.block;
        let row_off = r * state.cols;
        for c in 0..state.cols {
            let i = row_off + c;
            dot[br * grid_c + c / state.block] += g[i] as f64 * state.noise[i] as f64;
        }
    }
    let ln2 = std::f64::consts::LN_2;
    state
        .scale
        .iter()
        .zip(dot.iter())
        .map(|(&s, &d)| (-ln2 * s as f64 * d) as f32)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::{check, Gen};

    #[test]
    fn forward_formula_holds() {
        check("diffq fwd formula", 10, |g| {
            let (rows, cols, block) = (32, 48, 16);
            let w = g.normal_vec_f32(rows * cols);
            let grid = (rows / block) * (cols / block);
            let bt: Vec<f32> = (0..grid).map(|_| g.f64_in(3.0, 8.0) as f32).collect();
            let mut what = vec![0f32; w.len()];
            let st = forward(&w, rows, cols, block, &bt, g.u64(), &mut what);
            let grid_c = cols / block;
            for r in 0..rows {
                for c in 0..cols {
                    let i = r * cols + c;
                    let blk = (r / block) * grid_c + c / block;
                    let expect =
                        Bf16::from_f32(w[i] + st.noise[i] * st.scale[blk]).to_f32();
                    if what[i] != expect {
                        return Err(format!("({r},{c})"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn noise_is_uniform_half_range() {
        let mut g = Gen::new(1);
        let w = g.normal_vec_f32(128 * 128);
        let mut what = vec![0f32; w.len()];
        let st = forward(&w, 128, 128, 32, &vec![4.0; 16], 3, &mut what);
        assert!(st.noise.iter().all(|&x| (-0.5..=0.5).contains(&x)));
        let mean: f64 = st.noise.iter().map(|&x| x as f64).sum::<f64>() / st.noise.len() as f64;
        assert!(mean.abs() < 5e-3);
        // uniform has NO mass at exactly zero (almost surely) unlike GaussWS
        let zeros = st.noise.iter().filter(|&&x| x == 0.0).count();
        assert!(zeros < st.noise.len() / 100);
    }

    #[test]
    fn deterministic_per_seed() {
        let mut g = Gen::new(2);
        let w = g.normal_vec_f32(64 * 64);
        let bt = vec![4.0f32; 4];
        let mut a = vec![0f32; w.len()];
        let mut b = vec![0f32; w.len()];
        forward(&w, 64, 64, 32, &bt, 5, &mut a);
        forward(&w, 64, 64, 32, &bt, 5, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn bt_grad_sign_and_magnitude() {
        // With g = noise (positively correlated), dot > 0 so grad < 0:
        // increasing b_t shrinks noise which shrinks this loss.
        let mut g = Gen::new(3);
        let w = g.normal_vec_f32(32 * 32);
        let bt = vec![5.0f32];
        let mut what = vec![0f32; w.len()];
        let st = forward(&w, 32, 32, 32, &bt, 7, &mut what);
        let grads = backward_bt(&st, &st.noise.clone());
        assert!(grads[0] < 0.0);
    }

    #[test]
    fn memory_accounting_is_4x_gaussws() {
        let mut g = Gen::new(4);
        let w = g.normal_vec_f32(64 * 64);
        let mut what = vec![0f32; w.len()];
        let st = forward(&w, 64, 64, 32, &vec![4.0; 4], 1, &mut what);
        // 2 B/elem (paper) vs 0.5 B/elem for packed GaussWS noise
        assert_eq!(st.noise_bytes(), 64 * 64 * 2);
    }
}
