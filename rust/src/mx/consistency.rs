//! Forward/backward consistency analysis (paper §2.1 and Figure D.1).
//!
//! An MX matmul quantizes `W` along the inner dimension. In the forward pass
//! of `T = A·W` the inner dim of `W` is its rows; in the backward pass
//! `∂L/∂A = ∂L/∂T · Wᵀ` the inner dim of `Wᵀ` is the *columns* of `W`.
//! Vector-wise scales therefore differ between the two passes, so the network
//! effectively trains through a different weight matrix than it evaluates.
//! Square 32×32 blocks make the two views identical.
//!
//! Measurements run through the [`crate::quant`] engine directly (RNE, an
//! explicit [`Codec`] per element type).

use super::block::transpose;
use crate::numerics::Rounding;
use crate::quant::{fake_quantize, Axis, Codec, Geometry, Quantized};

/// Result of a consistency measurement on one matrix.
#[derive(Debug, Clone)]
pub struct ConsistencyReport {
    /// Fraction of elements whose fake-quantized value differs between the
    /// forward view and the (transposed) backward view.
    pub mismatch_fraction: f64,
    /// Mean |forward − backward| over all elements.
    pub mean_abs_gap: f64,
    /// Max |forward − backward|.
    pub max_abs_gap: f64,
    /// RMS quantization error of the forward view vs the original weights.
    pub rms_error_fwd: f64,
}

fn quantize_vec_col(w: &[f64], rows: usize, cols: usize, block: usize, codec: &Codec) -> Quantized {
    fake_quantize(
        w,
        rows,
        cols,
        Geometry::Vector { block, axis: Axis::Col },
        codec,
        Rounding::NearestEven,
        0,
    )
}

fn fq_square(w: &[f64], rows: usize, cols: usize, block: usize, codec: &Codec) -> Quantized {
    fake_quantize(w, rows, cols, Geometry::Square { block }, codec, Rounding::NearestEven, 0)
}

/// Quantize `w` for the forward pass (blocks along the inner dim) and for
/// the backward pass (quantize `wᵀ` along the same logical axis, transpose
/// back), then compare element-wise.
pub fn measure_vectorwise(
    w: &[f64],
    rows: usize,
    cols: usize,
    block: usize,
    codec: &Codec,
) -> ConsistencyReport {
    // Forward: inner dim = rows of W -> 1×block vectors down the columns.
    let fwd = quantize_vec_col(w, rows, cols, block, codec);
    // Backward: W^T with inner dim = rows of W^T = cols of W.
    let wt = transpose(w, rows, cols);
    let bwd_t = quantize_vec_col(&wt, cols, rows, block, codec);
    let bwd = transpose(&bwd_t.data, cols, rows);
    compare(w, &fwd, &bwd)
}

/// Same measurement with square-blockwise quantization: the report's
/// mismatch fraction is provably zero.
pub fn measure_square(
    w: &[f64],
    rows: usize,
    cols: usize,
    block: usize,
    codec: &Codec,
) -> ConsistencyReport {
    let fwd = fq_square(w, rows, cols, block, codec);
    let wt = transpose(w, rows, cols);
    let bwd_t = fq_square(&wt, cols, rows, block, codec);
    let bwd = transpose(&bwd_t.data, cols, rows);
    compare(w, &fwd, &bwd)
}

fn compare(w: &[f64], fwd: &Quantized, bwd: &[f64]) -> ConsistencyReport {
    let n = w.len() as f64;
    let mut mismatches = 0usize;
    let mut sum_gap = 0f64;
    let mut max_gap = 0f64;
    let mut sum_err2 = 0f64;
    for i in 0..w.len() {
        let gap = (fwd.data[i] - bwd[i]).abs();
        if gap > 0.0 {
            mismatches += 1;
        }
        sum_gap += gap;
        max_gap = max_gap.max(gap);
        let e = fwd.data[i] - w[i];
        sum_err2 += e * e;
    }
    ConsistencyReport {
        mismatch_fraction: mismatches as f64 / n,
        mean_abs_gap: sum_gap / n,
        max_abs_gap: max_gap,
        rms_error_fwd: (sum_err2 / n).sqrt(),
    }
}

/// The exact Figure D.1 demonstration: a 4×4 `N(0,1)` matrix, INT4 internal
/// type, block size 2, vector-wise quantization. Returns the original, the
/// backward-view and forward-view fake-quantized matrices.
pub fn fig_d1_example(seed: u64) -> (Vec<f64>, Vec<f64>, Vec<f64>) {
    use crate::prng::gauss::box_muller_pair;
    use crate::prng::Philox4x32;
    let mut g = Philox4x32::new(seed);
    let mut w = vec![0f64; 16];
    for i in 0..8 {
        let (a, b) = box_muller_pair(&mut g);
        w[2 * i] = a;
        w[2 * i + 1] = b;
    }
    let codec = Codec::Int { bits: 4 };
    let bwd = {
        let wt = transpose(&w, 4, 4);
        let q = quantize_vec_col(&wt, 4, 4, 2, &codec);
        transpose(&q.data, 4, 4)
    };
    let fwd = quantize_vec_col(&w, 4, 4, 2, &codec).data;
    (w, bwd, fwd)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::gauss::box_muller_pair;
    use crate::prng::Philox4x32;

    fn randn(seed: u64, n: usize) -> Vec<f64> {
        let mut g = Philox4x32::new(seed);
        (0..n).map(|_| box_muller_pair(&mut g).0).collect()
    }

    const INT4: Codec = Codec::Int { bits: 4 };

    #[test]
    fn square_blocks_are_always_consistent() {
        for seed in 0..5 {
            let w = randn(seed, 96 * 64);
            let rep = measure_square(&w, 96, 64, 32, &INT4);
            assert_eq!(rep.mismatch_fraction, 0.0, "seed {seed}: {rep:?}");
            assert_eq!(rep.max_abs_gap, 0.0);
        }
    }

    #[test]
    fn vectorwise_blocks_are_inconsistent() {
        let w = randn(10, 96 * 64);
        let rep = measure_vectorwise(&w, 96, 64, 32, &INT4);
        assert!(rep.mismatch_fraction > 0.05, "expected visible mismatch: {rep:?}");
        assert!(rep.max_abs_gap > 0.0);
    }

    #[test]
    fn fig_d1_reproduces_discrepancy() {
        let (w, bwd, fwd) = fig_d1_example(2026);
        assert_eq!(w.len(), 16);
        assert_ne!(bwd, fwd, "Fig D.1: fwd and bwd views must differ");
    }

    #[test]
    fn quantization_error_similar_between_geometries() {
        // Square blocks fix consistency without materially worse RMS error.
        let w = randn(11, 128 * 128);
        let rv = measure_vectorwise(&w, 128, 128, 32, &INT4);
        let rs = measure_square(&w, 128, 128, 32, &INT4);
        assert!(
            rs.rms_error_fwd < rv.rms_error_fwd * 2.5,
            "square RMS {} vs vector {}",
            rs.rms_error_fwd,
            rv.rms_error_fwd
        );
    }
}
