//! Microscaling (MX)-style blockwise substrate — the geometry helpers the
//! PQT layers build on.
//!
//! MX (Rouhani et al., 2023) groups tensor elements into blocks of 32 that
//! share one power-of-two scale; each element is stored in a narrow internal
//! datatype (INT4/INT8/FP8/...). The paper builds on two block geometries:
//!
//! * **vector-wise** — 1×32 blocks along one axis (standard MX). Quantizing
//!   along the matmul inner dimension makes the forward and backward passes
//!   see *different* quantized weights after transposition (Fig. D.1).
//! * **square-blockwise** — 32×32 blocks, a special case of vector-wise
//!   where adjacent vectors share the scale. Transpose-commutative, which is
//!   why GaussWS groups parameters this way (§3.2).
//!
//! The quantization engine itself lives in [`crate::quant`] — schemes
//! composed from codec × rounding × geometry, resolved by label through
//! `quant::Registry`; call `quant::resolve("<label>")` or
//! [`crate::quant::fake_quantize`] directly. (The PR-2 square/vector-wise
//! quantizer compatibility shims are gone.) What remains here are the f32
//! block-geometry helpers the training-side PQT path uses.

pub use crate::quant::{Axis, Quantized};

/// Square-blockwise max-abs of an f32 matrix — the `max_bl(|w|)` of Eq. 3.
/// Returns the block grid row-major, `⌈rows/block⌉ × ⌈cols/block⌉`.
pub fn block_absmax_f32(w: &[f32], rows: usize, cols: usize, block: usize) -> Vec<f32> {
    assert_eq!(w.len(), rows * cols);
    let grid_r = rows.div_ceil(block);
    let grid_c = cols.div_ceil(block);
    let mut out = vec![0f32; grid_r * grid_c];
    for r in 0..rows {
        let br = r / block;
        let row = &w[r * cols..(r + 1) * cols];
        for (bc, chunk) in row.chunks(block).enumerate() {
            let mut m = out[br * grid_c + bc];
            for &v in chunk {
                let a = v.abs();
                if a > m {
                    m = a;
                }
            }
            out[br * grid_c + bc] = m;
        }
    }
    out
}

/// Transpose a row-major f64 matrix.
pub fn transpose(w: &[f64], rows: usize, cols: usize) -> Vec<f64> {
    let mut out = vec![0f64; w.len()];
    for r in 0..rows {
        for c in 0..cols {
            out[c * rows + r] = w[r * cols + c];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numerics::Rounding;
    use crate::prng::Philox4x32;
    use crate::quant::{fake_quantize, Codec, Geometry};

    fn randn(seed: u64, n: usize) -> Vec<f64> {
        let mut g = Philox4x32::new(seed);
        let mut out = vec![0f64; n];
        let mut i = 0;
        while i + 1 < n {
            let (a, b) = crate::prng::gauss::box_muller_pair(&mut g);
            out[i] = a;
            out[i + 1] = b;
            i += 2;
        }
        if i < n {
            out[i] = crate::prng::gauss::box_muller_pair(&mut g).0;
        }
        out
    }

    const INT4: Codec = Codec::Int { bits: 4 };

    fn square(w: &[f64], rows: usize, cols: usize, block: usize, codec: &Codec) -> Quantized {
        fake_quantize(w, rows, cols, Geometry::Square { block }, codec, Rounding::NearestEven, 0)
    }

    #[test]
    fn square_block_is_transpose_commutative() {
        // quantize(W)^T == quantize(W^T) for square blocks — §2.1 claim.
        let (rows, cols) = (64, 96);
        let w = randn(3, rows * cols);
        let q = square(&w, rows, cols, 32, &INT4);
        let qt = transpose(&q.data, rows, cols);
        let wt = transpose(&w, rows, cols);
        let q_of_t = square(&wt, cols, rows, 32, &INT4);
        assert_eq!(qt, q_of_t.data);
    }

    #[test]
    fn vectorwise_is_not_transpose_commutative() {
        // The Fig. D.1 failure: vector-wise along rows != along cols.
        let (rows, cols) = (32, 32);
        let w = randn(4, rows * cols);
        let vector = |w: &[f64], r: usize, c: usize| {
            fake_quantize(
                w,
                r,
                c,
                Geometry::Vector { block: 2, axis: Axis::Row },
                &INT4,
                Rounding::NearestEven,
                0,
            )
        };
        let q = vector(&w, rows, cols);
        let qt = transpose(&q.data, rows, cols);
        let wt = transpose(&w, rows, cols);
        let q_of_t = vector(&wt, cols, rows);
        assert_ne!(qt, q_of_t.data, "vector-wise should NOT commute with transpose");
    }

    #[test]
    fn block_absmax_matches_naive() {
        let w: Vec<f32> = randn(6, 64 * 48).iter().map(|&x| x as f32).collect();
        let (rows, cols, block) = (64, 48, 16);
        let got = block_absmax_f32(&w, rows, cols, block);
        let grid_c = cols / block;
        for br in 0..rows / block {
            for bc in 0..grid_c {
                let mut m = 0f32;
                for r in br * block..(br + 1) * block {
                    for c in bc * block..(bc + 1) * block {
                        m = m.max(w[r * cols + c].abs());
                    }
                }
                assert_eq!(got[br * grid_c + bc], m);
            }
        }
    }

    #[test]
    fn ragged_edges_handled() {
        // rows/cols not multiples of the block size
        let w = randn(7, 37 * 45);
        let q = square(&w, 37, 45, 32, &INT4);
        assert_eq!(q.scales.len(), 2 * 2);
        let m = block_absmax_f32(&w.iter().map(|&x| x as f32).collect::<Vec<_>>(), 37, 45, 32);
        assert_eq!(m.len(), 4);
    }
}
