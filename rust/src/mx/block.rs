//! Microscaling (MX)-style blockwise quantization substrate.
//!
//! MX (Rouhani et al., 2023) groups tensor elements into blocks of 32 that
//! share one power-of-two scale; each element is stored in a narrow internal
//! datatype (INT4/INT8/FP8/...). The paper builds on two block geometries:
//!
//! * **vector-wise** — 1×32 blocks along one axis (standard MX). Quantizing
//!   along the matmul inner dimension makes the forward and backward passes
//!   see *different* quantized weights after transposition (Fig. D.1).
//! * **square-blockwise** — 32×32 blocks, a special case of vector-wise
//!   where adjacent vectors share the scale. Transpose-commutative, which is
//!   why GaussWS groups parameters this way (§3.2).
//!
//! **Deprecation note (kept for one PR):** the quantization engine moved to
//! [`crate::quant`] — schemes composed from codec × rounding × geometry,
//! resolved by label through `quant::Registry`. The free functions here
//! ([`quantize_square`], [`quantize_vectorwise`], [`po2_scale`]) and
//! [`ElemType`] are thin compatibility shims over it and will be removed;
//! new code should call `quant::resolve("<label>")` /
//! [`crate::quant::fake_quantize`] directly.

use crate::numerics::fpformat::{FpFormat, Rounding};
use crate::quant::{fake_quantize, Codec, Geometry};

pub use crate::quant::{Axis, Quantized};

/// Internal element datatype for quantization.
///
/// Shim over [`crate::quant::Codec`] (which adds the f32 passthrough arm);
/// prefer building a [`crate::quant::Scheme`] through the registry.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ElemType {
    /// Signed integer with `bits` total (symmetric, no zero-point).
    Int { bits: u32 },
    /// Low-precision float.
    Fp(FpFormat),
}

impl ElemType {
    /// The equivalent [`crate::quant::Codec`].
    pub fn to_codec(&self) -> Codec {
        match self {
            ElemType::Int { bits } => Codec::Int { bits: *bits },
            ElemType::Fp(f) => Codec::Fp(*f),
        }
    }

    /// Largest representable magnitude at scale 1.
    pub fn max_code(&self) -> f64 {
        self.to_codec().max_code()
    }

    /// Quantize a pre-scaled value (RNE) and clamp to range.
    pub fn quantize(&self, x: f64) -> f64 {
        self.to_codec().quantize(x, Rounding::NearestEven, 0)
    }
}

/// Compute the power-of-two shared scale for a block with max-abs `amax`
/// (MX convention; see [`crate::quant::po2_scale`]).
pub fn po2_scale(amax: f64, elem: &ElemType) -> f64 {
    crate::quant::po2_scale(amax, &elem.to_codec())
}

/// Vector-wise fake quantization with 1×`block` groups along `axis`
/// (round-to-nearest-even). Shim over [`crate::quant::fake_quantize`].
pub fn quantize_vectorwise(
    w: &[f64],
    rows: usize,
    cols: usize,
    block: usize,
    axis: Axis,
    elem: &ElemType,
) -> Quantized {
    fake_quantize(
        w,
        rows,
        cols,
        Geometry::Vector { block, axis },
        &elem.to_codec(),
        Rounding::NearestEven,
        0,
    )
}

/// Square-blockwise fake quantization with `block`×`block` groups — the
/// GaussWS geometry (round-to-nearest-even). Shim over
/// [`crate::quant::fake_quantize`].
pub fn quantize_square(
    w: &[f64],
    rows: usize,
    cols: usize,
    block: usize,
    elem: &ElemType,
) -> Quantized {
    fake_quantize(
        w,
        rows,
        cols,
        Geometry::Square { block },
        &elem.to_codec(),
        Rounding::NearestEven,
        0,
    )
}

/// Square-blockwise max-abs of an f32 matrix — the `max_bl(|w|)` of Eq. 3.
/// Returns the block grid row-major, `⌈rows/block⌉ × ⌈cols/block⌉`.
pub fn block_absmax_f32(w: &[f32], rows: usize, cols: usize, block: usize) -> Vec<f32> {
    assert_eq!(w.len(), rows * cols);
    let grid_r = rows.div_ceil(block);
    let grid_c = cols.div_ceil(block);
    let mut out = vec![0f32; grid_r * grid_c];
    for r in 0..rows {
        let br = r / block;
        let row = &w[r * cols..(r + 1) * cols];
        for (bc, chunk) in row.chunks(block).enumerate() {
            let mut m = out[br * grid_c + bc];
            for &v in chunk {
                let a = v.abs();
                if a > m {
                    m = a;
                }
            }
            out[br * grid_c + bc] = m;
        }
    }
    out
}

/// Transpose a row-major f64 matrix.
pub fn transpose(w: &[f64], rows: usize, cols: usize) -> Vec<f64> {
    let mut out = vec![0f64; w.len()];
    for r in 0..rows {
        for c in 0..cols {
            out[c * rows + r] = w[r * cols + c];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Philox4x32;
    use crate::quant::{QuantScheme, Scheme};

    fn randn(seed: u64, n: usize) -> Vec<f64> {
        let mut g = Philox4x32::new(seed);
        let mut out = vec![0f64; n];
        let mut i = 0;
        while i + 1 < n {
            let (a, b) = crate::prng::gauss::box_muller_pair(&mut g);
            out[i] = a;
            out[i + 1] = b;
            i += 2;
        }
        if i < n {
            out[i] = crate::prng::gauss::box_muller_pair(&mut g).0;
        }
        out
    }

    const INT4: ElemType = ElemType::Int { bits: 4 };

    #[test]
    fn quantization_is_idempotent() {
        let w = randn(1, 16 * 16);
        let q = quantize_square(&w, 16, 16, 4, &INT4);
        let q2 = quantize_square(&q.data, 16, 16, 4, &INT4);
        assert_eq!(q.data, q2.data);
    }

    #[test]
    fn error_bounded_by_half_scale() {
        let w = randn(2, 32 * 32);
        let q = quantize_square(&w, 32, 32, 32, &INT4);
        let s = q.scales[0];
        for (a, b) in w.iter().zip(q.data.iter()) {
            assert!((a - b).abs() <= 0.5 * s + 1e-12, "{a} vs {b} (s={s})");
        }
    }

    #[test]
    fn square_block_is_transpose_commutative() {
        // quantize(W)^T == quantize(W^T) for square blocks — §2.1 claim.
        let (rows, cols) = (64, 96);
        let w = randn(3, rows * cols);
        let q = quantize_square(&w, rows, cols, 32, &INT4);
        let qt = transpose(&q.data, rows, cols);
        let wt = transpose(&w, rows, cols);
        let q_of_t = quantize_square(&wt, cols, rows, 32, &INT4);
        assert_eq!(qt, q_of_t.data);
    }

    #[test]
    fn vectorwise_is_not_transpose_commutative() {
        // The Fig. D.1 failure: vector-wise along rows != along cols.
        let (rows, cols) = (32, 32);
        let w = randn(4, rows * cols);
        let q = quantize_vectorwise(&w, rows, cols, 2, Axis::Row, &INT4);
        let qt = transpose(&q.data, rows, cols);
        let wt = transpose(&w, rows, cols);
        let q_of_t = quantize_vectorwise(&wt, cols, rows, 2, Axis::Row, &INT4);
        assert_ne!(qt, q_of_t.data, "vector-wise should NOT commute with transpose");
    }

    #[test]
    fn po2_scales_are_powers_of_two() {
        let w = randn(5, 64 * 64);
        let q = quantize_square(&w, 64, 64, 32, &INT4);
        for &s in &q.scales {
            assert_eq!(s.log2().fract(), 0.0, "scale {s} not a power of two");
        }
    }

    #[test]
    fn shim_matches_scheme_quantize_bit_for_bit() {
        // the deprecated shims must stay bit-identical to the quant engine
        use crate::numerics::fpformat::formats::FP8_E3M4;
        let w = randn(9, 48 * 40);
        let shim = quantize_square(&w, 48, 40, 32, &ElemType::Fp(FP8_E3M4));
        let scheme = crate::quant::resolve("fp8_e3m4").unwrap();
        let direct = scheme.quantize(&w, 48, 40, 0);
        assert_eq!(shim.data, direct.data);
        assert_eq!(shim.scales, direct.scales);
        // elementwise scheme helpers agree with the ElemType shim
        let s = Scheme::new(
            "int4",
            INT4.to_codec(),
            crate::numerics::Rounding::NearestEven,
            crate::quant::Geometry::None,
        );
        for &x in w.iter().take(32) {
            assert_eq!(INT4.quantize(x), s.codec.quantize(x, s.rounding, 0));
        }
    }

    #[test]
    fn block_absmax_matches_naive() {
        let w: Vec<f32> = randn(6, 64 * 48).iter().map(|&x| x as f32).collect();
        let (rows, cols, block) = (64, 48, 16);
        let got = block_absmax_f32(&w, rows, cols, block);
        let grid_c = cols / block;
        for br in 0..rows / block {
            for bc in 0..grid_c {
                let mut m = 0f32;
                for r in br * block..(br + 1) * block {
                    for c in bc * block..(bc + 1) * block {
                        m = m.max(w[r * cols + c].abs());
                    }
                }
                assert_eq!(got[br * grid_c + bc], m);
            }
        }
    }

    #[test]
    fn ragged_edges_handled() {
        // rows/cols not multiples of the block size
        let w = randn(7, 37 * 45);
        let q = quantize_square(&w, 37, 45, 32, &INT4);
        assert_eq!(q.scales.len(), 2 * 2);
        let v = quantize_vectorwise(&w, 37, 45, 32, Axis::Row, &INT4);
        assert_eq!(v.data.len(), w.len());
        let m = block_absmax_f32(&w.iter().map(|&x| x as f32).collect::<Vec<_>>(), 37, 45, 32);
        assert_eq!(m.len(), 4);
    }

    #[test]
    fn fp_elem_type_quantizes_with_format() {
        use crate::numerics::fpformat::formats::FP8_E4M3;
        let e = ElemType::Fp(FP8_E4M3);
        let w = randn(8, 32 * 32);
        let q = quantize_square(&w, 32, 32, 32, &e);
        // every dequantized value representable in e4m3 at its scale
        for (i, &v) in q.data.iter().enumerate() {
            let s = q.scales[0];
            assert!(FP8_E4M3.is_representable(v / s), "elem {i}: {v}");
        }
    }
}
