//! Microscaling (MX) quantization substrate: blockwise scaling geometries
//! and the forward/backward consistency analysis of §2.1 / Fig. D.1.
//!
//! The quantization engine lives in [`crate::quant`] (schemes resolved by
//! label through `quant::Registry`); this module keeps the f32 geometry
//! helpers ([`block_absmax_f32`], [`transpose`]) and the consistency
//! measurements. The PR-2 deprecation shims (the square/vector-wise
//! quantizer free functions, the element-type enum, the po2 scale helper)
//! have been deleted — call `quant::resolve`/`quant::fake_quantize`.

pub mod block;
pub mod consistency;

pub use block::{block_absmax_f32, transpose, Axis, Quantized};
pub use consistency::{fig_d1_example, measure_square, measure_vectorwise, ConsistencyReport};
