//! Microscaling (MX) quantization substrate: blockwise scaling geometries
//! and the forward/backward consistency analysis of §2.1 / Fig. D.1.

pub mod block;
pub mod consistency;

pub use block::{
    block_absmax_f32, quantize_square, quantize_vectorwise, transpose, Axis, ElemType, Quantized,
};
pub use consistency::{fig_d1_example, measure_square, measure_vectorwise, ConsistencyReport};
