//! Microscaling (MX) quantization substrate: blockwise scaling geometries
//! and the forward/backward consistency analysis of §2.1 / Fig. D.1.
//!
//! The quantization engine itself lives in [`crate::quant`] now;
//! `quantize_square` / `quantize_vectorwise` / `ElemType` here are thin
//! deprecated shims kept for one PR (see `block` module docs).

pub mod block;
pub mod consistency;

pub use block::{
    block_absmax_f32, quantize_square, quantize_vectorwise, transpose, Axis, ElemType, Quantized,
};
pub use consistency::{fig_d1_example, measure_square, measure_vectorwise, ConsistencyReport};
