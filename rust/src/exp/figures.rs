//! Figure experiments: multi-arm training runs regenerating the loss-curve
//! and bitwidth figures (1b, 3a, 3b, 4, 5, F.1) at testbed scale, plus the
//! Fig. D.1 consistency demo.

use crate::config::schema::{Optimizer, TrainConfig};
use crate::coordinator::Trainer;
use crate::pqt::bitwidth::bt_stats;
use crate::runtime::Runtime;
use anyhow::Result;

/// One arm of a multi-run figure.
#[derive(Debug, Clone)]
pub struct Arm {
    /// Paper-style label, e.g. "gaussws[od] lr=6e-4".
    pub label: String,
    /// Artifact tag without `.train`, e.g. "tiny_gpt2.gaussws_od".
    pub artifact: String,
    pub max_lr: f64,
    pub min_lr: f64,
    pub optimizer: Optimizer,
    /// b_i weight decay. The paper uses 0.1 over 600k steps; short testbed
    /// runs scale it up so the same fraction of annealing is observable
    /// (decay^steps invariant — see EXPERIMENTS.md).
    pub bi_weight_decay: f64,
}

impl Arm {
    pub fn new(label: &str, artifact: &str, max_lr: f64) -> Arm {
        Arm {
            label: label.to_string(),
            artifact: artifact.to_string(),
            max_lr,
            min_lr: max_lr / 10.0,
            optimizer: Optimizer::AdamW,
            bi_weight_decay: 5.0,
        }
    }

    pub fn with_opt(mut self, opt: Optimizer) -> Arm {
        self.optimizer = opt;
        self
    }
}

/// Run one arm for `steps` steps; returns the trainer (holding log + b_i).
pub fn run_arm(
    artifacts_dir: &str,
    arm: &Arm,
    steps: usize,
    workers: usize,
    seed: u64,
) -> Result<Trainer> {
    let runtime = Runtime::new(artifacts_dir)?;
    let cfg = TrainConfig {
        steps,
        warmup_steps: (steps / 10).max(1),
        max_lr: arm.max_lr,
        min_lr: arm.min_lr,
        optimizer: arm.optimizer,
        workers,
        seed,
        ..Default::default()
    };
    let mut t = Trainer::new(runtime, &arm.artifact, cfg, &arm.label)?;
    t.bi_weight_decay = arm.bi_weight_decay;
    t.run(steps, 0)?;
    Ok(t)
}

/// Run a set of arms and write per-arm CSVs plus a combined summary.
pub fn run_figure(
    fig: &str,
    arms: &[Arm],
    artifacts_dir: &str,
    out_dir: &str,
    steps: usize,
    workers: usize,
    seed: u64,
) -> Result<Vec<Trainer>> {
    let mut out = Vec::new();
    println!("== {fig}: {} arms × {steps} steps ==", arms.len());
    for arm in arms {
        let t0 = std::time::Instant::now();
        let t = run_arm(artifacts_dir, arm, steps, workers, seed)?;
        let fl = t.log.final_loss().unwrap_or(f64::NAN);
        println!(
            "  {:<38} final wma-loss {:.4}  div@{:?}  {:.0} tok/s  ({:.1}s)",
            arm.label,
            fl,
            t.log.divergences.first(),
            t.log.tokens_per_sec(),
            t0.elapsed().as_secs_f64()
        );
        let dir = format!("{out_dir}/{fig}");
        let mut log = t.log.clone();
        log.name = arm.label.replace(['[', ']', '=', ' '], "_");
        log.write_to(&dir)?;
        out.push(t);
    }
    Ok(out)
}

/// Figure 1b arms: BF16 at two LRs vs GaussWS[all] vs DiffQ[all] at both.
pub fn fig1b_arms(lr_hi: f64, lr_lo: f64) -> Vec<Arm> {
    vec![
        Arm::new(&format!("bf16 lr={lr_hi:.0e}"), "tiny_gpt2.bf16", lr_hi),
        Arm::new(&format!("bf16 lr={lr_lo:.0e}"), "tiny_gpt2.bf16", lr_lo),
        Arm::new(&format!("gaussws[all] lr={lr_hi:.0e}"), "tiny_gpt2.gaussws_all", lr_hi),
        Arm::new(&format!("gaussws[all] lr={lr_lo:.0e}"), "tiny_gpt2.gaussws_all", lr_lo),
        Arm::new(&format!("diffq[all] lr={lr_hi:.0e}"), "tiny_gpt2.diffq_all", lr_hi),
        Arm::new(&format!("diffq[all] lr={lr_lo:.0e}"), "tiny_gpt2.diffq_all", lr_lo),
    ]
}

/// Figure 3a arms: GaussWS restricted to each linear of the GPT2 block.
pub fn fig3a_arms(lr: f64) -> Vec<Arm> {
    ["qkv", "out", "up", "down", "od", "all"]
        .iter()
        .map(|p| Arm::new(&format!("gaussws[{p}]"), &format!("tiny_gpt2.gaussws_{p}"), lr))
        .collect()
}

/// Figure 3b arms: Adam-mini vs AdamW on baseline / GaussWS / DiffQ.
pub fn fig3b_arms(lr: f64) -> Vec<Arm> {
    let mut arms = Vec::new();
    for (m, tag) in
        [("bf16", "tiny_gpt2.bf16"), ("gaussws[all]", "tiny_gpt2.gaussws_all"), ("diffq[all]", "tiny_gpt2.diffq_all")]
    {
        arms.push(Arm::new(&format!("{m} adamw"), tag, lr));
        arms.push(Arm::new(&format!("{m} adam-mini"), tag, lr).with_opt(Optimizer::AdamMini));
    }
    arms
}

/// Figure 4 arms: Llama2-style, baseline vs GaussWS vs DiffQ × optimizer.
pub fn fig4_arms(lr: f64) -> Vec<Arm> {
    let mut arms = Vec::new();
    for (m, tag) in [
        ("bf16", "tiny_llama2.bf16"),
        ("gaussws[all]", "tiny_llama2.gaussws_all"),
        ("diffq[all]", "tiny_llama2.diffq_all"),
    ] {
        arms.push(Arm::new(&format!("{m} adamw"), tag, lr));
        arms.push(Arm::new(&format!("{m} adam-mini"), tag, lr).with_opt(Optimizer::AdamMini));
    }
    arms
}

/// Figure F.1 arms: GaussWS with (b_init=8, b_target=6) vs default (6, 4).
pub fn figf1_arms(lr: f64) -> Vec<Arm> {
    vec![
        Arm::new("bf16", "tiny_llama2.bf16", lr),
        Arm::new("gaussws b6->4", "tiny_llama2.gaussws_all", lr),
        Arm::new("gaussws b8->6", "tiny_llama2.gaussws_b8t6", lr),
    ]
}

/// Stability probe (the paper's §4.1 claim that PQT mitigates BF16
/// training instability): sweep aggressive learning rates and record which
/// arms diverge. At paper scale the BF16 baseline destabilizes at 30B–200B
/// tokens; at testbed scale we provoke it with LR instead.
pub fn stability_arms(lrs: &[f64]) -> Vec<Arm> {
    let mut arms = Vec::new();
    for &lr in lrs {
        for (m, tag) in [
            ("bf16", "tiny_gpt2.bf16"),
            ("gaussws[all]", "tiny_gpt2.gaussws_all"),
            ("diffq[all]", "tiny_gpt2.diffq_all"),
        ] {
            let mut a = Arm::new(&format!("{m} lr={lr:.0e}"), tag, lr);
            a.min_lr = lr; // constant aggressive LR: no decay rescue
            arms.push(a);
        }
    }
    arms
}

/// Figure 5: per-layer b_t statistics from a trained PQT model.
/// Returns (layer_name, mean, std, min, max, tier fractions ≤5/≤9/≤12).
pub fn fig5_report(t: &Trainer) -> Vec<(String, f64, f64, f32, f32, [f64; 3])> {
    t.bi_layer_names()
        .iter()
        .map(|name| {
            let bt = t.bt_of(name).unwrap();
            let s = bt_stats(&bt);
            (name.clone(), s.mean, s.std, s.min, s.max, s.tier_frac)
        })
        .collect()
}

/// Render the Fig. 5 report as text.
pub fn render_fig5(rows: &[(String, f64, f64, f32, f32, [f64; 3])]) -> String {
    let mut out = String::from(
        "Fig 5 — resulting bitwidth b_t per layer\nlayer                    mean   std    min    max   ≤5      ≤9      ≤12\n",
    );
    let mut all_tiers = [0f64; 3];
    for (name, mean, std, min, max, tiers) in rows {
        out.push_str(&format!(
            "{name:<24} {mean:>5.2} {std:>6.3} {min:>6.2} {max:>6.2}  {:>5.1}%  {:>5.1}%  {:>5.1}%\n",
            tiers[0] * 100.0,
            tiers[1] * 100.0,
            tiers[2] * 100.0
        ));
        for k in 0..3 {
            all_tiers[k] += tiers[k];
        }
    }
    if !rows.is_empty() {
        let n = rows.len() as f64;
        out.push_str(&format!(
            "{:<24} {:>27}  {:>5.1}%  {:>5.1}%  {:>5.1}%\n",
            "ALL",
            "",
            all_tiers[0] / n * 100.0,
            all_tiers[1] / n * 100.0,
            all_tiers[2] / n * 100.0
        ));
    }
    out
}

/// Fig. D.1 demo: render the 4×4 inconsistency example.
pub fn render_figd1(seed: u64) -> String {
    let (w, bwd, fwd) = crate::mx::fig_d1_example(seed);
    let fmt = |m: &[f64]| -> String {
        let mut s = String::new();
        for r in 0..4 {
            s.push_str("    ");
            for c in 0..4 {
                s.push_str(&format!("{:>7.3}", m[r * 4 + c]));
            }
            s.push('\n');
        }
        s
    };
    let mut out = String::new();
    out.push_str("Fig D.1 — vector-wise quantization fwd/bwd discrepancy (INT4, block 2)\n");
    out.push_str("  original W ~ N(0,1):\n");
    out.push_str(&fmt(&w));
    out.push_str("  backward view (quantized along K of Wᵀ):\n");
    out.push_str(&fmt(&bwd));
    out.push_str("  forward view (quantized along K of W):\n");
    out.push_str(&fmt(&fwd));
    let mismatches = bwd.iter().zip(fwd.iter()).filter(|(a, b)| a != b).count();
    out.push_str(&format!("  -> {mismatches}/16 elements differ between passes\n"));
    out.push_str("  (square 32x32 blocks make the two views identical — see mx::consistency tests)\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arm_construction() {
        let arms = fig1b_arms(6e-4, 6e-5);
        assert_eq!(arms.len(), 6);
        assert!(arms[2].artifact.contains("gaussws_all"));
        let f3 = fig3a_arms(6e-4);
        assert_eq!(f3.len(), 6);
        assert!(fig3b_arms(1e-3).iter().any(|a| a.optimizer == Optimizer::AdamMini));
        assert_eq!(figf1_arms(1e-3).len(), 3);
    }

    #[test]
    fn figd1_renders_discrepancy() {
        let s = render_figd1(2026);
        assert!(s.contains("differ between passes"));
        // at least one element differs for this seed (checked in mx tests)
        assert!(!s.contains("-> 0/16"));
    }

    #[test]
    fn fig5_render_empty_safe() {
        assert!(render_fig5(&[]).contains("Fig 5"));
    }
}
