//! Paper tables regenerated from the library: Table C.1 (closed-form FP
//! bounds) and Table B.1 (method comparison summary).

use crate::numerics::analysis::{table_c1, TableC1Row};

/// Render Table C.1 exactly in the paper's column layout.
pub fn render_table_c1() -> String {
    let rows = table_c1();
    let mut out = String::new();
    out.push_str("Table C.1 — FP datatypes vs b_t (rounded-normal R, tau = 0)\n");
    out.push_str("b_t | exp(w) | e (exp ŵ) | m (mantissa ŵ) | datatype ŵ\n");
    out.push_str("----+--------+-----------+----------------+--------------------\n");
    for TableC1Row { bt, exp_w, exp_what, man_what, datatypes } in rows {
        out.push_str(&format!(
            "{bt:>3} | {exp_w:>6} | {exp_what:>9} | {man_what:>14} | {}\n",
            datatypes.join(", ")
        ));
    }
    out
}

/// One row of the Table B.1 qualitative comparison, with the quantitative
/// backing we measured in this reproduction.
#[derive(Debug, Clone)]
pub struct MethodRow {
    pub name: &'static str,
    pub throughput: &'static str,
    pub stability: &'static str,
    pub accuracy: &'static str,
    pub flexibility: &'static str,
}

/// Render Table B.1 (qualitative; the quantitative evidence lives in the
/// fig1b/fig4/table1 outputs).
pub fn render_table_b1() -> String {
    let rows = [
        MethodRow {
            name: "BF16",
            throughput: "Good",
            stability: "Good",
            accuracy: "Best",
            flexibility: "No",
        },
        MethodRow {
            name: "FQT",
            throughput: "Best",
            stability: "No guarantee",
            accuracy: "No guarantee",
            flexibility: "No",
        },
        MethodRow {
            name: "DiffQ",
            throughput: "Worse",
            stability: "Best",
            accuracy: "Good",
            flexibility: "Good",
        },
        MethodRow {
            name: "NIPQ",
            throughput: "Worst",
            stability: "-",
            accuracy: "-",
            flexibility: "Good",
        },
        MethodRow {
            name: "GaussWS",
            throughput: "Good",
            stability: "Best",
            accuracy: "Best",
            flexibility: "Best",
        },
    ];
    let mut out = String::new();
    out.push_str("Table B.1 — comparison of related methods\n");
    out.push_str(&format!(
        "{:<8} {:<12} {:<13} {:<13} {:<11}\n",
        "", "Throughput", "Stability", "Accuracy", "Flexibility"
    ));
    for r in rows {
        out.push_str(&format!(
            "{:<8} {:<12} {:<13} {:<13} {:<11}\n",
            r.name, r.throughput, r.stability, r.accuracy, r.flexibility
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn c1_contains_paper_anchor_rows() {
        let t = render_table_c1();
        assert!(t.contains("FP6_e3m2"));
        assert!(t.contains("FP8_e4m3, FP8_e3m4"));
        assert!(t.contains("FP32"));
        assert_eq!(t.lines().count(), 3 + 11);
    }

    #[test]
    fn b1_has_all_methods() {
        let t = render_table_b1();
        for m in ["BF16", "FQT", "DiffQ", "NIPQ", "GaussWS"] {
            assert!(t.contains(m), "{m}");
        }
    }
}
