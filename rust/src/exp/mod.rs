//! Experiment harness: one function per paper table/figure, shared by the
//! CLI (`gaussws exp <id>`) and the bench binaries. Each returns structured
//! results and writes CSV/JSON into the run directory.

pub mod figures;
pub mod tables;

pub use figures::*;
pub use tables::*;
