//! # GaussWS — Gaussian Weight Sampling for Pseudo-Quantization Training
//!
//! Reproduction of *"Gaussian Weight Sampling for Scalable, Efficient and
//! Stable Pseudo-Quantization Training"* (Ahn & Yoo, 2025) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **L1 (build-time)** — Pallas kernels for the Eq. 3 sampling op and the
//!   Eq. 10 bitwise rounded-normal generator (`python/compile/kernels/`).
//! * **L2 (build-time)** — GPT2/Llama2-style transformer fwd/bwd in JAX with
//!   PQT linears (custom VJP, Eq. 4), lowered once to HLO text artifacts.
//! * **L3 (this crate)** — the training framework: PJRT runtime that loads
//!   the artifacts, rust-side optimizers + bitwidth management + seed tree,
//!   data pipeline, metrics, checkpoints, and the benchmark/experiment
//!   harness reproducing every table and figure of the paper.
//!
//! Python never runs on the training path; after `make artifacts` the rust
//! binary is self-contained.

pub mod config;
pub mod exp;
pub mod coordinator;
pub mod data;
pub mod mx;
pub mod nn;
pub mod numerics;
pub mod pqt;
pub mod prng;
pub mod runtime;
pub mod testing;
pub mod util;
