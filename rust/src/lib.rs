//! # GaussWS — Gaussian Weight Sampling for Pseudo-Quantization Training
//!
//! Reproduction of *"Gaussian Weight Sampling for Scalable, Efficient and
//! Stable Pseudo-Quantization Training"* (Ahn & Yoo, 2025) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **L1 (build-time)** — Pallas kernels for the Eq. 3 sampling op and the
//!   Eq. 10 bitwise rounded-normal generator (`python/compile/kernels/`).
//! * **L2 (build-time)** — GPT2/Llama2-style transformer fwd/bwd in JAX with
//!   PQT linears (custom VJP, Eq. 4), lowered once to HLO text artifacts.
//! * **L3 (this crate)** — the training framework: PJRT runtime that loads
//!   the artifacts, rust-side optimizers + bitwidth management + seed tree,
//!   data pipeline, metrics, checkpoints, and the benchmark/experiment
//!   harness reproducing every table and figure of the paper.
//! * **L4 (this crate, [`serve`])** — the deployment side of the paper's
//!   claim: checkpoints are snapshotted into a low-precision MX weight
//!   store (BF16/FP8/FP6/FP4/INT square-blockwise, packed at true
//!   sub-byte code width — the GWQS3 format — and dequantized on load
//!   through per-codec lookup tables) and served through a
//!   continuous-batching engine
//!   with **paged KV-cache memory**: fixed-size position blocks in a
//!   global refcounted arena ([`nn::kv::PagedKv`] +
//!   `serve::BlockAllocator`), chunked prefill, cross-request prefix
//!   caching with copy-on-write, preemption under memory pressure, a
//!   multi-threaded decode worker pool with **weight-stationary wave
//!   batching** (steady-state decodes stack into one
//!   [`nn::transformer::Transformer::decode_wave`] GEMM per layer, so
//!   each weight matrix is streamed once per wave instead of once per
//!   sequence — bit-identical outputs either way), and p50/p95 latency +
//!   tokens/sec + block-occupancy accounting. The KV arena itself can be
//!   **quantized block-by-block** through any blockwise quant scheme
//!   ([`nn::kv::KvQuant`], `serve --kv-store fp8_e3m4|fp4_e2m1_sr|…`):
//!   sub-byte [`quant::PackedCodes`] + per-group po2 scales are the
//!   *only* resident state — attention dots q·k and accumulates p·v
//!   directly against the codes via fused LUT-dequant kernels, with an
//!   opt-in f32 debug mirror (`--kv-mirror`) asserted bit-identical to
//!   the fused path — and `--kv-store f32` preserves the
//!   bit-identical passthrough path. `gaussws serve` and
//!   `examples/serve_load.rs` drive it end to end; the storage seam is
//!   the [`nn::kv::KvStorage`] trait (contiguous `DecodeCache` for
//!   standalone decode, paged for serving — bit-identical logits).
//! * **[`net`](serve::net) + [`load`]** — the serving edge and its load
//!   harness: a std-only TCP front end (`serve --listen`) speaking
//!   length-prefixed newline-JSON frames with strict request parsing,
//!   free-block admission control / shed-with-retry backpressure,
//!   per-request deadlines and graceful drain; and a declarative workload
//!   framework (`load <scenario>`) — seeded distribution-based specs
//!   (TOML or builder), a deterministic generator, and a runner driving
//!   direct / in-process / loopback-TCP transports over a named scenario
//!   corpus, each arm recorded in `BENCH_serve.json`.
//! * **[`testing`]** — the in-crate test substrate: `testing::prop` is the
//!   mini property-testing framework (deterministic per-seed `Gen` +
//!   `check` runner), and `testing::fuzz` is the serving
//!   fuzz/conformance harness — `FuzzCase::generate(seed)` derives a
//!   random request mix + engine config, `check_case(seed)` asserts the
//!   serving invariants (leak-free drain, determinism, prefix-cache
//!   transparency, paged-f32 == contiguous, bounded quantized-KV logit
//!   drift, fused-decode == mirror bit-identity, spec on/off and
//!   wave-batch on/off bit-identity), and
//!   `tests/fuzz_serve.rs` runs the fixed 8-seed matrix (widened to 20 in
//!   CI to cover every KV stratum) in a dedicated release-mode CI job.
//! * **[`quant`]** — the unified quantization seam underneath L3 and L4:
//!   one `QuantScheme` trait (codec × rounding × scale geometry) plus a
//!   label registry (`"bf16"`, `"fp8_e3m4"`, `"int8_sr"`, …) shared by
//!   train-time fake-quant, checkpoint snapshots, and the packed serving
//!   store, so every format/rounding scenario is a single registry entry;
//!   [`quant::PackedCodes`] + [`quant::DequantLut`] underneath it store
//!   codes at their true bit width and decode by table lookup.
//! * **[`telemetry`]** — the shared observability substrate: a lock-light
//!   [`telemetry::Registry`] of sharded counters, gauges and log-bucketed
//!   histograms with JSON/Prometheus exposition, plus per-request Chrome
//!   trace-event timelines (`serve --trace-out`). `serve::ServeStats` and
//!   `coordinator::metrics::RunLog` are views over it, so serving latency
//!   percentiles, KV logit-drift histograms, and per-layer PQT noise
//!   amplitude / effective bitwidth gauges all share one exposition path.
//!
//! Python never runs on the training path; after `make artifacts` the rust
//! binary is self-contained. The PJRT execution path itself sits behind the
//! `pjrt` cargo feature (the `xla` crate is not in the offline vendor);
//! everything else — including the entire serve layer — is pure rust.

pub mod config;
pub mod exp;
pub mod coordinator;
pub mod data;
pub mod load;
pub mod mx;
pub mod nn;
pub mod numerics;
pub mod pqt;
pub mod prng;
pub mod quant;
pub mod runtime;
pub mod serve;
pub mod telemetry;
pub mod testing;
pub mod util;
