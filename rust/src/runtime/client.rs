//! PJRT runtime: load HLO-text artifacts, compile once, execute from the
//! rust hot path. Wraps the `xla` crate exactly as in
//! /opt/xla-example/load_hlo (PjRtClient::cpu → HloModuleProto::from_text_file
//! → compile → execute), plus signature checking against the manifest and a
//! host-buffer value type.
//!
//! The `xla` crate is only available behind the `pjrt` cargo feature (it is
//! not in the offline vendor). Without the feature, [`Runtime::new`] returns
//! a descriptive error so the artifact-driven tests and subcommands skip or
//! fail fast; everything that does not execute HLO — the manifest,
//! [`HostTensor`], the serve/ engine, the pure-rust transformer — is
//! feature-independent.

#[cfg(feature = "pjrt")]
use super::artifact::ArtifactSpec;
#[cfg(feature = "pjrt")]
use super::artifact::{Dtype, TensorSpec};
use super::artifact::Manifest;
#[cfg(feature = "pjrt")]
use anyhow::Context;
use anyhow::{bail, Result};
#[cfg(feature = "pjrt")]
use std::collections::HashMap;

/// A host tensor crossing the PJRT boundary.
#[derive(Debug, Clone, PartialEq)]
pub enum HostTensor {
    F32(Vec<f32>),
    S32(Vec<i32>),
    U32(Vec<u32>),
}

impl HostTensor {
    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32(v) => v.len(),
            HostTensor::S32(v) => v.len(),
            HostTensor::U32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32(v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self {
            HostTensor::F32(v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    /// Scalar f32/f64-ish value (loss outputs).
    pub fn scalar_f32(&self) -> Result<f32> {
        let v = self.as_f32()?;
        if v.len() != 1 {
            bail!("expected scalar, got {} elements", v.len());
        }
        Ok(v[0])
    }

    #[cfg(feature = "pjrt")]
    fn to_literal(&self, spec: &TensorSpec) -> Result<xla::Literal> {
        if self.len() != spec.numel() {
            bail!(
                "input '{}': expected {} elements ({:?}), got {}",
                spec.name,
                spec.numel(),
                spec.shape,
                self.len()
            );
        }
        let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
        let lit = match (self, spec.dtype) {
            (HostTensor::F32(v), Dtype::F32) => xla::Literal::vec1(v),
            (HostTensor::S32(v), Dtype::S32) => xla::Literal::vec1(v),
            (HostTensor::U32(v), Dtype::U32) => xla::Literal::vec1(v),
            (t, d) => bail!("input '{}': dtype mismatch {t:?} vs {d:?}", spec.name),
        };
        // scalars: vec1 of len 1 reshaped to rank-0
        Ok(lit.reshape(&dims)?)
    }

    #[cfg(feature = "pjrt")]
    fn from_literal(lit: &xla::Literal, spec: &TensorSpec) -> Result<HostTensor> {
        let out = match spec.dtype {
            Dtype::F32 => HostTensor::F32(lit.to_vec::<f32>()?),
            Dtype::Bf16 => {
                // widen bf16 outputs to f32 on the host
                let wide = lit.convert(xla::PrimitiveType::F32)?;
                HostTensor::F32(wide.to_vec::<f32>()?)
            }
            Dtype::S32 => HostTensor::S32(lit.to_vec::<i32>()?),
            Dtype::U32 => HostTensor::U32(lit.to_vec::<u32>()?),
        };
        if out.len() != spec.numel() {
            bail!("output '{}': expected {} elements, got {}", spec.name, spec.numel(), out.len());
        }
        Ok(out)
    }
}

/// The runtime: one PJRT CPU client + an executable cache keyed by artifact
/// name. Compilation happens once per artifact per process.
#[cfg(feature = "pjrt")]
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

#[cfg(feature = "pjrt")]
impl Runtime {
    /// Create a CPU runtime over an artifact directory.
    pub fn new(artifacts_dir: &str) -> Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, manifest, cache: HashMap::new() })
    }

    /// Platform string (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) an artifact's executable.
    pub fn load(&mut self, name: &str) -> Result<()> {
        if self.cache.contains_key(name) {
            return Ok(());
        }
        let spec = self.manifest.get(name)?.clone();
        let path = spec
            .file
            .to_str()
            .context("artifact path not utf8")?
            .to_string();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {path}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact '{name}'"))?;
        self.cache.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute an artifact with host inputs in manifest order; returns host
    /// outputs in manifest order. Signature-checked both ways.
    pub fn execute(&mut self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        self.load(name)?;
        let spec: ArtifactSpec = self.manifest.get(name)?.clone();
        if inputs.len() != spec.inputs.len() {
            bail!(
                "artifact '{name}': expected {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            );
        }
        let literals = inputs
            .iter()
            .zip(spec.inputs.iter())
            .map(|(t, s)| t.to_literal(s))
            .collect::<Result<Vec<_>>>()?;
        let exe = self.cache.get(name).unwrap();
        let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: root is a tuple of leaves.
        let mut parts = result;
        let leaves = parts.decompose_tuple()?;
        if leaves.len() != spec.outputs.len() {
            bail!(
                "artifact '{name}': expected {} outputs, got {}",
                spec.outputs.len(),
                leaves.len()
            );
        }
        leaves
            .iter()
            .zip(spec.outputs.iter())
            .map(|(l, s)| HostTensor::from_literal(l, s))
            .collect()
    }
}

/// Feature-off stub: carries the same API so callers (trainer, benches,
/// integration tests) compile unchanged; construction fails with a clear
/// message, which the artifact-driven tests already treat as "skip".
#[cfg(not(feature = "pjrt"))]
pub struct Runtime {
    pub manifest: Manifest,
}

#[cfg(not(feature = "pjrt"))]
impl Runtime {
    /// Always errors: HLO execution needs the `pjrt` feature (and the
    /// vendored `xla` crate). The manifest is still validated first so the
    /// "run `make artifacts`" hint stays the outermost error when relevant.
    pub fn new(artifacts_dir: &str) -> Result<Runtime> {
        let _manifest = Manifest::load(artifacts_dir)?;
        bail!(
            "PJRT runtime unavailable: gaussws was built without the `pjrt` \
             feature (the `xla` crate is not in the offline vendor). \
             Training/HLO paths are disabled; `serve`, `tables`, `demo` and \
             the pure-rust inference paths work without it."
        )
    }

    pub fn platform(&self) -> String {
        "none (pjrt feature disabled)".to_string()
    }

    pub fn load(&mut self, _name: &str) -> Result<()> {
        bail!("PJRT runtime unavailable (built without the `pjrt` feature)")
    }

    pub fn execute(&mut self, _name: &str, _inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        bail!("PJRT runtime unavailable (built without the `pjrt` feature)")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_accessor() {
        assert_eq!(HostTensor::F32(vec![2.5]).scalar_f32().unwrap(), 2.5);
        assert!(HostTensor::F32(vec![1.0, 2.0]).scalar_f32().is_err());
    }

    #[test]
    fn dtype_accessors() {
        let t = HostTensor::S32(vec![1, 2]);
        assert_eq!(t.len(), 2);
        assert!(!t.is_empty());
        assert!(t.as_f32().is_err());
        assert!(HostTensor::F32(vec![0.5]).into_f32().is_ok());
    }
}

// Literal round-trip tests need a real xla runtime; they ride the feature.
#[cfg(all(test, feature = "pjrt"))]
mod literal_tests {
    use super::super::artifact::{Dtype, TensorSpec};
    use super::*;

    fn spec(shape: &[usize], dtype: Dtype) -> TensorSpec {
        TensorSpec { name: "t".into(), shape: shape.to_vec(), dtype }
    }

    #[test]
    fn literal_roundtrip_f32() {
        let t = HostTensor::F32(vec![1.0, 2.0, 3.0, 4.0]);
        let s = spec(&[2, 2], Dtype::F32);
        let lit = t.to_literal(&s).unwrap();
        let back = HostTensor::from_literal(&lit, &s).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn literal_scalar() {
        let t = HostTensor::S32(vec![7]);
        let s = spec(&[], Dtype::S32);
        let lit = t.to_literal(&s).unwrap();
        assert_eq!(HostTensor::from_literal(&lit, &s).unwrap(), t);
    }

    #[test]
    fn shape_mismatch_rejected() {
        let t = HostTensor::F32(vec![1.0; 3]);
        assert!(t.to_literal(&spec(&[2, 2], Dtype::F32)).is_err());
    }

    #[test]
    fn dtype_mismatch_rejected() {
        let t = HostTensor::F32(vec![1.0; 4]);
        assert!(t.to_literal(&spec(&[4], Dtype::S32)).is_err());
    }
}
