//! Runtime layer: PJRT client wrapper + artifact manifest. Loads the HLO
//! text emitted by `python/compile/aot.py` and executes it from the L3 hot
//! path — Python never runs here. Execution requires the `pjrt` cargo
//! feature (the `xla` crate is not in the offline vendor); without it the
//! manifest still parses and `Runtime::new` errors descriptively.

pub mod artifact;
pub mod client;

pub use artifact::{ArtifactSpec, Dtype, Manifest, TensorSpec};
pub use client::{HostTensor, Runtime};
