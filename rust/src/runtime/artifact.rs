//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! rust runtime. The manifest records, for every lowered HLO module, the
//! flattened input/output signature (jax pytree order) plus model/PQT
//! metadata, so buffer marshalling here needs no knowledge of jax.

use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Element dtype of a tensor crossing the boundary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    Bf16,
    S32,
    U32,
}

impl Dtype {
    pub fn parse(s: &str) -> Result<Dtype> {
        Ok(match s {
            "f32" => Dtype::F32,
            "bf16" => Dtype::Bf16,
            "s32" => Dtype::S32,
            "u32" => Dtype::U32,
            other => bail!("unknown dtype '{other}' in manifest"),
        })
    }
}

/// Shape + dtype + pytree path of one input/output leaf.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<TensorSpec> {
        let name = j.get("name").as_str().context("tensor name")?.to_string();
        let shape = j
            .get("shape")
            .as_arr()
            .context("tensor shape")?
            .iter()
            .map(|v| v.as_usize().context("shape dim"))
            .collect::<Result<Vec<_>>>()?;
        let dtype = Dtype::parse(j.get("dtype").as_str().context("tensor dtype")?)?;
        Ok(TensorSpec { name, shape, dtype })
    }
}

/// One artifact entry.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    pub kind: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub meta: Json,
}

impl ArtifactSpec {
    /// Sorted parameter names from the meta block (train/eval artifacts).
    pub fn param_names(&self) -> Vec<String> {
        str_list(self.meta.get("param_names"))
    }

    pub fn bi_names(&self) -> Vec<String> {
        str_list(self.meta.get("bi_names"))
    }

    pub fn param_shape(&self, name: &str) -> Option<Vec<usize>> {
        shape_of(self.meta.get("param_shapes").get(name))
    }

    pub fn bi_shape(&self, name: &str) -> Option<Vec<usize>> {
        shape_of(self.meta.get("bi_shapes").get(name))
    }

    pub fn meta_usize(&self, key: &str) -> Option<usize> {
        self.meta.get(key).as_usize()
    }

    pub fn meta_str(&self, key: &str) -> Option<&str> {
        self.meta.get(key).as_str()
    }
}

fn str_list(j: &Json) -> Vec<String> {
    j.as_arr()
        .map(|a| a.iter().filter_map(|v| v.as_str().map(String::from)).collect())
        .unwrap_or_default()
}

fn shape_of(j: &Json) -> Option<Vec<usize>> {
    j.as_arr().map(|a| a.iter().filter_map(|v| v.as_usize()).collect())
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`?)", path.display()))?;
        let root = Json::parse(&text).context("parsing manifest.json")?;
        let mut artifacts = BTreeMap::new();
        let obj = root.get("artifacts").as_obj().context("manifest.artifacts")?;
        for (name, entry) in obj {
            let inputs = entry
                .get("inputs")
                .as_arr()
                .context("inputs")?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            let outputs = entry
                .get("outputs")
                .as_arr()
                .context("outputs")?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            let file = dir.join(entry.get("file").as_str().context("file")?);
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    name: name.clone(),
                    file,
                    kind: entry.get("kind").as_str().unwrap_or("op").to_string(),
                    inputs,
                    outputs,
                    meta: entry.get("meta").clone(),
                },
            );
        }
        Ok(Manifest { dir, artifacts })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts.get(name).with_context(|| {
            format!(
                "artifact '{name}' not in manifest (have: {:?})",
                self.artifacts.keys().take(8).collect::<Vec<_>>()
            )
        })
    }

    /// Artifact name for a (model_tag, method_tag, kind) triple, e.g.
    /// ("tiny_gpt2", "gaussws_all", "train").
    pub fn model_artifact(&self, model: &str, method: &str, kind: &str) -> Result<&ArtifactSpec> {
        self.get(&format!("{model}.{method}.{kind}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_manifest(dir: &Path) {
        let text = r#"{
 "artifacts": {
  "op.demo": {
   "file": "op.demo.hlo.txt",
   "kind": "op",
   "inputs": [{"name": "w", "shape": [4, 4], "dtype": "f32"},
              {"name": "seed", "shape": [], "dtype": "s32"}],
   "outputs": [{"name": "out", "shape": [4, 4], "dtype": "bf16"}],
   "meta": {"param_names": ["a", "b"], "param_shapes": {"a": [2, 2], "b": [3]},
            "batch": 8}
  }
 }
}"#;
        std::fs::write(dir.join("manifest.json"), text).unwrap();
    }

    #[test]
    fn parse_roundtrip() {
        let dir = std::env::temp_dir().join("gaussws_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        sample_manifest(&dir);
        let m = Manifest::load(&dir).unwrap();
        let a = m.get("op.demo").unwrap();
        assert_eq!(a.kind, "op");
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.inputs[0].shape, vec![4, 4]);
        assert_eq!(a.inputs[0].dtype, Dtype::F32);
        assert_eq!(a.inputs[1].shape, Vec::<usize>::new());
        assert_eq!(a.outputs[0].dtype, Dtype::Bf16);
        assert_eq!(a.param_names(), vec!["a", "b"]);
        assert_eq!(a.param_shape("a"), Some(vec![2, 2]));
        assert_eq!(a.meta_usize("batch"), Some(8));
        assert!(m.get("nope").is_err());
    }

    #[test]
    fn numel() {
        let t = TensorSpec { name: "x".into(), shape: vec![3, 4, 5], dtype: Dtype::F32 };
        assert_eq!(t.numel(), 60);
        let s = TensorSpec { name: "s".into(), shape: vec![], dtype: Dtype::S32 };
        assert_eq!(s.numel(), 1);
    }

    #[test]
    fn real_manifest_parses_if_present() {
        // integration-lite: if `make artifacts` has run, parse the real one
        if let Ok(m) = Manifest::load("artifacts") {
            assert!(m.artifacts.len() >= 3);
            let op = m.get("op.gaussws_sample").unwrap();
            assert_eq!(op.inputs.len(), 3);
        }
    }
}
