//! Test support: a mini property-testing framework (proptest substitute).

pub mod prop;

pub use prop::{assert_allclose, check, Gen};
