//! Test support: a mini property-testing framework (proptest substitute)
//! plus the deterministic serving fuzz/conformance substrate.
//!
//! * [`prop`] — per-seed case generation ([`Gen`]) and the [`check`]
//!   runner; failures panic with the generating seed and case index.
//! * [`fuzz`] — the serving conformance harness: [`fuzz::FuzzCase`]
//!   derives a random request mix + engine configuration from one seed,
//!   and [`fuzz::check_case`] asserts the serving invariants (leak-free
//!   drain, determinism, prefix-cache transparency, paged-f32 ==
//!   contiguous, bounded quantized-KV logit drift). Driven over a fixed
//!   seed matrix by `tests/fuzz_serve.rs` on every PR.

pub mod fuzz;
pub mod prop;

pub use prop::{assert_allclose, check, Gen};
