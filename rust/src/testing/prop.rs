//! Mini property-testing framework (substrate — proptest is not in the
//! offline vendor). Deterministic per-seed case generation with failure
//! reporting of the generating seed and case index, so failures reproduce.

use crate::prng::Philox4x32;

/// A source of random primitive values for one generated case.
pub struct Gen {
    rng: Philox4x32,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Gen { rng: Philox4x32::new(seed) }
    }

    pub fn u32(&mut self) -> u32 {
        self.rng.next_u32()
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    /// Uniform usize in [lo, hi] inclusive.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi >= lo);
        lo + (self.rng.next_u64() % (hi - lo + 1) as u64) as usize
    }

    /// Uniform i32 in [lo, hi] inclusive.
    pub fn i32_in(&mut self, lo: i32, hi: i32) -> i32 {
        lo + (self.rng.next_u64() % (hi as i64 - lo as i64 + 1) as u64) as i32
    }

    /// Uniform f64 in [lo, hi).
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.next_f64() * (hi - lo)
    }

    /// Standard normal.
    pub fn normal(&mut self) -> f64 {
        crate::prng::gauss::box_muller_pair(&mut self.rng).0
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Vector of f32 normals.
    pub fn normal_vec_f32(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal() as f32).collect()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u32() & 1 == 1
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_in(0, xs.len() - 1)]
    }
}

/// Run `cases` generated property checks. The property returns
/// `Result<(), String>`; the first failure panics with the seed and case
/// index baked into the message.
pub fn check(name: &str, cases: u32, mut prop: impl FnMut(&mut Gen) -> Result<(), String>) {
    let base_seed = 0xC0FFEE ^ fxhash(name);
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64);
        let mut g = Gen::new(seed);
        if let Err(msg) = prop(&mut g) {
            panic!("property '{name}' failed at case {case} (seed {seed}): {msg}");
        }
    }
}

/// Tiny FNV-ish string hash so property names decorrelate seeds.
fn fxhash(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Assert two f32 slices are element-wise close.
pub fn assert_allclose(a: &[f32], b: &[f32], atol: f32, rtol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch: {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
        let tol = atol + rtol * y.abs();
        if (x - y).abs() > tol && !(x.is_nan() && y.is_nan()) {
            return Err(format!("element {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn properties_run_and_pass() {
        check("addition commutes", 100, |g| {
            let a = g.f64_in(-1e6, 1e6);
            let b = g.f64_in(-1e6, 1e6);
            if a + b == b + a {
                Ok(())
            } else {
                Err(format!("{a} + {b}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failures_panic_with_context() {
        check("always fails", 10, |_| Err("nope".into()));
    }

    #[test]
    fn generators_respect_ranges() {
        check("ranges", 200, |g| {
            let u = g.usize_in(3, 7);
            if !(3..=7).contains(&u) {
                return Err(format!("usize {u}"));
            }
            let i = g.i32_in(-5, 5);
            if !(-5..=5).contains(&i) {
                return Err(format!("i32 {i}"));
            }
            let f = g.f64_in(0.25, 0.5);
            if !(0.25..0.5).contains(&f) {
                return Err(format!("f64 {f}"));
            }
            Ok(())
        });
    }

    #[test]
    fn allclose_detects_mismatch() {
        assert!(assert_allclose(&[1.0], &[1.0 + 1e-7], 1e-6, 0.0).is_ok());
        assert!(assert_allclose(&[1.0], &[1.1], 1e-6, 1e-6).is_err());
        assert!(assert_allclose(&[1.0, 2.0], &[1.0], 0.1, 0.0).is_err());
    }

    #[test]
    fn deterministic_per_name() {
        let mut v1 = Vec::new();
        check("det", 5, |g| {
            v1.push(g.u64());
            Ok(())
        });
        let mut v2 = Vec::new();
        check("det", 5, |g| {
            v2.push(g.u64());
            Ok(())
        });
        assert_eq!(v1, v2);
    }
}
