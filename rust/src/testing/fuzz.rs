//! Deterministic serving fuzz/conformance substrate: generates random
//! request mixes + engine configurations from a single seed and asserts
//! the serving invariants end to end. `tests/fuzz_serve.rs` drives this
//! over a fixed seed matrix (CI runs it in release mode on every PR);
//! every failure message names the generating seed, so a red run
//! reproduces with `check_case(seed)`.
//!
//! Invariants checked per case ([`check_case`]):
//!
//! 1. **completion + leak-freedom** — every request completes, and after
//!    the engine drains (prefix cache cleared) zero arena blocks remain
//!    live; refcount underflow/double-free would surface as an allocator
//!    error along the way.
//! 2. **determinism** — re-running the identical engine + workload yields
//!    identical greedy tokens, including for stochastic-rounding KV
//!    schemes (SR draws are keyed per layer/position).
//! 3. **prefix-cache transparency** — flipping the prefix cache on/off
//!    leaves every greedy completion unchanged.
//! 4. **paged f32 == contiguous** — under the `"f32"` KV store, engine
//!    outputs are bit-identical to a serial `DecodeCache` reference
//!    decode (and the storage-level logit drift is exactly zero).
//! 5. **bounded quantized drift** — under a quantized KV store, the
//!    final-position logits of every prompt fed through the quantized
//!    paged cache stay within [`drift_bound`] (max-abs) of the f32
//!    reference — [`FUZZ_DRIFT_BOUND`] for ≥ 6-bit codecs, a wider bound
//!    for the 4-bit stratum.
//! 6. **telemetry consistency** — every engine run in the harness records
//!    with tracing on, and after the drain the registry must be
//!    self-consistent: `admissions ≥ completed` (preemption re-admits,
//!    never skips), `prefix_hits + prefix_misses == prefix_lookups`, the
//!    live-block gauge reads zero once the arena is empty, and the trace
//!    stream is well-nested per request with one balanced
//!    `request` span per completion.
//! 7. **net transparency** ([`check_case_net`], `tests/fuzz_serve.rs`
//!    net arm) — the same request mix replayed over a loopback TCP server
//!    (wire codec + strict parse + framing) yields bit-identical tokens,
//!    loses no responses across the drain, and ends with zero live blocks.
//! 8. **fused decode == mirror** — re-running the engine with
//!    `EngineConfig::kv_mirror` on (the f32 debug mirror beside the packed
//!    codes) yields bit-identical greedy tokens: the fused dequant-dot
//!    kernels read exactly what the mirror materializes.
//! 9. **speculative transparency** — re-running the engine with
//!    self-speculative decoding on (`EngineConfig::spec_draft_store`, a
//!    4-bit SR draft round-trip, depth varied by seed) yields bit-identical
//!    greedy tokens and the same leak-free drain: exact-match acceptance
//!    plus deterministic rollback (position-keyed SR re-encoding) means
//!    speculation can never change an output, only its wave count.
//! 10. **wave-batch transparency** — re-running the engine with
//!    `EngineConfig::wave_batch` off (per-sequence decode instead of the
//!    weight-stationary batched wave) yields bit-identical greedy tokens
//!    and the same leak-free drain: stacking decode rows into one GEMM
//!    reorders nothing inside any row's accumulations, so batching can
//!    only change weight traffic, never an output.
//!
//! Cases are deliberately small (arena sizes near the per-request minimum
//! force preemption and copy-on-write; prompts shorter than a block force
//! mid-block prefix adoption) and hard-capped — at most
//! [`MAX_REQUESTS`] requests of ≤ 14 prompt + ≤ [`MAX_NEW_TOKENS`]
//! generated tokens on the tiny GPT2 config — so a full seed-matrix run
//! stays well under the CI wall-time budget.

use crate::config::schema::{Arch, ModelConfig};
use crate::nn::kv::{KvQuant, PagedKv};
use crate::nn::transformer::{DecodeCache, Params, Transformer};
use crate::serve::{
    Engine, EngineConfig, GenRequest, GenResponse, NetClient, NetServer, NetServerConfig,
};
use crate::testing::prop::Gen;

/// KV row-storage schemes the fuzzer rotates through. The `fp4_e2m1_sr`
/// stratum exercises the sub-byte packed-code path (4-bit codes straddle
/// byte boundaries) plus stochastic rounding.
pub const FUZZ_KV_LABELS: &[&str] = &["f32", "fp8_e3m4", "int8_sr", "fp4_e2m1_sr"];

/// The fixed seed matrix CI exercises on every PR (N = 8). Frozen so
/// regressions reproduce byte-for-byte across machines, and chosen to
/// cover every `seed % 4` residue — the KV scheme is stratified by seed
/// (see [`FuzzCase::generate`]), so the matrix provably exercises all of
/// [`FUZZ_KV_LABELS`].
pub const FUZZ_SEED_MATRIX: [u64; 8] = [12, 23, 37, 45, 53, 66, 79, 97];

/// Max-abs final-logit drift allowed for ≥ 6-bit quantized KV vs the f32
/// reference (per prompt). Generous: fp8/int8 row quantization on the
/// tiny config lands one to two orders of magnitude below this; the bound
/// exists to catch scale/codec wiring bugs, not to certify accuracy.
pub const FUZZ_DRIFT_BOUND: f32 = 2.5;

/// Drift bound for `kv_label` (invariant 5). Sub-5-bit codecs get a much
/// wider allowance — two-mantissa-bit fp4 rows genuinely perturb the tiny
/// model's logits by O(10) — while everything else keeps
/// [`FUZZ_DRIFT_BOUND`]. Like the base bound, this catches wiring bugs
/// (a mis-scaled group blows far past it), not accuracy claims.
pub fn drift_bound(kv_label: &str) -> f32 {
    let scheme = crate::quant::resolve(kv_label).expect("kv label is registered");
    if scheme.codec.is_packed() && scheme.codec.bits_per_elem() <= 4 {
        24.0
    } else {
        FUZZ_DRIFT_BOUND
    }
}

/// Per-case request cap (wall-time guard for the CI seed matrix).
pub const MAX_REQUESTS: usize = 8;

/// Per-request generation cap (wall-time guard for the CI seed matrix).
pub const MAX_NEW_TOKENS: usize = 6;

/// One generated fuzz case: a random engine configuration plus a random
/// greedy request mix (shared prefixes, varied prompt/gen lengths) on the
/// tiny GPT2 config.
pub struct FuzzCase {
    pub seed: u64,
    pub kv_label: &'static str,
    pub ecfg: EngineConfig,
    pub requests: Vec<GenRequest>,
}

impl FuzzCase {
    /// Deterministically generate the case for `seed`.
    pub fn generate(seed: u64) -> FuzzCase {
        let cfg = ModelConfig::tiny(Arch::Gpt2);
        let mut g = Gen::new(seed ^ 0xF022_5EED);
        // stratified, not drawn: a small seed matrix covering every
        // `seed % 4` residue provably exercises every scheme
        let kv_label = FUZZ_KV_LABELS[(seed % FUZZ_KV_LABELS.len() as u64) as usize];
        let kv_block = *g.choose(&[1usize, 2, 3, 4, 8]);
        let prefill_chunk = g.usize_in(1, 6);
        let max_batch = g.usize_in(1, 4);
        let threads = g.usize_in(1, 2);
        let prefix_cache = g.bool();
        // two candidate "system prompt" heads some requests share
        let heads: Vec<Vec<usize>> = (0..2)
            .map(|_| (0..g.usize_in(2, 6)).map(|_| g.usize_in(0, cfg.vocab - 1)).collect())
            .collect();
        let n_req = g.usize_in(3, MAX_REQUESTS);
        let mut requests = Vec::with_capacity(n_req);
        let mut max_need = 1;
        for id in 0..n_req {
            let mut prompt: Vec<usize> =
                if g.bool() { heads[g.usize_in(0, 1)].clone() } else { Vec::new() };
            let extra = g.usize_in(usize::from(prompt.is_empty()), 8);
            prompt.extend((0..extra).map(|_| g.usize_in(0, cfg.vocab - 1)));
            let max_new = g.usize_in(1, MAX_NEW_TOKENS);
            max_need = max_need.max(prompt.len() + max_new - 1);
            requests.push(GenRequest::greedy(id as u64, prompt, max_new));
        }
        // arena barely larger than the biggest single request: every
        // request fits alone (the enqueue bound) but concurrent sequences
        // contend, forcing preemption / prefix eviction / CoW paths
        let per_req = max_need.div_ceil(kv_block);
        let kv_blocks = per_req + g.usize_in(0, per_req.max(1));
        let ecfg = EngineConfig {
            max_batch,
            kv_block,
            kv_blocks,
            prefill_chunk,
            prefix_cache,
            threads,
            kv_scheme: crate::quant::resolve(kv_label).expect("fuzz kv label is registered"),
            kv_seed: seed,
            ..EngineConfig::default()
        };
        FuzzCase { seed, kv_label, ecfg, requests }
    }

    /// One-line description for failure messages.
    pub fn describe(&self) -> String {
        format!(
            "kv={} block={} arena={} chunk={} batch={} threads={} prefix={} reqs={}",
            self.kv_label,
            self.ecfg.kv_block,
            self.ecfg.kv_blocks,
            self.ecfg.prefill_chunk,
            self.ecfg.max_batch,
            self.ecfg.threads,
            self.ecfg.prefix_cache,
            self.requests.len()
        )
    }
}

/// The model every fuzz case serves (weights are fixed — the fuzzer
/// explores scheduling/storage space, not parameter space).
pub fn model_under_test() -> (Transformer, Params) {
    let cfg = ModelConfig::tiny(Arch::Gpt2);
    let model = Transformer::new(cfg.clone());
    let params = model.init_params(0xF00D);
    (model, params)
}

/// Drive one engine over `requests`; returns completions sorted by id.
/// Errors on incomplete drains, on block leaks (live blocks after the
/// prefix cache is cleared), and on telemetry inconsistencies — every
/// harness run records with tracing on and the registry/trace invariants
/// (invariant 6 in the module docs) are asserted after the drain.
pub fn run_engine(
    model: &Transformer,
    params: &Params,
    ecfg: &EngineConfig,
    requests: &[GenRequest],
    tag: &str,
) -> Result<Vec<GenResponse>, String> {
    let traced = EngineConfig { trace: true, ..ecfg.clone() };
    let mut e = Engine::new(model.cfg.clone(), params.clone(), traced);
    for r in requests {
        e.enqueue(r.clone()).map_err(|err| format!("{tag}: enqueue req {}: {err}", r.id))?;
    }
    let mut out = e.run_to_completion();
    if out.len() != requests.len() {
        return Err(format!("{tag}: {}/{} requests completed", out.len(), requests.len()));
    }
    e.clear_prefix_cache();
    let (live, total, _, _) = e.kv_usage();
    if live != 0 {
        return Err(format!("{tag}: {live} of {total} blocks leaked after drain"));
    }
    check_telemetry(&e, requests.len(), tag)?;
    out.sort_by_key(|r| r.id);
    Ok(out)
}

/// Telemetry consistency checks run against a drained engine (invariant 6).
fn check_telemetry(e: &Engine, n_requests: usize, tag: &str) -> Result<(), String> {
    let st = &e.stats;
    if st.completed() != n_requests {
        return Err(format!(
            "{tag}: telemetry counted {} completions for {n_requests} requests",
            st.completed()
        ));
    }
    if st.admissions() < st.completed() {
        return Err(format!(
            "{tag}: admissions {} < completed {} (every completion needs an admission)",
            st.admissions(),
            st.completed()
        ));
    }
    if st.prefix_hits() + st.prefix_misses() != st.prefix_lookups() {
        return Err(format!(
            "{tag}: prefix hits {} + misses {} != lookups {}",
            st.prefix_hits(),
            st.prefix_misses(),
            st.prefix_lookups()
        ));
    }
    if st.blocks_live_now() != 0.0 {
        return Err(format!(
            "{tag}: live-block gauge reads {} after drain + prefix clear",
            st.blocks_live_now()
        ));
    }
    let events = st.trace_events();
    if events.is_empty() {
        return Err(format!("{tag}: tracing was on but no events were recorded"));
    }
    crate::telemetry::check_well_nested(events)
        .map_err(|err| format!("{tag}: trace stream not well-nested: {err}"))?;
    // one balanced request span per completion
    let begins = events.iter().filter(|ev| ev.name == "request" && ev.ph.code() == "B").count();
    let ends = events.iter().filter(|ev| ev.name == "request" && ev.ph.code() == "E").count();
    if begins != n_requests || ends != n_requests {
        return Err(format!(
            "{tag}: expected {n_requests} balanced request spans, saw {begins} begins / {ends} ends"
        ));
    }
    Ok(())
}

/// Serial greedy reference: one request decoded token-at-a-time on the
/// contiguous f32 `DecodeCache` (no paging, no batching, no sharing).
pub fn reference_greedy(model: &Transformer, params: &Params, req: &GenRequest) -> Vec<usize> {
    let mut cache = DecodeCache::new(&model.cfg, model.cfg.seq_len);
    let mut fed = req.prompt.clone();
    let mut generated = Vec::new();
    let mut i = 0;
    loop {
        let logits = model.decode_step(params, fed[i], &mut cache);
        i += 1;
        if i < fed.len() {
            continue;
        }
        let mut best = 0;
        for (c, &v) in logits.iter().enumerate() {
            if v > logits[best] {
                best = c;
            }
        }
        generated.push(best);
        if generated.len() >= req.max_new_tokens {
            return generated;
        }
        fed.push(best);
    }
}

/// Max-abs difference of the per-step logits between feeding `tokens`
/// through a paged cache storing rows via `kv_label` and the contiguous
/// f32 reference. Exactly 0.0 for the `"f32"` passthrough.
pub fn kv_logit_drift(
    model: &Transformer,
    params: &Params,
    tokens: &[usize],
    kv_label: &str,
    kv_block: usize,
    kv_seed: u64,
) -> f32 {
    let scheme = crate::quant::resolve(kv_label).expect("kv label is registered");
    let quant = KvQuant::new(scheme, model.cfg.d_model, kv_seed).expect("hostable kv scheme");
    let mut paged = PagedKv::new_quantized(&model.cfg, kv_block, tokens.len(), quant);
    let mut reference = DecodeCache::new(&model.cfg, tokens.len());
    let mut drift = 0f32;
    for &t in tokens {
        let a = model.decode_step(params, t, &mut paged);
        let b = model.decode_step(params, t, &mut reference);
        let step = a.iter().zip(b.iter()).map(|(x, y)| (x - y).abs()).fold(0f32, f32::max);
        drift = drift.max(step);
    }
    drift
}

fn tokens_of(out: &[GenResponse]) -> Vec<Vec<usize>> {
    out.iter().map(|r| r.tokens.clone()).collect()
}

/// Generate and fully check the case for `seed`; `Err` carries the
/// violated invariant plus the case description (the caller prepends the
/// seed so failures reproduce).
pub fn check_case(seed: u64) -> Result<(), String> {
    let case = FuzzCase::generate(seed);
    let (model, params) = model_under_test();
    let tag = case.describe();

    // 1. complete + leak-free
    let first = run_engine(&model, &params, &case.ecfg, &case.requests, &tag)?;

    // 2. determinism: the identical engine reproduces every token
    let second = run_engine(&model, &params, &case.ecfg, &case.requests, &tag)?;
    if tokens_of(&first) != tokens_of(&second) {
        return Err(format!("{tag}: nondeterministic outputs across identical runs"));
    }

    // 3. prefix-cache transparency: flipping it changes nothing
    let flipped = EngineConfig { prefix_cache: !case.ecfg.prefix_cache, ..case.ecfg.clone() };
    let third = run_engine(&model, &params, &flipped, &case.requests, &tag)?;
    if tokens_of(&first) != tokens_of(&third) {
        return Err(format!(
            "{tag}: greedy outputs changed when prefix cache flipped to {}",
            flipped.prefix_cache
        ));
    }

    // 8. fused decode == mirror: materializing the f32 debug mirror next
    // to the packed codes must not change a single greedy token (for
    // "f32" passthrough the mirror IS the storage, so this is free)
    let mirrored = EngineConfig { kv_mirror: true, ..case.ecfg.clone() };
    let fourth = run_engine(&model, &params, &mirrored, &case.requests, &tag)?;
    if tokens_of(&first) != tokens_of(&fourth) {
        return Err(format!(
            "{tag}: greedy outputs changed when the f32 decode mirror was enabled \
             (fused dequant-dot kernels diverge from the mirror)"
        ));
    }

    // 9. speculative transparency: the same case with self-speculative
    // decoding on (lowest-bit draft stratum, depth varied by seed) must
    // reproduce every greedy token and drain leak-free (run_engine checks
    // leaks + telemetry; every fuzz request is greedy, so every decode
    // chunk is spec-eligible)
    let spec = EngineConfig {
        spec_draft_store: Some(
            crate::quant::resolve("fp4_e2m1_sr").expect("draft label is registered"),
        ),
        spec_k: 1 + (seed % 4) as usize,
        ..case.ecfg.clone()
    };
    let fifth = run_engine(&model, &params, &spec, &case.requests, &tag)?;
    if tokens_of(&first) != tokens_of(&fifth) {
        return Err(format!(
            "{tag}: greedy outputs changed with speculative decoding on \
             (draft fp4_e2m1_sr, k={})",
            spec.spec_k
        ));
    }

    // 10. wave-batch transparency: disabling the weight-stationary batched
    // decode wave (per-sequence decode for every chunk) must not change a
    // single greedy token — the default runs above all had it on, so this
    // pins both sides of the switch to the same token streams
    let unbatched = EngineConfig { wave_batch: false, ..case.ecfg.clone() };
    let sixth = run_engine(&model, &params, &unbatched, &case.requests, &tag)?;
    if tokens_of(&first) != tokens_of(&sixth) {
        return Err(format!(
            "{tag}: greedy outputs changed when wave batching was disabled \
             (batched decode_wave diverges from per-sequence decode)"
        ));
    }

    if case.kv_label == "f32" {
        // 4. paged f32 serving is bit-identical to the contiguous reference
        for (resp, req) in first.iter().zip(case.requests.iter()) {
            let want = reference_greedy(&model, &params, req);
            if resp.tokens != want {
                return Err(format!(
                    "{tag}: req {} diverged from the contiguous f32 reference \
                     (got {:?}, want {want:?})",
                    req.id, resp.tokens
                ));
            }
        }
        for req in &case.requests {
            let drift = kv_logit_drift(
                &model,
                &params,
                &req.prompt,
                "f32",
                case.ecfg.kv_block,
                case.ecfg.kv_seed,
            );
            if drift != 0.0 {
                return Err(format!("{tag}: f32 passthrough produced nonzero drift {drift}"));
            }
        }
    } else {
        // 5. bounded logit drift for quantized KV (per-label bound: the
        // 4-bit stratum is allowed more than fp8/int8)
        let bound = drift_bound(case.kv_label);
        for req in &case.requests {
            let drift = kv_logit_drift(
                &model,
                &params,
                &req.prompt,
                case.kv_label,
                case.ecfg.kv_block,
                case.ecfg.kv_seed,
            );
            if !drift.is_finite() || drift > bound {
                return Err(format!(
                    "{tag}: req {} logit drift {drift} exceeds bound {bound}",
                    req.id
                ));
            }
        }
    }
    Ok(())
}

/// The net-transport conformance arm: replay the case's request mix over a
/// loopback TCP server (requests round-trip through the wire codec and the
/// strict parser, replies through the frame protocol) and assert the token
/// streams are bit-identical to the in-process engine, the drain loses no
/// responses, and the live-block gauge ends at zero.
pub fn check_case_net(seed: u64) -> Result<(), String> {
    let case = FuzzCase::generate(seed);
    let (model, params) = model_under_test();
    let tag = format!("net/{}", case.describe());

    let reference = run_engine(&model, &params, &case.ecfg, &case.requests, &tag)?;

    let traced = EngineConfig { trace: true, ..case.ecfg.clone() };
    let engine = Engine::new(model.cfg.clone(), params.clone(), traced);
    let server = NetServer::bind("127.0.0.1:0", engine, NetServerConfig::default())
        .map_err(|e| format!("{tag}: bind: {e:#}"))?;
    let mut client = NetClient::connect(server.local_addr())
        .map_err(|e| format!("{tag}: connect: {e:#}"))?;
    // pipeline every request on one connection: replies arrive in
    // completion order and are re-sorted by id below
    for r in &case.requests {
        client.send(r).map_err(|e| format!("{tag}: send req {}: {e:#}", r.id))?;
    }
    let mut got = Vec::with_capacity(case.requests.len());
    for _ in 0..case.requests.len() {
        match client.recv() {
            Ok(Ok(resp)) => got.push(resp),
            Ok(Err(err)) => return Err(format!("{tag}: server errored a request: {}", err.error)),
            Err(e) => return Err(format!("{tag}: recv: {e:#}")),
        }
    }
    let stats = server.shutdown();
    got.sort_by_key(|r| r.id);
    if tokens_of(&got) != tokens_of(&reference) {
        return Err(format!("{tag}: TCP outputs diverged from the in-process engine"));
    }
    if stats.completed() != case.requests.len() {
        return Err(format!(
            "{tag}: server stats counted {} completions for {} requests",
            stats.completed(),
            case.requests.len()
        ));
    }
    if stats.blocks_live_now() != 0.0 {
        return Err(format!(
            "{tag}: live-block gauge reads {} after server drain",
            stats.blocks_live_now()
        ));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn case_generation_is_deterministic_and_bounded() {
        for seed in [3u64, 99, 12345] {
            let a = FuzzCase::generate(seed);
            let b = FuzzCase::generate(seed);
            assert_eq!(a.kv_label, b.kv_label);
            assert_eq!(a.requests, b.requests);
            assert_eq!(a.ecfg.kv_blocks, b.ecfg.kv_blocks);
            assert!(a.requests.len() <= MAX_REQUESTS);
            for r in &a.requests {
                assert!(!r.prompt.is_empty());
                assert!(r.max_new_tokens <= MAX_NEW_TOKENS);
                assert!(
                    a.ecfg.kv_blocks
                        >= (r.prompt.len() + r.max_new_tokens - 1).div_ceil(a.ecfg.kv_block),
                    "seed {seed}: request {} cannot fit the arena alone",
                    r.id
                );
            }
            assert!(a.describe().contains(a.kv_label));
        }
    }

    #[test]
    fn reference_greedy_matches_engine_on_a_simple_case() {
        let (model, params) = model_under_test();
        let req = GenRequest::greedy(1, vec![4, 9, 2], 4);
        let mut e = Engine::new(
            model.cfg.clone(),
            params.clone(),
            EngineConfig { max_batch: 1, threads: 1, ..EngineConfig::default() },
        );
        e.enqueue(req.clone()).unwrap();
        let out = e.run_to_completion();
        assert_eq!(out[0].tokens, reference_greedy(&model, &params, &req));
    }

    #[test]
    fn drift_is_zero_for_f32_and_small_for_fp8() {
        let (model, params) = model_under_test();
        let tokens: Vec<usize> = (0..12).map(|k| (k * 7 + 1) % 50).collect();
        assert_eq!(kv_logit_drift(&model, &params, &tokens, "f32", 4, 9), 0.0);
        let d = kv_logit_drift(&model, &params, &tokens, "fp8_e3m4", 4, 9);
        assert!(d > 0.0, "fp8 KV should perturb logits at least slightly");
        assert!(d < FUZZ_DRIFT_BOUND, "fp8 drift {d} out of bound");
    }

    #[test]
    fn drift_bound_widens_only_for_four_bit_labels() {
        for label in ["f32", "bf16", "fp8_e3m4", "int8_sr", "fp6_e3m2"] {
            assert_eq!(drift_bound(label), FUZZ_DRIFT_BOUND, "{label}");
        }
        for label in ["fp4_e2m1", "fp4_e2m1_sr", "int4", "int4_sr"] {
            assert!(drift_bound(label) > FUZZ_DRIFT_BOUND, "{label}");
        }
        // and the 4-bit stratum actually stays inside its widened bound
        let (model, params) = model_under_test();
        let tokens: Vec<usize> = (0..12).map(|k| (k * 7 + 1) % 50).collect();
        let d = kv_logit_drift(&model, &params, &tokens, "fp4_e2m1_sr", 4, 9);
        assert!(d > 0.0, "fp4 KV must perturb logits");
        assert!(d < drift_bound("fp4_e2m1_sr"), "fp4 drift {d} out of bound");
    }

    #[test]
    fn every_kv_stratum_is_reachable_from_the_seed_matrix() {
        let mut hit = vec![false; FUZZ_KV_LABELS.len()];
        for &seed in &FUZZ_SEED_MATRIX {
            hit[(seed % FUZZ_KV_LABELS.len() as u64) as usize] = true;
        }
        assert!(hit.iter().all(|&h| h), "seed matrix misses a KV stratum: {hit:?}");
    }
}
