//! The [`QuantScheme`] abstraction: one (codec × rounding × scale-geometry)
//! description of a quantization scheme, shared by train-time fake-quant
//! (`pqt`), MX snapshot analysis (`mx`), and the packed serving store
//! (`serve::weights`).
//!
//! The paper's whole pitch is that a single noise/cast abstraction
//! (`ŵ = bf16(w + R·scale)`, then FP casting) spans training and
//! low-precision deployment. A scheme captures the casting half of that
//! claim as data:
//!
//! * **codec** — how one pre-scaled element is represented: a low-precision
//!   float ([`crate::numerics::FpFormat`] emulation), a symmetric signed
//!   integer, or master f32 passthrough; and how it bit-packs to a code.
//! * **rounding** — RNE / toward-zero / stochastic
//!   ([`crate::numerics::Rounding`]). Stochastic rounding is what the
//!   direct-quantized-training arms (Zhao et al., 2024; Chmiel et al.,
//!   2025) need.
//! * **geometry** — which elements share a scale: square `b×b` blocks
//!   (GaussWS §3.2, transpose-commutative), 1×b vectors (standard MX), or
//!   no block scaling at all (a plain elementwise cast, e.g. the ŵ "BF16
//!   operator").
//!
//! Schemes are resolved from string labels through [`super::Registry`]; new
//! (format × rounding × geometry) combinations are one registry entry, not
//! a fourth re-implementation of "format + block scale + rounding".

use crate::numerics::fpformat::{round_ties_even, FpFormat, Rounding};
use crate::prng::Philox4x32;

/// Which axis 1×`block` vectors run along (vector-wise MX geometry).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Axis {
    /// Blocks are contiguous within a row (along columns).
    Row,
    /// Blocks run down a column (along rows).
    Col,
}

/// Element codec: how one (pre-scaled) element value is represented and how
/// it packs into a code of at most 16 bits.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Codec {
    /// Master f32 passthrough — no quantization, no packing.
    F32,
    /// Low-precision float elements (software [`FpFormat`] emulation).
    Fp(FpFormat),
    /// Symmetric signed integer with `bits` total bits (no zero point).
    Int { bits: u32 },
}

impl Codec {
    /// Largest representable magnitude at scale 1. Infinite for [`Codec::F32`]
    /// (passthrough never clips).
    pub fn max_code(&self) -> f64 {
        match self {
            Codec::F32 => f64::INFINITY,
            Codec::Fp(f) => f.max_finite(),
            Codec::Int { bits } => ((1i64 << (bits - 1)) - 1) as f64,
        }
    }

    /// Bytes one packed element code occupies (4 for unpacked f32).
    pub fn bytes_per_elem(&self) -> usize {
        match self {
            Codec::F32 => 4,
            Codec::Fp(f) => {
                if f.total_bits() <= 8 {
                    1
                } else {
                    2
                }
            }
            Codec::Int { bits } => {
                if *bits <= 8 {
                    1
                } else {
                    2
                }
            }
        }
    }

    /// Total code bits (sign included). 32 for unpacked f32.
    pub fn total_bits(&self) -> u32 {
        match self {
            Codec::F32 => 32,
            Codec::Fp(f) => f.total_bits(),
            Codec::Int { bits } => *bits,
        }
    }

    /// Bits one element code occupies in a dense packing — the width
    /// [`crate::quant::PackedCodes`] stores codes at (fp4 → 4 bits, not a
    /// padded byte). Identical to [`Codec::total_bits`]; named for the
    /// storage question it answers.
    pub fn bits_per_elem(&self) -> u32 {
        self.total_bits()
    }

    /// True iff this codec bit-packs into u8/u16 element codes.
    pub fn is_packed(&self) -> bool {
        !matches!(self, Codec::F32)
    }

    /// Quantize a pre-scaled value to the nearest representable value under
    /// `rounding`, clamping to range. `rand` is consumed only by
    /// [`Rounding::Stochastic`]; pass 0 otherwise.
    ///
    /// NaN policy (documented here, enforced by `tests/quant_suite.rs`):
    /// NaN never contaminates a shared block scale (amax folds ignore it —
    /// `f64::max` skips NaN), and per element it maps to the codec's
    /// nearest representable notion of "NaN": FP formats with inf/nan
    /// codes propagate NaN, saturating FP formats clamp it to ±max_finite
    /// (see [`FpFormat::cast_mode`]), and symmetric INT codecs — which have
    /// no NaN code at all — map it to 0.
    pub fn quantize(&self, x: f64, rounding: Rounding, rand: u32) -> f64 {
        match self {
            Codec::F32 => x,
            Codec::Fp(f) => f.cast_mode(x, rounding, rand),
            Codec::Int { .. } => {
                if x.is_nan() {
                    return 0.0;
                }
                let m = self.max_code();
                let r = match rounding {
                    Rounding::NearestEven => round_ties_even(x),
                    Rounding::TowardZero => x.trunc(),
                    Rounding::Stochastic => {
                        let fl = x.floor();
                        let frac = x - fl;
                        // rand/2^32 uniform in [0,1)
                        let u = (rand as f64) / 4294967296.0;
                        if frac > u {
                            fl + 1.0
                        } else {
                            fl
                        }
                    }
                };
                r.clamp(-m, m)
            }
        }
    }

    /// Encode a representable pre-scaled value into its packed code.
    ///
    /// FP codecs use sign/exp/mantissa bit layout; INT codecs use two's
    /// complement masked to `bits`. Panics for [`Codec::F32`] (raw tensors
    /// are stored unpacked).
    pub fn encode(&self, v: f64) -> u16 {
        match self {
            Codec::F32 => panic!("Codec::F32 has no packed code (store raw f32)"),
            Codec::Fp(fmt) => encode_fp(fmt, v),
            Codec::Int { bits } => {
                let mask = (1u32 << bits) - 1;
                ((v as i64) as u32 & mask) as u16
            }
        }
    }

    /// Decode a code produced by [`Codec::encode`] back to its exact value.
    pub fn decode(&self, code: u16) -> f64 {
        match self {
            Codec::F32 => panic!("Codec::F32 has no packed code (store raw f32)"),
            Codec::Fp(fmt) => decode_fp(fmt, code),
            Codec::Int { bits } => {
                let raw = (code as u32 & ((1u32 << bits) - 1)) as i64;
                let half = 1i64 << (bits - 1);
                (if raw >= half { raw - (1i64 << bits) } else { raw }) as f64
            }
        }
    }
}

/// Encode a value exactly representable in `fmt` into its sign/exp/mantissa
/// code (at most 16 bits for every format this crate defines).
fn encode_fp(fmt: &FpFormat, v: f64) -> u16 {
    let m = fmt.man_bits;
    if v.is_nan() {
        // only formats with inf/nan codes can hold a NaN (saturating
        // formats never produce one — `cast_mode` clamps NaN to max_finite)
        debug_assert!(fmt.has_inf_nan, "NaN reached encode for a format without NaN codes");
        return ((((1u32 << fmt.exp_bits) - 1) as u16) << m) | 1;
    }
    let sign: u16 = if v.is_sign_negative() { 1 << (fmt.exp_bits + m) } else { 0 };
    let a = v.abs();
    if a == 0.0 {
        return sign;
    }
    if a.is_infinite() {
        // only reachable for has_inf_nan formats
        return sign | ((((1u32 << fmt.exp_bits) - 1) as u16) << m);
    }
    let e = a.log2().floor() as i32;
    if e < fmt.min_normal_exp() {
        // subnormal: mantissa counts the min-subnormal step
        let man = (a / fmt.min_subnormal()).round() as u16;
        sign | man
    } else {
        let exp_code = (e + fmt.bias()) as u16;
        let frac = a / (e as f64).exp2() - 1.0; // in [0, 1)
        let man = (frac * (1u64 << m) as f64).round() as u16;
        sign | (exp_code << m) | man
    }
}

/// Decode a code produced by [`encode_fp`] back to its exact value.
fn decode_fp(fmt: &FpFormat, code: u16) -> f64 {
    let m = fmt.man_bits;
    let man = (code & ((1u16 << m) - 1)) as u32;
    let exp_code = ((code >> m) as u32) & ((1u32 << fmt.exp_bits) - 1);
    let sign = if (code >> (fmt.exp_bits + m)) & 1 == 1 { -1.0 } else { 1.0 };
    if exp_code == 0 {
        return sign * man as f64 * fmt.min_subnormal();
    }
    if fmt.has_inf_nan && exp_code == (1u32 << fmt.exp_bits) - 1 {
        return if man == 0 { sign * f64::INFINITY } else { f64::NAN };
    }
    let e = exp_code as i32 - fmt.bias();
    sign * (1.0 + man as f64 / (1u64 << m) as f64) * (e as f64).exp2()
}

/// Scale geometry: which elements share one power-of-two scale.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Geometry {
    /// No block scaling — a plain elementwise cast at unit scale (the ŵ
    /// "BF16 operator" of §3.3).
    None,
    /// Square `block`×`block` groups — the GaussWS geometry (§3.2),
    /// transpose-commutative.
    Square { block: usize },
    /// 1×`block` vectors along `axis` — standard MX (not transpose-
    /// commutative, Fig. D.1).
    Vector { block: usize, axis: Axis },
}

impl Geometry {
    /// The block size, if this geometry has one.
    pub fn block(&self) -> Option<usize> {
        match self {
            Geometry::None => None,
            Geometry::Square { block } | Geometry::Vector { block, .. } => Some(*block),
        }
    }

    /// Number of shared scales for a `rows`×`cols` matrix.
    pub fn n_scales(&self, rows: usize, cols: usize) -> usize {
        match self {
            Geometry::None => 1,
            Geometry::Square { block } => rows.div_ceil(*block) * cols.div_ceil(*block),
            Geometry::Vector { block, axis: Axis::Row } => rows * cols.div_ceil(*block),
            Geometry::Vector { block, axis: Axis::Col } => cols * rows.div_ceil(*block),
        }
    }
}

/// Deterministic per-tensor seed for stochastic-rounding quantization
/// (FNV-1a over the tensor name, xored with a caller salt): snapshots and
/// checkpoint-side quantization stay reproducible byte-for-byte, and every
/// consumer derives seeds the same way.
pub fn tensor_seed(name: &str, salt: u64) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h ^ salt
}

/// Compute the power-of-two shared scale for a block with max-abs `amax`,
/// mapping amax *within* the codec's range (MX convention): the smallest
/// power of two such that `amax / scale <= max_code`, so the block maximum
/// never clips.
pub fn po2_scale(amax: f64, codec: &Codec) -> f64 {
    if amax == 0.0 || !codec.is_packed() {
        return 1.0;
    }
    let target = codec.max_code();
    (amax / target).log2().ceil().exp2()
}

/// A matrix fake-quantized blockwise: values are dequantized back to f64 so
/// downstream math can compare against the original. `scales` holds one
/// scale per block, in the geometry's traversal order (row-major over the
/// block grid for [`Geometry::Square`]).
#[derive(Debug, Clone)]
pub struct Quantized {
    pub data: Vec<f64>,
    pub rows: usize,
    pub cols: usize,
    pub scales: Vec<f64>,
}

/// Fake-quantize `w` under an explicit (geometry × codec × rounding)
/// triple. `seed` feeds the per-element stochastic-rounding draws and is
/// ignored (no PRNG advance) for deterministic rounding modes, so RNE
/// results do not depend on it.
pub fn fake_quantize(
    w: &[f64],
    rows: usize,
    cols: usize,
    geometry: Geometry,
    codec: &Codec,
    rounding: Rounding,
    seed: u64,
) -> Quantized {
    assert_eq!(w.len(), rows * cols);
    let stochastic = rounding == Rounding::Stochastic;
    let mut rng = Philox4x32::new(seed);
    let mut out = vec![0f64; w.len()];
    let mut scales = Vec::with_capacity(geometry.n_scales(rows, cols));
    match geometry {
        Geometry::None => {
            scales.push(1.0);
            for (o, &x) in out.iter_mut().zip(w.iter()) {
                let rand = if stochastic { rng.next_u32() } else { 0 };
                *o = codec.quantize(x, rounding, rand);
            }
        }
        Geometry::Square { block } => {
            let grid_r = rows.div_ceil(block);
            let grid_c = cols.div_ceil(block);
            scales.resize(grid_r * grid_c, 0.0);
            for br in 0..grid_r {
                for bc in 0..grid_c {
                    let r1 = ((br + 1) * block).min(rows);
                    let c1 = ((bc + 1) * block).min(cols);
                    let mut amax = 0f64;
                    for r in br * block..r1 {
                        for c in bc * block..c1 {
                            amax = amax.max(w[r * cols + c].abs());
                        }
                    }
                    let s = po2_scale(amax, codec);
                    scales[br * grid_c + bc] = s;
                    for r in br * block..r1 {
                        for c in bc * block..c1 {
                            let rand = if stochastic { rng.next_u32() } else { 0 };
                            let i = r * cols + c;
                            out[i] = codec.quantize(w[i] / s, rounding, rand) * s;
                        }
                    }
                }
            }
        }
        Geometry::Vector { block, axis: Axis::Row } => {
            for r in 0..rows {
                for b0 in (0..cols).step_by(block) {
                    let b1 = (b0 + block).min(cols);
                    let amax = (b0..b1).map(|c| w[r * cols + c].abs()).fold(0.0, f64::max);
                    let s = po2_scale(amax, codec);
                    scales.push(s);
                    for c in b0..b1 {
                        let rand = if stochastic { rng.next_u32() } else { 0 };
                        out[r * cols + c] = codec.quantize(w[r * cols + c] / s, rounding, rand) * s;
                    }
                }
            }
        }
        Geometry::Vector { block, axis: Axis::Col } => {
            for c in 0..cols {
                for b0 in (0..rows).step_by(block) {
                    let b1 = (b0 + block).min(rows);
                    let amax = (b0..b1).map(|r| w[r * cols + c].abs()).fold(0.0, f64::max);
                    let s = po2_scale(amax, codec);
                    scales.push(s);
                    for r in b0..b1 {
                        let rand = if stochastic { rng.next_u32() } else { 0 };
                        out[r * cols + c] = codec.quantize(w[r * cols + c] / s, rounding, rand) * s;
                    }
                }
            }
        }
    }
    Quantized { data: out, rows, cols, scales }
}

/// One quantization scheme: a label plus the (codec × rounding × geometry)
/// triple it names. Resolved from strings through [`super::Registry`].
#[derive(Debug, Clone, PartialEq)]
pub struct Scheme {
    label: String,
    pub codec: Codec,
    pub rounding: Rounding,
    pub geometry: Geometry,
}

impl Scheme {
    pub fn new(label: &str, codec: Codec, rounding: Rounding, geometry: Geometry) -> Scheme {
        Scheme { label: label.to_string(), codec, rounding, geometry }
    }

    /// Same scheme with the block size replaced (no-op for
    /// [`Geometry::None`]).
    pub fn with_block(mut self, block: usize) -> Scheme {
        assert!(block > 0, "block size must be positive");
        self.geometry = match self.geometry {
            Geometry::None => Geometry::None,
            Geometry::Square { .. } => Geometry::Square { block },
            Geometry::Vector { axis, .. } => Geometry::Vector { block, axis },
        };
        self
    }

    /// Same codec/rounding as an elementwise cast (geometry
    /// [`Geometry::None`]) — the ŵ "BF16 operator" shape.
    pub fn elementwise(mut self) -> Scheme {
        self.geometry = Geometry::None;
        self
    }

    /// The block size, if the geometry has one.
    pub fn block(&self) -> Option<usize> {
        self.geometry.block()
    }

    /// Elementwise scale-free cast of one value through the codec (ignores
    /// the geometry). `rand` feeds stochastic rounding; pass 0 otherwise.
    pub fn cast_f32(&self, x: f32, rand: u32) -> f32 {
        self.codec.quantize(x as f64, self.rounding, rand) as f32
    }

    /// Short human description, e.g. `fp(e3m4) rne square32`.
    pub fn describe(&self) -> String {
        let codec = match &self.codec {
            Codec::F32 => "f32".to_string(),
            Codec::Fp(f) => format!("fp(e{}m{})", f.exp_bits, f.man_bits),
            Codec::Int { bits } => format!("int{bits}"),
        };
        let rounding = match self.rounding {
            Rounding::NearestEven => "rne",
            Rounding::TowardZero => "tz",
            Rounding::Stochastic => "sr",
        };
        let geometry = match self.geometry {
            Geometry::None => "elementwise".to_string(),
            Geometry::Square { block } => format!("square{block}"),
            Geometry::Vector { block, axis: Axis::Row } => format!("vec{block}/row"),
            Geometry::Vector { block, axis: Axis::Col } => format!("vec{block}/col"),
        };
        format!("{codec} {rounding} {geometry}")
    }
}

/// The unified quantization interface: every consumer (train-time ŵ cast,
/// MX snapshot, packed serving store) programs against this trait, so a new
/// format/rounding/geometry combination plugs in as one registry entry.
pub trait QuantScheme {
    /// Canonical registry label, e.g. `"fp8_e3m4"`.
    fn label(&self) -> &str;
    fn codec(&self) -> &Codec;
    fn rounding(&self) -> Rounding;
    fn geometry(&self) -> Geometry;

    /// Bytes one packed element code occupies (4 for unpacked f32).
    fn bytes_per_elem(&self) -> usize {
        self.codec().bytes_per_elem()
    }

    /// False for master-precision passthrough schemes.
    fn is_quantizing(&self) -> bool {
        self.codec().is_packed()
    }

    /// The shared power-of-two scale for a block with max-abs `amax`
    /// (1.0 for elementwise geometry / passthrough codecs).
    fn scale(&self, amax: f64) -> f64 {
        match self.geometry() {
            Geometry::None => 1.0,
            _ => po2_scale(amax, self.codec()),
        }
    }

    /// Fake-quantize one block of values sharing a single scale, in place;
    /// returns the scale used. `rng` feeds stochastic rounding only.
    fn quantize_block(&self, vals: &mut [f64], rng: &mut Philox4x32) -> f64 {
        let amax = vals.iter().fold(0.0f64, |m, v| m.max(v.abs()));
        let s = self.scale(amax);
        let stochastic = self.rounding() == Rounding::Stochastic;
        for v in vals.iter_mut() {
            let rand = if stochastic { rng.next_u32() } else { 0 };
            *v = self.codec().quantize(*v / s, self.rounding(), rand) * s;
        }
        s
    }

    /// Encode a representable pre-scaled value into its packed code.
    fn encode(&self, v: f64) -> u16 {
        self.codec().encode(v)
    }

    /// Decode a packed code back to its exact pre-scaled value.
    fn decode(&self, code: u16) -> f64 {
        self.codec().decode(code)
    }

    /// Fake-quantize a full `rows`×`cols` matrix under this scheme's
    /// geometry. `seed` feeds stochastic rounding and is ignored for
    /// deterministic modes.
    fn quantize(&self, w: &[f64], rows: usize, cols: usize, seed: u64) -> Quantized {
        fake_quantize(w, rows, cols, self.geometry(), self.codec(), self.rounding(), seed)
    }
}

impl QuantScheme for Scheme {
    fn label(&self) -> &str {
        &self.label
    }

    fn codec(&self) -> &Codec {
        &self.codec
    }

    fn rounding(&self) -> Rounding {
        self.rounding
    }

    fn geometry(&self) -> Geometry {
        self.geometry
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numerics::fpformat::formats;

    #[test]
    fn int_codes_roundtrip_two_complement() {
        for bits in [2u32, 4, 8, 16] {
            let codec = Codec::Int { bits };
            let m = codec.max_code() as i64;
            for v in -m..=m {
                let code = codec.encode(v as f64);
                assert!((code as u32) < (1u32 << bits) || bits == 16, "bits={bits} v={v}");
                assert_eq!(codec.decode(code), v as f64, "bits={bits} v={v}");
            }
        }
    }

    #[test]
    fn fp_codes_roundtrip_exhaustively_for_tiny_formats() {
        for fmt in [formats::FP8_E3M4, formats::FP8_E4M3, formats::FP6_E3M2, formats::FP4_E2M1] {
            let codec = Codec::Fp(fmt);
            let max_code = 1u32 << fmt.total_bits();
            for v in fmt.enumerate_non_negative() {
                for signed in [v, -v] {
                    let code = codec.encode(signed);
                    assert!((code as u32) < max_code, "{fmt:?}: code {code} overflows");
                    let back = codec.decode(code);
                    assert_eq!(back, signed, "{fmt:?}: {signed} -> {code} -> {back}");
                }
            }
        }
    }

    #[test]
    fn square_geometry_matches_scale_semantics() {
        let codec = Codec::Int { bits: 4 };
        let w: Vec<f64> = (0..64).map(|i| (i as f64 - 32.0) * 0.21).collect();
        let geom = Geometry::Square { block: 4 };
        let q = fake_quantize(&w, 8, 8, geom, &codec, Rounding::NearestEven, 0);
        assert_eq!(q.scales.len(), 4);
        for &s in &q.scales {
            assert_eq!(s.log2().fract(), 0.0, "scale {s} not a power of two");
        }
        // max error bounded by half the largest step
        for (a, b) in w.iter().zip(q.data.iter()) {
            let s = q.scales.iter().cloned().fold(0.0f64, f64::max);
            assert!((a - b).abs() <= 0.5 * s + 1e-12);
        }
    }

    #[test]
    fn elementwise_geometry_is_plain_cast() {
        let scheme =
            Scheme::new("bf16", Codec::Fp(formats::BF16), Rounding::NearestEven, Geometry::None);
        let w = [1.00001f64, -0.33333, 1e-30, 250.0];
        let q = scheme.quantize(&w, 1, 4, 0);
        assert_eq!(q.scales, vec![1.0]);
        for (x, y) in w.iter().zip(q.data.iter()) {
            assert_eq!(*y, formats::BF16.cast(*x));
        }
    }

    #[test]
    fn deterministic_rounding_ignores_seed() {
        let scheme = Scheme::new(
            "fp8_e3m4",
            Codec::Fp(formats::FP8_E3M4),
            Rounding::NearestEven,
            Geometry::Square { block: 4 },
        );
        let w: Vec<f64> = (0..36).map(|i| (i as f64) * 0.173 - 3.0).collect();
        let a = scheme.quantize(&w, 6, 6, 1);
        let b = scheme.quantize(&w, 6, 6, 999);
        assert_eq!(a.data, b.data);
    }

    #[test]
    fn stochastic_rounding_depends_on_seed_but_reproduces() {
        let scheme = Scheme::new(
            "int8_sr",
            Codec::Int { bits: 8 },
            Rounding::Stochastic,
            Geometry::Square { block: 8 },
        );
        let w: Vec<f64> = (0..64).map(|i| ((i * 37) % 19) as f64 * 0.073 - 0.6).collect();
        let a = scheme.quantize(&w, 8, 8, 7);
        let a2 = scheme.quantize(&w, 8, 8, 7);
        let b = scheme.quantize(&w, 8, 8, 8);
        assert_eq!(a.data, a2.data, "same seed must reproduce");
        assert_ne!(a.data, b.data, "different seeds should differ");
    }

    #[test]
    fn quantize_block_shares_one_scale() {
        let scheme = Scheme::new(
            "fp6_e3m2",
            Codec::Fp(formats::FP6_E3M2),
            Rounding::NearestEven,
            Geometry::Square { block: 32 },
        );
        let mut vals: Vec<f64> = (0..32).map(|i| (i as f64 - 16.0) * 0.37).collect();
        let orig = vals.clone();
        let mut rng = Philox4x32::new(0);
        let s = scheme.quantize_block(&mut vals, &mut rng);
        assert!(s > 0.0 && s.log2().fract() == 0.0);
        for (o, v) in orig.iter().zip(vals.iter()) {
            assert!(formats::FP6_E3M2.is_representable(v / s), "{o} -> {v} (s={s})");
        }
    }

    #[test]
    fn with_block_and_elementwise_rewrite_geometry() {
        let s = Scheme::new(
            "fp4_e2m1",
            Codec::Fp(formats::FP4_E2M1),
            Rounding::NearestEven,
            Geometry::Square { block: 32 },
        );
        assert_eq!(s.clone().with_block(16).block(), Some(16));
        assert_eq!(s.elementwise().block(), None);
    }
}
