//! Label → [`Scheme`] resolution: the single place format labels are
//! parsed. The CLI (`quantize`, `serve`, `info`), the TOML config, and the
//! serving snapshot loader all resolve through [`Registry::global`], so an
//! unknown label fails once, with the full list of what *is* available.

use super::scheme::{Axis, Codec, Geometry, QuantScheme, Scheme};
use crate::numerics::fpformat::{formats, Rounding};
use anyhow::{bail, Result};
use std::collections::BTreeMap;
use std::sync::OnceLock;

/// Default square block size for blockwise schemes (the paper's b_l = 32).
pub const DEFAULT_BLOCK: usize = 32;

/// A set of registered quantization schemes, addressable by canonical label
/// or alias (case-insensitive).
#[derive(Debug, Clone, Default)]
pub struct Registry {
    schemes: Vec<Scheme>,
    /// lowercased label/alias → index into `schemes`
    index: BTreeMap<String, usize>,
}

impl Registry {
    /// The process-wide registry of built-in schemes.
    pub fn global() -> &'static Registry {
        static GLOBAL: OnceLock<Registry> = OnceLock::new();
        GLOBAL.get_or_init(Registry::builtin)
    }

    /// Register `scheme` under its canonical label plus `aliases`.
    pub fn register(&mut self, scheme: Scheme, aliases: &[&str]) {
        let idx = self.schemes.len();
        let canonical = scheme.label().to_ascii_lowercase();
        assert!(
            !self.index.contains_key(&canonical),
            "duplicate quant scheme label '{canonical}'"
        );
        self.index.insert(canonical, idx);
        for a in aliases {
            let a = a.to_ascii_lowercase();
            assert!(!self.index.contains_key(&a), "duplicate quant scheme alias '{a}'");
            self.index.insert(a, idx);
        }
        self.schemes.push(scheme);
    }

    /// Resolve `label` (canonical or alias, case-insensitive) to a scheme
    /// instance. Unknown labels fail with the full list of registered
    /// labels.
    pub fn resolve(&self, label: &str) -> Result<Scheme> {
        match self.index.get(&label.to_ascii_lowercase()) {
            Some(&idx) => Ok(self.schemes[idx].clone()),
            None => bail!(
                "unknown quant scheme '{label}' (available: {})",
                self.labels().join(", ")
            ),
        }
    }

    /// Canonical labels in registration order.
    pub fn labels(&self) -> Vec<&str> {
        self.schemes.iter().map(|s| s.label()).collect()
    }

    /// All registered schemes in registration order.
    pub fn schemes(&self) -> &[Scheme] {
        &self.schemes
    }

    /// Human-readable table of every registered scheme (used by
    /// `gaussws info`).
    pub fn render_table(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{:<14} {:<22} {:>5}  aliases\n",
            "label", "codec/rounding/geom", "B/el"
        ));
        for (i, s) in self.schemes.iter().enumerate() {
            let aliases: Vec<&str> = self
                .index
                .iter()
                .filter(|(k, &v)| v == i && k.as_str() != s.label())
                .map(|(k, _)| k.as_str())
                .collect();
            let bytes = s.bytes_per_elem().to_string();
            out.push_str(&format!(
                "{:<14} {:<22} {:>5}  {}\n",
                s.label(),
                s.describe(),
                bytes,
                aliases.join(", ")
            ));
        }
        out
    }

    /// The built-in scheme set. Square-blockwise schemes default to the
    /// paper's b_l = 32; use [`Scheme::with_block`] to override.
    fn builtin() -> Registry {
        use Rounding::{NearestEven, Stochastic};
        let sq = Geometry::Square { block: DEFAULT_BLOCK };
        let mut r = Registry::default();
        // master passthrough (serving fidelity baseline)
        r.register(Scheme::new("f32", Codec::F32, NearestEven, Geometry::None), &[
            "fp32", "master", "none",
        ]);
        // round-to-nearest-even FP schemes, square-blockwise (Table C.1)
        r.register(Scheme::new("bf16", Codec::Fp(formats::BF16), NearestEven, sq), &[]);
        r.register(Scheme::new("fp16", Codec::Fp(formats::FP16), NearestEven, sq), &["f16"]);
        r.register(Scheme::new("fp12_e4m7", Codec::Fp(formats::FP12_E4M7), NearestEven, sq), &[]);
        r.register(Scheme::new("fp8_e4m3", Codec::Fp(formats::FP8_E4M3), NearestEven, sq), &[
            "e4m3",
        ]);
        r.register(Scheme::new("fp8_e5m2", Codec::Fp(formats::FP8_E5M2), NearestEven, sq), &[
            "e5m2",
        ]);
        r.register(Scheme::new("fp8_e3m4", Codec::Fp(formats::FP8_E3M4), NearestEven, sq), &[
            "e3m4",
        ]);
        r.register(Scheme::new("fp6_e3m2", Codec::Fp(formats::FP6_E3M2), NearestEven, sq), &[]);
        r.register(Scheme::new("fp6_e2m3", Codec::Fp(formats::FP6_E2M3), NearestEven, sq), &[]);
        r.register(Scheme::new("fp4_e2m1", Codec::Fp(formats::FP4_E2M1), NearestEven, sq), &[
            "fp4",
        ]);
        // integer MX schemes
        r.register(Scheme::new("int8", Codec::Int { bits: 8 }, NearestEven, sq), &[]);
        r.register(Scheme::new("int4", Codec::Int { bits: 4 }, NearestEven, sq), &[]);
        // stochastic-rounding arms: direct quantized training (Zhao et al.,
        // 2024) and FP4 FQT (Chmiel et al., 2025)
        r.register(Scheme::new("int8_sr", Codec::Int { bits: 8 }, Stochastic, sq), &[]);
        r.register(Scheme::new("int4_sr", Codec::Int { bits: 4 }, Stochastic, sq), &[]);
        r.register(Scheme::new("fp8_e4m3_sr", Codec::Fp(formats::FP8_E4M3), Stochastic, sq), &[]);
        r.register(Scheme::new("fp4_e2m1_sr", Codec::Fp(formats::FP4_E2M1), Stochastic, sq), &[
            "fp4_sr",
        ]);
        // vector-wise MX reference geometry (Fig. D.1 comparisons)
        r.register(
            Scheme::new(
                "fp8_e3m4_vec",
                Codec::Fp(formats::FP8_E3M4),
                NearestEven,
                Geometry::Vector { block: DEFAULT_BLOCK, axis: Axis::Row },
            ),
            &[],
        );
        r
    }
}

/// Resolve `label` against the global registry.
pub fn resolve(label: &str) -> Result<Scheme> {
    Registry::global().resolve(label)
}

/// Canonical labels of the global registry.
pub fn labels() -> Vec<&'static str> {
    Registry::global().labels()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn canonical_labels_resolve() {
        for label in [
            "f32",
            "bf16",
            "fp16",
            "fp12_e4m7",
            "fp8_e4m3",
            "fp8_e5m2",
            "fp8_e3m4",
            "fp6_e3m2",
            "fp6_e2m3",
            "fp4_e2m1",
            "int8",
            "int4",
            "int8_sr",
            "int4_sr",
            "fp8_e4m3_sr",
            "fp4_e2m1_sr",
            "fp8_e3m4_vec",
        ] {
            let s = resolve(label).unwrap();
            assert_eq!(s.label(), label);
        }
    }

    #[test]
    fn aliases_resolve_to_canonical() {
        assert_eq!(resolve("fp4").unwrap().label(), "fp4_e2m1");
        assert_eq!(resolve("e3m4").unwrap().label(), "fp8_e3m4");
        assert_eq!(resolve("master").unwrap().label(), "f32");
        assert_eq!(resolve("FP8_E4M3").unwrap().label(), "fp8_e4m3");
    }

    #[test]
    fn unknown_label_lists_available() {
        let err = resolve("fp7_e9m9").unwrap_err().to_string();
        assert!(err.contains("unknown quant scheme 'fp7_e9m9'"), "{err}");
        assert!(err.contains("fp8_e3m4"), "error should list labels: {err}");
        assert!(err.contains("int8_sr"), "error should list labels: {err}");
    }

    #[test]
    fn blockwise_schemes_default_to_paper_block() {
        assert_eq!(resolve("fp8_e3m4").unwrap().block(), Some(DEFAULT_BLOCK));
        assert_eq!(resolve("f32").unwrap().block(), None);
    }

    #[test]
    fn stochastic_arms_are_registered_as_sr() {
        for label in ["int8_sr", "fp4_e2m1_sr", "fp8_e4m3_sr"] {
            assert_eq!(resolve(label).unwrap().rounding(), Rounding::Stochastic);
        }
    }

    #[test]
    fn render_table_mentions_every_label() {
        let table = Registry::global().render_table();
        for label in labels() {
            assert!(table.contains(label), "table missing {label}:\n{table}");
        }
    }
}
