//! Sub-byte code packing + table-driven dequantization.
//!
//! Every packed codec in the registry emits codes of
//! [`Codec::bits_per_elem`] bits (4 for fp4/int4, 6 for fp6, 8 for fp8 …),
//! but until PR 8 both the KV arena and the GWQS store spent a whole
//! `u16` slot per code. This module is the shared fix:
//!
//! * [`PackedCodes`] — a dense LSB-first bitvector of fixed-width codes
//!   (2–16 bits). Code `i` occupies bits `[i*bits, (i+1)*bits)` of the
//!   byte buffer, so nothing is padded to byte boundaries: an fp4 KV row
//!   of 64 elements is exactly 32 bytes, and a scale group may start and
//!   end mid-byte. Random-access `get`/`set` (blocks write slots out of
//!   order), `push` for streaming writers, and `iter_group` for the fused
//!   group-wise kernels.
//! * [`DequantLut`] — the full 2^bits code→value table of a codec, built
//!   once per scheme so decoding a code on the serving hot path is one
//!   bounds-checked index instead of `decode_fp` bit surgery. Entries are
//!   exactly [`Codec::decode`] (`f64`-bit-identical, property-tested in
//!   `tests/property_suite.rs`), so LUT-driven paths cannot drift from
//!   the canonical codec.
//!
//! Consumers: `nn::kv` (packed KV rows + fused dequant-dot),
//! `serve::weights` (GWQS3 packed tensor payloads + dequantize-on-load).

use crate::quant::scheme::Codec;
use anyhow::{bail, Result};

/// Bytes a dense packing of `len` codes of `bits` bits each occupies.
#[inline]
pub fn packed_bytes(bits: u32, len: usize) -> usize {
    (len * bits as usize).div_ceil(8)
}

/// A dense LSB-first bitvector of fixed-width (2–16 bit) codes.
///
/// Layout invariant: code `i` lives in bits `[i*bits, (i+1)*bits)` of
/// `bytes` (bit `b` = bit `b % 8` of `bytes[b / 8]`), and every bit past
/// `len * bits` is zero — so equal contents compare equal byte-for-byte
/// and the serialized form is canonical.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PackedCodes {
    bits: u32,
    len: usize,
    bytes: Vec<u8>,
}

impl PackedCodes {
    /// An empty vector of `bits`-wide codes. Panics outside 2–16 bits
    /// (codecs narrower or wider than that don't exist in the registry).
    pub fn new(bits: u32) -> PackedCodes {
        assert!((2..=16).contains(&bits), "code width {bits} outside the supported 2-16 bits");
        PackedCodes { bits, len: 0, bytes: Vec::new() }
    }

    /// `len` zero codes (the preallocated-block shape: slots are then
    /// written in arbitrary order via [`PackedCodes::set`]).
    pub fn with_len(bits: u32, len: usize) -> PackedCodes {
        let mut pc = PackedCodes::new(bits);
        pc.len = len;
        pc.bytes = vec![0u8; packed_bytes(bits, len)];
        pc
    }

    /// `len` zero codes at `codec`'s width. Panics for unpacked codecs
    /// (`f32` passthrough has no code stream).
    pub fn for_codec(codec: &Codec, len: usize) -> PackedCodes {
        assert!(codec.is_packed(), "{codec:?} is not a packed codec");
        PackedCodes::with_len(codec.bits_per_elem(), len)
    }

    /// Code width in bits.
    pub fn bits(&self) -> u32 {
        self.bits
    }

    /// Number of codes held.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Bytes the packed buffer occupies (the true storage cost).
    pub fn byte_len(&self) -> usize {
        self.bytes.len()
    }

    /// The raw packed buffer (for serialization).
    pub fn as_bytes(&self) -> &[u8] {
        &self.bytes
    }

    /// Rebuild from a serialized buffer. Rejects wrong buffer lengths and
    /// non-zero bits past `len * bits` (the canonical-form invariant that
    /// makes `PartialEq` meaningful), so a corrupt snapshot fails here
    /// instead of aliasing a different code stream.
    pub fn from_bytes(bits: u32, len: usize, bytes: Vec<u8>) -> Result<PackedCodes> {
        if !(2..=16).contains(&bits) {
            bail!("packed code width {bits} outside the supported 2-16 bits");
        }
        let want = packed_bytes(bits, len);
        if bytes.len() != want {
            bail!("packed buffer is {} bytes, {len} x {bits}-bit codes need {want}", bytes.len());
        }
        let used = len * bits as usize;
        if used % 8 != 0 {
            let tail_mask = !((1u8 << (used % 8)) - 1);
            if bytes[used / 8] & tail_mask != 0 {
                bail!("packed buffer has non-zero bits past the last code");
            }
        }
        Ok(PackedCodes { bits, len, bytes })
    }

    #[inline]
    fn mask(&self) -> u32 {
        (1u32 << self.bits) - 1
    }

    /// The `i`-th code.
    #[inline]
    pub fn get(&self, i: usize) -> u16 {
        debug_assert!(i < self.len, "code index {i} out of range {}", self.len);
        let bit = i * self.bits as usize;
        let (byte, shift) = (bit / 8, bit % 8);
        // a 2-16-bit code shifted by <= 7 bits spans at most 3 bytes
        let mut acc = 0u32;
        for (k, &b) in self.bytes[byte..self.bytes.len().min(byte + 3)].iter().enumerate() {
            acc |= (b as u32) << (8 * k);
        }
        ((acc >> shift) & self.mask()) as u16
    }

    /// Overwrite the `i`-th code. Panics if `code` is wider than the
    /// configured width (a codec/width mismatch is a bug, not data).
    #[inline]
    pub fn set(&mut self, i: usize, code: u16) {
        assert!(i < self.len, "code index {i} out of range {}", self.len);
        let mask = self.mask();
        assert!(code as u32 & !mask == 0, "code {code:#x} does not fit in {} bits", self.bits);
        let bit = i * self.bits as usize;
        let (byte, shift) = (bit / 8, bit % 8);
        let end = self.bytes.len().min(byte + 3);
        let mut acc = 0u32;
        for (k, &b) in self.bytes[byte..end].iter().enumerate() {
            acc |= (b as u32) << (8 * k);
        }
        acc = (acc & !(mask << shift)) | ((code as u32) << shift);
        for (k, b) in self.bytes[byte..end].iter_mut().enumerate() {
            *b = (acc >> (8 * k)) as u8;
        }
    }

    /// Append one code.
    pub fn push(&mut self, code: u16) {
        self.len += 1;
        self.bytes.resize(packed_bytes(self.bits, self.len), 0);
        self.set(self.len - 1, code);
    }

    /// All codes in order.
    pub fn iter(&self) -> impl Iterator<Item = u16> + '_ {
        self.iter_group(0, self.len)
    }

    /// Codes `[start, start + n)` — one scale group of a KV row, or any
    /// other contiguous span (group boundaries need not be byte-aligned).
    ///
    /// Decodes word-at-a-time: one `u64` read from the byte buffer yields
    /// up to `⌊57/bits⌋` codes (16 for 2-bit, 14 for 4-bit, 9 for 6-bit)
    /// before the next refill, instead of the 3-byte reassembly
    /// [`PackedCodes::get`] pays per code. Code order and values are
    /// identical to the scalar walk — this is purely a read-width change.
    pub fn iter_group(&self, start: usize, n: usize) -> GroupIter<'_> {
        assert!(
            start + n <= self.len,
            "group [{start}, {}) out of range {}",
            start + n,
            self.len
        );
        GroupIter {
            bytes: &self.bytes,
            bits: self.bits as usize,
            mask: self.mask(),
            bit: start * self.bits as usize,
            remaining: n,
            acc: 0,
            acc_bits: 0,
        }
    }
}

/// Word-at-a-time reader over a contiguous span of packed codes (from
/// [`PackedCodes::iter_group`]): a 64-bit accumulator is refilled with one
/// wide load and drained LSB-first, so most `next` calls are a shift+mask.
#[derive(Debug)]
pub struct GroupIter<'a> {
    bytes: &'a [u8],
    bits: usize,
    mask: u32,
    /// Absolute bit offset of the next code not yet in the accumulator.
    bit: usize,
    remaining: usize,
    acc: u64,
    /// Valid low bits of `acc` still undrained.
    acc_bits: usize,
}

impl Iterator for GroupIter<'_> {
    type Item = u16;

    #[inline]
    fn next(&mut self) -> Option<u16> {
        if self.remaining == 0 {
            return None;
        }
        if self.acc_bits < self.bits {
            // refill from the cursor: up to 8 bytes assembled little-endian
            // (fewer at the buffer tail — the span's last code is fully
            // inside the buffer, so the partial word still covers it)
            let byte = self.bit / 8;
            let shift = self.bit % 8;
            let end = self.bytes.len().min(byte + 8);
            let mut word = 0u64;
            for (k, &b) in self.bytes[byte..end].iter().enumerate() {
                word |= (b as u64) << (8 * k);
            }
            self.acc = word >> shift;
            self.acc_bits = (end - byte) * 8 - shift;
        }
        let code = (self.acc as u32 & self.mask) as u16;
        self.acc >>= self.bits;
        self.acc_bits -= self.bits;
        self.bit += self.bits;
        self.remaining -= 1;
        Some(code)
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        (self.remaining, Some(self.remaining))
    }
}

impl ExactSizeIterator for GroupIter<'_> {}

/// The full `2^bits` code→value table of a packed codec: `table[c] ==
/// codec.decode(c)` for every representable code pattern (including the
/// inf/NaN patterns of saturating FP formats — decode is total).
#[derive(Debug, Clone)]
pub struct DequantLut {
    bits: u32,
    table: Vec<f64>,
}

impl DequantLut {
    /// Build the table for `codec`, or `None` for unpacked codecs (`f32`
    /// passthrough decodes nothing).
    pub fn for_codec(codec: &Codec) -> Option<DequantLut> {
        if !codec.is_packed() {
            return None;
        }
        let bits = codec.bits_per_elem();
        let table = (0..1usize << bits).map(|c| codec.decode(c as u16)).collect();
        Some(DequantLut { bits, table })
    }

    /// Decode one code: a single table index on the hot path.
    #[inline]
    pub fn decode(&self, code: u16) -> f64 {
        self.table[code as usize]
    }

    /// Code width the table covers.
    pub fn bits(&self) -> u32 {
        self.bits
    }

    /// Table size (`2^bits`).
    pub fn len(&self) -> usize {
        self.table.len()
    }

    pub fn is_empty(&self) -> bool {
        self.table.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_straddles_byte_boundaries() {
        // 6-bit codes: code 1 occupies bits 6..12, straddling bytes 0/1
        let mut pc = PackedCodes::new(6);
        let want = [0x3Fu16, 0x2A, 0x15, 0x01, 0x3E];
        for &c in &want {
            pc.push(c);
        }
        assert_eq!(pc.len(), 5);
        assert_eq!(pc.byte_len(), packed_bytes(6, 5)); // 30 bits -> 4 bytes
        assert_eq!(pc.byte_len(), 4);
        for (i, &c) in want.iter().enumerate() {
            assert_eq!(pc.get(i), c, "code {i}");
        }
        assert_eq!(pc.iter().collect::<Vec<_>>(), want);
    }

    #[test]
    fn random_access_set_matches_push_order() {
        // write slots out of order (the KvBlock pattern) and compare to a
        // sequential build of the same contents
        let mut by_set = PackedCodes::with_len(4, 9);
        let codes = [3u16, 7, 1, 15, 0, 8, 12, 5, 9];
        for &i in &[8usize, 0, 4, 2, 6, 1, 7, 3, 5] {
            by_set.set(i, codes[i]);
        }
        let mut by_push = PackedCodes::new(4);
        for &c in &codes {
            by_push.push(c);
        }
        assert_eq!(by_set, by_push);
        // overwrite in place never disturbs the neighbours
        by_set.set(4, 2);
        assert_eq!(by_set.get(3), 15);
        assert_eq!(by_set.get(4), 2);
        assert_eq!(by_set.get(5), 8);
    }

    #[test]
    fn iter_group_walks_non_byte_aligned_groups() {
        // 3 groups of 3 codes x 6 bits = 18 bits/group: every group
        // boundary lands mid-byte
        let mut pc = PackedCodes::new(6);
        for c in 0..9u16 {
            pc.push(c * 7 % 64);
        }
        for gi in 0..3 {
            let got: Vec<u16> = pc.iter_group(gi * 3, 3).collect();
            let want: Vec<u16> = (gi as u16 * 3..gi as u16 * 3 + 3).map(|c| c * 7 % 64).collect();
            assert_eq!(got, want, "group {gi}");
        }
    }

    #[test]
    fn word_iter_group_matches_scalar_get_for_every_width() {
        // the word-at-a-time reader must reproduce the scalar 3-byte `get`
        // walk exactly for every supported width, every start phase, and
        // spans that end at (and short of) the buffer tail
        for bits in 2..=16u32 {
            let len = 131; // prime so group starts land on every bit phase
            let mask = (1u32 << bits) - 1;
            let mut pc = PackedCodes::with_len(bits, len);
            for i in 0..len {
                pc.set(i, ((i as u32).wrapping_mul(2654435761).rotate_right(7) & mask) as u16);
            }
            for &(start, n) in
                &[(0usize, len), (1, len - 1), (7, 13), (len - 9, 9), (len - 1, 1), (5, 0), (len, 0)]
            {
                let got: Vec<u16> = pc.iter_group(start, n).collect();
                let want: Vec<u16> = (start..start + n).map(|i| pc.get(i)).collect();
                assert_eq!(got, want, "bits {bits} span [{start}, {})", start + n);
            }
        }
    }

    #[test]
    fn word_iter_group_matches_scalar_get_random_spans() {
        use crate::testing::prop::{check, Gen};
        check("word iter_group == scalar get", 30, |g: &mut Gen| {
            let bits = g.usize_in(2, 16) as u32;
            let len = g.usize_in(1, 300);
            let mask = (1u64 << bits) - 1;
            let mut pc = PackedCodes::with_len(bits, len);
            for i in 0..len {
                pc.set(i, (g.u64() & mask) as u16);
            }
            let start = g.usize_in(0, len - 1);
            let n = g.usize_in(0, len - start);
            let got: Vec<u16> = pc.iter_group(start, n).collect();
            let want: Vec<u16> = (start..start + n).map(|i| pc.get(i)).collect();
            if got != want {
                return Err(format!("bits {bits} len {len} span [{start}, {})", start + n));
            }
            Ok(())
        });
    }

    #[test]
    fn serialization_roundtrip_and_corruption_rejected() {
        let mut pc = PackedCodes::new(4);
        for c in [0xFu16, 0x1, 0x7] {
            pc.push(c);
        }
        let back = PackedCodes::from_bytes(4, 3, pc.as_bytes().to_vec()).unwrap();
        assert_eq!(back, pc);
        // wrong length
        assert!(PackedCodes::from_bytes(4, 3, vec![0u8; 3]).is_err());
        // non-zero tail bits past the last code
        let mut dirty = pc.as_bytes().to_vec();
        *dirty.last_mut().unwrap() |= 0xF0;
        let err = PackedCodes::from_bytes(4, 3, dirty).unwrap_err().to_string();
        assert!(err.contains("past the last code"), "{err}");
        // absurd widths
        assert!(PackedCodes::from_bytes(1, 3, vec![0u8; 1]).is_err());
        assert!(PackedCodes::from_bytes(17, 3, vec![0u8; 7]).is_err());
    }

    #[test]
    fn sixteen_bit_codes_degenerate_to_plain_u16() {
        let mut pc = PackedCodes::new(16);
        for c in [0u16, 1, 0xFFFF, 0xBEEF] {
            pc.push(c);
        }
        assert_eq!(pc.byte_len(), 8);
        assert_eq!(pc.iter().collect::<Vec<_>>(), vec![0, 1, 0xFFFF, 0xBEEF]);
    }

    #[test]
    #[should_panic(expected = "does not fit")]
    fn overwide_code_is_a_bug_not_data() {
        let mut pc = PackedCodes::with_len(4, 2);
        pc.set(0, 0x10);
    }

    #[test]
    fn lut_exists_exactly_for_packed_codecs() {
        let fp4 = crate::quant::resolve("fp4_e2m1").unwrap();
        let lut = DequantLut::for_codec(&fp4.codec).expect("fp4 is packed");
        assert_eq!(lut.len(), 16);
        assert_eq!(lut.bits(), 4);
        // spot-check against the canonical decoder, bit-for-bit
        for c in 0..16u16 {
            assert_eq!(lut.decode(c).to_bits(), fp4.codec.decode(c).to_bits(), "code {c}");
        }
        let f32s = crate::quant::resolve("f32").unwrap();
        assert!(DequantLut::for_codec(&f32s.codec).is_none());
    }
}
