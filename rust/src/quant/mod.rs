//! Unified quantization subsystem: one [`QuantScheme`] API for train-time
//! fake-quant, MX snapshots, and serving.
//!
//! Before this layer existed, "format + block scale + rounding" was
//! re-implemented four times (`numerics/fpformat`, `mx/block`,
//! `pqt/gaussws`, `serve/weights`) and kept bit-compatible by convention
//! only. Now:
//!
//! * [`Scheme`] composes a [`Codec`] (FP emulation / symmetric INT / f32
//!   passthrough) × [`crate::numerics::Rounding`] (RNE / toward-zero /
//!   stochastic) × [`Geometry`] (square-blockwise / vector-wise / plain
//!   elementwise cast) behind the [`QuantScheme`] trait
//!   (`quantize`, `quantize_block`, `encode`/`decode`, `scale`,
//!   `bytes_per_elem`).
//! * [`Registry`] resolves string labels (`"bf16"`, `"fp8_e3m4"`,
//!   `"int8_sr"`, …) to scheme instances; the CLI, the TOML config, and the
//!   GWQS snapshot loader all parse labels here and nowhere else.
//! * every consumer — train-time ŵ cast, MX consistency analysis, the
//!   GWQS3 snapshot pack/unpack in `serve::weights` — calls
//!   [`fake_quantize`] / the scheme codec directly (the PR-2 `mx::` shims
//!   are deleted).
//! * [`PackedCodes`] / [`DequantLut`] ([`packing`], PR 8) are the shared
//!   sub-byte storage layer: codes are stored densely at
//!   [`Codec::bits_per_elem`] bits (fp4 = 4 bits, not a padded byte), and
//!   decoding is one 2^bits table lookup. The KV arena (`nn::kv`) and the
//!   GWQS3 store both pack and dequantize through it.
//!
//! A new (format × rounding × geometry) scenario — e.g. stochastic-rounded
//! INT8 direct quantized training, or an FP4 serving store — is one
//! `Registry::register` call, not a four-site change.

pub mod packing;
pub mod registry;
pub mod scheme;

pub use packing::{packed_bytes, DequantLut, GroupIter, PackedCodes};
pub use registry::{labels, resolve, Registry, DEFAULT_BLOCK};
pub use scheme::{
    fake_quantize, po2_scale, tensor_seed, Axis, Codec, Geometry, QuantScheme, Quantized, Scheme,
};
