//! Request/response types for the serving engine, plus a line-oriented JSON
//! wire encoding (one object per line) so load generators and logs can
//! round-trip requests without a schema library.

use crate::util::json::{arr, num, obj, s, Json};
use anyhow::{bail, Context, Result};

/// A generation request.
#[derive(Debug, Clone, PartialEq)]
pub struct GenRequest {
    /// Caller-chosen id, echoed in the response.
    pub id: u64,
    /// Prompt token ids (must be non-empty; serving has no BOS convention).
    pub prompt: Vec<usize>,
    /// Number of tokens to generate (the sequence may stop earlier on EOS).
    pub max_new_tokens: usize,
    /// Softmax temperature; `0.0` means greedy argmax.
    pub temperature: f32,
    /// Top-k truncation for sampling; `0` means the full vocabulary.
    pub top_k: usize,
    /// Per-request sampling seed (ignored when greedy).
    pub seed: u64,
}

impl GenRequest {
    /// A greedy request with default knobs.
    pub fn greedy(id: u64, prompt: Vec<usize>, max_new_tokens: usize) -> GenRequest {
        GenRequest { id, prompt, max_new_tokens, temperature: 0.0, top_k: 0, seed: id }
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("id", num(self.id as f64)),
            ("prompt", arr(self.prompt.iter().map(|&t| num(t as f64)).collect())),
            ("max_new_tokens", num(self.max_new_tokens as f64)),
            ("temperature", num(self.temperature as f64)),
            ("top_k", num(self.top_k as f64)),
            ("seed", num(self.seed as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<GenRequest> {
        let prompt = j
            .get("prompt")
            .as_arr()
            .context("request.prompt must be an array")?
            .iter()
            .map(|v| v.as_usize().context("prompt token must be a number"))
            .collect::<Result<Vec<_>>>()?;
        if prompt.is_empty() {
            bail!("request.prompt must be non-empty");
        }
        Ok(GenRequest {
            id: j.get("id").as_u64().context("request.id")?,
            prompt,
            max_new_tokens: j.get("max_new_tokens").as_usize().unwrap_or(16),
            temperature: j.get("temperature").as_f64().unwrap_or(0.0) as f32,
            top_k: j.get("top_k").as_usize().unwrap_or(0),
            seed: j.get("seed").as_u64().unwrap_or(0),
        })
    }
}

/// Why a sequence stopped.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    /// Hit `max_new_tokens`.
    Length,
    /// Produced the engine's EOS token.
    Eos,
}

impl FinishReason {
    pub fn name(&self) -> &'static str {
        match self {
            FinishReason::Length => "length",
            FinishReason::Eos => "eos",
        }
    }
}

/// A completed generation.
#[derive(Debug, Clone, PartialEq)]
pub struct GenResponse {
    pub id: u64,
    pub prompt_len: usize,
    /// Generated token ids (prompt excluded).
    pub tokens: Vec<usize>,
    pub finish: FinishReason,
    /// Seconds spent queued before the first engine wave touched it.
    pub queue_s: f64,
    /// Seconds from enqueue to the first *generated* token.
    pub ttft_s: f64,
    /// Seconds from enqueue to completion.
    pub total_s: f64,
}

impl GenResponse {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("id", num(self.id as f64)),
            ("prompt_len", num(self.prompt_len as f64)),
            ("tokens", arr(self.tokens.iter().map(|&t| num(t as f64)).collect())),
            ("finish", s(self.finish.name())),
            ("queue_ms", num(self.queue_s * 1e3)),
            ("ttft_ms", num(self.ttft_s * 1e3)),
            ("total_ms", num(self.total_s * 1e3)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_json_roundtrip() {
        let r = GenRequest {
            id: 42,
            prompt: vec![1, 2, 3],
            max_new_tokens: 8,
            temperature: 0.7,
            top_k: 40,
            seed: 99,
        };
        let text = r.to_json().to_string();
        let back = GenRequest::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.id, 42);
        assert_eq!(back.prompt, vec![1, 2, 3]);
        assert_eq!(back.max_new_tokens, 8);
        assert!((back.temperature - 0.7).abs() < 1e-6);
        assert_eq!(back.top_k, 40);
        assert_eq!(back.seed, 99);
    }

    #[test]
    fn request_defaults_fill_in() {
        let j = Json::parse(r#"{"id": 1, "prompt": [5]}"#).unwrap();
        let r = GenRequest::from_json(&j).unwrap();
        assert_eq!(r.max_new_tokens, 16);
        assert_eq!(r.temperature, 0.0);
    }

    #[test]
    fn empty_prompt_rejected() {
        let j = Json::parse(r#"{"id": 1, "prompt": []}"#).unwrap();
        assert!(GenRequest::from_json(&j).is_err());
    }

    #[test]
    fn response_json_has_timing() {
        let r = GenResponse {
            id: 7,
            prompt_len: 3,
            tokens: vec![9, 9],
            finish: FinishReason::Length,
            queue_s: 0.001,
            ttft_s: 0.002,
            total_s: 0.004,
        };
        let j = r.to_json();
        assert_eq!(j.get("finish").as_str(), Some("length"));
        assert!((j.get("ttft_ms").as_f64().unwrap() - 2.0).abs() < 1e-9);
    }
}
