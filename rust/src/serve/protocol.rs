//! Request/response types for the serving engine, plus a line-oriented JSON
//! wire encoding (one object per line) so load generators and logs can
//! round-trip requests without a schema library.
//!
//! Two parse modes: [`GenRequest::from_json`] is lenient (missing knobs
//! default — fine for logs and tests), while the TCP front end
//! ([`crate::serve::net`]) uses [`GenRequest::from_json_strict`], which
//! rejects missing/invalid fields with one per-field error message.
//! Malformed or load-shed requests get a structured [`ErrorResponse`]
//! frame back instead of a dropped connection; [`parse_reply`] classifies
//! reply frames client-side.

use crate::util::json::{arr, num, obj, s, Json};
use anyhow::{bail, Context, Result};

/// A generation request.
#[derive(Debug, Clone, PartialEq)]
pub struct GenRequest {
    /// Caller-chosen id, echoed in the response.
    pub id: u64,
    /// Prompt token ids (must be non-empty; serving has no BOS convention).
    pub prompt: Vec<usize>,
    /// Number of tokens to generate (the sequence may stop earlier on EOS).
    pub max_new_tokens: usize,
    /// Softmax temperature; `0.0` means greedy argmax.
    pub temperature: f32,
    /// Top-k truncation for sampling; `0` means the full vocabulary.
    pub top_k: usize,
    /// Per-request sampling seed (ignored when greedy).
    pub seed: u64,
    /// Optional deadline in milliseconds from enqueue; a request that has
    /// not completed by its deadline finishes with
    /// [`FinishReason::Deadline`] (returning whatever tokens it generated).
    pub deadline_ms: Option<u64>,
}

impl GenRequest {
    /// A greedy request with default knobs.
    pub fn greedy(id: u64, prompt: Vec<usize>, max_new_tokens: usize) -> GenRequest {
        GenRequest {
            id,
            prompt,
            max_new_tokens,
            temperature: 0.0,
            top_k: 0,
            seed: id,
            deadline_ms: None,
        }
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("id", num(self.id as f64)),
            ("prompt", arr(self.prompt.iter().map(|&t| num(t as f64)).collect())),
            ("max_new_tokens", num(self.max_new_tokens as f64)),
            ("temperature", num(self.temperature as f64)),
            ("top_k", num(self.top_k as f64)),
            ("seed", num(self.seed as f64)),
        ];
        if let Some(d) = self.deadline_ms {
            pairs.push(("deadline_ms", num(d as f64)));
        }
        obj(pairs)
    }

    pub fn from_json(j: &Json) -> Result<GenRequest> {
        let prompt = j
            .get("prompt")
            .as_arr()
            .context("request.prompt must be an array")?
            .iter()
            .map(|v| v.as_usize().context("prompt token must be a number"))
            .collect::<Result<Vec<_>>>()?;
        if prompt.is_empty() {
            bail!("request.prompt must be non-empty");
        }
        Ok(GenRequest {
            id: j.get("id").as_u64().context("request.id")?,
            prompt,
            max_new_tokens: j.get("max_new_tokens").as_usize().unwrap_or(16),
            temperature: j.get("temperature").as_f64().unwrap_or(0.0) as f32,
            top_k: j.get("top_k").as_usize().unwrap_or(0),
            seed: j.get("seed").as_u64().unwrap_or(0),
            deadline_ms: j.get("deadline_ms").as_u64(),
        })
    }

    /// Strict wire-mode parse for the TCP path: every field the lenient
    /// [`GenRequest::from_json`] would default is required here, and every
    /// present field must have the right type. All field errors are
    /// collected into one `"field: problem; field: problem"` message so a
    /// client sees the full shape of its mistake in a single error frame.
    pub fn from_json_strict(j: &Json) -> Result<GenRequest> {
        let mut errs: Vec<String> = Vec::new();
        // required fields: missing or mistyped is an error, never a default
        let id = match j.get("id").as_u64() {
            Some(v) => v,
            None => {
                errs.push("id: required, must be a non-negative integer".to_string());
                0
            }
        };
        let max_new_tokens = match j.get("max_new_tokens").as_usize() {
            Some(v) if v > 0 => v,
            Some(_) => {
                errs.push("max_new_tokens: must be > 0".to_string());
                0
            }
            None => {
                errs.push("max_new_tokens: required, must be a positive integer".to_string());
                0
            }
        };
        let prompt: Vec<usize> = match j.get("prompt").as_arr() {
            Some(a) => match a.iter().map(|t| t.as_usize()).collect::<Option<Vec<_>>>() {
                Some(t) if !t.is_empty() => t,
                Some(_) => {
                    errs.push("prompt: must be non-empty".to_string());
                    Vec::new()
                }
                None => {
                    errs.push("prompt: tokens must be non-negative integers".to_string());
                    Vec::new()
                }
            },
            None => {
                errs.push("prompt: required, must be an array of token ids".to_string());
                Vec::new()
            }
        };
        // optional fields: absent is fine, present-but-mistyped is an error
        let opt = |name: &'static str, errs: &mut Vec<String>| -> Option<u64> {
            match j.get(name) {
                Json::Null => None,
                v => match v.as_u64() {
                    Some(x) => Some(x),
                    None => {
                        errs.push(format!("{name}: must be a non-negative integer"));
                        None
                    }
                },
            }
        };
        let top_k = opt("top_k", &mut errs).unwrap_or(0) as usize;
        let seed = opt("seed", &mut errs).unwrap_or(0);
        let deadline_ms = opt("deadline_ms", &mut errs);
        let temperature = match j.get("temperature") {
            Json::Null => 0.0f32,
            v => match v.as_f64() {
                Some(t) if t >= 0.0 => t as f32,
                _ => {
                    errs.push("temperature: must be a number >= 0".to_string());
                    0.0
                }
            },
        };
        if !errs.is_empty() {
            bail!("{}", errs.join("; "));
        }
        Ok(GenRequest { id, prompt, max_new_tokens, temperature, top_k, seed, deadline_ms })
    }
}

/// Why a sequence stopped.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    /// Hit `max_new_tokens`.
    Length,
    /// Produced the engine's EOS token.
    Eos,
    /// Expired its per-request deadline before completing (the response
    /// carries whatever tokens were generated by then).
    Deadline,
}

impl FinishReason {
    pub fn name(&self) -> &'static str {
        match self {
            FinishReason::Length => "length",
            FinishReason::Eos => "eos",
            FinishReason::Deadline => "deadline",
        }
    }

    /// Inverse of [`FinishReason::name`] (wire decoding).
    pub fn from_name(name: &str) -> Result<FinishReason> {
        match name {
            "length" => Ok(FinishReason::Length),
            "eos" => Ok(FinishReason::Eos),
            "deadline" => Ok(FinishReason::Deadline),
            other => bail!("unknown finish reason {other:?}"),
        }
    }
}

/// A completed generation.
#[derive(Debug, Clone, PartialEq)]
pub struct GenResponse {
    pub id: u64,
    pub prompt_len: usize,
    /// Generated token ids (prompt excluded).
    pub tokens: Vec<usize>,
    pub finish: FinishReason,
    /// Seconds spent queued before the first engine wave touched it.
    pub queue_s: f64,
    /// Seconds from enqueue to the first *generated* token.
    pub ttft_s: f64,
    /// Seconds from enqueue to completion.
    pub total_s: f64,
}

impl GenResponse {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("id", num(self.id as f64)),
            ("prompt_len", num(self.prompt_len as f64)),
            ("tokens", arr(self.tokens.iter().map(|&t| num(t as f64)).collect())),
            ("finish", s(self.finish.name())),
            ("queue_ms", num(self.queue_s * 1e3)),
            ("ttft_ms", num(self.ttft_s * 1e3)),
            ("total_ms", num(self.total_s * 1e3)),
        ])
    }

    /// Wire decoding for the TCP client (the inverse of
    /// [`GenResponse::to_json`]; timings come back from milliseconds).
    pub fn from_json(j: &Json) -> Result<GenResponse> {
        let tokens = j
            .get("tokens")
            .as_arr()
            .context("response.tokens must be an array")?
            .iter()
            .map(|v| v.as_usize().context("response token must be a number"))
            .collect::<Result<Vec<_>>>()?;
        Ok(GenResponse {
            id: j.get("id").as_u64().context("response.id")?,
            prompt_len: j.get("prompt_len").as_usize().context("response.prompt_len")?,
            tokens,
            finish: FinishReason::from_name(
                j.get("finish").as_str().context("response.finish")?,
            )?,
            queue_s: j.get("queue_ms").as_f64().context("response.queue_ms")? / 1e3,
            ttft_s: j.get("ttft_ms").as_f64().context("response.ttft_ms")? / 1e3,
            total_s: j.get("total_ms").as_f64().context("response.total_ms")? / 1e3,
        })
    }
}

/// A structured error reply: malformed or rejected requests get this frame
/// instead of a dropped connection. `retry_after_ms` is set when the
/// rejection is load-shedding (arena headroom / queue bound exceeded) and
/// the client should back off and retry; it is absent for permanent errors
/// (parse failures, invalid fields).
#[derive(Debug, Clone, PartialEq)]
pub struct ErrorResponse {
    /// The offending request's id, when one could be parsed out of it.
    pub id: Option<u64>,
    pub error: String,
    pub retry_after_ms: Option<u64>,
}

impl ErrorResponse {
    /// A permanent (non-retryable) error.
    pub fn permanent(id: Option<u64>, error: impl Into<String>) -> ErrorResponse {
        ErrorResponse { id, error: error.into(), retry_after_ms: None }
    }

    /// A load-shedding rejection: retry after `retry_after_ms`.
    pub fn retryable(id: u64, error: impl Into<String>, retry_after_ms: u64) -> ErrorResponse {
        ErrorResponse { id: Some(id), error: error.into(), retry_after_ms: Some(retry_after_ms) }
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = Vec::new();
        if let Some(id) = self.id {
            pairs.push(("id", num(id as f64)));
        }
        pairs.push(("error", s(&self.error)));
        if let Some(ms) = self.retry_after_ms {
            pairs.push(("retry_after_ms", num(ms as f64)));
        }
        obj(pairs)
    }

    pub fn from_json(j: &Json) -> Result<ErrorResponse> {
        Ok(ErrorResponse {
            id: j.get("id").as_u64(),
            error: j.get("error").as_str().context("error frame missing .error")?.to_string(),
            retry_after_ms: j.get("retry_after_ms").as_u64(),
        })
    }
}

/// Classify a decoded reply frame: any object carrying an `"error"` key is
/// an [`ErrorResponse`]; everything else must parse as a [`GenResponse`].
pub fn parse_reply(j: &Json) -> Result<std::result::Result<GenResponse, ErrorResponse>> {
    if !matches!(j.get("error"), Json::Null) {
        Ok(Err(ErrorResponse::from_json(j)?))
    } else {
        Ok(Ok(GenResponse::from_json(j)?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_json_roundtrip() {
        let r = GenRequest {
            id: 42,
            prompt: vec![1, 2, 3],
            max_new_tokens: 8,
            temperature: 0.7,
            top_k: 40,
            seed: 99,
            deadline_ms: Some(250),
        };
        let text = r.to_json().to_string();
        let back = GenRequest::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.id, 42);
        assert_eq!(back.prompt, vec![1, 2, 3]);
        assert_eq!(back.max_new_tokens, 8);
        assert!((back.temperature - 0.7).abs() < 1e-6);
        assert_eq!(back.top_k, 40);
        assert_eq!(back.seed, 99);
        assert_eq!(back.deadline_ms, Some(250));
        // strict parse accepts the same complete frame and agrees
        let strict = GenRequest::from_json_strict(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(strict, back);
    }

    #[test]
    fn deadline_ms_is_optional_on_the_wire() {
        let r = GenRequest::greedy(3, vec![1], 4);
        assert_eq!(r.deadline_ms, None);
        let j = r.to_json();
        assert_eq!(*j.get("deadline_ms"), Json::Null, "absent, not null-emitted");
        assert_eq!(GenRequest::from_json(&j).unwrap().deadline_ms, None);
    }

    #[test]
    fn strict_parse_rejects_missing_fields_with_per_field_errors() {
        // lenient mode defaults these; strict mode must name each problem
        let j = Json::parse(r#"{"prompt": [5]}"#).unwrap();
        let err = GenRequest::from_json_strict(&j).unwrap_err().to_string();
        assert!(err.contains("id:"), "{err}");
        assert!(err.contains("max_new_tokens:"), "{err}");
        assert!(!err.contains("prompt:"), "present fields are not flagged: {err}");
        // mistyped optional field is still an error in strict mode
        let j = Json::parse(r#"{"id": 1, "prompt": [5], "max_new_tokens": 4, "top_k": "many"}"#)
            .unwrap();
        let err = GenRequest::from_json_strict(&j).unwrap_err().to_string();
        assert!(err.contains("top_k:"), "{err}");
        // minimal valid strict frame
        let j = Json::parse(r#"{"id": 1, "prompt": [5], "max_new_tokens": 4}"#).unwrap();
        let r = GenRequest::from_json_strict(&j).unwrap();
        assert_eq!(r.max_new_tokens, 4);
        assert_eq!(r.temperature, 0.0);
        assert_eq!(r.deadline_ms, None);
    }

    #[test]
    fn strict_parse_rejects_zero_max_new_tokens() {
        let j = Json::parse(r#"{"id": 1, "prompt": [5], "max_new_tokens": 0}"#).unwrap();
        let err = GenRequest::from_json_strict(&j).unwrap_err().to_string();
        assert!(err.contains("max_new_tokens: must be > 0"), "{err}");
    }

    #[test]
    fn request_defaults_fill_in() {
        let j = Json::parse(r#"{"id": 1, "prompt": [5]}"#).unwrap();
        let r = GenRequest::from_json(&j).unwrap();
        assert_eq!(r.max_new_tokens, 16);
        assert_eq!(r.temperature, 0.0);
    }

    #[test]
    fn empty_prompt_rejected() {
        let j = Json::parse(r#"{"id": 1, "prompt": []}"#).unwrap();
        assert!(GenRequest::from_json(&j).is_err());
    }

    #[test]
    fn response_json_has_timing() {
        let r = GenResponse {
            id: 7,
            prompt_len: 3,
            tokens: vec![9, 9],
            finish: FinishReason::Length,
            queue_s: 0.001,
            ttft_s: 0.002,
            total_s: 0.004,
        };
        let j = r.to_json();
        assert_eq!(j.get("finish").as_str(), Some("length"));
        assert!((j.get("ttft_ms").as_f64().unwrap() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn response_json_roundtrip() {
        let r = GenResponse {
            id: 11,
            prompt_len: 2,
            tokens: vec![4, 5, 6],
            finish: FinishReason::Deadline,
            queue_s: 0.003,
            ttft_s: 0.005,
            total_s: 0.009,
        };
        let back = GenResponse::from_json(&Json::parse(&r.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back.id, 11);
        assert_eq!(back.tokens, vec![4, 5, 6]);
        assert_eq!(back.finish, FinishReason::Deadline);
        assert!((back.total_s - 0.009).abs() < 1e-9);
    }

    #[test]
    fn finish_reason_names_roundtrip() {
        for f in [FinishReason::Length, FinishReason::Eos, FinishReason::Deadline] {
            assert_eq!(FinishReason::from_name(f.name()).unwrap(), f);
        }
        assert!(FinishReason::from_name("bogus").is_err());
    }

    #[test]
    fn error_frame_roundtrips() {
        let e = ErrorResponse::retryable(9, "arena full", 50);
        let back = ErrorResponse::from_json(&Json::parse(&e.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back, e);
        let p = ErrorResponse::permanent(None, "prompt: must be an array");
        let back = ErrorResponse::from_json(&Json::parse(&p.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back.id, None);
        assert_eq!(back.retry_after_ms, None);
    }

    #[test]
    fn parse_reply_classifies_frames() {
        let ok = GenResponse {
            id: 1,
            prompt_len: 1,
            tokens: vec![2],
            finish: FinishReason::Eos,
            queue_s: 0.0,
            ttft_s: 0.0,
            total_s: 0.0,
        };
        assert!(parse_reply(&ok.to_json()).unwrap().is_ok());
        let err = ErrorResponse::permanent(Some(1), "bad");
        assert!(parse_reply(&err.to_json()).unwrap().is_err());
    }
}
