//! Continuous batching: a FIFO request queue feeding a bounded set of
//! active sequences. Unlike static batching, sequences join and leave the
//! batch *between decode waves* — a finished sequence's KV slot is recycled
//! to the next queued request immediately, so the batch stays full under
//! heterogeneous generation lengths (the property production schedulers
//! like Orca/vLLM exploit).
//!
//! The batcher owns scheduling state only; the decode math lives in the
//! engine, which advances every active sequence by one position per wave
//! (prompt tokens first — prefill — then sampled continuation tokens).

use crate::prng::Philox4x32;
use crate::serve::kvcache::{KvCachePool, SlotId};
use crate::serve::protocol::{FinishReason, GenRequest, GenResponse};
use std::collections::VecDeque;
use std::time::Instant;

/// Sample a next token from a logits row. `temperature <= 0` is greedy
/// argmax; otherwise softmax at that temperature, optionally truncated to
/// the `top_k` most likely tokens.
pub fn sample_logits(logits: &[f32], temperature: f32, top_k: usize, rng: &mut Philox4x32) -> usize {
    assert!(!logits.is_empty());
    if temperature <= 0.0 {
        let mut best = 0;
        for (i, &v) in logits.iter().enumerate() {
            if v > logits[best] {
                best = i;
            }
        }
        return best;
    }
    // candidate set: all tokens, or the top-k by logit
    let mut idx: Vec<usize> = (0..logits.len()).collect();
    if top_k > 0 && top_k < logits.len() {
        idx.sort_by(|&a, &b| logits[b].partial_cmp(&logits[a]).unwrap_or(std::cmp::Ordering::Equal));
        idx.truncate(top_k);
    }
    let inv_t = 1.0 / temperature;
    let mx = idx.iter().map(|&i| logits[i]).fold(f32::NEG_INFINITY, f32::max);
    let weights: Vec<f64> = idx.iter().map(|&i| (((logits[i] - mx) * inv_t) as f64).exp()).collect();
    let total: f64 = weights.iter().sum();
    let mut u = rng.next_f64() * total;
    for (k, &w) in weights.iter().enumerate() {
        u -= w;
        if u <= 0.0 {
            return idx[k];
        }
    }
    *idx.last().unwrap()
}

/// One admitted sequence: request + decode progress + its KV slot.
#[derive(Debug)]
pub struct ActiveSeq {
    pub req: GenRequest,
    pub slot: SlotId,
    pub generated: Vec<usize>,
    /// Prompt tokens fed so far (prefill progress).
    prompt_cursor: usize,
    rng: Philox4x32,
    pub enqueued: Instant,
    pub admitted: Instant,
    pub first_token_at: Option<Instant>,
    pub finish: Option<FinishReason>,
}

impl ActiveSeq {
    fn new(req: GenRequest, slot: SlotId, enqueued: Instant) -> ActiveSeq {
        let rng = Philox4x32::new(req.seed ^ 0x5E2E_F00D);
        ActiveSeq {
            req,
            slot,
            generated: Vec::new(),
            prompt_cursor: 0,
            rng,
            enqueued,
            admitted: Instant::now(),
            first_token_at: None,
            finish: None,
        }
    }

    /// The token to feed at the next decode wave.
    pub fn next_input(&self) -> usize {
        if self.prompt_cursor < self.req.prompt.len() {
            self.req.prompt[self.prompt_cursor]
        } else {
            *self.generated.last().expect("active sequence past prefill has a last token")
        }
    }

    /// Still consuming prompt tokens (the wave after this input is prefill
    /// unless it was the last prompt token)?
    pub fn in_prefill(&self) -> bool {
        self.prompt_cursor < self.req.prompt.len()
    }

    /// Consume the logits the engine produced for [`ActiveSeq::next_input`]:
    /// advance prefill, or sample the next token and check termination.
    pub fn absorb(&mut self, logits: &[f32], eos: Option<usize>) {
        debug_assert!(self.finish.is_none(), "absorbing into a finished sequence");
        if self.prompt_cursor < self.req.prompt.len() {
            self.prompt_cursor += 1;
            if self.prompt_cursor < self.req.prompt.len() {
                return; // mid-prefill: logits predict a token we already have
            }
        }
        let tok = sample_logits(logits, self.req.temperature, self.req.top_k, &mut self.rng);
        if self.first_token_at.is_none() {
            self.first_token_at = Some(Instant::now());
        }
        self.generated.push(tok);
        if eos == Some(tok) {
            self.finish = Some(FinishReason::Eos);
        } else if self.generated.len() >= self.req.max_new_tokens {
            self.finish = Some(FinishReason::Length);
        }
    }

    fn into_response(self, now: Instant) -> GenResponse {
        GenResponse {
            id: self.req.id,
            prompt_len: self.req.prompt.len(),
            tokens: self.generated,
            finish: self.finish.unwrap_or(FinishReason::Length),
            queue_s: self.admitted.duration_since(self.enqueued).as_secs_f64(),
            ttft_s: self
                .first_token_at
                .unwrap_or(now)
                .duration_since(self.enqueued)
                .as_secs_f64(),
            total_s: now.duration_since(self.enqueued).as_secs_f64(),
        }
    }
}

/// The continuous-batching scheduler.
#[derive(Debug)]
pub struct Batcher {
    pub max_batch: usize,
    pending: VecDeque<(GenRequest, Instant)>,
    pub active: Vec<ActiveSeq>,
}

impl Batcher {
    pub fn new(max_batch: usize) -> Batcher {
        assert!(max_batch > 0);
        Batcher { max_batch, pending: VecDeque::new(), active: Vec::new() }
    }

    /// Queue a request (admission happens at the next wave boundary).
    pub fn push(&mut self, req: GenRequest) {
        self.pending.push_back((req, Instant::now()));
    }

    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    pub fn active_len(&self) -> usize {
        self.active.len()
    }

    /// Nothing queued and nothing in flight.
    pub fn is_idle(&self) -> bool {
        self.pending.is_empty() && self.active.is_empty()
    }

    /// Admit queued requests while the batch has room AND the pool has a
    /// free KV slot. Returns the number admitted this boundary.
    pub fn admit(&mut self, pool: &mut KvCachePool) -> usize {
        let mut admitted = 0;
        while self.active.len() < self.max_batch && !self.pending.is_empty() {
            let Some(slot) = pool.try_alloc() else { break };
            let (req, enqueued) = self.pending.pop_front().unwrap();
            self.active.push(ActiveSeq::new(req, slot, enqueued));
            admitted += 1;
        }
        admitted
    }

    /// Remove finished sequences, recycling their KV slots; returns their
    /// responses.
    pub fn retire(&mut self, pool: &mut KvCachePool) -> Vec<GenResponse> {
        let now = Instant::now();
        let mut done = Vec::new();
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].finish.is_some() {
                let seq = self.active.swap_remove(i);
                pool.release(seq.slot);
                done.push(seq.into_response(now));
            } else {
                i += 1;
            }
        }
        done
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::schema::{Arch, ModelConfig};

    fn pool(n: usize) -> KvCachePool {
        KvCachePool::new(&ModelConfig::tiny(Arch::Gpt2), n, 32)
    }

    #[test]
    fn greedy_sampling_is_argmax() {
        let mut rng = Philox4x32::new(1);
        let logits = [0.1f32, 2.0, -1.0, 1.9];
        assert_eq!(sample_logits(&logits, 0.0, 0, &mut rng), 1);
    }

    #[test]
    fn top_k_restricts_support() {
        let mut rng = Philox4x32::new(2);
        let logits = [5.0f32, 4.0, -50.0, -50.0];
        for _ in 0..50 {
            let t = sample_logits(&logits, 1.0, 2, &mut rng);
            assert!(t == 0 || t == 1, "top-2 sample escaped: {t}");
        }
    }

    #[test]
    fn temperature_sampling_is_seed_deterministic() {
        let logits = [0.5f32, 0.4, 0.3, 0.2];
        let mut a = Philox4x32::new(7);
        let mut b = Philox4x32::new(7);
        for _ in 0..20 {
            assert_eq!(
                sample_logits(&logits, 0.8, 0, &mut a),
                sample_logits(&logits, 0.8, 0, &mut b)
            );
        }
    }

    #[test]
    fn admission_respects_batch_and_slots() {
        let mut b = Batcher::new(2);
        let mut p = pool(1);
        for id in 0..3 {
            b.push(GenRequest::greedy(id, vec![1, 2], 4));
        }
        // slot-bound: only one admitted despite max_batch = 2
        assert_eq!(b.admit(&mut p), 1);
        assert_eq!(b.active_len(), 1);
        assert_eq!(b.pending_len(), 2);
        // finish it; retire frees the slot, next admit picks up the queue
        b.active[0].finish = Some(FinishReason::Length);
        let done = b.retire(&mut p);
        assert_eq!(done.len(), 1);
        assert_eq!(b.admit(&mut p), 1);
        assert_eq!(b.pending_len(), 1);
    }

    #[test]
    fn prefill_then_generate_state_machine() {
        let mut seq = ActiveSeq::new(GenRequest::greedy(1, vec![10, 11, 12], 2), 0, Instant::now());
        // feeding prompt: inputs are the prompt tokens in order
        assert_eq!(seq.next_input(), 10);
        seq.absorb(&[0.0, 1.0, 0.0], None); // logits ignored mid-prefill
        assert!(seq.in_prefill());
        assert_eq!(seq.next_input(), 11);
        seq.absorb(&[0.0, 1.0, 0.0], None);
        assert_eq!(seq.next_input(), 12);
        // last prompt token: its logits produce the first generated token
        seq.absorb(&[0.0, 0.0, 5.0], None);
        assert_eq!(seq.generated, vec![2]);
        assert!(seq.first_token_at.is_some());
        assert!(seq.finish.is_none());
        assert_eq!(seq.next_input(), 2);
        seq.absorb(&[9.0, 0.0, 0.0], None);
        assert_eq!(seq.generated, vec![2, 0]);
        assert_eq!(seq.finish, Some(FinishReason::Length));
    }

    #[test]
    fn eos_stops_generation() {
        let mut seq = ActiveSeq::new(GenRequest::greedy(1, vec![3], 10), 0, Instant::now());
        seq.absorb(&[0.0, 7.0, 0.0], Some(1));
        assert_eq!(seq.finish, Some(FinishReason::Eos));
        assert_eq!(seq.generated, vec![1]);
    }
}
