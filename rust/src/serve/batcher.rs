//! Budget-aware continuous-batching scheduler: a FIFO request queue
//! feeding a bounded set of active sequences, with admission gated on
//! *free arena blocks* (not slots), chunked prefill interleaved with
//! decode waves, cross-request prefix adoption at admission, and
//! preemption of the newest sequence back to the queue when the block
//! arena runs dry.
//!
//! Scheduling state machine per sequence:
//!
//! ```text
//!   pending ──admit──▶ prefill ──chunks──▶ decode ──EOS/len──▶ retired
//!      ▲                  │                   │                  │
//!      └──── preempt ◀────┴───────────────────┘        prompt chain
//!        (blocks freed,                            published to the
//!         tokens retained,                           prefix index
//!         re-prefilled later)
//! ```
//!
//! The scheduler owns ordering and lifecycle only; block accounting lives
//! in [`BlockAllocator`] and the decode math in the engine, which
//! advances every active sequence by its planned chunk each wave.

use crate::config::schema::ModelConfig;
use crate::prng::Philox4x32;
use crate::serve::kvcache::BlockAllocator;
use crate::serve::protocol::{FinishReason, GenRequest, GenResponse};
use crate::serve::stats::ServeStats;
use crate::util::json::{num, s, Json};
use std::collections::VecDeque;
use std::time::Instant;

/// Sample a next token from a logits row. `temperature <= 0` is greedy
/// argmax; otherwise softmax at that temperature, optionally truncated to
/// the `top_k` most likely tokens.
pub fn sample_logits(logits: &[f32], temperature: f32, top_k: usize, rng: &mut Philox4x32) -> usize {
    assert!(!logits.is_empty());
    if temperature <= 0.0 {
        let mut best = 0;
        for (i, &v) in logits.iter().enumerate() {
            if v > logits[best] {
                best = i;
            }
        }
        return best;
    }
    // candidate set: all tokens, or the top-k by logit
    let mut idx: Vec<usize> = (0..logits.len()).collect();
    if top_k > 0 && top_k < logits.len() {
        idx.sort_by(|&a, &b| logits[b].partial_cmp(&logits[a]).unwrap_or(std::cmp::Ordering::Equal));
        idx.truncate(top_k);
    }
    let inv_t = 1.0 / temperature;
    let mx = idx.iter().map(|&i| logits[i]).fold(f32::NEG_INFINITY, f32::max);
    let weights: Vec<f64> = idx.iter().map(|&i| (((logits[i] - mx) * inv_t) as f64).exp()).collect();
    let total: f64 = weights.iter().sum();
    let mut u = rng.next_f64() * total;
    for (k, &w) in weights.iter().enumerate() {
        u -= w;
        if u <= 0.0 {
            return idx[k];
        }
    }
    *idx.last().unwrap()
}

/// One speculative round planned for a sequence: the engine forks the
/// sequence's KV chain (shared blocks, zero copies), drafts up to `k`
/// tokens through the low-bit draft store into `draft_kv`, then verifies
/// them in one all-rows chunk through the target store. The wave worker
/// fills in the outcome fields; the planner thread rolls the target cache
/// back to `commit_len` and releases the fork afterwards — so a plan left
/// attached at preemption/expiry/retirement is simply drained there,
/// keeping the arena leak-free no matter where the round was abandoned.
#[derive(Debug)]
pub struct SpecPlan {
    /// The CoW fork the draft tokens decode into (shares the parent's
    /// committed blocks; its own appends copy-on-write).
    pub draft_kv: crate::nn::kv::PagedKv,
    /// Draft tokens to produce this round (already capped by budget,
    /// `max_new_tokens` headroom, and sequence length).
    pub k: usize,
    /// Committed target length when the round was planned.
    pub base_len: usize,
    /// Draft tokens actually produced (== `k` unless the draft hit EOS
    /// territory — drafting never stops early today, so == `k`).
    pub drafted: usize,
    /// Drafts confirmed by exact greedy match against the target logits.
    pub accepted: usize,
    /// Target length the planner must roll back to after the wave
    /// (`base_len` + tokens the verify pass absorbed).
    pub commit_len: usize,
}

/// One admitted sequence: request + decode progress + its paged KV chain.
///
/// The *feed stream* of a sequence is `prompt ++ generated` — every token
/// that must pass through the model (each generated token except the very
/// last is fed back to produce the next). `kv.len()` is the cursor into
/// that stream: positions already cached. Prefill is simply the state
/// where the cursor trails the stream by more than one (also true while
/// re-prefilling after a preemption, when `generated` is non-empty).
#[derive(Debug)]
pub struct ActiveSeq {
    pub req: GenRequest,
    /// The sequence's paged KV chain (empty while preempted).
    pub kv: crate::nn::kv::PagedKv,
    pub generated: Vec<usize>,
    rng: Philox4x32,
    pub enqueued: Instant,
    pub admitted: Instant,
    pub first_token_at: Option<Instant>,
    pub finish: Option<FinishReason>,
    /// Admission order stamp (re-stamped on re-admission); the preemption
    /// victim is always the sequence with the highest stamp.
    pub seq_no: u64,
    /// The speculative round in flight for this wave, if the engine
    /// planned one (greedy steady-state decode only).
    pub spec: Option<SpecPlan>,
}

impl ActiveSeq {
    fn new(req: GenRequest, kv: crate::nn::kv::PagedKv, enqueued: Instant) -> ActiveSeq {
        let rng = Philox4x32::new(req.seed ^ 0x5E2E_F00D);
        ActiveSeq {
            req,
            kv,
            generated: Vec::new(),
            rng,
            enqueued,
            admitted: Instant::now(),
            first_token_at: None,
            finish: None,
            seq_no: 0,
            spec: None,
        }
    }

    /// Length of the feed stream (`prompt ++ generated`).
    pub fn stream_len(&self) -> usize {
        self.req.prompt.len() + self.generated.len()
    }

    /// The feed stream materialized (prefix-index lookups hash it).
    pub fn stream_tokens(&self) -> Vec<usize> {
        self.req.prompt.iter().chain(self.generated.iter()).copied().collect()
    }

    /// Token at feed-stream position `p`.
    fn feed_token(&self, p: usize) -> usize {
        if p < self.req.prompt.len() {
            self.req.prompt[p]
        } else {
            self.generated[p - self.req.prompt.len()]
        }
    }

    /// Tokens this sequence wants to feed next, capped at `prefill_chunk`
    /// per wave. In steady-state decode this is exactly one token.
    pub fn next_chunk_len(&self, prefill_chunk: usize) -> usize {
        use crate::nn::kv::KvStorage;
        (self.stream_len() - self.kv.len()).min(prefill_chunk.max(1))
    }

    /// The next `n` feed-stream tokens (n from [`ActiveSeq::next_chunk_len`]).
    pub fn next_tokens(&self, n: usize) -> Vec<usize> {
        use crate::nn::kv::KvStorage;
        let start = self.kv.len();
        (start..start + n).map(|p| self.feed_token(p)).collect()
    }

    /// Still catching the cache up to the feed stream (true during initial
    /// prefill and during re-prefill after a preemption)?
    pub fn in_prefill(&self) -> bool {
        use crate::nn::kv::KvStorage;
        self.kv.len() + 1 < self.stream_len()
    }

    /// Consume the last-position logits of the chunk the engine just ran
    /// (`kv` already committed): mid-prefill they are discarded; once the
    /// cache has caught up to the stream, sample the next token and check
    /// termination.
    pub fn absorb(&mut self, logits: &[f32], eos: Option<usize>) {
        use crate::nn::kv::KvStorage;
        debug_assert!(self.finish.is_none(), "absorbing into a finished sequence");
        if self.kv.len() < self.stream_len() {
            return; // mid-prefill: logits predict a token we already have
        }
        let tok = sample_logits(logits, self.req.temperature, self.req.top_k, &mut self.rng);
        if self.first_token_at.is_none() {
            self.first_token_at = Some(Instant::now());
        }
        self.generated.push(tok);
        if eos == Some(tok) {
            self.finish = Some(FinishReason::Eos);
        } else if self.generated.len() >= self.req.max_new_tokens {
            self.finish = Some(FinishReason::Length);
        }
    }

    fn into_response(self, now: Instant) -> GenResponse {
        GenResponse {
            id: self.req.id,
            prompt_len: self.req.prompt.len(),
            tokens: self.generated,
            finish: self.finish.unwrap_or(FinishReason::Length),
            queue_s: self.admitted.duration_since(self.enqueued).as_secs_f64(),
            ttft_s: self
                .first_token_at
                .unwrap_or(now)
                .duration_since(self.enqueued)
                .as_secs_f64(),
            total_s: now.duration_since(self.enqueued).as_secs_f64(),
        }
    }
}

/// Release a sequence's in-flight draft fork, if any — called wherever a
/// sequence leaves the active set (preemption, deadline expiry,
/// retirement) so an abandoned speculative round can never strand blocks.
fn drain_spec(seq: &mut ActiveSeq, alloc: &mut BlockAllocator) {
    if let Some(plan) = seq.spec.take() {
        alloc.release_fork(plan.draft_kv).expect("abandoned draft fork chain was live");
    }
}

/// The budget-aware continuous-batching scheduler.
#[derive(Debug)]
pub struct Scheduler {
    pub max_batch: usize,
    /// Max prompt tokens fed per sequence per wave.
    pub prefill_chunk: usize,
    /// Cross-request prompt-prefix sharing on admission/retirement.
    pub prefix_cache: bool,
    pending: VecDeque<(GenRequest, Instant)>,
    /// Preempted sequences await re-admission ahead of fresh requests,
    /// in preemption order.
    preempted: VecDeque<ActiveSeq>,
    /// Active set in admission order (the last element is the newest —
    /// the preemption victim).
    pub active: Vec<ActiveSeq>,
    next_seq_no: u64,
}

impl Scheduler {
    pub fn new(max_batch: usize, prefill_chunk: usize, prefix_cache: bool) -> Scheduler {
        assert!(max_batch > 0);
        assert!(prefill_chunk > 0, "prefill chunk must be positive");
        Scheduler {
            max_batch,
            prefill_chunk,
            prefix_cache,
            pending: VecDeque::new(),
            preempted: VecDeque::new(),
            active: Vec::new(),
            next_seq_no: 0,
        }
    }

    /// Queue a request (admission happens at the next wave boundary).
    pub fn push(&mut self, req: GenRequest) {
        self.pending.push_back((req, Instant::now()));
    }

    pub fn pending_len(&self) -> usize {
        self.pending.len() + self.preempted.len()
    }

    pub fn active_len(&self) -> usize {
        self.active.len()
    }

    /// Nothing queued, nothing preempted, nothing in flight.
    pub fn is_idle(&self) -> bool {
        self.pending.is_empty() && self.preempted.is_empty() && self.active.is_empty()
    }

    /// Admit sequences while the batch has room AND the arena has enough
    /// free blocks for each sequence's first chunk. Preempted sequences
    /// re-admit ahead of fresh requests. Admission adopts the longest
    /// cached prompt prefix when the prefix cache is enabled. Returns the
    /// number admitted this boundary.
    pub fn admit(
        &mut self,
        cfg: &ModelConfig,
        capacity: usize,
        alloc: &mut BlockAllocator,
        stats: &mut ServeStats,
    ) -> usize {
        let mut admitted = 0;
        while self.active.len() < self.max_batch {
            let (mut seq, from_preempted) = if let Some(s) = self.preempted.pop_front() {
                (s, true)
            } else if let Some((req, enqueued)) = self.pending.pop_front() {
                (ActiveSeq::new(req, alloc.new_seq(cfg, capacity), enqueued), false)
            } else {
                break;
            };
            let live_before = alloc.live_blocks();
            // prefix adoption: reuse the longest cached prefix of the feed
            // stream (for re-admissions that includes generated tokens)
            let mut reused = 0usize;
            if self.prefix_cache {
                let stream = seq.stream_tokens();
                if let Some((chain, n)) = alloc.prefix_lookup(&stream) {
                    seq.kv.adopt_prefix(&chain, n);
                    reused = n;
                    // the lookup's retain now belongs to the sequence; the
                    // local clones just go away
                    drop(chain);
                }
                // counted at the lookup site (not at admission success) so
                // hits + misses == lookups holds even when this admission
                // later bounces off a dry arena
                stats.record_prefix_lookup(reused);
            }
            // admission by free blocks: reserve the first chunk's blocks up
            // front (including a possible copy-on-write of an adopted
            // partial tail), so each admission genuinely shrinks the budget
            loop {
                let chunk = seq.next_chunk_len(self.prefill_chunk);
                if alloc.reserve(&mut seq.kv, chunk) {
                    seq.seq_no = self.next_seq_no;
                    self.next_seq_no += 1;
                    stats.record_admission(if self.prefix_cache { Some(reused) } else { None });
                    if let Some(t) = stats.trace_mut() {
                        // reserve delta, not absolute: LRU evictions during
                        // the loop can shrink the live count concurrently
                        let delta = alloc.live_blocks() as i64 - live_before as i64;
                        t.begin(
                            "resident",
                            seq.req.id,
                            vec![
                                (
                                    "prefix",
                                    s(if !self.prefix_cache {
                                        "off"
                                    } else if reused > 0 {
                                        "hit"
                                    } else {
                                        "miss"
                                    }),
                                ),
                                ("reused", num(reused as f64)),
                                ("readmit", Json::Bool(from_preempted)),
                                ("blocks_reserved", num(delta as f64)),
                            ],
                        );
                    }
                    self.active.push(seq);
                    admitted += 1;
                    break;
                }
                // arena dry: reclaim cached prefixes; if the index is empty
                // too, put the sequence back and stop admitting
                if alloc.prefix_evict_lru() {
                    continue;
                }
                alloc
                    .release_chain(seq.kv.take_blocks())
                    .expect("un-admitted sequence chain was live");
                if from_preempted {
                    self.preempted.push_front(seq);
                } else {
                    self.pending.push_front((seq.req, seq.enqueued));
                }
                return admitted;
            }
        }
        admitted
    }

    /// Preempt the newest active sequence: its blocks are freed, its
    /// tokens retained for a later re-prefill, and it rejoins the queue
    /// ahead of fresh requests. Returns the index it held in `active`, or
    /// `None` if the active set is empty.
    pub fn preempt_newest(
        &mut self,
        alloc: &mut BlockAllocator,
        stats: &mut ServeStats,
    ) -> Option<usize> {
        let idx = self
            .active
            .iter()
            .enumerate()
            .max_by_key(|(_, s)| s.seq_no)
            .map(|(i, _)| i)?;
        let mut seq = self.active.remove(idx);
        drain_spec(&mut seq, alloc);
        let chain = seq.kv.take_blocks();
        let released = chain.len();
        alloc.release_chain(chain).expect("preempted sequence chain was live");
        stats.record_preemption();
        if let Some(t) = stats.trace_mut() {
            t.end(
                "resident",
                seq.req.id,
                vec![("reason", s("preempt")), ("blocks_released", num(released as f64))],
            );
            t.instant("preempt", seq.req.id, vec![]);
        }
        self.preempted.push_back(seq);
        Some(idx)
    }

    /// Expire requests whose per-request deadline has passed, wherever
    /// they sit in the lifecycle: queued requests finish empty-handed,
    /// preempted and active sequences finish with whatever they generated
    /// so far (an active victim's blocks are released; nothing is
    /// published to the prefix index — a canceled sequence's prefix is not
    /// a prefix anyone asked to cache). Every expiry is recorded through
    /// [`ServeStats::record_deadline`], which closes the request's trace
    /// spans (the "resident" span only for sequences that were active).
    pub fn expire_deadlines(
        &mut self,
        alloc: &mut BlockAllocator,
        stats: &mut ServeStats,
    ) -> Vec<GenResponse> {
        let now = Instant::now();
        let due = |req: &GenRequest, enqueued: Instant| -> bool {
            req.deadline_ms
                .map(|d| now.duration_since(enqueued).as_millis() as u64 >= d)
                .unwrap_or(false)
        };
        let mut out = Vec::new();
        // queued: never admitted, nothing generated, no blocks held
        let mut i = 0;
        while i < self.pending.len() {
            if due(&self.pending[i].0, self.pending[i].1) {
                let (req, enqueued) = self.pending.remove(i).expect("index checked");
                let waited = now.duration_since(enqueued).as_secs_f64();
                let resp = GenResponse {
                    id: req.id,
                    prompt_len: req.prompt.len(),
                    tokens: Vec::new(),
                    finish: FinishReason::Deadline,
                    queue_s: waited,
                    ttft_s: waited,
                    total_s: waited,
                };
                stats.record_deadline(&resp, false);
                out.push(resp);
            } else {
                i += 1;
            }
        }
        // preempted: blocks were already released at preemption
        let mut i = 0;
        while i < self.preempted.len() {
            if due(&self.preempted[i].req, self.preempted[i].enqueued) {
                let mut seq = self.preempted.remove(i).expect("index checked");
                seq.finish = Some(FinishReason::Deadline);
                let resp = seq.into_response(now);
                stats.record_deadline(&resp, false);
                out.push(resp);
            } else {
                i += 1;
            }
        }
        // active: release the chain mid-flight
        let mut i = 0;
        while i < self.active.len() {
            if due(&self.active[i].req, self.active[i].enqueued) {
                let mut seq = self.active.remove(i);
                drain_spec(&mut seq, alloc);
                alloc
                    .release_chain(seq.kv.take_blocks())
                    .expect("expired sequence chain was live");
                seq.finish = Some(FinishReason::Deadline);
                let resp = seq.into_response(now);
                stats.record_deadline(&resp, true);
                out.push(resp);
            } else {
                i += 1;
            }
        }
        out
    }

    /// Remove finished sequences, publishing their prompt chains to the
    /// prefix index and releasing their blocks; returns their responses.
    pub fn retire(&mut self, alloc: &mut BlockAllocator) -> Vec<GenResponse> {
        let now = Instant::now();
        let mut done = Vec::new();
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].finish.is_some() {
                // `remove` (not swap_remove) keeps admission order intact,
                // so `active.last()` stays the newest sequence
                let mut seq = self.active.remove(i);
                drain_spec(&mut seq, alloc);
                if self.prefix_cache {
                    alloc.prefix_insert(&seq.req.prompt, &seq.kv);
                }
                alloc.release_chain(seq.kv.take_blocks()).expect("retired sequence chain was live");
                done.push(seq.into_response(now));
            } else {
                i += 1;
            }
        }
        done
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::schema::{Arch, ModelConfig};
    use crate::nn::kv::{KvStorage, PagedKv};

    fn cfg() -> ModelConfig {
        ModelConfig::tiny(Arch::Gpt2)
    }

    fn arena(n_blocks: usize) -> BlockAllocator {
        BlockAllocator::new(&cfg(), n_blocks, 4)
    }

    fn seq(req: GenRequest) -> ActiveSeq {
        ActiveSeq::new(req, PagedKv::new(&cfg(), 4, 64), Instant::now())
    }

    /// Simulate the engine's side of a wave: commit `n` fed positions.
    fn feed(s: &mut ActiveSeq, n: usize) {
        let c = cfg();
        let row = vec![0.0f32; c.d_model];
        for _ in 0..n {
            let pos = s.kv.len();
            for l in 0..c.n_layer {
                s.kv.write(l, pos, &row, &row);
            }
            s.kv.commit(1);
        }
    }

    #[test]
    fn greedy_sampling_is_argmax() {
        let mut rng = Philox4x32::new(1);
        let logits = [0.1f32, 2.0, -1.0, 1.9];
        assert_eq!(sample_logits(&logits, 0.0, 0, &mut rng), 1);
    }

    #[test]
    fn top_k_restricts_support() {
        let mut rng = Philox4x32::new(2);
        let logits = [5.0f32, 4.0, -50.0, -50.0];
        for _ in 0..50 {
            let t = sample_logits(&logits, 1.0, 2, &mut rng);
            assert!(t == 0 || t == 1, "top-2 sample escaped: {t}");
        }
    }

    #[test]
    fn temperature_sampling_is_seed_deterministic() {
        let logits = [0.5f32, 0.4, 0.3, 0.2];
        let mut a = Philox4x32::new(7);
        let mut b = Philox4x32::new(7);
        for _ in 0..20 {
            assert_eq!(
                sample_logits(&logits, 0.8, 0, &mut a),
                sample_logits(&logits, 0.8, 0, &mut b)
            );
        }
    }

    #[test]
    fn chunked_prefill_then_generate_state_machine() {
        let mut s = seq(GenRequest::greedy(1, vec![10, 11, 12, 13, 14], 2));
        // first wave: a chunk of 3 prompt tokens
        assert_eq!(s.next_chunk_len(3), 3);
        assert_eq!(s.next_tokens(3), vec![10, 11, 12]);
        feed(&mut s, 3);
        s.absorb(&[0.0, 1.0, 0.0], None); // mid-prefill: logits ignored
        assert!(s.in_prefill());
        assert!(s.generated.is_empty());
        // second wave: the remaining 2 prompt tokens finish prefill
        assert_eq!(s.next_chunk_len(3), 2);
        assert_eq!(s.next_tokens(2), vec![13, 14]);
        feed(&mut s, 2);
        s.absorb(&[0.0, 0.0, 5.0], None); // caught up: sample
        assert_eq!(s.generated, vec![2]);
        assert!(s.first_token_at.is_some());
        assert!(s.finish.is_none());
        assert!(!s.in_prefill());
        // steady-state decode: exactly one token per wave
        assert_eq!(s.next_chunk_len(3), 1);
        assert_eq!(s.next_tokens(1), vec![2]);
        feed(&mut s, 1);
        s.absorb(&[9.0, 0.0, 0.0], None);
        assert_eq!(s.generated, vec![2, 0]);
        assert_eq!(s.finish, Some(FinishReason::Length));
    }

    #[test]
    fn eos_stops_generation() {
        let mut s = seq(GenRequest::greedy(1, vec![3], 10));
        feed(&mut s, 1);
        s.absorb(&[0.0, 7.0, 0.0], Some(1));
        assert_eq!(s.finish, Some(FinishReason::Eos));
        assert_eq!(s.generated, vec![1]);
    }

    #[test]
    fn admission_is_block_bound_not_slot_bound() {
        let c = cfg();
        let mut stats = ServeStats::new();
        // 2 blocks of 4 positions; prompts of 5 need 2 blocks each
        let mut alloc = arena(2);
        let mut sched = Scheduler::new(4, 8, false);
        for id in 0..3 {
            sched.push(GenRequest::greedy(id, vec![1, 2, 3, 4, 5], 2));
        }
        // block-bound: only one admitted despite max_batch = 4 (admission
        // reserved its first chunk's blocks, draining the arena)
        assert_eq!(sched.admit(&c, 64, &mut alloc, &mut stats), 1);
        assert_eq!(sched.active_len(), 1);
        assert_eq!(sched.pending_len(), 2);
        assert_eq!(alloc.free_blocks(), 0);
        // finish it; retire frees both blocks, next admit takes the queue
        sched.active[0].finish = Some(FinishReason::Length);
        let done = sched.retire(&mut alloc);
        assert_eq!(done.len(), 1);
        assert_eq!(alloc.free_blocks(), 2);
        assert_eq!(sched.admit(&c, 64, &mut alloc, &mut stats), 1);
        assert_eq!(sched.pending_len(), 1);
        assert_eq!(stats.admissions(), 2);
    }

    #[test]
    fn preemption_releases_blocks_and_requeues() {
        let c = cfg();
        let mut stats = ServeStats::new();
        let mut alloc = arena(4);
        let mut sched = Scheduler::new(4, 8, false);
        sched.push(GenRequest::greedy(0, vec![1, 2, 3], 4));
        sched.push(GenRequest::greedy(1, vec![4, 5, 6], 4));
        assert_eq!(sched.admit(&c, 64, &mut alloc, &mut stats), 2);
        assert!(alloc.reserve(&mut sched.active[0].kv, 3));
        assert!(alloc.reserve(&mut sched.active[1].kv, 3));
        feed(&mut sched.active[0], 3);
        feed(&mut sched.active[1], 3);
        sched.active[1].absorb(&[0.0, 1.0], None); // seq 1 samples a token
        let live_before = alloc.live_blocks();
        let idx = sched.preempt_newest(&mut alloc, &mut stats).unwrap();
        assert_eq!(idx, 1, "victim is the newest admission");
        assert_eq!(sched.active_len(), 1);
        assert_eq!(sched.pending_len(), 1, "victim waits for re-admission");
        assert!(alloc.live_blocks() < live_before);
        assert_eq!(stats.preemptions(), 1);
        // re-admission keeps its progress: stream = prompt ++ generated
        assert_eq!(sched.admit(&c, 64, &mut alloc, &mut stats), 1);
        let re = sched.active.last().unwrap();
        assert_eq!(re.req.id, 1);
        assert_eq!(re.generated, vec![1]);
        assert_eq!(re.kv.len(), 0, "re-prefills from scratch");
        assert_eq!(re.next_chunk_len(8), 4, "prompt(3) + generated(1) to re-feed");
    }

    #[test]
    fn deadline_expiry_sweeps_queued_and_active() {
        let c = cfg();
        let mut stats = ServeStats::new();
        let mut alloc = arena(8);
        let mut sched = Scheduler::new(2, 8, false);
        let with_deadline = |id: u64, ms: u64| {
            let mut r = GenRequest::greedy(id, vec![1, 2, 3], 4);
            r.deadline_ms = Some(ms);
            r
        };
        sched.push(with_deadline(1, 0));
        sched.push(GenRequest::greedy(2, vec![4, 5, 6], 4));
        assert_eq!(sched.admit(&c, 64, &mut alloc, &mut stats), 2);
        sched.push(with_deadline(0, 0)); // queued behind the full batch
        let live_before = alloc.live_blocks();
        let mut done = sched.expire_deadlines(&mut alloc, &mut stats);
        done.sort_by_key(|r| r.id);
        assert_eq!(done.len(), 2);
        assert_eq!(done[0].id, 0, "queued request expired");
        assert_eq!(done[0].finish, FinishReason::Deadline);
        assert!(done[0].tokens.is_empty());
        assert_eq!(done[1].id, 1, "active sequence expired");
        assert_eq!(done[1].finish, FinishReason::Deadline);
        assert_eq!(sched.active_len(), 1, "the deadline-free sequence survives");
        assert_eq!(sched.pending_len(), 0);
        assert!(alloc.live_blocks() < live_before, "expired active blocks released");
        assert_eq!(stats.deadline_expired(), 2);
        // no deadline or a future deadline: the sweep is a no-op
        assert!(sched.expire_deadlines(&mut alloc, &mut stats).is_empty());
        assert_eq!(sched.active_len(), 1);
    }

    #[test]
    fn admission_adopts_cached_prefix() {
        let c = cfg();
        let mut stats = ServeStats::new();
        let mut alloc = arena(8);
        let mut sched = Scheduler::new(4, 8, true);
        let prompt: Vec<usize> = (1..=10).collect();
        // run one sequence to retirement so its prompt chain is published
        sched.push(GenRequest::greedy(0, prompt.clone(), 1));
        assert_eq!(sched.admit(&c, 64, &mut alloc, &mut stats), 1);
        assert!(alloc.reserve(&mut sched.active[0].kv, 10));
        feed(&mut sched.active[0], 10);
        sched.active[0].absorb(&[1.0, 0.0], None);
        assert!(sched.active[0].finish.is_some());
        sched.retire(&mut alloc);
        assert!(alloc.prefix_stats().entries > 0);
        // an identical prompt admits with most of its prefill skipped
        sched.push(GenRequest::greedy(1, prompt.clone(), 1));
        assert_eq!(sched.admit(&c, 64, &mut alloc, &mut stats), 1);
        assert_eq!(stats.prefix_hits(), 1);
        assert_eq!(stats.prefix_lookups(), stats.prefix_hits() + stats.prefix_misses());
        let re = sched.active.last().unwrap();
        assert_eq!(re.kv.len(), 8, "block-aligned prefix of 10-1 positions");
        assert_eq!(re.next_chunk_len(8), 2, "only the unshared tail re-feeds");
        assert_eq!(stats.prefix_tokens_reused(), 8);
    }
}
