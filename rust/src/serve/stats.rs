//! Serving-side accounting: per-request latency (queue / TTFT / total),
//! generated-token throughput, and per-wave batch occupancy. This is the
//! first latency-oriented metrics surface in the codebase — the training
//! loop reports tokens/step, serving reports p50/p95 and tokens/sec.

use crate::serve::protocol::GenResponse;
use crate::util::json::{num, obj, s, Json};
use crate::util::stats::percentile;
use std::time::Instant;

/// Aggregated serving statistics.
#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    /// Completed requests.
    pub completed: usize,
    /// Prompt tokens consumed (prefill work).
    pub prompt_tokens: usize,
    /// Tokens generated (decode work the caller actually received).
    pub gen_tokens: usize,
    /// Decode waves executed.
    pub waves: usize,
    /// Sequences admitted into the active batch (re-admissions after
    /// preemption count again).
    pub admissions: usize,
    /// Multi-token prefill chunks executed (waves where a sequence
    /// advanced by more than one position).
    pub prefill_chunks: usize,
    /// Prompt positions fed through multi-token chunks.
    pub prefill_chunk_tokens: usize,
    /// Admissions that adopted a cached prompt-prefix chain.
    pub prefix_hits: usize,
    /// Admissions that looked up the prefix index and missed.
    pub prefix_misses: usize,
    /// KV positions skipped (neither recomputed nor re-stored) thanks to
    /// prefix reuse.
    pub prefix_tokens_reused: usize,
    /// Sequences pushed back to the queue because the arena ran dry.
    pub preemptions: usize,
    /// Arena block budget (set once by the engine).
    pub kv_blocks_total: usize,
    /// Canonical label of the KV row-storage scheme (`"f32"`, `"fp8_e3m4"`,
    /// …; set once by the engine).
    pub kv_store: String,
    /// Encoded bytes one cached position costs under the KV scheme
    /// (packed codes + per-group scales, or raw f32 for passthrough).
    pub kv_bytes_per_position: usize,
    /// Resident bytes of the arena budget (includes the emulation's f32
    /// decode mirror for quantized schemes).
    pub kv_arena_bytes: usize,
    /// Encoded bytes of the arena budget — what a deployment layout
    /// storing only codes + scales would cost.
    pub kv_arena_encoded_bytes: usize,
    /// Sequences advanced per wave (the continuous-batching occupancy).
    occupancy: Vec<usize>,
    /// Live arena blocks sampled per wave.
    block_live: Vec<usize>,
    total_s: Vec<f64>,
    ttft_s: Vec<f64>,
    queue_s: Vec<f64>,
    /// Wall-clock span from the first wave to the last completion.
    first_wave: Option<Instant>,
    last_done: Option<Instant>,
}

impl ServeStats {
    pub fn new() -> ServeStats {
        ServeStats::default()
    }

    /// Record one decode wave that advanced `n_seqs` sequences.
    pub fn record_wave(&mut self, n_seqs: usize) {
        if n_seqs == 0 {
            return;
        }
        self.waves += 1;
        self.occupancy.push(n_seqs);
        if self.first_wave.is_none() {
            self.first_wave = Some(Instant::now());
        }
    }

    /// Sample the arena's live-block count for the current wave.
    pub fn record_blocks(&mut self, live: usize, total: usize) {
        self.kv_blocks_total = total;
        self.block_live.push(live);
    }

    /// Record one multi-token prefill chunk of `tokens` positions.
    pub fn record_prefill_chunk(&mut self, tokens: usize) {
        self.prefill_chunks += 1;
        self.prefill_chunk_tokens += tokens;
    }

    /// Record an admission; `reused` is the prefix positions adopted from
    /// the prefix index (`None` when the prefix cache is disabled).
    pub fn record_admission(&mut self, reused: Option<usize>) {
        self.admissions += 1;
        match reused {
            Some(0) => self.prefix_misses += 1,
            Some(n) => {
                self.prefix_hits += 1;
                self.prefix_tokens_reused += n;
            }
            None => {}
        }
    }

    pub fn record_preemption(&mut self) {
        self.preemptions += 1;
    }

    /// Record the KV row-storage scheme and its byte accounting (set once
    /// by the engine at construction).
    pub fn set_kv_store(
        &mut self,
        label: &str,
        bytes_per_position: usize,
        arena_bytes: usize,
        arena_encoded_bytes: usize,
    ) {
        self.kv_store = label.to_string();
        self.kv_bytes_per_position = bytes_per_position;
        self.kv_arena_bytes = arena_bytes;
        self.kv_arena_encoded_bytes = arena_encoded_bytes;
    }

    /// Fraction of prefix-index lookups that found a reusable chain.
    pub fn prefix_hit_rate(&self) -> f64 {
        let lookups = self.prefix_hits + self.prefix_misses;
        if lookups == 0 {
            0.0
        } else {
            self.prefix_hits as f64 / lookups as f64
        }
    }

    /// Mean live arena blocks per wave.
    pub fn mean_blocks_live(&self) -> f64 {
        if self.block_live.is_empty() {
            return 0.0;
        }
        self.block_live.iter().sum::<usize>() as f64 / self.block_live.len() as f64
    }

    /// Peak live arena blocks in any wave.
    pub fn max_blocks_live(&self) -> usize {
        self.block_live.iter().copied().max().unwrap_or(0)
    }

    /// Mean fraction of the arena budget live per wave.
    pub fn block_occupancy_mean(&self) -> f64 {
        if self.kv_blocks_total == 0 {
            0.0
        } else {
            self.mean_blocks_live() / self.kv_blocks_total as f64
        }
    }

    /// Peak fraction of the arena budget live in any wave.
    pub fn block_occupancy_max(&self) -> f64 {
        if self.kv_blocks_total == 0 {
            0.0
        } else {
            self.max_blocks_live() as f64 / self.kv_blocks_total as f64
        }
    }

    /// Record a completed request.
    pub fn record_completion(&mut self, resp: &GenResponse) {
        self.completed += 1;
        self.prompt_tokens += resp.prompt_len;
        self.gen_tokens += resp.tokens.len();
        self.total_s.push(resp.total_s);
        self.ttft_s.push(resp.ttft_s);
        self.queue_s.push(resp.queue_s);
        self.last_done = Some(Instant::now());
    }

    /// Wall seconds from the first decode wave to the last completion.
    pub fn wall_s(&self) -> f64 {
        match (self.first_wave, self.last_done) {
            (Some(a), Some(b)) => b.duration_since(a).as_secs_f64(),
            _ => 0.0,
        }
    }

    /// Generated tokens per second of serving wall time.
    pub fn tokens_per_sec(&self) -> f64 {
        let w = self.wall_s();
        if w > 0.0 {
            self.gen_tokens as f64 / w
        } else {
            0.0
        }
    }

    pub fn p50_total_ms(&self) -> f64 {
        percentile(&self.total_s, 50.0) * 1e3
    }

    pub fn p95_total_ms(&self) -> f64 {
        percentile(&self.total_s, 95.0) * 1e3
    }

    pub fn p50_ttft_ms(&self) -> f64 {
        percentile(&self.ttft_s, 50.0) * 1e3
    }

    pub fn p95_ttft_ms(&self) -> f64 {
        percentile(&self.ttft_s, 95.0) * 1e3
    }

    pub fn mean_queue_ms(&self) -> f64 {
        crate::util::stats::mean(&self.queue_s) * 1e3
    }

    /// Mean sequences advanced per wave.
    pub fn mean_occupancy(&self) -> f64 {
        if self.occupancy.is_empty() {
            return 0.0;
        }
        self.occupancy.iter().sum::<usize>() as f64 / self.occupancy.len() as f64
    }

    /// Peak sequences advanced in one wave.
    pub fn max_occupancy(&self) -> usize {
        self.occupancy.iter().copied().max().unwrap_or(0)
    }

    /// The BENCH record: one flat JSON object per serving run, consumed by
    /// `benches/bench_serve.rs` and the perf-trajectory file
    /// `BENCH_serve.json`.
    pub fn bench_json(&self, label: &str, extra: Vec<(&str, Json)>) -> Json {
        let mut pairs = vec![
            ("bench", s("serve")),
            ("label", s(label)),
            ("requests", num(self.completed as f64)),
            ("prompt_tokens", num(self.prompt_tokens as f64)),
            ("gen_tokens", num(self.gen_tokens as f64)),
            ("waves", num(self.waves as f64)),
            ("tokens_per_sec", num(self.tokens_per_sec())),
            ("p50_total_ms", num(self.p50_total_ms())),
            ("p95_total_ms", num(self.p95_total_ms())),
            ("p50_ttft_ms", num(self.p50_ttft_ms())),
            ("p95_ttft_ms", num(self.p95_ttft_ms())),
            ("mean_queue_ms", num(self.mean_queue_ms())),
            ("mean_batch_occupancy", num(self.mean_occupancy())),
            ("max_batch_occupancy", num(self.max_occupancy() as f64)),
            ("prefill_chunks", num(self.prefill_chunks as f64)),
            ("prefill_chunk_tokens", num(self.prefill_chunk_tokens as f64)),
            ("prefix_hits", num(self.prefix_hits as f64)),
            ("prefix_hit_rate", num(self.prefix_hit_rate())),
            ("prefix_tokens_reused", num(self.prefix_tokens_reused as f64)),
            ("preemptions", num(self.preemptions as f64)),
            ("kv_blocks_total", num(self.kv_blocks_total as f64)),
            ("block_occupancy_mean", num(self.block_occupancy_mean())),
            ("block_occupancy_max", num(self.block_occupancy_max())),
            ("kv_store", s(&self.kv_store)),
            ("kv_bytes_per_position", num(self.kv_bytes_per_position as f64)),
            ("kv_arena_encoded_bytes", num(self.kv_arena_encoded_bytes as f64)),
        ];
        pairs.extend(extra);
        obj(pairs)
    }

    /// Human-readable summary table.
    pub fn render(&self, label: &str) -> String {
        format!(
            "== serve stats [{label}] ==\n\
             requests        {:>10}\n\
             prompt tokens   {:>10}\n\
             gen tokens      {:>10}\n\
             decode waves    {:>10}\n\
             tokens/sec      {:>10.1}\n\
             latency p50/p95 {:>7.1} / {:.1} ms\n\
             ttft    p50/p95 {:>7.1} / {:.1} ms\n\
             queue mean      {:>10.2} ms\n\
             occupancy mean  {:>10.2}  (max {})\n\
             prefill chunks  {:>10}  ({} tokens)\n\
             prefix hits     {:>10}  ({:.0}% rate, {} positions reused)\n\
             preemptions     {:>10}\n\
             kv blocks       {:>7.2}/{} live mean (occupancy {:.0}%, peak {:.0}%)\n\
             kv store        {:>10}  ({} B/position encoded, arena {} B encoded)",
            self.completed,
            self.prompt_tokens,
            self.gen_tokens,
            self.waves,
            self.tokens_per_sec(),
            self.p50_total_ms(),
            self.p95_total_ms(),
            self.p50_ttft_ms(),
            self.p95_ttft_ms(),
            self.mean_queue_ms(),
            self.mean_occupancy(),
            self.max_occupancy(),
            self.prefill_chunks,
            self.prefill_chunk_tokens,
            self.prefix_hits,
            self.prefix_hit_rate() * 100.0,
            self.prefix_tokens_reused,
            self.preemptions,
            self.mean_blocks_live(),
            self.kv_blocks_total,
            self.block_occupancy_mean() * 100.0,
            self.block_occupancy_max() * 100.0,
            self.kv_store,
            self.kv_bytes_per_position,
            self.kv_arena_encoded_bytes,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::protocol::FinishReason;

    fn resp(id: u64, n: usize, total: f64) -> GenResponse {
        GenResponse {
            id,
            prompt_len: 4,
            tokens: vec![1; n],
            finish: FinishReason::Length,
            queue_s: 0.001,
            ttft_s: total / 2.0,
            total_s: total,
        }
    }

    #[test]
    fn aggregates_completions() {
        let mut st = ServeStats::new();
        st.record_wave(1);
        st.record_wave(3);
        st.record_wave(2);
        for i in 0..4 {
            st.record_completion(&resp(i, 5, 0.010 * (i + 1) as f64));
        }
        assert_eq!(st.completed, 4);
        assert_eq!(st.gen_tokens, 20);
        assert_eq!(st.prompt_tokens, 16);
        assert_eq!(st.max_occupancy(), 3);
        assert!((st.mean_occupancy() - 2.0).abs() < 1e-9);
        assert!(st.p50_total_ms() > 0.0);
        assert!(st.p95_total_ms() >= st.p50_total_ms());
        assert!(st.tokens_per_sec() >= 0.0);
    }

    #[test]
    fn empty_waves_not_counted() {
        let mut st = ServeStats::new();
        st.record_wave(0);
        assert_eq!(st.waves, 0);
        assert_eq!(st.mean_occupancy(), 0.0);
    }

    #[test]
    fn bench_json_shape() {
        let mut st = ServeStats::new();
        st.record_wave(2);
        st.record_completion(&resp(0, 3, 0.02));
        let j = st.bench_json("bf16/b4", vec![("batch", num(4.0))]);
        assert_eq!(j.get("bench").as_str(), Some("serve"));
        assert_eq!(j.get("label").as_str(), Some("bf16/b4"));
        assert_eq!(j.get("gen_tokens").as_usize(), Some(3));
        assert_eq!(j.get("batch").as_usize(), Some(4));
        // reparses as valid JSON
        assert!(Json::parse(&j.to_string()).is_ok());
    }

    #[test]
    fn render_mentions_occupancy() {
        let mut st = ServeStats::new();
        st.record_wave(2);
        st.record_completion(&resp(0, 3, 0.02));
        let text = st.render("test");
        assert!(text.contains("occupancy"));
        assert!(text.contains("tokens/sec"));
        assert!(text.contains("prefix hits"));
        assert!(text.contains("kv blocks"));
        assert!(text.contains("kv store"));
    }

    #[test]
    fn kv_store_accounting_flows_to_bench_json() {
        let mut st = ServeStats::new();
        st.set_kv_store("fp8_e3m4", 288, 1 << 20, 1 << 18);
        assert_eq!(st.kv_store, "fp8_e3m4");
        let j = st.bench_json("kv", vec![]);
        assert_eq!(j.get("kv_store").as_str(), Some("fp8_e3m4"));
        assert_eq!(j.get("kv_bytes_per_position").as_usize(), Some(288));
        assert_eq!(j.get("kv_arena_encoded_bytes").as_usize(), Some(1 << 18));
        let text = st.render("kv");
        assert!(text.contains("fp8_e3m4"), "{text}");
        assert!(text.contains("288"), "{text}");
    }

    #[test]
    fn paged_metrics_aggregate() {
        let mut st = ServeStats::new();
        st.record_blocks(4, 16);
        st.record_blocks(12, 16);
        st.record_prefill_chunk(8);
        st.record_prefill_chunk(3);
        st.record_admission(Some(0));
        st.record_admission(Some(10));
        st.record_admission(None); // prefix cache disabled: no lookup
        st.record_preemption();
        assert_eq!(st.admissions, 3);
        assert_eq!(st.prefill_chunks, 2);
        assert_eq!(st.prefill_chunk_tokens, 11);
        assert_eq!(st.prefix_hits, 1);
        assert_eq!(st.prefix_misses, 1);
        assert_eq!(st.prefix_tokens_reused, 10);
        assert!((st.prefix_hit_rate() - 0.5).abs() < 1e-12);
        assert_eq!(st.preemptions, 1);
        assert!((st.mean_blocks_live() - 8.0).abs() < 1e-12);
        assert_eq!(st.max_blocks_live(), 12);
        assert!((st.block_occupancy_mean() - 0.5).abs() < 1e-12);
        assert!((st.block_occupancy_max() - 0.75).abs() < 1e-12);
        let j = st.bench_json("paged", vec![]);
        assert_eq!(j.get("preemptions").as_usize(), Some(1));
        assert_eq!(j.get("prefix_hits").as_usize(), Some(1));
        assert_eq!(j.get("kv_blocks_total").as_usize(), Some(16));
    }
}
