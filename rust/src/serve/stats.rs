//! Serving-side accounting: per-request latency (queue / TTFT / total),
//! generated-token throughput, and per-wave batch occupancy. This is the
//! first latency-oriented metrics surface in the codebase — the training
//! loop reports tokens/step, serving reports p50/p95 and tokens/sec.

use crate::serve::protocol::GenResponse;
use crate::util::json::{num, obj, s, Json};
use crate::util::stats::percentile;
use std::time::Instant;

/// Aggregated serving statistics.
#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    /// Completed requests.
    pub completed: usize,
    /// Prompt tokens consumed (prefill work).
    pub prompt_tokens: usize,
    /// Tokens generated (decode work the caller actually received).
    pub gen_tokens: usize,
    /// Decode waves executed.
    pub waves: usize,
    /// Sequences advanced per wave (the continuous-batching occupancy).
    occupancy: Vec<usize>,
    total_s: Vec<f64>,
    ttft_s: Vec<f64>,
    queue_s: Vec<f64>,
    /// Wall-clock span from the first wave to the last completion.
    first_wave: Option<Instant>,
    last_done: Option<Instant>,
}

impl ServeStats {
    pub fn new() -> ServeStats {
        ServeStats::default()
    }

    /// Record one decode wave that advanced `n_seqs` sequences.
    pub fn record_wave(&mut self, n_seqs: usize) {
        if n_seqs == 0 {
            return;
        }
        self.waves += 1;
        self.occupancy.push(n_seqs);
        if self.first_wave.is_none() {
            self.first_wave = Some(Instant::now());
        }
    }

    /// Record a completed request.
    pub fn record_completion(&mut self, resp: &GenResponse) {
        self.completed += 1;
        self.prompt_tokens += resp.prompt_len;
        self.gen_tokens += resp.tokens.len();
        self.total_s.push(resp.total_s);
        self.ttft_s.push(resp.ttft_s);
        self.queue_s.push(resp.queue_s);
        self.last_done = Some(Instant::now());
    }

    /// Wall seconds from the first decode wave to the last completion.
    pub fn wall_s(&self) -> f64 {
        match (self.first_wave, self.last_done) {
            (Some(a), Some(b)) => b.duration_since(a).as_secs_f64(),
            _ => 0.0,
        }
    }

    /// Generated tokens per second of serving wall time.
    pub fn tokens_per_sec(&self) -> f64 {
        let w = self.wall_s();
        if w > 0.0 {
            self.gen_tokens as f64 / w
        } else {
            0.0
        }
    }

    pub fn p50_total_ms(&self) -> f64 {
        percentile(&self.total_s, 50.0) * 1e3
    }

    pub fn p95_total_ms(&self) -> f64 {
        percentile(&self.total_s, 95.0) * 1e3
    }

    pub fn p50_ttft_ms(&self) -> f64 {
        percentile(&self.ttft_s, 50.0) * 1e3
    }

    pub fn p95_ttft_ms(&self) -> f64 {
        percentile(&self.ttft_s, 95.0) * 1e3
    }

    pub fn mean_queue_ms(&self) -> f64 {
        crate::util::stats::mean(&self.queue_s) * 1e3
    }

    /// Mean sequences advanced per wave.
    pub fn mean_occupancy(&self) -> f64 {
        if self.occupancy.is_empty() {
            return 0.0;
        }
        self.occupancy.iter().sum::<usize>() as f64 / self.occupancy.len() as f64
    }

    /// Peak sequences advanced in one wave.
    pub fn max_occupancy(&self) -> usize {
        self.occupancy.iter().copied().max().unwrap_or(0)
    }

    /// The BENCH record: one flat JSON object per serving run, consumed by
    /// `benches/bench_serve.rs` and the perf-trajectory file
    /// `BENCH_serve.json`.
    pub fn bench_json(&self, label: &str, extra: Vec<(&str, Json)>) -> Json {
        let mut pairs = vec![
            ("bench", s("serve")),
            ("label", s(label)),
            ("requests", num(self.completed as f64)),
            ("prompt_tokens", num(self.prompt_tokens as f64)),
            ("gen_tokens", num(self.gen_tokens as f64)),
            ("waves", num(self.waves as f64)),
            ("tokens_per_sec", num(self.tokens_per_sec())),
            ("p50_total_ms", num(self.p50_total_ms())),
            ("p95_total_ms", num(self.p95_total_ms())),
            ("p50_ttft_ms", num(self.p50_ttft_ms())),
            ("p95_ttft_ms", num(self.p95_ttft_ms())),
            ("mean_queue_ms", num(self.mean_queue_ms())),
            ("mean_batch_occupancy", num(self.mean_occupancy())),
            ("max_batch_occupancy", num(self.max_occupancy() as f64)),
        ];
        pairs.extend(extra);
        obj(pairs)
    }

    /// Human-readable summary table.
    pub fn render(&self, label: &str) -> String {
        format!(
            "== serve stats [{label}] ==\n\
             requests        {:>10}\n\
             prompt tokens   {:>10}\n\
             gen tokens      {:>10}\n\
             decode waves    {:>10}\n\
             tokens/sec      {:>10.1}\n\
             latency p50/p95 {:>7.1} / {:.1} ms\n\
             ttft    p50/p95 {:>7.1} / {:.1} ms\n\
             queue mean      {:>10.2} ms\n\
             occupancy mean  {:>10.2}  (max {})",
            self.completed,
            self.prompt_tokens,
            self.gen_tokens,
            self.waves,
            self.tokens_per_sec(),
            self.p50_total_ms(),
            self.p95_total_ms(),
            self.p50_ttft_ms(),
            self.p95_ttft_ms(),
            self.mean_queue_ms(),
            self.mean_occupancy(),
            self.max_occupancy(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::protocol::FinishReason;

    fn resp(id: u64, n: usize, total: f64) -> GenResponse {
        GenResponse {
            id,
            prompt_len: 4,
            tokens: vec![1; n],
            finish: FinishReason::Length,
            queue_s: 0.001,
            ttft_s: total / 2.0,
            total_s: total,
        }
    }

    #[test]
    fn aggregates_completions() {
        let mut st = ServeStats::new();
        st.record_wave(1);
        st.record_wave(3);
        st.record_wave(2);
        for i in 0..4 {
            st.record_completion(&resp(i, 5, 0.010 * (i + 1) as f64));
        }
        assert_eq!(st.completed, 4);
        assert_eq!(st.gen_tokens, 20);
        assert_eq!(st.prompt_tokens, 16);
        assert_eq!(st.max_occupancy(), 3);
        assert!((st.mean_occupancy() - 2.0).abs() < 1e-9);
        assert!(st.p50_total_ms() > 0.0);
        assert!(st.p95_total_ms() >= st.p50_total_ms());
        assert!(st.tokens_per_sec() >= 0.0);
    }

    #[test]
    fn empty_waves_not_counted() {
        let mut st = ServeStats::new();
        st.record_wave(0);
        assert_eq!(st.waves, 0);
        assert_eq!(st.mean_occupancy(), 0.0);
    }

    #[test]
    fn bench_json_shape() {
        let mut st = ServeStats::new();
        st.record_wave(2);
        st.record_completion(&resp(0, 3, 0.02));
        let j = st.bench_json("bf16/b4", vec![("batch", num(4.0))]);
        assert_eq!(j.get("bench").as_str(), Some("serve"));
        assert_eq!(j.get("label").as_str(), Some("bf16/b4"));
        assert_eq!(j.get("gen_tokens").as_usize(), Some(3));
        assert_eq!(j.get("batch").as_usize(), Some(4));
        // reparses as valid JSON
        assert!(Json::parse(&j.to_string()).is_ok());
    }

    #[test]
    fn render_mentions_occupancy() {
        let mut st = ServeStats::new();
        st.record_wave(2);
        st.record_completion(&resp(0, 3, 0.02));
        let text = st.render("test");
        assert!(text.contains("occupancy"));
        assert!(text.contains("tokens/sec"));
    }
}
