//! Serving-side accounting: per-request latency (queue / TTFT / total),
//! generated-token throughput, per-wave batch occupancy, and the KV
//! logit-drift histogram — all as a *view over* a
//! [`telemetry::Registry`](crate::telemetry::Registry).
//!
//! Every counter, gauge and latency percentile below is backed by a named
//! registry metric (prefix `serve.`), so `--metrics-every` snapshots,
//! Prometheus exposition, `bench_json`, and `render` read the same state.
//! Latency percentiles are histogram-backed (log-bucketed, nearest-rank —
//! within one bucket width of the exact percentile); means and extrema
//! stay exact via the histograms' count/sum/min/max atomics.
//!
//! When tracing is enabled ([`ServeStats::enable_trace`], wired to
//! `EngineConfig::trace` / `serve --trace-out`), the struct also carries
//! the per-request [`TraceBuffer`] timeline; `record_completion` closes
//! each request's spans.
//!
//! Cloning a `ServeStats` clones the registry *handles*, not the values:
//! clones observe and mutate the same underlying metrics.

use crate::serve::protocol::GenResponse;
use crate::telemetry::{Counter, Gauge, Histogram, Registry, TraceBuffer, TraceEvent};
use crate::util::json::{num, obj, s, Json};
use std::time::Instant;

/// Aggregated serving statistics (view over a telemetry registry).
#[derive(Debug, Clone)]
pub struct ServeStats {
    reg: Registry,
    completed: Counter,
    prompt_tokens: Counter,
    gen_tokens: Counter,
    waves: Counter,
    admissions: Counter,
    prefill_chunks: Counter,
    prefill_chunk_tokens: Counter,
    prefix_lookups: Counter,
    prefix_hits: Counter,
    prefix_misses: Counter,
    prefix_tokens_reused: Counter,
    preemptions: Counter,
    deadline_expired: Counter,
    spec_rounds: Counter,
    spec_drafted: Counter,
    spec_accepted: Counter,
    /// Current live arena blocks — an occupancy-over-time gauge updated on
    /// every reserve/release edge, not just end-state.
    blocks_live: Gauge,
    occupancy: Histogram,
    /// Sequences advanced through the weight-stationary batched decode
    /// (`Transformer::decode_wave`) per wave; only waves that batched at
    /// least one sequence record a sample.
    wave_batch: Histogram,
    block_live: Histogram,
    total_s: Histogram,
    ttft_s: Histogram,
    queue_s: Histogram,
    kv_drift: Histogram,
    /// Arena block budget (set once by the engine).
    pub kv_blocks_total: usize,
    /// Canonical label of the KV row-storage scheme (`"f32"`, `"fp8_e3m4"`,
    /// …; set once by the engine).
    pub kv_store: String,
    /// Encoded bytes one cached position costs under the KV scheme
    /// (packed codes + per-group scales, or raw f32 for passthrough).
    pub kv_bytes_per_position: usize,
    /// Resident bytes of the arena budget. Equal to
    /// [`ServeStats::kv_arena_encoded_bytes`] under the default fused
    /// decode; larger only when the engine runs with `kv_mirror` (the f32
    /// debug mirror is then resident alongside the packed codes).
    pub kv_arena_bytes: usize,
    /// Encoded bytes of the arena budget — what a deployment layout
    /// storing only codes + scales would cost.
    pub kv_arena_encoded_bytes: usize,
    trace: Option<TraceBuffer>,
    /// Wall-clock span from the first wave to the last completion.
    first_wave: Option<Instant>,
    last_done: Option<Instant>,
}

impl Default for ServeStats {
    fn default() -> Self {
        ServeStats::new()
    }
}

impl ServeStats {
    pub fn new() -> ServeStats {
        ServeStats::with_registry(Registry::new())
    }

    /// Build a view over an existing registry (metric names are prefixed
    /// `serve.`).
    pub fn with_registry(reg: Registry) -> ServeStats {
        ServeStats {
            completed: reg.counter("serve.requests_completed"),
            prompt_tokens: reg.counter("serve.prompt_tokens"),
            gen_tokens: reg.counter("serve.gen_tokens"),
            waves: reg.counter("serve.waves"),
            admissions: reg.counter("serve.admissions"),
            prefill_chunks: reg.counter("serve.prefill_chunks"),
            prefill_chunk_tokens: reg.counter("serve.prefill_chunk_tokens"),
            prefix_lookups: reg.counter("serve.prefix_lookups"),
            prefix_hits: reg.counter("serve.prefix_hits"),
            prefix_misses: reg.counter("serve.prefix_misses"),
            prefix_tokens_reused: reg.counter("serve.prefix_tokens_reused"),
            preemptions: reg.counter("serve.preemptions"),
            deadline_expired: reg.counter("serve.deadline_expired"),
            spec_rounds: reg.counter("serve.spec_rounds"),
            spec_drafted: reg.counter("serve.spec_drafted"),
            spec_accepted: reg.counter("serve.spec_accepted"),
            blocks_live: reg.gauge("serve.kv_blocks_live"),
            occupancy: reg.histogram("serve.batch_occupancy"),
            wave_batch: reg.histogram("serve.wave_batch_size"),
            block_live: reg.histogram("serve.kv_blocks_live_per_wave"),
            total_s: reg.histogram("serve.latency_total_s"),
            ttft_s: reg.histogram("serve.latency_ttft_s"),
            queue_s: reg.histogram("serve.latency_queue_s"),
            kv_drift: reg.histogram("serve.kv_logit_drift"),
            reg,
            kv_blocks_total: 0,
            kv_store: String::new(),
            kv_bytes_per_position: 0,
            kv_arena_bytes: 0,
            kv_arena_encoded_bytes: 0,
            trace: None,
            first_wave: None,
            last_done: None,
        }
    }

    /// The backing registry (for `--metrics-every` snapshots and
    /// Prometheus exposition).
    pub fn registry(&self) -> &Registry {
        &self.reg
    }

    /// Turn on per-request trace-timeline recording.
    pub fn enable_trace(&mut self) {
        if self.trace.is_none() {
            self.trace = Some(TraceBuffer::new());
        }
    }

    pub fn trace_enabled(&self) -> bool {
        self.trace.is_some()
    }

    pub fn trace(&self) -> Option<&TraceBuffer> {
        self.trace.as_ref()
    }

    pub fn trace_mut(&mut self) -> Option<&mut TraceBuffer> {
        self.trace.as_mut()
    }

    /// Recorded trace events (empty when tracing is off).
    pub fn trace_events(&self) -> &[TraceEvent] {
        self.trace.as_ref().map(|t| t.events()).unwrap_or(&[])
    }

    // ---- counter views ------------------------------------------------

    /// Completed requests.
    pub fn completed(&self) -> usize {
        self.completed.get() as usize
    }

    /// Prompt tokens consumed (prefill work).
    pub fn prompt_tokens(&self) -> usize {
        self.prompt_tokens.get() as usize
    }

    /// Tokens generated (decode work the caller actually received).
    pub fn gen_tokens(&self) -> usize {
        self.gen_tokens.get() as usize
    }

    /// Decode waves executed.
    pub fn waves(&self) -> usize {
        self.waves.get() as usize
    }

    /// Sequences admitted into the active batch (re-admissions after
    /// preemption count again).
    pub fn admissions(&self) -> usize {
        self.admissions.get() as usize
    }

    /// Multi-token prefill chunks executed (waves where a sequence
    /// advanced by more than one position).
    pub fn prefill_chunks(&self) -> usize {
        self.prefill_chunks.get() as usize
    }

    /// Prompt positions fed through multi-token chunks.
    pub fn prefill_chunk_tokens(&self) -> usize {
        self.prefill_chunk_tokens.get() as usize
    }

    /// Prefix-index lookups performed at admission.
    pub fn prefix_lookups(&self) -> usize {
        self.prefix_lookups.get() as usize
    }

    /// Lookups that found a reusable cached prompt-prefix chain.
    pub fn prefix_hits(&self) -> usize {
        self.prefix_hits.get() as usize
    }

    /// Lookups that missed the prefix index.
    pub fn prefix_misses(&self) -> usize {
        self.prefix_misses.get() as usize
    }

    /// KV positions skipped (neither recomputed nor re-stored) thanks to
    /// prefix reuse.
    pub fn prefix_tokens_reused(&self) -> usize {
        self.prefix_tokens_reused.get() as usize
    }

    /// Sequences pushed back to the queue because the arena ran dry.
    pub fn preemptions(&self) -> usize {
        self.preemptions.get() as usize
    }

    /// Requests finished by per-request deadline expiry (these also count
    /// in [`ServeStats::completed`] — the caller got a response).
    pub fn deadline_expired(&self) -> usize {
        self.deadline_expired.get() as usize
    }

    /// Speculative rounds executed (one fork + draft + verify cycle).
    pub fn spec_rounds(&self) -> usize {
        self.spec_rounds.get() as usize
    }

    /// Draft tokens proposed across all speculative rounds.
    pub fn spec_drafted(&self) -> usize {
        self.spec_drafted.get() as usize
    }

    /// Draft tokens confirmed by exact greedy match against the target
    /// store's logits (the accepted-token-rate numerator).
    pub fn spec_accepted(&self) -> usize {
        self.spec_accepted.get() as usize
    }

    /// Current live arena blocks (the occupancy-over-time gauge).
    pub fn blocks_live_now(&self) -> f64 {
        self.blocks_live.get()
    }

    // ---- recording ----------------------------------------------------

    /// Record one decode wave that advanced `n_seqs` sequences.
    pub fn record_wave(&mut self, n_seqs: usize) {
        if n_seqs == 0 {
            return;
        }
        self.waves.inc();
        self.occupancy.record(n_seqs as f64);
        if self.first_wave.is_none() {
            self.first_wave = Some(Instant::now());
        }
    }

    /// Record one wave whose steady-state decodes went through the
    /// weight-stationary batched path (`n_seqs` sequences in the batch).
    /// Waves with nothing to batch (all prefill / speculative) record no
    /// sample, mirroring [`ServeStats::record_wave`]'s empty-wave rule.
    pub fn record_wave_batch(&mut self, n_seqs: usize) {
        if n_seqs == 0 {
            return;
        }
        self.wave_batch.record(n_seqs as f64);
    }

    /// Sample the arena's live-block count for the current wave.
    pub fn record_blocks(&mut self, live: usize, total: usize) {
        self.kv_blocks_total = total;
        self.block_live.record(live as f64);
        self.set_blocks_live(live);
    }

    /// Update the live-block gauge (called on every reserve/release edge:
    /// per wave, at retire, and when the prefix cache is cleared).
    pub fn set_blocks_live(&mut self, live: usize) {
        self.blocks_live.set(live as f64);
        if let Some(t) = self.trace.as_mut() {
            t.counter("kv_blocks_live", live as f64);
        }
    }

    /// Record one multi-token prefill chunk of `tokens` positions.
    pub fn record_prefill_chunk(&mut self, tokens: usize) {
        self.prefill_chunks.inc();
        self.prefill_chunk_tokens.add(tokens as u64);
    }

    /// Record a prefix-index lookup that adopted `reused` cached positions
    /// (0 = miss). Called at the lookup site, so `hits + misses ==
    /// lookups` holds even when the admission later bounces off a dry
    /// arena — the fuzz harness checks exactly that.
    pub fn record_prefix_lookup(&mut self, reused: usize) {
        self.prefix_lookups.inc();
        if reused > 0 {
            self.prefix_hits.inc();
        } else {
            self.prefix_misses.inc();
        }
    }

    /// Record an admission; `reused` is the prefix positions adopted from
    /// the prefix index (`None` when the prefix cache is disabled).
    pub fn record_admission(&mut self, reused: Option<usize>) {
        self.admissions.inc();
        if let Some(n) = reused {
            self.prefix_tokens_reused.add(n as u64);
        }
    }

    pub fn record_preemption(&mut self) {
        self.preemptions.inc();
    }

    /// Record one speculative round: `drafted` tokens proposed through the
    /// draft store, `accepted` of them confirmed by the verify wave.
    pub fn record_spec(&mut self, drafted: usize, accepted: usize) {
        self.spec_rounds.inc();
        self.spec_drafted.add(drafted as u64);
        self.spec_accepted.add(accepted as u64);
    }

    /// Record one KV quantized-vs-f32 logit drift sample into the
    /// streaming drift histogram (`serve.kv_logit_drift`).
    pub fn record_kv_drift(&mut self, drift: f64) {
        self.kv_drift.record(drift);
    }

    /// Record the KV row-storage scheme and its byte accounting (set once
    /// by the engine at construction).
    pub fn set_kv_store(
        &mut self,
        label: &str,
        bytes_per_position: usize,
        arena_bytes: usize,
        arena_encoded_bytes: usize,
    ) {
        self.kv_store = label.to_string();
        self.kv_bytes_per_position = bytes_per_position;
        self.kv_arena_bytes = arena_bytes;
        self.kv_arena_encoded_bytes = arena_encoded_bytes;
    }

    /// Record a completed request.
    pub fn record_completion(&mut self, resp: &GenResponse) {
        self.completed.inc();
        self.prompt_tokens.add(resp.prompt_len as u64);
        self.gen_tokens.add(resp.tokens.len() as u64);
        self.total_s.record(resp.total_s);
        self.ttft_s.record(resp.ttft_s);
        self.queue_s.record(resp.queue_s);
        self.last_done = Some(Instant::now());
        if let Some(t) = self.trace.as_mut() {
            t.end("resident", resp.id, vec![]);
            t.end("request", resp.id, vec![("gen_tokens", num(resp.tokens.len() as f64))]);
        }
    }

    /// Record a deadline-expired request. Counts toward completions (the
    /// caller received a response) and the total/queue latency histograms,
    /// but not toward `prompt_tokens` — an expired-in-queue prompt was
    /// never fed, and a partially-fed prompt would overcount prefill work
    /// either way. The **TTFT histogram** only takes a sample when the
    /// request actually delivered tokens: a never-admitted (or
    /// never-sampled) expiry has no first token, and recording its wait as
    /// one would pollute the p95/p99 TTFT of the requests that were
    /// genuinely served. `was_resident` says whether the sequence sat in
    /// the active batch when it expired: only then is there an open
    /// "resident" trace span to close (queued/preempted requests have none
    /// — closing one unconditionally would break the well-nestedness
    /// invariant the fuzz harness checks).
    pub fn record_deadline(&mut self, resp: &GenResponse, was_resident: bool) {
        self.deadline_expired.inc();
        self.completed.inc();
        self.gen_tokens.add(resp.tokens.len() as u64);
        self.total_s.record(resp.total_s);
        if !resp.tokens.is_empty() {
            self.ttft_s.record(resp.ttft_s);
        }
        self.queue_s.record(resp.queue_s);
        self.last_done = Some(Instant::now());
        if let Some(t) = self.trace.as_mut() {
            if was_resident {
                t.end("resident", resp.id, vec![("reason", s("deadline"))]);
            }
            t.end("request", resp.id, vec![("finish", s("deadline"))]);
        }
    }

    // ---- derived views ------------------------------------------------

    /// Fraction of prefix-index lookups that found a reusable chain.
    pub fn prefix_hit_rate(&self) -> f64 {
        let lookups = self.prefix_lookups();
        if lookups == 0 {
            0.0
        } else {
            self.prefix_hits() as f64 / lookups as f64
        }
    }

    /// Fraction of draft tokens the verify wave accepted (0 when no
    /// speculative round ran).
    pub fn spec_acceptance_rate(&self) -> f64 {
        let drafted = self.spec_drafted();
        if drafted == 0 {
            0.0
        } else {
            self.spec_accepted() as f64 / drafted as f64
        }
    }

    /// Mean live arena blocks per wave (exact, via histogram sum/count).
    pub fn mean_blocks_live(&self) -> f64 {
        self.block_live.mean()
    }

    /// Peak live arena blocks in any wave (exact).
    pub fn max_blocks_live(&self) -> usize {
        self.block_live.max() as usize
    }

    /// Mean fraction of the arena budget live per wave.
    pub fn block_occupancy_mean(&self) -> f64 {
        if self.kv_blocks_total == 0 {
            0.0
        } else {
            self.mean_blocks_live() / self.kv_blocks_total as f64
        }
    }

    /// Peak fraction of the arena budget live in any wave.
    pub fn block_occupancy_max(&self) -> f64 {
        if self.kv_blocks_total == 0 {
            0.0
        } else {
            self.max_blocks_live() as f64 / self.kv_blocks_total as f64
        }
    }

    /// Wall seconds from the first decode wave to the last completion.
    pub fn wall_s(&self) -> f64 {
        match (self.first_wave, self.last_done) {
            (Some(a), Some(b)) => b.duration_since(a).as_secs_f64(),
            _ => 0.0,
        }
    }

    /// Generated tokens per second of serving wall time.
    pub fn tokens_per_sec(&self) -> f64 {
        let w = self.wall_s();
        if w > 0.0 {
            self.gen_tokens() as f64 / w
        } else {
            0.0
        }
    }

    pub fn p50_total_ms(&self) -> f64 {
        self.total_s.quantile(0.5) * 1e3
    }

    pub fn p95_total_ms(&self) -> f64 {
        self.total_s.quantile(0.95) * 1e3
    }

    pub fn p99_total_ms(&self) -> f64 {
        self.total_s.quantile(0.99) * 1e3
    }

    pub fn p50_ttft_ms(&self) -> f64 {
        self.ttft_s.quantile(0.5) * 1e3
    }

    pub fn p95_ttft_ms(&self) -> f64 {
        self.ttft_s.quantile(0.95) * 1e3
    }

    pub fn mean_queue_ms(&self) -> f64 {
        self.queue_s.mean() * 1e3
    }

    /// Mean sequences advanced per wave (exact).
    pub fn mean_occupancy(&self) -> f64 {
        self.occupancy.mean()
    }

    /// Peak sequences advanced in one wave (exact).
    pub fn max_occupancy(&self) -> usize {
        self.occupancy.max() as usize
    }

    /// Waves in which at least one sequence decoded through the batched
    /// weight-stationary path.
    pub fn wave_batch_waves(&self) -> usize {
        self.wave_batch.count() as usize
    }

    /// Mean batched-decode size over batching waves (exact; 0 when none).
    pub fn mean_wave_batch(&self) -> f64 {
        self.wave_batch.mean()
    }

    /// Peak batched-decode size in any wave (exact; 0 when none).
    pub fn max_wave_batch(&self) -> usize {
        self.wave_batch.max() as usize
    }

    /// Max KV quantized logit drift observed (0 when none recorded).
    pub fn kv_drift_max(&self) -> f64 {
        self.kv_drift.max()
    }

    /// Median KV quantized logit drift (0 when none recorded).
    pub fn kv_drift_p50(&self) -> f64 {
        self.kv_drift.quantile(0.5)
    }

    /// The BENCH record: one flat JSON object per serving run, consumed by
    /// `benches/bench_serve.rs` and the perf-trajectory file
    /// `BENCH_serve.json`.
    pub fn bench_json(&self, label: &str, extra: Vec<(&str, Json)>) -> Json {
        let mut pairs = vec![
            ("bench", s("serve")),
            ("label", s(label)),
            ("requests", num(self.completed() as f64)),
            ("prompt_tokens", num(self.prompt_tokens() as f64)),
            ("gen_tokens", num(self.gen_tokens() as f64)),
            ("waves", num(self.waves() as f64)),
            ("tokens_per_sec", num(self.tokens_per_sec())),
            ("p50_total_ms", num(self.p50_total_ms())),
            ("p95_total_ms", num(self.p95_total_ms())),
            ("p99_total_ms", num(self.p99_total_ms())),
            ("p50_ttft_ms", num(self.p50_ttft_ms())),
            ("p95_ttft_ms", num(self.p95_ttft_ms())),
            ("mean_queue_ms", num(self.mean_queue_ms())),
            ("mean_batch_occupancy", num(self.mean_occupancy())),
            ("max_batch_occupancy", num(self.max_occupancy() as f64)),
            ("prefill_chunks", num(self.prefill_chunks() as f64)),
            ("prefill_chunk_tokens", num(self.prefill_chunk_tokens() as f64)),
            ("prefix_lookups", num(self.prefix_lookups() as f64)),
            ("prefix_hits", num(self.prefix_hits() as f64)),
            ("prefix_hit_rate", num(self.prefix_hit_rate())),
            ("prefix_tokens_reused", num(self.prefix_tokens_reused() as f64)),
            ("preemptions", num(self.preemptions() as f64)),
            ("deadline_expired", num(self.deadline_expired() as f64)),
            ("kv_blocks_total", num(self.kv_blocks_total as f64)),
            ("block_occupancy_mean", num(self.block_occupancy_mean())),
            ("block_occupancy_max", num(self.block_occupancy_max())),
            ("kv_store", s(&self.kv_store)),
            ("kv_bytes_per_position", num(self.kv_bytes_per_position as f64)),
            ("kv_arena_encoded_bytes", num(self.kv_arena_encoded_bytes as f64)),
        ];
        if self.kv_drift.count() > 0 {
            pairs.push(("kv_logit_drift_max", num(self.kv_drift_max())));
            pairs.push(("kv_logit_drift_p50", num(self.kv_drift_p50())));
        }
        if self.wave_batch_waves() > 0 {
            pairs.push(("wave_batch_waves", num(self.wave_batch_waves() as f64)));
            pairs.push(("wave_batch_mean", num(self.mean_wave_batch())));
            pairs.push(("wave_batch_max", num(self.max_wave_batch() as f64)));
        }
        if self.spec_rounds() > 0 {
            pairs.push(("spec_rounds", num(self.spec_rounds() as f64)));
            pairs.push(("spec_drafted", num(self.spec_drafted() as f64)));
            pairs.push(("spec_accepted", num(self.spec_accepted() as f64)));
            pairs.push(("spec_acceptance_rate", num(self.spec_acceptance_rate())));
        }
        pairs.extend(extra);
        obj(pairs)
    }

    /// Human-readable summary table.
    pub fn render(&self, label: &str) -> String {
        format!(
            "== serve stats [{label}] ==\n\
             requests        {:>10}\n\
             prompt tokens   {:>10}\n\
             gen tokens      {:>10}\n\
             decode waves    {:>10}\n\
             tokens/sec      {:>10.1}\n\
             latency p50/p95 {:>7.1} / {:.1} ms\n\
             ttft    p50/p95 {:>7.1} / {:.1} ms\n\
             queue mean      {:>10.2} ms\n\
             occupancy mean  {:>10.2}  (max {})\n\
             prefill chunks  {:>10}  ({} tokens)\n\
             prefix hits     {:>10}  ({:.0}% rate, {} positions reused)\n\
             preemptions     {:>10}\n\
             deadline expiry {:>10}\n\
             spec decode     {:>10} rounds ({} drafted, {} accepted, {:.0}% rate)\n\
             kv blocks       {:>7.2}/{} live mean (occupancy {:.0}%, peak {:.0}%)\n\
             kv store        {:>10}  ({} B/position encoded, arena {} B encoded)",
            self.completed(),
            self.prompt_tokens(),
            self.gen_tokens(),
            self.waves(),
            self.tokens_per_sec(),
            self.p50_total_ms(),
            self.p95_total_ms(),
            self.p50_ttft_ms(),
            self.p95_ttft_ms(),
            self.mean_queue_ms(),
            self.mean_occupancy(),
            self.max_occupancy(),
            self.prefill_chunks(),
            self.prefill_chunk_tokens(),
            self.prefix_hits(),
            self.prefix_hit_rate() * 100.0,
            self.prefix_tokens_reused(),
            self.preemptions(),
            self.deadline_expired(),
            self.spec_rounds(),
            self.spec_drafted(),
            self.spec_accepted(),
            self.spec_acceptance_rate() * 100.0,
            self.mean_blocks_live(),
            self.kv_blocks_total,
            self.block_occupancy_mean() * 100.0,
            self.block_occupancy_max() * 100.0,
            self.kv_store,
            self.kv_bytes_per_position,
            self.kv_arena_encoded_bytes,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::protocol::FinishReason;
    use crate::telemetry::hist;
    use crate::util::stats::percentile_nearest_rank;

    fn resp(id: u64, n: usize, total: f64) -> GenResponse {
        GenResponse {
            id,
            prompt_len: 4,
            tokens: vec![1; n],
            finish: FinishReason::Length,
            queue_s: 0.001,
            ttft_s: total / 2.0,
            total_s: total,
        }
    }

    #[test]
    fn aggregates_completions() {
        let mut st = ServeStats::new();
        st.record_wave(1);
        st.record_wave(3);
        st.record_wave(2);
        for i in 0..4 {
            st.record_completion(&resp(i, 5, 0.010 * (i + 1) as f64));
        }
        assert_eq!(st.completed(), 4);
        assert_eq!(st.gen_tokens(), 20);
        assert_eq!(st.prompt_tokens(), 16);
        assert_eq!(st.max_occupancy(), 3);
        assert!((st.mean_occupancy() - 2.0).abs() < 1e-9);
        assert!(st.p50_total_ms() > 0.0);
        assert!(st.p95_total_ms() >= st.p50_total_ms());
        assert!(st.p99_total_ms() >= st.p95_total_ms());
        assert!(st.tokens_per_sec() >= 0.0);
    }

    #[test]
    fn histogram_percentiles_match_exact_within_one_bucket() {
        // the acceptance contract: histogram-backed p50/p95 agree with the
        // exact nearest-rank percentile to within one bucket width
        let mut st = ServeStats::new();
        let totals: Vec<f64> = (0..60).map(|i| 0.005 + 0.003 * ((i * 7) % 23) as f64).collect();
        for (i, &t) in totals.iter().enumerate() {
            st.record_completion(&resp(i as u64, 2, t));
        }
        for (p, got_ms) in [(50.0, st.p50_total_ms()), (95.0, st.p95_total_ms())] {
            let exact = percentile_nearest_rank(&totals, p);
            let got = got_ms / 1e3;
            assert!(
                (got - exact).abs() <= hist::bucket_width(exact),
                "p{p}: histogram {got} vs exact {exact} (bucket width {})",
                hist::bucket_width(exact)
            );
        }
    }

    #[test]
    fn empty_waves_not_counted() {
        let mut st = ServeStats::new();
        st.record_wave(0);
        assert_eq!(st.waves(), 0);
        assert_eq!(st.mean_occupancy(), 0.0);
    }

    #[test]
    fn bench_json_shape() {
        let mut st = ServeStats::new();
        st.record_wave(2);
        st.record_completion(&resp(0, 3, 0.02));
        let j = st.bench_json("bf16/b4", vec![("batch", num(4.0))]);
        assert_eq!(j.get("bench").as_str(), Some("serve"));
        assert_eq!(j.get("label").as_str(), Some("bf16/b4"));
        assert_eq!(j.get("gen_tokens").as_usize(), Some(3));
        assert_eq!(j.get("batch").as_usize(), Some(4));
        // drift keys only appear once drift samples exist
        assert_eq!(*j.get("kv_logit_drift_max"), Json::Null);
        st.record_kv_drift(0.25);
        let j2 = st.bench_json("bf16/b4", vec![]);
        assert_eq!(j2.get("kv_logit_drift_max").as_f64(), Some(0.25));
        assert!(j2.get("kv_logit_drift_p50").as_f64().is_some());
        // reparses as valid JSON
        assert!(Json::parse(&j.to_string()).is_ok());
    }

    #[test]
    fn render_mentions_occupancy() {
        let mut st = ServeStats::new();
        st.record_wave(2);
        st.record_completion(&resp(0, 3, 0.02));
        let text = st.render("test");
        assert!(text.contains("occupancy"));
        assert!(text.contains("tokens/sec"));
        assert!(text.contains("prefix hits"));
        assert!(text.contains("kv blocks"));
        assert!(text.contains("kv store"));
    }

    #[test]
    fn kv_store_accounting_flows_to_bench_json() {
        let mut st = ServeStats::new();
        st.set_kv_store("fp8_e3m4", 288, 1 << 20, 1 << 18);
        assert_eq!(st.kv_store, "fp8_e3m4");
        let j = st.bench_json("kv", vec![]);
        assert_eq!(j.get("kv_store").as_str(), Some("fp8_e3m4"));
        assert_eq!(j.get("kv_bytes_per_position").as_usize(), Some(288));
        assert_eq!(j.get("kv_arena_encoded_bytes").as_usize(), Some(1 << 18));
        let text = st.render("kv");
        assert!(text.contains("fp8_e3m4"), "{text}");
        assert!(text.contains("288"), "{text}");
    }

    #[test]
    fn paged_metrics_aggregate() {
        let mut st = ServeStats::new();
        st.record_blocks(4, 16);
        st.record_blocks(12, 16);
        st.record_prefill_chunk(8);
        st.record_prefill_chunk(3);
        st.record_prefix_lookup(0);
        st.record_admission(Some(0));
        st.record_prefix_lookup(10);
        st.record_admission(Some(10));
        st.record_admission(None); // prefix cache disabled: no lookup
        st.record_preemption();
        assert_eq!(st.admissions(), 3);
        assert_eq!(st.prefill_chunks(), 2);
        assert_eq!(st.prefill_chunk_tokens(), 11);
        assert_eq!(st.prefix_lookups(), 2);
        assert_eq!(st.prefix_hits(), 1);
        assert_eq!(st.prefix_misses(), 1);
        assert_eq!(st.prefix_tokens_reused(), 10);
        assert!((st.prefix_hit_rate() - 0.5).abs() < 1e-12);
        assert_eq!(st.preemptions(), 1);
        assert!((st.mean_blocks_live() - 8.0).abs() < 1e-12);
        assert_eq!(st.max_blocks_live(), 12);
        assert!((st.block_occupancy_mean() - 0.5).abs() < 1e-12);
        assert!((st.block_occupancy_max() - 0.75).abs() < 1e-12);
        assert_eq!(st.blocks_live_now(), 12.0, "gauge tracks the last sample");
        let j = st.bench_json("paged", vec![]);
        assert_eq!(j.get("preemptions").as_usize(), Some(1));
        assert_eq!(j.get("prefix_hits").as_usize(), Some(1));
        assert_eq!(j.get("prefix_lookups").as_usize(), Some(2));
        assert_eq!(j.get("kv_blocks_total").as_usize(), Some(16));
    }

    #[test]
    fn registry_exposition_sees_serve_metrics() {
        let mut st = ServeStats::new();
        st.record_wave(2);
        st.record_completion(&resp(0, 3, 0.02));
        let snap = st.registry().snapshot_json();
        assert_eq!(snap.get("serve.requests_completed").as_usize(), Some(1));
        assert_eq!(snap.get("serve.gen_tokens").as_usize(), Some(3));
        assert_eq!(snap.get("serve.latency_total_s").get("count").as_usize(), Some(1));
        let prom = st.registry().prometheus_text();
        assert!(prom.contains("gaussws_serve_requests_completed 1"));
    }

    #[test]
    fn clones_share_the_registry() {
        let mut st = ServeStats::new();
        let view = st.clone();
        st.record_admission(None);
        assert_eq!(view.admissions(), 1, "clones are views over the same metrics");
    }

    #[test]
    fn deadline_recording_counts_and_closes_spans() {
        let mut st = ServeStats::new();
        st.enable_trace();
        // a queued expiry: only the "request" span is open
        if let Some(t) = st.trace_mut() {
            t.begin("request", 0, vec![]);
        }
        let mut r = resp(0, 0, 0.05);
        r.finish = FinishReason::Deadline;
        st.record_deadline(&r, false);
        // an active expiry: both spans are open
        if let Some(t) = st.trace_mut() {
            t.begin("request", 1, vec![]);
            t.begin("resident", 1, vec![]);
        }
        let mut r = resp(1, 2, 0.07);
        r.finish = FinishReason::Deadline;
        st.record_deadline(&r, true);
        assert_eq!(st.deadline_expired(), 2);
        assert_eq!(st.completed(), 2, "expiries count as completions");
        assert_eq!(st.gen_tokens(), 2, "partial tokens delivered are counted");
        assert_eq!(st.prompt_tokens(), 0, "expired prompts were not (fully) fed");
        assert!(crate::telemetry::check_well_nested(st.trace_events()).is_ok());
        let j = st.bench_json("deadline", vec![]);
        assert_eq!(j.get("deadline_expired").as_usize(), Some(2));
    }

    #[test]
    fn never_admitted_expiry_leaves_ttft_histogram_empty() {
        // the TTFT-pollution regression: a queued request that expired
        // before emitting any token must not contribute a first-token
        // sample (its "TTFT" would just be its queue wait, skewing
        // p95/p99), while total/queue latency still count it
        let mut st = ServeStats::new();
        let mut r = resp(0, 0, 5.0); // 0 tokens, waited 5 s in queue
        r.ttft_s = 5.0;
        r.finish = FinishReason::Deadline;
        st.record_deadline(&r, false);
        let snap = st.registry().snapshot_json();
        assert_eq!(
            snap.get("serve.latency_ttft_s").get("count").as_usize(),
            Some(0),
            "never-admitted expiry must not record a TTFT sample"
        );
        assert_eq!(snap.get("serve.latency_total_s").get("count").as_usize(), Some(1));
        assert_eq!(snap.get("serve.latency_queue_s").get("count").as_usize(), Some(1));
        assert_eq!(st.completed(), 1, "latency totals still count the expiry");
        assert_eq!(st.p95_ttft_ms(), 0.0, "percentiles stay clean");
        // an expiry that DID deliver tokens keeps its genuine TTFT sample
        let mut r = resp(1, 2, 0.07);
        r.ttft_s = 0.03;
        r.finish = FinishReason::Deadline;
        st.record_deadline(&r, true);
        let snap = st.registry().snapshot_json();
        assert_eq!(snap.get("serve.latency_ttft_s").get("count").as_usize(), Some(1));
    }

    #[test]
    fn spec_counters_aggregate_and_flow_to_bench_json() {
        let mut st = ServeStats::new();
        assert_eq!(st.spec_acceptance_rate(), 0.0, "no rounds: rate is 0");
        // spec keys only appear once a round ran (like the drift keys)
        assert_eq!(*st.bench_json("spec", vec![]).get("spec_rounds"), Json::Null);
        st.record_spec(4, 4); // accept-all round
        st.record_spec(4, 1); // mostly rejected round
        st.record_spec(2, 0); // rollback-all round
        assert_eq!(st.spec_rounds(), 3);
        assert_eq!(st.spec_drafted(), 10);
        assert_eq!(st.spec_accepted(), 5);
        assert!((st.spec_acceptance_rate() - 0.5).abs() < 1e-12);
        let j = st.bench_json("spec", vec![]);
        assert_eq!(j.get("spec_rounds").as_usize(), Some(3));
        assert_eq!(j.get("spec_drafted").as_usize(), Some(10));
        assert_eq!(j.get("spec_accepted").as_usize(), Some(5));
        assert_eq!(j.get("spec_acceptance_rate").as_f64(), Some(0.5));
        let snap = st.registry().snapshot_json();
        assert_eq!(snap.get("serve.spec_rounds").as_usize(), Some(3));
        assert_eq!(snap.get("serve.spec_accepted").as_usize(), Some(5));
        let text = st.render("spec");
        assert!(text.contains("spec decode"), "{text}");
        assert!(text.contains("50% rate"), "{text}");
    }

    #[test]
    fn wave_batch_aggregates_and_flows_to_bench_json() {
        let mut st = ServeStats::new();
        assert_eq!(st.wave_batch_waves(), 0);
        // like the spec/drift keys, absent until a wave actually batched
        assert_eq!(*st.bench_json("wb", vec![]).get("wave_batch_waves"), Json::Null);
        st.record_wave_batch(0); // nothing to batch: no sample
        assert_eq!(st.wave_batch_waves(), 0);
        st.record_wave_batch(4);
        st.record_wave_batch(2);
        assert_eq!(st.wave_batch_waves(), 2);
        assert_eq!(st.max_wave_batch(), 4);
        assert!((st.mean_wave_batch() - 3.0).abs() < 1e-12);
        let j = st.bench_json("wb", vec![]);
        assert_eq!(j.get("wave_batch_waves").as_usize(), Some(2));
        assert_eq!(j.get("wave_batch_max").as_usize(), Some(4));
        assert_eq!(j.get("wave_batch_mean").as_f64(), Some(3.0));
        let snap = st.registry().snapshot_json();
        assert_eq!(snap.get("serve.wave_batch_size").get("count").as_usize(), Some(2));
    }

    #[test]
    fn trace_records_completion_spans() {
        let mut st = ServeStats::new();
        assert!(st.trace_events().is_empty());
        st.enable_trace();
        if let Some(t) = st.trace_mut() {
            t.begin("request", 0, vec![]);
            t.begin("resident", 0, vec![]);
        }
        st.record_completion(&resp(0, 3, 0.02));
        let names: Vec<&str> = st.trace_events().iter().map(|e| e.name).collect();
        assert_eq!(names, vec!["request", "resident", "resident", "request"]);
        assert!(crate::telemetry::check_well_nested(st.trace_events()).is_ok());
    }
}
