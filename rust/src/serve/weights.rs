//! Low-precision MX weight store for serving: linear weights are snapshotted
//! as square-blockwise (32×32) groups with one power-of-two scale per block
//! and *bit-packed element codes* in the target FP format (BF16 → 2 bytes,
//! FP8/FP6/FP4 → 1 byte per element). Dequantization happens per block on
//! load, reproducing exactly what `mx::quantize_square` would emit — so the
//! serving path inherits the Table C.1 fidelity claims of the training-time
//! grouping.
//!
//! Non-linear tensors (embeddings, norms) stay f32: they are a small
//! fraction of the parameters and the paper's claim covers the PQT linears.
//!
//! On-disk format (`GWQS1`), little-endian:
//!
//! ```text
//! magic "GWQS1\n"
//! u32 label_len | label bytes                 (store mode, e.g. "fp8_e3m4")
//! u32 arch_len  | arch bytes                  ("gpt2" | "llama2")
//! u64 ×6: n_layer d_model n_head d_ff vocab seq_len
//! u64 block
//! u8 elem tag: 0 = f32 (no quantization), 1 = FP(e,m,inf,sat)
//! if FP: u8 exp_bits | u8 man_bits | u8 has_inf_nan | u8 saturating
//! u32 n_tensors
//! per tensor:
//!   u32 name_len | name | u64 rows | u64 cols
//!   u8 kind: 0 = raw f32, 1 = u8 codes, 2 = u16 codes
//!   raw:   rows*cols × f32
//!   coded: u64 n_scales | n_scales × f32 | rows*cols × (u8|u16)
//! ```

use crate::config::schema::{Arch, ModelConfig};
use crate::mx::{quantize_square, ElemType};
use crate::nn::tensor::Mat;
use crate::nn::transformer::Params;
use crate::numerics::fpformat::{formats, FpFormat, Overflow};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 6] = b"GWQS1\n";

/// Encode a value exactly representable in `fmt` into its sign/exp/mantissa
/// code (at most 16 bits for every format this crate defines).
pub fn encode_code(fmt: &FpFormat, v: f64) -> u16 {
    let m = fmt.man_bits;
    let sign: u16 = if v.is_sign_negative() { 1 << (fmt.exp_bits + m) } else { 0 };
    let a = v.abs();
    if a == 0.0 {
        return sign;
    }
    if a.is_infinite() {
        // only reachable for has_inf_nan formats
        return sign | ((((1u32 << fmt.exp_bits) - 1) as u16) << m);
    }
    let e = a.log2().floor() as i32;
    if e < fmt.min_normal_exp() {
        // subnormal: mantissa counts the min-subnormal step
        let man = (a / fmt.min_subnormal()).round() as u16;
        sign | man
    } else {
        let exp_code = (e + fmt.bias()) as u16;
        let frac = a / (e as f64).exp2() - 1.0; // in [0, 1)
        let man = (frac * (1u64 << m) as f64).round() as u16;
        sign | (exp_code << m) | man
    }
}

/// Decode a code produced by [`encode_code`] back to its exact value.
pub fn decode_code(fmt: &FpFormat, code: u16) -> f64 {
    let m = fmt.man_bits;
    let man = (code & ((1u16 << m) - 1)) as u32;
    let exp_code = ((code >> m) as u32) & ((1u32 << fmt.exp_bits) - 1);
    let sign = if (code >> (fmt.exp_bits + m)) & 1 == 1 { -1.0 } else { 1.0 };
    if exp_code == 0 {
        return sign * man as f64 * fmt.min_subnormal();
    }
    if fmt.has_inf_nan && exp_code == (1u32 << fmt.exp_bits) - 1 {
        return if man == 0 { sign * f64::INFINITY } else { f64::NAN };
    }
    let e = exp_code as i32 - fmt.bias();
    sign * (1.0 + man as f64 / (1u64 << m) as f64) * (e as f64).exp2()
}

/// The element storage mode of a store.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum StoreElem {
    /// Keep master f32 (no quantization) — the fidelity baseline.
    F32,
    /// Bit-packed low-precision FP elements with per-block po2 scales.
    Fp(FpFormat),
}

impl StoreElem {
    /// Parse a CLI/store-mode name: `f32`/`master`, or any
    /// `numerics::formats::by_name` format of at most 16 total bits
    /// (bf16, fp12_e4m7, fp8_e3m4, fp6_e3m2, ...). The packed code path
    /// stores one `u16` per element, so wider formats (fp32) are only
    /// servable unquantized via `f32`.
    pub fn parse(name: &str) -> Result<StoreElem> {
        match name.to_ascii_lowercase().as_str() {
            "f32" | "fp32" | "master" | "none" => Ok(StoreElem::F32),
            other => {
                let fmt = formats::by_name(other)
                    .with_context(|| format!("unknown weight-store mode '{other}'"))?;
                if fmt.total_bits() > 16 {
                    bail!("weight-store mode '{other}' is {} bits; max packed width is 16 (use 'f32' for unquantized serving)", fmt.total_bits());
                }
                Ok(StoreElem::Fp(fmt))
            }
        }
    }

    pub fn name(&self) -> String {
        match self {
            StoreElem::F32 => "f32".to_string(),
            StoreElem::Fp(f) => format!("fp{}_e{}m{}", f.total_bits(), f.exp_bits, f.man_bits),
        }
    }
}

/// Packed element payload of one stored tensor.
#[derive(Debug, Clone, PartialEq)]
pub enum Codes {
    /// Unquantized master weights.
    F32(Vec<f32>),
    /// One byte per element (formats with ≤ 8 total bits).
    U8(Vec<u8>),
    /// Two bytes per element (BF16 and other 9–16 bit formats).
    U16(Vec<u16>),
}

impl Codes {
    pub fn len(&self) -> usize {
        match self {
            Codes::F32(v) => v.len(),
            Codes::U8(v) => v.len(),
            Codes::U16(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Payload bytes (the compression the store actually achieves).
    pub fn bytes(&self) -> usize {
        match self {
            Codes::F32(v) => v.len() * 4,
            Codes::U8(v) => v.len(),
            Codes::U16(v) => v.len() * 2,
        }
    }
}

/// One tensor in the store.
#[derive(Debug, Clone, PartialEq)]
pub struct StoredTensor {
    pub rows: usize,
    pub cols: usize,
    /// Per-block po2 scales, row-major over the ⌈rows/b⌉ × ⌈cols/b⌉ grid.
    /// Empty for raw-f32 tensors.
    pub scales: Vec<f32>,
    pub codes: Codes,
}

impl StoredTensor {
    pub fn bytes(&self) -> usize {
        self.scales.len() * 4 + self.codes.bytes()
    }
}

/// A quantized snapshot of a model's parameters, ready to serve.
#[derive(Debug, Clone)]
pub struct WeightStore {
    pub cfg: ModelConfig,
    pub elem: StoreElem,
    pub block: usize,
    pub tensors: BTreeMap<String, StoredTensor>,
}

impl WeightStore {
    /// Snapshot `params`: linear weights are MX-quantized square-blockwise
    /// and bit-packed in the `elem` format; everything else stays f32.
    pub fn from_params(
        params: &Params,
        cfg: &ModelConfig,
        elem: StoreElem,
        block: usize,
    ) -> WeightStore {
        assert!(block > 0, "block size must be positive");
        let linears: std::collections::BTreeSet<String> =
            Params::linear_names(cfg).into_iter().collect();
        let mut tensors = BTreeMap::new();
        for (name, m) in &params.tensors {
            let st = match (&elem, linears.contains(name)) {
                (StoreElem::Fp(fmt), true) => pack_matrix(m, fmt, block),
                _ => StoredTensor {
                    rows: m.rows,
                    cols: m.cols,
                    scales: Vec::new(),
                    codes: Codes::F32(m.data.clone()),
                },
            };
            tensors.insert(name.clone(), st);
        }
        WeightStore { cfg: cfg.clone(), elem, block, tensors }
    }

    /// Snapshot straight from a training checkpoint (the train→serve hop).
    pub fn from_checkpoint(
        ck: &crate::coordinator::Checkpoint,
        cfg: &ModelConfig,
        elem: StoreElem,
        block: usize,
    ) -> Result<WeightStore> {
        let params = ck.to_params(cfg)?;
        Ok(WeightStore::from_params(&params, cfg, elem, block))
    }

    /// Dequantize every tensor back to f32 [`Params`] (per block: decode the
    /// element code, multiply by the block scale). For quantized linears the
    /// result is bit-identical to `mx::quantize_square` of the original
    /// weights cast to f32.
    pub fn to_params(&self) -> Params {
        let mut tensors = BTreeMap::new();
        for (name, st) in &self.tensors {
            tensors.insert(name.clone(), unpack_matrix(st, &self.elem, self.block));
        }
        Params { tensors }
    }

    /// Total payload bytes (scales + codes) across all tensors.
    pub fn bytes(&self) -> usize {
        self.tensors.values().map(|t| t.bytes()).sum()
    }

    /// Bytes the same tensors occupy as master f32.
    pub fn master_bytes(&self) -> usize {
        self.tensors.values().map(|t| t.rows * t.cols * 4).sum()
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        if let Some(dir) = path.as_ref().parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(MAGIC)?;
        write_str(&mut f, &self.elem.name())?;
        write_str(&mut f, self.cfg.arch.name())?;
        for v in [
            self.cfg.n_layer,
            self.cfg.d_model,
            self.cfg.n_head,
            self.cfg.d_ff,
            self.cfg.vocab,
            self.cfg.seq_len,
            self.block,
        ] {
            f.write_all(&(v as u64).to_le_bytes())?;
        }
        match &self.elem {
            StoreElem::F32 => f.write_all(&[0u8])?,
            StoreElem::Fp(fmt) => {
                f.write_all(&[1u8])?;
                f.write_all(&[
                    fmt.exp_bits as u8,
                    fmt.man_bits as u8,
                    fmt.has_inf_nan as u8,
                    (fmt.overflow == Overflow::Saturate) as u8,
                ])?;
            }
        }
        f.write_all(&(self.tensors.len() as u32).to_le_bytes())?;
        for (name, st) in &self.tensors {
            write_str(&mut f, name)?;
            f.write_all(&(st.rows as u64).to_le_bytes())?;
            f.write_all(&(st.cols as u64).to_le_bytes())?;
            match &st.codes {
                Codes::F32(v) => {
                    f.write_all(&[0u8])?;
                    for x in v {
                        f.write_all(&x.to_le_bytes())?;
                    }
                }
                Codes::U8(v) => {
                    f.write_all(&[1u8])?;
                    write_scales(&mut f, &st.scales)?;
                    f.write_all(v)?;
                }
                Codes::U16(v) => {
                    f.write_all(&[2u8])?;
                    write_scales(&mut f, &st.scales)?;
                    for x in v {
                        f.write_all(&x.to_le_bytes())?;
                    }
                }
            }
        }
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<WeightStore> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(&path)
                .with_context(|| format!("opening weight store {}", path.as_ref().display()))?,
        );
        let mut magic = [0u8; 6];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("bad weight-store magic (not a GWQS1 file)");
        }
        let label = read_str(&mut f)?;
        let arch = Arch::parse(&read_str(&mut f)?)?;
        let mut dims = [0usize; 7];
        for d in dims.iter_mut() {
            *d = read_u64(&mut f)? as usize;
        }
        let cfg = ModelConfig {
            arch,
            n_layer: dims[0],
            d_model: dims[1],
            n_head: dims[2],
            d_ff: dims[3],
            vocab: dims[4],
            seq_len: dims[5],
        };
        cfg.validate()?;
        let block = dims[6];
        if block == 0 || block > 1 << 16 {
            bail!("unreasonable block size {block} in weight store");
        }
        let mut tag = [0u8; 1];
        f.read_exact(&mut tag)?;
        let elem = match tag[0] {
            0 => StoreElem::F32,
            1 => {
                let mut fb = [0u8; 4];
                f.read_exact(&mut fb)?;
                StoreElem::Fp(FpFormat {
                    exp_bits: fb[0] as u32,
                    man_bits: fb[1] as u32,
                    has_inf_nan: fb[2] != 0,
                    overflow: if fb[3] != 0 { Overflow::Saturate } else { Overflow::Infinity },
                })
            }
            other => bail!("unknown elem tag {other} in weight store"),
        };
        if let StoreElem::Fp(f) = &elem {
            if f.exp_bits == 0 || f.exp_bits > 8 || f.total_bits() > 16 {
                bail!(
                    "unsupported packed format e{}m{} in weight store",
                    f.exp_bits,
                    f.man_bits
                );
            }
        }
        if elem.name() != label {
            bail!("weight store label '{label}' disagrees with format descriptor '{}'", elem.name());
        }
        let mut u32b = [0u8; 4];
        f.read_exact(&mut u32b)?;
        let n = u32::from_le_bytes(u32b);
        let mut tensors = BTreeMap::new();
        for _ in 0..n {
            let name = read_str(&mut f)?;
            let rows = read_u64(&mut f)? as usize;
            let cols = read_u64(&mut f)? as usize;
            f.read_exact(&mut tag)?;
            let numel = rows * cols;
            let (scales, codes) = match tag[0] {
                0 => (Vec::new(), Codes::F32(read_f32s(&mut f, numel)?)),
                1 => {
                    let scales = read_scales(&mut f)?;
                    let mut bytes = vec![0u8; numel];
                    f.read_exact(&mut bytes)?;
                    (scales, Codes::U8(bytes))
                }
                2 => {
                    let scales = read_scales(&mut f)?;
                    let mut bytes = vec![0u8; numel * 2];
                    f.read_exact(&mut bytes)?;
                    let v = bytes
                        .chunks_exact(2)
                        .map(|c| u16::from_le_bytes([c[0], c[1]]))
                        .collect();
                    (scales, Codes::U16(v))
                }
                other => bail!("unknown tensor kind {other} in weight store"),
            };
            if elem == StoreElem::F32 && !matches!(codes, Codes::F32(_)) {
                bail!("tensor '{name}': coded payload in an f32 store");
            }
            let expect_scales = if matches!(codes, Codes::F32(_)) {
                0
            } else {
                rows.div_ceil(block) * cols.div_ceil(block)
            };
            if scales.len() != expect_scales {
                bail!("tensor '{name}': {} scales, expected {expect_scales}", scales.len());
            }
            tensors.insert(name, StoredTensor { rows, cols, scales, codes });
        }
        Ok(WeightStore { cfg, elem, block, tensors })
    }
}

/// Quantize + bit-pack one matrix.
fn pack_matrix(m: &Mat, fmt: &FpFormat, block: usize) -> StoredTensor {
    let w64: Vec<f64> = m.data.iter().map(|&x| x as f64).collect();
    let q = quantize_square(&w64, m.rows, m.cols, block, &ElemType::Fp(*fmt));
    let grid_c = m.cols.div_ceil(block);
    let encode_at = |i: usize| -> u16 {
        let (r, c) = (i / m.cols, i % m.cols);
        let s = q.scales[(r / block) * grid_c + c / block];
        encode_code(fmt, q.data[i] / s)
    };
    let codes = if fmt.total_bits() <= 8 {
        Codes::U8((0..q.data.len()).map(|i| encode_at(i) as u8).collect())
    } else {
        Codes::U16((0..q.data.len()).map(encode_at).collect())
    };
    StoredTensor {
        rows: m.rows,
        cols: m.cols,
        scales: q.scales.iter().map(|&s| s as f32).collect(),
        codes,
    }
}

/// Dequantize one stored tensor back to an f32 matrix (per-block decode).
fn unpack_matrix(st: &StoredTensor, elem: &StoreElem, block: usize) -> Mat {
    match (&st.codes, elem) {
        (Codes::F32(v), _) => Mat::from_vec(st.rows, st.cols, v.clone()),
        (codes, StoreElem::Fp(fmt)) => {
            let grid_c = st.cols.div_ceil(block);
            let mut data = vec![0f32; st.rows * st.cols];
            for (i, out) in data.iter_mut().enumerate() {
                let (r, c) = (i / st.cols, i % st.cols);
                let s = st.scales[(r / block) * grid_c + c / block] as f64;
                let code = match codes {
                    Codes::U8(v) => v[i] as u16,
                    Codes::U16(v) => v[i],
                    Codes::F32(_) => unreachable!(),
                };
                *out = (decode_code(fmt, code) * s) as f32;
            }
            Mat::from_vec(st.rows, st.cols, data)
        }
        (_, StoreElem::F32) => {
            unreachable!("coded tensor in an f32 store")
        }
    }
}

fn write_str(f: &mut impl Write, s: &str) -> Result<()> {
    f.write_all(&(s.len() as u32).to_le_bytes())?;
    f.write_all(s.as_bytes())?;
    Ok(())
}

fn read_str(f: &mut impl Read) -> Result<String> {
    let mut u32b = [0u8; 4];
    f.read_exact(&mut u32b)?;
    let len = u32::from_le_bytes(u32b) as usize;
    if len > 1 << 20 {
        bail!("unreasonable string length {len} in weight store");
    }
    let mut bytes = vec![0u8; len];
    f.read_exact(&mut bytes)?;
    String::from_utf8(bytes).context("weight-store string utf8")
}

fn read_u64(f: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    f.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn write_scales(f: &mut impl Write, scales: &[f32]) -> Result<()> {
    f.write_all(&(scales.len() as u64).to_le_bytes())?;
    for s in scales {
        f.write_all(&s.to_le_bytes())?;
    }
    Ok(())
}

fn read_scales(f: &mut impl Read) -> Result<Vec<f32>> {
    let n = read_u64(f)? as usize;
    read_f32s(f, n)
}

fn read_f32s(f: &mut impl Read, n: usize) -> Result<Vec<f32>> {
    let mut bytes = vec![0u8; n * 4];
    f.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::schema::Arch;
    use crate::nn::transformer::Transformer;
    use crate::testing::prop::{check, Gen};

    #[test]
    fn codes_roundtrip_exhaustively_for_tiny_formats() {
        for fmt in [formats::FP8_E3M4, formats::FP8_E4M3, formats::FP6_E3M2, formats::FP4_E2M1] {
            let max_code = 1u32 << fmt.total_bits();
            for v in fmt.enumerate_non_negative() {
                for signed in [v, -v] {
                    let code = encode_code(&fmt, signed);
                    assert!((code as u32) < max_code, "{fmt:?}: code {code} overflows");
                    let back = decode_code(&fmt, code);
                    // -0.0 decodes to -0.0; compare bit-exactly via total order
                    assert_eq!(back, signed, "{fmt:?}: {signed} -> {code} -> {back}");
                }
            }
        }
    }

    #[test]
    fn codes_roundtrip_bf16_samples() {
        check("bf16 code roundtrip", 50, |g: &mut Gen| {
            let x = g.f64_in(-100.0, 100.0);
            let v = formats::BF16.cast(x);
            let code = encode_code(&formats::BF16, v);
            let back = decode_code(&formats::BF16, code);
            if back == v {
                Ok(())
            } else {
                Err(format!("{v} -> {code} -> {back}"))
            }
        });
    }

    #[test]
    fn store_matches_quantize_square_exactly() {
        // dequantize-on-load must reproduce the fq_inference quantization
        // path bit-for-bit (same blocks, same scales, same element cast)
        let cfg = ModelConfig::tiny(Arch::Gpt2);
        let model = Transformer::new(cfg.clone());
        let params = model.init_params(5);
        for fmt in [formats::BF16, formats::FP8_E3M4, formats::FP6_E3M2] {
            let store = WeightStore::from_params(&params, &cfg, StoreElem::Fp(fmt), 32);
            let served = store.to_params();
            for name in Params::linear_names(&cfg) {
                let m = params.get(&name);
                let w64: Vec<f64> = m.data.iter().map(|&x| x as f64).collect();
                let q = quantize_square(&w64, m.rows, m.cols, 32, &ElemType::Fp(fmt));
                let got = served.get(&name);
                for (i, (&g, &want)) in got.data.iter().zip(q.data.iter()).enumerate() {
                    assert_eq!(g, want as f32, "{name}[{i}] under {fmt:?}");
                }
            }
            // non-linear tensors pass through untouched
            assert_eq!(served.get("embed").data, params.get("embed").data);
        }
    }

    #[test]
    fn store_compresses_linears() {
        let cfg = ModelConfig::tiny(Arch::Gpt2);
        let model = Transformer::new(cfg.clone());
        let params = model.init_params(6);
        let fp8 = WeightStore::from_params(&params, &cfg, StoreElem::Fp(formats::FP8_E3M4), 32);
        let f32s = WeightStore::from_params(&params, &cfg, StoreElem::F32, 32);
        assert!(fp8.bytes() < f32s.bytes(), "{} !< {}", fp8.bytes(), f32s.bytes());
        assert_eq!(f32s.bytes(), f32s.master_bytes());
    }

    #[test]
    fn save_load_roundtrip() {
        let cfg = ModelConfig::tiny(Arch::Llama2);
        let model = Transformer::new(cfg.clone());
        let params = model.init_params(7);
        let store = WeightStore::from_params(&params, &cfg, StoreElem::Fp(formats::FP8_E4M3), 32);
        let path = std::env::temp_dir().join("gaussws_store_test.gwqs");
        store.save(&path).unwrap();
        let back = WeightStore::load(&path).unwrap();
        assert_eq!(back.cfg, cfg);
        assert_eq!(back.elem, store.elem);
        assert_eq!(back.block, 32);
        assert_eq!(back.tensors, store.tensors);
        let a = store.to_params();
        let b = back.to_params();
        for (name, m) in &a.tensors {
            assert_eq!(m, b.get(name), "{name}");
        }
    }

    #[test]
    fn corrupt_store_rejected() {
        let path = std::env::temp_dir().join("gaussws_store_bad.gwqs");
        std::fs::write(&path, b"NOTGWQSjunk").unwrap();
        assert!(WeightStore::load(&path).is_err());
    }

    #[test]
    fn store_elem_parse_names() {
        assert_eq!(StoreElem::parse("f32").unwrap(), StoreElem::F32);
        // fp32 cannot be bit-packed into u16 codes: served unquantized
        assert_eq!(StoreElem::parse("fp32").unwrap(), StoreElem::F32);
        assert_eq!(StoreElem::parse("bf16").unwrap(), StoreElem::Fp(formats::BF16));
        assert_eq!(StoreElem::parse("fp8_e3m4").unwrap(), StoreElem::Fp(formats::FP8_E3M4));
        assert!(StoreElem::parse("fp99").is_err());
        assert_eq!(StoreElem::Fp(formats::FP6_E3M2).name(), "fp6_e3m2");
        assert_eq!(StoreElem::Fp(formats::BF16).name(), "fp16_e8m7");
    }
}
