//! Low-precision MX weight store for serving: linear weights are snapshotted
//! as square-blockwise (default 32×32) groups with one power-of-two scale
//! per block and *bit-packed element codes* at the codec's true width
//! ([`crate::quant::PackedCodes`]: BF16 → 16 bits, FP8/INT8 → 8, FP6 → 6,
//! FP4/INT4 → 4 bits per element — no byte padding). Dequantization
//! happens per block on load through the codec's
//! [`crate::quant::DequantLut`], reproducing exactly what the scheme's
//! [`QuantScheme::quantize`] would emit — so the serving path inherits the
//! Table C.1 fidelity claims of the training-time grouping.
//!
//! Which quantization applies is described by a [`crate::quant::Scheme`]
//! resolved from a label through [`crate::quant::Registry`] — the same
//! registry the trainer and the CLI parse labels with. Stochastic-rounding
//! schemes (`int8_sr`, `fp4_e2m1_sr`, …) snapshot with a deterministic
//! per-tensor seed so a store is reproducible byte-for-byte.
//!
//! Non-linear tensors (embeddings, norms) stay f32: they are a small
//! fraction of the parameters and the paper's claim covers the PQT linears.
//!
//! On-disk format (`GWQS3`), little-endian:
//!
//! ```text
//! magic "GWQS3\n"
//! u32 label_len | label bytes                 (canonical scheme label)
//! u8 codec tag: 0 = f32 | 1 = fp | 2 = int
//!   fp:  u8 exp_bits | u8 man_bits | u8 has_inf_nan | u8 saturating
//!   int: u8 bits
//! u8 rounding: 0 = rne | 1 = toward-zero | 2 = stochastic
//! u8 geometry: 0 = none | 1 = square (then u64 block)
//! u32 arch_len  | arch bytes                  ("gpt2" | "llama2")
//! u64 ×6: n_layer d_model n_head d_ff vocab seq_len
//! u32 n_tensors
//! per tensor:
//!   u32 name_len | name | u64 rows | u64 cols
//!   u8 kind: 0 = raw f32, 3 = packed codes
//!   raw:    rows*cols × f32
//!   packed: u64 n_scales | n_scales × f32
//!           u32 bits | u64 n_codes | ⌈n_codes·bits/8⌉ bytes (LSB-first)
//! ```
//!
//! The previous layouts stay readable: `GWQS2` (PR 4: same header, element
//! codes padded to one/two bytes — kinds 1/2) and `GWQS1` (PR 1: FP-only,
//! RNE, square-blockwise). Legacy code payloads are re-packed to the dense
//! sub-byte layout on load, so in memory every store looks like GWQS3;
//! [`WeightStore::save`] always writes GWQS3.

use crate::config::schema::{Arch, ModelConfig};
use crate::nn::tensor::Mat;
use crate::nn::transformer::Params;
use crate::numerics::fpformat::{FpFormat, Overflow, Rounding};
use crate::quant::{packed_bytes, Codec, DequantLut, Geometry, PackedCodes, QuantScheme, Scheme};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC_V3: &[u8; 6] = b"GWQS3\n";
const MAGIC_V2: &[u8; 6] = b"GWQS2\n";
const MAGIC_V1: &[u8; 6] = b"GWQS1\n";

/// Element payload of one stored tensor.
#[derive(Debug, Clone, PartialEq)]
pub enum Codes {
    /// Unquantized master weights.
    F32(Vec<f32>),
    /// Element codes packed densely at the codec's true bit width (GWQS3;
    /// GWQS1/2 byte-padded payloads are re-packed to this on load).
    Packed(PackedCodes),
}

impl Codes {
    pub fn len(&self) -> usize {
        match self {
            Codes::F32(v) => v.len(),
            Codes::Packed(pc) => pc.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Payload bytes (the compression the store actually achieves — true
    /// packed bytes, not a padded byte per code).
    pub fn bytes(&self) -> usize {
        match self {
            Codes::F32(v) => v.len() * 4,
            Codes::Packed(pc) => pc.byte_len(),
        }
    }
}

/// One tensor in the store.
#[derive(Debug, Clone, PartialEq)]
pub struct StoredTensor {
    pub rows: usize,
    pub cols: usize,
    /// Per-block po2 scales, row-major over the ⌈rows/b⌉ × ⌈cols/b⌉ grid.
    /// Empty for raw-f32 tensors.
    pub scales: Vec<f32>,
    pub codes: Codes,
}

impl StoredTensor {
    pub fn bytes(&self) -> usize {
        self.scales.len() * 4 + self.codes.bytes()
    }
}

/// A quantized snapshot of a model's parameters, ready to serve.
#[derive(Debug, Clone)]
pub struct WeightStore {
    pub cfg: ModelConfig,
    /// The quantization scheme linear weights were packed with (geometry
    /// carries the block size). `Codec::F32` schemes store everything raw.
    pub scheme: Scheme,
    pub tensors: BTreeMap<String, StoredTensor>,
}

impl WeightStore {
    /// Snapshot `params`: linear weights are quantized under `scheme` and
    /// bit-packed through its codec; everything else stays f32. Packed
    /// schemes must be square-blockwise (the serving store's scale layout);
    /// vector-wise and elementwise packed schemes are rejected.
    ///
    /// `seed` salts the per-tensor stochastic-rounding draws (via
    /// [`crate::quant::tensor_seed`], the same derivation
    /// `Params::quantize_linears` uses) — pass the checkpoint's master seed
    /// so an SR store serves exactly the weights `gaussws quantize`
    /// evaluated. Ignored by deterministic schemes.
    pub fn from_params(
        params: &Params,
        cfg: &ModelConfig,
        scheme: Scheme,
        seed: u64,
    ) -> Result<WeightStore> {
        if scheme.codec.is_packed() {
            match scheme.geometry {
                Geometry::Square { block } => {
                    if block == 0 {
                        bail!("block size must be positive");
                    }
                }
                other => bail!(
                    "weight store requires a square-blockwise scheme, got {other:?} \
                     (vector-wise / elementwise stores are not supported yet)"
                ),
            }
            if scheme.codec.total_bits() > 16 {
                bail!(
                    "scheme '{}' packs {} bits/element; max packed width is 16 \
                     (use 'f32' for unquantized serving)",
                    scheme.label(),
                    scheme.codec.total_bits()
                );
            }
        }
        let linears: std::collections::BTreeSet<String> =
            Params::linear_names(cfg).into_iter().collect();
        let mut tensors = BTreeMap::new();
        for (name, m) in &params.tensors {
            let st = if scheme.codec.is_packed() && linears.contains(name) {
                pack_matrix(m, &scheme, crate::quant::tensor_seed(name, seed))
            } else {
                StoredTensor {
                    rows: m.rows,
                    cols: m.cols,
                    scales: Vec::new(),
                    codes: Codes::F32(m.data.clone()),
                }
            };
            tensors.insert(name.clone(), st);
        }
        Ok(WeightStore { cfg: cfg.clone(), scheme, tensors })
    }

    /// Snapshot straight from a training checkpoint (the train→serve hop).
    /// SR draws are salted with the checkpoint's master seed, matching
    /// [`crate::coordinator::Checkpoint::to_quantized_params`].
    pub fn from_checkpoint(
        ck: &crate::coordinator::Checkpoint,
        cfg: &ModelConfig,
        scheme: Scheme,
    ) -> Result<WeightStore> {
        let params = ck.to_params(cfg)?;
        WeightStore::from_params(&params, cfg, scheme, ck.master_seed)
    }

    /// The square block size of the packing geometry (1 for raw-f32 stores).
    pub fn block(&self) -> usize {
        self.scheme.block().unwrap_or(1)
    }

    /// Canonical label of the packing scheme.
    pub fn label(&self) -> &str {
        self.scheme.label()
    }

    /// Dequantize every tensor back to f32 [`Params`] (per block: decode the
    /// element code, multiply by the block scale). For quantized linears the
    /// result is bit-identical to the scheme's fake-quant of the original
    /// weights cast to f32.
    pub fn to_params(&self) -> Params {
        let mut tensors = BTreeMap::new();
        for (name, st) in &self.tensors {
            tensors.insert(name.clone(), unpack_matrix(st, &self.scheme));
        }
        Params { tensors }
    }

    /// Total payload bytes (scales + codes) across all tensors.
    pub fn bytes(&self) -> usize {
        self.tensors.values().map(|t| t.bytes()).sum()
    }

    /// Bytes the same tensors occupy as master f32.
    pub fn master_bytes(&self) -> usize {
        self.tensors.values().map(|t| t.rows * t.cols * 4).sum()
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        if let Some(dir) = path.as_ref().parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(MAGIC_V3)?;
        write_str(&mut f, self.scheme.label())?;
        match &self.scheme.codec {
            Codec::F32 => f.write_all(&[0u8])?,
            Codec::Fp(fmt) => {
                f.write_all(&[1u8])?;
                f.write_all(&[
                    fmt.exp_bits as u8,
                    fmt.man_bits as u8,
                    fmt.has_inf_nan as u8,
                    (fmt.overflow == Overflow::Saturate) as u8,
                ])?;
            }
            Codec::Int { bits } => f.write_all(&[2u8, *bits as u8])?,
        }
        let rounding = match self.scheme.rounding {
            Rounding::NearestEven => 0u8,
            Rounding::TowardZero => 1,
            Rounding::Stochastic => 2,
        };
        f.write_all(&[rounding])?;
        match self.scheme.geometry {
            Geometry::None => f.write_all(&[0u8])?,
            Geometry::Square { block } => {
                f.write_all(&[1u8])?;
                f.write_all(&(block as u64).to_le_bytes())?;
            }
            Geometry::Vector { .. } => {
                bail!("vector-wise schemes cannot be saved to a weight store")
            }
        }
        write_str(&mut f, self.cfg.arch.name())?;
        for v in [
            self.cfg.n_layer,
            self.cfg.d_model,
            self.cfg.n_head,
            self.cfg.d_ff,
            self.cfg.vocab,
            self.cfg.seq_len,
        ] {
            f.write_all(&(v as u64).to_le_bytes())?;
        }
        f.write_all(&(self.tensors.len() as u32).to_le_bytes())?;
        for (name, st) in &self.tensors {
            write_str(&mut f, name)?;
            f.write_all(&(st.rows as u64).to_le_bytes())?;
            f.write_all(&(st.cols as u64).to_le_bytes())?;
            match &st.codes {
                Codes::F32(v) => {
                    f.write_all(&[0u8])?;
                    for x in v {
                        f.write_all(&x.to_le_bytes())?;
                    }
                }
                Codes::Packed(pc) => {
                    f.write_all(&[3u8])?;
                    write_scales(&mut f, &st.scales)?;
                    f.write_all(&pc.bits().to_le_bytes())?;
                    f.write_all(&(pc.len() as u64).to_le_bytes())?;
                    f.write_all(pc.as_bytes())?;
                }
            }
        }
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<WeightStore> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(&path)
                .with_context(|| format!("opening weight store {}", path.as_ref().display()))?,
        );
        let mut magic = [0u8; 6];
        f.read_exact(&mut magic)?;
        match &magic {
            // V3 and V2 share the header; they differ only in the tensor
            // payload kinds read_tensors accepts
            m if m == MAGIC_V3 || m == MAGIC_V2 => load_v2(&mut f),
            m if m == MAGIC_V1 => load_v1(&mut f),
            _ => bail!("bad weight-store magic (not a GWQS1/GWQS2/GWQS3 file)"),
        }
    }
}

fn read_codec(f: &mut impl Read) -> Result<Codec> {
    let mut tag = [0u8; 1];
    f.read_exact(&mut tag)?;
    let codec = match tag[0] {
        0 => Codec::F32,
        1 => {
            let mut fb = [0u8; 4];
            f.read_exact(&mut fb)?;
            Codec::Fp(FpFormat {
                exp_bits: fb[0] as u32,
                man_bits: fb[1] as u32,
                has_inf_nan: fb[2] != 0,
                overflow: if fb[3] != 0 { Overflow::Saturate } else { Overflow::Infinity },
            })
        }
        2 => {
            let mut b = [0u8; 1];
            f.read_exact(&mut b)?;
            Codec::Int { bits: b[0] as u32 }
        }
        other => bail!("unknown codec tag {other} in weight store"),
    };
    match &codec {
        Codec::Fp(fmt) => {
            if fmt.exp_bits == 0 || fmt.exp_bits > 8 || fmt.total_bits() > 16 {
                bail!(
                    "unsupported packed format e{}m{} in weight store",
                    fmt.exp_bits,
                    fmt.man_bits
                );
            }
        }
        Codec::Int { bits } => {
            if *bits < 2 || *bits > 16 {
                bail!("unsupported packed int{bits} in weight store");
            }
        }
        Codec::F32 => {}
    }
    Ok(codec)
}

fn read_model_cfg(f: &mut impl Read) -> Result<ModelConfig> {
    let arch = Arch::parse(&read_str(f)?)?;
    let mut dims = [0usize; 6];
    for d in dims.iter_mut() {
        *d = read_u64(f)? as usize;
    }
    let cfg = ModelConfig {
        arch,
        n_layer: dims[0],
        d_model: dims[1],
        n_head: dims[2],
        d_ff: dims[3],
        vocab: dims[4],
        seq_len: dims[5],
    };
    cfg.validate()?;
    Ok(cfg)
}

fn read_tensors(
    f: &mut impl Read,
    scheme: &Scheme,
) -> Result<BTreeMap<String, StoredTensor>> {
    let mut u32b = [0u8; 4];
    f.read_exact(&mut u32b)?;
    let n = u32::from_le_bytes(u32b);
    let mut tag = [0u8; 1];
    let mut tensors = BTreeMap::new();
    for _ in 0..n {
        let name = read_str(f)?;
        let rows = read_u64(f)? as usize;
        let cols = read_u64(f)? as usize;
        f.read_exact(&mut tag)?;
        let numel = rows * cols;
        if tag[0] != 0 && !scheme.codec.is_packed() {
            bail!("tensor '{name}': coded payload in an f32 store");
        }
        let (scales, codes) = match tag[0] {
            0 => (Vec::new(), Codes::F32(read_f32s(f, numel)?)),
            // GWQS1/2 legacy payloads: one or two bytes per code,
            // re-packed to the dense layout on load
            1 => {
                let scales = read_scales(f)?;
                let mut bytes = vec![0u8; numel];
                f.read_exact(&mut bytes)?;
                (scales, repack_legacy(bytes.iter().map(|&b| b as u16), numel, &scheme.codec)?)
            }
            2 => {
                let scales = read_scales(f)?;
                let mut bytes = vec![0u8; numel * 2];
                f.read_exact(&mut bytes)?;
                let it = bytes.chunks_exact(2).map(|c| u16::from_le_bytes([c[0], c[1]]));
                (scales, repack_legacy(it, numel, &scheme.codec)?)
            }
            // GWQS3: codes already densely packed on disk
            3 => {
                let scales = read_scales(f)?;
                let bits = read_u32(f)?;
                if bits != scheme.codec.bits_per_elem() {
                    bail!(
                        "tensor '{name}': packed at {bits} bits but scheme '{}' codes \
                         are {} bits wide",
                        scheme.label(),
                        scheme.codec.bits_per_elem()
                    );
                }
                let n_codes = read_u64(f)? as usize;
                if n_codes != numel {
                    bail!("tensor '{name}': {n_codes} packed codes for {numel} elements");
                }
                let mut bytes = vec![0u8; packed_bytes(bits, n_codes)];
                f.read_exact(&mut bytes)?;
                let pc = PackedCodes::from_bytes(bits, n_codes, bytes)
                    .with_context(|| format!("tensor '{name}': corrupt packed payload"))?;
                (scales, Codes::Packed(pc))
            }
            other => bail!("unknown tensor kind {other} in weight store"),
        };
        let expect_scales = if matches!(codes, Codes::F32(_)) {
            0
        } else {
            scheme.geometry.n_scales(rows, cols)
        };
        if scales.len() != expect_scales {
            bail!("tensor '{name}': {} scales, expected {expect_scales}", scales.len());
        }
        tensors.insert(name, StoredTensor { rows, cols, scales, codes });
    }
    Ok(tensors)
}

/// GWQS2/GWQS3 (shared header): self-describing scheme descriptor, label
/// cross-checked against the registry when the label is a registered one.
/// Tensor payloads may be byte-padded (V2 kinds 1/2, re-packed on load) or
/// densely packed (V3 kind 3).
fn load_v2(f: &mut impl Read) -> Result<WeightStore> {
    let label = read_str(f)?;
    let codec = read_codec(f)?;
    let mut b = [0u8; 1];
    f.read_exact(&mut b)?;
    let rounding = match b[0] {
        0 => Rounding::NearestEven,
        1 => Rounding::TowardZero,
        2 => Rounding::Stochastic,
        other => bail!("unknown rounding tag {other} in weight store"),
    };
    f.read_exact(&mut b)?;
    let geometry = match b[0] {
        0 => Geometry::None,
        1 => {
            let block = read_u64(f)? as usize;
            if block == 0 || block > 1 << 16 {
                bail!("unreasonable block size {block} in weight store");
            }
            Geometry::Square { block }
        }
        other => bail!("unknown geometry tag {other} in weight store"),
    };
    if codec.is_packed() && !matches!(geometry, Geometry::Square { .. }) {
        bail!(
            "weight store has a packed codec but non-square geometry \
             (corrupt or unsupported GWQS2 file)"
        );
    }
    let scheme = Scheme::new(&label, codec, rounding, geometry);
    // If the label is registered, its codec/rounding must agree with the
    // file's descriptor (block size may legitimately differ via --block).
    if let Ok(reg) = crate::quant::resolve(&label) {
        if reg.codec != scheme.codec || reg.rounding != scheme.rounding {
            bail!(
                "weight store label '{label}' disagrees with its scheme descriptor \
                 ({} vs registered {})",
                scheme.describe(),
                reg.describe()
            );
        }
    }
    let cfg = read_model_cfg(f)?;
    let tensors = read_tensors(f, &scheme)?;
    Ok(WeightStore { cfg, scheme, tensors })
}

/// GWQS1 (PR 1 layout): FP-only elem descriptor, RNE, square-blockwise,
/// block size carried as a seventh dim. Mapped onto the scheme API; the
/// canonical registry label is recovered when one matches.
fn load_v1(f: &mut impl Read) -> Result<WeightStore> {
    let label = read_str(f)?;
    // GWQS1 layout after the label: arch + the same six dims as GWQS2,
    // followed by the block size as a seventh u64
    let cfg = read_model_cfg(f)?;
    let block = read_u64(f)? as usize;
    if block == 0 || block > 1 << 16 {
        bail!("unreasonable block size {block} in weight store");
    }
    let codec = read_codec(f)?;
    if let Codec::Int { .. } = codec {
        bail!("GWQS1 stores cannot carry int codecs");
    }
    // GWQS1 wrote StoreElem::name(): "f32" or "fp{total}_e{e}m{m}"
    let legacy_name = match &codec {
        Codec::F32 => "f32".to_string(),
        Codec::Fp(f) => format!("fp{}_e{}m{}", f.total_bits(), f.exp_bits, f.man_bits),
        Codec::Int { .. } => unreachable!(),
    };
    if legacy_name != label {
        bail!("weight store label '{label}' disagrees with format descriptor '{legacy_name}'");
    }
    let geometry =
        if codec.is_packed() { Geometry::Square { block } } else { Geometry::None };
    // recover the canonical label if this (codec, RNE, square) is registered
    let canonical = crate::quant::Registry::global()
        .schemes()
        .iter()
        .find(|s| s.codec == codec && s.rounding == Rounding::NearestEven && s.codec.is_packed())
        .map(|s| s.label().to_string())
        .unwrap_or(label);
    let scheme = Scheme::new(&canonical, codec, Rounding::NearestEven, geometry);
    let tensors = read_tensors(f, &scheme)?;
    Ok(WeightStore { cfg, scheme, tensors })
}

/// Quantize + bit-pack one matrix through the scheme's codec: element
/// codes land densely at [`Codec::bits_per_elem`] bits each.
fn pack_matrix(m: &Mat, scheme: &Scheme, seed: u64) -> StoredTensor {
    let block = scheme.block().expect("packed schemes are square-blockwise");
    let w64: Vec<f64> = m.data.iter().map(|&x| x as f64).collect();
    let q = scheme.quantize(&w64, m.rows, m.cols, seed);
    let grid_c = m.cols.div_ceil(block);
    let mut codes = PackedCodes::for_codec(&scheme.codec, q.data.len());
    for i in 0..q.data.len() {
        let (r, c) = (i / m.cols, i % m.cols);
        let s = q.scales[(r / block) * grid_c + c / block];
        codes.set(i, scheme.encode(q.data[i] / s));
    }
    StoredTensor {
        rows: m.rows,
        cols: m.cols,
        scales: q.scales.iter().map(|&s| s as f32).collect(),
        codes: Codes::Packed(codes),
    }
}

/// Dequantize one stored tensor back to an f32 matrix: per element, one
/// [`DequantLut`] table index and one block-scale multiply — the same
/// decode the KV arena's fused kernels run.
fn unpack_matrix(st: &StoredTensor, scheme: &Scheme) -> Mat {
    match &st.codes {
        Codes::F32(v) => Mat::from_vec(st.rows, st.cols, v.clone()),
        Codes::Packed(pc) => {
            let block = scheme.block().expect("packed schemes are square-blockwise");
            let lut = DequantLut::for_codec(&scheme.codec).expect("packed codec has a LUT");
            let grid_c = st.cols.div_ceil(block);
            let mut data = vec![0f32; st.rows * st.cols];
            for ((i, out), code) in data.iter_mut().enumerate().zip(pc.iter()) {
                let (r, c) = (i / st.cols, i % st.cols);
                let s = st.scales[(r / block) * grid_c + c / block] as f64;
                *out = (lut.decode(code) * s) as f32;
            }
            Mat::from_vec(st.rows, st.cols, data)
        }
    }
}

/// Re-pack a GWQS1/GWQS2 byte-padded code payload into the dense layout,
/// rejecting codes wider than the codec (corrupt or mislabeled file).
fn repack_legacy(
    codes: impl Iterator<Item = u16>,
    n: usize,
    codec: &Codec,
) -> Result<Codes> {
    let bits = codec.bits_per_elem();
    let limit = 1u32 << bits;
    let mut pc = PackedCodes::with_len(bits, n);
    for (i, c) in codes.enumerate() {
        if (c as u32) >= limit {
            bail!("element code {c} exceeds the codec's {bits}-bit width");
        }
        pc.set(i, c);
    }
    Ok(Codes::Packed(pc))
}

fn write_str(f: &mut impl Write, s: &str) -> Result<()> {
    f.write_all(&(s.len() as u32).to_le_bytes())?;
    f.write_all(s.as_bytes())?;
    Ok(())
}

fn read_str(f: &mut impl Read) -> Result<String> {
    let mut u32b = [0u8; 4];
    f.read_exact(&mut u32b)?;
    let len = u32::from_le_bytes(u32b) as usize;
    if len > 1 << 20 {
        bail!("unreasonable string length {len} in weight store");
    }
    let mut bytes = vec![0u8; len];
    f.read_exact(&mut bytes)?;
    String::from_utf8(bytes).context("weight-store string utf8")
}

fn read_u64(f: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    f.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn read_u32(f: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    f.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn write_scales(f: &mut impl Write, scales: &[f32]) -> Result<()> {
    f.write_all(&(scales.len() as u64).to_le_bytes())?;
    for s in scales {
        f.write_all(&s.to_le_bytes())?;
    }
    Ok(())
}

fn read_scales(f: &mut impl Read) -> Result<Vec<f32>> {
    let n = read_u64(f)? as usize;
    read_f32s(f, n)
}

fn read_f32s(f: &mut impl Read, n: usize) -> Result<Vec<f32>> {
    let mut bytes = vec![0u8; n * 4];
    f.read_exact(&mut bytes)?;
    Ok(bytes.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::schema::Arch;
    use crate::nn::transformer::Transformer;
    use crate::numerics::fpformat::formats;
    use crate::quant::resolve;

    #[test]
    fn store_matches_scheme_quantize_exactly() {
        // dequantize-on-load must reproduce the scheme's fake-quant path
        // bit-for-bit (same blocks, same scales, same element cast)
        let cfg = ModelConfig::tiny(Arch::Gpt2);
        let model = Transformer::new(cfg.clone());
        let params = model.init_params(5);
        for label in ["bf16", "fp8_e3m4", "fp6_e3m2", "int8", "int4"] {
            let scheme = resolve(label).unwrap();
            let store = WeightStore::from_params(&params, &cfg, scheme.clone(), 5).unwrap();
            let served = store.to_params();
            for name in Params::linear_names(&cfg) {
                let m = params.get(&name);
                let w64: Vec<f64> = m.data.iter().map(|&x| x as f64).collect();
                let q =
                    scheme.quantize(&w64, m.rows, m.cols, crate::quant::tensor_seed(&name, 5));
                let got = served.get(&name);
                for (i, (&g, &want)) in got.data.iter().zip(q.data.iter()).enumerate() {
                    assert_eq!(g, want as f32, "{name}[{i}] under {label}");
                }
            }
            // non-linear tensors pass through untouched
            assert_eq!(served.get("embed").data, params.get("embed").data);
        }
    }

    #[test]
    fn store_compresses_linears() {
        let cfg = ModelConfig::tiny(Arch::Gpt2);
        let model = Transformer::new(cfg.clone());
        let params = model.init_params(6);
        let fp8 =
            WeightStore::from_params(&params, &cfg, resolve("fp8_e3m4").unwrap(), 6).unwrap();
        let f32s = WeightStore::from_params(&params, &cfg, resolve("f32").unwrap(), 6).unwrap();
        assert!(fp8.bytes() < f32s.bytes(), "{} !< {}", fp8.bytes(), f32s.bytes());
        assert_eq!(f32s.bytes(), f32s.master_bytes());
        // sub-byte packing is a real further win: fp4 code payloads are
        // half of fp8's, not the same padded byte per element
        let fp4 =
            WeightStore::from_params(&params, &cfg, resolve("fp4_e2m1").unwrap(), 6).unwrap();
        for name in Params::linear_names(&cfg) {
            let c8 = fp8.tensors[&name].codes.bytes();
            let c4 = fp4.tensors[&name].codes.bytes();
            assert_eq!(c4 * 2, c8, "{name}: fp4 codes {c4} B vs fp8 {c8} B");
        }
    }

    #[test]
    fn save_load_roundtrip_gwqs3() {
        let cfg = ModelConfig::tiny(Arch::Llama2);
        let model = Transformer::new(cfg.clone());
        let params = model.init_params(7);
        // fp6/fp4 exercise the sub-byte packed payload path end to end
        for label in ["fp8_e4m3", "fp6_e3m2", "fp4_e2m1", "int8", "int8_sr", "f32"] {
            let store =
                WeightStore::from_params(&params, &cfg, resolve(label).unwrap(), 7).unwrap();
            let path = std::env::temp_dir().join(format!("gaussws_store_test_{label}.gwqs"));
            store.save(&path).unwrap();
            let back = WeightStore::load(&path).unwrap();
            assert_eq!(back.cfg, cfg);
            assert_eq!(back.scheme, store.scheme, "{label}");
            assert_eq!(back.tensors, store.tensors, "{label}");
            let a = store.to_params();
            let b = back.to_params();
            for (name, m) in &a.tensors {
                assert_eq!(m, b.get(name), "{name}");
            }
        }
    }

    #[test]
    fn stochastic_store_is_reproducible() {
        // per-tensor seeds make SR snapshots deterministic
        let cfg = ModelConfig::tiny(Arch::Gpt2);
        let model = Transformer::new(cfg.clone());
        let params = model.init_params(8);
        let a =
            WeightStore::from_params(&params, &cfg, resolve("int8_sr").unwrap(), 8).unwrap();
        let b =
            WeightStore::from_params(&params, &cfg, resolve("int8_sr").unwrap(), 8).unwrap();
        assert_eq!(a.tensors, b.tensors);
        // a different SR seed draws a different snapshot
        let c =
            WeightStore::from_params(&params, &cfg, resolve("int8_sr").unwrap(), 9).unwrap();
        assert_ne!(a.tensors, c.tensors);
    }

    #[test]
    fn vectorwise_scheme_rejected() {
        let cfg = ModelConfig::tiny(Arch::Gpt2);
        let model = Transformer::new(cfg.clone());
        let params = model.init_params(9);
        let err = WeightStore::from_params(&params, &cfg, resolve("fp8_e3m4_vec").unwrap(), 9);
        assert!(err.is_err());
    }

    /// Write one tensor's element payload the way GWQS1/2 did: one byte
    /// per code for ≤8-bit codecs, two bytes otherwise.
    fn write_legacy_codes(f: &mut impl Write, st: &StoredTensor) {
        match &st.codes {
            Codes::F32(v) => {
                f.write_all(&[0u8]).unwrap();
                for x in v {
                    f.write_all(&x.to_le_bytes()).unwrap();
                }
            }
            Codes::Packed(pc) => {
                if pc.bits() <= 8 {
                    f.write_all(&[1u8]).unwrap();
                    write_scales(f, &st.scales).unwrap();
                    let bytes: Vec<u8> = pc.iter().map(|c| c as u8).collect();
                    f.write_all(&bytes).unwrap();
                } else {
                    f.write_all(&[2u8]).unwrap();
                    write_scales(f, &st.scales).unwrap();
                    for c in pc.iter() {
                        f.write_all(&c.to_le_bytes()).unwrap();
                    }
                }
            }
        }
    }

    /// Write the old GWQS1 layout for back-compat tests (the PR 1 writer,
    /// kept in test code only).
    fn write_gwqs1(store: &WeightStore, path: &Path) {
        let fmt = match &store.scheme.codec {
            Codec::Fp(f) => *f,
            _ => panic!("gwqs1 test writer covers fp codecs"),
        };
        let legacy = format!("fp{}_e{}m{}", fmt.total_bits(), fmt.exp_bits, fmt.man_bits);
        let mut f = std::io::BufWriter::new(std::fs::File::create(path).unwrap());
        f.write_all(MAGIC_V1).unwrap();
        write_str(&mut f, &legacy).unwrap();
        write_str(&mut f, store.cfg.arch.name()).unwrap();
        for v in [
            store.cfg.n_layer,
            store.cfg.d_model,
            store.cfg.n_head,
            store.cfg.d_ff,
            store.cfg.vocab,
            store.cfg.seq_len,
            store.block(),
        ] {
            f.write_all(&(v as u64).to_le_bytes()).unwrap();
        }
        f.write_all(&[1u8]).unwrap();
        f.write_all(&[
            fmt.exp_bits as u8,
            fmt.man_bits as u8,
            fmt.has_inf_nan as u8,
            (fmt.overflow == Overflow::Saturate) as u8,
        ])
        .unwrap();
        f.write_all(&(store.tensors.len() as u32).to_le_bytes()).unwrap();
        for (name, st) in &store.tensors {
            write_str(&mut f, name).unwrap();
            f.write_all(&(st.rows as u64).to_le_bytes()).unwrap();
            f.write_all(&(st.cols as u64).to_le_bytes()).unwrap();
            write_legacy_codes(&mut f, st);
        }
    }

    /// Write the old GWQS2 layout for back-compat tests (the PR 4 writer,
    /// kept in test code only: same header as GWQS3, byte-padded codes).
    fn write_gwqs2(store: &WeightStore, path: &Path) {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path).unwrap());
        f.write_all(MAGIC_V2).unwrap();
        write_str(&mut f, store.scheme.label()).unwrap();
        match &store.scheme.codec {
            Codec::F32 => f.write_all(&[0u8]).unwrap(),
            Codec::Fp(fmt) => {
                f.write_all(&[1u8]).unwrap();
                f.write_all(&[
                    fmt.exp_bits as u8,
                    fmt.man_bits as u8,
                    fmt.has_inf_nan as u8,
                    (fmt.overflow == Overflow::Saturate) as u8,
                ])
                .unwrap();
            }
            Codec::Int { bits } => f.write_all(&[2u8, *bits as u8]).unwrap(),
        }
        let rounding = match store.scheme.rounding {
            Rounding::NearestEven => 0u8,
            Rounding::TowardZero => 1,
            Rounding::Stochastic => 2,
        };
        f.write_all(&[rounding]).unwrap();
        match store.scheme.geometry {
            Geometry::None => f.write_all(&[0u8]).unwrap(),
            Geometry::Square { block } => {
                f.write_all(&[1u8]).unwrap();
                f.write_all(&(block as u64).to_le_bytes()).unwrap();
            }
            Geometry::Vector { .. } => panic!("vector-wise stores are unsupported"),
        }
        write_str(&mut f, store.cfg.arch.name()).unwrap();
        for v in [
            store.cfg.n_layer,
            store.cfg.d_model,
            store.cfg.n_head,
            store.cfg.d_ff,
            store.cfg.vocab,
            store.cfg.seq_len,
        ] {
            f.write_all(&(v as u64).to_le_bytes()).unwrap();
        }
        f.write_all(&(store.tensors.len() as u32).to_le_bytes()).unwrap();
        for (name, st) in &store.tensors {
            write_str(&mut f, name).unwrap();
            f.write_all(&(st.rows as u64).to_le_bytes()).unwrap();
            f.write_all(&(st.cols as u64).to_le_bytes()).unwrap();
            write_legacy_codes(&mut f, st);
        }
    }

    #[test]
    fn gwqs2_snapshots_still_load() {
        // byte-padded V2 payloads re-pack to the dense layout on load and
        // compare equal to a natively-packed store — for a sub-byte codec
        // (u8 payload → 4-bit packing) and a 16-bit one (u16 payload)
        let cfg = ModelConfig::tiny(Arch::Gpt2);
        let model = Transformer::new(cfg.clone());
        let params = model.init_params(11);
        for label in ["fp4_e2m1", "bf16"] {
            let store =
                WeightStore::from_params(&params, &cfg, resolve(label).unwrap(), 11).unwrap();
            let path = std::env::temp_dir().join(format!("gaussws_store_v2_{label}.gwqs"));
            write_gwqs2(&store, &path);
            let back = WeightStore::load(&path).unwrap();
            assert_eq!(back.scheme, store.scheme, "{label}");
            assert_eq!(back.tensors, store.tensors, "{label}");
            assert_eq!(back.cfg, cfg);
        }
    }

    #[test]
    fn gwqs1_snapshots_still_load() {
        let cfg = ModelConfig::tiny(Arch::Gpt2);
        let model = Transformer::new(cfg.clone());
        let params = model.init_params(10);
        let store =
            WeightStore::from_params(&params, &cfg, resolve("fp8_e3m4").unwrap(), 10).unwrap();
        let path = std::env::temp_dir().join("gaussws_store_v1.gwqs");
        write_gwqs1(&store, &path);
        let back = WeightStore::load(&path).unwrap();
        // the legacy label maps back to the canonical registry scheme
        assert_eq!(back.scheme, store.scheme);
        assert_eq!(back.tensors, store.tensors);
        assert_eq!(back.cfg, cfg);
    }

    #[test]
    fn corrupt_store_rejected() {
        let path = std::env::temp_dir().join("gaussws_store_bad.gwqs");
        std::fs::write(&path, b"NOTGWQSjunk").unwrap();
        assert!(WeightStore::load(&path).is_err());
    }

    #[test]
    fn packed_codec_with_non_square_geometry_rejected_cleanly() {
        // a crafted GWQS2 header with an fp codec but geometry tag 0 must
        // produce a clean error, not a panic at dequantize time
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC_V2);
        bytes.extend_from_slice(&(4u32).to_le_bytes());
        bytes.extend_from_slice(b"bf16");
        bytes.extend_from_slice(&[1u8, 8, 7, 1, 0]); // fp e8m7, ieee
        bytes.push(0); // rounding: rne
        bytes.push(0); // geometry: none — invalid with a packed codec
        let path = std::env::temp_dir().join("gaussws_store_badgeom.gwqs");
        std::fs::write(&path, &bytes).unwrap();
        let err = WeightStore::load(&path).unwrap_err().to_string();
        assert!(err.contains("non-square geometry"), "{err}");
    }

    #[test]
    fn labels_resolve_like_the_old_store_parser() {
        // the registry supersedes StoreElem::parse
        assert!(!resolve("f32").unwrap().codec.is_packed());
        assert!(!resolve("fp32").unwrap().codec.is_packed());
        assert_eq!(resolve("bf16").unwrap().codec, Codec::Fp(formats::BF16));
        assert_eq!(resolve("fp8_e3m4").unwrap().codec, Codec::Fp(formats::FP8_E3M4));
        assert!(resolve("fp99").is_err());
    }
}
