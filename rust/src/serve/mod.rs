//! L4 — the serving layer: batched fake-quantized inference over
//! GaussWS-trained checkpoints, with paged KV-cache memory.
//!
//! The train→serve lifecycle this layer closes:
//!
//! 1. **snapshot** — [`weights::WeightStore`] captures a checkpoint's linear
//!    weights as square-blockwise (32×32) MX groups: one power-of-two scale
//!    per block plus element codes packed at their true sub-byte width
//!    ([`crate::quant::PackedCodes`] — fp4 codes cost 4 bits, not a padded
//!    byte) in the codec of a [`crate::quant::Scheme`] resolved by label
//!    (BF16 / FP8 / FP6 / FP4 / INT8 / INT4, RNE or stochastic). The
//!    on-disk format is **GWQS3** (GWQS1/GWQS2 snapshots still load);
//!    dequantize-on-load walks the packed codes through a per-codec
//!    2^bits [`crate::quant::DequantLut`] and reproduces the scheme's
//!    fake-quant bit-for-bit, so serving inherits the Table C.1
//!    graceful-degradation claims of the training-time grouping.
//! 2. **decode** — `nn::transformer::prefill_chunk` advances a sequence by
//!    N positions per wave (`decode_step` is its 1-token case) against a
//!    paged per-sequence KV chain ([`crate::nn::kv::PagedKv`]): fixed-size
//!    position blocks resolved through a block table, bit-identical to the
//!    contiguous cache. `nn::transformer::decode_wave` is the
//!    weight-stationary batched form: the current-token rows of many
//!    decoding sequences stack into one activation matrix so each layer's
//!    dense weights are streamed once per wave instead of once per
//!    sequence, with attention still per-sequence over its own cache —
//!    logits bit-identical to per-sequence decode.
//! 3. **allocate** — [`kvcache::BlockAllocator`] owns the global block
//!    arena: free-list recycling, per-block refcounted states (O(1)
//!    double-free detection, surfaced as `Err` not panics), copy-on-write
//!    for shared tails, and a prefix index (token-prefix hash → block
//!    chain) so identical prompt prefixes across requests share physical
//!    blocks *and* skip their prefill compute. The arena also owns the
//!    **KV row-storage scheme** ([`crate::nn::kv::KvQuant`], CLI
//!    `--kv-store`): blocks can hold K/V rows as sub-byte
//!    [`crate::quant::PackedCodes`] + per-group po2 scales through any
//!    blockwise `quant::Scheme` (`"fp8_e3m4"`, `"fp4_e2m1_sr"`, …) —
//!    attention reads them through fused dequant-dot kernels
//!    (`dot_k`/`axpy_v`, no f32 materialization; fp4 is 160 B/position
//!    on the tiny config vs 1024 B raw). An opt-in f32 decode mirror
//!    (`--kv-mirror`, [`crate::nn::kv::KvQuant::with_mirror`]) exists as
//!    a debug mode asserted bit-identical to the fused path; raw f32
//!    (`"f32"`) stays bit-identical to pre-quantization serving.
//! 4. **schedule** — [`batcher::Scheduler`] continuously batches with a
//!    block budget: admission waits on free blocks (not slots), prefill
//!    runs in chunks interleaved with decode waves, and when the arena
//!    runs dry the newest sequence is preempted back to the queue (blocks
//!    freed, tokens retained, re-prefilled later).
//! 5. **serve** — [`engine::Engine`] plans + reserves each sequence's
//!    chunk, then splits the wave: steady-state single-token decodes are
//!    stacked into one weight-stationary `decode_wave` batch
//!    ([`engine::EngineConfig::wave_batch`], on by default — each weight
//!    matrix read once for the whole batch), while prefill chunks and
//!    speculative rounds advance per-sequence across worker threads,
//!    dealt round-robin by estimated cost so wall time tracks the largest
//!    item (safe: blocks are `Arc`-shared read-only, writable tails
//!    exclusive); both paths are bit-identical by construction. Finished
//!    sequences retire into the prefix index; a spawned engine front
//!    exposes blocking [`engine::EngineClient`]s. With a draft store
//!    configured (`--spec-draft`, [`engine::EngineConfig::spec_draft_store`])
//!    the engine runs **self-speculative decoding** on the CoW machinery:
//!    greedy decode chunks fork the sequence's KV chain
//!    ([`kvcache::BlockAllocator::fork_seq`], refcount bumps only), draft
//!    up to `--spec-k` tokens through a lower-bit round-trip of the same
//!    weights, verify all of them in one all-rows chunk
//!    (`nn::transformer::prefill_chunk_logits`) through the target
//!    weights, then roll back the rejected tail
//!    ([`kvcache::BlockAllocator::rollback_to`]) and release the fork.
//!    Acceptance is exact greedy token match, so spec on/off outputs are
//!    bit-identical ([`batcher::SpecPlan`]).
//! 6. **account** — [`stats::ServeStats`] is a view over a shared
//!    [`crate::telemetry::Registry`]: counters, gauges and log-bucketed
//!    histograms back p50/p95/p99 latency, TTFT, tokens/sec, batch
//!    occupancy, a live-block gauge sampled over time, prefix-hit rate,
//!    preemptions, prefill chunks, the KV logit-drift histogram, and the
//!    KV scheme's bytes/position + encoded arena bytes; it emits the
//!    `BENCH_serve.json` record and exposes JSON / Prometheus-text
//!    snapshots (`serve --metrics-every`). With `EngineConfig::trace` on
//!    (`serve --trace-out`), every request additionally records a Chrome
//!    trace-event timeline — enqueue → admit (prefix hit/miss, block
//!    reserve delta) → prefill chunks → decode waves → preempt/re-admit →
//!    retire — exported as JSONL for ui.perfetto.dev.
//!
//! 7. **listen** — [`net::NetServer`] is the TCP edge (`serve --listen`):
//!    length-prefixed newline-JSON frames ([`net::frame`]) carrying
//!    strict-parsed requests ([`protocol::GenRequest::from_json_strict`]),
//!    admission control and backpressure against free-block headroom
//!    (bounded queue, shed-with-`retry_after_ms`), per-request deadlines
//!    ([`protocol::FinishReason::Deadline`]), structured
//!    [`protocol::ErrorResponse`] frames for malformed/rejected requests,
//!    and graceful drain on shutdown. Driven by the declarative workload
//!    framework in [`crate::load`] (`load <scenario>`).
//!
//! The conformance harness for all of the above — a seeded, deterministic
//! serving fuzzer asserting leak-freedom, determinism, paged-vs-contiguous
//! greedy identity, prefix on/off equivalence, wave-batch on/off
//! equivalence, bounded quantized-KV logit drift, and telemetry/trace
//! consistency — lives in
//! [`crate::testing::fuzz`] and runs from `tests/fuzz_serve.rs`; the
//! net-transport arm replays the same seeds over a loopback TCP server and
//! asserts bit-identical outputs.

pub mod batcher;
pub mod engine;
pub mod kvcache;
pub mod net;
pub mod protocol;
pub mod stats;
pub mod weights;

pub use batcher::{sample_logits, ActiveSeq, Scheduler, SpecPlan};
pub use engine::{Engine, EngineClient, EngineConfig, EngineHandle};
pub use kvcache::{BlockAllocator, BlockId, BlockState, PrefixCacheStats};
pub use net::{NetClient, NetServer, NetServerConfig};
pub use protocol::{ErrorResponse, FinishReason, GenRequest, GenResponse};
pub use stats::ServeStats;
pub use weights::WeightStore;
