//! L4 — the serving layer: batched fake-quantized inference over
//! GaussWS-trained checkpoints.
//!
//! The train→serve lifecycle this layer closes:
//!
//! 1. **snapshot** — [`weights::WeightStore`] captures a checkpoint's linear
//!    weights as square-blockwise (32×32) MX groups: one power-of-two scale
//!    per block plus bit-packed element codes in the codec of a
//!    [`crate::quant::Scheme`] resolved by label (BF16 / FP8 / FP6 / FP4 /
//!    INT8 / INT4, RNE or stochastic). Dequantize-on-load reproduces the
//!    scheme's fake-quant bit-for-bit, so serving inherits the Table C.1
//!    graceful-degradation claims of the training-time grouping.
//! 2. **decode** — `nn::transformer::decode_step` runs one token against a
//!    per-sequence KV cache ([`kvcache::KvCachePool`] slots with free-list
//!    reuse) instead of recomputing the full train-shaped forward.
//! 3. **schedule** — [`batcher::Batcher`] continuously batches: sequences
//!    join and leave the active set at wave boundaries, so a retiring
//!    sequence's KV slot is immediately recycled to the queue.
//! 4. **serve** — [`engine::Engine`] advances every active sequence one
//!    position per wave, splitting the batch across worker threads; a
//!    spawned engine front exposes blocking [`engine::EngineClient`]s.
//! 5. **account** — [`stats::ServeStats`] tracks p50/p95 latency, TTFT,
//!    queue time, tokens/sec and batch occupancy, and emits the
//!    `BENCH_serve.json` throughput record.

pub mod batcher;
pub mod engine;
pub mod kvcache;
pub mod protocol;
pub mod stats;
pub mod weights;

pub use batcher::{sample_logits, Batcher};
pub use engine::{Engine, EngineClient, EngineConfig, EngineHandle};
pub use kvcache::{KvCachePool, SlotId};
pub use protocol::{FinishReason, GenRequest, GenResponse};
pub use stats::ServeStats;
pub use weights::WeightStore;
