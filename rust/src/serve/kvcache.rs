//! Slot-pooled KV cache: a fixed set of preallocated per-sequence
//! [`DecodeCache`]s with free-list reuse. The pool size bounds serving
//! memory (`slots × 2 × n_layer × capacity × d_model × 4 B`); when every
//! slot is busy, admission control in the batcher holds new sequences in
//! the queue until a sequence retires and its slot is recycled.

use crate::config::schema::ModelConfig;
use crate::nn::transformer::DecodeCache;

/// Identifier of one pool slot.
pub type SlotId = usize;

/// A pool of reusable KV-cache slots.
#[derive(Debug)]
pub struct KvCachePool {
    /// `None` while a slot is checked out to a decode wave.
    slots: Vec<Option<DecodeCache>>,
    free: Vec<SlotId>,
    /// Allocations served since construction.
    pub allocs: usize,
    /// Slot recycles (a previously-used slot handed to a new sequence).
    pub reuses: usize,
    /// Per-slot flag: has this slot served a sequence before?
    used_before: Vec<bool>,
    high_water: usize,
    slot_bytes: usize,
}

impl KvCachePool {
    /// `n_slots` caches, each holding up to `capacity` positions (clamped to
    /// the model's `seq_len` by [`DecodeCache::new`]).
    pub fn new(cfg: &ModelConfig, n_slots: usize, capacity: usize) -> KvCachePool {
        assert!(n_slots > 0, "pool needs at least one slot");
        let slots: Vec<Option<DecodeCache>> =
            (0..n_slots).map(|_| Some(DecodeCache::new(cfg, capacity))).collect();
        let slot_bytes = slots[0].as_ref().map(|c| c.bytes()).unwrap_or(0);
        KvCachePool {
            slots,
            free: (0..n_slots).rev().collect(),
            allocs: 0,
            reuses: 0,
            used_before: vec![false; n_slots],
            high_water: 0,
            slot_bytes,
        }
    }

    pub fn n_slots(&self) -> usize {
        self.slots.len()
    }

    pub fn in_use(&self) -> usize {
        self.slots.len() - self.free.len()
    }

    /// Peak concurrent slot usage.
    pub fn high_water(&self) -> usize {
        self.high_water
    }

    /// Bytes of K/V storage across all slots.
    pub fn bytes(&self) -> usize {
        self.slot_bytes * self.slots.len()
    }

    /// Claim a free slot (its cache is reset), or `None` if all are busy.
    pub fn try_alloc(&mut self) -> Option<SlotId> {
        let id = self.free.pop()?;
        if self.used_before[id] {
            self.reuses += 1;
        }
        self.used_before[id] = true;
        if let Some(c) = self.slots[id].as_mut() {
            c.reset();
        }
        self.allocs += 1;
        self.high_water = self.high_water.max(self.in_use());
        Some(id)
    }

    /// Return a retired sequence's slot to the free list.
    pub fn release(&mut self, id: SlotId) {
        debug_assert!(self.slots[id].is_some(), "releasing a checked-out slot");
        debug_assert!(!self.free.contains(&id), "double release of slot {id}");
        self.free.push(id);
    }

    /// Check a slot's cache out for a decode wave (the caller gets owned
    /// mutable access with no aliasing, so waves can run on worker threads).
    pub fn take(&mut self, id: SlotId) -> DecodeCache {
        self.slots[id].take().expect("slot already checked out")
    }

    /// Return a checked-out cache.
    pub fn put_back(&mut self, id: SlotId, cache: DecodeCache) {
        debug_assert!(self.slots[id].is_none(), "slot was not checked out");
        self.slots[id] = Some(cache);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::schema::Arch;

    fn pool(n: usize) -> KvCachePool {
        KvCachePool::new(&ModelConfig::tiny(Arch::Gpt2), n, 16)
    }

    #[test]
    fn alloc_release_cycle() {
        let mut p = pool(2);
        let a = p.try_alloc().unwrap();
        let b = p.try_alloc().unwrap();
        assert_ne!(a, b);
        assert_eq!(p.in_use(), 2);
        assert!(p.try_alloc().is_none(), "exhausted pool must refuse");
        p.release(a);
        assert_eq!(p.in_use(), 1);
        let c = p.try_alloc().unwrap();
        assert_eq!(c, a, "free list reuses the released slot");
        assert_eq!(p.reuses, 1);
        assert_eq!(p.high_water(), 2);
        p.release(b);
        p.release(c);
    }

    #[test]
    fn reused_slot_cache_is_reset() {
        let mut p = pool(1);
        let id = p.try_alloc().unwrap();
        let mut c = p.take(id);
        c.len = 5; // simulate use
        p.put_back(id, c);
        p.release(id);
        let id2 = p.try_alloc().unwrap();
        assert_eq!(id, id2);
        assert_eq!(p.take(id2).len, 0, "alloc must hand out a reset cache");
    }

    #[test]
    fn take_put_back_preserves_contents() {
        let mut p = pool(2);
        let id = p.try_alloc().unwrap();
        let mut c = p.take(id);
        c.len = 3;
        p.put_back(id, c);
        let c = p.take(id);
        assert_eq!(c.len, 3);
        p.put_back(id, c);
    }

    #[test]
    fn pool_reports_bytes() {
        let p = pool(3);
        assert!(p.bytes() > 0);
    }
}
