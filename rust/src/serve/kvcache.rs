//! Paged KV-cache memory management: one global block arena shared by all
//! sequences, replacing the PR-1 slot pool (which preallocated
//! `slots × 2 × n_layer × capacity × d_model` floats per sequence and
//! stranded most of it for short requests).
//!
//! * [`BlockAllocator`] — a budget of `n_blocks` fixed-size
//!   [`KvBlock`]s. Buffers are recycled through a free list; every block
//!   id carries a [`BlockState`] (free / live-with-refcount), so double
//!   release and retain-after-free are O(1) checks that surface as
//!   `Err` (not panics) to the caller.
//! * **Quantized arenas** — the allocator owns the [`KvQuant`] row-storage
//!   policy: every block it hands out is shaped for the chosen
//!   `quant::Scheme` (densely packed sub-byte codes + po2 scales, read
//!   through the fused dequant kernels; or raw f32 for the `"f32"`
//!   passthrough), and every [`PagedKv`] it creates writes through that
//!   policy. The resident footprint IS the packed one —
//!   [`BlockAllocator::bytes`] equals [`BlockAllocator::encoded_bytes`]
//!   unless the policy opted into the f32 debug mirror
//!   ([`KvQuant::with_mirror`]). [`BlockAllocator::bytes_per_position`]
//!   reports the bit-true encoded bytes/position of the scheme.
//! * **Copy-on-write append** — a sequence whose next write lands in a
//!   *shared* block (adopted from the prefix index) gets an exclusive
//!   copy first ([`BlockAllocator::reserve`]); the shared original stays
//!   frozen for its other holders.
//! * [`PrefixIndex`] (internal) — hash of token-prefix → cached block
//!   chain. Retiring sequences publish their prompt's blocks; admission
//!   looks up the longest cached prefix of a new prompt and adopts the
//!   chain (refcount bump, zero copies), so identical prompt prefixes
//!   across requests share physical memory AND skip recomputing their
//!   K/V. Entries are LRU-evicted when the arena runs dry.
//!
//! The scheduler side (admission by free blocks, chunked prefill,
//! preemption) lives in [`crate::serve::batcher`].

use crate::config::schema::ModelConfig;
use crate::nn::kv::{KvBlock, KvQuant, KvStorage, PagedKv};
use crate::quant::Scheme;
use anyhow::{bail, Result};
use std::collections::HashMap;
use std::sync::Arc;

/// Identifier of one arena block (the block-table entry type).
pub type BlockId = u32;

/// Lifecycle state of one arena block id. A separate enum (rather than an
/// `Option<..>` slot) so release/retain misuse is detected in O(1): the
/// old pool's `free.contains(&id)` double-free scan was O(n) per release,
/// and its `slots[id].is_some()` assert fired misleadingly while a cache
/// was merely checked out to a decode wave.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BlockState {
    /// Recyclable: not referenced by any sequence or prefix entry.
    Free,
    /// Referenced by `refs` holders (sequences and/or prefix entries).
    /// `refs > 1` means the block is shared and must be copy-on-written
    /// before any append.
    Live { refs: u32 },
}

/// Aggregate prefix-cache counters (reported through `ServeStats`).
#[derive(Debug, Clone, Copy, Default)]
pub struct PrefixCacheStats {
    pub entries: usize,
    pub insertions: usize,
    pub evictions: usize,
}

/// One cached prompt prefix: the exact tokens it covers plus the block
/// chain holding their K/V (tokens are kept so a hash collision can never
/// alias two different prefixes).
#[derive(Debug)]
struct PrefixEntry {
    tokens: Vec<usize>,
    blocks: Vec<Arc<KvBlock>>,
    last_used: u64,
}

/// Hash of a token prefix (FNV-1a over the token values).
fn prefix_hash(tokens: &[usize]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &t in tokens {
        h = fnv_step(h, t);
    }
    h ^ tokens.len() as u64
}

#[inline]
fn fnv_step(mut h: u64, token: usize) -> u64 {
    for b in (token as u64).to_le_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[derive(Debug, Default)]
struct PrefixIndex {
    map: HashMap<u64, PrefixEntry>,
    insertions: usize,
    evictions: usize,
}

/// The global paged KV arena: block budget, buffer free list, per-block
/// refcounted states, copy-on-write support, the row-storage (quant)
/// policy, and the cross-request prefix index.
#[derive(Debug)]
pub struct BlockAllocator {
    n_layer: usize,
    d_model: usize,
    block_size: usize,
    total: usize,
    /// Per-id lifecycle state; indexed by [`BlockId`].
    states: Vec<BlockState>,
    /// Recyclable ids (their buffers live in `spare` or were dropped).
    free_ids: Vec<BlockId>,
    /// Recycled buffers awaiting reuse.
    spare: Vec<KvBlock>,
    /// Unique live blocks (each shared block counts once).
    live: usize,
    block_bytes: usize,
    /// Blocks handed out since construction.
    pub allocs: usize,
    /// Allocations served from a recycled buffer.
    pub reuses: usize,
    /// Copy-on-write block copies performed.
    pub cow_copies: usize,
    high_water: usize,
    prefix: PrefixIndex,
    tick: u64,
    /// How K/V rows are stored in every block of this arena.
    quant: KvQuant,
}

impl BlockAllocator {
    /// An arena of `n_blocks` raw-f32 blocks of `block_size` positions
    /// each (the passthrough layout).
    pub fn new(cfg: &ModelConfig, n_blocks: usize, block_size: usize) -> BlockAllocator {
        BlockAllocator::with_quant(cfg, n_blocks, block_size, KvQuant::passthrough(cfg.d_model))
    }

    /// An arena whose blocks store K/V through `scheme` (see
    /// [`KvQuant::new`] for the geometries rejected here). `seed` keys the
    /// stochastic-rounding streams.
    pub fn with_scheme(
        cfg: &ModelConfig,
        n_blocks: usize,
        block_size: usize,
        scheme: Scheme,
        seed: u64,
    ) -> Result<BlockAllocator> {
        let quant = KvQuant::new(scheme, cfg.d_model, seed)?;
        Ok(BlockAllocator::with_quant(cfg, n_blocks, block_size, quant))
    }

    /// An arena over an explicit row-storage policy — what
    /// [`crate::serve::EngineConfig::kv_mirror`] routes through to keep the
    /// f32 debug mirror next to the packed codes.
    pub fn with_quant(
        cfg: &ModelConfig,
        n_blocks: usize,
        block_size: usize,
        quant: KvQuant,
    ) -> BlockAllocator {
        assert!(n_blocks > 0, "arena needs at least one block");
        assert!(block_size > 0, "kv block size must be positive");
        let probe = KvBlock::for_quant(0, cfg.n_layer, block_size, cfg.d_model, &quant);
        BlockAllocator {
            n_layer: cfg.n_layer,
            d_model: cfg.d_model,
            block_size,
            total: n_blocks,
            states: Vec::new(),
            free_ids: Vec::new(),
            spare: Vec::new(),
            live: 0,
            block_bytes: probe.bytes(),
            allocs: 0,
            reuses: 0,
            cow_copies: 0,
            high_water: 0,
            prefix: PrefixIndex::default(),
            tick: 0,
            quant,
        }
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    /// The row-storage policy every block of this arena uses.
    pub fn kv_quant(&self) -> &KvQuant {
        &self.quant
    }

    /// Canonical label of the KV storage scheme (`"f32"`, `"fp8_e3m4"`, …).
    pub fn kv_store_label(&self) -> &str {
        self.quant.label()
    }

    /// Encoded bytes one sequence position costs under this arena's
    /// scheme (codes + scales, or raw f32 for passthrough).
    pub fn bytes_per_position(&self) -> usize {
        self.quant.bytes_per_position(self.n_layer)
    }

    /// Total block budget.
    pub fn total_blocks(&self) -> usize {
        self.total
    }

    /// Unique blocks currently referenced.
    pub fn live_blocks(&self) -> usize {
        self.live
    }

    pub fn free_blocks(&self) -> usize {
        self.total - self.live
    }

    /// Peak concurrent live blocks.
    pub fn high_water(&self) -> usize {
        self.high_water
    }

    /// Resident bytes of the full arena budget. In the fused default this
    /// matches [`BlockAllocator::encoded_bytes`] — packed codes + scales
    /// are all a quantized block keeps; only a [`KvQuant::with_mirror`]
    /// policy adds the f32 decode mirror on top.
    pub fn bytes(&self) -> usize {
        self.block_bytes * self.total
    }

    /// Encoded bytes of the full arena budget under the chosen scheme —
    /// the deployment layout storing only codes + scales.
    pub fn encoded_bytes(&self) -> usize {
        self.bytes_per_position() * self.block_size * self.total
    }

    /// Resident bytes of K/V currently live.
    pub fn live_bytes(&self) -> usize {
        self.block_bytes * self.live
    }

    /// Blocks needed to hold `positions` sequence positions.
    pub fn blocks_for(&self, positions: usize) -> usize {
        positions.div_ceil(self.block_size)
    }

    /// An empty paged cache wired to this arena's geometry and storage
    /// scheme (blocks must be reserved through the allocator before
    /// writes).
    pub fn new_seq(&self, cfg: &ModelConfig, capacity: usize) -> PagedKv {
        PagedKv::external_quantized(cfg, self.block_size, capacity, self.quant.clone())
    }

    fn state(&self, id: BlockId) -> BlockState {
        self.states[id as usize]
    }

    /// The block is referenced by more than one holder.
    pub fn is_shared(&self, id: BlockId) -> bool {
        matches!(self.state(id), BlockState::Live { refs } if refs > 1)
    }

    /// Claim one exclusive block, or `None` if the budget is exhausted.
    pub fn try_alloc(&mut self) -> Option<Arc<KvBlock>> {
        if self.live >= self.total {
            return None;
        }
        let mut buf = match self.spare.pop() {
            Some(b) => {
                self.reuses += 1;
                b
            }
            None => KvBlock::for_quant(0, self.n_layer, self.block_size, self.d_model, &self.quant),
        };
        let id = match self.free_ids.pop() {
            Some(id) => id,
            None => {
                self.states.push(BlockState::Free);
                (self.states.len() - 1) as BlockId
            }
        };
        buf.id = id;
        debug_assert_eq!(self.states[id as usize], BlockState::Free);
        self.states[id as usize] = BlockState::Live { refs: 1 };
        self.live += 1;
        self.allocs += 1;
        self.high_water = self.high_water.max(self.live);
        Some(Arc::new(buf))
    }

    /// Register an additional holder of each block (sharing a chain).
    /// Retaining a block whose id was already freed is misuse and returns
    /// an error (the states of blocks retained so far are still applied).
    pub fn retain(&mut self, blocks: &[Arc<KvBlock>]) -> Result<()> {
        for b in blocks {
            match self.states[b.id as usize] {
                BlockState::Live { refs } => {
                    self.states[b.id as usize] = BlockState::Live { refs: refs + 1 }
                }
                BlockState::Free => bail!("retain of freed block {}", b.id),
            }
        }
        Ok(())
    }

    /// Drop one holder's reference. When the last holder releases, the id
    /// and (if no stray `Arc` remains) the buffer are recycled. A double
    /// release is caught in O(1) by the state enum and returned as an
    /// error (the arena stays consistent — nothing is freed twice).
    pub fn release(&mut self, block: Arc<KvBlock>) -> Result<()> {
        let id = block.id as usize;
        match self.states[id] {
            BlockState::Free => bail!("double release of block {id}"),
            BlockState::Live { refs: 1 } => {
                self.states[id] = BlockState::Free;
                self.free_ids.push(id as BlockId);
                self.live -= 1;
                if let Ok(buf) = Arc::try_unwrap(block) {
                    self.spare.push(buf);
                }
            }
            BlockState::Live { refs } => {
                self.states[id] = BlockState::Live { refs: refs - 1 };
            }
        }
        Ok(())
    }

    /// Release every block of a chain (sequence retirement / preemption).
    /// Returns the first misuse error, after attempting every release.
    pub fn release_chain(&mut self, blocks: Vec<Arc<KvBlock>>) -> Result<()> {
        let mut first_err = Ok(());
        for b in blocks {
            let r = self.release(b);
            if r.is_err() && first_err.is_ok() {
                first_err = r;
            }
        }
        first_err
    }

    /// Positions `kv` could absorb right now given the free budget (counting
    /// the copy-on-write block its shared tail would need), capped by the
    /// cache's own position capacity.
    pub fn max_appendable(&self, kv: &PagedKv) -> usize {
        let mut free = self.free_blocks();
        let room = kv.staged_room();
        if room > 0 {
            if let Some(tail) = kv.tail_block() {
                if self.is_shared(tail.id) {
                    if free == 0 {
                        return 0;
                    }
                    free -= 1; // the CoW copy consumes one block
                }
            }
        }
        let positions = room + free * self.block_size;
        positions.min(kv.capacity().saturating_sub(kv.len()))
    }

    /// Make `kv` writable for `n_tokens` more positions: copy-on-write a
    /// shared tail, then attach fresh blocks. Returns `false` when the
    /// arena runs dry (already-attached blocks stay with `kv`; the caller
    /// evicts prefix entries or preempts a sequence and retries).
    pub fn reserve(&mut self, kv: &mut PagedKv, n_tokens: usize) -> bool {
        if !self.make_tail_exclusive(kv) {
            return false;
        }
        for _ in 0..kv.blocks_needed(n_tokens) {
            match self.try_alloc() {
                Some(b) => kv.push_block(b),
                None => return false,
            }
        }
        true
    }

    /// Copy-on-write: if `kv`'s next append lands in a shared block,
    /// replace that block with an exclusive copy. `false` = out of blocks.
    pub fn make_tail_exclusive(&mut self, kv: &mut PagedKv) -> bool {
        let Some(tail) = kv.tail_block() else { return true };
        if !self.is_shared(tail.id) {
            return true;
        }
        let Some(mut fresh) = self.try_alloc() else { return false };
        let src = tail.clone();
        Arc::get_mut(&mut fresh).expect("fresh block is exclusive").copy_contents_from(&src);
        drop(src);
        let old = kv.replace_tail(fresh);
        self.release(old).expect("CoW-displaced block was live");
        self.cow_copies += 1;
        true
    }

    // ------------------------------------------------ speculative forking

    /// Fork a sequence's cache (speculative-decode draft): the fork adopts
    /// the chain covering `kv`'s committed positions and every shared
    /// block is retained, so the fork starts at the same length reading
    /// the same physical K/V with **zero copies**. The fork's first append
    /// into the shared tail copy-on-writes through the ordinary
    /// [`BlockAllocator::reserve`] path, leaving the parent's view frozen.
    /// Pure refcount bumps — cannot fail on arena capacity, only on
    /// retain misuse.
    pub fn fork_seq(&mut self, cfg: &ModelConfig, kv: &PagedKv) -> Result<PagedKv> {
        let chain = kv.blocks_covering(kv.len());
        self.retain(chain)?;
        let mut fork = self.new_seq(cfg, kv.capacity());
        fork.adopt_prefix(chain, kv.len());
        Ok(fork)
    }

    /// Release a fork created by [`BlockAllocator::fork_seq`] (the draft
    /// round is over — accepted or not, the draft chain is discarded).
    pub fn release_fork(&mut self, mut fork: PagedKv) -> Result<()> {
        self.release_chain(fork.take_blocks())
    }

    /// Roll a sequence's cache back to `new_len` committed positions
    /// (rejected speculative tail), releasing the blocks the shorter chain
    /// no longer covers. Stale slots inside the kept tail block are simply
    /// rewritten by the next append — stage-time SR encoding is keyed on
    /// the absolute position, so the rewrite is deterministic.
    pub fn rollback_to(&mut self, kv: &mut PagedKv, new_len: usize) -> Result<()> {
        let released = kv.truncate(new_len);
        self.release_chain(released)
    }

    // ---------------------------------------------------- prefix caching

    /// Publish `tokens`' K/V chain (a retired sequence's prompt) under the
    /// full prefix and every block-aligned sub-prefix, so future prompts
    /// can share from any of those cut points. No-op for already-cached
    /// prefixes (their LRU stamp refreshes).
    pub fn prefix_insert(&mut self, tokens: &[usize], kv: &PagedKv) {
        if tokens.len() < 2 {
            return; // reuse feeds at most len-1 positions; nothing to share
        }
        self.tick += 1;
        let mut lengths: Vec<usize> = (1..)
            .map(|i| i * self.block_size)
            .take_while(|&l| l < tokens.len())
            .collect();
        lengths.push(tokens.len());
        for l in lengths {
            let key = prefix_hash(&tokens[..l]);
            let tick = self.tick;
            if let Some(e) = self.prefix.map.get_mut(&key) {
                if e.tokens.as_slice() == &tokens[..l] {
                    e.last_used = tick;
                }
                continue; // cached already (or a collision: keep the old entry)
            }
            let blocks: Vec<Arc<KvBlock>> = kv.blocks_covering(l).to_vec();
            self.retain(&blocks).expect("published chain blocks are live");
            self.prefix.map.insert(
                key,
                PrefixEntry { tokens: tokens[..l].to_vec(), blocks, last_used: self.tick },
            );
            self.prefix.insertions += 1;
        }
    }

    /// Longest cached prefix of `tokens` usable by a new sequence (at most
    /// `tokens.len() - 1` positions — the final token must still be fed to
    /// produce logits). Every length is probed, longest first, via one
    /// pass of running FNV hashes, so non-block-aligned entries (cached
    /// full prompts) are found too — adopting one mid-block is what makes
    /// the copy-on-write append path live. Returns the retained chain and
    /// the number of positions it covers.
    pub fn prefix_lookup(&mut self, tokens: &[usize]) -> Option<(Vec<Arc<KvBlock>>, usize)> {
        let usable = tokens.len().saturating_sub(1);
        if usable == 0 {
            return None;
        }
        self.tick += 1;
        let mut running = Vec::with_capacity(usable);
        let mut h = 0xcbf29ce484222325u64;
        for (i, &t) in tokens[..usable].iter().enumerate() {
            h = fnv_step(h, t);
            running.push(h ^ (i as u64 + 1));
        }
        for l in (1..=usable).rev() {
            let key = running[l - 1];
            let tick = self.tick;
            let Some(e) = self.prefix.map.get_mut(&key) else { continue };
            if e.tokens.as_slice() != &tokens[..l] {
                continue;
            }
            e.last_used = tick;
            let blocks = e.blocks.clone();
            self.retain(&blocks).expect("indexed chain blocks are live");
            return Some((blocks, l));
        }
        None
    }

    /// Evict the least-recently-used prefix entry, releasing its blocks.
    /// Returns `false` when the index is empty.
    pub fn prefix_evict_lru(&mut self) -> bool {
        let Some((&key, _)) =
            self.prefix.map.iter().min_by_key(|(_, e)| e.last_used)
        else {
            return false;
        };
        let entry = self.prefix.map.remove(&key).expect("key just found");
        self.release_chain(entry.blocks).expect("evicted chain blocks were live");
        self.prefix.evictions += 1;
        true
    }

    /// Drop every prefix entry (e.g. at shutdown or for tests).
    pub fn prefix_clear(&mut self) {
        while self.prefix_evict_lru() {}
    }

    pub fn prefix_stats(&self) -> PrefixCacheStats {
        PrefixCacheStats {
            entries: self.prefix.map.len(),
            insertions: self.prefix.insertions,
            evictions: self.prefix.evictions,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::schema::Arch;
    use crate::nn::kv::KvStorage;

    fn cfg() -> ModelConfig {
        ModelConfig::tiny(Arch::Gpt2)
    }

    fn arena(n: usize, bs: usize) -> BlockAllocator {
        BlockAllocator::new(&cfg(), n, bs)
    }

    #[test]
    fn alloc_release_cycle_recycles_ids_and_buffers() {
        let mut a = arena(2, 4);
        let b0 = a.try_alloc().unwrap();
        let b1 = a.try_alloc().unwrap();
        assert_ne!(b0.id, b1.id);
        assert_eq!(a.live_blocks(), 2);
        assert_eq!(a.free_blocks(), 0);
        assert!(a.try_alloc().is_none(), "exhausted arena must refuse");
        let id0 = b0.id;
        a.release(b0).unwrap();
        assert_eq!(a.free_blocks(), 1);
        let b2 = a.try_alloc().unwrap();
        assert_eq!(b2.id, id0, "freed id is recycled");
        assert_eq!(a.reuses, 1, "freed buffer is recycled");
        assert_eq!(a.high_water(), 2);
        a.release(b1).unwrap();
        a.release(b2).unwrap();
        assert_eq!(a.live_blocks(), 0);
        assert!(a.bytes() > 0 && a.live_bytes() == 0);
    }

    #[test]
    fn shared_blocks_release_once_per_holder() {
        let mut a = arena(4, 4);
        let b = a.try_alloc().unwrap();
        let clone = b.clone();
        a.retain(std::slice::from_ref(&clone)).unwrap();
        assert!(a.is_shared(b.id));
        a.release(b).unwrap();
        assert_eq!(a.live_blocks(), 1, "still held by the clone");
        assert!(!a.is_shared(clone.id));
        a.release(clone).unwrap();
        assert_eq!(a.live_blocks(), 0);
    }

    #[test]
    fn double_release_returns_error_in_o1() {
        let mut a = arena(2, 4);
        let b = a.try_alloc().unwrap();
        let dup = b.clone();
        let id = b.id;
        a.release(b).unwrap();
        let err = a.release(dup).unwrap_err().to_string();
        assert!(err.contains(&format!("double release of block {id}")), "{err}");
        // the arena stayed consistent: the id is still free exactly once
        assert_eq!(a.free_blocks(), 2);
        assert!(a.try_alloc().is_some());
    }

    #[test]
    fn retain_after_free_returns_error() {
        let mut a = arena(2, 4);
        let b = a.try_alloc().unwrap();
        let dup = b.clone();
        let id = b.id;
        a.release(b).unwrap();
        let err = a.retain(std::slice::from_ref(&dup)).unwrap_err().to_string();
        assert!(err.contains(&format!("retain of freed block {id}")), "{err}");
        assert_eq!(a.live_blocks(), 0, "failed retain must not resurrect the block");
    }

    #[test]
    fn release_chain_reports_first_error_but_releases_rest() {
        let mut a = arena(3, 4);
        let b0 = a.try_alloc().unwrap();
        let b1 = a.try_alloc().unwrap();
        let stale = b0.clone();
        a.release(b0).unwrap();
        // chain contains one already-freed block and one live block
        let err = a.release_chain(vec![stale, b1]).unwrap_err().to_string();
        assert!(err.contains("double release"), "{err}");
        assert_eq!(a.live_blocks(), 0, "live block after the bad entry was still released");
    }

    #[test]
    fn reserve_attaches_blocks_and_respects_budget() {
        let c = cfg();
        let mut a = arena(3, 4);
        let mut kv = a.new_seq(&c, 64);
        assert_eq!(a.max_appendable(&kv), 12);
        assert!(a.reserve(&mut kv, 9)); // 3 blocks
        assert_eq!(kv.n_blocks(), 3);
        assert_eq!(a.free_blocks(), 0);
        let row = vec![0.0f32; c.d_model];
        for pos in 0..9 {
            for l in 0..c.n_layer {
                kv.write(l, pos, &row, &row);
            }
            kv.commit(1);
        }
        assert_eq!(a.max_appendable(&kv), 3, "room left in the third block");
        assert!(a.reserve(&mut kv, 3), "in-chain room needs no new block");
        assert!(!a.reserve(&mut kv, 4), "fourth block exceeds the budget");
        a.release_chain(kv.take_blocks()).unwrap();
        assert_eq!(a.free_blocks(), 3);
    }

    #[test]
    fn cow_append_copies_shared_tail() {
        let c = cfg();
        let mut a = arena(4, 4);
        // sequence 1 writes 6 positions (2 blocks), publishes its chain
        let mut kv1 = a.new_seq(&c, 64);
        assert!(a.reserve(&mut kv1, 6));
        let row = vec![1.0f32; c.d_model];
        for pos in 0..6 {
            for l in 0..c.n_layer {
                kv1.write(l, pos, &row, &row);
            }
            kv1.commit(1);
        }
        let chain = kv1.take_blocks();
        a.retain(&chain).unwrap(); // simulate an index holding the chain
        // sequence 2 adopts the chain (positions 0..6) and appends
        let mut kv2 = a.new_seq(&c, 64);
        kv2.adopt_prefix(&chain, 6);
        a.retain(kv2.blocks_covering(6)).unwrap();
        a.release_chain(chain).unwrap(); // original holder leaves; index copy stays
        assert!(a.is_shared(kv2.block_table()[1]));
        assert!(a.reserve(&mut kv2, 1), "CoW within budget");
        assert_eq!(a.cow_copies, 1);
        assert!(
            !a.is_shared(kv2.tail_block().unwrap().id),
            "tail is now exclusive"
        );
        let row2 = vec![2.0f32; c.d_model];
        for l in 0..c.n_layer {
            kv2.write(l, 6, &row2, &row2);
        }
        kv2.commit(1);
        // the frozen shared copy kept sequence 1's data
        assert_eq!(kv2.k_row(0, 6), &row2[..]);
        assert_eq!(kv2.k_row(0, 5), &row[..]);
    }

    #[test]
    fn make_tail_exclusive_refcount_transitions() {
        // shared tail (refs 2): CoW allocates a fresh exclusive block,
        // drops one reference from the original (refs 2 -> 1), and leaves
        // the other holder's view untouched
        let c = cfg();
        let mut a = arena(4, 4);
        let mut kv1 = a.new_seq(&c, 64);
        assert!(a.reserve(&mut kv1, 2));
        let row = vec![3.0f32; c.d_model];
        for pos in 0..2 {
            for l in 0..c.n_layer {
                kv1.write(l, pos, &row, &row);
            }
            kv1.commit(1);
        }
        let chain = kv1.take_blocks();
        a.retain(&chain).unwrap(); // a second holder (e.g. the prefix index)
        let shared_id = chain[0].id;
        let mut kv2 = a.new_seq(&c, 64);
        // adopt clones the Arcs only; register kv2 as a holder explicitly,
        // the way the scheduler does, then drop the original holder
        kv2.adopt_prefix(&chain, 2);
        a.retain(kv2.blocks_covering(2)).unwrap();
        a.release_chain(chain).unwrap();
        assert!(a.is_shared(shared_id), "index + kv2 share the block");
        let live_before = a.live_blocks();
        assert!(a.make_tail_exclusive(&mut kv2));
        assert_eq!(a.cow_copies, 1);
        assert_eq!(a.live_blocks(), live_before + 1, "CoW consumed one fresh block");
        assert!(!a.is_shared(shared_id), "original dropped to a single holder");
        let new_tail = kv2.tail_block().unwrap().id;
        assert_ne!(new_tail, shared_id);
        assert!(!a.is_shared(new_tail), "fresh copy is exclusive");
        // idempotent: an exclusive tail needs no further copies
        assert!(a.make_tail_exclusive(&mut kv2));
        assert_eq!(a.cow_copies, 1);
        // cleanup: both chains release without error
        a.release_chain(kv2.take_blocks()).unwrap();
        a.prefix_clear();
    }

    #[test]
    fn prefix_index_roundtrip_and_lru_eviction() {
        let c = cfg();
        let mut a = arena(8, 4);
        let prompt: Vec<usize> = (0..10).collect();
        let mut kv = a.new_seq(&c, 64);
        assert!(a.reserve(&mut kv, 10));
        let row = vec![0.5f32; c.d_model];
        for pos in 0..10 {
            for l in 0..c.n_layer {
                kv.write(l, pos, &row, &row);
            }
            kv.commit(1);
        }
        a.prefix_insert(&prompt, &kv);
        // full prefix (10) + block-aligned cuts (4, 8)
        assert_eq!(a.prefix_stats().insertions, 3);
        a.release_chain(kv.take_blocks()).unwrap();
        assert_eq!(a.live_blocks(), 3, "index keeps the chain alive");

        // identical prompt: reuse covers len-1 = 9 positions? no entry at 9,
        // so the block-aligned 8 wins
        let (chain, reused) = a.prefix_lookup(&prompt).unwrap();
        assert_eq!(reused, 8);
        assert_eq!(chain.len(), 2);
        a.release_chain(chain).unwrap();

        // a prompt sharing only the first 4 tokens
        let mut other: Vec<usize> = (0..10).collect();
        other[5] = 40;
        let (chain, reused) = a.prefix_lookup(&other).unwrap();
        assert_eq!(reused, 4);
        a.release_chain(chain).unwrap();

        // unknown prompt misses
        assert!(a.prefix_lookup(&[30, 31, 32]).is_none());

        // eviction drains the index and frees the blocks
        assert!(a.prefix_evict_lru());
        a.prefix_clear();
        assert_eq!(a.prefix_stats().entries, 0);
        assert_eq!(a.live_blocks(), 0);
    }

    #[test]
    fn prefix_lru_evicts_in_recency_order() {
        // three distinct short prompts (each shorter than a block => one
        // entry each); touching one refreshes its stamp, so eviction must
        // walk the untouched entries oldest-first
        let c = cfg();
        let mut a = arena(8, 4);
        let prompts: [Vec<usize>; 3] = [vec![1, 2, 3], vec![4, 5, 6], vec![7, 8, 9]];
        for p in &prompts {
            let mut kv = a.new_seq(&c, 64);
            assert!(a.reserve(&mut kv, p.len()));
            let row = vec![0.25f32; c.d_model];
            for pos in 0..p.len() {
                for l in 0..c.n_layer {
                    kv.write(l, pos, &row, &row);
                }
                kv.commit(1);
            }
            a.prefix_insert(p, &kv);
            a.release_chain(kv.take_blocks()).unwrap();
        }
        assert_eq!(a.prefix_stats().entries, 3);
        // lookups see at most len-1 positions, so probe with the prompt
        // plus one divergent token to hit the full 3-token entries
        let probe = |p: &[usize]| {
            let mut q = p.to_vec();
            q.push(99);
            q
        };
        // touch prompt 0: its stamp is now the newest
        let (chain, n) = a.prefix_lookup(&probe(&prompts[0])).unwrap();
        assert_eq!(n, 3);
        a.release_chain(chain).unwrap();
        // first eviction removes prompt 1 (oldest untouched) …
        assert!(a.prefix_evict_lru());
        assert!(
            a.prefix_lookup(&probe(&prompts[1])).is_none(),
            "prompt 1 should be evicted first"
        );
        let (chain, _) = a.prefix_lookup(&probe(&prompts[0])).unwrap(); // touch again
        a.release_chain(chain).unwrap();
        // … second removes prompt 2 …
        assert!(a.prefix_evict_lru());
        assert!(
            a.prefix_lookup(&probe(&prompts[2])).is_none(),
            "prompt 2 should be evicted second"
        );
        // … and the most-recently-used prompt 0 survives to the last round
        let (chain, _) = a.prefix_lookup(&probe(&prompts[0])).unwrap();
        a.release_chain(chain).unwrap();
        assert!(a.prefix_evict_lru());
        assert_eq!(a.prefix_stats().entries, 0);
        assert_eq!(a.live_blocks(), 0);
    }

    #[test]
    fn fork_shares_blocks_and_first_append_cows() {
        let c = cfg();
        let mut a = arena(6, 4);
        let mut kv = a.new_seq(&c, 64);
        assert!(a.reserve(&mut kv, 6)); // 2 blocks, tail half-full
        let row = vec![1.0f32; c.d_model];
        for pos in 0..6 {
            for l in 0..c.n_layer {
                kv.write(l, pos, &row, &row);
            }
            kv.commit(1);
        }
        let live_before = a.live_blocks();
        let mut fork = a.fork_seq(&c, &kv).unwrap();
        assert_eq!(fork.len(), 6);
        assert_eq!(fork.block_table(), kv.block_table(), "fork shares the chain");
        assert_eq!(a.live_blocks(), live_before, "fork is refcounts only, zero fresh blocks");
        assert!(a.is_shared(kv.tail_block().unwrap().id));
        // fork's first append copy-on-writes its tail; parent stays frozen
        assert!(a.reserve(&mut fork, 1));
        assert_eq!(a.cow_copies, 1);
        assert_ne!(fork.block_table()[1], kv.block_table()[1]);
        let draft = vec![9.0f32; c.d_model];
        for l in 0..c.n_layer {
            fork.write(l, 6, &draft, &draft);
        }
        fork.commit(1);
        assert_eq!(kv.k_row(0, 5), &row[..], "parent view unchanged by the fork's append");
        // parent appends next: its tail is exclusive again after the CoW
        assert!(!a.is_shared(kv.tail_block().unwrap().id));
        a.release_fork(fork).unwrap();
        a.release_chain(kv.take_blocks()).unwrap();
        assert_eq!(a.live_blocks(), 0, "fork + rollback leaks nothing");
    }

    #[test]
    fn rollback_releases_uncovered_blocks() {
        let c = cfg();
        let mut a = arena(4, 4);
        let mut kv = a.new_seq(&c, 64);
        assert!(a.reserve(&mut kv, 11)); // 3 blocks
        let row = vec![0.5f32; c.d_model];
        for pos in 0..11 {
            for l in 0..c.n_layer {
                kv.write(l, pos, &row, &row);
            }
            kv.commit(1);
        }
        assert_eq!(a.live_blocks(), 3);
        a.rollback_to(&mut kv, 6).unwrap();
        assert_eq!(kv.len(), 6);
        assert_eq!(a.live_blocks(), 2, "block 3 released to the arena");
        // the kept tail's stale slots are rewritable straight away
        assert!(a.reserve(&mut kv, 1));
        for l in 0..c.n_layer {
            kv.write(l, 6, &row, &row);
        }
        kv.commit(1);
        a.rollback_to(&mut kv, 0).unwrap();
        assert_eq!(a.live_blocks(), 0);
    }

    #[test]
    fn quantized_arena_hands_out_encoded_blocks() {
        let c = cfg();
        let scheme = crate::quant::resolve("fp8_e3m4").unwrap();
        let mut a = BlockAllocator::with_scheme(&c, 4, 4, scheme, 11).unwrap();
        assert_eq!(a.kv_store_label(), "fp8_e3m4");
        assert!(a.bytes_per_position() < 2 * c.n_layer * c.d_model * 4);
        // fused default: what's resident IS the encoded layout, no mirror
        assert_eq!(a.bytes(), a.encoded_bytes());
        let b = a.try_alloc().unwrap();
        assert!(b.is_encoded());
        assert!(!b.has_mirror());
        let mut kv = a.new_seq(&c, 64);
        assert!(kv.kv_quant().is_quantizing());
        assert!(a.reserve(&mut kv, 2));
        let row: Vec<f32> = (0..c.d_model).map(|i| (i as f32) * 0.03 - 0.9).collect();
        for l in 0..c.n_layer {
            kv.write(l, 0, &row, &row);
        }
        kv.commit(1);
        // no f32 rows to read: reconstruct each element through the fused
        // kernel (a one-hot dot) and check the row really quantized
        let decoded: Vec<f32> = (0..c.d_model).map(|e| kv.dot_k(0, 0, e, &[1.0])).collect();
        assert!(decoded.iter().zip(&row).any(|(x, y)| x != y), "rows must quantize");
        a.release_chain(kv.take_blocks()).unwrap();
        a.release(b).unwrap();
        assert_eq!(a.live_blocks(), 0);
    }

    #[test]
    fn mirror_arena_costs_more_than_fused() {
        let c = cfg();
        let scheme = crate::quant::resolve("fp8_e3m4").unwrap();
        let fused = BlockAllocator::with_scheme(&c, 4, 4, scheme.clone(), 11).unwrap();
        let quant = KvQuant::new(scheme, c.d_model, 11).unwrap().with_mirror();
        let mirrored = BlockAllocator::with_quant(&c, 4, 4, quant);
        assert_eq!(mirrored.encoded_bytes(), fused.encoded_bytes());
        assert_eq!(
            mirrored.bytes(),
            fused.bytes() + 4 * 2 * c.n_layer * 4 * c.d_model * 4,
            "mirror adds exactly the f32 rows"
        );
    }

    #[test]
    fn with_scheme_rejects_unhostable_geometry() {
        let c = cfg();
        let elem = crate::quant::resolve("fp8_e3m4").unwrap().elementwise();
        assert!(BlockAllocator::with_scheme(&c, 4, 4, elem, 0).is_err());
        let ragged = crate::quant::resolve("fp8_e3m4").unwrap().with_block(48);
        assert!(BlockAllocator::with_scheme(&c, 4, 4, ragged, 0).is_err());
    }
}
