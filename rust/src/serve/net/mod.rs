//! TCP serving front end — the network edge over
//! [`serve::protocol`](crate::serve::protocol).
//!
//! Std-only (no async runtime, no new dependencies): a connection
//! acceptor plus per-connection reader/writer threads feed the one engine
//! thread that owns the [`Engine`](crate::serve::Engine). The wire format
//! is length-prefixed newline-JSON ([`frame`]): `<len> <payload>\n`, one
//! compact JSON object per frame.
//!
//! The edge enforces what the in-process front never had to:
//!
//! * **strict parsing** —
//!   [`GenRequest::from_json_strict`](crate::serve::GenRequest::from_json_strict):
//!   missing or mistyped fields come back as one per-field
//!   [`ErrorResponse`](crate::serve::ErrorResponse) frame, never a silent
//!   default;
//! * **admission control / backpressure** — requests admit against live
//!   free-block headroom, queue up to a bound, then shed with a
//!   `retry_after_ms` hint ([`NetServerConfig`]);
//! * **deadlines** — per-request `deadline_ms` (or a server default)
//!   finishes overdue requests with `FinishReason::Deadline` and whatever
//!   tokens they produced;
//! * **graceful drain** — [`NetServer::shutdown`] stops accepting,
//!   completes and flushes every in-flight request, then returns the run's
//!   [`ServeStats`](crate::serve::ServeStats) with the live-block gauge at
//!   zero.
//!
//! The whole lifecycle is observable through the engine's telemetry
//! registry: `net.connections_accepted/closed`, `net.frames_in/bad`,
//! `net.requests_admitted/rejected/shed`, `net.responses_sent`, plus the
//! per-request trace spans the engine already records.

pub mod client;
pub mod frame;
pub mod server;

pub use client::NetClient;
pub use server::{NetServer, NetServerConfig};
