//! A small blocking TCP client over the frame protocol — the transport the
//! load runner uses in `--driver tcp` mode, and what an external client of
//! the server would look like.

use crate::serve::net::frame;
use crate::serve::protocol::{parse_reply, ErrorResponse, GenRequest, GenResponse};
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::io::BufReader;
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

/// One connection to a [`NetServer`](crate::serve::net::NetServer).
/// Requests may be pipelined ([`NetClient::send`] repeatedly, then
/// [`NetClient::recv`]); replies come back in completion order, not send
/// order, so pipelining callers must route by response id.
pub struct NetClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl NetClient {
    pub fn connect(addr: impl ToSocketAddrs) -> Result<NetClient> {
        let stream = TcpStream::connect(addr).context("connect")?;
        let _ = stream.set_nodelay(true);
        let writer = stream.try_clone().context("clone stream")?;
        Ok(NetClient { reader: BufReader::new(stream), writer })
    }

    /// Frame and send one request (does not wait for the reply).
    pub fn send(&mut self, req: &GenRequest) -> Result<()> {
        frame::write_frame(&mut self.writer, &req.to_json().to_string())
            .context("send request frame")?;
        Ok(())
    }

    /// Block for the next reply frame: a completed [`GenResponse`] or a
    /// structured [`ErrorResponse`].
    pub fn recv(&mut self) -> Result<std::result::Result<GenResponse, ErrorResponse>> {
        let payload = frame::read_frame(&mut self.reader)
            .context("read reply frame")?
            .context("connection closed by server")?;
        let j = Json::parse(&payload).context("reply is not valid JSON")?;
        parse_reply(&j)
    }

    /// Closed-loop convenience: send one request and block for its reply.
    /// An error frame becomes an `Err` (with the retry hint in the
    /// message); use [`NetClient::generate_retrying`] to honor it instead.
    pub fn generate(&mut self, req: &GenRequest) -> Result<GenResponse> {
        self.send(req)?;
        match self.recv()? {
            Ok(resp) if resp.id == req.id => Ok(resp),
            Ok(resp) => bail!("response id {} does not match request {}", resp.id, req.id),
            Err(e) => match e.retry_after_ms {
                Some(ms) => bail!("request {} shed: {} (retry after {ms} ms)", req.id, e.error),
                None => bail!("request {} rejected: {}", req.id, e.error),
            },
        }
    }

    /// [`NetClient::generate`], but back off and retry when the server
    /// sheds the request with a `retry_after_ms` hint. Permanent errors
    /// still fail immediately. `max_retries` bounds the retry loop.
    pub fn generate_retrying(&mut self, req: &GenRequest, max_retries: usize) -> Result<GenResponse> {
        let mut attempts = 0;
        loop {
            self.send(req)?;
            match self.recv()? {
                Ok(resp) if resp.id == req.id => return Ok(resp),
                Ok(resp) => bail!("response id {} does not match request {}", resp.id, req.id),
                Err(e) => match e.retry_after_ms {
                    Some(ms) if attempts < max_retries => {
                        attempts += 1;
                        std::thread::sleep(Duration::from_millis(ms.clamp(1, 100)));
                    }
                    Some(_) => bail!("request {} shed after {attempts} retries: {}", req.id, e.error),
                    None => bail!("request {} rejected: {}", req.id, e.error),
                },
            }
        }
    }
}
