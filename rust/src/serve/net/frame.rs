//! Length-prefixed newline-JSON framing for the TCP front end.
//!
//! A frame is `<len> <payload>\n`: the payload's byte length in ASCII
//! decimal, one space, exactly `len` payload bytes, one trailing newline.
//! The explicit length makes the stream self-synchronizing for well-behaved
//! peers while staying trivially greppable on the wire (each frame is one
//! line); the trailing newline is *verified*, so a peer whose length field
//! lies is detected immediately instead of silently desynchronizing.
//!
//! Defensive bounds: the length header is capped at 8 digits and the
//! payload at [`MAX_FRAME_LEN`], so a garbage or adversarial header cannot
//! make the server allocate unbounded memory. All violations surface as
//! `io::ErrorKind::InvalidData`; the connection is then dropped after an
//! error frame (resynchronizing with a malformed peer is not attempted).

use std::io::{self, BufRead, Write};

/// Hard cap on a frame's payload size (1 MiB — far above any real request:
/// a full-context prompt serializes to a few hundred KiB).
pub const MAX_FRAME_LEN: usize = 1 << 20;

fn bad(msg: &str) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg.to_string())
}

/// Write one frame. The frame is materialized first so the transport sees
/// a single `write_all` (one syscall on an unbuffered `TcpStream`).
pub fn write_frame<W: Write>(w: &mut W, payload: &str) -> io::Result<()> {
    if payload.len() > MAX_FRAME_LEN {
        return Err(bad("frame payload exceeds MAX_FRAME_LEN"));
    }
    w.write_all(format!("{} {}\n", payload.len(), payload).as_bytes())
}

/// Read one frame. `Ok(None)` is a clean EOF at a frame boundary; EOF
/// anywhere inside a frame, a malformed header, an oversized length, or a
/// missing trailing newline is an `InvalidData`/`UnexpectedEof` error.
pub fn read_frame<R: BufRead>(r: &mut R) -> io::Result<Option<String>> {
    let mut len: usize = 0;
    let mut digits = 0;
    loop {
        let mut b = [0u8; 1];
        match r.read(&mut b) {
            Ok(0) => {
                return if digits == 0 {
                    Ok(None) // clean EOF between frames
                } else {
                    Err(bad("eof inside frame header"))
                };
            }
            Ok(_) => {}
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
        match b[0] {
            b'0'..=b'9' => {
                digits += 1;
                if digits > 8 {
                    return Err(bad("frame length header too long"));
                }
                len = len * 10 + (b[0] - b'0') as usize;
            }
            b' ' if digits > 0 => break,
            _ => return Err(bad("frame header must be '<len> <payload>\\n'")),
        }
    }
    if len > MAX_FRAME_LEN {
        return Err(bad("frame payload exceeds MAX_FRAME_LEN"));
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    let mut nl = [0u8; 1];
    r.read_exact(&mut nl)?;
    if nl[0] != b'\n' {
        return Err(bad("frame length does not match payload (no trailing newline)"));
    }
    String::from_utf8(payload).map(Some).map_err(|_| bad("frame payload is not UTF-8"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn rt(payloads: &[&str]) -> Vec<String> {
        let mut buf = Vec::new();
        for p in payloads {
            write_frame(&mut buf, p).unwrap();
        }
        let mut r = Cursor::new(buf);
        let mut out = Vec::new();
        while let Some(p) = read_frame(&mut r).unwrap() {
            out.push(p);
        }
        out
    }

    #[test]
    fn frames_roundtrip() {
        let payloads = [r#"{"id":1}"#, "", "x", "newline \\n inside stays escaped"];
        assert_eq!(rt(&payloads), payloads);
    }

    #[test]
    fn clean_eof_is_none() {
        let mut r = Cursor::new(Vec::<u8>::new());
        assert!(read_frame(&mut r).unwrap().is_none());
    }

    #[test]
    fn garbage_header_rejected() {
        for bad_stream in ["hello\n", " 5 abcde\n", "5x abc\n", "\n"] {
            let mut r = Cursor::new(bad_stream.as_bytes().to_vec());
            assert!(read_frame(&mut r).is_err(), "{bad_stream:?}");
        }
    }

    #[test]
    fn lying_length_rejected() {
        // header says 3 bytes but the payload has 5 before the newline
        let mut r = Cursor::new(b"3 abcde\n".to_vec());
        assert!(read_frame(&mut r).is_err());
    }

    #[test]
    fn partial_frame_then_eof_rejected() {
        for partial in ["12", "12 ", "5 ab"] {
            let mut r = Cursor::new(partial.as_bytes().to_vec());
            assert!(read_frame(&mut r).is_err(), "{partial:?}");
        }
    }

    #[test]
    fn oversized_frame_rejected() {
        let mut r = Cursor::new(format!("{} x\n", MAX_FRAME_LEN + 1).into_bytes());
        assert!(read_frame(&mut r).is_err());
        let mut sink = Vec::new();
        let huge = "y".repeat(MAX_FRAME_LEN + 1);
        assert!(write_frame(&mut sink, &huge).is_err());
    }
}
