//! The TCP serving front end: an acceptor plus per-connection reader and
//! writer threads feeding one engine thread.
//!
//! Thread layout (all std, no async runtime):
//!
//! ```text
//!   acceptor ──spawns──▶ reader ──(GenRequest, reply_tx)──▶ engine thread
//!                          │                                    │ step()
//!                          └─parse errors─▶ writer ◀─responses──┘
//! ```
//!
//! * The **engine thread** owns the [`Engine`] outright — and with it the
//!   single-writer [`TraceBuffer`](crate::telemetry::TraceBuffer) — so
//!   every admission decision and trace event happens on one thread.
//!   Registry counters are sharded atomics, so connection threads bump the
//!   `net.*` counters directly.
//! * **Admission/backpressure** is decided on the engine thread against
//!   live [`BlockAllocator`](crate::serve::kvcache::BlockAllocator) state:
//!   a request whose block need fits the current free headroom is
//!   admitted; otherwise it may still queue while the scheduler's pending
//!   queue is below [`NetServerConfig::max_pending`]; beyond that it is
//!   shed with a retryable [`ErrorResponse`] carrying `retry_after_ms`.
//! * **Graceful drain** ([`NetServer::shutdown`]): the acceptor stops
//!   accepting and exits; every open connection's read half is shut down
//!   (readers unblock, drop their channel senders); the engine thread
//!   keeps stepping until the channel disconnects *and* the engine is
//!   idle, so every in-flight request completes and its response is
//!   flushed; finally the prefix cache is dropped so the live-block gauge
//!   drains to zero.

use crate::serve::engine::Engine;
use crate::serve::net::frame;
use crate::serve::protocol::{ErrorResponse, GenRequest};
use crate::serve::stats::ServeStats;
use crate::telemetry::Counter;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::io::{self, BufReader, BufWriter, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Open-connection registry: one shutdown handle per live connection,
/// keyed by a monotonic connection id. The acceptor inserts, the
/// connection's reader removes its own entry when it exits — so the map
/// tracks exactly the live connections (it is how graceful drain unblocks
/// blocked readers) instead of accumulating one dead `TcpStream` clone
/// per connection ever accepted for the server's whole lifetime.
type ConnMap = Arc<Mutex<HashMap<u64, TcpStream>>>;

/// Front-end admission knobs (the engine's own config governs everything
/// behind the socket).
#[derive(Debug, Clone)]
pub struct NetServerConfig {
    /// Scheduler pending-queue bound: a request that does not fit the free
    /// block headroom may still queue until this many requests wait;
    /// beyond it the server sheds load with a retryable error.
    pub max_pending: usize,
    /// Back-off hint (milliseconds) returned with shed requests.
    pub retry_after_ms: u64,
    /// Deadline applied to requests that carry none (`None` = unbounded).
    pub default_deadline_ms: Option<u64>,
}

impl Default for NetServerConfig {
    fn default() -> Self {
        NetServerConfig { max_pending: 64, retry_after_ms: 50, default_deadline_ms: None }
    }
}

/// Connection-thread telemetry handles (cloned per connection; counters
/// are thread-safe sharded atomics on the engine's registry).
#[derive(Clone)]
struct ConnCounters {
    accepted: Counter,
    closed: Counter,
    clone_failed: Counter,
    frames_in: Counter,
    frames_bad: Counter,
}

enum NetMsg {
    Request(GenRequest, mpsc::Sender<String>),
}

/// A listening TCP serving front end over one [`Engine`].
pub struct NetServer {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    conns: ConnMap,
    msg_tx: Option<mpsc::Sender<NetMsg>>,
    acceptor: Option<std::thread::JoinHandle<()>>,
    engine_join: Option<std::thread::JoinHandle<ServeStats>>,
}

impl NetServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral test port) and
    /// start serving `engine` on a dedicated thread.
    pub fn bind(addr: &str, engine: Engine, cfg: NetServerConfig) -> Result<NetServer> {
        let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        listener.set_nonblocking(true).context("listener nonblocking")?;
        let local = listener.local_addr().context("local_addr")?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let conns: ConnMap = Arc::new(Mutex::new(HashMap::new()));
        let (msg_tx, msg_rx) = mpsc::channel::<NetMsg>();
        let reg = engine.stats.registry().clone();
        let counters = ConnCounters {
            accepted: reg.counter("net.connections_accepted"),
            closed: reg.counter("net.connections_closed"),
            clone_failed: reg.counter("net.accept_clone_failures"),
            frames_in: reg.counter("net.frames_in"),
            frames_bad: reg.counter("net.frames_bad"),
        };
        let engine_join = std::thread::spawn(move || engine_loop(engine, msg_rx, cfg));
        let acceptor = {
            let shutdown = shutdown.clone();
            let conns = conns.clone();
            let msg_tx = msg_tx.clone();
            std::thread::spawn(move || accept_loop(listener, msg_tx, shutdown, conns, counters))
        };
        Ok(NetServer {
            addr: local,
            shutdown,
            conns,
            msg_tx: Some(msg_tx),
            acceptor: Some(acceptor),
            engine_join: Some(engine_join),
        })
    }

    /// The bound address (the ephemeral port when bound to `:0`).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Connections currently open (registry entries). Closed connections
    /// are reaped by their reader on exit, so this is live state, not a
    /// lifetime total — `net.connections_accepted` minus
    /// `net.connections_closed` converges to it at quiescence.
    pub fn open_connections(&self) -> usize {
        self.conns.lock().expect("conns lock").len()
    }

    /// Graceful drain: stop accepting, finish every in-flight request,
    /// flush its response, and return the engine's stats.
    pub fn shutdown(mut self) -> ServeStats {
        self.drain()
    }

    fn drain(&mut self) -> ServeStats {
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(a) = self.acceptor.take() {
            a.join().expect("acceptor thread panicked");
        }
        // unblock every still-open reader: in-flight requests drain, new
        // frames stop (each reader reaps its own registry entry as it exits)
        for (_, c) in self.conns.lock().expect("conns lock").iter() {
            let _ = c.shutdown(Shutdown::Read);
        }
        drop(self.msg_tx.take());
        self.engine_join
            .take()
            .expect("server already shut down")
            .join()
            .expect("engine thread panicked")
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        if self.engine_join.is_some() {
            self.drain();
        }
    }
}

fn accept_loop(
    listener: TcpListener,
    msg_tx: mpsc::Sender<NetMsg>,
    shutdown: Arc<AtomicBool>,
    conns: ConnMap,
    counters: ConnCounters,
) {
    let mut next_conn_id: u64 = 0;
    while !shutdown.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                counters.accepted.inc();
                // accepted sockets can inherit the listener's nonblocking
                // mode on some platforms; readers/writers want blocking IO
                let _ = stream.set_nonblocking(false);
                let _ = stream.set_nodelay(true);
                // the writer thread and the shutdown registry each need
                // their own handle; if the OS won't dup the fd the
                // connection cannot be served — close it explicitly and
                // count both edges (it was counted accepted) rather than
                // silently leaking a half-set-up socket
                let (write_half, keep) = match (stream.try_clone(), stream.try_clone()) {
                    (Ok(w), Ok(k)) => (w, k),
                    _ => {
                        counters.clone_failed.inc();
                        counters.closed.inc();
                        let _ = stream.shutdown(Shutdown::Both);
                        continue;
                    }
                };
                let conn_id = next_conn_id;
                next_conn_id += 1;
                conns.lock().expect("conns lock").insert(conn_id, keep);
                let (out_tx, out_rx) = mpsc::channel::<String>();
                std::thread::spawn(move || writer_loop(write_half, out_rx));
                let tx = msg_tx.clone();
                let cc = counters.clone();
                let registry = conns.clone();
                std::thread::spawn(move || reader_loop(stream, conn_id, registry, tx, out_tx, cc));
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(2)),
        }
    }
}

/// Per-connection reader: decode frames, strict-parse requests, forward to
/// the engine thread. Malformed payloads get an [`ErrorResponse`] and the
/// connection stays open; a framing violation gets one and closes it. On
/// exit the reader reaps its own entry from the open-connection registry
/// (the socket's last shutdown handle drops with it) and counts the close.
fn reader_loop(
    stream: TcpStream,
    conn_id: u64,
    conns: ConnMap,
    msg_tx: mpsc::Sender<NetMsg>,
    out_tx: mpsc::Sender<String>,
    counters: ConnCounters,
) {
    let mut r = BufReader::new(stream);
    loop {
        match frame::read_frame(&mut r) {
            Ok(Some(payload)) => {
                counters.frames_in.inc();
                let reply = match Json::parse(&payload) {
                    Ok(j) => match GenRequest::from_json_strict(&j) {
                        Ok(req) => {
                            if msg_tx.send(NetMsg::Request(req, out_tx.clone())).is_ok() {
                                None
                            } else {
                                Some(ErrorResponse::permanent(
                                    j.get("id").as_u64(),
                                    "server is shutting down",
                                ))
                            }
                        }
                        Err(e) => {
                            counters.frames_bad.inc();
                            Some(ErrorResponse::permanent(j.get("id").as_u64(), format!("{e:#}")))
                        }
                    },
                    Err(e) => {
                        counters.frames_bad.inc();
                        Some(ErrorResponse::permanent(None, format!("invalid JSON: {e}")))
                    }
                };
                if let Some(err) = reply {
                    if out_tx.send(err.to_json().to_string()).is_err() {
                        break;
                    }
                }
            }
            Ok(None) => break, // clean EOF
            Err(e) => {
                counters.frames_bad.inc();
                let _ = out_tx
                    .send(ErrorResponse::permanent(None, format!("framing: {e}")).to_json().to_string());
                break;
            }
        }
    }
    conns.lock().expect("conns lock").remove(&conn_id);
    counters.closed.inc();
}

/// Per-connection writer: owns the socket's write half; frames every
/// outgoing payload and flushes per message (replies are latency-bound).
/// Exits when every sender (reader + engine-held response routes) is gone.
fn writer_loop(stream: TcpStream, rx: mpsc::Receiver<String>) {
    let mut w = BufWriter::new(stream);
    while let Ok(payload) = rx.recv() {
        if frame::write_frame(&mut w, &payload).is_err() || w.flush().is_err() {
            return;
        }
    }
}

/// The engine thread: owns the [`Engine`], decides admission, steps waves,
/// and routes responses back to each request's connection writer.
fn engine_loop(mut engine: Engine, rx: mpsc::Receiver<NetMsg>, cfg: NetServerConfig) -> ServeStats {
    let reg = engine.stats.registry().clone();
    let admitted = reg.counter("net.requests_admitted");
    let rejected = reg.counter("net.requests_rejected");
    let shed = reg.counter("net.requests_shed");
    let responses = reg.counter("net.responses_sent");
    let mut responders: Vec<(u64, mpsc::Sender<String>)> = Vec::new();
    let mut open = true;
    loop {
        // block for work when fully idle; otherwise drain whatever arrived
        if engine.is_idle() && open {
            match rx.recv() {
                Ok(msg) => handle(&mut engine, &cfg, &mut responders, msg, (&admitted, &rejected, &shed)),
                Err(_) => open = false,
            }
        }
        while open {
            match rx.try_recv() {
                Ok(msg) => handle(&mut engine, &cfg, &mut responders, msg, (&admitted, &rejected, &shed)),
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    open = false;
                    break;
                }
            }
        }
        for resp in engine.step() {
            if let Some(i) = responders.iter().position(|(id, _)| *id == resp.id) {
                let (_, tx) = responders.swap_remove(i);
                if tx.send(resp.to_json().to_string()).is_ok() {
                    responses.inc();
                }
            }
        }
        if !open && engine.is_idle() {
            // drain epilogue: drop cached prefix chains so the live-block
            // gauge ends at zero (the leak invariant tests assert on)
            engine.clear_prefix_cache();
            return engine.stats;
        }
    }
}

/// Admission control for one incoming request, on the engine thread.
fn handle(
    engine: &mut Engine,
    cfg: &NetServerConfig,
    responders: &mut Vec<(u64, mpsc::Sender<String>)>,
    msg: NetMsg,
    (admitted, rejected, shed): (&Counter, &Counter, &Counter),
) {
    let NetMsg::Request(mut req, reply_tx) = msg;
    let id = req.id;
    // responses route by id, so a duplicate in-flight id is ambiguous
    if responders.iter().any(|(rid, _)| *rid == id) {
        rejected.inc();
        let _ = reply_tx.send(
            ErrorResponse::permanent(Some(id), format!("request {id}: duplicate in-flight id"))
                .to_json()
                .to_string(),
        );
        return;
    }
    if req.deadline_ms.is_none() {
        req.deadline_ms = cfg.default_deadline_ms;
    }
    // backpressure: fits-free-headroom admits; otherwise queue while the
    // pending queue is below its bound; beyond that, shed with a hint
    if engine.blocks_for_request(&req) > engine.free_blocks()
        && engine.queued() >= cfg.max_pending
    {
        shed.inc();
        if let Some(t) = engine.stats.trace_mut() {
            t.instant("net.shed", id, vec![]);
        }
        let _ = reply_tx.send(
            ErrorResponse::retryable(
                id,
                format!(
                    "overloaded: {} free blocks, {} queued (bound {})",
                    engine.free_blocks(),
                    engine.queued(),
                    cfg.max_pending
                ),
                cfg.retry_after_ms,
            )
            .to_json()
            .to_string(),
        );
        return;
    }
    match engine.enqueue(req) {
        Ok(()) => {
            admitted.inc();
            responders.push((id, reply_tx));
        }
        Err(e) => {
            rejected.inc();
            let _ = reply_tx
                .send(ErrorResponse::permanent(Some(id), format!("{e:#}")).to_json().to_string());
        }
    }
}
