//! The serving engine: glues the quantized weight store, the KV-cache pool,
//! the continuous batcher and the stats sink around the transformer's
//! incremental decode. Two fronts:
//!
//! * [`Engine`] — synchronous: `enqueue` + `step`/`run_to_completion`, used
//!   by tests, benches and the CLI's self-driven load mode;
//! * [`Engine::spawn`] — a server thread + cloneable [`EngineClient`]s with
//!   a blocking `generate` RPC, used by the closed-loop load generator
//!   (`examples/serve_load.rs`). Worker parallelism *within* a decode wave
//!   splits the active sequences across scoped threads.

use crate::config::schema::ModelConfig;
use crate::nn::transformer::{DecodeCache, Params, Transformer};
use crate::serve::batcher::{ActiveSeq, Batcher};
use crate::serve::kvcache::KvCachePool;
use crate::serve::protocol::{GenRequest, GenResponse};
use crate::serve::stats::ServeStats;
use crate::serve::weights::WeightStore;
use anyhow::{bail, Context, Result};
use std::sync::mpsc;

/// Engine sizing/behaviour knobs.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Max sequences advanced per decode wave.
    pub max_batch: usize,
    /// KV-cache slots (≥ max_batch is typical; fewer throttles admission).
    pub kv_slots: usize,
    /// Worker threads per decode wave (1 = serial).
    pub threads: usize,
    /// Optional end-of-sequence token id.
    pub eos: Option<usize>,
    /// Per-sequence KV capacity in positions (clamped to the model seq_len).
    pub capacity: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            max_batch: 8,
            kv_slots: 8,
            threads: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            eos: None,
            capacity: usize::MAX,
        }
    }
}

/// The batched fake-quantized inference engine.
pub struct Engine {
    pub model: Transformer,
    pub params: Params,
    pool: KvCachePool,
    batcher: Batcher,
    pub stats: ServeStats,
    cfg: EngineConfig,
    capacity: usize,
}

impl Engine {
    /// Build from already-materialized params (e.g. a freshly initialized
    /// model, or `WeightStore::to_params`).
    pub fn new(model_cfg: ModelConfig, params: Params, cfg: EngineConfig) -> Engine {
        let model = Transformer::new(model_cfg.clone());
        let capacity = cfg.capacity.min(model_cfg.seq_len);
        let pool = KvCachePool::new(&model_cfg, cfg.kv_slots.max(1), capacity);
        let batcher = Batcher::new(cfg.max_batch.max(1));
        Engine { model, params, pool, batcher, stats: ServeStats::new(), cfg, capacity }
    }

    /// Build from a quantized snapshot: dequantize-on-load, then serve.
    pub fn from_store(store: &WeightStore, cfg: EngineConfig) -> Engine {
        Engine::new(store.cfg.clone(), store.to_params(), cfg)
    }

    /// Validate and queue a request.
    pub fn enqueue(&mut self, req: GenRequest) -> Result<()> {
        let vocab = self.model.cfg.vocab;
        if req.prompt.is_empty() {
            bail!("request {}: empty prompt", req.id);
        }
        if let Some(&bad) = req.prompt.iter().find(|&&t| t >= vocab) {
            bail!("request {}: prompt token {bad} out of vocab {vocab}", req.id);
        }
        if req.max_new_tokens == 0 {
            bail!("request {}: max_new_tokens must be > 0", req.id);
        }
        // positions consumed: the whole prompt plus every generated token
        // except the last (which is never fed back)
        let need = req.prompt.len() + req.max_new_tokens - 1;
        if need > self.capacity {
            bail!(
                "request {}: needs {need} KV positions, capacity is {}",
                req.id,
                self.capacity
            );
        }
        self.batcher.push(req);
        Ok(())
    }

    pub fn queued(&self) -> usize {
        self.batcher.pending_len()
    }

    pub fn active(&self) -> usize {
        self.batcher.active_len()
    }

    pub fn is_idle(&self) -> bool {
        self.batcher.is_idle()
    }

    /// KV pool occupancy diagnostics: (in_use, slots, high_water, bytes).
    pub fn kv_usage(&self) -> (usize, usize, usize, usize) {
        (self.pool.in_use(), self.pool.n_slots(), self.pool.high_water(), self.pool.bytes())
    }

    /// One engine iteration: admit from the queue, advance every active
    /// sequence by one position (parallel across workers), retire finished
    /// sequences. Returns completions.
    pub fn step(&mut self) -> Vec<GenResponse> {
        self.batcher.admit(&mut self.pool);
        let n = self.batcher.active.len();
        if n == 0 {
            return Vec::new();
        }
        // stamp the wave BEFORE the compute so wall-clock throughput
        // includes the first wave's work
        self.stats.record_wave(n);
        // check the active slots' caches out of the pool so each worker
        // thread gets exclusive &mut access to its sequences' state
        let slots: Vec<usize> = self.batcher.active.iter().map(|s| s.slot).collect();
        let mut caches: Vec<DecodeCache> = slots.iter().map(|&id| self.pool.take(id)).collect();
        {
            let model = &self.model;
            let params = &self.params;
            let eos = self.cfg.eos;
            let mut work: Vec<(&mut ActiveSeq, &mut DecodeCache)> =
                self.batcher.active.iter_mut().zip(caches.iter_mut()).collect();
            let n_threads = self.cfg.threads.clamp(1, work.len());
            if n_threads == 1 {
                for (seq, cache) in work.iter_mut() {
                    advance(model, params, seq, cache, eos);
                }
            } else {
                let chunk = work.len().div_ceil(n_threads);
                std::thread::scope(|sc| {
                    for part in work.chunks_mut(chunk) {
                        sc.spawn(move || {
                            for (seq, cache) in part.iter_mut() {
                                advance(model, params, seq, cache, eos);
                            }
                        });
                    }
                });
            }
        }
        for (id, cache) in slots.into_iter().zip(caches) {
            self.pool.put_back(id, cache);
        }
        let done = self.batcher.retire(&mut self.pool);
        for r in &done {
            self.stats.record_completion(r);
        }
        done
    }

    /// Drive the engine until queue and batch drain; returns all
    /// completions in finish order.
    pub fn run_to_completion(&mut self) -> Vec<GenResponse> {
        let mut out = Vec::new();
        while !self.is_idle() {
            out.extend(self.step());
        }
        out
    }

    /// Start a server thread; returns a handle whose clients issue blocking
    /// `generate` calls. Dropping the handle and every client stops the
    /// server once in-flight work drains.
    pub fn spawn(self) -> EngineHandle {
        let (tx, rx) = mpsc::channel::<(GenRequest, mpsc::Sender<GenResponse>)>();
        let join = std::thread::spawn(move || serve_loop(self, rx));
        EngineHandle { tx: Some(tx), join }
    }
}

/// Advance one sequence by one decode position.
fn advance(
    model: &Transformer,
    params: &Params,
    seq: &mut ActiveSeq,
    cache: &mut DecodeCache,
    eos: Option<usize>,
) {
    let token = seq.next_input();
    let logits = model.decode_step(params, token, cache);
    seq.absorb(&logits, eos);
}

fn serve_loop(
    mut engine: Engine,
    rx: mpsc::Receiver<(GenRequest, mpsc::Sender<GenResponse>)>,
) -> ServeStats {
    let mut responders: Vec<(u64, mpsc::Sender<GenResponse>)> = Vec::new();
    let mut disconnected = false;
    loop {
        // block for work when idle; otherwise just drain whatever arrived
        if engine.is_idle() && !disconnected {
            match rx.recv() {
                Ok((req, resp_tx)) => accept(&mut engine, &mut responders, req, resp_tx),
                Err(_) => disconnected = true,
            }
        }
        loop {
            match rx.try_recv() {
                Ok((req, resp_tx)) => accept(&mut engine, &mut responders, req, resp_tx),
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    disconnected = true;
                    break;
                }
            }
        }
        for resp in engine.step() {
            if let Some(i) = responders.iter().position(|(id, _)| *id == resp.id) {
                let (_, tx) = responders.swap_remove(i);
                let _ = tx.send(resp);
            }
        }
        if disconnected && engine.is_idle() {
            return engine.stats;
        }
    }
}

fn accept(
    engine: &mut Engine,
    responders: &mut Vec<(u64, mpsc::Sender<GenResponse>)>,
    req: GenRequest,
    resp_tx: mpsc::Sender<GenResponse>,
) {
    let id = req.id;
    // responses route back by request id, so a second in-flight request
    // with the same id would be misdelivered — reject it up front
    if responders.iter().any(|(rid, _)| *rid == id) {
        return; // dropping resp_tx errors the client's recv
    }
    match engine.enqueue(req) {
        Ok(()) => responders.push((id, resp_tx)),
        Err(_) => drop(resp_tx), // client's recv errors: request rejected
    }
}

/// Handle to a spawned engine thread.
pub struct EngineHandle {
    tx: Option<mpsc::Sender<(GenRequest, mpsc::Sender<GenResponse>)>>,
    join: std::thread::JoinHandle<ServeStats>,
}

impl EngineHandle {
    /// A cloneable client for issuing blocking generate calls.
    pub fn client(&self) -> EngineClient {
        EngineClient { tx: self.tx.as_ref().expect("handle already shut down").clone() }
    }

    /// Stop accepting requests, wait for in-flight work, return the stats.
    /// All [`EngineClient`]s must be dropped for the server to exit.
    pub fn shutdown(mut self) -> ServeStats {
        self.tx.take(); // close our sender
        self.join.join().expect("engine thread panicked")
    }
}

/// Cloneable blocking client to a spawned engine.
#[derive(Clone)]
pub struct EngineClient {
    tx: mpsc::Sender<(GenRequest, mpsc::Sender<GenResponse>)>,
}

impl EngineClient {
    /// Submit a request and block until its response (closed-loop client).
    /// Request ids must be unique among in-flight requests; a concurrent
    /// duplicate id is rejected (this call returns an error).
    pub fn generate(&self, req: GenRequest) -> Result<GenResponse> {
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send((req, rtx))
            .ok()
            .context("engine is shut down")?;
        rrx.recv().ok().context("request rejected or engine stopped")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::schema::Arch;

    fn tiny_engine(max_batch: usize, kv_slots: usize, threads: usize) -> Engine {
        let cfg = ModelConfig::tiny(Arch::Gpt2);
        let model = Transformer::new(cfg.clone());
        let params = model.init_params(3);
        Engine::new(
            cfg,
            params,
            EngineConfig { max_batch, kv_slots, threads, eos: None, capacity: usize::MAX },
        )
    }

    #[test]
    fn single_request_greedy_matches_direct_decode() {
        let mut e = tiny_engine(4, 4, 1);
        let prompt = vec![5usize, 9, 23];
        e.enqueue(GenRequest::greedy(1, prompt.clone(), 6)).unwrap();
        let out = e.run_to_completion();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].tokens.len(), 6);

        // reference: direct greedy loop over decode_step
        let mut cache = DecodeCache::new(&e.model.cfg, 64);
        let mut fed: Vec<usize> = prompt.clone();
        let mut generated = Vec::new();
        for i in 0.. {
            let logits = e.model.decode_step(&e.params, fed[i], &mut cache);
            if i + 1 < fed.len() {
                continue;
            }
            let mut best = 0;
            for (c, &v) in logits.iter().enumerate() {
                if v > logits[best] {
                    best = c;
                }
            }
            generated.push(best);
            if generated.len() == 6 {
                break;
            }
            fed.push(best);
        }
        assert_eq!(out[0].tokens, generated);
    }

    #[test]
    fn concurrent_requests_batch_and_all_complete() {
        let mut e = tiny_engine(4, 4, 2);
        for id in 0..6 {
            e.enqueue(GenRequest::greedy(id, vec![(id as usize) % 50 + 1, 2, 3], 4 + id as usize % 3))
                .unwrap();
        }
        let out = e.run_to_completion();
        assert_eq!(out.len(), 6);
        for r in &out {
            assert!(!r.tokens.is_empty());
            assert!(r.total_s >= 0.0 && r.ttft_s >= 0.0);
        }
        assert!(e.stats.max_occupancy() > 1, "continuous batching never batched");
        assert_eq!(e.stats.completed, 6);
        let (in_use, slots, high_water, bytes) = e.kv_usage();
        assert_eq!(in_use, 0);
        assert_eq!(slots, 4);
        assert!(high_water > 1);
        assert!(bytes > 0);
    }

    #[test]
    fn batching_is_transparent_to_results() {
        // the same greedy requests must produce identical tokens whether
        // served one-at-a-time or continuously batched on worker threads
        let reqs: Vec<GenRequest> =
            (0..5).map(|id| GenRequest::greedy(id, vec![1 + id as usize * 7, 4], 5)).collect();
        let mut serial = tiny_engine(1, 1, 1);
        let mut batched = tiny_engine(4, 4, 2);
        for r in &reqs {
            serial.enqueue(r.clone()).unwrap();
            batched.enqueue(r.clone()).unwrap();
        }
        let mut a = serial.run_to_completion();
        let mut b = batched.run_to_completion();
        a.sort_by_key(|r| r.id);
        b.sort_by_key(|r| r.id);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.tokens, y.tokens, "req {}", x.id);
        }
        assert_eq!(serial.stats.max_occupancy(), 1);
        assert!(batched.stats.max_occupancy() > 1);
    }

    #[test]
    fn invalid_requests_rejected() {
        let mut e = tiny_engine(2, 2, 1);
        assert!(e.enqueue(GenRequest::greedy(1, vec![], 4)).is_err());
        assert!(e.enqueue(GenRequest::greedy(2, vec![9999], 4)).is_err());
        assert!(e.enqueue(GenRequest::greedy(3, vec![1], 0)).is_err());
        let too_long = vec![1usize; 200]; // tiny seq_len is 64
        assert!(e.enqueue(GenRequest::greedy(4, too_long, 4)).is_err());
        assert!(e.is_idle());
    }

    #[test]
    fn engine_from_store_serves_quantized_weights() {
        let cfg = ModelConfig::tiny(Arch::Gpt2);
        let model = Transformer::new(cfg.clone());
        let params = model.init_params(4);
        let store = WeightStore::from_params(
            &params,
            &cfg,
            crate::quant::resolve("fp8_e3m4").unwrap(),
            4,
        )
        .unwrap();
        let mut e = Engine::from_store(&store, EngineConfig::default());
        e.enqueue(GenRequest::greedy(1, vec![2, 3, 4], 5)).unwrap();
        let out = e.run_to_completion();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].tokens.len(), 5);
    }

    #[test]
    fn spawned_engine_serves_concurrent_clients() {
        let handle = tiny_engine(4, 4, 2).spawn();
        let mut joins = Vec::new();
        for c in 0..3u64 {
            let client = handle.client();
            joins.push(std::thread::spawn(move || {
                let mut lens = Vec::new();
                for k in 0..2u64 {
                    let id = c * 100 + k;
                    let resp = client
                        .generate(GenRequest::greedy(id, vec![1 + c as usize, 2], 3))
                        .unwrap();
                    assert_eq!(resp.id, id);
                    lens.push(resp.tokens.len());
                }
                lens
            }));
        }
        for j in joins {
            assert_eq!(j.join().unwrap(), vec![3, 3]);
        }
        let stats = handle.shutdown();
        assert_eq!(stats.completed, 6);
    }

    #[test]
    fn temperature_sampling_reproducible_per_seed() {
        let mk = || {
            let mut e = tiny_engine(2, 2, 1);
            let req = GenRequest {
                id: 1,
                prompt: vec![4, 5],
                max_new_tokens: 8,
                temperature: 0.9,
                top_k: 20,
                seed: 1234,
            };
            e.enqueue(req).unwrap();
            e.run_to_completion().remove(0).tokens
        };
        assert_eq!(mk(), mk());
    }
}
